//! Bench: LLM serving cost model (Fig 12 speedups + Fig 13 energy).

use cuda_myth::config::DeviceKind;
use cuda_myth::harness;
use cuda_myth::models::llama::{self, LlamaConfig};
use cuda_myth::util::benchkit::{black_box, Bencher};

fn main() {
    for id in ["fig12", "fig13"] {
        for r in harness::run_experiment(id).unwrap() {
            r.print();
        }
    }
    let mut b = Bencher::new();
    let cfg8 = LlamaConfig::llama31_8b();
    let cfg70 = LlamaConfig::llama31_70b();
    b.bench("serve_fixed 8B b64 out400 (both devices)", || {
        black_box(llama::serve_fixed(&cfg8, DeviceKind::Gaudi2, 64, 100, 400, 1));
        black_box(llama::serve_fixed(&cfg8, DeviceKind::A100, 64, 100, 400, 1));
    });
    b.bench("serve_fixed 70B tp8 b64 out400", || {
        black_box(llama::serve_fixed(&cfg70, DeviceKind::Gaudi2, 64, 100, 400, 8))
    });
    b.bench("decode_step_cost 8B b64 kv4096", || {
        black_box(llama::decode_step_cost(&cfg8, DeviceKind::Gaudi2, 64, 4096, 1))
    });
    b.finish("llm");
}
