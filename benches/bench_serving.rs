//! Bench: the serving engine's own hot paths — the §Perf targets of the
//! L3 coordinator (scheduler step, block-manager churn, layout builds,
//! end-to-end engine episodes). The engine overhead must be negligible
//! against simulated step times (~ms).

use cuda_myth::config::ServingConfig;
use cuda_myth::models::llama::LlamaConfig;
use cuda_myth::serving::block_table::{BlockList, BlockTable};
use cuda_myth::serving::engine::{Engine, SimBackend};
use cuda_myth::serving::kv_cache::{EvictionPolicy, KvBlockManager};
use cuda_myth::serving::request::Request;
use cuda_myth::serving::scheduler::{Scheduler, Step};
use cuda_myth::util::benchkit::{black_box, Bencher};
use cuda_myth::workload::DynamicSonnet;

fn main() {
    let mut b = Bencher::new();

    b.bench("kv manager alloc/free churn (64 seqs)", || {
        let mut m = KvBlockManager::new(4096, 128, 0.01);
        for i in 0..64u64 {
            m.allocate(i, 1024 + (i as usize % 7) * 128).unwrap();
        }
        for i in 0..64u64 {
            m.free(i);
        }
        black_box(m.num_free())
    });

    b.bench("prefix cache acquire/release churn (32 groups, LRU evict)", || {
        let mut m = KvBlockManager::new(4096, 128, 0.01)
            .with_prefix_cache(64, EvictionPolicy::Lru);
        for round in 0..4u64 {
            for g in 0..32u64 {
                let _ = m.acquire_prefix(g, 256 + (g as usize % 5) * 128, 1.0, 8);
                if round % 2 == 0 {
                    m.release_prefix(g);
                }
            }
        }
        while m.evict_one_idle_prefix() {}
        black_box(m.prefix_stats().evictions)
    });

    let mut mgr = KvBlockManager::new(4096, 128, 0.0);
    let ids: Vec<u64> = (0..64).collect();
    for &i in &ids {
        mgr.allocate(i, 512 + (i as usize % 13) * 256).unwrap();
    }
    b.bench("BlockTable::build (64 seqs)", || black_box(BlockTable::build(&mgr, &ids)));
    b.bench("BlockList::build (64 seqs)", || black_box(BlockList::build(&mgr, &ids)));

    b.bench("scheduler full episode (32 reqs)", || {
        let cfg = ServingConfig { num_blocks: 2048, max_decode_batch: 32, ..Default::default() };
        let mut s = Scheduler::new(cfg);
        for i in 0..32u64 {
            s.submit(Request::new(i, 128, 32, 0.0));
        }
        let mut n = 0u64;
        loop {
            match s.schedule() {
                Step::Prefill(_) => {}
                Step::Decode(ids) => {
                    n += 1;
                    s.complete_decode(&ids, n as f64);
                }
                Step::Idle => break,
            }
        }
        black_box(n)
    });

    b.bench("engine e2e episode (48 dynamic reqs, sim backend)", || {
        let cfg = ServingConfig { num_blocks: 8192, max_decode_batch: 32, ..Default::default() };
        let backend = SimBackend::new(LlamaConfig::llama31_8b(), &cfg);
        let mut e = Engine::new(cfg, backend);
        for r in DynamicSonnet::default().generate(48, f64::INFINITY, 9) {
            e.submit(r);
        }
        black_box(e.run_to_completion())
    });

    b.finish("serving");
}
