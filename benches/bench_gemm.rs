//! Bench: GEMM model (Fig 4 roofline, Fig 5 heatmaps, Fig 7 geometry).
//! Regenerates the paper series and times the simulator hot path.

use cuda_myth::config::DeviceKind;
use cuda_myth::harness;
use cuda_myth::ops::gemm;
use cuda_myth::sim::Dtype;
use cuda_myth::util::benchkit::{black_box, Bencher};

fn main() {
    // Regenerate the paper figures this bench covers.
    for id in ["fig4", "fig5", "fig7"] {
        for r in harness::run_experiment(id).unwrap() {
            r.print();
        }
    }
    // Time the hot paths.
    let mut b = Bencher::new();
    b.bench("mme::run_gemm 8192^3", || {
        black_box(gemm::run(DeviceKind::Gaudi2, 8192, 8192, 8192, Dtype::Bf16))
    });
    b.bench("tensor_core::run_gemm 8192^3", || {
        black_box(gemm::run(DeviceKind::A100, 8192, 8192, 8192, Dtype::Bf16))
    });
    b.bench("fig4 full sweep (both devices)", || {
        for (m, k, n) in gemm::fig4_shapes() {
            black_box(gemm::run(DeviceKind::Gaudi2, m, k, n, Dtype::Bf16));
            black_box(gemm::run(DeviceKind::A100, m, k, n, Dtype::Bf16));
        }
    });
    b.finish("gemm");
}
