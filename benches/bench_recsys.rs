//! Bench: RecSys end-to-end model (Fig 11) + embedding operators (Fig 15).

use cuda_myth::config::DeviceKind;
use cuda_myth::harness;
use cuda_myth::models::dlrm::{self, DlrmConfig};
use cuda_myth::ops::embedding::{self, rm2_work, EmbeddingImpl};
use cuda_myth::sim::Dtype;
use cuda_myth::util::benchkit::{black_box, Bencher};

fn main() {
    for id in ["fig11", "fig15"] {
        for r in harness::run_experiment(id).unwrap() {
            r.print();
        }
    }
    let mut b = Bencher::new();
    let rm1 = DlrmConfig::rm1();
    b.bench("dlrm::serve RM1 b4096 d128 (both devices)", || {
        black_box(dlrm::serve(&rm1, DeviceKind::Gaudi2, 4096, 128));
        black_box(dlrm::serve(&rm1, DeviceKind::A100, 4096, 128));
    });
    b.bench("embedding fig15 grid x 4 impls", || {
        for (batch, v) in embedding::fig15_grid() {
            for imp in [
                EmbeddingImpl::GaudiSdkSingleTable,
                EmbeddingImpl::GaudiSingleTable,
                EmbeddingImpl::GaudiBatchedTable,
                EmbeddingImpl::A100Fbgemm,
            ] {
                black_box(embedding::run(imp, rm2_work(batch, v), Dtype::Fp32));
            }
        }
    });
    b.finish("recsys");
}
