//! Bench: PagedAttention operators + the Fig 17 case study.

use cuda_myth::harness;
use cuda_myth::ops::attention::{run as attn, PagedAttnImpl, PagedAttnWork};
use cuda_myth::util::benchkit::{black_box, Bencher};

fn main() {
    for r in harness::run_experiment("fig17").unwrap() {
        r.print();
    }
    let mut b = Bencher::new();
    let w = PagedAttnWork::llama8b(32, 4096);
    for imp in [PagedAttnImpl::GaudiVllmBase, PagedAttnImpl::GaudiVllmOpt, PagedAttnImpl::A100Paged]
    {
        b.bench(&format!("paged attention model: {}", imp.name()), || {
            black_box(attn(imp, w))
        });
    }
    b.bench("fig17a sweep (16 points x 2 impls)", || {
        for &s in &[512usize, 1024, 2048, 4096] {
            for &bsz in &[8usize, 16, 32, 64] {
                let w = PagedAttnWork::llama8b(bsz, s);
                black_box(attn(PagedAttnImpl::GaudiVllmBase, w));
                black_box(attn(PagedAttnImpl::GaudiVllmOpt, w));
            }
        }
    });
    b.finish("vllm");
}
