//! Bench: gather/scatter memory model (Fig 9).

use cuda_myth::config::DeviceKind;
use cuda_myth::harness;
use cuda_myth::sim::memory::{self, AccessDir};
use cuda_myth::util::benchkit::{black_box, Bencher};

fn main() {
    for r in harness::run_experiment("fig9").unwrap() {
        r.print();
    }
    let mut b = Bencher::new();
    let g = DeviceKind::Gaudi2.spec();
    let a = DeviceKind::A100.spec();
    b.bench("fig9 full sweep (both devices)", || {
        for &v in &[16.0, 32.0, 64.0, 128.0, 256.0, 512.0, 1024.0, 2048.0] {
            for &f in &[0.01, 0.1, 0.5, 1.0] {
                black_box(memory::random_access(&g, AccessDir::Gather, 4e6 * f, v));
                black_box(memory::random_access(&a, AccessDir::Gather, 4e6 * f, v));
                black_box(memory::random_access(&g, AccessDir::Scatter, 4e6 * f, v));
                black_box(memory::random_access(&a, AccessDir::Scatter, 4e6 * f, v));
            }
        }
    });
    b.finish("memory");
}
