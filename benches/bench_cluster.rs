//! Bench: the cluster simulator's own hot paths — the indexed
//! discrete-event core must stay negligible against the simulated step
//! times it dispatches, or fleet sweeps (`repro run cluster`) stop being
//! interactive. The large-fleet cases (100 replicas x 10k/100k streamed
//! arrivals) are where the heap dispatch separates from the old
//! O(replicas)-per-event scan; `repro run sim-speed` tracks the same
//! ratio as a gated artifact. Runs under the in-tree `util::benchkit`
//! harness (the repo's criterion replacement; `cargo bench --bench
//! bench_cluster`).

use cuda_myth::config::{DeviceKind, ServingConfig};
use cuda_myth::models::llama::LlamaConfig;
use cuda_myth::serving::cluster::ClusterSim;
use cuda_myth::serving::router::{RoutePolicy, Router};
use cuda_myth::util::benchkit::{black_box, Bencher};
use cuda_myth::workload::{DynamicSonnet, OpenLoopTrace};

fn episode(replicas: usize, policy: RoutePolicy, n_requests: usize) -> usize {
    let cfg = ServingConfig {
        replicas,
        route_policy: policy,
        max_decode_batch: 16,
        num_blocks: 4096,
        ..Default::default()
    };
    let mut sim = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
    sim.submit_all(DynamicSonnet::default().generate(n_requests, 60.0, 17));
    let s = sim.run_to_completion();
    s.requests
}

fn mixed_episode(n_requests: usize) -> usize {
    let cfg = ServingConfig {
        route_policy: RoutePolicy::PrefixAffinity,
        max_decode_batch: 16,
        num_blocks: 4096,
        ..Default::default()
    }
    .with_fleet(vec![
        DeviceKind::Gaudi2,
        DeviceKind::Gaudi2,
        DeviceKind::A100,
        DeviceKind::A100,
    ]);
    let mut sim = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
    sim.submit_all(DynamicSonnet::default().with_prefix_groups(8).generate(n_requests, 60.0, 17));
    let s = sim.run_to_completion();
    s.requests
}

/// Large-fleet episode: 100 replicas fed a lazy short-decode stream, the
/// shape the indexed event core exists for (O(log) dispatch, O(open
/// requests) memory).
fn large_fleet_episode(replicas: usize, n_requests: usize) -> usize {
    let cfg = ServingConfig {
        replicas,
        route_policy: RoutePolicy::LeastLoaded,
        max_queued: 100_000,
        max_decode_batch: 16,
        num_blocks: 2048,
        ..Default::default()
    };
    let mut sim = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
    let w = DynamicSonnet { max_input: 64, max_output: 8, ..Default::default() };
    sim.feed(w.stream(n_requests, n_requests as f64 / 600.0, 17));
    let s = sim.run_to_completion();
    s.requests
}

fn main() {
    let mut b = Bencher::new();

    b.bench("router route/complete churn (least-loaded, 4 replicas)", || {
        let mut r = Router::new(RoutePolicy::LeastLoaded, 4, 1 << 20);
        let reqs = DynamicSonnet::default().generate(256, f64::INFINITY, 3);
        let mut placed = Vec::with_capacity(reqs.len());
        for req in &reqs {
            placed.push(r.route(req).unwrap());
        }
        for (idx, req) in placed.iter().zip(&reqs) {
            r.complete(*idx, req);
        }
        black_box(r.queued())
    });

    b.bench("open-loop trace generation (1k requests)", || {
        black_box(OpenLoopTrace::new(200.0, 5.0).generate(23).len())
    });

    for &n in &[1usize, 2, 4] {
        b.bench(
            &format!("cluster e2e episode ({n} replica(s), 32 reqs, round-robin)"),
            || black_box(episode(n, RoutePolicy::RoundRobin, 32)),
        );
    }

    b.bench("cluster e2e episode (4 replicas, 32 reqs, least-loaded)", || {
        black_box(episode(4, RoutePolicy::LeastLoaded, 32))
    });

    b.bench("router route/complete churn (prefix-affinity, 4 costs, 8 groups)", || {
        let mut r = Router::with_costs(
            RoutePolicy::PrefixAffinity,
            vec![1.0, 1.0, 1.7, 1.7],
            1 << 20,
        );
        let reqs = DynamicSonnet::default().with_prefix_groups(8).generate(256, f64::INFINITY, 3);
        let mut placed = Vec::with_capacity(reqs.len());
        for req in &reqs {
            placed.push(r.route(req).unwrap());
        }
        for (idx, req) in placed.iter().zip(&reqs) {
            r.complete(*idx, req);
        }
        black_box(r.queued())
    });

    b.bench("mixed-fleet e2e episode (2x Gaudi-2 + 2x A100, 32 reqs, prefix-affinity)", || {
        black_box(mixed_episode(32))
    });

    b.finish("cluster");

    // The scale cases run under quick settings: each iteration is a full
    // streamed episode, so default min-time targets would take minutes.
    let mut big = Bencher::quick();
    big.bench("large-fleet episode (100 replicas, 10k streamed arrivals)", || {
        black_box(large_fleet_episode(100, 10_000))
    });
    big.bench("large-fleet episode (100 replicas, 100k streamed arrivals)", || {
        black_box(large_fleet_episode(100, 100_000))
    });
    big.finish("cluster-large");
}
