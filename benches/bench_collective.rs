//! Bench: collective-communication model (Fig 10, six collectives).

use cuda_myth::config::DeviceKind;
use cuda_myth::harness;
use cuda_myth::sim::collective::{self, ALL_COLLECTIVES};
use cuda_myth::util::benchkit::{black_box, Bencher};

fn main() {
    for r in harness::run_experiment("fig10").unwrap() {
        r.print();
    }
    let mut b = Bencher::new();
    b.bench("fig10 full sweep (6 colls x 3 sizes x 2 devices x 3 ns)", || {
        for coll in ALL_COLLECTIVES {
            for kind in [DeviceKind::Gaudi2, DeviceKind::A100] {
                for n in [2usize, 4, 8] {
                    for s in [2e3, 2e6, 32e6] {
                        black_box(collective::run(kind, coll, n, s));
                    }
                }
            }
        }
    });
    b.finish("collective");
}
