//! Bench: STREAM microbenchmark models (Fig 8, all four panels).

use cuda_myth::config::DeviceKind;
use cuda_myth::harness;
use cuda_myth::sim::tpc::{self, StreamOp};
use cuda_myth::sim::Dtype;
use cuda_myth::util::benchkit::{black_box, Bencher};

fn main() {
    for r in harness::run_experiment("fig8").unwrap() {
        r.print();
    }
    let spec = DeviceKind::Gaudi2.spec();
    let mut b = Bencher::new();
    b.bench("single_tpc_throughput sweep", || {
        for u in [1usize, 2, 4, 8, 16] {
            for g in [2.0, 64.0, 256.0, 2048.0] {
                black_box(tpc::single_tpc_throughput(StreamOp::Triad, u, g, Dtype::Bf16));
            }
        }
    });
    b.bench("weak_scaled_throughput 24 tpcs x 3 ops", || {
        for op in [StreamOp::Add, StreamOp::Scale, StreamOp::Triad] {
            for n in 1..=24 {
                black_box(tpc::weak_scaled_throughput(&spec, op, n, Dtype::Bf16));
            }
        }
    });
    b.finish("stream");
}
