//! Minimal in-tree shim for the `anyhow` crate — the build container has
//! no network access, so the real crate cannot be fetched. Implements the
//! subset this repository uses: [`Error`], [`Result`], the [`anyhow!`],
//! [`bail!`] and [`ensure!`] macros, and the [`Context`] extension trait
//! for `Result` and `Option`.
//!
//! Like the real crate, `Error` deliberately does **not** implement
//! `std::error::Error` — that is what allows the blanket
//! `From<E: std::error::Error>` conversion to coexist with the reflexive
//! `From<Error>` impl.

use std::fmt;

/// A context-carrying error: `msgs[0]` is the outermost context, the last
/// entry is the root cause.
pub struct Error {
    msgs: Vec<String>,
}

impl Error {
    pub fn new(msg: String) -> Error {
        Error { msgs: vec![msg] }
    }

    pub fn msg(msg: impl fmt::Display) -> Error {
        Error::new(msg.to_string())
    }

    /// Wrap with an outer context message (what `.context(...)` does).
    pub fn context(mut self, msg: impl fmt::Display) -> Error {
        self.msgs.insert(0, msg.to_string());
        self
    }

    /// The error chain, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.msgs.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.msgs.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` renders the whole chain, like real anyhow.
            write!(f, "{}", self.msgs.join(": "))
        } else {
            write!(f, "{}", self.msgs.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.msgs.split_first() {
            None => Ok(()),
            Some((head, rest)) => {
                write!(f, "{head}")?;
                if !rest.is_empty() {
                    write!(f, "\n\nCaused by:")?;
                    for m in rest {
                        write!(f, "\n    {m}")?;
                    }
                }
                Ok(())
            }
        }
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        Error { msgs }
    }
}

/// `anyhow::Result<T>`, defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::new(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Error::from(io_err()).context("loading artifact");
        assert_eq!(format!("{e}"), "loading artifact");
        assert_eq!(format!("{e:#}"), "loading artifact: missing");
    }

    #[test]
    fn macros_and_context() {
        fn inner(fail: bool) -> Result<u32> {
            ensure!(!fail, "failed with code {}", 7);
            Ok(1)
        }
        assert_eq!(inner(false).unwrap(), 1);
        assert_eq!(format!("{}", inner(true).unwrap_err()), "failed with code 7");

        let got: Result<u32> = None.context("nothing here");
        assert_eq!(format!("{}", got.unwrap_err()), "nothing here");

        let e = anyhow!("x = {}", 3);
        assert_eq!(e.root_cause(), "x = 3");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{}", f().unwrap_err()), "missing");
    }
}
