//! Stub for the `xla` crate (PJRT bindings). The build container has
//! neither network access nor an XLA/PJRT shared library, so this crate
//! provides the exact API surface `runtime::Runtime` compiles against
//! while reporting the PJRT client as unavailable at run time:
//! `PjRtClient::cpu()` returns [`Error::Unavailable`], so every caller
//! degrades gracefully (the real-numerics tests in
//! `rust/tests/integration_runtime.rs` already skip when artifacts are
//! missing, and `repro real-serve` prints the error and exits non-zero).
//!
//! Swapping in the real `xla` crate (e.g. LaurentMazare/xla-rs against
//! `xla_extension`) requires no source changes above this layer — only a
//! `Cargo.toml` dependency edit.

use std::fmt;

/// Errors surfaced by the stub. `Unavailable` is the only one produced in
/// practice; `Other` exists so richer bindings can map onto the same type.
#[derive(Debug, Clone)]
pub enum Error {
    /// The stub has no PJRT runtime to execute on.
    Unavailable(&'static str),
    Other(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what} unavailable: built against the in-tree xla stub \
                 (no PJRT runtime in this environment)"
            ),
            Error::Other(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can carry across the boundary.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// A host-side tensor value. The stub keeps no storage — nothing can be
/// executed, so nothing ever needs to be read back.
#[derive(Debug, Clone)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T: NativeType>(_values: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal { _private: () })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::Unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::Unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module (stub: never constructed successfully).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation handle.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device-resident buffer returned by an execution.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// The PJRT client. `cpu()` is the stub's front door and always fails.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unavailable("PJRT CPU client"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("unavailable"), "{msg}");
        assert!(msg.contains("stub"), "{msg}");
    }

    #[test]
    fn literal_construction_is_cheap_and_reads_fail() {
        let lit = Literal::vec1(&[1.0f32, 2.0]).reshape(&[2, 1]).unwrap();
        assert!(lit.to_vec::<f32>().is_err());
        assert!(lit.to_tuple().is_err());
    }
}
