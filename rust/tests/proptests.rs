//! Property-based tests (in-tree harness, `util::proptest`) on coordinator
//! invariants: KV block manager conservation, scheduler safety, chaos-engine
//! determinism and request/token conservation, collective accounting, MME
//! geometry selection, and layout equivalence.

use cuda_myth::config::{DeviceKind, ServingConfig};
use cuda_myth::harness::cache_sweep::LegacyWarmBackend;
use cuda_myth::models::llama::LlamaConfig;
use cuda_myth::serving::block_table::{BlockList, BlockTable};
use cuda_myth::serving::engine::{Engine, SimBackend};
use cuda_myth::serving::kv_cache::{EvictionPolicy, KvBlockManager, PrefixAcquire};
use cuda_myth::serving::request::Request;
use cuda_myth::serving::router::{RoutePolicy, Router};
use cuda_myth::serving::scheduler::{Scheduler, Step};
use cuda_myth::workload::DynamicSonnet;
use cuda_myth::sim::collective::{self, Collective, ALL_COLLECTIVES};
use cuda_myth::sim::mme;
use cuda_myth::sim::Dtype;
use cuda_myth::util::prng::Rng;
use cuda_myth::util::proptest::{forall, Gen, PairOf, UsizeIn, VecOf};

#[test]
fn kv_manager_conserves_blocks_under_random_churn() {
    // Random alloc/grow/free sequences never double-allocate or leak.
    struct Ops;
    impl Gen for Ops {
        type Value = Vec<(u8, u64, usize)>; // (op, id, tokens)
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            (0..rng.range(1, 60))
                .map(|_| (rng.below(3) as u8, rng.below(8), rng.range(1, 2000) as usize))
                .collect()
        }
        fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
            if v.is_empty() {
                vec![]
            } else {
                vec![v[..v.len() / 2].to_vec(), v[..v.len() - 1].to_vec()]
            }
        }
    }
    forall(11, 300, &Ops, |ops| {
        let mut m = KvBlockManager::new(24, 128, 0.05);
        for &(op, id, tokens) in ops {
            match op {
                0 | 1 => {
                    let _ = m.allocate(id, tokens);
                }
                _ => m.free(id),
            }
            if !m.check_conservation() {
                return false;
            }
        }
        // Freeing every holder returns all blocks.
        let holders: Vec<u64> = m.holders().collect();
        for id in holders {
            m.free(id);
        }
        m.num_free() == m.num_blocks()
    });
}

#[test]
fn shared_prefix_conservation_under_random_churn() {
    // Random interleavings of prefix acquire/release, prefixed sequence
    // alloc, free and forced eviction: every physical block stays exactly
    // one of {free, exclusively owned, shared-resident}, the resident
    // total respects the budget, and releasing everything returns the
    // pool (free + exclusive + shared == total throughout).
    struct Ops;
    impl Gen for Ops {
        type Value = Vec<(u8, u64, usize)>; // (op, id/group, tokens)
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            (0..rng.range(1, 80))
                .map(|_| (rng.below(5) as u8, rng.below(6), rng.range(1, 1500) as usize))
                .collect()
        }
        fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
            if v.is_empty() {
                vec![]
            } else {
                vec![v[..v.len() / 2].to_vec(), v[..v.len() - 1].to_vec()]
            }
        }
    }
    forall(53, 200, &Ops, |ops| {
        for policy in EvictionPolicy::ALL {
            let mut m = KvBlockManager::new(48, 128, 0.0).with_prefix_cache(12, policy);
            // Outstanding pins per group (releases must balance acquires).
            let mut pins: Vec<u64> = Vec::new();
            let mut next_seq = 1000u64;
            for &(op, group, tokens) in ops {
                match op {
                    // Acquire a prefix pin (weight varies by group).
                    0 => {
                        let got = m.acquire_prefix(group, tokens.min(800), 1.0 + group as f64, 2);
                        if got != PrefixAcquire::Uncached {
                            pins.push(group);
                        }
                    }
                    // Release the oldest outstanding pin.
                    1 => {
                        if !pins.is_empty() {
                            m.release_prefix(pins.remove(0));
                        }
                    }
                    // A sequence sharing the group's front (if resident).
                    2 => {
                        let _ = m.allocate_prefixed(next_seq, tokens, Some(group));
                        next_seq += 1;
                    }
                    // Free a random-ish sequence.
                    3 => {
                        let holders: Vec<u64> = m.holders().collect();
                        if !holders.is_empty() {
                            m.free(holders[tokens % holders.len()]);
                        }
                    }
                    // Forced eviction attempt.
                    _ => {
                        m.evict_one_idle_prefix();
                    }
                }
                if !m.check_conservation() {
                    return false;
                }
                if m.prefix_resident_blocks() > 12 {
                    return false; // budget overrun
                }
            }
            // Drain everything: all blocks return except still-resident
            // shared prefixes, which eviction can fully reclaim once the
            // remaining pins are released.
            let holders: Vec<u64> = m.holders().collect();
            for id in holders {
                m.free(id);
            }
            for g in pins {
                m.release_prefix(g);
            }
            while m.evict_one_idle_prefix() {}
            if m.num_free() != m.num_blocks() || !m.check_conservation() {
                return false;
            }
        }
        true
    });
}

#[test]
fn pinned_prefixes_are_never_evicted() {
    // Whatever churn the cache sees, a group holding at least one pin
    // stays resident; only idle groups are eviction victims.
    forall(59, 200, &VecOf(PairOf(UsizeIn(0, 8), UsizeIn(64, 900)), 40), |ops| {
        for policy in EvictionPolicy::ALL {
            let mut m = KvBlockManager::new(64, 128, 0.0).with_prefix_cache(10, policy);
            // Group 0 is pinned once and never released.
            if m.acquire_prefix(0, 500, 1.0, 0) == PrefixAcquire::Uncached {
                return false; // empty cache must accept the first prefix
            }
            for &(group, tokens) in ops {
                // Other groups churn through acquire+release (idle).
                let g = 1 + group as u64;
                if m.acquire_prefix(g, tokens, 0.5 + group as f64, 0)
                    != PrefixAcquire::Uncached
                {
                    m.release_prefix(g);
                }
                if !m.prefix_resident(0) {
                    return false; // the pinned group vanished
                }
                if !m.check_conservation() {
                    return false;
                }
            }
        }
        true
    });
}

#[test]
fn lru_evicts_in_last_use_order() {
    // Acquire-and-release groups in a random order, then starve the
    // cache: victims must leave in exactly the order of their last use.
    forall(61, 300, &VecOf(UsizeIn(0, 5), 24), |touches| {
        let mut m = KvBlockManager::new(64, 128, 0.0).with_prefix_cache(64, EvictionPolicy::Lru);
        // Every group is 1 block (128 tokens), so sizes never confound order.
        let mut order: Vec<u64> = Vec::new(); // last-use order, oldest first
        for &g in touches {
            let g = g as u64;
            if m.acquire_prefix(g, 100, 1.0, 0) == PrefixAcquire::Uncached {
                return false;
            }
            m.release_prefix(g);
            order.retain(|&x| x != g);
            order.push(g);
        }
        // Evict until dry: victims follow the model's LRU order.
        let mut evicted: Vec<u64> = Vec::new();
        while m.evict_one_idle_prefix() {
            let gone: Vec<u64> =
                order.iter().copied().filter(|&g| !m.prefix_resident(g)).collect();
            // Exactly one more group disappeared, and it is the oldest
            // still-expected one.
            if gone.len() != evicted.len() + 1 {
                return false;
            }
            let newly = gone.iter().copied().find(|g| !evicted.contains(g)).unwrap();
            let expect = order.iter().copied().find(|g| !evicted.contains(g)).unwrap();
            if newly != expect {
                return false;
            }
            evicted.push(newly);
        }
        evicted.len() == order.len()
    });
}

#[test]
fn unbounded_cache_is_bitwise_equal_to_legacy_warm_set() {
    // Property over random workload shapes: at unbounded capacity (and
    // ample memory) "resident at admission" degenerates to "seen
    // before", so every per-request metric is the same f64 the deleted
    // `seen_prefixes` implementation produced.
    forall(
        67,
        12,
        &PairOf(PairOf(UsizeIn(6, 20), UsizeIn(1, 5)), UsizeIn(1, 1000)),
        |&((n, groups), seed)| {
            let trace = || {
                DynamicSonnet::default()
                    .with_prefix_groups(groups)
                    .generate(n, 30.0, seed as u64)
            };
            let unified_cfg = ServingConfig {
                num_blocks: 4096,
                max_decode_batch: 16,
                prefix_cache_blocks: 4096,
                ..Default::default()
            };
            let mut unified = Engine::new(
                unified_cfg.clone(),
                SimBackend::new(LlamaConfig::llama31_8b(), &unified_cfg),
            );
            let legacy_cfg = ServingConfig { prefix_cache_blocks: 0, ..unified_cfg.clone() };
            let mut legacy = Engine::new(
                legacy_cfg.clone(),
                LegacyWarmBackend::new(LlamaConfig::llama31_8b(), &legacy_cfg),
            );
            for r in trace() {
                unified.submit(r);
            }
            for r in trace() {
                legacy.submit(r);
            }
            unified.run_to_completion();
            legacy.run_to_completion();
            // Bitwise: the shared comparator behind every parity claim.
            unified.metrics.max_request_delta(&legacy.metrics) == 0.0
        },
    );
}

#[test]
fn single_class_configs_are_bitwise_equal_to_the_legacy_scalar_path() {
    // Property over random workload shapes (serving::qos): tagging a
    // trace across uniform-priority-0 classes — the degenerate class
    // structure every pre-refactor run implicitly had — must replay the
    // untagged single-default-class run per-request bitwise, through the
    // full cluster path (scheduler admission/preemption order, router
    // scoring, per-class metrics feedback).
    use cuda_myth::serving::cluster::ClusterSim;
    use cuda_myth::serving::qos::{ClassSet, TrafficClass};
    forall(
        71,
        10,
        &PairOf(PairOf(UsizeIn(8, 28), UsizeIn(1, 3)), UsizeIn(1, 1000)),
        |&((n, replicas), seed)| {
            let base = ServingConfig {
                replicas,
                route_policy: RoutePolicy::LeastLoaded,
                num_blocks: 2048,
                max_decode_batch: 12,
                ..Default::default()
            };
            let uniform = ClassSet::new(vec![
                TrafficClass::new("a", 0, 1.0, 0.1, 1.0),
                TrafficClass::new("b", 0, 0.4, 0.05, 3.0),
                TrafficClass::new("c", 0, 6.0, 0.4, 0.5),
            ])
            .unwrap();
            let run = |cfg: &ServingConfig, mix: Vec<(usize, usize)>| {
                let mut w = DynamicSonnet::default();
                if !mix.is_empty() {
                    w = w.with_class_mix(mix);
                }
                let mut sim = ClusterSim::new(cfg, LlamaConfig::llama31_8b());
                sim.submit_all(w.generate(n, 25.0, seed as u64));
                sim.run_to_completion();
                sim.fleet_metrics()
            };
            let single = run(&base, vec![]);
            let multi = run(
                &ServingConfig { classes: uniform, ..base.clone() },
                vec![(0, 2), (1, 1), (2, 1)],
            );
            single.max_request_delta(&multi) == 0.0
        },
    );
}

#[test]
fn preemption_never_victimizes_a_strictly_higher_priority_sequence() {
    // Property (serving::qos): whatever random mixed-class load hits a
    // memory-starved scheduler, every preemption victim has priority <=
    // every sequence still running at that moment — a higher class is
    // never recomputed while a lower class keeps its KV.
    use cuda_myth::serving::qos::ClassSet;
    forall(
        73,
        60,
        &VecOf(PairOf(PairOf(UsizeIn(64, 700), UsizeIn(4, 120)), UsizeIn(0, 2)), 14),
        |reqs| {
            let cfg = ServingConfig {
                classes: ClassSet::three_tier(),
                num_blocks: 12, // 12 x 128 tokens: heavy pressure
                max_decode_batch: 6,
                max_seq_len: 2048,
                watermark: 0.0,
                ..Default::default()
            };
            let mut s = Scheduler::new(cfg);
            let classes = ClassSet::three_tier();
            for (i, &((prompt, out), class)) in reqs.iter().enumerate() {
                let prompt = prompt.min(1500);
                let out = out.min(2048 - prompt).max(1);
                s.submit(Request::new(i as u64, prompt, out, 0.0).with_class(class));
            }
            let prio = |s: &Scheduler, id: u64| classes.priority_of(s.seq(id).req.class_id);
            let mut guard = 0;
            let mut finished: Vec<u64> = Vec::new();
            loop {
                guard += 1;
                if guard > 100_000 {
                    return false; // livelock
                }
                match s.schedule() {
                    Step::Decode(ids) => s.complete_decode(&ids, guard as f64),
                    Step::Prefill(ids) => {
                        if ids.is_empty() {
                            return false;
                        }
                    }
                    Step::Idle => break,
                }
                // Every victim of this step must be of the lowest
                // priority present: no still-running sequence may sit
                // strictly below any victim.
                for v in s.take_preempted() {
                    let vp = prio(&s, v);
                    if s.running_ids().iter().any(|&r| prio(&s, r) < vp) {
                        return false;
                    }
                }
                finished.extend(s.take_finished());
                if !s.kv.check_conservation() {
                    return false;
                }
            }
            // No request finishes twice, whatever preemption interleaving
            // the pressure produced.
            let n = finished.len();
            finished.sort_unstable();
            finished.dedup();
            n == finished.len() && s.kv.check_conservation()
        },
    );
}

#[test]
fn indexed_event_core_is_bitwise_equal_to_the_scan_loop_oracle() {
    // Property over random fleets, workloads and QoS mixes: the indexed
    // discrete-event core (heap-dispatched arrivals + replica wakes) must
    // replay the retained pre-refactor scan loop bit-for-bit — same
    // per-request metrics, same backpressure requeue count, same event
    // count, same prefix-cache counters. Small queue caps are drawn on
    // purpose so the requeue path's same-time ordering is exercised too.
    use cuda_myth::serving::cluster::ClusterSim;
    use cuda_myth::serving::qos::ClassSet;
    forall(
        79,
        10,
        &PairOf(
            PairOf(UsizeIn(6, 30), UsizeIn(1, 4)),
            PairOf(UsizeIn(1, 1000), PairOf(UsizeIn(0, 4), UsizeIn(4, 48))),
        ),
        |&((n, replicas), (seed, (groups, max_queued)))| {
            let classes = if seed % 2 == 0 { ClassSet::default() } else { ClassSet::three_tier() };
            let cfg = ServingConfig {
                replicas,
                route_policy: RoutePolicy::LeastLoaded,
                max_queued,
                num_blocks: 2048,
                max_decode_batch: 12,
                classes,
                ..Default::default()
            };
            let trace = || {
                let mut w = DynamicSonnet::default().with_prefix_groups(groups);
                if seed % 2 == 1 {
                    w = w.with_class_mix(vec![(0, 2), (1, 1), (2, 1)]);
                }
                w.generate(n, 10.0 + (seed % 50) as f64, seed as u64)
            };
            let mut indexed = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
            indexed.submit_all(trace());
            indexed.run_to_completion();
            let mut oracle = ClusterSim::new_scan_oracle(&cfg, LlamaConfig::llama31_8b());
            oracle.submit_all(trace());
            oracle.run_to_completion();
            indexed.fleet_metrics().max_request_delta(&oracle.fleet_metrics()) == 0.0
                && indexed.requeues == oracle.requeues
                && indexed.events() == oracle.events()
                && indexed.completed() == oracle.completed()
                && format!("{:?}", indexed.fleet_prefix_stats())
                    == format!("{:?}", oracle.fleet_prefix_stats())
        },
    );
}

#[test]
fn fault_schedules_replay_bitwise_given_the_seed() {
    // Property (serving::chaos): the same seed, schedule and trace replay
    // the whole chaotic run bit-for-bit — per-request metrics, event
    // count, and every chaos counter (crashes, requeues, hedges, shed).
    use cuda_myth::serving::chaos::FaultSchedule;
    use cuda_myth::serving::cluster::ClusterSim;
    use cuda_myth::serving::qos::ClassSet;
    forall(
        83,
        8,
        &PairOf(PairOf(UsizeIn(10, 30), UsizeIn(2, 4)), UsizeIn(1, 1000)),
        |&((n, replicas), seed)| {
            let schedule = FaultSchedule::random(seed as u64, replicas, 6.0);
            let cfg = ServingConfig {
                replicas,
                route_policy: RoutePolicy::LeastLoaded,
                num_blocks: 2048,
                max_decode_batch: 12,
                classes: ClassSet::three_tier(),
                hedge_after_s: 0.3,
                ..Default::default()
            };
            let run = || {
                let mut sim = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
                sim.install_chaos(&schedule);
                sim.submit_all(
                    DynamicSonnet::default()
                        .with_class_mix(vec![(0, 2), (1, 1), (2, 1)])
                        .generate(n, 12.0, seed as u64),
                );
                sim.run_to_completion();
                sim
            };
            let a = run();
            let b = run();
            a.fleet_metrics().max_request_delta(&b.fleet_metrics()) == 0.0
                && a.events() == b.events()
                && a.chaos_stats() == b.chaos_stats()
        },
    );
}

#[test]
fn macro_stepping_replays_the_micro_loop_bitwise() {
    // Property (serving::engine + cluster): the quiescent-window decode
    // macro-stepping fast path must replay the retained micro-step
    // oracle bit-for-bit — per-request metrics, token counts, requeues,
    // event counts, chaos counters and prefix-cache stats — across
    // random fleets, class mixes, queue caps, chaos schedules and hedge
    // timers. The burst accumulator proves the property is not vacuous:
    // across the sampled draws the fast path must actually engage.
    use cuda_myth::serving::chaos::FaultSchedule;
    use cuda_myth::serving::cluster::ClusterSim;
    use cuda_myth::serving::qos::ClassSet;
    let bursts = std::cell::Cell::new(0u64);
    forall(
        103,
        10,
        &PairOf(
            PairOf(UsizeIn(8, 30), UsizeIn(1, 4)),
            PairOf(UsizeIn(1, 1000), PairOf(UsizeIn(0, 4), UsizeIn(4, 48))),
        ),
        |&((n, replicas), (seed, (groups, max_queued)))| {
            let classes = if seed % 2 == 0 { ClassSet::default() } else { ClassSet::three_tier() };
            let cfg = ServingConfig {
                replicas,
                route_policy: RoutePolicy::LeastLoaded,
                max_queued,
                num_blocks: 2048,
                max_decode_batch: 12,
                classes,
                hedge_after_s: if seed % 3 == 0 { 0.3 } else { 0.0 },
                ..Default::default()
            };
            let schedule =
                (seed % 2 == 0).then(|| FaultSchedule::random(seed as u64, replicas, 6.0));
            let trace = || {
                let mut w = DynamicSonnet::default().with_prefix_groups(groups);
                if seed % 2 == 1 {
                    w = w.with_class_mix(vec![(0, 2), (1, 1), (2, 1)]);
                }
                w.generate(n, 10.0 + (seed % 50) as f64, seed as u64)
            };
            let run = |micro: bool| {
                let model = LlamaConfig::llama31_8b();
                let mut sim = if micro {
                    ClusterSim::new_micro_oracle(&cfg, model)
                } else {
                    ClusterSim::new(&cfg, model)
                };
                if let Some(s) = &schedule {
                    sim.install_chaos(s);
                }
                sim.submit_all(trace());
                sim.run_to_completion();
                sim
            };
            let fast = run(false);
            let micro = run(true);
            bursts.set(bursts.get() + fast.macro_bursts());
            let tokens = |sim: &ClusterSim| {
                sim.fleet_metrics().per_request().iter().map(|m| m.output_tokens).sum::<usize>()
            };
            fast.fleet_metrics().max_request_delta(&micro.fleet_metrics()) == 0.0
                && tokens(&fast) == tokens(&micro)
                && fast.requeues == micro.requeues
                && fast.events() == micro.events()
                && fast.completed() == micro.completed()
                && fast.chaos_stats() == micro.chaos_stats()
                && micro.macro_ticks() == 0
                && format!("{:?}", fast.fleet_prefix_stats())
                    == format!("{:?}", micro.fleet_prefix_stats())
        },
    );
    assert!(bursts.get() > 0, "the fast path never engaged across the sampled draws");
}

#[test]
fn chaos_conserves_every_request_and_token() {
    // Property (serving::chaos): under random fault schedules, fleet
    // sizes and class mixes, no request is ever lost or double-served —
    // submitted == completed + shed, completion ids are unique originals
    // (no hedge-tagged id leaks into metrics), and every completed
    // request's tokens are charged exactly once (crash-requeued work
    // restarts but still yields its full output exactly once).
    use cuda_myth::serving::chaos::{FaultSchedule, HEDGE_BIT};
    use cuda_myth::serving::cluster::ClusterSim;
    use cuda_myth::serving::qos::ClassSet;
    forall(
        89,
        8,
        &PairOf(PairOf(UsizeIn(10, 36), UsizeIn(2, 4)), UsizeIn(1, 1000)),
        |&((n, replicas), seed)| {
            let schedule = FaultSchedule::random(seed as u64 + 7, replicas, 5.0);
            let cfg = ServingConfig {
                replicas,
                route_policy: RoutePolicy::LeastLoaded,
                num_blocks: 2048,
                max_decode_batch: 12,
                max_queued: 16,
                classes: ClassSet::three_tier(),
                hedge_after_s: 0.25,
                shed_threshold: if seed % 2 == 0 { 1.0 } else { 0.5 },
                ..Default::default()
            };
            let trace = || {
                DynamicSonnet::default()
                    .with_class_mix(vec![(0, 2), (1, 1), (2, 1)])
                    .generate(n, 15.0, seed as u64)
            };
            let expected_tokens: usize = trace().iter().map(|r| r.max_new_tokens).sum();
            let mut sim = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
            sim.install_chaos(&schedule);
            sim.submit_all(trace());
            sim.run_to_completion();
            let ms = sim.fleet_metrics();
            let shed = sim.chaos_stats().shed as usize;
            let mut ids: Vec<u64> = ms.per_request().iter().map(|m| m.id).collect();
            let unique = {
                let len = ids.len();
                ids.sort_unstable();
                ids.dedup();
                len == ids.len()
            };
            let shed_tokens: usize = expected_tokens
                - ms.per_request().iter().map(|m| m.output_tokens).sum::<usize>();
            sim.completed() + shed == n
                && unique
                && ids.iter().all(|&id| id & HEDGE_BIT == 0 && id < n as u64)
                && (shed > 0) == (shed_tokens > 0)
        },
    );
}

#[test]
fn hedging_never_duplicates_a_completion_or_a_token() {
    // Property (serving::chaos): however aggressive the hedge timer and
    // the straggler, first-completion-wins means every request completes
    // exactly once and its output tokens are charged exactly once — the
    // cancelled copy's id never reaches the metrics.
    use cuda_myth::serving::chaos::{Fault, FaultSchedule, HEDGE_BIT};
    use cuda_myth::serving::cluster::ClusterSim;
    forall(
        97,
        8,
        &PairOf(PairOf(UsizeIn(8, 24), UsizeIn(1, 20)), UsizeIn(1, 1000)),
        |&((n, factor_x), seed)| {
            let schedule = FaultSchedule::empty().with(Fault::Straggler {
                replica: 0,
                from: 0.0,
                until: 50.0,
                factor: 1.0 + factor_x as f64,
            });
            let cfg = ServingConfig {
                replicas: 2,
                route_policy: RoutePolicy::RoundRobin,
                num_blocks: 2048,
                max_decode_batch: 12,
                hedge_after_s: 0.05 + (seed % 5) as f64 * 0.1,
                ..Default::default()
            };
            let trace = || DynamicSonnet::default().generate(n, 8.0, seed as u64);
            let expected_tokens: usize = trace().iter().map(|r| r.max_new_tokens).sum();
            let mut sim = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
            sim.install_chaos(&schedule);
            sim.submit_all(trace());
            sim.run_to_completion();
            let ms = sim.fleet_metrics();
            let mut ids: Vec<u64> = ms.per_request().iter().map(|m| m.id).collect();
            ids.sort_unstable();
            ids.dedup();
            let st = sim.chaos_stats();
            sim.completed() == n
                && ids.len() == n
                && ids.iter().all(|&id| id & HEDGE_BIT == 0)
                && ms.per_request().iter().map(|m| m.output_tokens).sum::<usize>()
                    == expected_tokens
                && st.hedges_won <= st.hedges_launched
                && st.hedges_cancelled <= st.hedges_launched
        },
    );
}

#[test]
fn empty_fault_schedule_is_bitwise_inert_across_fleets() {
    // Property (serving::chaos): installing an *empty* schedule must be
    // indistinguishable from never touching the chaos engine at all, for
    // every random fleet size, queue cap and class mix — the third event
    // heap stays empty, so the indexed loop's fast path never diverges.
    use cuda_myth::serving::chaos::FaultSchedule;
    use cuda_myth::serving::cluster::ClusterSim;
    use cuda_myth::serving::qos::ClassSet;
    forall(
        101,
        10,
        &PairOf(
            PairOf(UsizeIn(6, 30), UsizeIn(1, 4)),
            PairOf(UsizeIn(1, 1000), UsizeIn(4, 48)),
        ),
        |&((n, replicas), (seed, max_queued))| {
            let classes = if seed % 2 == 0 { ClassSet::default() } else { ClassSet::three_tier() };
            let cfg = ServingConfig {
                replicas,
                route_policy: RoutePolicy::LeastLoaded,
                max_queued,
                num_blocks: 2048,
                max_decode_batch: 12,
                classes,
                ..Default::default()
            };
            let trace = || {
                let mut w = DynamicSonnet::default();
                if seed % 2 == 1 {
                    w = w.with_class_mix(vec![(0, 2), (1, 1), (2, 1)]);
                }
                w.generate(n, 10.0 + (seed % 40) as f64, seed as u64)
            };
            let run = |chaos: bool| {
                let mut sim = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
                if chaos {
                    sim.install_chaos(&FaultSchedule::empty());
                }
                sim.submit_all(trace());
                sim.run_to_completion();
                sim
            };
            let plain = run(false);
            let empty = run(true);
            plain.fleet_metrics().max_request_delta(&empty.fleet_metrics()) == 0.0
                && plain.events() == empty.events()
                && plain.requeues == empty.requeues
                && plain.completed() == empty.completed()
        },
    );
}

#[test]
fn block_table_and_list_agree_on_effectual_blocks() {
    forall(13, 200, &VecOf(UsizeIn(1, 3000), 16), |lens| {
        let mut m = KvBlockManager::new(512, 128, 0.0);
        let ids: Vec<u64> = (0..lens.len() as u64).collect();
        for (i, &l) in lens.iter().enumerate() {
            if m.allocate(i as u64, l).is_err() {
                return true; // oversubscribed draw; nothing to check
            }
        }
        let t = BlockTable::build(&m, &ids);
        let l = BlockList::build(&m, &ids);
        let real: usize = t.effectual.iter().sum();
        let pad_ok = t.padding_fraction() >= 0.0 && t.padding_fraction() < 1.0
            || t.padded_entries() == 0;
        real == l.entries() && pad_ok && t.padded_entries() >= real
    });
}

#[test]
fn scheduler_never_exceeds_decode_batch_or_leaks_blocks() {
    forall(
        17,
        120,
        &PairOf(UsizeIn(1, 16), VecOf(PairOf(UsizeIn(1, 800), UsizeIn(1, 100)), 24)),
        |(max_batch, reqs)| {
            let cfg = ServingConfig {
                device: DeviceKind::Gaudi2,
                max_decode_batch: *max_batch,
                num_blocks: 128,
                block_size: 128,
                max_seq_len: 2048,
                max_prefill_tokens: 4096,
                ..Default::default()
            };
            let mut s = Scheduler::new(cfg);
            for (i, &(prompt, out)) in reqs.iter().enumerate() {
                let prompt = prompt.min(1900);
                let out = out.min(2048 - prompt);
                if out == 0 {
                    continue;
                }
                s.submit(Request::new(i as u64, prompt, out, 0.0));
            }
            let mut guard = 0;
            loop {
                guard += 1;
                if guard > 200_000 {
                    return false; // livelock
                }
                match s.schedule() {
                    Step::Prefill(ids) => {
                        if ids.is_empty() {
                            return false;
                        }
                    }
                    Step::Decode(ids) => {
                        if ids.len() > *max_batch {
                            return false;
                        }
                        s.complete_decode(&ids, guard as f64);
                    }
                    Step::Idle => break,
                }
                if !s.kv.check_conservation() {
                    return false;
                }
            }
            // Everything that was admitted eventually finished or is still
            // waiting (possible under permanent OOM); blocks of finished
            // sequences must be free.
            s.kv.check_conservation()
        },
    );
}

#[test]
fn router_load_accounting_balances_under_random_churn() {
    // Random interleavings of route/complete: the router's queued count
    // and per-replica loads must exactly track a reference model (so load
    // can never go negative and `complete` is balanced against `route`),
    // and backpressure must trigger exactly at `max_queued`.
    struct Ops;
    impl Gen for Ops {
        type Value = Vec<(u8, u64)>; // (op kind, payload)
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            (0..rng.range(1, 120)).map(|_| (rng.below(4) as u8, rng.next_u64())).collect()
        }
        fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
            if v.is_empty() {
                vec![]
            } else {
                vec![v[..v.len() / 2].to_vec(), v[..v.len() - 1].to_vec()]
            }
        }
    }
    forall(31, 150, &Ops, |ops| {
        for policy in RoutePolicy::ALL {
            let (replicas, max_queued) = (3usize, 8usize);
            let mut r = Router::new(policy, replicas, max_queued);
            let mut outstanding: Vec<(usize, Request)> = Vec::new();
            let mut model_load = vec![0u64; replicas];
            let mut next_id = 0u64;
            for &(op, payload) in ops {
                if op < 3 {
                    // Route a fresh request.
                    let req =
                        Request::new(next_id, 1 + (payload % 512) as usize, 1 + op as usize * 7, 0.0);
                    next_id += 1;
                    match r.route(&req) {
                        Ok(idx) => {
                            // Admission past the cap is a backpressure bug.
                            if outstanding.len() >= max_queued || idx >= replicas {
                                return false;
                            }
                            model_load[idx] += (req.prompt_len + req.max_new_tokens) as u64;
                            outstanding.push((idx, req));
                        }
                        Err(_) => {
                            // Rejection below the cap is a backpressure bug.
                            if outstanding.len() < max_queued {
                                return false;
                            }
                        }
                    }
                } else if !outstanding.is_empty() {
                    // Complete a random outstanding request.
                    let (idx, req) = outstanding.remove(payload as usize % outstanding.len());
                    model_load[idx] -= (req.prompt_len + req.max_new_tokens) as u64;
                    r.complete(idx, &req);
                }
                if r.queued() != outstanding.len() {
                    return false;
                }
                for (i, &want) in model_load.iter().enumerate() {
                    if r.load_of(i) != want {
                        return false;
                    }
                }
            }
        }
        true
    });
}

#[test]
fn prefix_affinity_never_routes_to_a_drained_replica() {
    // Random interleavings of route / complete / drain / undrain: the
    // cost-aware prefix-affinity policy (and, by the same invariant,
    // every other policy) must never place a request on a drained
    // replica, no matter which prefix was warm where when the drain hit.
    struct Ops;
    impl Gen for Ops {
        type Value = Vec<(u8, u64)>; // (op kind, payload)
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            (0..rng.range(1, 150)).map(|_| (rng.below(6) as u8, rng.next_u64())).collect()
        }
        fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
            if v.is_empty() {
                vec![]
            } else {
                vec![v[..v.len() / 2].to_vec(), v[..v.len() - 1].to_vec()]
            }
        }
    }
    forall(43, 200, &Ops, |ops| {
        let replicas = 4usize;
        // Heterogeneous costs so the cost term is exercised too.
        let costs = vec![1.0, 2.5, 1.0, 7.0];
        let mut r = Router::with_costs(RoutePolicy::PrefixAffinity, costs, 1 << 20);
        let mut outstanding: Vec<(usize, Request)> = Vec::new();
        let mut next_id = 0u64;
        for &(op, payload) in ops {
            match op {
                // Route a request tagged with one of 5 prefix groups.
                0..=2 => {
                    let req = Request::new(next_id, 1 + (payload % 700) as usize, 8, 0.0)
                        .with_prefix(payload % 5);
                    next_id += 1;
                    let idx = r.route(&req).unwrap();
                    if r.is_drained(idx) {
                        return false; // the property under test
                    }
                    outstanding.push((idx, req));
                }
                // Complete a random outstanding request.
                3 => {
                    if !outstanding.is_empty() {
                        let (idx, req) = outstanding.remove(payload as usize % outstanding.len());
                        r.complete(idx, &req);
                    }
                }
                // Drain a random replica (respecting the last-active rule).
                4 => {
                    let victim = payload as usize % replicas;
                    if r.is_drained(victim) || r.num_active() > 1 {
                        r.drain(victim);
                    }
                }
                // Undrain a random replica.
                _ => r.undrain(payload as usize % replicas),
            }
        }
        r.num_active() >= 1
    });
}

#[test]
fn autoscaler_desired_replicas_is_monotone_in_offered_load() {
    use cuda_myth::serving::autoscale::{AutoscaleConfig, Autoscaler};
    forall(
        47,
        300,
        &PairOf(PairOf(UsizeIn(1, 500), UsizeIn(1, 500)), UsizeIn(1, 400)),
        |&((a, b), cap_tenths)| {
            let ctl = Autoscaler::new(AutoscaleConfig {
                max_replicas: 32,
                ..Default::default()
            });
            let capacity = cap_tenths as f64 / 10.0;
            let (lo, hi) = (a.min(b) as f64, a.max(b) as f64);
            let want_lo = ctl.desired_replicas(lo, capacity);
            let want_hi = ctl.desired_replicas(hi, capacity);
            // Monotone in offered load, and always inside the bounds.
            want_lo <= want_hi && (1..=32).contains(&want_lo) && (1..=32).contains(&want_hi)
        },
    );
}

#[test]
fn router_affinity_is_stable_per_request_id() {
    forall(37, 300, &PairOf(UsizeIn(0, 1_000_000), UsizeIn(2, 9)), |&(id, replicas)| {
        let mut r = Router::new(RoutePolicy::Affinity, replicas, 100);
        let req = Request::new(id as u64, 10, 10, 0.0);
        let a = r.route(&req).unwrap();
        r.complete(a, &req);
        let b = r.route(&req).unwrap();
        a < replicas && a == b
    });
}

#[test]
fn collective_utilization_bounded_and_monotone_in_size() {
    forall(19, 300, &PairOf(UsizeIn(0, 5), PairOf(UsizeIn(2, 8), UsizeIn(10, 25))), |(ci, (n, logs))| {
        let coll = ALL_COLLECTIVES[*ci];
        let bytes = (1u64 << *logs) as f64;
        for kind in [DeviceKind::Gaudi2, DeviceKind::A100] {
            let r = collective::run(kind, coll, *n, bytes);
            if !(r.utilization > 0.0 && r.utilization <= 1.0) {
                return false;
            }
            let bigger = collective::run(kind, coll, *n, bytes * 4.0);
            if bigger.utilization < r.utilization - 1e-9 {
                return false; // larger payloads amortize latency
            }
        }
        true
    });
}

#[test]
fn mme_always_picks_a_valid_geometry() {
    let spec = DeviceKind::Gaudi2.spec();
    forall(
        23,
        400,
        &PairOf(UsizeIn(1, 8192), PairOf(UsizeIn(1, 8192), UsizeIn(1, 8192))),
        |(m, (k, n))| {
            let r = mme::run_gemm(&spec, *m, *k, *n, Dtype::Bf16);
            r.time > 0.0
                && r.utilization > 0.0
                && r.utilization <= 1.0
                && r.active_mac_fraction > 0.0
                && r.active_mac_fraction <= 1.0
                && mme::geometry_menu().contains(&r.geometry)
        },
    );
}

#[test]
fn allreduce_time_scales_with_payload() {
    forall(29, 200, &PairOf(UsizeIn(2, 8), UsizeIn(10, 24)), |(n, logs)| {
        let b = (1u64 << *logs) as f64;
        let t1 = collective::run(DeviceKind::Gaudi2, Collective::AllReduce, *n, b).time;
        let t2 = collective::run(DeviceKind::Gaudi2, Collective::AllReduce, *n, 2.0 * b).time;
        t2 > t1 && t2 < 2.5 * t1
    });
}

#[test]
fn tp1_replica_spec_fleets_replay_the_legacy_path() {
    // Property (config + cluster): any random fleet of tp=1 `ReplicaSpec`s
    // is bitwise-equal to the legacy `Vec<DeviceKind>` fleet on the same
    // trace — across random device mixes, class mixes, queue caps and
    // chaos schedules. And when the draw is homogeneous, both must also
    // replay the scalar `device x replicas` config: a width-1 group IS a
    // single device, everywhere.
    use cuda_myth::config::ReplicaSpec;
    use cuda_myth::serving::chaos::FaultSchedule;
    use cuda_myth::serving::cluster::ClusterSim;
    use cuda_myth::serving::qos::ClassSet;
    forall(
        89,
        10,
        &PairOf(
            PairOf(VecOf(UsizeIn(0, 1), 4), UsizeIn(8, 24)),
            PairOf(UsizeIn(1, 1000), UsizeIn(4, 48)),
        ),
        |((picks, n), (seed, max_queued))| {
            let mut devices: Vec<DeviceKind> = picks
                .iter()
                .map(|&p| if p == 0 { DeviceKind::Gaudi2 } else { DeviceKind::A100 })
                .collect();
            if devices.is_empty() {
                devices.push(DeviceKind::Gaudi2);
            }
            let classes =
                if seed % 2 == 0 { ClassSet::default() } else { ClassSet::three_tier() };
            let base = ServingConfig {
                route_policy: RoutePolicy::LeastLoaded,
                max_queued: *max_queued,
                num_blocks: 2048,
                max_decode_batch: 12,
                classes,
                ..Default::default()
            };
            let legacy = base.clone().with_fleet(devices.clone());
            let grouped = base.clone().with_replica_specs(
                devices.iter().map(|&d| ReplicaSpec::single(d)).collect(),
            );
            let schedule =
                (seed % 3 == 0).then(|| FaultSchedule::random(*seed as u64, devices.len(), 5.0));
            let run = |cfg: &ServingConfig| {
                let mut sim = ClusterSim::new(cfg, LlamaConfig::llama31_8b());
                if let Some(s) = &schedule {
                    sim.install_chaos(s);
                }
                sim.submit_all(
                    DynamicSonnet::default()
                        .with_prefix_groups(seed % 4)
                        .generate(*n, 10.0 + (seed % 40) as f64, *seed as u64),
                );
                sim.run_to_completion();
                sim
            };
            let a = run(&legacy);
            let b = run(&grouped);
            let mut ok = a.fleet_metrics().max_request_delta(&b.fleet_metrics()) == 0.0
                && a.requeues == b.requeues
                && a.events() == b.events()
                && a.completed() == b.completed();
            if ok && devices.iter().all(|&d| d == devices[0]) {
                let mut scalar_cfg = base.clone();
                scalar_cfg.replicas = devices.len();
                scalar_cfg.device = devices[0];
                let c = run(&scalar_cfg);
                ok = a.fleet_metrics().max_request_delta(&c.fleet_metrics()) == 0.0
                    && a.events() == c.events();
            }
            ok
        },
    );
}
