//! Integration tests for the cluster layer's conservation laws:
//! every submitted request finishes exactly once, fleet token throughput
//! equals the sum of replica throughputs, and a 1-replica `ClusterSim`
//! reproduces the single-`Engine` path bit-for-bit on the same trace.

use std::collections::HashMap;

use cuda_myth::config::{DeviceKind, ServingConfig};
use cuda_myth::models::llama::LlamaConfig;
use cuda_myth::serving::autoscale::{AutoscaleConfig, Autoscaler};
use cuda_myth::serving::cluster::ClusterSim;
use cuda_myth::serving::engine::{Engine, SimBackend};
use cuda_myth::serving::request::{Request, RequestId};
use cuda_myth::serving::router::RoutePolicy;
use cuda_myth::workload::{DynamicSonnet, OpenLoopTrace};

fn trace() -> Vec<Request> {
    DynamicSonnet::default().generate(40, 30.0, 42)
}

fn base_cfg(replicas: usize, policy: RoutePolicy) -> ServingConfig {
    ServingConfig {
        replicas,
        route_policy: policy,
        num_blocks: 8192,
        max_decode_batch: 32,
        ..Default::default()
    }
}

#[test]
fn one_replica_cluster_matches_single_engine_bit_for_bit() {
    // Single-engine reference on the same DynamicSonnet trace and seed.
    let cfg = base_cfg(1, RoutePolicy::RoundRobin);
    let backend = SimBackend::new(LlamaConfig::llama31_8b(), &cfg);
    let mut engine = Engine::new(cfg.clone(), backend);
    for r in trace() {
        engine.submit(r);
    }
    let engine_summary = engine.run_to_completion();

    let mut sim = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
    sim.submit_all(trace());
    let cluster_summary = sim.run_to_completion();

    assert_eq!(cluster_summary.requests, engine_summary.requests);
    // Identical per-request metrics — not approximately: the cluster loop
    // must replay the exact same step sequence, so TTFT/TPOT/E2E are the
    // same f64s.
    let by_id = |ms: &[cuda_myth::serving::metrics::RequestMetrics]| -> HashMap<RequestId, (f64, f64, f64)> {
        ms.iter().map(|m| (m.id, (m.ttft, m.tpot, m.e2e))).collect()
    };
    let single = by_id(engine.metrics.per_request());
    let fleet_metrics = sim.fleet_metrics();
    let fleet = by_id(fleet_metrics.per_request());
    assert_eq!(single.len(), fleet.len());
    for (id, s) in &single {
        let f = fleet.get(id).unwrap_or_else(|| panic!("request {id} missing from cluster"));
        assert!(s.0 == f.0 && s.1 == f.1 && s.2 == f.2, "request {id}: {s:?} vs {f:?}");
    }
    assert!(engine.metrics.makespan == fleet_metrics.makespan, "makespan must match exactly");
    assert_eq!(sim.replica(0).steps_executed(), engine.steps_executed());
}

#[test]
fn every_request_finishes_exactly_once() {
    for policy in [RoutePolicy::RoundRobin, RoutePolicy::LeastLoaded, RoutePolicy::Affinity] {
        let reqs = trace();
        let n = reqs.len();
        let mut sim = ClusterSim::new(&base_cfg(3, policy), LlamaConfig::llama31_8b());
        sim.submit_all(reqs);
        let s = sim.run_to_completion();
        assert_eq!(s.requests, n, "{policy:?}");
        assert_eq!(sim.completed(), n, "{policy:?}");
        let mut ids: Vec<RequestId> =
            sim.fleet_metrics().per_request().iter().map(|m| m.id).collect();
        ids.sort_unstable();
        let expected: Vec<RequestId> = (0..n as u64).collect();
        assert_eq!(ids, expected, "{policy:?}: finished set must be exactly the trace, once each");
        assert_eq!(sim.router().queued(), 0, "{policy:?}");
    }
}

#[test]
fn fleet_throughput_is_the_sum_of_replica_throughputs() {
    let reqs = trace();
    let expected_tokens: usize = reqs.iter().map(|r| r.max_new_tokens).sum();
    let mut sim = ClusterSim::new(&base_cfg(3, RoutePolicy::LeastLoaded), LlamaConfig::llama31_8b());
    sim.submit_all(reqs);
    let fleet = sim.run_to_completion();
    // Token conservation: the fleet emitted exactly the requested tokens.
    let metrics = sim.fleet_metrics();
    assert_eq!(metrics.output_tokens(), expected_tokens);
    assert!(
        (fleet.throughput_tps * metrics.makespan - expected_tokens as f64).abs() < 1e-6,
        "tps x makespan must equal total tokens"
    );
    // Replica summaries over the fleet makespan sum to the fleet numbers.
    let replica_tps: f64 = sim.replica_summaries().iter().map(|s| s.throughput_tps).sum();
    assert!(
        (replica_tps - fleet.throughput_tps).abs() / fleet.throughput_tps < 1e-9,
        "sum of replica throughputs {replica_tps} != fleet {}",
        fleet.throughput_tps
    );
    // And every replica returned its KV blocks.
    for i in 0..sim.num_replicas() {
        let e = sim.replica(i);
        assert_eq!(e.sched.kv.num_free(), e.sched.kv.num_blocks());
    }
}

#[test]
fn all_gaudi_mixed_fleet_is_bitwise_equal_to_homogeneous_path() {
    // `fleet: [gaudi2; 3]` must not merely approximate the homogeneous
    // `replicas: 3, device: gaudi2` deployment — it must BE it: same
    // router costs, same per-replica configs, same step sequences, so
    // every per-request metric is the same f64.
    for policy in [RoutePolicy::RoundRobin, RoutePolicy::PrefixAffinity] {
        let homog_cfg = base_cfg(3, policy);
        let mixed_cfg = base_cfg(3, policy).with_fleet(vec![DeviceKind::Gaudi2; 3]);
        let trace = || DynamicSonnet::default().with_prefix_groups(4).generate(40, 30.0, 42);

        let run = |cfg: &ServingConfig| {
            let mut sim = ClusterSim::new(cfg, LlamaConfig::llama31_8b());
            sim.submit_all(trace());
            sim.run_to_completion();
            sim
        };
        let homog = run(&homog_cfg);
        let mixed = run(&mixed_cfg);

        let by_id = |sim: &ClusterSim| -> HashMap<RequestId, (f64, f64, f64)> {
            sim.fleet_metrics()
                .per_request()
                .iter()
                .map(|m| (m.id, (m.ttft, m.tpot, m.e2e)))
                .collect()
        };
        let h = by_id(&homog);
        let m = by_id(&mixed);
        assert_eq!(h.len(), m.len(), "{policy:?}");
        for (id, hv) in &h {
            assert_eq!(hv, m.get(id).expect("request served by both"), "{policy:?} id {id}");
        }
        assert!(
            homog.fleet_metrics().makespan == mixed.fleet_metrics().makespan,
            "{policy:?}: makespan must match exactly"
        );
        for i in 0..3 {
            assert_eq!(
                homog.replica(i).steps_executed(),
                mixed.replica(i).steps_executed(),
                "{policy:?} replica {i}"
            );
        }
        for id in 0..40u64 {
            assert_eq!(
                homog.assignment_of(id),
                mixed.assignment_of(id),
                "{policy:?}: same routing decision for request {id}"
            );
        }
    }
}

#[test]
fn heterogeneous_fleet_conserves_requests_under_prefix_affinity() {
    let cfg = base_cfg(4, RoutePolicy::PrefixAffinity)
        .with_fleet(vec![
            DeviceKind::Gaudi2,
            DeviceKind::Gaudi2,
            DeviceKind::A100,
            DeviceKind::A100,
        ]);
    let reqs = OpenLoopTrace::new(25.0, 3.0).with_prefix_groups(6).generate(31);
    let n = reqs.len();
    assert!(n > 40, "trace too small: {n}");
    let mut sim = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
    sim.submit_all(reqs);
    let s = sim.run_to_completion();
    assert_eq!(s.requests, n);
    let mut ids: Vec<RequestId> = sim.fleet_metrics().per_request().iter().map(|m| m.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..n as u64).collect::<Vec<_>>(), "finished set is exactly the trace");
    assert_eq!(sim.router().queued(), 0);
    // Every replica returned its per-sequence KV blocks — only shared
    // prefix blocks stay resident (warm) — and both device types served.
    let mut served = [0usize; 2];
    for i in 0..sim.num_replicas() {
        let e = sim.replica(i);
        assert_eq!(
            e.sched.kv.num_free() + e.sched.kv.prefix_resident_blocks(),
            e.sched.kv.num_blocks()
        );
        assert!(e.sched.kv.check_conservation());
        let kind = if sim.device_of(i) == DeviceKind::Gaudi2 { 0 } else { 1 };
        served[kind] += e.metrics.len();
    }
    assert!(served[0] > 0 && served[1] > 0, "both device types must serve: {served:?}");
    // Residency-steered routing delivered real cache hits.
    assert!(sim.fleet_prefix_stats().hits > 0, "{:?}", sim.fleet_prefix_stats());
}

#[test]
fn autoscaled_fleet_conserves_requests_and_scales_up() {
    let mut sim = ClusterSim::new(
        &base_cfg(1, RoutePolicy::LeastLoaded),
        LlamaConfig::llama31_8b(),
    );
    let reqs = OpenLoopTrace::new(40.0, 3.0).generate(19);
    let n = reqs.len();
    sim.submit_all(reqs);
    let mut ctl = Autoscaler::new(AutoscaleConfig {
        scale_up_device: DeviceKind::A100,
        max_replicas: 6,
        ..Default::default()
    });
    let s = sim.run_autoscaled(&mut ctl);
    assert_eq!(s.requests, n);
    assert_eq!(sim.completed(), n);
    assert_eq!(sim.router().queued(), 0);
    assert!(sim.num_replicas() > 1, "40 req/s must force a scale-up");
    assert!(sim.router().num_active() <= 6, "active fleet never exceeds max_replicas");
    // Every provisioned replica traces back to a logged ScaleUp (some
    // scale-ups may have reused a drained replica instead of adding one).
    assert!(ctl.scale_ups() >= sim.num_replicas() - 1);
    // Scaled-up replicas are A100s.
    assert_eq!(sim.device_of(sim.num_replicas() - 1), DeviceKind::A100);
}

#[test]
fn open_loop_load_with_backpressure_conserves_requests() {
    let reqs = OpenLoopTrace::new(30.0, 2.0).generate(13);
    let n = reqs.len();
    assert!(n > 20, "trace too small: {n}");
    let mut cfg = base_cfg(2, RoutePolicy::RoundRobin);
    cfg.max_queued = 8; // force requeues under the burst
    let mut sim = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
    sim.submit_all(reqs);
    let s = sim.run_to_completion();
    assert_eq!(s.requests, n);
    assert!(sim.requeues > 0, "expected backpressure at max_queued=8");
    // Requeued requests pay their queueing delay in TTFT, never lose it.
    assert!(s.p99_ttft > 0.0);
}
