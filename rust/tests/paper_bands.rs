//! Paper-band regression tests: every headline number the paper reports,
//! asserted against the simulator with explicit tolerances. This file is
//! the repo's "does it still reproduce the paper?" gate; the per-module
//! unit tests check the underlying mechanisms.

use cuda_myth::config::DeviceKind;
use cuda_myth::models::dlrm::{self, fig11_grid, DlrmConfig};
use cuda_myth::models::llama::{self, LlamaConfig};
use cuda_myth::ops::attention::{run as attn, PagedAttnImpl, PagedAttnWork};
use cuda_myth::ops::gemm;
use cuda_myth::sim::collective::{self, Collective, ALL_COLLECTIVES};
use cuda_myth::sim::memory::{self, AccessDir};
use cuda_myth::sim::tpc::{self, StreamOp};
use cuda_myth::sim::Dtype;
use cuda_myth::util::stats::mean;

fn assert_band(name: &str, value: f64, target: f64, tol: f64) {
    assert!(
        (value - target).abs() < tol,
        "{name}: measured {value:.3} vs paper {target:.3} (tol {tol:.3})"
    );
}

#[test]
fn fig4_gaudi_429_tflops_at_8192() {
    let p = gemm::run(DeviceKind::Gaudi2, 8192, 8192, 8192, Dtype::Bf16);
    assert_band("fig4 peak TFLOPS", p.exec.achieved_flops / 1e12, 429.0, 4.0);
    assert_band("fig4 peak util", p.exec.utilization, 0.993, 0.01);
}

#[test]
fn fig4_gaudi_wins_every_shape() {
    for (m, k, n) in gemm::fig4_shapes() {
        let g = gemm::run(DeviceKind::Gaudi2, m, k, n, Dtype::Bf16);
        let a = gemm::run(DeviceKind::A100, m, k, n, Dtype::Bf16);
        assert!(g.exec.achieved_flops >= a.exec.achieved_flops, "({m},{k},{n})");
    }
}

#[test]
fn fig5_avg_utilization_gap() {
    let gaps: Vec<f64> = gemm::fig4_shapes()
        .into_iter()
        .chain(gemm::fig5_irregular_grid())
        .map(|(m, k, n)| {
            gemm::run(DeviceKind::Gaudi2, m, k, n, Dtype::Bf16).exec.utilization
                - gemm::run(DeviceKind::A100, m, k, n, Dtype::Bf16).exec.utilization
        })
        .collect();
    assert_band("fig5 avg gap (pp)", 100.0 * mean(&gaps), 4.5, 4.0);
    let max = gaps.iter().cloned().fold(f64::MIN, f64::max);
    assert_band("fig5 max gap (pp)", 100.0 * max, 32.0, 14.0);
}

#[test]
fn fig8_chip_stream_saturation() {
    let spec = DeviceKind::Gaudi2.spec();
    let sat = |op| tpc::weak_scaled_throughput(&spec, op, 24, Dtype::Bf16) / 1e9;
    assert_band("fig8 ADD GF", sat(StreamOp::Add), 330.0, 40.0);
    assert_band("fig8 SCALE GF", sat(StreamOp::Scale), 530.0, 50.0);
    assert_band("fig8 TRIAD GF", sat(StreamOp::Triad), 670.0, 50.0);
}

#[test]
fn fig8_intensity_saturation_ratios() {
    let g = DeviceKind::Gaudi2.spec();
    let a = DeviceKind::A100.spec();
    assert_band(
        "gaudi TRIAD sat TF",
        tpc::intensity_sweep_throughput(&g, StreamOp::Triad, 1e5) / 1e12,
        10.9,
        0.3,
    );
    assert_band(
        "a100 TRIAD sat TF",
        cuda_myth::sim::simd::intensity_sweep_throughput(&a, StreamOp::Triad, 1e5) / 1e12,
        38.2,
        1.0,
    );
}

#[test]
fn fig9_gather_utilization_bands() {
    let avg = |kind: DeviceKind, sizes: &[f64]| {
        mean(
            &sizes
                .iter()
                .map(|&v| {
                    memory::random_access(&kind.spec(), AccessDir::Gather, 4e6, v).utilization
                })
                .collect::<Vec<_>>(),
        )
    };
    assert_band("gaudi >=256B", avg(DeviceKind::Gaudi2, &[256., 512., 1024., 2048.]), 0.64, 0.05);
    assert_band("a100 >=256B", avg(DeviceKind::A100, &[256., 512., 1024., 2048.]), 0.72, 0.05);
    assert_band("gaudi <=128B", avg(DeviceKind::Gaudi2, &[16., 32., 64., 128.]), 0.15, 0.04);
    assert_band("a100 <=128B", avg(DeviceKind::A100, &[16., 32., 64., 128.]), 0.36, 0.06);
}

#[test]
fn fig10_winner_counts_and_scaling() {
    let mut gaudi_wins = 0;
    for coll in ALL_COLLECTIVES {
        let g = collective::run(DeviceKind::Gaudi2, coll, 8, 32e6);
        let a = collective::run(DeviceKind::A100, coll, 8, 32e6);
        if g.utilization > a.utilization {
            gaudi_wins += 1;
        }
    }
    assert_eq!(gaudi_wins, 5, "paper: Gaudi wins 5 of 6 at 8 devices");
    // Linear decline: 2-device AllReduce utilization ~1/7 of 8-device.
    let u2 = collective::run(DeviceKind::Gaudi2, Collective::AllReduce, 2, 32e6).utilization;
    let u8 = collective::run(DeviceKind::Gaudi2, Collective::AllReduce, 8, 32e6).utilization;
    assert_band("gaudi allreduce 2/8 ratio", u2 / u8, 1.0 / 7.0, 0.08);
}

#[test]
fn fig11_recsys_deficits() {
    let avg_speedup = |cfg: &DlrmConfig| {
        mean(
            &fig11_grid()
                .into_iter()
                .map(|(b, d)| {
                    dlrm::serve(cfg, DeviceKind::A100, b, d).time
                        / dlrm::serve(cfg, DeviceKind::Gaudi2, b, d).time
                })
                .collect::<Vec<_>>(),
        )
    };
    assert_band("rm1 avg speedup", avg_speedup(&DlrmConfig::rm1()), 0.78, 0.12);
    assert_band("rm2 avg speedup", avg_speedup(&DlrmConfig::rm2()), 0.82, 0.12);
}

#[test]
fn fig12_llm_speedups() {
    let grid: Vec<(usize, usize)> =
        [4usize, 16, 64].iter().flat_map(|&b| [25usize, 100, 400].map(|o| (b, o))).collect();
    let avg = |cfg: &LlamaConfig, tp: usize| {
        mean(
            &grid
                .iter()
                .map(|&(b, o)| {
                    llama::serve_fixed(cfg, DeviceKind::A100, b, 100, o, tp).total_time()
                        / llama::serve_fixed(cfg, DeviceKind::Gaudi2, b, 100, o, tp).total_time()
                })
                .collect::<Vec<_>>(),
        )
    };
    let cfg8 = LlamaConfig::llama31_8b();
    let cfg70 = LlamaConfig::llama31_70b();
    assert_band("8B single-device speedup", avg(&cfg8, 1), 1.47, 0.20);
    assert_band("70B tp2 speedup", avg(&cfg70, 2), 1.29, 0.15);
    assert_band("70B tp4 speedup", avg(&cfg70, 4), 1.32, 0.15);
    assert_band("70B tp8 speedup", avg(&cfg70, 8), 1.35, 0.15);
}

#[test]
fn fig13_energy_efficiency() {
    let grid: Vec<(usize, usize)> =
        [4usize, 16, 64].iter().flat_map(|&b| [25usize, 100, 400].map(|o| (b, o))).collect();
    let cfg8 = LlamaConfig::llama31_8b();
    let effs: Vec<f64> = grid
        .iter()
        .map(|&(b, o)| {
            let g = llama::serve_fixed(&cfg8, DeviceKind::Gaudi2, b, 100, o, 1);
            let a = llama::serve_fixed(&cfg8, DeviceKind::A100, b, 100, o, 1);
            g.tokens_per_joule(b, o) / a.tokens_per_joule(b, o)
        })
        .collect();
    assert_band("8B energy-eff", mean(&effs), 1.48, 0.30);
}

#[test]
fn fig17_paged_attention_bands() {
    let mut base_opt = Vec::new();
    let mut a100_opt = Vec::new();
    for &s in &[512usize, 1024, 2048, 4096] {
        for &b in &[8usize, 16, 32, 64] {
            let w = PagedAttnWork::llama8b(b, s);
            base_opt.push(
                attn(PagedAttnImpl::GaudiVllmBase, w).time
                    / attn(PagedAttnImpl::GaudiVllmOpt, w).time,
            );
            a100_opt.push(
                attn(PagedAttnImpl::A100Paged, w).time / attn(PagedAttnImpl::GaudiVllmOpt, w).time,
            );
        }
    }
    assert_band("fig17a opt/base", mean(&base_opt), 7.4, 2.5);
    assert_band("fig17c opt vs a100", mean(&a100_opt), 0.45, 0.12);
}
