//! Integration tests for the AOT → PJRT path: load the HLO text artifacts
//! produced by `python/compile/aot.py`, execute them on the CPU PJRT
//! client, and check the numerics against host-side references.
//!
//! Requires `make artifacts` to have run; tests are skipped (pass
//! trivially with a note) if the artifacts are missing so `cargo test`
//! stays green in a fresh checkout.

use cuda_myth::runtime::{HostTensor, Runtime};
use cuda_myth::serving::real_engine::PjrtLlmEngine;
use cuda_myth::serving::request::Request;

fn artifacts_dir() -> Option<String> {
    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    if std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

#[test]
fn stream_triad_matches_reference() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let exe = rt.load("stream_triad").unwrap();
    let n = exe.entry.inputs[0].num_elements();
    let a: Vec<f32> = (0..n).map(|i| (i % 1000) as f32 * 0.25).collect();
    let b: Vec<f32> = (0..n).map(|i| (i % 777) as f32 - 100.0).collect();
    let out = exe.run(&[HostTensor::F32(a.clone()), HostTensor::F32(b.clone())]).unwrap();
    let got = out[0].as_f32().unwrap();
    assert_eq!(got.len(), n);
    for i in (0..n).step_by(1009) {
        let want = 3.0 * a[i] + b[i];
        assert!((got[i] - want).abs() < 1e-4, "i={i}: {} vs {want}", got[i]);
    }
}

#[test]
fn embedding_gather_matches_reference() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let exe = rt.load("embedding_gather").unwrap();
    let rows = exe.entry.inputs[0].shape[0];
    let dim = exe.entry.inputs[0].shape[1];
    let (n_tables, batch) = (exe.entry.inputs[1].shape[0], exe.entry.inputs[1].shape[1]);
    let tables: Vec<f32> = (0..rows * dim).map(|i| (i as f32).sin()).collect();
    let rows_per = rows / n_tables;
    let indices: Vec<i32> =
        (0..n_tables * batch).map(|i| ((i * 7 + 3) % rows_per) as i32).collect();
    let offsets: Vec<i32> = (0..n_tables).map(|t| (t * rows_per) as i32).collect();
    let out = exe
        .run(&[
            HostTensor::F32(tables.clone()),
            HostTensor::I32(indices.clone()),
            HostTensor::I32(offsets.clone()),
        ])
        .unwrap();
    let got = out[0].as_f32().unwrap();
    for t in 0..n_tables {
        for b in 0..batch {
            let row = indices[t * batch + b] as usize + offsets[t] as usize;
            for d in (0..dim).step_by(17) {
                let want = tables[row * dim + d];
                let g = got[(t * batch + b) * dim + d];
                assert!((g - want).abs() < 1e-6, "t={t} b={b} d={d}");
            }
        }
    }
}

#[test]
fn paged_attention_artifact_runs_and_normalizes() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let exe = rt.load("paged_attention").unwrap();
    let batch = exe.entry.inputs[0].shape[0];
    let d = exe.entry.inputs[0].shape[1];
    let nb = exe.entry.inputs[1].shape[1];
    let bs = exe.entry.inputs[1].shape[2];
    let q: Vec<f32> = (0..batch * d).map(|i| ((i * 31) % 17) as f32 * 0.1 - 0.8).collect();
    // V constant per block -> outputs are convex combinations of block ids.
    let mut kv = vec![0.0f32; 2 * nb * bs * d];
    for blk in 0..nb {
        for t in 0..bs {
            for x in 0..d {
                kv[(blk * bs + t) * d + x] = ((blk + t + x) % 13) as f32 * 0.1; // K
                kv[(nb * bs + blk * bs + t) * d + x] = blk as f32; // V = block id
            }
        }
    }
    let block_list: Vec<i32> = (0..nb as i32).collect();
    let offsets: Vec<i32> = vec![0, 2, 4, 6, 8]; // 2 blocks per sequence
    let lens: Vec<i32> = vec![bs as i32, (2 * bs) as i32, 5, (bs + 3) as i32];
    let out = exe
        .run(&[
            HostTensor::F32(q),
            HostTensor::F32(kv),
            HostTensor::I32(block_list),
            HostTensor::I32(offsets.clone()),
            HostTensor::I32(lens.clone()),
        ])
        .unwrap();
    let got = out[0].as_f32().unwrap();
    // Sequence 0 attends only tokens in block 0 (len = bs) -> output == 0.
    for x in 0..d {
        assert!(got[x].abs() < 1e-5, "seq0[{x}] = {}", got[x]);
    }
    // Sequence 2 (blocks 4,5; len 5 < bs) -> only block 4 -> output == 4.
    for x in 0..d {
        assert!((got[2 * d + x] - 4.0).abs() < 1e-4, "seq2[{x}] = {}", got[2 * d + x]);
    }
    // Sequence 1 spans blocks 2 and 3 -> output strictly between 2 and 3.
    for x in 0..d {
        let v = got[d + x];
        assert!(v > 2.0 && v < 3.0, "seq1[{x}] = {v}");
    }
}

#[test]
fn dlrm_forward_artifact_runs() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let weights = {
        let init = rt.load("init_dlrm_weights").unwrap();
        init.run(&[]).unwrap().remove(0)
    };
    let exe = rt.load("dlrm_forward").unwrap();
    let batch = exe.entry.inputs[1].shape[0];
    let dense_in = exe.entry.inputs[1].shape[1];
    let idx_elems = exe.entry.inputs[2].num_elements();
    let rows = exe.entry.meta["rows_per_table"] as usize;
    let dense: Vec<f32> = (0..batch * dense_in).map(|i| (i % 7) as f32 * 0.1).collect();
    let indices: Vec<i32> = (0..idx_elems).map(|i| ((i * 13) % rows) as i32).collect();
    let out = exe
        .run(&[weights.clone(), HostTensor::F32(dense.clone()), HostTensor::I32(indices.clone())])
        .unwrap();
    let scores = out[0].as_f32().unwrap();
    assert_eq!(scores.len(), batch);
    assert!(scores.iter().all(|s| s.is_finite()));
    // Different indices must change the score (embeddings actually used).
    let indices2: Vec<i32> = indices.iter().map(|&i| (i + 37) % rows as i32).collect();
    let out2 =
        exe.run(&[weights, HostTensor::F32(dense), HostTensor::I32(indices2)]).unwrap();
    let scores2 = out2[0].as_f32().unwrap();
    assert!(scores.iter().zip(scores2).any(|(a, b)| (a - b).abs() > 1e-6));
}

#[test]
fn real_engine_serves_requests_end_to_end() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = PjrtLlmEngine::new(&dir).unwrap();
    let dims = engine.dims();
    // More requests than slots to exercise slot recycling.
    let n_req = dims.batch_slots + 2;
    for i in 0..n_req as u64 {
        let prompt_len = 4 + (i as usize % 3);
        let prompt: Vec<i32> = (0..prompt_len as i32).map(|t| (t * 7 + i as i32) % 50).collect();
        engine
            .submit(Request::new(i, prompt_len, 6 + (i as usize % 4), 0.0), prompt)
            .unwrap();
    }
    let summary = engine.run_to_completion().unwrap();
    assert_eq!(summary.requests, n_req);
    assert!(summary.mean_ttft > 0.0);
    assert!(summary.mean_tpot > 0.0);
    assert!(summary.throughput_tps > 0.0);
    assert!(engine.tokens_generated() as usize >= n_req * 6);
}

#[test]
fn decode_is_deterministic() {
    let Some(dir) = artifacts_dir() else { return };
    let run_once = || {
        let mut e = PjrtLlmEngine::new(&dir).unwrap();
        e.submit(Request::new(0, 3, 5, 0.0), vec![11, 23, 42]).unwrap();
        e.run_to_completion().unwrap();
        e.tokens_generated()
    };
    assert_eq!(run_once(), run_once());
}

#[test]
fn flash_prefill_artifact_is_causal_attention() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let exe = rt.load("flash_prefill").unwrap();
    let seq = exe.entry.inputs[0].shape[0];
    let d = exe.entry.inputs[0].shape[1];
    // V = row index: row i attends rows <= i (causal), so the output is a
    // convex combination of 0..=i and must be bounded by i.
    let q: Vec<f32> = (0..seq * d).map(|i| ((i * 13) % 7) as f32 * 0.2 - 0.5).collect();
    let k: Vec<f32> = (0..seq * d).map(|i| ((i * 29) % 11) as f32 * 0.1).collect();
    let v: Vec<f32> = (0..seq * d).map(|i| (i / d) as f32).collect();
    let out = exe
        .run(&[HostTensor::F32(q), HostTensor::F32(k), HostTensor::F32(v.clone())])
        .unwrap();
    let got = out[0].as_f32().unwrap();
    // Row 0 attends only itself: output == v[0] == 0.
    for x in 0..d {
        assert!(got[x].abs() < 1e-5, "row0[{x}] = {}", got[x]);
    }
    // Every row i's output lies in [0, i] (causal convex combination).
    for i in 0..seq {
        for x in 0..d {
            let y = got[i * d + x];
            assert!(y >= -1e-4 && y <= i as f32 + 1e-4, "row{i}[{x}] = {y}");
        }
    }
}
