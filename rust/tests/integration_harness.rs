//! Integration tests over the experiment harness and the typed report
//! model: every registered experiment regenerates, renders, exports CSV,
//! round-trips through JSON, and passes its paper-claim `Expectation`s —
//! the typed replacement for the old substring asserts over rendered
//! ASCII.

use cuda_myth::harness::{self, Experiment};
use cuda_myth::report::{Cell, Report, Unit, Value};
use cuda_myth::util::json::Json;
use cuda_myth::util::proptest::{forall, F64In, PairOf, UsizeIn};

#[test]
fn every_experiment_runs_and_renders() {
    for e in harness::registry() {
        let reports = e.run(&e.params());
        assert!(!reports.is_empty(), "{} produced no reports", e.id());
        for r in &reports {
            let text = r.render();
            assert!(text.len() > 40, "{}: report too small", e.id());
            assert!(text.contains("=="), "{}: missing title", e.id());
        }
    }
}

#[test]
fn every_paper_claim_expectation_passes() {
    // The typed successor of `repro run all --check`: every experiment's
    // headline-claim expectations evaluate green over fresh reports.
    let mut checked = 0;
    for e in harness::registry() {
        let params = e.params();
        let reports = e.run(&params);
        for res in harness::evaluate(e.as_ref(), &params, &reports) {
            assert!(res.pass, "{}: {} ({})", res.id, res.detail, res.claim);
            checked += 1;
        }
    }
    assert!(checked >= 20, "only {checked} expectations registered across the harness");
}

#[test]
fn every_report_roundtrips_through_json() {
    for e in harness::registry() {
        for r in e.run(&e.params()) {
            let dumped = r.to_json().dump();
            let parsed = Json::parse(&dumped)
                .unwrap_or_else(|err| panic!("{}: artifact JSON invalid: {err}", e.id()));
            let back = Report::from_json(&parsed)
                .unwrap_or_else(|err| panic!("{}: report JSON unreadable: {err}", e.id()));
            assert_eq!(back, r, "{}: JSON round-trip must be lossless", e.id());
        }
    }
}

#[test]
fn ascii_and_json_agree_on_every_cell() {
    // Property over the full registry: for every cell, the ASCII table
    // shows exactly the canonical formatting of the raw value that the
    // JSON artifact carries — the two channels cannot drift apart.
    for e in harness::registry() {
        for r in e.run(&e.params()) {
            let text = r.render();
            let parsed = Report::from_json(&Json::parse(&r.to_json().dump()).unwrap()).unwrap();
            for (row, prow) in r.rows().iter().zip(parsed.rows()) {
                for (cell, pcell) in row.iter().zip(prow) {
                    let shown = cell.fmt();
                    assert_eq!(pcell.fmt(), shown, "{}: cell formatting drifted", e.id());
                    assert!(
                        text.contains(&shown),
                        "{}: rendered table is missing cell '{shown}'",
                        e.id()
                    );
                    if let (Some(v), Some(pv)) = (cell.value(), pcell.value()) {
                        assert_eq!(pv, v, "{}: raw value changed across JSON", e.id());
                    }
                }
            }
        }
    }
}

#[test]
fn value_formatting_agrees_with_json_for_random_inputs() {
    // Randomized cell property: a Value rebuilt from its JSON renders
    // the identical ASCII string, across magnitudes and units.
    let units = [Unit::Tflops, Unit::Ratio, Unit::Percent, Unit::Pp, Unit::Count, Unit::Millis];
    forall(7, 500, &PairOf(F64In(-1e6, 1e6), UsizeIn(0, units.len() - 1)), |&(x, u)| {
        let v = Value::new(x, units[u]);
        let j = Json::parse(&v.to_json().dump()).unwrap();
        let back = Value::from_json(&j).unwrap();
        back == v && back.fmt() == v.fmt()
    });
}

#[test]
fn artifact_json_is_schema_stable_for_all() {
    for e in harness::registry() {
        let params = e.params();
        let reports = e.run(&params);
        let results = harness::evaluate(e.as_ref(), &params, &reports);
        let artifact = harness::artifact_json(e.as_ref(), &params, &reports, &results);
        let j = Json::parse(&artifact.dump()).unwrap();
        assert_eq!(j.get("schema").unwrap().as_str(), Some(harness::ARTIFACT_SCHEMA));
        assert_eq!(j.get("experiment").unwrap().as_str(), Some(e.id()));
        assert!(j.get("title").unwrap().as_str().is_some());
        assert!(j.get("params").is_some());
        let reps = j.get("reports").unwrap().as_arr().unwrap();
        assert_eq!(reps.len(), reports.len());
        let exps = j.get("expectations").unwrap().as_arr().unwrap();
        assert_eq!(exps.len(), results.len());
        for x in exps {
            assert_eq!(x.get("pass").unwrap().as_bool(), Some(true), "{}", e.id());
        }
    }
}

#[test]
fn csv_export_has_header_and_raw_rows() {
    let reports = harness::run_experiment("fig4").unwrap();
    let csv = reports[0].to_csv();
    let lines: Vec<&str> = csv.lines().collect();
    assert!(lines.len() > 5);
    assert!(lines[0].contains(','));
    // CSV cells are raw numbers: the utilization column is a fraction,
    // not a formatted percentage.
    assert!(!csv.contains('%'), "CSV must carry raw values:\n{csv}");
}

#[test]
fn run_all_covers_all_registry_entries() {
    let n_reports = harness::run_all().len();
    // Each experiment yields at least one report.
    assert!(n_reports >= harness::registry().len());
}

#[test]
fn sweep_artifacts_are_jobs_invariant() {
    // The parallel executor's headline contract: the full JSON artifact
    // (params, every report cell, every evaluated claim) is byte-equal
    // whether the sweep grid ran on one worker or eight.
    for id in ["cluster_sweep", "tp_sweep"] {
        let e = harness::find(id).unwrap();
        let params = e.params();
        let dump = |jobs: usize| {
            cuda_myth::util::par::with_jobs(jobs, || {
                let reports = e.run(&params);
                let results = harness::evaluate(e.as_ref(), &params, &reports);
                harness::artifact_json(e.as_ref(), &params, &reports, &results).dump()
            })
        };
        assert_eq!(dump(1), dump(8), "{id}: artifact bytes depend on the jobs count");
    }
}

#[test]
fn a_panicking_experiment_fails_alone_in_a_batch_run() {
    use cuda_myth::harness::Params;
    use cuda_myth::report::{Expectation, Report};

    struct Panicky;
    impl Experiment for Panicky {
        fn id(&self) -> &'static str {
            "panicky"
        }
        fn title(&self) -> &'static str {
            "always panics"
        }
        fn run(&self, _params: &Params) -> Vec<Report> {
            panic!("grid point 3 exploded")
        }
        fn expectations(&self, _params: &Params) -> Vec<Expectation> {
            Vec::new()
        }
    }

    let exps: Vec<Box<dyn Experiment>> = vec![Box::new(Panicky), harness::find("fig4").unwrap()];
    let runs = harness::run_all_isolated(&exps, &[]);
    assert_eq!(runs.len(), 2);

    // The panic becomes that entry's failure: a synthesized failing
    // claim carrying the payload, no reports, failed() true.
    let bad = &runs[0];
    assert_eq!(bad.id, "panicky");
    assert!(bad.panic.as_deref().unwrap().contains("grid point 3 exploded"));
    assert!(bad.reports.is_empty());
    assert_eq!(bad.results.len(), 1);
    assert_eq!(bad.results[0].id, "panicky.run_panicked");
    assert!(!bad.results[0].pass);
    assert!(bad.failed());

    // The sibling is untouched: same order, real reports, green claims.
    let good = &runs[1];
    assert_eq!(good.id, "fig4");
    assert!(good.panic.is_none());
    assert!(!good.reports.is_empty());
    assert!(good.results.iter().all(|r| r.pass));
    assert!(!good.failed());
}

#[test]
fn typed_cells_beat_substring_matching() {
    // The old string-contains asserts, migrated: the fig4 headline is a
    // typed cell with a unit, not a substring of a rendered table.
    let reports = harness::run_experiment("fig4").unwrap();
    let peak = reports[0].value_at("8192x8192x8192", "Gaudi-2 TF").unwrap();
    assert_eq!(peak.unit, Unit::Tflops);
    assert!(peak.x >= 425.0, "{}", peak.x);
    // And the same number is reachable as a column series.
    let series = reports[0].series("Gaudi-2 TF").unwrap();
    assert!(series.max() >= 425.0);
    assert_eq!(series.values.len(), reports[0].num_rows());
    // Text cells stay text.
    assert!(matches!(
        &reports[0].rows()[0][0],
        Cell::Text(s) if s.contains('x')
    ));
}
