//! Integration tests over the experiment harness: every registered
//! table/figure regenerates, renders non-trivially, and exports CSV.

use cuda_myth::harness;

#[test]
fn every_experiment_runs_and_renders() {
    for e in harness::registry() {
        let reports = (e.run)();
        assert!(!reports.is_empty(), "{} produced no reports", e.id);
        for r in &reports {
            let text = r.render();
            assert!(text.len() > 40, "{}: report too small", e.id);
            assert!(text.contains("=="), "{}: missing title", e.id);
        }
    }
}

#[test]
fn csv_export_has_header_and_rows() {
    let reports = harness::run_experiment("fig4").unwrap();
    let csv = reports[0].to_csv();
    let lines: Vec<&str> = csv.lines().collect();
    assert!(lines.len() > 5);
    assert!(lines[0].contains(','));
}

#[test]
fn run_all_covers_all_registry_entries() {
    let n_reports = harness::run_all().len();
    // Each experiment yields at least one report.
    assert!(n_reports >= harness::registry().len());
}
