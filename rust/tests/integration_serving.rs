//! Integration tests across the serving stack: router → engine →
//! scheduler → block manager with the simulated backend, including
//! failure injection (OOM preemption, backpressure) and the Fig 17(d)
//! engine-level comparison.

use cuda_myth::config::{DeviceKind, ServingConfig};
use cuda_myth::models::llama::LlamaConfig;
use cuda_myth::serving::engine::{Engine, SimBackend};
use cuda_myth::serving::request::Request;
use cuda_myth::serving::router::{QueueFull, RoutePolicy, Router};
use cuda_myth::workload::DynamicSonnet;

fn engine_with(cfg: ServingConfig) -> Engine<SimBackend> {
    let backend = SimBackend::new(LlamaConfig::llama31_8b(), &cfg);
    Engine::new(cfg, backend)
}

#[test]
fn dynamic_workload_completes_under_continuous_batching() {
    let cfg = ServingConfig { num_blocks: 8192, max_decode_batch: 32, ..Default::default() };
    let mut e = engine_with(cfg);
    let reqs = DynamicSonnet::default().generate(64, 50.0, 5);
    let total_out: usize = reqs.iter().map(|r| r.max_new_tokens).sum();
    for r in reqs {
        e.submit(r);
    }
    let s = e.run_to_completion();
    assert_eq!(s.requests, 64);
    assert!(s.throughput_tps > 0.0);
    assert!((s.throughput_tps * e.metrics.makespan - total_out as f64).abs() < 1.0);
    assert_eq!(e.sched.kv.num_free(), e.sched.kv.num_blocks());
}

#[test]
fn memory_pressure_forces_preemption_but_everything_finishes() {
    // A KV pool far too small for the batch: the scheduler must preempt
    // (recompute) and still finish every request.
    let cfg = ServingConfig {
        num_blocks: 48,
        block_size: 128,
        max_decode_batch: 16,
        max_seq_len: 4096,
        ..Default::default()
    };
    let mut e = engine_with(cfg);
    for i in 0..12u64 {
        e.submit(Request::new(i, 256, 300, 0.0));
    }
    let s = e.run_to_completion();
    assert_eq!(s.requests, 12);
    let preemptions: usize = (0..12u64).map(|i| e.sched.seq(i).preemptions).sum();
    assert!(preemptions > 0, "expected preemptions under memory pressure");
    assert!(e.sched.kv.check_conservation());
}

#[test]
fn fig17d_block_list_beats_block_table_at_engine_level() {
    let run = |use_block_list: bool| {
        let cfg = ServingConfig {
            num_blocks: 8192,
            max_decode_batch: 32,
            use_block_list,
            ..Default::default()
        };
        let mut e = engine_with(cfg);
        for r in DynamicSonnet::default().generate(48, f64::INFINITY, 9) {
            e.submit(r);
        }
        e.run_to_completion().throughput_tps
    };
    let opt = run(true);
    let base = run(false);
    assert!(opt > 1.5 * base, "opt {opt} vs base {base}");
}

#[test]
fn router_and_engines_drain_a_multi_replica_deployment() {
    let mut router = Router::new(RoutePolicy::LeastLoaded, 3, 1000);
    let mut engines: Vec<Engine<SimBackend>> = (0..3)
        .map(|_| {
            engine_with(ServingConfig {
                num_blocks: 4096,
                max_decode_batch: 16,
                ..Default::default()
            })
        })
        .collect();
    let reqs = DynamicSonnet::default().generate(45, f64::INFINITY, 21);
    let mut per_replica = vec![0usize; 3];
    for r in &reqs {
        let idx = router.route(r).unwrap();
        per_replica[idx] += 1;
        engines[idx].submit(r.clone());
    }
    // Least-loaded keeps the split roughly even.
    assert!(per_replica.iter().all(|&c| c >= 10), "{per_replica:?}");
    let mut total = 0;
    for e in &mut engines {
        total += e.run_to_completion().requests;
    }
    assert_eq!(total, 45);
}

#[test]
fn router_backpressure_surfaces_queue_full() {
    let mut router = Router::new(RoutePolicy::RoundRobin, 2, 4);
    let reqs = DynamicSonnet::default().generate(6, f64::INFINITY, 2);
    let mut accepted = 0;
    let mut rejected = 0;
    for r in &reqs {
        match router.route(r) {
            Ok(_) => accepted += 1,
            Err(QueueFull) => rejected += 1,
        }
    }
    assert_eq!(accepted, 4);
    assert_eq!(rejected, 2);
}

#[test]
fn gaudi_and_a100_backends_both_serve() {
    for device in [DeviceKind::Gaudi2, DeviceKind::A100] {
        let cfg = ServingConfig { device, num_blocks: 8192, ..Default::default() };
        let mut e = engine_with(cfg);
        for r in DynamicSonnet::default().generate(16, f64::INFINITY, 3) {
            e.submit(r);
        }
        let s = e.run_to_completion();
        assert_eq!(s.requests, 16, "{device:?}");
    }
}

#[test]
fn trace_captures_the_serving_timeline() {
    let cfg = ServingConfig { num_blocks: 8192, max_decode_batch: 32, ..Default::default() };
    let mut e = engine_with(cfg);
    for r in DynamicSonnet::default().generate(24, f64::INFINITY, 13) {
        e.submit(r);
    }
    e.run_to_completion();
    assert!(e.trace.total_recorded() > 24, "at least one step per request");
    // Trace accounting agrees with the engine clock.
    let traced_time: f64 = e.trace.iter().map(|ev| ev.duration).sum();
    assert!((traced_time - e.clock()).abs() / e.clock() < 0.01);
    // Mostly decode time for a generation workload.
    assert!(e.trace.decode_time_share() > 0.5, "{}", e.trace.decode_time_share());
    // CSV export round-trips the row count.
    let csv = e.trace.to_csv();
    assert_eq!(csv.lines().count() as u64, 1 + e.trace.total_recorded().min(4096));
    // Chronological order.
    let starts: Vec<f64> = e.trace.iter().map(|ev| ev.t_start).collect();
    assert!(starts.windows(2).all(|w| w[1] >= w[0]));
}
