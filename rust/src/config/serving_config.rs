//! Serving-engine configuration, loadable from JSON so the launcher
//! (`repro serve --config <file>`) can be driven without recompiling.
//! Covers both a single engine replica (scheduler/KV knobs) and the
//! cluster deployment above it (`replicas`, `route_policy`, `max_queued`).

use crate::config::DeviceKind;
use crate::serving::kv_cache::EvictionPolicy;
use crate::serving::qos::ClassSet;
use crate::serving::router::RoutePolicy;
use crate::util::json::Json;

/// One replica as a *device group*: `tp` cards of `device` acting as a
/// single tensor-parallel serving unit behind the router. Each card holds
/// 1/tp of every GEMM shard and 1/tp of the KV bytes; the group pays two
/// all-reduces per transformer block on the device's interconnect
/// (`sim::collective::CollectiveModel`). `tp = 1` is exactly the old
/// single-device replica.
///
/// JSON: the compact legacy form `"gaudi2"` means tp 1; the object form
/// `{"device": "gaudi2", "tp": 4}` names the group explicitly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReplicaSpec {
    /// Device kind of every card in the group.
    pub device: DeviceKind,
    /// Cards in the group (tensor-parallel degree): 1, 2, 4 or 8.
    pub tp: usize,
}

impl ReplicaSpec {
    pub fn new(device: DeviceKind, tp: usize) -> ReplicaSpec {
        ReplicaSpec { device, tp }
    }

    /// A single-card group — the legacy replica.
    pub fn single(device: DeviceKind) -> ReplicaSpec {
        ReplicaSpec { device, tp: 1 }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        if ![1, 2, 4, 8].contains(&self.tp) {
            anyhow::bail!("replica tp must be 1, 2, 4 or 8 (got {})", self.tp);
        }
        Ok(())
    }

    /// Parse either fleet-entry form: `"gaudi2"` (tp 1) or
    /// `{"device": "gaudi2", "tp": 4}`.
    pub fn from_json(j: &Json) -> anyhow::Result<ReplicaSpec> {
        match j {
            Json::Str(name) => {
                let device = DeviceKind::parse(name)
                    .ok_or_else(|| anyhow::anyhow!("unknown fleet device '{name}'"))?;
                Ok(ReplicaSpec::single(device))
            }
            Json::Obj(_) => {
                let name = j
                    .req("device")
                    .map_err(|e| anyhow::anyhow!("fleet entry: {e}"))?
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("fleet entry 'device' must be a string"))?;
                let device = DeviceKind::parse(name)
                    .ok_or_else(|| anyhow::anyhow!("unknown fleet device '{name}'"))?;
                let tp = match j.get("tp") {
                    None => 1,
                    Some(v) => v
                        .as_usize()
                        .ok_or_else(|| anyhow::anyhow!("fleet entry 'tp' must be an integer"))?,
                };
                let spec = ReplicaSpec { device, tp };
                spec.validate()?;
                Ok(spec)
            }
            _ => anyhow::bail!("bad 'fleet' entry (want a device string or {{device, tp}} object)"),
        }
    }

    /// Emit the compact string form when tp = 1 so pre-group configs and
    /// committed artifacts round-trip byte-identically.
    pub fn to_json(&self) -> Json {
        if self.tp == 1 {
            Json::Str(self.device.json_tag().into())
        } else {
            Json::obj(vec![
                ("device", Json::Str(self.device.json_tag().into())),
                ("tp", Json::Num(self.tp as f64)),
            ])
        }
    }

    /// Human-readable group label: `gaudi2` or `gaudi2 x4`.
    pub fn desc(&self) -> String {
        if self.tp == 1 {
            self.device.json_tag().to_string()
        } else {
            format!("{} x{}", self.device.json_tag(), self.tp)
        }
    }
}

/// Configuration for the vLLM-style serving engine / cluster.
#[derive(Debug, Clone, PartialEq)]
pub struct ServingConfig {
    /// Target device for the simulated backend.
    pub device: DeviceKind,
    /// Number of devices (tensor parallelism degree) *per replica*.
    pub tensor_parallel: usize,
    /// KV-cache block size in tokens (vLLM default 128 on Gaudi, 16 on GPU).
    pub block_size: usize,
    /// Total KV blocks available (per replica).
    pub num_blocks: usize,
    /// Maximum number of sequences decoded per step (Fig 17(d) knob).
    pub max_decode_batch: usize,
    /// Maximum tokens scheduled per prefill step.
    pub max_prefill_tokens: usize,
    /// Maximum model sequence length.
    pub max_seq_len: usize,
    /// Use the BlockList layout (vLLM_opt) instead of zero-padded
    /// BlockTable (vLLM_base).
    pub use_block_list: bool,
    /// Fraction of blocks kept free before admitting new prefills.
    pub watermark: f64,
    /// Budget (in blocks, out of `num_blocks`) the shared-prefix cache
    /// may hold resident per replica. 0 disables prefix caching; a value
    /// >= `num_blocks` is effectively unbounded (only physical pressure
    /// then limits residency, which reproduces the legacy ever-warm-set
    /// behavior under ample memory).
    pub prefix_cache_blocks: usize,
    /// Which idle shared prefix to evict first under cache pressure.
    pub eviction: EvictionPolicy,
    /// Data-parallel engine replicas behind the router
    /// (`serving::cluster::ClusterSim`).
    pub replicas: usize,
    /// Router dispatch policy across replicas.
    pub route_policy: RoutePolicy,
    /// Router queue cap: maximum in-flight (routed, unfinished) requests
    /// before admission returns backpressure.
    pub max_queued: usize,
    /// Per-replica device groups for heterogeneous fleets (mixed Gaudi-2 +
    /// A100 behind one router, each replica `tp` cards wide). Empty means
    /// homogeneous: `replicas` copies of `device` at `tensor_parallel`
    /// cards each. When non-empty its length must equal `replicas`. JSON
    /// accepts `"gaudi2"` (tp 1) and `{"device": "gaudi2", "tp": 4}`
    /// entries interchangeably.
    pub fleet: Vec<ReplicaSpec>,
    /// Traffic classes served by this deployment (`serving::qos`): each
    /// request carries a `class_id` indexing this set, fixing its SLO,
    /// scheduling priority and goodput weight. JSON: `"classes":
    /// [{"name": ..., "priority": ..., "ttft_slo": ..., "tpot_slo": ...,
    /// "weight": ...}, ...]`. The default is the single `default` class,
    /// which reproduces the pre-QoS scalar-SLO behavior bitwise.
    pub classes: ClassSet,
    /// Hedged requests (`serving::chaos`): a routed request still
    /// first-token-less this many seconds after delivery is duplicated
    /// to a second replica; first completion wins, the loser is
    /// cancelled. 0 (the default) disables hedging.
    pub hedge_after_s: f64,
    /// Per-class admission control: once the router's queue reaches this
    /// fraction of `max_queued`, priority-0 background requests are shed
    /// at the door. Must be in (0, 1]; 1.0 (the default) disables
    /// shedding (that regime belongs to `QueueFull` backpressure).
    pub shed_threshold: f64,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            device: DeviceKind::Gaudi2,
            tensor_parallel: 1,
            block_size: 128,
            num_blocks: 4096,
            max_decode_batch: 64,
            max_prefill_tokens: 8192,
            max_seq_len: 4096,
            use_block_list: true,
            watermark: 0.01,
            prefix_cache_blocks: 4096,
            eviction: EvictionPolicy::Lru,
            replicas: 1,
            route_policy: RoutePolicy::RoundRobin,
            max_queued: 4096,
            fleet: Vec::new(),
            classes: ClassSet::default(),
            hedge_after_s: 0.0,
            shed_threshold: 1.0,
        }
    }
}

impl ServingConfig {
    pub fn from_json(s: &str) -> anyhow::Result<Self> {
        let j = Json::parse(s).map_err(|e| anyhow::anyhow!("{e}"))?;
        let d = ServingConfig::default();
        let get_usize = |key: &str, dflt: usize| -> anyhow::Result<usize> {
            match j.get(key) {
                None => Ok(dflt),
                Some(v) => v.as_usize().ok_or_else(|| anyhow::anyhow!("bad field '{key}'")),
            }
        };
        let cfg = ServingConfig {
            device: match j.get("device") {
                None => d.device,
                Some(v) => {
                    let name = v.as_str().ok_or_else(|| anyhow::anyhow!("bad 'device'"))?;
                    DeviceKind::parse(name)
                        .ok_or_else(|| anyhow::anyhow!("unknown device '{name}'"))?
                }
            },
            tensor_parallel: get_usize("tensor_parallel", d.tensor_parallel)?,
            block_size: get_usize("block_size", d.block_size)?,
            num_blocks: get_usize("num_blocks", d.num_blocks)?,
            max_decode_batch: get_usize("max_decode_batch", d.max_decode_batch)?,
            max_prefill_tokens: get_usize("max_prefill_tokens", d.max_prefill_tokens)?,
            max_seq_len: get_usize("max_seq_len", d.max_seq_len)?,
            use_block_list: match j.get("use_block_list") {
                None => d.use_block_list,
                Some(v) => v.as_bool().ok_or_else(|| anyhow::anyhow!("bad 'use_block_list'"))?,
            },
            watermark: match j.get("watermark") {
                None => d.watermark,
                Some(v) => v.as_f64().ok_or_else(|| anyhow::anyhow!("bad 'watermark'"))?,
            },
            prefix_cache_blocks: get_usize("prefix_cache_blocks", d.prefix_cache_blocks)?,
            eviction: match j.get("eviction") {
                None => d.eviction,
                Some(v) => {
                    let name = v.as_str().ok_or_else(|| anyhow::anyhow!("bad 'eviction'"))?;
                    EvictionPolicy::parse(name)
                        .ok_or_else(|| anyhow::anyhow!("unknown eviction '{name}'"))?
                }
            },
            replicas: get_usize("replicas", d.replicas)?,
            route_policy: match j.get("route_policy") {
                None => d.route_policy,
                Some(v) => {
                    let name = v.as_str().ok_or_else(|| anyhow::anyhow!("bad 'route_policy'"))?;
                    RoutePolicy::parse(name)
                        .ok_or_else(|| anyhow::anyhow!("unknown route_policy '{name}'"))?
                }
            },
            max_queued: get_usize("max_queued", d.max_queued)?,
            fleet: match j.get("fleet") {
                None => Vec::new(),
                Some(v) => v
                    .as_arr()
                    .ok_or_else(|| {
                        anyhow::anyhow!(
                            "bad 'fleet' (want an array of device names or {{device, tp}} objects)"
                        )
                    })?
                    .iter()
                    .map(ReplicaSpec::from_json)
                    .collect::<anyhow::Result<Vec<ReplicaSpec>>>()?,
            },
            classes: match j.get("classes") {
                None => ClassSet::default(),
                Some(v) => ClassSet::from_json(v)?,
            },
            hedge_after_s: match j.get("hedge_after_s") {
                None => d.hedge_after_s,
                Some(v) => v.as_f64().ok_or_else(|| anyhow::anyhow!("bad 'hedge_after_s'"))?,
            },
            shed_threshold: match j.get("shed_threshold") {
                None => d.shed_threshold,
                Some(v) => v.as_f64().ok_or_else(|| anyhow::anyhow!("bad 'shed_threshold'"))?,
            },
        };
        // A fleet listed without an explicit replica count sizes the fleet.
        let cfg = if !cfg.fleet.is_empty() && j.get("replicas").is_none() {
            ServingConfig { replicas: cfg.fleet.len(), ..cfg }
        } else {
            cfg
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn to_json(&self) -> String {
        Json::obj(vec![
            ("device", Json::Str(self.device.json_tag().into())),
            ("tensor_parallel", Json::Num(self.tensor_parallel as f64)),
            ("block_size", Json::Num(self.block_size as f64)),
            ("num_blocks", Json::Num(self.num_blocks as f64)),
            ("max_decode_batch", Json::Num(self.max_decode_batch as f64)),
            ("max_prefill_tokens", Json::Num(self.max_prefill_tokens as f64)),
            ("max_seq_len", Json::Num(self.max_seq_len as f64)),
            ("use_block_list", Json::Bool(self.use_block_list)),
            ("watermark", Json::Num(self.watermark)),
            ("prefix_cache_blocks", Json::Num(self.prefix_cache_blocks as f64)),
            ("eviction", Json::Str(self.eviction.name().into())),
            ("replicas", Json::Num(self.replicas as f64)),
            ("route_policy", Json::Str(self.route_policy.name().into())),
            ("max_queued", Json::Num(self.max_queued as f64)),
            ("fleet", Json::Arr(self.fleet.iter().map(|s| s.to_json()).collect())),
            ("classes", self.classes.to_json()),
            ("hedge_after_s", Json::Num(self.hedge_after_s)),
            ("shed_threshold", Json::Num(self.shed_threshold)),
        ])
        .dump()
    }

    /// The device group of every replica: the explicit `fleet` when
    /// given, otherwise `replicas` copies of `device` at the scalar
    /// `tensor_parallel` degree — so every pre-group config describes
    /// exactly the fleet it always did.
    pub fn replica_specs(&self) -> Vec<ReplicaSpec> {
        if self.fleet.is_empty() {
            vec![ReplicaSpec::new(self.device, self.tensor_parallel); self.replicas]
        } else {
            self.fleet.clone()
        }
    }

    /// The device kind of every replica (group width dropped) — kept for
    /// callers that only care about heterogeneity, e.g. fleet labels.
    pub fn replica_devices(&self) -> Vec<DeviceKind> {
        self.replica_specs().iter().map(|s| s.device).collect()
    }

    /// Device-group fleet constructor: one `ReplicaSpec` per replica.
    pub fn with_replica_specs(mut self, fleet: Vec<ReplicaSpec>) -> ServingConfig {
        self.replicas = fleet.len().max(1);
        self.fleet = fleet;
        self
    }

    /// Heterogeneous-fleet constructor: one single-card entry per
    /// replica. Thin shim over [`ServingConfig::with_replica_specs`],
    /// kept for pre-group callers; prefer the spec form in new code.
    pub fn with_fleet(self, fleet: Vec<DeviceKind>) -> ServingConfig {
        self.with_replica_specs(fleet.into_iter().map(ReplicaSpec::single).collect())
    }

    /// Basic sanity validation; returns an error naming the bad field.
    pub fn validate(&self) -> anyhow::Result<()> {
        if self.block_size == 0 || !self.block_size.is_power_of_two() {
            anyhow::bail!("block_size must be a nonzero power of two");
        }
        if self.num_blocks == 0 {
            anyhow::bail!("num_blocks must be > 0");
        }
        // `prefix_cache_blocks` needs no bound: 0 disables prefix caching
        // and any value >= num_blocks is effectively unbounded.
        if self.max_decode_batch == 0 {
            anyhow::bail!("max_decode_batch must be > 0");
        }
        if !(0.0..0.5).contains(&self.watermark) {
            anyhow::bail!("watermark must be in [0, 0.5)");
        }
        if ![1, 2, 4, 8].contains(&self.tensor_parallel) {
            anyhow::bail!("tensor_parallel must be 1, 2, 4 or 8");
        }
        if self.replicas == 0 {
            anyhow::bail!("replicas must be > 0");
        }
        if self.max_queued == 0 {
            anyhow::bail!("max_queued must be > 0");
        }
        if !self.fleet.is_empty() && self.fleet.len() != self.replicas {
            anyhow::bail!(
                "fleet lists {} device groups but replicas is {}",
                self.fleet.len(),
                self.replicas
            );
        }
        for spec in &self.fleet {
            spec.validate()?;
        }
        self.classes.validate()?;
        if !self.hedge_after_s.is_finite() || self.hedge_after_s < 0.0 {
            anyhow::bail!("hedge_after_s must be finite and >= 0");
        }
        if !self.shed_threshold.is_finite()
            || self.shed_threshold <= 0.0
            || self.shed_threshold > 1.0
        {
            anyhow::bail!("shed_threshold must be in (0, 1]");
        }
        Ok(())
    }

    /// Replace the deployment's traffic classes (builder-style).
    pub fn with_classes(mut self, classes: ClassSet) -> ServingConfig {
        self.classes = classes;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        ServingConfig::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let c = ServingConfig {
            max_decode_batch: 128,
            device: DeviceKind::A100,
            use_block_list: false,
            replicas: 4,
            route_policy: RoutePolicy::LeastLoaded,
            max_queued: 512,
            ..Default::default()
        };
        let j = c.to_json();
        let c2 = ServingConfig::from_json(&j).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn partial_json_uses_defaults() {
        let c = ServingConfig::from_json(r#"{"max_decode_batch": 32}"#).unwrap();
        assert_eq!(c.max_decode_batch, 32);
        assert_eq!(c.block_size, ServingConfig::default().block_size);
        assert_eq!(c.replicas, 1);
        assert_eq!(c.route_policy, RoutePolicy::RoundRobin);
    }

    #[test]
    fn prefix_cache_fields_parse_and_roundtrip() {
        let c = ServingConfig::from_json(
            r#"{"prefix_cache_blocks": 256, "eviction": "cost_aware"}"#,
        )
        .unwrap();
        assert_eq!(c.prefix_cache_blocks, 256);
        assert_eq!(c.eviction, EvictionPolicy::CostAware);
        let back = ServingConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back, c);
        // Defaults: budget equals the default pool (effectively unbounded),
        // LRU eviction.
        let d = ServingConfig::default();
        assert_eq!(d.prefix_cache_blocks, d.num_blocks);
        assert_eq!(d.eviction, EvictionPolicy::Lru);
        // 0 disables; bad names are errors.
        assert_eq!(
            ServingConfig::from_json(r#"{"prefix_cache_blocks": 0}"#).unwrap().prefix_cache_blocks,
            0
        );
        assert!(ServingConfig::from_json(r#"{"eviction": "fifo"}"#).is_err());
        assert!(ServingConfig::from_json(r#"{"prefix_cache_blocks": true}"#).is_err());
    }

    #[test]
    fn cluster_fields_parse() {
        let c = ServingConfig::from_json(
            r#"{"replicas": 8, "route_policy": "least_loaded", "max_queued": 64}"#,
        )
        .unwrap();
        assert_eq!(c.replicas, 8);
        assert_eq!(c.route_policy, RoutePolicy::LeastLoaded);
        assert_eq!(c.max_queued, 64);
    }

    #[test]
    fn fleet_roundtrips_and_sizes_replicas() {
        let c = ServingConfig::from_json(r#"{"fleet": ["gaudi2", "a100", "gaudi2"]}"#).unwrap();
        assert_eq!(c.replicas, 3);
        assert_eq!(
            c.replica_devices(),
            vec![DeviceKind::Gaudi2, DeviceKind::A100, DeviceKind::Gaudi2]
        );
        let back = ServingConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back, c);
        // Homogeneous config expands `device` x `replicas`.
        let h = ServingConfig { replicas: 2, device: DeviceKind::A100, ..Default::default() };
        assert_eq!(h.replica_devices(), vec![DeviceKind::A100; 2]);
        // Builder keeps replicas in sync.
        let b = ServingConfig::default().with_fleet(vec![DeviceKind::A100; 4]);
        assert_eq!(b.replicas, 4);
        b.validate().unwrap();
    }

    #[test]
    fn fleet_replica_mismatch_rejected() {
        assert!(ServingConfig::from_json(r#"{"replicas": 2, "fleet": ["gaudi2"]}"#).is_err());
        assert!(ServingConfig::from_json(r#"{"fleet": ["warp9"]}"#).is_err());
        assert!(ServingConfig::from_json(r#"{"fleet": [3]}"#).is_err());
        assert!(ServingConfig::from_json(r#"{"fleet": "gaudi2"}"#).is_err());
    }

    #[test]
    fn fleet_object_form_parses_and_roundtrips() {
        // Both entry forms in one fleet: bare string means tp 1.
        let c = ServingConfig::from_json(
            r#"{"fleet": ["gaudi2", {"device": "a100", "tp": 4}, {"device": "gaudi2"}]}"#,
        )
        .unwrap();
        assert_eq!(c.replicas, 3);
        assert_eq!(
            c.fleet,
            vec![
                ReplicaSpec::single(DeviceKind::Gaudi2),
                ReplicaSpec::new(DeviceKind::A100, 4),
                ReplicaSpec::single(DeviceKind::Gaudi2),
            ]
        );
        let back = ServingConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back, c);
        // The emitted JSON keeps tp=1 groups in the compact string form,
        // so pre-group configs and artifacts round-trip unchanged.
        assert!(c.to_json().contains(r#""gaudi2""#));
        assert!(c.to_json().contains(r#""tp": 4"#) || c.to_json().contains(r#""tp":4"#));
        // Bare-string-form and object-form tp=1 entries are the same spec.
        let s = ServingConfig::from_json(r#"{"fleet": ["a100"]}"#).unwrap();
        let o = ServingConfig::from_json(r#"{"fleet": [{"device": "a100", "tp": 1}]}"#).unwrap();
        assert_eq!(s.fleet, o.fleet);
        assert_eq!(s.to_json(), o.to_json());
    }

    #[test]
    fn replica_specs_defaults_and_validation() {
        // No explicit fleet: replicas x (device, tensor_parallel).
        let h = ServingConfig {
            replicas: 2,
            device: DeviceKind::A100,
            tensor_parallel: 4,
            ..Default::default()
        };
        assert_eq!(h.replica_specs(), vec![ReplicaSpec::new(DeviceKind::A100, 4); 2]);
        assert_eq!(h.replica_devices(), vec![DeviceKind::A100; 2]);
        // Builder keeps replicas in sync and survives validation.
        let b = ServingConfig::default().with_replica_specs(vec![
            ReplicaSpec::new(DeviceKind::Gaudi2, 8),
            ReplicaSpec::single(DeviceKind::A100),
        ]);
        assert_eq!(b.replicas, 2);
        b.validate().unwrap();
        assert_eq!(b.fleet[0].desc(), "gaudi2 x8");
        assert_eq!(b.fleet[1].desc(), "a100");
        // The legacy shim builds tp=1 groups.
        let legacy = ServingConfig::default().with_fleet(vec![DeviceKind::Gaudi2; 3]);
        assert_eq!(legacy.fleet, vec![ReplicaSpec::single(DeviceKind::Gaudi2); 3]);
        // Bad group widths are rejected in JSON and in validate().
        assert!(ServingConfig::from_json(r#"{"fleet": [{"device": "a100", "tp": 3}]}"#).is_err());
        assert!(ServingConfig::from_json(r#"{"fleet": [{"tp": 2}]}"#).is_err());
        let bad = ServingConfig::default()
            .with_replica_specs(vec![ReplicaSpec::new(DeviceKind::A100, 5)]);
        assert!(bad.validate().is_err());
    }

    #[test]
    fn classes_parse_roundtrip_and_default() {
        // Default: the single legacy-equivalent class.
        let d = ServingConfig::default();
        assert_eq!(d.classes, ClassSet::default());
        assert_eq!(d.classes.class(0).name, "default");
        // Explicit classes parse with per-field defaults.
        let c = ServingConfig::from_json(
            r#"{"classes": [
                {"name": "interactive", "priority": 2, "ttft_slo": 0.5, "tpot_slo": 0.05, "weight": 4.0},
                {"name": "batch", "priority": 1, "ttft_slo": 2.0},
                {"name": "background"}
            ]}"#,
        )
        .unwrap();
        assert_eq!(c.classes.len(), 3);
        assert_eq!(c.classes.class(0).priority, 2);
        assert_eq!(c.classes.class(1).tpot_slo, 0.1, "unspecified fields default");
        assert_eq!(c.classes.class(2).priority, 0);
        let back = ServingConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back, c);
        // Builder keeps validation happy.
        let b = ServingConfig::default().with_classes(ClassSet::three_tier());
        b.validate().unwrap();
        assert_eq!(b.classes.len(), 3);
    }

    #[test]
    fn bad_classes_rejected() {
        assert!(ServingConfig::from_json(r#"{"classes": []}"#).is_err());
        assert!(ServingConfig::from_json(r#"{"classes": "chat"}"#).is_err());
        assert!(ServingConfig::from_json(r#"{"classes": [{"priority": 1}]}"#).is_err());
        assert!(ServingConfig::from_json(
            r#"{"classes": [{"name": "a"}, {"name": "a"}]}"#
        )
        .is_err());
        assert!(ServingConfig::from_json(
            r#"{"classes": [{"name": "a", "ttft_slo": 0.0}]}"#
        )
        .is_err());
    }

    #[test]
    fn chaos_fields_parse_roundtrip_and_validate() {
        let d = ServingConfig::default();
        assert_eq!(d.hedge_after_s, 0.0, "hedging off by default");
        assert_eq!(d.shed_threshold, 1.0, "shedding off by default");
        let c = ServingConfig::from_json(
            r#"{"hedge_after_s": 0.25, "shed_threshold": 0.5}"#,
        )
        .unwrap();
        assert_eq!(c.hedge_after_s, 0.25);
        assert_eq!(c.shed_threshold, 0.5);
        let back = ServingConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back, c);
        assert!(ServingConfig::from_json(r#"{"hedge_after_s": -1.0}"#).is_err());
        assert!(ServingConfig::from_json(r#"{"hedge_after_s": "fast"}"#).is_err());
        assert!(ServingConfig::from_json(r#"{"shed_threshold": 0.0}"#).is_err());
        assert!(ServingConfig::from_json(r#"{"shed_threshold": 1.5}"#).is_err());
    }

    #[test]
    fn validation_rejects_bad_fields() {
        assert!(ServingConfig::from_json(r#"{"block_size": 100}"#).is_err());
        assert!(ServingConfig::from_json(r#"{"tensor_parallel": 3}"#).is_err());
        assert!(ServingConfig::from_json(r#"{"watermark": 0.9}"#).is_err());
        assert!(ServingConfig::from_json(r#"{"device": "tpu9"}"#).is_err());
        assert!(ServingConfig::from_json(r#"{"replicas": 0}"#).is_err());
        assert!(ServingConfig::from_json(r#"{"route_policy": "hash9"}"#).is_err());
        assert!(ServingConfig::from_json(r#"{"max_queued": 0}"#).is_err());
        assert!(ServingConfig::from_json("not json").is_err());
    }
}
