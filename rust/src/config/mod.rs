//! Configuration: static device specifications (Table 1 of the paper) and
//! run-time experiment/serving configuration loaded from JSON.

pub mod device_specs;
pub mod serving_config;

pub use device_specs::{DeviceKind, DeviceSpec};
pub use serving_config::{ReplicaSpec, ServingConfig};
