//! Device specifications — Table 1 of the paper, plus the microarchitectural
//! parameters the simulators need (sourced from the paper's §2 and public
//! documentation: MME 256×256×2 MACs, 24 TPCs with 2048-bit SIMD and
//! 4-cycle architectural latency, 256 B minimum global access granularity;
//! A100: 108 SMs, 32 B DRAM sectors).

use crate::util::units::{GB, TB, TFLOPS};

/// Which device a simulation runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DeviceKind {
    /// Intel Gaudi-2 NPU (HLS-Gaudi-2 server node, 8 devices, RoCE P2P mesh).
    Gaudi2,
    /// NVIDIA A100 80GB (DGX A100 node, 8 devices, NVSwitch).
    A100,
}

impl DeviceKind {
    pub fn name(&self) -> &'static str {
        match self {
            DeviceKind::Gaudi2 => "Gaudi-2",
            DeviceKind::A100 => "A100",
        }
    }

    pub fn spec(&self) -> DeviceSpec {
        match self {
            DeviceKind::Gaudi2 => DeviceSpec::gaudi2(),
            DeviceKind::A100 => DeviceSpec::a100(),
        }
    }

    /// Parse a CLI/JSON name ("gaudi2", "a100", case-insensitive).
    pub fn parse(s: &str) -> Option<DeviceKind> {
        match s.to_ascii_lowercase().as_str() {
            "gaudi2" | "gaudi-2" | "hpu" => Some(DeviceKind::Gaudi2),
            "a100" | "cuda" | "gpu" => Some(DeviceKind::A100),
            _ => None,
        }
    }

    /// Canonical JSON/config tag — the emit side of [`DeviceKind::parse`]
    /// (`parse(k.json_tag()) == Some(k)` for every kind).
    pub fn json_tag(&self) -> &'static str {
        match self {
            DeviceKind::Gaudi2 => "gaudi2",
            DeviceKind::A100 => "a100",
        }
    }

    pub const BOTH: [DeviceKind; 2] = [DeviceKind::Gaudi2, DeviceKind::A100];
}

/// Static per-device specification (Table 1) + microarchitecture constants.
#[derive(Debug, Clone)]
pub struct DeviceSpec {
    pub kind: DeviceKind,
    /// Peak matrix-engine throughput, BF16 FLOP/s (MME / Tensor Cores).
    pub matrix_tflops: f64,
    /// Peak vector-engine throughput, BF16 FLOP/s (TPC / SIMD cores).
    pub vector_tflops: f64,
    /// HBM capacity in bytes.
    pub hbm_capacity: f64,
    /// HBM peak bandwidth, bytes/sec.
    pub hbm_bandwidth: f64,
    /// On-chip SRAM (Gaudi shared memory / A100 L2), bytes.
    pub sram_bytes: f64,
    /// Aggregate intra-node communication bandwidth per device, bytes/sec
    /// per direction (both nodes: 300 GB/s).
    pub comm_bandwidth: f64,
    /// TDP in watts.
    pub tdp_watts: f64,
    /// Minimum efficient global-memory access granularity, bytes
    /// (Gaudi: 256 B chunks; A100: 32 B sectors).
    pub mem_access_granularity: f64,
    /// Number of independently schedulable vector processors
    /// (Gaudi: 24 TPCs; A100: 108 SMs).
    pub num_vector_cores: usize,
    /// Empirical fraction of peak HBM bandwidth sustainable by streaming
    /// kernels (STREAM-like). Calibrated: Gaudi TRIAD saturates ~2.0 TB/s
    /// of 2.45; A100 ~1.74 of 2.0.
    pub stream_efficiency: f64,
    /// Per-access random-access derating overhead in bytes (row activation,
    /// TLB, request-path) applied by the gather/scatter model.
    pub random_access_overhead_bytes: f64,
    /// Kernel launch overhead, seconds (CUDA launch ~4 us; Gaudi TPC kernel
    /// dispatch through synLaunch is heavier).
    pub kernel_launch_overhead: f64,
}

impl DeviceSpec {
    pub fn gaudi2() -> Self {
        DeviceSpec {
            kind: DeviceKind::Gaudi2,
            matrix_tflops: 432.0 * TFLOPS,
            vector_tflops: 11.0 * TFLOPS,
            hbm_capacity: 96.0 * GB,
            hbm_bandwidth: 2.45 * TB,
            sram_bytes: 48e6,
            comm_bandwidth: 300.0 * GB,
            tdp_watts: 600.0,
            mem_access_granularity: 256.0,
            num_vector_cores: 24,
            stream_efficiency: 0.82,
            random_access_overhead_bytes: 112.0,
            kernel_launch_overhead: 5e-6,
        }
    }

    pub fn a100() -> Self {
        DeviceSpec {
            kind: DeviceKind::A100,
            matrix_tflops: 312.0 * TFLOPS,
            vector_tflops: 39.0 * TFLOPS,
            hbm_capacity: 80.0 * GB,
            hbm_bandwidth: 2.0 * TB,
            sram_bytes: 40e6,
            comm_bandwidth: 300.0 * GB,
            tdp_watts: 400.0,
            mem_access_granularity: 32.0,
            num_vector_cores: 108,
            stream_efficiency: 0.87,
            random_access_overhead_bytes: 64.0,
            kernel_launch_overhead: 3e-6,
        }
    }

    /// Table-1 style ratio row helper: Gaudi-2 value / A100 value.
    pub fn ratio(get: impl Fn(&DeviceSpec) -> f64) -> f64 {
        get(&DeviceSpec::gaudi2()) / get(&DeviceSpec::a100())
    }

    /// Gaudi-3 projection (paper footnote 1: "virtually identical to
    /// Gaudi-2 ... except higher compute and memory throughput, thanks to
    /// its chiplet-based design"): 2x MME FLOPS (1835 BF16 TF/2 = public
    /// 1835 is FP8; BF16 is ~2x Gaudi-2), 128 GB HBM2E @ 3.7 TB/s, 64 TPCs
    /// worth of vector throughput, 96 MB SRAM, 1200 GbE RoCE.
    pub fn gaudi3_projection() -> Self {
        DeviceSpec {
            kind: DeviceKind::Gaudi2, // same simulator mechanisms
            matrix_tflops: 864.0 * TFLOPS,
            vector_tflops: 28.7 * TFLOPS,
            hbm_capacity: 128.0 * GB,
            hbm_bandwidth: 3.7 * TB,
            sram_bytes: 96e6,
            comm_bandwidth: 600.0 * GB,
            tdp_watts: 900.0,
            ..DeviceSpec::gaudi2()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_ratios_match_paper() {
        // Table 1 of the paper reports these ratios (Gaudi-2 / A100).
        assert!((DeviceSpec::ratio(|s| s.matrix_tflops) - 1.3846).abs() < 0.01); // "1.4x"
        assert!((DeviceSpec::ratio(|s| s.vector_tflops) - 0.282).abs() < 0.01); // "0.3x"
        assert!((DeviceSpec::ratio(|s| s.hbm_capacity) - 1.2).abs() < 0.01);
        assert!((DeviceSpec::ratio(|s| s.hbm_bandwidth) - 1.225).abs() < 0.03); // "1.2x"
        assert!((DeviceSpec::ratio(|s| s.sram_bytes) - 1.2).abs() < 0.01);
        assert!((DeviceSpec::ratio(|s| s.comm_bandwidth) - 1.0).abs() < 1e-9);
        assert!((DeviceSpec::ratio(|s| s.tdp_watts) - 1.5).abs() < 1e-9);
    }

    #[test]
    fn kind_roundtrip() {
        assert_eq!(DeviceKind::Gaudi2.spec().kind, DeviceKind::Gaudi2);
        assert_eq!(DeviceKind::A100.spec().kind, DeviceKind::A100);
        assert_eq!(DeviceKind::Gaudi2.name(), "Gaudi-2");
        for k in DeviceKind::BOTH {
            assert_eq!(DeviceKind::parse(k.json_tag()), Some(k), "{k:?}");
        }
    }

    #[test]
    fn aggregate_compute_ratio() {
        // Paper: "Gaudi-2 delivers approximately 1.26x in aggregate higher
        // compute throughput than A100".
        let g = DeviceSpec::gaudi2();
        let a = DeviceSpec::a100();
        let ratio = (g.matrix_tflops + g.vector_tflops) / (a.matrix_tflops + a.vector_tflops);
        assert!((ratio - 1.26).abs() < 0.01, "aggregate ratio {ratio}");
    }
}
