//! Attention operators: flash-style prefill attention and the three
//! PagedAttention implementations of the §4.2 vLLM case study (Fig 16/17).
//!
//! * `A100Paged` — vLLM's fused CUDA PagedAttention kernel: one pass over
//!   the KV cache at near-streaming bandwidth.
//! * `GaudiVllmBase` — the baseline Gaudi vLLM fork: a zero-padded 2D
//!   `BlockTable` drives a fine-grained TPC gather of *every* table entry
//!   (including padding), the gathered KV is written back to a contiguous
//!   HBM region (the shapes are bucketed to the model's max length to
//!   avoid graph recompilation), and only then FusedSDPA runs — no
//!   MME/TPC pipelining is possible across the contiguous barrier, and
//!   each step pays per-block dispatch plus dynamic-shape fallback costs.
//! * `GaudiVllmOpt` — the paper's optimization: a flat `BlockList` of only
//!   the effectual block indices; the TPC gather and the MME batched GEMM
//!   are sliced by the graph compiler and pipelined through SRAM. KV still
//!   crosses the pins twice (QK^T and PV passes — Gaudi cannot fuse a
//!   FlashAttention-style single pass), which is the remaining ~2.2× gap
//!   vs the A100 kernel (Key Takeaway #7).

use crate::config::{DeviceKind, DeviceSpec};
use crate::sim::device::Device;
use crate::sim::graph_compiler;
use crate::sim::Dtype;

/// Shape of one paged-attention execution (decode step, per layer).
#[derive(Debug, Clone, Copy)]
pub struct PagedAttnWork {
    pub batch: usize,
    /// Effectual KV length per sequence (tokens).
    pub kv_len: usize,
    /// Padded BlockTable length (tokens); >= kv_len. The zero-padding
    /// fraction of Fig 17(b) is `1 - kv_len/padded_len`.
    pub padded_len: usize,
    pub n_q_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    /// KV-cache block size in tokens.
    pub block_size: usize,
}

impl PagedAttnWork {
    /// Llama-3.1-8B attention geometry at a given batch/length.
    pub fn llama8b(batch: usize, kv_len: usize) -> Self {
        PagedAttnWork {
            batch,
            kv_len,
            padded_len: kv_len,
            n_q_heads: 32,
            n_kv_heads: 8,
            head_dim: 128,
            block_size: 128,
        }
    }

    pub fn with_padding(mut self, zero_fraction: f64) -> Self {
        assert!((0.0..1.0).contains(&zero_fraction));
        self.padded_len = ((self.kv_len as f64 / (1.0 - zero_fraction)).round() as usize)
            .max(self.kv_len);
        self
    }

    /// KV bytes per sequence-token (K + V, all kv heads), BF16.
    pub fn kv_bytes_per_token(&self) -> f64 {
        2.0 * self.n_kv_heads as f64 * self.head_dim as f64 * Dtype::Bf16.bytes()
    }

    /// Effectual KV-cache bytes read by a correct implementation.
    pub fn kv_bytes(&self) -> f64 {
        self.batch as f64 * self.kv_len as f64 * self.kv_bytes_per_token()
    }

    /// Padded KV bytes (what vLLM_base actually touches).
    pub fn padded_kv_bytes(&self) -> f64 {
        self.batch as f64 * self.padded_len as f64 * self.kv_bytes_per_token()
    }

    /// Attention FLOPs for one decode step (QK^T + PV).
    pub fn flops(&self) -> f64 {
        2.0 * 2.0
            * self.batch as f64
            * self.n_q_heads as f64
            * self.kv_len as f64
            * self.head_dim as f64
    }
}

/// Which PagedAttention implementation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PagedAttnImpl {
    GaudiVllmBase,
    GaudiVllmOpt,
    A100Paged,
}

impl PagedAttnImpl {
    pub fn name(&self) -> &'static str {
        match self {
            PagedAttnImpl::GaudiVllmBase => "vLLM_base(Gaudi)",
            PagedAttnImpl::GaudiVllmOpt => "vLLM_opt(Gaudi)",
            PagedAttnImpl::A100Paged => "vLLM(A100)",
        }
    }

    pub fn device(&self) -> DeviceKind {
        match self {
            PagedAttnImpl::A100Paged => DeviceKind::A100,
            _ => DeviceKind::Gaudi2,
        }
    }
}

// --- Calibrated efficiency constants (see module docs for mechanisms) ---

/// vLLM_base's BlockTable gather: per-head fine-grained index_select-style
/// TPC processing, SDK-operator quality.
const BASE_GATHER_EFF: f64 = 0.14;
/// Streaming efficiency of the contiguous writeback + FusedSDPA reads.
const STREAM_EFF: f64 = 0.82;
/// vLLM_base dispatches TPC gather work in 8-block slices.
const BASE_BLOCKS_PER_DISPATCH: f64 = 8.0;
const BASE_DISPATCH_OVERHEAD: f64 = 3e-6;
/// Dynamic-shape handling cost per step (bucketing miss / partial graph
/// replay) in the baseline fork.
const BASE_STEP_OVERHEAD: f64 = 180e-6;
/// vLLM_base buckets the FusedSDPA shapes to the model max length.
const BASE_BUCKET_LEN: usize = 4096;
/// vLLM_opt's BlockList gather efficiency (block-granular random reads).
const OPT_GATHER_EFF: f64 = 0.60;
/// KV crosses HBM twice on Gaudi (QK^T pass + PV pass; no flash fusion).
const OPT_KV_PASSES: f64 = 2.0;
/// A100 fused PagedAttention kernel streams KV once.
const A100_KV_EFF: f64 = 0.88;

/// Result of a paged-attention execution.
#[derive(Debug, Clone, Copy)]
pub struct PagedAttnResult {
    pub time: f64,
    /// Output tokens per second for this step's batch.
    pub tokens_per_sec: f64,
    /// HBM bytes actually moved (diagnostic).
    pub hbm_traffic: f64,
}

/// Model one PagedAttention decode step (single layer granularity — the
/// model layer multiplies by layer count).
pub fn run(imp: PagedAttnImpl, w: PagedAttnWork) -> PagedAttnResult {
    let spec = imp.device().spec();
    let (time, traffic) = match imp {
        PagedAttnImpl::A100Paged => a100_time(&spec, w),
        PagedAttnImpl::GaudiVllmOpt => opt_time(&spec, w),
        PagedAttnImpl::GaudiVllmBase => base_time(&spec, w),
    };
    PagedAttnResult { time, tokens_per_sec: w.batch as f64 / time, hbm_traffic: traffic }
}

fn a100_time(spec: &DeviceSpec, w: PagedAttnWork) -> (f64, f64) {
    let traffic = w.kv_bytes();
    let mem = traffic / (spec.hbm_bandwidth * A100_KV_EFF);
    // Tensor-core side is never the bound for decode GEMV shapes, but
    // include it for completeness.
    let compute = w.flops() / (spec.matrix_tflops * 0.25);
    (spec.kernel_launch_overhead + mem.max(compute), traffic)
}

fn opt_time(spec: &DeviceSpec, w: PagedAttnWork) -> (f64, f64) {
    // BlockList: gather only effectual blocks; pipeline gather (TPC) with
    // the batched GEMM (MME). Both stages contend for HBM, so the pipeline
    // overlaps compute but the pin traffic adds: one gather read + one
    // extra pass (QK^T results cannot stay resident for PV at realistic
    // batch sizes, and no flash-style fusion exists).
    let kv = w.kv_bytes();
    let gather = kv / (spec.hbm_bandwidth * OPT_GATHER_EFF);
    let mme_stream = (OPT_KV_PASSES - 1.0) * kv / (spec.hbm_bandwidth * STREAM_EFF);
    let gemm = w.flops() / (spec.matrix_tflops * 0.20);
    // The graph compiler slices gather/bgemm; slicing overhead applies.
    let sliced = graph_compiler::pipeline_chain(
        spec,
        &[gather, mme_stream.max(gemm)],
        kv.min(spec.sram_bytes * 8.0),
        true,
    );
    // HBM traffic is additive even when pipelined.
    let mem_floor = gather + mme_stream;
    (spec.kernel_launch_overhead + sliced.time.max(mem_floor), kv * OPT_KV_PASSES)
}

fn base_time(spec: &DeviceSpec, w: PagedAttnWork) -> (f64, f64) {
    // BlockTable: gather *padded_len* worth of KV at fine granularity,
    // write it back contiguously, then FusedSDPA reads it twice over the
    // bucketed shape. No pipelining across the contiguous barrier.
    let padded = w.padded_kv_bytes();
    let bucket_len = w.padded_len.max(BASE_BUCKET_LEN.min(4096));
    let bucketed =
        w.batch as f64 * bucket_len as f64 * w.kv_bytes_per_token();
    let gather = padded / (spec.hbm_bandwidth * BASE_GATHER_EFF);
    let writeback = padded / (spec.hbm_bandwidth * STREAM_EFF);
    let sdpa = 2.0 * bucketed / (spec.hbm_bandwidth * STREAM_EFF);
    let n_blocks = (w.batch * w.padded_len / w.block_size) as f64;
    let dispatch = (n_blocks / BASE_BLOCKS_PER_DISPATCH).ceil() * BASE_DISPATCH_OVERHEAD;
    let time = BASE_STEP_OVERHEAD + dispatch + gather + writeback + sdpa;
    (time, padded * 2.0 + bucketed * 2.0)
}

/// Cost one decode step over a ragged batch expressed as length buckets
/// (one `PagedAttnWork` per bucket, each with its own `batch`).
///
/// On Gaudi every distinct bucketed shape is its own sliced kernel launch
/// — shape bucketing is how the graph stack avoids recompilation — so
/// per-bucket launch costs are real and additive (`GaudiVllmOpt`). The
/// baseline fork's dynamic-shape step penalty is paid once per engine
/// step regardless of bucket count, and the A100's fused kernel handles
/// ragged lengths in a single launch, so those fixed costs are charged
/// once and the extra copies the per-bucket `run` calls included are
/// refunded.
pub fn run_bucketed(imp: PagedAttnImpl, buckets: &[PagedAttnWork]) -> f64 {
    if buckets.is_empty() {
        return 0.0;
    }
    let total: f64 = buckets.iter().map(|w| run(imp, *w).time).sum();
    let extra = (buckets.len() - 1) as f64;
    match imp {
        PagedAttnImpl::GaudiVllmOpt => total,
        PagedAttnImpl::GaudiVllmBase => total - extra * BASE_STEP_OVERHEAD,
        PagedAttnImpl::A100Paged => {
            total - extra * imp.device().spec().kernel_launch_overhead
        }
    }
}

/// Flash-style prefill attention time (one layer, full batch).
pub fn prefill_attention_time(
    device: &Device,
    batch: usize,
    seq: usize,
    n_q_heads: usize,
    head_dim: usize,
) -> f64 {
    // Causal attention: ~half the S^2 work; flash kernels reach ~65-70% of
    // matrix peak at these shapes.
    let flops =
        2.0 * 2.0 * batch as f64 * n_q_heads as f64 * (seq as f64).powi(2) * head_dim as f64 / 2.0;
    let eff = match device.kind() {
        DeviceKind::Gaudi2 => 0.62, // FusedSDPA
        DeviceKind::A100 => 0.68,   // FlashAttention-2
    };
    device.spec.kernel_launch_overhead + flops / (device.spec.matrix_tflops * eff)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::mean;

    /// The Fig 17(a) sweep grid: sequence length × batch.
    fn fig17a_grid() -> Vec<PagedAttnWork> {
        let mut v = Vec::new();
        for &s in &[512usize, 1024, 2048, 4096] {
            for &b in &[8usize, 16, 32, 64] {
                v.push(PagedAttnWork::llama8b(b, s));
            }
        }
        v
    }

    #[test]
    fn fig17a_opt_avg_7x_over_base_at_zero_padding() {
        let ratios: Vec<f64> = fig17a_grid()
            .into_iter()
            .map(|w| {
                run(PagedAttnImpl::GaudiVllmBase, w).time / run(PagedAttnImpl::GaudiVllmOpt, w).time
            })
            .collect();
        let avg = mean(&ratios);
        assert!((avg - 7.4).abs() < 2.5, "avg speedup {avg} (ratios {ratios:?})");
        for r in &ratios {
            assert!(*r > 1.0, "opt must always win: {r}");
        }
    }

    #[test]
    fn fig17b_padding_amplifies_speedup() {
        // seq 4K, batch 32; padding fraction 10%..90%.
        let base_w = PagedAttnWork::llama8b(32, 4096);
        let mut ratios = Vec::new();
        for p in [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9] {
            // padded_len is capped by the 4K bucket: padding means the
            // *effectual* length shrinks while the table stays 4K.
            let eff_len = ((4096.0 * (1.0 - p)) as usize).max(1);
            let w = PagedAttnWork { kv_len: eff_len, padded_len: 4096, ..base_w };
            let r =
                run(PagedAttnImpl::GaudiVllmBase, w).time / run(PagedAttnImpl::GaudiVllmOpt, w).time;
            ratios.push(r);
        }
        let avg = mean(&ratios);
        let max = ratios.iter().cloned().fold(f64::MIN, f64::max);
        assert!(ratios.windows(2).all(|w| w[1] > w[0]), "monotone in padding: {ratios:?}");
        assert!((avg - 21.0).abs() < 9.0, "avg {avg}");
        assert!((max - 55.7).abs() < 20.0, "max {max}");
    }

    #[test]
    fn fig17c_opt_is_about_45pct_of_a100() {
        let ratios: Vec<f64> = fig17a_grid()
            .into_iter()
            .map(|w| {
                run(PagedAttnImpl::A100Paged, w).time / run(PagedAttnImpl::GaudiVllmOpt, w).time
            })
            .collect();
        let avg = mean(&ratios);
        assert!((avg - 0.45).abs() < 0.12, "opt/a100 {avg}");
    }

    #[test]
    fn traffic_accounting() {
        let w = PagedAttnWork::llama8b(32, 4096);
        let opt = run(PagedAttnImpl::GaudiVllmOpt, w);
        let base = run(PagedAttnImpl::GaudiVllmBase, w);
        let a100 = run(PagedAttnImpl::A100Paged, w);
        assert!(base.hbm_traffic > opt.hbm_traffic);
        assert!(opt.hbm_traffic > a100.hbm_traffic);
        // 32 seqs * 4096 tokens * 4096 B/token = 512 MiB effectual KV.
        assert!((a100.hbm_traffic - 32.0 * 4096.0 * 4096.0).abs() < 1.0);
    }

    #[test]
    fn padding_helper() {
        let w = PagedAttnWork::llama8b(8, 1000).with_padding(0.5);
        assert_eq!(w.padded_len, 2000);
        assert_eq!(w.kv_len, 1000);
    }

    #[test]
    fn bucketed_costing_preserves_totals_and_charges_gaudi_launches() {
        // Two buckets with the same total effectual KV as one merged call.
        let merged = PagedAttnWork::llama8b(4, 816);
        let buckets = [PagedAttnWork::llama8b(1, 3072), PagedAttnWork::llama8b(3, 64)];
        // A100's fused ragged kernel: bucketing must be cost-neutral (the
        // model is linear in total KV traffic; extra launches refunded).
        let a_merged = run(PagedAttnImpl::A100Paged, merged).time;
        let a_bucketed = run_bucketed(PagedAttnImpl::A100Paged, &buckets);
        assert!(
            (a_bucketed - a_merged).abs() / a_merged < 0.05,
            "a100 merged {a_merged} bucketed {a_bucketed}"
        );
        // Gaudi opt: each bucket is a separate sliced launch, so the
        // skewed (2-bucket) batch costs strictly more than one shape.
        let g_merged = run(PagedAttnImpl::GaudiVllmOpt, merged).time;
        let g_bucketed = run_bucketed(PagedAttnImpl::GaudiVllmOpt, &buckets);
        assert!(g_bucketed > g_merged, "gaudi merged {g_merged} bucketed {g_bucketed}");
        // Single bucket degenerates to `run`.
        let one = run_bucketed(PagedAttnImpl::GaudiVllmOpt, &[merged]);
        assert!((one - g_merged).abs() < 1e-15);
        assert_eq!(run_bucketed(PagedAttnImpl::GaudiVllmOpt, &[]), 0.0);
    }

    #[test]
    fn prefill_attention_scales_quadratically() {
        let d = Device::new(DeviceKind::Gaudi2);
        let t1 = prefill_attention_time(&d, 4, 512, 32, 128);
        let t2 = prefill_attention_time(&d, 4, 1024, 32, 128);
        assert!(t2 / t1 > 3.0 && t2 / t1 < 4.5, "ratio {}", t2 / t1);
    }

    #[test]
    fn tokens_per_sec_consistent() {
        let w = PagedAttnWork::llama8b(16, 1024);
        let r = run(PagedAttnImpl::A100Paged, w);
        assert!((r.tokens_per_sec - 16.0 / r.time).abs() < 1e-6);
    }
}
