//! Operator-level models composed from the device simulators: the units of
//! work that end-to-end applications (DLRM, Llama) and the serving engine
//! schedule.

pub mod attention;
pub mod embedding;
pub mod gemm;
pub mod mlp;
