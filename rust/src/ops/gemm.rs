//! GEMM operator: the unified entry point plus the shape sweeps used by
//! Fig 4 (roofline), Fig 5 (utilization heatmaps) and Fig 7 (geometry).

use crate::config::DeviceKind;
use crate::sim::device::{Device, GemmExec};
use crate::sim::Dtype;

/// The square GEMM sizes the figures sweep.
pub const SQUARE_SIZES: [usize; 6] = [256, 512, 1024, 2048, 4096, 8192];

/// The (M=K) sizes for irregular GEMMs with N fixed at 16 (Fig 4 triangles).
pub const IRREGULAR_MK: [usize; 4] = [2048, 4096, 8192, 16384];

/// Fixed N for irregularly-shaped GEMMs.
pub const IRREGULAR_N: usize = 16;

/// A GEMM data point for the harness.
#[derive(Debug, Clone)]
pub struct GemmPoint {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub exec: GemmExec,
    /// Arithmetic intensity FLOP/byte (x-axis of the roofline).
    pub intensity: f64,
}

/// Run one GEMM on a device kind.
pub fn run(kind: DeviceKind, m: usize, k: usize, n: usize, dtype: Dtype) -> GemmPoint {
    let exec = Device::new(kind).gemm(m, k, n, dtype);
    let flops = 2.0 * m as f64 * k as f64 * n as f64;
    let bytes = ((m * k + k * n + m * n) as f64) * dtype.bytes();
    GemmPoint { m, k, n, exec, intensity: flops / bytes }
}

/// All square + irregular shapes of Fig 4.
pub fn fig4_shapes() -> Vec<(usize, usize, usize)> {
    let mut v: Vec<(usize, usize, usize)> =
        SQUARE_SIZES.iter().map(|&s| (s, s, s)).collect();
    v.extend(IRREGULAR_MK.iter().map(|&s| (s, s, IRREGULAR_N)));
    v
}

/// The (M,N) grid of the Fig 5(a) square-heatmap (M=K=N diagonal) and
/// Fig 5(b) irregular heatmap (M,K large, N fixed small).
pub fn fig5_irregular_grid() -> Vec<(usize, usize, usize)> {
    let mut v = Vec::new();
    for &mk in &[2048usize, 4096, 8192, 16384] {
        for &n in &[16usize, 32, 64, 128] {
            v.push((mk, mk, n));
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::mean;

    #[test]
    fn fig5_gaudi_avg_utilization_gap() {
        // Paper: Gaudi-2 achieves on average ~4.5pp higher compute
        // utilization than A100 across the evaluated points, max ~32pp.
        let mut gaps = Vec::new();
        for (m, k, n) in fig4_shapes().into_iter().chain(fig5_irregular_grid()) {
            let g = run(DeviceKind::Gaudi2, m, k, n, Dtype::Bf16);
            let a = run(DeviceKind::A100, m, k, n, Dtype::Bf16);
            gaps.push(g.exec.utilization - a.exec.utilization);
        }
        let avg = mean(&gaps);
        let max = gaps.iter().cloned().fold(f64::MIN, f64::max);
        assert!(avg > 0.02 && avg < 0.10, "avg gap {avg}");
        assert!(max > 0.15 && max < 0.45, "max gap {max}");
    }

    #[test]
    fn square_gemms_climb_the_roofline() {
        let mut last = 0.0;
        for &s in &SQUARE_SIZES {
            let p = run(DeviceKind::Gaudi2, s, s, s, Dtype::Bf16);
            assert!(p.exec.achieved_flops >= last, "not monotone at {s}");
            last = p.exec.achieved_flops;
        }
    }

    #[test]
    fn irregular_gemms_sit_on_bandwidth_slope() {
        for &mk in &IRREGULAR_MK {
            let p = run(DeviceKind::Gaudi2, mk, mk, IRREGULAR_N, Dtype::Bf16);
            assert!(p.exec.memory_bound, "mk={mk} should be memory bound");
            // Achieved ~= intensity * BW (within the efficiency factor).
            let roof = p.intensity * 2.45e12;
            assert!(p.exec.achieved_flops < roof * 1.2, "above the roof at {mk}");
            assert!(p.exec.achieved_flops > roof * 0.5, "far below the roof at {mk}");
        }
    }

    #[test]
    fn intensity_computed_correctly() {
        let p = run(DeviceKind::A100, 100, 100, 100, Dtype::Bf16);
        let expect = 2.0 * 100.0f64.powi(3) / (3.0 * 10_000.0 * 2.0);
        assert!((p.intensity - expect).abs() < 1e-9);
    }
}
