//! MLP stacks and the DLRM DCNv2 interaction layer — the dense building
//! blocks of the RecSys cost model (Fig 11).
//!
//! On Gaudi, every GEMM→activation pair is pipelined by the graph compiler
//! (MME + TPC), but each fused op still pays the heavier HPU kernel
//! dispatch; on A100 the activation is a fused cuBLAS epilogue. Small MLP
//! layers are launch-bound, which is one of the two mechanisms (with
//! fine-grained gathers) behind Gaudi's RecSys deficit.

use crate::sim::device::{Device, GemmExec};
use crate::sim::graph_compiler;
use crate::sim::{Dtype, Dtype::Fp32};

/// Extra per-layer dispatch cost on Gaudi for the RecSys dense path: the
/// Gaudi SDK has no TorchRec integration, so every dense layer goes through
/// the PyTorch→graph-compiler op dispatch individually instead of a fused
/// captured graph (paper §3.5: SDK "currently lacks support for multi-device
/// RecSys serving"; the single-device path is similarly immature).
pub const GAUDI_DENSE_DISPATCH_OVERHEAD: f64 = 12e-6;

/// Result of running a dense stack.
#[derive(Debug, Clone)]
pub struct DenseResult {
    pub time: f64,
    pub flops: f64,
    /// Mean matrix-engine utilization across layers (power model input).
    pub avg_matrix_util: f64,
    /// Mean active MAC fraction (Gaudi power gating).
    pub avg_active_fraction: f64,
}

/// Time for one GEMM + element-wise activation, pipelined where possible.
fn layer_time(device: &Device, batch: usize, k: usize, n: usize, dtype: Dtype) -> (f64, GemmExec) {
    let g = device.gemm(batch, k, n, dtype);
    // Activation: stream the (batch × n) output through the vector engine.
    let act_bytes = 2.0 * batch as f64 * n as f64 * dtype.bytes();
    let act = act_bytes / (device.spec.hbm_bandwidth * device.spec.stream_efficiency);
    let t = match device.kind() {
        crate::config::DeviceKind::Gaudi2 => {
            // Graph compiler pipelines MME and TPC through SRAM, but each
            // layer pays the un-captured dispatch path.
            GAUDI_DENSE_DISPATCH_OVERHEAD
                + graph_compiler::pipeline2(&device.spec, g.time, act, act_bytes, true).time
        }
        crate::config::DeviceKind::A100 => g.time + act * 0.25, // fused epilogue
    };
    (device.spec.kernel_launch_overhead + t, g)
}

/// An MLP defined by its layer widths, e.g. bottom MLP `[13, 512, 256, 64]`
/// (input dim first).
pub fn mlp(device: &Device, batch: usize, widths: &[usize], dtype: Dtype) -> DenseResult {
    assert!(widths.len() >= 2, "need at least input and one layer");
    let mut time = 0.0;
    let mut flops = 0.0;
    let mut util = 0.0;
    let mut active = 0.0;
    let mut layers = 0.0;
    for win in widths.windows(2) {
        let (k, n) = (win[0], win[1]);
        let (t, g) = layer_time(device, batch, k, n, dtype);
        time += t;
        flops += 2.0 * batch as f64 * k as f64 * n as f64;
        util += g.utilization;
        active += g.matrix_active_fraction;
        layers += 1.0;
    }
    DenseResult {
        time,
        flops,
        avg_matrix_util: util / layers,
        avg_active_fraction: active / layers,
    }
}

/// DCNv2 low-rank cross interaction: per layer
/// `x_{l+1} = x0 ⊙ (U_l (V_l x_l) + b_l) + x_l` with rank-`r` factors,
/// over a feature vector of `dim` elements.
pub fn dcn_interaction(
    device: &Device,
    batch: usize,
    dim: usize,
    rank: usize,
    layers: usize,
) -> DenseResult {
    let mut time = 0.0;
    let mut flops = 0.0;
    let mut util = 0.0;
    let mut active = 0.0;
    for _ in 0..layers {
        let (t1, g1) = layer_time(device, batch, dim, rank, Fp32);
        let (t2, g2) = layer_time(device, batch, rank, dim, Fp32);
        time += t1 + t2;
        flops += 2.0 * batch as f64 * (dim * rank + rank * dim) as f64;
        util += (g1.utilization + g2.utilization) / 2.0;
        active += (g1.matrix_active_fraction + g2.matrix_active_fraction) / 2.0;
    }
    DenseResult {
        time,
        flops,
        avg_matrix_util: util / layers as f64,
        avg_active_fraction: active / layers as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceKind;

    #[test]
    fn mlp_time_positive_and_grows_with_batch() {
        let d = Device::new(DeviceKind::Gaudi2);
        let small = mlp(&d, 128, &[512, 256, 64], Fp32);
        let big = mlp(&d, 4096, &[512, 256, 64], Fp32);
        assert!(small.time > 0.0);
        assert!(big.time > small.time);
        assert!((big.flops / small.flops - 32.0).abs() < 1e-9);
    }

    #[test]
    fn small_mlps_are_launch_bound_on_gaudi() {
        // At tiny batch, per-layer dispatch dominates; Gaudi's heavier
        // launch makes it slower than A100 despite the stronger MME.
        let g = mlp(&Device::new(DeviceKind::Gaudi2), 64, &[256, 64, 64, 1], Fp32);
        let a = mlp(&Device::new(DeviceKind::A100), 64, &[256, 64, 64, 1], Fp32);
        assert!(g.time > a.time, "gaudi {} a100 {}", g.time, a.time);
    }

    #[test]
    fn large_mlps_favor_gaudi() {
        let g = mlp(&Device::new(DeviceKind::Gaudi2), 8192, &[1024, 1024, 512, 256], Fp32);
        let a = mlp(&Device::new(DeviceKind::A100), 8192, &[1024, 1024, 512, 256], Fp32);
        assert!(g.time < a.time, "gaudi {} a100 {}", g.time, a.time);
    }

    #[test]
    fn dcn_interaction_runs() {
        let d = Device::new(DeviceKind::A100);
        let r = dcn_interaction(&d, 1024, 512, 512, 3);
        assert!(r.time > 0.0);
        assert!(r.avg_matrix_util > 0.0 && r.avg_matrix_util <= 1.0);
        assert!(r.flops > 0.0);
    }

    #[test]
    #[should_panic]
    fn mlp_requires_two_widths() {
        mlp(&Device::new(DeviceKind::A100), 16, &[64], Fp32);
    }
}
