//! Embedding-lookup operators — the §4.1 DLRM case study (Fig 14/15).
//!
//! Four implementations are modeled:
//! * `GaudiSdkSingleTable` — the operator shipped with the Gaudi SDK: one
//!   TPC kernel launch per table, no unrolling, poor TPC work distribution
//!   (the paper measured it at ~37% of FBGEMM/A100).
//! * `GaudiSingleTable` — the paper's custom TPC-C SingleTable: unroll-4
//!   over lookup indices, gathered vectors staged in TPC local memory,
//!   offsets distributed across TPCs (~1.6× the SDK operator).
//! * `GaudiBatchedTable` — the paper's TPC-C port of FBGEMM's BatchedTable:
//!   all tables fused into one kernel with `tableOffsets` indexing, so
//!   chip-wide memory-level parallelism is available even at low batch.
//! * `A100Fbgemm` — FBGEMM's CUDA BatchedTable (TorchRec backend).
//!
//! The performance mechanism: a gather's achievable bandwidth is capped by
//! how many TPCs have work *within one kernel launch* (`min(24, concurrent
//! lookups / unroll)`), by the per-TPC random-access path, and by the
//! chip-level random-access efficiency of `sim::memory`. SingleTable
//! kernels expose only one table's lookups per launch; BatchedTable exposes
//! `tables ×` more.

use crate::config::{DeviceKind, DeviceSpec};
use crate::sim::memory::{fetched_bytes_per_vector, random_stream_efficiency};
use crate::sim::tpc::NUM_TPCS;
use crate::sim::Dtype;

/// Which embedding-lookup operator implementation to model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EmbeddingImpl {
    GaudiSdkSingleTable,
    GaudiSingleTable,
    GaudiBatchedTable,
    A100Fbgemm,
}

impl EmbeddingImpl {
    pub fn name(&self) -> &'static str {
        match self {
            EmbeddingImpl::GaudiSdkSingleTable => "SDK-SingleTable",
            EmbeddingImpl::GaudiSingleTable => "SingleTable",
            EmbeddingImpl::GaudiBatchedTable => "BatchedTable",
            EmbeddingImpl::A100Fbgemm => "FBGEMM(A100)",
        }
    }

    pub fn device(&self) -> DeviceKind {
        match self {
            EmbeddingImpl::A100Fbgemm => DeviceKind::A100,
            _ => DeviceKind::Gaudi2,
        }
    }
}

/// An embedding-layer workload: `tables` tables, `batch` samples, each
/// sample gathering `pooling` vectors of `vec_bytes` from every table.
#[derive(Debug, Clone, Copy)]
pub struct EmbeddingWork {
    pub tables: usize,
    pub batch: usize,
    pub pooling: usize,
    pub vec_bytes: f64,
}

impl EmbeddingWork {
    pub fn lookups_per_table(&self) -> f64 {
        (self.batch * self.pooling) as f64
    }

    pub fn total_lookups(&self) -> f64 {
        self.lookups_per_table() * self.tables as f64
    }

    pub fn useful_bytes(&self) -> f64 {
        self.total_lookups() * self.vec_bytes
    }
}

/// Result of one embedding-lookup execution.
#[derive(Debug, Clone, Copy)]
pub struct EmbeddingResult {
    pub time: f64,
    /// Useful bytes / (peak HBM bandwidth × time) — y-axis of Fig 15.
    pub bandwidth_utilization: f64,
    pub kernel_launches: usize,
}

/// Per-TPC random-gather bandwidth in the BatchedTable kernel, where each
/// TPC interleaves lookups from several tables (more independent streams
/// to hide latency), bytes/s.
const PER_TPC_GATHER_BW_BATCHED: f64 = 110e9;
/// Per-TPC random-gather bandwidth of the custom SingleTable kernel:
/// unroll-4 within one table's index stream.
const PER_TPC_GATHER_BW_SINGLE: f64 = 50e9;
/// Per-TPC random-gather bandwidth of the SDK kernel (no unrolling → one
/// outstanding gather per TPC).
const PER_TPC_GATHER_BW_SDK: f64 = 45e9;
/// SDK kernel uses a static index-space split that leaves TPCs idle.
const SDK_TPC_FRACTION: f64 = 0.65;
/// Unroll factor of the optimized kernels: 4 concurrent vector gathers per
/// TPC per loop iteration.
const UNROLL: usize = 4;

/// Model one embedding lookup execution.
pub fn run(imp: EmbeddingImpl, w: EmbeddingWork, dtype: Dtype) -> EmbeddingResult {
    let spec = imp.device().spec();
    let _ = dtype; // vec_bytes already encodes the element size
    match imp {
        EmbeddingImpl::A100Fbgemm => run_a100(&spec, w),
        EmbeddingImpl::GaudiBatchedTable => run_gaudi(&spec, w, true, false),
        EmbeddingImpl::GaudiSingleTable => run_gaudi(&spec, w, false, false),
        EmbeddingImpl::GaudiSdkSingleTable => run_gaudi(&spec, w, false, true),
    }
}

/// Chip random-gather bandwidth ceiling (useful+waste bytes/s).
fn chip_random_bw(spec: &DeviceSpec) -> f64 {
    spec.hbm_bandwidth * random_stream_efficiency(spec.kind)
}

fn run_gaudi(spec: &DeviceSpec, w: EmbeddingWork, batched: bool, sdk: bool) -> EmbeddingResult {
    let fetched_per_vec = fetched_bytes_per_vector(spec, w.vec_bytes);
    let (per_tpc_bw, tpc_budget) = if sdk {
        (PER_TPC_GATHER_BW_SDK, (NUM_TPCS as f64 * SDK_TPC_FRACTION) as usize)
    } else if batched {
        (PER_TPC_GATHER_BW_BATCHED, NUM_TPCS)
    } else {
        (PER_TPC_GATHER_BW_SINGLE, NUM_TPCS)
    };
    // How many lookups are concurrently visible inside one kernel launch.
    let (launches, lookups_per_launch) = if batched {
        (1, w.total_lookups())
    } else {
        (w.tables, w.lookups_per_table())
    };
    let unroll = if sdk { 1 } else { UNROLL };
    // Index space is split over TPCs in unroll-sized work items.
    let active_tpcs =
        ((lookups_per_launch / unroll as f64).ceil() as usize).clamp(1, tpc_budget);
    let launch_bw = (active_tpcs as f64 * per_tpc_bw).min(chip_random_bw(spec));
    let fetched_per_launch = lookups_per_launch * fetched_per_vec;
    let time =
        launches as f64 * (spec.kernel_launch_overhead + fetched_per_launch / launch_bw);
    EmbeddingResult {
        time,
        bandwidth_utilization: w.useful_bytes() / (spec.hbm_bandwidth * time),
        kernel_launches: launches,
    }
}

fn run_a100(spec: &DeviceSpec, w: EmbeddingWork) -> EmbeddingResult {
    // FBGEMM BatchedTable: one kernel; warp-per-lookup parallelism is
    // effectively unbounded, so only the memory system limits throughput.
    let fetched = w.total_lookups() * fetched_bytes_per_vector(spec, w.vec_bytes);
    // Parallelism limit at very small workloads: up to 4 gathering warps
    // per SM, each sustaining ~4 GB/s of random traffic.
    let warp_bw = 4e9;
    let active_warps = w.total_lookups().min(4.0 * spec.num_vector_cores as f64).max(1.0);
    let bw = (active_warps * warp_bw).min(chip_random_bw(spec));
    let time = spec.kernel_launch_overhead + fetched / bw;
    EmbeddingResult {
        time,
        bandwidth_utilization: w.useful_bytes() / (spec.hbm_bandwidth * time),
        kernel_launches: 1,
    }
}

/// The sweep grid used by Fig 15(b,c,d): batch × vector size (MLPerf
/// DCNv2 inference serves large batches).
pub fn fig15_grid() -> Vec<(usize, f64)> {
    let mut v = Vec::new();
    for &batch in &[256usize, 1024, 4096, 16384] {
        for &vec in &[64.0f64, 128.0, 256.0, 512.0, 1024.0, 2048.0] {
            v.push((batch, vec));
        }
    }
    v
}

/// RM2's embedding configuration (Table 3) at a given batch/vec size;
/// DCNv2 multi-hot averages ~20 lookups per table per sample.
pub fn rm2_work(batch: usize, vec_bytes: f64) -> EmbeddingWork {
    EmbeddingWork { tables: 20, batch, pooling: 1, vec_bytes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::mean;

    fn grid_util(imp: EmbeddingImpl) -> Vec<f64> {
        fig15_grid()
            .into_iter()
            .map(|(b, v)| run(imp, rm2_work(b, v), Dtype::Fp32).bandwidth_utilization)
            .collect()
    }

    #[test]
    fn fig15a_batched_scales_with_tables_single_does_not() {
        // At low batch, SingleTable's utilization is flat in table count
        // while BatchedTable's grows.
        let util = |imp, tables| {
            let w = EmbeddingWork { tables, batch: 64, pooling: 1, vec_bytes: 256.0 };
            run(imp, w, Dtype::Fp32).bandwidth_utilization
        };
        let s1 = util(EmbeddingImpl::GaudiSingleTable, 1);
        let s8 = util(EmbeddingImpl::GaudiSingleTable, 8);
        let b1 = util(EmbeddingImpl::GaudiBatchedTable, 1);
        let b8 = util(EmbeddingImpl::GaudiBatchedTable, 8);
        assert!((s8 - s1).abs() / s1 < 0.05, "single flat: {s1} vs {s8}");
        assert!(b8 > 2.0 * b1, "batched grows: {b1} vs {b8}");
        assert!(b8 > 2.0 * s8, "batched beats single at 8 tables");
    }

    #[test]
    fn fig15_batched_avg_and_peak_utilization() {
        // Paper: BatchedTable averages 34.2% with a peak of 70.5%.
        let u = grid_util(EmbeddingImpl::GaudiBatchedTable);
        let avg = mean(&u);
        let peak = u.iter().cloned().fold(f64::MIN, f64::max);
        assert!((avg - 0.342).abs() < 0.08, "avg {avg}");
        assert!((peak - 0.705).abs() < 0.06, "peak {peak}");
    }

    #[test]
    fn fig15_a100_avg_and_peak_utilization() {
        // Paper: A100 averages 38.7% with a peak of 81.8%.
        let u = grid_util(EmbeddingImpl::A100Fbgemm);
        let avg = mean(&u);
        let peak = u.iter().cloned().fold(f64::MIN, f64::max);
        assert!((avg - 0.387).abs() < 0.09, "avg {avg}");
        assert!((peak - 0.818).abs() < 0.09, "peak {peak}");
    }

    /// Ratios in the bandwidth-bound regime (very large batch), where the
    /// paper's averaged claims are structural rather than launch-overhead
    /// artifacts. The low-batch behaviour is covered by
    /// `fig15a_batched_scales_with_tables_single_does_not`.
    fn bw_bound_ratio(num: EmbeddingImpl, den: EmbeddingImpl) -> f64 {
        let ratios: Vec<f64> = [256.0f64, 512.0, 1024.0, 2048.0]
            .iter()
            .map(|&v| {
                let w = rm2_work(1 << 18, v);
                run(num, w, Dtype::Fp32).time / run(den, w, Dtype::Fp32).time
            })
            .collect();
        mean(&ratios)
    }

    #[test]
    fn batched_1_5x_over_single_table() {
        // Paper: BatchedTable = 1.52x SingleTable.
        let r = bw_bound_ratio(EmbeddingImpl::GaudiSingleTable, EmbeddingImpl::GaudiBatchedTable);
        assert!((r - 1.52).abs() < 0.25, "speedup {r}");
    }

    #[test]
    fn custom_single_1_6x_over_sdk() {
        // Paper footnote 2: custom SingleTable ~1.6x the SDK operator.
        let r =
            bw_bound_ratio(EmbeddingImpl::GaudiSdkSingleTable, EmbeddingImpl::GaudiSingleTable);
        assert!(r > 1.3 && r < 2.0, "speedup {r}");
    }

    #[test]
    fn sdk_is_about_37pct_of_a100() {
        // Paper: the SDK embedding operator reaches ~37% of FBGEMM/A100.
        let r = bw_bound_ratio(EmbeddingImpl::A100Fbgemm, EmbeddingImpl::GaudiSdkSingleTable);
        assert!((r - 0.37).abs() < 0.12, "sdk/a100 {r}");
    }

    #[test]
    fn batched_vs_a100_large_and_small_vectors() {
        // Paper: ~95% of A100 for >=256 B vectors, ~47% for <256 B.
        let ratio_for = |vecs: &[f64]| {
            let r: Vec<f64> = vecs
                .iter()
                .flat_map(|&v| {
                    [256usize, 1024, 4096].iter().map(move |&b| {
                        let w = rm2_work(b, v);
                        run(EmbeddingImpl::A100Fbgemm, w, Dtype::Fp32).time
                            / run(EmbeddingImpl::GaudiBatchedTable, w, Dtype::Fp32).time
                    })
                })
                .collect();
            mean(&r)
        };
        let large = ratio_for(&[256.0, 512.0, 1024.0, 2048.0]);
        let small = ratio_for(&[64.0, 128.0]);
        assert!((large - 0.95).abs() < 0.15, "large-vector ratio {large}");
        assert!((small - 0.47).abs() < 0.15, "small-vector ratio {small}");
    }

    #[test]
    fn single_table_gap_closes_at_large_batch() {
        // Fig 15(b,c): with larger batches SingleTable catches up.
        let gap = |batch| {
            let w = rm2_work(batch, 512.0);
            run(EmbeddingImpl::GaudiSingleTable, w, Dtype::Fp32).time
                / run(EmbeddingImpl::GaudiBatchedTable, w, Dtype::Fp32).time
        };
        // The gap shrinks from launch/parallelism-dominated (several x) to
        // the structural per-kernel bandwidth ratio (~1.5x).
        assert!(gap(256) > gap(32768), "gap should shrink: {} vs {}", gap(256), gap(32768));
        assert!(gap(32768) < 2.0 && gap(32768) > 1.2, "large-batch gap {}", gap(32768));
    }

    #[test]
    fn launches_accounting() {
        let w = rm2_work(256, 256.0);
        assert_eq!(run(EmbeddingImpl::GaudiBatchedTable, w, Dtype::Fp32).kernel_launches, 1);
        assert_eq!(run(EmbeddingImpl::GaudiSingleTable, w, Dtype::Fp32).kernel_launches, 20);
    }
}
