//! Workload generators: fixed-length sweeps (§3.5), a Dynamic-Sonnet-like
//! variable-length trace (Fig 17(d,e)), Poisson arrivals, and Zipf
//! embedding-index streams for the RecSys benchmarks.
//!
//! The serving generators come in two forms sharing one draw sequence:
//! eager `generate()` (a materialized `Vec<Request>`) and the lazy
//! [`ArrivalStream`] iterator, which `ClusterSim::feed` pulls one request
//! at a time so million-request traces run at O(open requests) memory. A
//! `Constant`-rate stream replays the eager generator *exactly* (same RNG
//! draw order per request); the [`RateProcess`] modulators layer diurnal,
//! MMPP, or flash-crowd load shapes on top of the same length mixture.

use crate::serving::qos::ClassId;
use crate::serving::request::Request;
use crate::util::prng::{Rng, Zipf};

/// Fixed input/output length batch, all arriving at t=0 (§3.5 methodology:
/// "a synthetic dataset with an input token length fixed at 100 and output
/// token lengths swept from 25 to 400").
pub fn fixed_batch(n: usize, input_len: usize, output_len: usize) -> Vec<Request> {
    (0..n as u64).map(|i| Request::new(i, input_len, output_len, 0.0)).collect()
}

/// Dynamic-Sonnet-like workload: variable input lengths drawn from a
/// bucketed mixture (512/1K/2K-token prompt buckets, jittered) and
/// variable output lengths (lognormal-ish, capped), reproducing the
/// dataset's dynamism for the Fig 17(d,e) serving experiments.
#[derive(Debug, Clone)]
pub struct DynamicSonnet {
    pub max_input: usize,
    pub max_output: usize,
    /// Number of shared-prefix groups (system prompts / sessions) to tag
    /// requests with, for `RoutePolicy::PrefixAffinity`. 0 (the default)
    /// leaves requests untagged. The tag is derived from the request id,
    /// NOT from the RNG, so enabling prefixes never perturbs the length
    /// or arrival streams of an existing seed.
    pub prefix_groups: usize,
    /// Traffic-class mix as `(class_id, share)` pairs (`serving::qos`):
    /// request ids are mapped deterministically onto classes in share
    /// proportion — id `i` takes the class whose cumulative share bucket
    /// contains `i mod total_shares`. Empty (the default) leaves every
    /// request in class 0. Like prefix tagging, the mapping is id-derived
    /// and RNG-free, so enabling a class mix never perturbs the length or
    /// arrival streams of an existing seed.
    pub class_mix: Vec<(ClassId, usize)>,
}

impl Default for DynamicSonnet {
    fn default() -> Self {
        DynamicSonnet { max_input: 2048, max_output: 512, prefix_groups: 0, class_mix: Vec::new() }
    }
}

impl DynamicSonnet {
    /// Tag generated requests with `groups` shared-prefix groups
    /// (builder-style; 0 disables tagging).
    pub fn with_prefix_groups(mut self, groups: usize) -> Self {
        self.prefix_groups = groups;
        self
    }

    /// Tag generated requests with a deterministic traffic-class mix
    /// (builder-style; see `class_mix`). Shares must be positive.
    pub fn with_class_mix(mut self, mix: Vec<(ClassId, usize)>) -> Self {
        assert!(mix.iter().all(|&(_, share)| share > 0), "class shares must be positive");
        self.class_mix = mix;
        self
    }

    /// Request-id -> class tag (id-derived, RNG-free; see `class_mix`).
    fn class_of(&self, id: u64) -> ClassId {
        if self.class_mix.is_empty() {
            return 0;
        }
        let total: usize = self.class_mix.iter().map(|&(_, s)| s).sum();
        let r = (id % total as u64) as usize;
        let mut acc = 0;
        for &(class, share) in &self.class_mix {
            acc += share;
            if r < acc {
                return class;
            }
        }
        unreachable!("r < total by construction")
    }

    /// Request-id -> prefix-group and class tags (id-derived, RNG-free;
    /// see `prefix_groups` / `class_mix`).
    fn tag(&self, req: Request) -> Request {
        let req = req.with_class(self.class_of(req.id));
        if self.prefix_groups == 0 {
            return req;
        }
        let group = req.id % self.prefix_groups as u64;
        req.with_prefix(group)
    }

    /// Generate `n` requests arriving by a Poisson process of `rate`
    /// requests/sec (rate = infinity ⇒ all at t=0). Eager form of
    /// [`stream`](Self::stream) — identical draws, materialized (the
    /// stream's exact size hint makes `collect` preallocate).
    pub fn generate(&self, n: usize, rate: f64, seed: u64) -> Vec<Request> {
        self.clone().stream(n, rate, seed).collect()
    }

    /// Streaming form of [`generate`](Self::generate): one request at a
    /// time, count-capped at `n`. `w.stream(n, rate, seed).collect()`
    /// equals `w.generate(n, rate, seed)` exactly. Feed it to
    /// `ClusterSim::feed` for O(open requests) memory, or reshape the
    /// load with [`ArrivalStream::with_process`].
    pub fn stream(self, n: usize, rate: f64, seed: u64) -> ArrivalStream {
        ArrivalStream::new(self, rate, seed, Some(n), None)
    }
}

/// How the instantaneous arrival rate evolves along an [`ArrivalStream`].
/// `Constant` replays the eager generators' draw order exactly; the
/// modulated processes trade that replay property for time-varying load.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RateProcess {
    /// Homogeneous Poisson at the stream's base rate.
    Constant,
    /// Diurnal day via Lewis-Shedler thinning:
    /// `rate(t) = base * (1 - depth * cos(2*pi*t / period_s))` — trough
    /// at t = 0 (night), peak at t = period_s/2 (midday). `depth` in
    /// [0, 1).
    Diurnal { period_s: f64, depth: f64 },
    /// Two-state Markov-modulated Poisson process: the rate multiplier
    /// alternates between `calm` and `burst`, with exponential dwell
    /// times of mean `1 / switch_rate` seconds in each state.
    Mmpp { calm: f64, burst: f64, switch_rate: f64 },
    /// Flash crowd via Lewis-Shedler thinning: the rate jumps to
    /// `base * mult` over `[start_s, start_s + duration_s)` and is the
    /// base rate everywhere else — the deterministic overload window
    /// chaos schedules pair with preemption storms. `mult >= 1`.
    FlashCrowd { start_s: f64, duration_s: f64, mult: f64 },
}

/// Lazy request iterator: the Dynamic-Sonnet length mixture under a
/// (possibly modulated) arrival process, drawn one request at a time.
/// Built by [`DynamicSonnet::stream`] (count-capped) or
/// [`OpenLoopTrace::stream`] (time-capped); consumed by `collect` or by
/// `ClusterSim::feed`.
pub struct ArrivalStream {
    workload: DynamicSonnet,
    rng: Rng,
    rate: f64,
    process: RateProcess,
    t: f64,
    id: u64,
    /// Count cap ([`DynamicSonnet::stream`]); `None` = unbounded count.
    remaining: Option<usize>,
    /// Time cap ([`OpenLoopTrace::stream`]); `None` = unbounded time.
    duration: Option<f64>,
    /// MMPP state: currently in the `burst` multiplier?
    bursting: bool,
    /// MMPP next state-switch time.
    next_switch: f64,
    done: bool,
}

impl ArrivalStream {
    fn new(
        workload: DynamicSonnet,
        rate: f64,
        seed: u64,
        remaining: Option<usize>,
        duration: Option<f64>,
    ) -> ArrivalStream {
        ArrivalStream {
            workload,
            rng: Rng::new(seed),
            rate,
            process: RateProcess::Constant,
            t: 0.0,
            id: 0,
            remaining,
            duration,
            bursting: false,
            next_switch: 0.0,
            done: false,
        }
    }

    /// Swap the arrival process (builder-style). Modulated processes need
    /// a finite positive base rate.
    pub fn with_process(mut self, process: RateProcess) -> ArrivalStream {
        match process {
            RateProcess::Constant => {}
            RateProcess::Diurnal { period_s, depth } => {
                assert!(self.rate.is_finite() && self.rate > 0.0, "modulation needs a finite rate");
                assert!(period_s > 0.0 && (0.0..1.0).contains(&depth));
            }
            RateProcess::Mmpp { calm, burst, switch_rate } => {
                assert!(self.rate.is_finite() && self.rate > 0.0, "modulation needs a finite rate");
                assert!(calm > 0.0 && burst > 0.0 && switch_rate > 0.0);
                self.next_switch = self.rng.exp(switch_rate);
            }
            RateProcess::FlashCrowd { start_s, duration_s, mult } => {
                assert!(self.rate.is_finite() && self.rate > 0.0, "modulation needs a finite rate");
                assert!(start_s.is_finite() && start_s >= 0.0);
                assert!(duration_s.is_finite() && duration_s > 0.0);
                assert!(mult.is_finite() && mult >= 1.0);
            }
        }
        self.process = process;
        self
    }

    /// Advance `self.t` to the next arrival under the active process.
    fn advance_arrival(&mut self) {
        match self.process {
            RateProcess::Constant => {
                if self.rate.is_finite() {
                    self.t += self.rng.exp(self.rate);
                }
            }
            RateProcess::Diurnal { period_s, depth } => {
                // Lewis-Shedler thinning against the envelope rate
                // base * (1 + depth): candidates at the envelope rate are
                // accepted with probability rate(t) / envelope.
                let envelope = self.rate * (1.0 + depth);
                loop {
                    self.t += self.rng.exp(envelope);
                    let rate_t = self.rate
                        * (1.0 - depth * (2.0 * std::f64::consts::PI * self.t / period_s).cos());
                    if self.rng.f64() < rate_t / envelope {
                        break;
                    }
                    // Past the time cap no acceptance is needed: the
                    // caller rejects this timestamp anyway.
                    if self.duration.is_some_and(|d| self.t > d) {
                        break;
                    }
                }
            }
            RateProcess::Mmpp { calm, burst, switch_rate } => {
                // Exact piecewise-exponential sampling: draw within the
                // current state's dwell; on crossing the switch point,
                // flip state and redraw (memorylessness makes the
                // restart exact).
                loop {
                    let mult = if self.bursting { burst } else { calm };
                    let step = self.rng.exp(self.rate * mult);
                    if self.t + step <= self.next_switch {
                        self.t += step;
                        break;
                    }
                    self.t = self.next_switch;
                    self.bursting = !self.bursting;
                    self.next_switch = self.t + self.rng.exp(switch_rate);
                    if self.duration.is_some_and(|d| self.t > d) {
                        break;
                    }
                }
            }
            RateProcess::FlashCrowd { start_s, duration_s, mult } => {
                // Lewis-Shedler thinning against the crowd-peak envelope
                // base * mult: candidates outside the crowd window are
                // accepted with probability 1 / mult.
                let envelope = self.rate * mult;
                loop {
                    self.t += self.rng.exp(envelope);
                    let in_crowd = self.t >= start_s && self.t < start_s + duration_s;
                    let rate_t = if in_crowd { envelope } else { self.rate };
                    if self.rng.f64() < rate_t / envelope {
                        break;
                    }
                    // Past the time cap no acceptance is needed: the
                    // caller rejects this timestamp anyway.
                    if self.duration.is_some_and(|d| self.t > d) {
                        break;
                    }
                }
            }
        }
    }
}

impl Iterator for ArrivalStream {
    type Item = Request;

    fn next(&mut self) -> Option<Request> {
        if self.done || self.remaining == Some(0) {
            return None;
        }
        self.advance_arrival();
        if self.duration.is_some_and(|d| self.t > d) {
            self.done = true;
            return None;
        }
        // The per-request draw order below matches the eager generators
        // exactly: bucket, jitter, output (see `DynamicSonnet::generate`).
        let buckets = [512usize, 1024, 2048];
        let bucket = *self.rng.choose(&buckets);
        // Jitter within (50%, 100%] of the bucket.
        let input = (((bucket as f64) * (0.5 + 0.5 * self.rng.f64())).round() as usize)
            .clamp(16, self.workload.max_input);
        // Output: lognormal-ish around 128 tokens.
        let output = ((self.rng.normal(4.8, 0.6).exp()).round() as usize)
            .clamp(8, self.workload.max_output);
        let req = self.workload.tag(Request::new(self.id, input, output, self.t));
        self.id += 1;
        if let Some(r) = &mut self.remaining {
            *r -= 1;
        }
        Some(req)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        match (self.done, self.remaining) {
            (true, _) => (0, Some(0)),
            // Count-capped streams know their length exactly (unless a
            // time cap can cut them short).
            (false, Some(n)) => (if self.duration.is_none() { n } else { 0 }, Some(n)),
            (false, None) => (0, None),
        }
    }
}

/// Open-loop arrival trace targeted at the cluster simulator: the
/// Dynamic-Sonnet length mixture sustained at a Poisson `rate` for
/// `duration` seconds. Unlike `DynamicSonnet::generate` (a fixed request
/// *count*), an open-loop trace fixes the *offered load*, which is what
/// deployment sizing sweeps over — the fleet either keeps up or queueing
/// delay (and router backpressure) grows without bound.
#[derive(Debug, Clone)]
pub struct OpenLoopTrace {
    pub workload: DynamicSonnet,
    /// Offered load in requests/second.
    pub rate: f64,
    /// Trace length in seconds.
    pub duration: f64,
}

impl OpenLoopTrace {
    pub fn new(rate: f64, duration: f64) -> OpenLoopTrace {
        assert!(rate.is_finite() && rate > 0.0 && duration > 0.0);
        OpenLoopTrace { workload: DynamicSonnet::default(), rate, duration }
    }

    /// Tag generated requests with `groups` shared-prefix groups
    /// (builder-style; RNG-free, see `DynamicSonnet::prefix_groups`).
    pub fn with_prefix_groups(mut self, groups: usize) -> Self {
        self.workload.prefix_groups = groups;
        self
    }

    /// Tag generated requests with a deterministic traffic-class mix
    /// (builder-style; RNG-free, see `DynamicSonnet::class_mix`).
    pub fn with_class_mix(mut self, mix: Vec<(ClassId, usize)>) -> Self {
        self.workload = self.workload.with_class_mix(mix);
        self
    }

    /// Generate the trace (request count is Poisson-distributed around
    /// `rate * duration`; ids are sequential from 0). Eager form of
    /// [`stream`](Self::stream) — identical draws, materialized with the
    /// expected-count preallocation.
    pub fn generate(&self, seed: u64) -> Vec<Request> {
        let mut out = Vec::with_capacity((self.rate * self.duration) as usize + 1);
        out.extend(self.stream(seed));
        out
    }

    /// Streaming form of [`generate`](Self::generate): one request at a
    /// time until `duration` elapses. `tr.stream(seed).collect()` equals
    /// `tr.generate(seed)` exactly.
    pub fn stream(&self, seed: u64) -> ArrivalStream {
        ArrivalStream::new(self.workload.clone(), self.rate, seed, None, Some(self.duration))
    }

    /// Streaming diurnal day: the same length mixture under a cosine-
    /// modulated rate whose period is the trace duration — trough at the
    /// start and end, peak mid-trace (see [`RateProcess::Diurnal`]).
    pub fn diurnal_stream(&self, depth: f64, seed: u64) -> ArrivalStream {
        self.stream(seed)
            .with_process(RateProcess::Diurnal { period_s: self.duration, depth })
    }
}

/// Deterministic synthetic token prompts for engines that consume real
/// token ids (`repro real-serve` / `PjrtLlmEngine`): requests plus their
/// prompt tokens from one seeded generator, so the real-numerics path
/// shares workload code with the simulated-serving generators above
/// instead of hand-rolling prompt loops inline.
#[derive(Debug, Clone)]
pub struct TokenPrompts {
    /// Token ids are drawn uniformly below this bound.
    pub vocab: usize,
    /// Longest prompt to emit (the engine's `prompt_pad`).
    pub max_prompt: usize,
    /// Cap on prompt + generated tokens (the engine's `max_seq`).
    pub max_total: usize,
}

impl TokenPrompts {
    pub fn new(vocab: usize, max_prompt: usize, max_total: usize) -> TokenPrompts {
        assert!(vocab > 0 && max_prompt > 0 && max_total > max_prompt);
        TokenPrompts { vocab, max_prompt, max_total }
    }

    /// Generate `n` requests arriving at t=0 with short varied prompts
    /// (4-8 tokens) and output budgets (8-15 tokens), clamped to the
    /// engine's shape limits.
    pub fn generate(&self, n: usize, seed: u64) -> Vec<(Request, Vec<i32>)> {
        let mut rng = Rng::new(seed);
        (0..n as u64)
            .map(|i| {
                let plen = (4 + rng.below(5) as usize).min(self.max_prompt);
                let out = (8 + rng.below(8) as usize).min(self.max_total - plen).max(1);
                let prompt: Vec<i32> =
                    (0..plen).map(|_| rng.below(self.vocab as u64) as i32).collect();
                (Request::new(i, plen, out, 0.0), prompt)
            })
            .collect()
    }
}

/// Zipf-distributed embedding index stream for `tables` tables of
/// `rows` rows: RecSys lookups are power-law distributed over hot items.
pub struct EmbeddingTrace {
    zipf: Zipf,
    rng: Rng,
    pub tables: usize,
    pub rows: usize,
}

impl EmbeddingTrace {
    pub fn new(tables: usize, rows: usize, skew: f64, seed: u64) -> EmbeddingTrace {
        EmbeddingTrace { zipf: Zipf::new(rows as u64, skew), rng: Rng::new(seed), tables, rows }
    }

    /// Draw a batch of lookup indices: `batch × tables × pooling`.
    pub fn batch(&mut self, batch: usize, pooling: usize) -> Vec<u32> {
        let n = batch * self.tables * pooling;
        (0..n).map(|_| self.zipf.sample(&mut self.rng) as u32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_batch_shape() {
        let reqs = fixed_batch(8, 100, 25);
        assert_eq!(reqs.len(), 8);
        assert!(reqs.iter().all(|r| r.prompt_len == 100 && r.max_new_tokens == 25));
        assert!(reqs.iter().all(|r| r.arrival == 0.0));
    }

    #[test]
    fn dynamic_sonnet_variability() {
        let w = DynamicSonnet::default();
        let reqs = w.generate(200, f64::INFINITY, 7);
        let inputs: Vec<usize> = reqs.iter().map(|r| r.prompt_len).collect();
        let min = *inputs.iter().min().unwrap();
        let max = *inputs.iter().max().unwrap();
        assert!(max > 2 * min, "inputs should vary: {min}..{max}");
        assert!(max <= 2048);
        let outputs: Vec<usize> = reqs.iter().map(|r| r.max_new_tokens).collect();
        assert!(outputs.iter().any(|&o| o > 150) && outputs.iter().any(|&o| o < 100));
    }

    #[test]
    fn poisson_arrivals_increase() {
        let w = DynamicSonnet::default();
        let reqs = w.generate(50, 10.0, 3);
        for pair in reqs.windows(2) {
            assert!(pair[1].arrival >= pair[0].arrival);
        }
        let span = reqs.last().unwrap().arrival;
        // ~50 requests at 10/sec -> about 5 seconds.
        assert!(span > 2.0 && span < 12.0, "span {span}");
    }

    #[test]
    fn deterministic_given_seed() {
        let w = DynamicSonnet::default();
        let a = w.generate(20, 5.0, 42);
        let b = w.generate(20, 5.0, 42);
        assert_eq!(
            a.iter().map(|r| r.prompt_len).collect::<Vec<_>>(),
            b.iter().map(|r| r.prompt_len).collect::<Vec<_>>()
        );
    }

    #[test]
    fn open_loop_trace_tracks_offered_load() {
        let tr = OpenLoopTrace::new(20.0, 10.0);
        let reqs = tr.generate(11);
        // ~200 expected; allow generous Poisson slack.
        assert!(reqs.len() > 120 && reqs.len() < 300, "n = {}", reqs.len());
        assert!(reqs.iter().all(|r| r.arrival > 0.0 && r.arrival <= 10.0));
        for pair in reqs.windows(2) {
            assert!(pair[1].arrival >= pair[0].arrival);
            assert_eq!(pair[1].id, pair[0].id + 1);
        }
        // Deterministic given the seed.
        let again = tr.generate(11);
        assert_eq!(reqs.len(), again.len());
        assert!(reqs.iter().zip(&again).all(|(a, b)| a.prompt_len == b.prompt_len));
    }

    #[test]
    fn prefix_tagging_is_rng_free() {
        let plain = DynamicSonnet::default().generate(30, 12.0, 5);
        let tagged = DynamicSonnet::default().with_prefix_groups(4).generate(30, 12.0, 5);
        // Same lengths and arrivals — the tag never consumes RNG draws.
        for (a, b) in plain.iter().zip(&tagged) {
            assert_eq!(a.prompt_len, b.prompt_len);
            assert_eq!(a.max_new_tokens, b.max_new_tokens);
            assert_eq!(a.arrival, b.arrival);
            assert_eq!(a.prefix_id, None);
            assert_eq!(b.prefix_id, Some(b.id % 4));
        }
        let open = OpenLoopTrace::new(20.0, 3.0).with_prefix_groups(3).generate(11);
        let open_plain = OpenLoopTrace::new(20.0, 3.0).generate(11);
        assert_eq!(open.len(), open_plain.len());
        assert!(open.iter().all(|r| r.prefix_id == Some(r.id % 3)));
        assert!(open.iter().zip(&open_plain).all(|(a, b)| a.arrival == b.arrival));
    }

    #[test]
    fn class_tagging_is_rng_free_and_share_proportional() {
        let plain = DynamicSonnet::default().generate(40, 12.0, 5);
        let mix = vec![(0usize, 2usize), (1, 1), (2, 1)];
        let tagged = DynamicSonnet::default().with_class_mix(mix.clone()).generate(40, 12.0, 5);
        // Same lengths and arrivals — the tag never consumes RNG draws.
        for (a, b) in plain.iter().zip(&tagged) {
            assert_eq!(a.prompt_len, b.prompt_len);
            assert_eq!(a.max_new_tokens, b.max_new_tokens);
            assert_eq!(a.arrival, b.arrival);
            assert_eq!(a.class_id, 0);
        }
        // Shares land exactly: ids cycle 0,0,1,2 over total share 4.
        let count = |c: usize| tagged.iter().filter(|r| r.class_id == c).count();
        assert_eq!((count(0), count(1), count(2)), (20, 10, 10));
        assert_eq!(tagged[0].class_id, 0);
        assert_eq!(tagged[2].class_id, 1);
        assert_eq!(tagged[3].class_id, 2);
        // Class and prefix tagging compose.
        let both = DynamicSonnet::default()
            .with_class_mix(mix)
            .with_prefix_groups(4)
            .generate(12, 12.0, 5);
        assert!(both.iter().all(|r| r.prefix_id == Some(r.id % 4)));
        assert!(both.iter().any(|r| r.class_id > 0));
        // Open-loop traces tag identically.
        let open = OpenLoopTrace::new(20.0, 3.0).with_class_mix(vec![(1, 1)]).generate(11);
        let open_plain = OpenLoopTrace::new(20.0, 3.0).generate(11);
        assert_eq!(open.len(), open_plain.len());
        assert!(open.iter().all(|r| r.class_id == 1));
        assert!(open.iter().zip(&open_plain).all(|(a, b)| a.arrival == b.arrival));
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_class_share_rejected() {
        let _ = DynamicSonnet::default().with_class_mix(vec![(0, 0)]);
    }

    #[test]
    fn stream_replays_generate_exactly() {
        // Poisson-arrival, prefix- and class-tagged: every field matches.
        let w = DynamicSonnet::default().with_prefix_groups(3).with_class_mix(vec![(0, 1), (2, 1)]);
        let eager = w.generate(25, 12.0, 9);
        let lazy: Vec<Request> = w.clone().stream(25, 12.0, 9).collect();
        assert_eq!(eager.len(), lazy.len());
        for (a, b) in eager.iter().zip(&lazy) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.prompt_len, b.prompt_len);
            assert_eq!(a.max_new_tokens, b.max_new_tokens);
            assert_eq!(a.arrival, b.arrival);
            assert_eq!(a.prefix_id, b.prefix_id);
            assert_eq!(a.class_id, b.class_id);
        }
        // Batch form (rate = infinity, all at t = 0) replays too.
        let eb = DynamicSonnet::default().generate(10, f64::INFINITY, 4);
        let lb: Vec<Request> = DynamicSonnet::default().stream(10, f64::INFINITY, 4).collect();
        assert_eq!(eb.len(), lb.len());
        assert!(eb
            .iter()
            .zip(&lb)
            .all(|(a, b)| a.arrival == b.arrival && a.prompt_len == b.prompt_len));
        // Duration-capped open-loop trace replays as well.
        let tr = OpenLoopTrace::new(20.0, 5.0).with_prefix_groups(2);
        let eager = tr.generate(11);
        let lazy: Vec<Request> = tr.stream(11).collect();
        assert_eq!(eager.len(), lazy.len());
        assert!(eager.iter().zip(&lazy).all(|(a, b)| a.arrival == b.arrival
            && a.prompt_len == b.prompt_len
            && a.max_new_tokens == b.max_new_tokens
            && a.prefix_id == b.prefix_id));
    }

    #[test]
    fn stream_size_hint_enables_preallocation() {
        // Count-capped: exact (this is what lets `generate`'s collect
        // preallocate); time-capped: unknown length.
        let s = DynamicSonnet::default().stream(100, 10.0, 1);
        assert_eq!(s.size_hint(), (100, Some(100)));
        let s = OpenLoopTrace::new(10.0, 2.0).stream(1);
        assert_eq!(s.size_hint(), (0, None));
    }

    #[test]
    fn diurnal_stream_concentrates_load_at_midday() {
        let day = 1000.0;
        let tr = OpenLoopTrace::new(5.0, day);
        let reqs: Vec<Request> = tr.diurnal_stream(0.8, 7).collect();
        assert!(reqs.len() > 2_000, "n = {}", reqs.len());
        assert!(reqs.iter().all(|r| r.arrival > 0.0 && r.arrival <= day));
        for pair in reqs.windows(2) {
            assert!(pair[1].arrival >= pair[0].arrival);
            assert_eq!(pair[1].id, pair[0].id + 1);
        }
        // The midday half [day/4, 3*day/4] (where cos < 0) must carry
        // well over half the arrivals at depth 0.8 (expected share 75%).
        let mid = reqs
            .iter()
            .filter(|r| r.arrival > day / 4.0 && r.arrival < 3.0 * day / 4.0)
            .count();
        assert!(3 * mid > 2 * reqs.len(), "midday {mid} of {}", reqs.len());
        // Deterministic given the seed.
        let again: Vec<Request> = tr.diurnal_stream(0.8, 7).collect();
        assert_eq!(reqs.len(), again.len());
        assert!(reqs.iter().zip(&again).all(|(a, b)| a.arrival == b.arrival));
    }

    #[test]
    fn mmpp_stream_is_bursty_and_deterministic() {
        let mmpp = RateProcess::Mmpp { calm: 0.2, burst: 5.0, switch_rate: 0.5 };
        let reqs: Vec<Request> =
            DynamicSonnet::default().stream(400, 10.0, 5).with_process(mmpp).collect();
        assert_eq!(reqs.len(), 400);
        for pair in reqs.windows(2) {
            assert!(pair[1].arrival >= pair[0].arrival);
        }
        // Burstiness: squared coefficient of variation of inter-arrival
        // gaps well above the Poisson value of 1.
        let gaps: Vec<f64> = reqs.windows(2).map(|p| p[1].arrival - p[0].arrival).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var = gaps.iter().map(|g| (g - mean).powi(2)).sum::<f64>() / gaps.len() as f64;
        assert!(var / (mean * mean) > 1.5, "cv^2 = {}", var / (mean * mean));
        let again: Vec<Request> =
            DynamicSonnet::default().stream(400, 10.0, 5).with_process(mmpp).collect();
        assert!(reqs.iter().zip(&again).all(|(a, b)| a.arrival == b.arrival));
    }

    #[test]
    fn flash_crowd_densifies_the_window_and_is_deterministic() {
        let crowd = RateProcess::FlashCrowd { start_s: 20.0, duration_s: 10.0, mult: 6.0 };
        let tr = OpenLoopTrace::new(4.0, 60.0);
        let reqs: Vec<Request> = tr.stream(13).with_process(crowd).collect();
        assert!(reqs.iter().all(|r| r.arrival > 0.0 && r.arrival <= 60.0));
        for pair in reqs.windows(2) {
            assert!(pair[1].arrival >= pair[0].arrival);
            assert_eq!(pair[1].id, pair[0].id + 1);
        }
        // The 10 s crowd window at mult 6 carries ~240 expected arrivals
        // vs ~200 over the remaining 50 s: per-second density inside the
        // window must be several times the outside density.
        let inside =
            reqs.iter().filter(|r| r.arrival >= 20.0 && r.arrival < 30.0).count() as f64 / 10.0;
        let outside =
            reqs.iter().filter(|r| r.arrival < 20.0 || r.arrival >= 30.0).count() as f64 / 50.0;
        assert!(inside > 3.0 * outside, "inside {inside}/s vs outside {outside}/s");
        // Deterministic given the seed.
        let again: Vec<Request> = tr.stream(13).with_process(crowd).collect();
        assert_eq!(reqs.len(), again.len());
        assert!(reqs.iter().zip(&again).all(|(a, b)| a.arrival == b.arrival));
        // mult = 1 degenerates to a (thinned) homogeneous process whose
        // count tracks the same offered load.
        let flat: Vec<Request> = tr
            .stream(13)
            .with_process(RateProcess::FlashCrowd { start_s: 20.0, duration_s: 10.0, mult: 1.0 })
            .collect();
        let plain: Vec<Request> = tr.stream(13).collect();
        let (lo, hi) = (plain.len() / 2, plain.len() * 2);
        assert!((lo..hi).contains(&flat.len()), "flat {} vs plain {}", flat.len(), plain.len());
    }

    #[test]
    #[should_panic(expected = "mult")]
    fn flash_crowd_rejects_damping_multiplier() {
        let _ = OpenLoopTrace::new(4.0, 60.0)
            .stream(1)
            .with_process(RateProcess::FlashCrowd { start_s: 0.0, duration_s: 5.0, mult: 0.5 });
    }

    #[test]
    #[should_panic(expected = "finite rate")]
    fn modulated_stream_rejects_infinite_rate() {
        let _ = DynamicSonnet::default()
            .stream(10, f64::INFINITY, 1)
            .with_process(RateProcess::Diurnal { period_s: 10.0, depth: 0.5 });
    }

    #[test]
    fn token_prompts_respect_engine_limits() {
        let gen = TokenPrompts::new(100, 8, 20);
        let batch = gen.generate(32, 11);
        assert_eq!(batch.len(), 32);
        for (req, prompt) in &batch {
            assert_eq!(prompt.len(), req.prompt_len);
            assert!(req.prompt_len >= 4 && req.prompt_len <= 8);
            assert!(req.prompt_len + req.max_new_tokens <= 20);
            assert!(req.max_new_tokens >= 1);
            assert!(prompt.iter().all(|&t| (0..100).contains(&t)));
            assert_eq!(req.arrival, 0.0);
        }
        // Deterministic given the seed; ids sequential.
        let again = gen.generate(32, 11);
        assert!(batch.iter().zip(&again).all(|(a, b)| a.1 == b.1 && a.0.id == b.0.id));
        assert_eq!(batch[31].0.id, 31);
    }

    #[test]
    fn embedding_trace_is_skewed() {
        let mut t = EmbeddingTrace::new(4, 10_000, 1.1, 9);
        let batch = t.batch(64, 2);
        assert_eq!(batch.len(), 64 * 4 * 2);
        let hot = batch.iter().filter(|&&i| i < 100).count();
        assert!(hot as f64 / batch.len() as f64 > 0.2, "hot share {hot}");
        assert!(batch.iter().all(|&i| (i as usize) < 10_000));
    }
}
