//! Fig 9: memory-bandwidth utilization of random vector gather/scatter,
//! 4M-vector working set, vector sizes 16 B – 2048 B, sweeping the
//! fraction of vectors accessed — plus a typed summary of the paper's
//! granularity-band averages.

use crate::config::DeviceKind;
use crate::harness::{Experiment, Params};
use crate::report::{Cell, Check, Expectation, Report, Selector, Unit};
use crate::sim::memory::{self, AccessDir};
use crate::util::stats::mean;

const VEC_SIZES: [f64; 8] = [16.0, 32.0, 64.0, 128.0, 256.0, 512.0, 1024.0, 2048.0];
const TOTAL_VECTORS: f64 = 4e6;

fn panel(dir: AccessDir, title: &str) -> Report {
    let mut r = Report::new(title);
    r.header(&["vec size (B)", "fraction", "Gaudi-2", "A100"]);
    for &v in &VEC_SIZES {
        for frac in [0.01f64, 0.1, 0.5, 1.0] {
            let n = TOTAL_VECTORS * frac;
            let g = memory::random_access(&DeviceKind::Gaudi2.spec(), dir, n, v);
            let a = memory::random_access(&DeviceKind::A100.spec(), dir, n, v);
            r.row(vec![
                Cell::val(v, Unit::Count),
                Cell::val(frac, Unit::Percent),
                Cell::val(g.utilization, Unit::Percent),
                Cell::val(a.utilization, Unit::Percent),
            ]);
        }
    }
    r
}

/// Mean full-working-set gather utilization over a band of vector sizes.
fn band_avg(kind: DeviceKind, sizes: &[f64]) -> f64 {
    mean(
        &sizes
            .iter()
            .map(|&v| {
                memory::random_access(&kind.spec(), AccessDir::Gather, TOTAL_VECTORS, v).utilization
            })
            .collect::<Vec<_>>(),
    )
}

pub struct Fig9;

impl Experiment for Fig9 {
    fn id(&self) -> &'static str {
        "fig9"
    }

    fn title(&self) -> &'static str {
        "Fig 9: vector gather/scatter bandwidth utilization"
    }

    fn run(&self, _params: &Params) -> Vec<Report> {
        let mut gather = panel(AccessDir::Gather, "Fig 9(a): vector gather bandwidth utilization");
        gather.note("paper: Gaudi-2 64% avg >=256 B vs A100 72%; <=128 B: 15% vs 36% (2.4x)");
        let scatter = panel(AccessDir::Scatter, "Fig 9(b): vector scatter bandwidth utilization");

        let coarse = [256.0, 512.0, 1024.0, 2048.0];
        let fine = [16.0, 32.0, 64.0, 128.0];
        let mut summary = Report::new("Fig 9 summary: gather utilization by granularity band");
        summary.header(&["band", "Gaudi-2", "A100"]);
        summary.row(vec![
            Cell::text(">=256B"),
            Cell::val(band_avg(DeviceKind::Gaudi2, &coarse), Unit::Percent),
            Cell::val(band_avg(DeviceKind::A100, &coarse), Unit::Percent),
        ]);
        summary.row(vec![
            Cell::text("<=128B"),
            Cell::val(band_avg(DeviceKind::Gaudi2, &fine), Unit::Percent),
            Cell::val(band_avg(DeviceKind::A100, &fine), Unit::Percent),
        ]);
        summary.note("full 4M-vector working set; the 256 B access-granularity cliff");
        vec![gather, scatter, summary]
    }

    fn expectations(&self, _params: &Params) -> Vec<Expectation> {
        vec![
            Expectation::new(
                "fig9.gaudi_coarse",
                "Gaudi-2 averages ~64% bandwidth utilization for >=256 B gathers",
                Selector::cell("Fig 9 summary", ">=256B", "Gaudi-2"),
                Check::Within { target: 0.64, tol: 0.05 },
            ),
            Expectation::new(
                "fig9.a100_coarse",
                "A100 averages ~72% for >=256 B gathers",
                Selector::cell("Fig 9 summary", ">=256B", "A100"),
                Check::Within { target: 0.72, tol: 0.05 },
            ),
            Expectation::new(
                "fig9.gaudi_fine",
                "Gaudi-2 collapses to ~15% below the 256 B granularity",
                Selector::cell("Fig 9 summary", "<=128B", "Gaudi-2"),
                Check::Within { target: 0.15, tol: 0.04 },
            ),
            Expectation::new(
                "fig9.a100_fine",
                "A100's 32 B sectors hold ~36% on small vectors (2.4x Gaudi-2)",
                Selector::cell("Fig 9 summary", "<=128B", "A100"),
                Check::Within { target: 0.36, tol: 0.06 },
            ),
        ]
    }
}

/// Run with default params (convenience for tests and library callers).
pub fn run() -> Vec<Report> {
    Fig9.run(&Fig9.params())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gather_scatter_panels_and_summary() {
        let reports = run();
        assert_eq!(reports.len(), 3);
        assert_eq!(reports[0].num_rows(), 32);
        assert_eq!(reports[2].num_rows(), 2);
    }

    #[test]
    fn expectations_pass() {
        let reports = run();
        for e in Fig9.expectations(&Fig9.params()) {
            let res = e.evaluate(&reports);
            assert!(res.pass, "{}: {}", res.id, res.detail);
        }
    }
}
