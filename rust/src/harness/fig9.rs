//! Fig 9: memory-bandwidth utilization of random vector gather/scatter,
//! 4M-vector working set, vector sizes 16 B – 2048 B, sweeping the
//! fraction of vectors accessed.

use crate::config::DeviceKind;
use crate::sim::memory::{self, AccessDir};
use crate::util::table::{fmt_pct, Report};

const VEC_SIZES: [f64; 8] = [16.0, 32.0, 64.0, 128.0, 256.0, 512.0, 1024.0, 2048.0];
const TOTAL_VECTORS: f64 = 4e6;

fn panel(dir: AccessDir, title: &str) -> Report {
    let mut r = Report::new(title);
    r.header(&["vec size (B)", "fraction", "Gaudi-2", "A100"]);
    for &v in &VEC_SIZES {
        for frac in [0.01f64, 0.1, 0.5, 1.0] {
            let n = TOTAL_VECTORS * frac;
            let g = memory::random_access(&DeviceKind::Gaudi2.spec(), dir, n, v);
            let a = memory::random_access(&DeviceKind::A100.spec(), dir, n, v);
            r.row(vec![
                format!("{v}"),
                format!("{:.0}%", frac * 100.0),
                fmt_pct(g.utilization),
                fmt_pct(a.utilization),
            ]);
        }
    }
    r
}

pub fn run() -> Vec<Report> {
    let mut gather = panel(AccessDir::Gather, "Fig 9(a): vector gather bandwidth utilization");
    gather.note("paper: Gaudi-2 64% avg >=256 B vs A100 72%; <=128 B: 15% vs 36% (2.4x)");
    let scatter = panel(AccessDir::Scatter, "Fig 9(b): vector scatter bandwidth utilization");
    vec![gather, scatter]
}

#[cfg(test)]
mod tests {
    #[test]
    fn gather_and_scatter_panels() {
        let reports = super::run();
        assert_eq!(reports.len(), 2);
        assert_eq!(reports[0].num_rows(), 32);
    }
}
