//! Fig 4: roofline of achieved BF16 TFLOPS for square and irregular
//! (N=16) GEMM shapes on Gaudi-2 and A100.

use crate::config::DeviceKind;
use crate::harness::{Experiment, Params};
use crate::ops::gemm;
use crate::report::{Agg, Cell, Check, Expectation, Report, Selector, Unit};
use crate::sim::Dtype;

pub struct Fig4;

impl Experiment for Fig4 {
    fn id(&self) -> &'static str {
        "fig4"
    }

    fn title(&self) -> &'static str {
        "Fig 4: GEMM roofline (achieved TFLOPS, BF16)"
    }

    fn run(&self, _params: &Params) -> Vec<Report> {
        let mut r = Report::new("Fig 4: GEMM roofline (BF16)");
        r.header(&[
            "shape (M,K,N)",
            "AI (FLOP/B)",
            "Gaudi-2 TF",
            "A100 TF",
            "G/A",
            "util(G)",
            "bound(G)",
            "bound(A)",
        ]);
        for (m, k, n) in gemm::fig4_shapes() {
            let g = gemm::run(DeviceKind::Gaudi2, m, k, n, Dtype::Bf16);
            let a = gemm::run(DeviceKind::A100, m, k, n, Dtype::Bf16);
            r.row(vec![
                Cell::text(format!("{m}x{k}x{n}")),
                Cell::val(g.intensity, Unit::FlopPerByte),
                Cell::val(g.exec.achieved_flops / 1e12, Unit::Tflops),
                Cell::val(a.exec.achieved_flops / 1e12, Unit::Tflops),
                Cell::val(g.exec.achieved_flops / a.exec.achieved_flops, Unit::Ratio),
                Cell::val(g.exec.utilization, Unit::Percent),
                Cell::text(if g.exec.memory_bound { "mem" } else { "mme" }),
                Cell::text(if a.exec.memory_bound { "mem" } else { "tc" }),
            ]);
        }
        r.note("paper: Gaudi-2 reaches 429 TF at 8192^3 (99.3% of 432 peak) and wins every shape");
        vec![r]
    }

    fn expectations(&self, _params: &Params) -> Vec<Expectation> {
        vec![
            Expectation::new(
                "fig4.peak_tflops",
                "Gaudi-2 reaches >= 425 achieved TFLOPS at the 8192^3 GEMM",
                Selector::cell("Fig 4", "8192x8192x8192", "Gaudi-2 TF"),
                Check::Ge(425.0),
            ),
            Expectation::new(
                "fig4.peak_util",
                "the 8192^3 point runs at 99.3% of the 432 TF peak",
                Selector::cell("Fig 4", "8192x8192x8192", "util(G)"),
                Check::Within { target: 0.993, tol: 0.01 },
            ),
            Expectation::new(
                "fig4.gaudi_wins_every_shape",
                "Gaudi-2 beats the A100 on every Fig 4 shape",
                Selector::column("Fig 4", "G/A", Agg::Min),
                Check::Ge(1.0),
            ),
        ]
    }
}

/// Run with default params (convenience for tests and library callers).
pub fn run() -> Vec<Report> {
    Fig4.run(&Fig4.params())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headline_point_present() {
        let reports = run();
        let peak = reports[0].value_at("8192x8192x8192", "Gaudi-2 TF").unwrap();
        assert!((peak.x - 429.0).abs() < 4.0, "peak {}", peak.x);
        assert_eq!(peak.unit, Unit::Tflops);
    }

    #[test]
    fn expectations_pass() {
        let reports = run();
        for e in Fig4.expectations(&Fig4.params()) {
            let res = e.evaluate(&reports);
            assert!(res.pass, "{}: {}", res.id, res.detail);
        }
    }
}
