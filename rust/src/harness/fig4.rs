//! Fig 4: roofline of achieved BF16 TFLOPS for square and irregular
//! (N=16) GEMM shapes on Gaudi-2 and A100.

use crate::config::DeviceKind;
use crate::ops::gemm;
use crate::sim::Dtype;
use crate::util::table::{fmt3, Report};

pub fn run() -> Vec<Report> {
    let mut r = Report::new("Fig 4: GEMM roofline (BF16)");
    r.header(&["shape (M,K,N)", "AI (FLOP/B)", "Gaudi-2 TF", "A100 TF", "bound(G)", "bound(A)"]);
    for (m, k, n) in gemm::fig4_shapes() {
        let g = gemm::run(DeviceKind::Gaudi2, m, k, n, Dtype::Bf16);
        let a = gemm::run(DeviceKind::A100, m, k, n, Dtype::Bf16);
        r.row(vec![
            format!("{m}x{k}x{n}"),
            fmt3(g.intensity),
            fmt3(g.exec.achieved_flops / 1e12),
            fmt3(a.exec.achieved_flops / 1e12),
            if g.exec.memory_bound { "mem" } else { "mme" }.into(),
            if a.exec.memory_bound { "mem" } else { "tc" }.into(),
        ]);
    }
    r.note("paper: Gaudi-2 reaches 429 TF at 8192^3 (99.3% of 432 peak) and wins every shape");
    vec![r]
}

#[cfg(test)]
mod tests {
    #[test]
    fn headline_point_present() {
        let reports = super::run();
        let text = reports[0].render();
        assert!(text.contains("8192x8192x8192"));
        // 429 +- a few TFLOPS at the headline point.
        assert!(text.contains("429") || text.contains("428") || text.contains("430"), "{text}");
    }
}
