//! Sim-speed experiment: the simulator benchmarking itself. Five typed
//! reports pin the indexed discrete-event core (`serving/cluster.rs`)
//! against its two retained oracles: (1) bitwise parity vs the
//! pre-refactor scan loop on a backpressured reference trace, (2) the
//! same bitwise parity for decode macro-stepping vs the retained
//! micro-step oracle (plus how many bursts the fast path actually
//! took), (3) raw dispatch throughput — a million-request streamed
//! diurnal day on a 100-replica fleet vs the scan-loop oracle, in
//! simulated events per wall-clock second — (4) macro-stepping
//! throughput on a saturated decode-heavy drain vs the micro-step
//! oracle, and (5) the derived headline claims (>= 10x events/sec,
//! O(open requests) memory, macro parity + speedup). `repro run
//! sim-speed --json --out bench/` writes the run as
//! `BENCH_sim_speed.json` for the CI bench-diff gate, whose time-polarity
//! units (`s` lower-better, `ev/s` higher-better) make a simulator
//! slowdown a gate failure, not a silent drift.
//!
//! Wall-clock cells are the one machine-dependent number in the artifact
//! set; the speedup *ratio* divides the machine out, which is why the
//! typed claims bound the ratio and the structural counts, not absolute
//! seconds (see bench/baseline/README.md for how the gate treats them).

use std::time::Instant;

use crate::config::ServingConfig;
use crate::harness::{Experiment, Params};
use crate::models::llama::LlamaConfig;
use crate::report::{Cell, Check, Expectation, Report, Selector, Unit};
use crate::serving::cluster::ClusterSim;
use crate::serving::qos::ClassSet;
use crate::serving::router::RoutePolicy;
use crate::workload::{DynamicSonnet, RateProcess};

struct Knobs {
    replicas: usize,
    streamed_arrivals: usize,
    oracle_arrivals: usize,
    day_s: f64,
    diurnal_depth: f64,
    parity_arrivals: usize,
    macro_arrivals: usize,
    macro_replicas: usize,
    seed: u64,
}

impl Knobs {
    fn from(params: &Params) -> Knobs {
        Knobs {
            replicas: params.get_or("replicas", 100.0) as usize,
            streamed_arrivals: params.get_or("streamed_arrivals", 1_000_000.0) as usize,
            oracle_arrivals: params.get_or("oracle_arrivals", 100_000.0) as usize,
            day_s: params.get_or("day_s", 86_400.0),
            diurnal_depth: params.get_or("diurnal_depth", 0.6),
            parity_arrivals: params.get_or("parity_arrivals", 40.0) as usize,
            macro_arrivals: params.get_or("macro_arrivals", 20_000.0) as usize,
            macro_replicas: params.get_or("macro_replicas", 8.0) as usize,
            seed: params.get_or("seed", 42.0) as u64,
        }
    }

    /// Mean offered load that fits `streamed_arrivals` into one day.
    fn rate_rps(&self) -> f64 {
        self.streamed_arrivals as f64 / self.day_s
    }
}

/// Short-decode Dynamic-Sonnet: clamped prompts and 8-token outputs keep
/// per-request event counts small, so the million-request day measures
/// dispatch cost (what this experiment is about), not decode length.
fn short_workload() -> DynamicSonnet {
    DynamicSonnet { max_input: 64, max_output: 8, ..DynamicSonnet::default() }
}

/// Decode-heavy Dynamic-Sonnet: short prompts, long outputs. Submitted
/// as one instantaneous burst, it drains as long stable decode windows —
/// the macro-stepping fast path's natural habitat, and the regime the
/// dispatch-bound `short_workload` deliberately avoids.
fn decode_heavy_workload() -> DynamicSonnet {
    DynamicSonnet { max_input: 64, max_output: 256, ..DynamicSonnet::default() }
}

fn fleet_config(replicas: usize) -> ServingConfig {
    ServingConfig {
        replicas,
        route_policy: RoutePolicy::LeastLoaded,
        // Generous cap: throughput runs measure dispatch, not backpressure
        // (the parity trace covers the requeue path separately).
        max_queued: 100_000,
        num_blocks: 2048,
        max_decode_batch: 16,
        ..Default::default()
    }
}

/// One timed `run_to_completion` with its dispatch-rate bookkeeping.
struct RunStats {
    arrivals: usize,
    completed: usize,
    events: u64,
    wall_s: f64,
    sim_span_s: f64,
    peak_open: usize,
    macro_bursts: u64,
    macro_ticks: u64,
}

impl RunStats {
    fn measure(mut sim: ClusterSim, arrivals: usize) -> RunStats {
        let t0 = Instant::now();
        sim.run_to_completion();
        let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
        RunStats {
            arrivals,
            completed: sim.completed(),
            events: sim.events(),
            wall_s,
            sim_span_s: sim.fleet_metrics().makespan,
            peak_open: sim.peak_open(),
            macro_bursts: sim.macro_bursts(),
            macro_ticks: sim.macro_ticks(),
        }
    }

    fn events_per_sec(&self) -> f64 {
        self.events as f64 / self.wall_s
    }

    /// Wall seconds spent per simulated hour — the "how long does a day
    /// of traffic take in CI" number.
    fn wall_per_sim_hour(&self) -> f64 {
        self.wall_s * 3600.0 / self.sim_span_s.max(1e-9)
    }
}

/// The headline run: a streamed diurnal day, O(open requests) memory.
fn run_streamed(k: &Knobs) -> RunStats {
    let mut sim = ClusterSim::new(&fleet_config(k.replicas), LlamaConfig::llama31_8b());
    sim.feed(
        short_workload()
            .stream(k.streamed_arrivals, k.rate_rps(), k.seed)
            .with_process(RateProcess::Diurnal { period_s: k.day_s, depth: k.diurnal_depth }),
    );
    RunStats::measure(sim, k.streamed_arrivals)
}

/// The baseline: the retained scan loop, eagerly submitted (it predates
/// streaming) at the same offered load, sized down so the O(replicas)
/// scan still finishes in CI time — events/sec is a rate, so the
/// comparison does not need equal trace lengths.
fn run_oracle(k: &Knobs) -> RunStats {
    let mut sim = ClusterSim::new_scan_oracle(&fleet_config(k.replicas), LlamaConfig::llama31_8b());
    sim.submit_all(short_workload().generate(k.oracle_arrivals, k.rate_rps(), k.seed));
    RunStats::measure(sim, k.oracle_arrivals)
}

/// The macro-stepping timed pair: a saturated decode-heavy drain where
/// quiescent windows dominate. `micro` retains the per-tick oracle so
/// the events/sec ratio isolates exactly what macro-stepping buys.
fn run_macro_timed(k: &Knobs, micro: bool) -> RunStats {
    let cfg = fleet_config(k.macro_replicas);
    let model = LlamaConfig::llama31_8b();
    let mut sim =
        if micro { ClusterSim::new_micro_oracle(&cfg, model) } else { ClusterSim::new(&cfg, model) };
    sim.submit_all(decode_heavy_workload().generate(k.macro_arrivals, f64::INFINITY, k.seed));
    RunStats::measure(sim, k.macro_arrivals)
}

/// Bitwise parity on the reference trace: tight queue cap, three-tier
/// class mix and prefix groups, so requeues, QoS feedback and prefix
/// routing all flow through both dispatch loops.
struct Parity {
    request_delta: f64,
    requeue_delta: u64,
    event_delta: u64,
    prefix_mismatches: usize,
}

/// The backpressured reference deployment both parity sections run on.
fn parity_config() -> ServingConfig {
    ServingConfig {
        replicas: 3,
        route_policy: RoutePolicy::LeastLoaded,
        max_queued: 8,
        num_blocks: 4096,
        max_decode_batch: 16,
        classes: ClassSet::three_tier(),
        ..Default::default()
    }
}

fn parity_trace(k: &Knobs) -> Vec<crate::serving::request::Request> {
    DynamicSonnet::default()
        .with_prefix_groups(4)
        .with_class_mix(vec![(0, 2), (1, 1), (2, 1)])
        .generate(k.parity_arrivals, 60.0, k.seed)
}

fn parity_delta(a: &ClusterSim, b: &ClusterSim) -> Parity {
    Parity {
        request_delta: a.fleet_metrics().max_request_delta(&b.fleet_metrics()),
        requeue_delta: a.requeues.abs_diff(b.requeues),
        event_delta: a.events().abs_diff(b.events()),
        prefix_mismatches: usize::from(
            format!("{:?}", a.fleet_prefix_stats()) != format!("{:?}", b.fleet_prefix_stats()),
        ),
    }
}

fn parity_check(k: &Knobs) -> Parity {
    let cfg = parity_config();
    let mut indexed = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
    indexed.submit_all(parity_trace(k));
    indexed.run_to_completion();
    let mut oracle = ClusterSim::new_scan_oracle(&cfg, LlamaConfig::llama31_8b());
    oracle.submit_all(parity_trace(k));
    oracle.run_to_completion();
    parity_delta(&indexed, &oracle)
}

/// Macro-stepping parity on the same backpressured reference trace: the
/// default (macro-enabled) run vs the retained micro-step oracle, plus
/// how much burst coverage the fast path actually achieved — a parity
/// claim over a trace the fast path never engages on would be vacuous.
struct MacroParity {
    parity: Parity,
    bursts: u64,
    ticks: u64,
}

fn macro_parity_check(k: &Knobs) -> MacroParity {
    let cfg = parity_config();
    let mut fast = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
    fast.submit_all(parity_trace(k));
    fast.run_to_completion();
    let mut micro = ClusterSim::new_micro_oracle(&cfg, LlamaConfig::llama31_8b());
    micro.submit_all(parity_trace(k));
    micro.run_to_completion();
    let bursts = fast.macro_bursts();
    let ticks = fast.macro_ticks();
    MacroParity { parity: parity_delta(&fast, &micro), bursts, ticks }
}

/// Shared column set of the two timed-throughput reports.
const THROUGHPUT_COLS: [&str; 7] = [
    "event loop",
    "arrivals",
    "events",
    "wall s",
    "events/sec",
    "wall s per sim-hour",
    "peak open",
];

fn throughput_row(label: &str, s: &RunStats) -> Vec<Cell> {
    vec![
        Cell::text(label),
        Cell::count(s.arrivals),
        Cell::count(s.events as usize),
        Cell::val(s.wall_s, Unit::Seconds),
        Cell::val(s.events_per_sec(), Unit::EventPerSec),
        Cell::val(s.wall_per_sim_hour(), Unit::Seconds),
        Cell::count(s.peak_open),
    ]
}

pub struct SimSpeed;

impl Experiment for SimSpeed {
    fn id(&self) -> &'static str {
        "sim_speed"
    }

    fn title(&self) -> &'static str {
        "Sim-speed: indexed event core vs scan-loop oracle (events/sec, parity, memory)"
    }

    fn params(&self) -> Params {
        Params::new()
            .with("replicas", 100.0)
            .with("streamed_arrivals", 1_000_000.0)
            .with("oracle_arrivals", 100_000.0)
            .with("day_s", 86_400.0)
            .with("diurnal_depth", 0.6)
            .with("parity_arrivals", 40.0)
            .with("macro_arrivals", 20_000.0)
            .with("macro_replicas", 8.0)
            .with("seed", 42.0)
            // Thresholds of the machine-dependent events/sec speedup
            // claims (desk-estimated; see ROADMAP). `--param min_speedup=K`
            // / `--param min_macro_speedup=K` let a CI runner gate at a
            // measured value instead of hard-failing on a constant nobody
            // timed on its hardware.
            .with("min_speedup", 10.0)
            .with("min_macro_speedup", 1.3)
    }

    fn run(&self, params: &Params) -> Vec<Report> {
        let k = Knobs::from(params);
        let parity = parity_check(&k);
        let macro_parity = macro_parity_check(&k);
        let streamed = run_streamed(&k);
        let oracle = run_oracle(&k);
        let macro_fast = run_macro_timed(&k, false);
        let macro_micro = run_macro_timed(&k, true);

        let mut p = Report::new(
            "Sim-speed parity: indexed event core vs retained scan-loop oracle",
        );
        p.header(&["check", "value"]);
        p.row(vec![
            Cell::text("max per-request metric delta"),
            Cell::val(parity.request_delta, Unit::Seconds),
        ]);
        p.row(vec![
            Cell::text("requeue-count delta"),
            Cell::count(parity.requeue_delta as usize),
        ]);
        p.row(vec![Cell::text("event-count delta"), Cell::count(parity.event_delta as usize)]);
        p.row(vec![
            Cell::text("prefix-cache stat mismatches"),
            Cell::count(parity.prefix_mismatches),
        ]);
        p.note(format!(
            "reference trace: {} requests at 60 req/s (seed {}), 3 replicas, queue cap 8 \
             (forces requeues), three-tier class mix, 4 prefix groups — both loops must \
             agree bit-for-bit",
            k.parity_arrivals, k.seed
        ));

        let mut mp = Report::new(
            "Sim-speed macro parity: decode macro-stepping vs retained micro-step oracle",
        );
        mp.header(&["check", "value"]);
        mp.row(vec![
            Cell::text("max per-request metric delta"),
            Cell::val(macro_parity.parity.request_delta, Unit::Seconds),
        ]);
        mp.row(vec![
            Cell::text("requeue-count delta"),
            Cell::count(macro_parity.parity.requeue_delta as usize),
        ]);
        mp.row(vec![
            Cell::text("event-count delta"),
            Cell::count(macro_parity.parity.event_delta as usize),
        ]);
        mp.row(vec![
            Cell::text("prefix-cache stat mismatches"),
            Cell::count(macro_parity.parity.prefix_mismatches),
        ]);
        mp.row(vec![Cell::text("macro bursts taken"), Cell::count(macro_parity.bursts as usize)]);
        mp.row(vec![Cell::text("macro ticks covered"), Cell::count(macro_parity.ticks as usize)]);
        mp.note(
            "same backpressured reference trace as the scan-loop parity section; the \
             default run macro-steps quiescent decode windows while the oracle steps \
             every tick — identical arithmetic, so all deltas must be zero, and the \
             burst count proves the fast path actually engaged (a parity claim over a \
             trace it never fires on would be vacuous)",
        );

        let mut t = Report::new(format!(
            "Sim-speed throughput: {}-replica fleet, short-decode Dynamic-Sonnet",
            k.replicas
        ));
        t.header(&THROUGHPUT_COLS);
        for (label, s) in [("indexed + streamed", &streamed), ("scan oracle (eager)", &oracle)] {
            t.row(throughput_row(label, s));
        }
        t.note(format!(
            "streamed run: diurnal day ({}s period, depth {}) at mean {:.2} req/s fed \
             lazily; oracle run: same load, eager submission, legacy O(replicas) scan \
             per event",
            k.day_s,
            k.diurnal_depth,
            k.rate_rps()
        ));

        let mut mt = Report::new(format!(
            "Sim-speed macro-stepping throughput: {}-replica saturated decode-heavy drain",
            k.macro_replicas
        ));
        mt.header(&THROUGHPUT_COLS);
        for (label, s) in
            [("macro bursts on", &macro_fast), ("micro-step oracle", &macro_micro)]
        {
            mt.row(throughput_row(label, s));
        }
        mt.note(format!(
            "{} decode-heavy requests (<= 64-token prompts, <= 256-token outputs) \
             submitted as one burst and drained: long stable decode windows, so the \
             fast path covers most ticks ({} bursts over {} ticks here); the micro \
             oracle pays one full scheduler + costing pass per tick",
            k.macro_arrivals, macro_fast.macro_bursts, macro_fast.macro_ticks
        ));

        let conservation = streamed.arrivals.abs_diff(streamed.completed)
            + oracle.arrivals.abs_diff(oracle.completed)
            + macro_fast.arrivals.abs_diff(macro_fast.completed)
            + macro_micro.arrivals.abs_diff(macro_micro.completed);
        let mut c = Report::new("Sim-speed derived claims");
        c.header(&["claim", "value"]);
        c.row(vec![
            Cell::text("indexed events/sec over scan-loop oracle"),
            Cell::val(streamed.events_per_sec() / oracle.events_per_sec(), Unit::Ratio),
        ]);
        c.row(vec![
            Cell::text("macro events/sec over micro-step oracle"),
            Cell::val(macro_fast.events_per_sec() / macro_micro.events_per_sec(), Unit::Ratio),
        ]);
        c.row(vec![
            Cell::text("bitwise parity: max per-request delta"),
            Cell::val(parity.request_delta, Unit::Seconds),
        ]);
        c.row(vec![
            Cell::text("macro parity: max per-request delta"),
            Cell::val(macro_parity.parity.request_delta, Unit::Seconds),
        ]);
        c.row(vec![
            Cell::text("streamed arrivals per run"),
            Cell::count(streamed.arrivals),
        ]);
        c.row(vec![
            Cell::text("peak open / streamed arrivals"),
            Cell::val(streamed.peak_open as f64 / streamed.arrivals.max(1) as f64, Unit::Ratio),
        ]);
        c.row(vec![
            Cell::text("request conservation violations"),
            Cell::count(conservation),
        ]);
        c.note(
            "the memory claim is structural (working set = open requests, not trace \
             length); the speedup claims are wall-clock and release-build only — debug \
             timings are meaningless, so unit tests check the structural claims and CI \
             checks all of them",
        );

        vec![p, mp, t, mt, c]
    }

    fn expectations(&self, params: &Params) -> Vec<Expectation> {
        vec![
            Expectation::new(
                "sim_speed.bitwise_parity",
                "the indexed event core replays the legacy scan loop bit-for-bit",
                Selector::cell(
                    "Sim-speed derived claims",
                    "bitwise parity: max per-request delta",
                    "value",
                ),
                Check::EqExact(0.0),
            ),
            Expectation::new(
                "sim_speed.macro_parity",
                "decode macro-stepping replays the retained micro-step oracle bit-for-bit \
                 on the backpressured reference trace",
                Selector::cell(
                    "Sim-speed derived claims",
                    "macro parity: max per-request delta",
                    "value",
                ),
                Check::EqExact(0.0),
            ),
            Expectation::new(
                "sim_speed.macro_engaged",
                "the macro fast path takes real bursts on the parity trace (a vacuous \
                 parity claim would pass trivially)",
                Selector::cell(
                    "Sim-speed macro parity: decode macro-stepping vs retained \
                     micro-step oracle",
                    "macro bursts taken",
                    "value",
                ),
                Check::Ge(1.0),
            ),
            Expectation::new(
                "sim_speed.macro_speedup",
                "macro-stepping beats the micro-step oracle's events/sec on the \
                 decode-heavy drain by the min_macro_speedup factor (default 1.3x, \
                 `--param min_macro_speedup=K` to recalibrate)",
                Selector::cell(
                    "Sim-speed derived claims",
                    "macro events/sec over micro-step oracle",
                    "value",
                ),
                Check::Ge(params.get_or("min_macro_speedup", 1.3)),
            ),
            Expectation::new(
                "sim_speed.indexed_speedup",
                "indexed dispatch beats the scan loop's events/sec by the min_speedup \
                 factor (default 10x, `--param min_speedup=K` to recalibrate)",
                Selector::cell(
                    "Sim-speed derived claims",
                    "indexed events/sec over scan-loop oracle",
                    "value",
                ),
                Check::Ge(params.get_or("min_speedup", 10.0)),
            ),
            Expectation::new(
                "sim_speed.million_request_day",
                "the streamed run covers a full million-request day",
                Selector::cell("Sim-speed derived claims", "streamed arrivals per run", "value"),
                Check::Ge(1_000_000.0),
            ),
            Expectation::new(
                "sim_speed.memory_bounded",
                "streaming keeps the working set at open requests, not trace length",
                Selector::cell(
                    "Sim-speed derived claims",
                    "peak open / streamed arrivals",
                    "value",
                ),
                Check::Le(0.05),
            ),
            Expectation::new(
                "sim_speed.conservation",
                "every arrival completes exactly once in both timed runs",
                Selector::cell(
                    "Sim-speed derived claims",
                    "request conservation violations",
                    "value",
                ),
                Check::EqExact(0.0),
            ),
        ]
    }
}

/// Run with default params (convenience for library callers; note the
/// default grid is the full million-request day — CI-scale, not
/// unit-test-scale).
pub fn run() -> Vec<Report> {
    SimSpeed.run(&SimSpeed.params())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_params() -> Params {
        // A three-hundred-request "day" keeps the debug-build unit test
        // quick; the full default grid runs under `repro run sim-speed`.
        SimSpeed
            .params()
            .with("replicas", 4.0)
            .with("streamed_arrivals", 300.0)
            .with("oracle_arrivals", 300.0)
            .with("day_s", 30.0)
            .with("parity_arrivals", 30.0)
            .with("macro_arrivals", 48.0)
            .with("macro_replicas", 2.0)
    }

    #[test]
    fn reports_have_expected_shape() {
        let reports = SimSpeed.run(&small_params());
        assert_eq!(reports.len(), 5);
        assert_eq!(reports[0].num_rows(), 4);
        assert_eq!(reports[1].num_rows(), 6);
        assert_eq!(reports[2].num_rows(), 2);
        assert_eq!(reports[3].num_rows(), 2);
        assert_eq!(reports[4].num_rows(), 7);
    }

    #[test]
    fn structural_claims_hold_at_any_scale() {
        // The timing claims (>= 10x indexed, >= 1.3x macro) and the
        // million-request scale claim are CI-only: they need the
        // release-build default grid, and debug-build wall clocks are
        // meaningless. Parity, burst engagement, memory and conservation
        // are structural — they must hold at every scale.
        let reports = SimSpeed.run(&small_params());
        for e in SimSpeed.expectations(&SimSpeed.params()) {
            if e.id.ends_with("indexed_speedup")
                || e.id.ends_with("macro_speedup")
                || e.id.ends_with("million_request_day")
            {
                continue;
            }
            let res = e.evaluate(&reports);
            assert!(res.pass, "{}: {}", res.id, res.detail);
        }
    }

    #[test]
    fn speedup_threshold_follows_the_min_speedup_param() {
        // `--param min_speedup=K` must move the machine-dependent claim's
        // bound — the default 10.0 is a desk estimate, not a measurement.
        let find_check = |params: &Params, id: &str| {
            SimSpeed
                .expectations(params)
                .into_iter()
                .find(|e| e.id.ends_with(id))
                .unwrap()
                .check
        };
        assert_eq!(find_check(&SimSpeed.params(), "indexed_speedup"), Check::Ge(10.0));
        assert_eq!(
            find_check(&SimSpeed.params().with("min_speedup", 2.5), "indexed_speedup"),
            Check::Ge(2.5)
        );
        // And the macro claim's knob moves independently.
        assert_eq!(find_check(&SimSpeed.params(), "macro_speedup"), Check::Ge(1.3));
        assert_eq!(
            find_check(&SimSpeed.params().with("min_macro_speedup", 1.05), "macro_speedup"),
            Check::Ge(1.05)
        );
    }

    #[test]
    fn macro_timed_pair_counts_identical_events_and_takes_bursts() {
        // The macro/micro timed pair must agree on *what* was simulated —
        // identical event and completion counts — and differ only in how
        // many scheduler passes paid for it. Burst coverage > burst count
        // proves multi-tick windows, not degenerate 1-tick bursts.
        let k = Knobs::from(&small_params());
        let fast = run_macro_timed(&k, false);
        let micro = run_macro_timed(&k, true);
        assert_eq!(fast.events, micro.events);
        assert_eq!(fast.completed, micro.completed);
        assert_eq!(fast.completed, k.macro_arrivals);
        assert!(fast.macro_bursts > 0, "the drain must engage the fast path");
        assert!(fast.macro_ticks > fast.macro_bursts);
        assert_eq!(micro.macro_ticks, 0, "the oracle must stay micro-stepped");
    }
}
