//! QoS-sweep experiment: traffic-class mix x offered load — the paper's
//! single-SLO vLLM serving study generalized to the mixed traffic a
//! production fleet actually sees (interactive chat / batch
//! summarization / background eval, `serving::qos`). Each grid point
//! runs the same open-loop trace twice: once with class priorities live
//! (priority admission, lowest-class-first preemption, QoS routing) and
//! once class-blind (priorities flattened to 0 — the legacy FIFO path) —
//! so every row reports interactive attainment with and without QoS and
//! the percentage-point gain.
//!
//! Two structural claims are checked (`repro run qos-sweep --check`):
//! the mean interactive-class attainment gain over the grid is
//! non-negative (priorities help the tight-SLO class under mixed load),
//! and the class machinery is **inert at uniform priority** — a
//! uniform-priority tagged run is bitwise-equal (EqExact 0) to the
//! untagged single-default-class run, and the class-aware metrics
//! bitwise-equal in-harness replays of the deleted scalar formulas (the
//! same oracle-parity pattern the cache-sweep used for the prefix
//! cache). One *deliberate* divergence from the literal pre-refactor
//! binary is out of the claim's scope: the decode loop no longer decodes
//! a sequence preempted earlier in the same step (a legacy double-run
//! bug fixed in this PR; both arms of the oracle carry the fix).
//! `repro run qos-sweep --json --out bench/` writes the grid as
//! `BENCH_qos_sweep.json` for the CI bench-diff gate.

use crate::config::ServingConfig;
use crate::harness::{Experiment, Params};
use crate::models::llama::LlamaConfig;
use crate::report::{Cell, Check, Expectation, Report, Selector, Unit};
use crate::serving::cluster::ClusterSim;
use crate::serving::metrics::MetricsCollector;
use crate::serving::qos::ClassSet;
use crate::serving::router::RoutePolicy;
use crate::util::par;
use crate::workload::OpenLoopTrace;

/// Replicas per deployment (fixed, so curves compare mixes and loads at
/// equal fleet size).
const REPLICAS: usize = 2;

/// (label, shares per class) — shares index the `ClassSet::three_tier`
/// order: interactive (0), batch (1), background (2).
const MIXES: [(&str, [usize; 3]); 3] = [
    ("interactive-heavy 70/20/10", [7, 2, 1]),
    ("balanced 40/30/30", [4, 3, 3]),
    ("background-heavy 20/30/50", [2, 3, 5]),
];

struct Knobs {
    load_min_rps: f64,
    load_step_rps: f64,
    load_points: usize,
    duration_s: f64,
    seed: u64,
}

impl Knobs {
    fn from(params: &Params) -> Knobs {
        Knobs {
            load_min_rps: params.get_or("load_min_rps", 8.0),
            load_step_rps: params.get_or("load_step_rps", 8.0),
            load_points: params.get_or("load_points", 3.0) as usize,
            duration_s: params.get_or("duration_s", 3.0),
            seed: params.get_or("seed", 31.0) as u64,
        }
    }

    fn loads(&self) -> Vec<f64> {
        crate::harness::load_grid(self.load_min_rps, self.load_step_rps, self.load_points)
    }
}

fn qos_config(classes: ClassSet) -> ServingConfig {
    ServingConfig {
        replicas: REPLICAS,
        route_policy: RoutePolicy::LeastLoaded,
        max_decode_batch: 24,
        num_blocks: 4096,
        classes,
        ..Default::default()
    }
}

/// One (mix, offered load) grid point: the QoS run and its class-blind
/// control on the same trace.
struct SweepPoint {
    offered_rps: f64,
    submitted: usize,
    completed: usize,
    /// Per-class attainment under live priorities (three-tier order).
    att: [f64; 3],
    weighted: f64,
    interactive_goodput: f64,
    /// Interactive attainment with priorities flattened (class-blind).
    blind_interactive: f64,
    blind_completed: usize,
    tps: f64,
    requeues: u64,
}

fn run_point(k: &Knobs, shares: [usize; 3], rate: f64) -> SweepPoint {
    let classes = ClassSet::three_tier();
    let mix: Vec<(usize, usize)> =
        shares.iter().enumerate().filter(|(_, s)| **s > 0).map(|(c, s)| (c, *s)).collect();
    let trace =
        || OpenLoopTrace::new(rate, k.duration_s).with_class_mix(mix.clone()).generate(k.seed);
    let submitted = trace().len();

    let run = |set: ClassSet| -> (ClusterSim, MetricsCollector, f64) {
        let mut sim = ClusterSim::new(&qos_config(set), LlamaConfig::llama31_8b());
        sim.submit_all(trace());
        let s = sim.run_to_completion();
        let fleet = sim.fleet_metrics();
        (sim, fleet, s.throughput_tps)
    };

    // Live priorities vs the class-blind control (same SLOs and weights,
    // priorities flattened to 0 — legacy FIFO/youngest/no-penalty).
    let (sim, fleet, tps) = run(classes.clone());
    let (blind_sim, blind_fleet, _) = run(classes.flatten_priorities());

    let per = fleet.class_breakdown(&classes);
    let blind_per = blind_fleet.class_breakdown(&classes);
    SweepPoint {
        offered_rps: rate,
        submitted,
        completed: sim.completed(),
        att: [per[0].attainment, per[1].attainment, per[2].attainment],
        weighted: fleet.weighted_attainment(&classes),
        interactive_goodput: per[0].goodput_rps,
        blind_interactive: blind_per[0].attainment,
        blind_completed: blind_sim.completed(),
        tps,
        requeues: sim.requeues,
    }
}

/// Replays of the three deleted scalar-SLO metrics formulas — the
/// executable spec of the pre-refactor `goodput_under_slo` /
/// `slo_attainment` / `energy_per_good_token` call sites that each
/// re-filtered `per_request` by a bare `(ttft, tpot)` pair.
mod legacy {
    use crate::serving::metrics::MetricsCollector;

    pub fn goodput(ms: &MetricsCollector, ttft: f64, tpot: f64) -> f64 {
        let ok = ms.per_request().iter().filter(|m| m.ttft <= ttft && m.tpot <= tpot).count();
        ok as f64 / ms.makespan.max(1e-12)
    }

    pub fn attainment(ms: &MetricsCollector, ttft: f64, tpot: f64) -> f64 {
        if ms.per_request().is_empty() {
            return 0.0;
        }
        let ok = ms.per_request().iter().filter(|m| m.ttft <= ttft && m.tpot <= tpot).count();
        ok as f64 / ms.per_request().len() as f64
    }

    pub fn energy_per_good_token(ms: &MetricsCollector, ttft: f64, tpot: f64) -> Option<f64> {
        let good: usize = ms
            .per_request()
            .iter()
            .filter(|m| m.ttft <= ttft && m.tpot <= tpot)
            .map(|m| m.output_tokens)
            .sum();
        (good > 0 && ms.energy_j > 0.0).then(|| ms.energy_j / good as f64)
    }
}

/// Max delta between the refactored class path and the pre-refactor
/// scalar-SLO path — exact-zero by construction, from two directions:
///
/// 1. *Dynamics*: a run whose requests are tagged across three
///    uniform-priority-0 classes must replay an untagged
///    single-default-class run per-request bitwise (priority 0 never
///    reorders admission, never changes a preemption victim, never moves
///    a routing score).
/// 2. *Formulas*: the class-aware goodput / attainment / J-per-good-token
///    of a single scalar class must equal the deleted scalar formulas
///    replayed verbatim on the same collector.
fn scalar_parity_delta(k: &Knobs) -> f64 {
    let (ttft, tpot) = (1.0, 0.1);
    let rate = k.load_min_rps;
    let untagged = || OpenLoopTrace::new(rate, k.duration_s).generate(k.seed);
    // Same arrivals/lengths (class tagging is RNG-free), spread over
    // three classes with *uniform* priority 0 and identical SLOs.
    let uniform = ClassSet::new(vec![
        crate::serving::qos::TrafficClass::new("a", 0, ttft, tpot, 1.0),
        crate::serving::qos::TrafficClass::new("b", 0, ttft, tpot, 1.0),
        crate::serving::qos::TrafficClass::new("c", 0, ttft, tpot, 1.0),
    ])
    .expect("valid class set");
    let tagged = || {
        OpenLoopTrace::new(rate, k.duration_s)
            .with_class_mix(vec![(0, 1), (1, 1), (2, 1)])
            .generate(k.seed)
    };

    let run = |cfg: &ServingConfig, reqs: Vec<crate::serving::request::Request>| {
        let mut sim = ClusterSim::new(cfg, LlamaConfig::llama31_8b());
        sim.submit_all(reqs);
        sim.run_to_completion();
        sim.fleet_metrics()
    };
    let single = run(&qos_config(ClassSet::default()), untagged());
    let multi = run(&qos_config(uniform), tagged());
    let mut delta = single.max_request_delta(&multi);

    // Formula parity on the single-class run.
    let classes = ClassSet::scalar(ttft, tpot);
    delta += (single.goodput(&classes) - legacy::goodput(&single, ttft, tpot)).abs();
    delta += (single.attainment(&classes) - legacy::attainment(&single, ttft, tpot)).abs();
    let new_e = single.energy_per_good_token(&classes);
    let old_e = legacy::energy_per_good_token(&single, ttft, tpot);
    delta += match (new_e, old_e) {
        (Some(a), Some(b)) => (a - b).abs(),
        (None, None) => 0.0,
        _ => 1.0,
    };
    delta
}

pub struct QosSweep;

impl Experiment for QosSweep {
    fn id(&self) -> &'static str {
        "qos_sweep"
    }

    fn title(&self) -> &'static str {
        "QoS sweep: traffic-class mix x offered load (per-class attainment, QoS vs class-blind)"
    }

    fn params(&self) -> Params {
        Params::new()
            .with("load_min_rps", 8.0)
            .with("load_step_rps", 8.0)
            .with("load_points", 3.0)
            .with("duration_s", 3.0)
            .with("seed", 31.0)
    }

    fn run(&self, params: &Params) -> Vec<Report> {
        let k = Knobs::from(params);
        let loads = k.loads();
        // Fan the flattened (mix, load) grid across the worker pool —
        // each point is an independent seeded run (both QoS and blind
        // arms); submission-ordered assembly keeps the artifact
        // byte-identical at any --jobs value.
        let all_points = par::par_map_indexed(MIXES.len() * loads.len(), |idx| {
            run_point(&k, MIXES[idx / loads.len()].1, loads[idx % loads.len()])
        });
        let mut point_chunks = all_points.chunks_exact(loads.len());
        let mut reports = Vec::new();
        let mut curves: Vec<(&str, &[SweepPoint])> = Vec::new();

        for (label, _shares) in MIXES {
            let points: &[SweepPoint] = point_chunks.next().expect("one chunk per mix");
            let mut r = Report::new(format!(
                "QoS load sweep [{label}]: {REPLICAS} replicas, three-tier classes \
                 (interactive 0.5s/50ms, batch 2s/200ms, background 8s/500ms)"
            ));
            r.header(&[
                "offered",
                "offered req/s",
                "served",
                "interactive att",
                "batch att",
                "background att",
                "weighted att",
                "blind interactive att",
                "interactive gain pp",
                "interactive goodput req/s",
                "tok/s",
                "requeues",
            ]);
            for p in points {
                r.row(vec![
                    Cell::text(format!("{:.0} rps", p.offered_rps)),
                    Cell::val(p.offered_rps, Unit::ReqPerSec),
                    Cell::count(p.completed),
                    Cell::val(p.att[0], Unit::Percent),
                    Cell::val(p.att[1], Unit::Percent),
                    Cell::val(p.att[2], Unit::Percent),
                    Cell::val(p.weighted, Unit::Percent),
                    Cell::val(p.blind_interactive, Unit::Percent),
                    Cell::val((p.att[0] - p.blind_interactive) * 100.0, Unit::Pp),
                    Cell::val(p.interactive_goodput, Unit::ReqPerSec),
                    Cell::val(p.tps, Unit::TokPerSec),
                    Cell::count(p.requeues as usize),
                ]);
            }
            r.note(format!(
                "open-loop mixed-class trace at each offered load for {}s (seed {}); \
                 'blind' = same trace, priorities flattened to 0 (legacy FIFO path)",
                k.duration_s, k.seed
            ));
            reports.push(r);
            curves.push((label, points));
        }

        // Derived claims over the grid.
        let parity = scalar_parity_delta(&k);
        let all: Vec<&SweepPoint> = curves.iter().flat_map(|(_, ps)| ps.iter()).collect();
        let conservation: usize = all
            .iter()
            .map(|p| p.submitted.abs_diff(p.completed) + p.submitted.abs_diff(p.blind_completed))
            .sum();
        let mean_gain_pp = if all.is_empty() {
            0.0
        } else {
            all.iter().map(|p| (p.att[0] - p.blind_interactive) * 100.0).sum::<f64>()
                / all.len() as f64
        };
        let min_gain_pp = all
            .iter()
            .map(|p| (p.att[0] - p.blind_interactive) * 100.0)
            .fold(f64::INFINITY, f64::min);
        let grid_points = all.len();

        let mut claims = Report::new("QoS-sweep derived claims");
        claims.header(&["claim", "value"]);
        claims.row(vec![
            Cell::text("single default class vs scalar-SLO legacy path: max delta"),
            Cell::val(parity, Unit::Seconds),
        ]);
        claims.row(vec![
            Cell::text("mean interactive attainment gain vs class-blind (pp)"),
            Cell::val(mean_gain_pp, Unit::Pp),
        ]);
        claims.row(vec![
            Cell::text("min interactive attainment gain vs class-blind (pp)"),
            Cell::val(min_gain_pp, Unit::Pp),
        ]);
        claims.row(vec![
            Cell::text("request conservation violations over the grid"),
            Cell::count(conservation),
        ]);
        claims.row(vec![Cell::text("grid points swept"), Cell::count(grid_points)]);
        claims.note(
            "parity is exact-zero by construction: priority-0 classes never reorder \
             admission, never change preemption victims, never move routing scores, and \
             the class-aware metrics replay the deleted scalar formulas bit-for-bit \
             (both arms include this PR's fix for the legacy preempted-mid-batch \
             double-decode bug, which is outside the claim's scope)",
        );
        reports.push(claims);

        reports
    }

    fn expectations(&self, _params: &Params) -> Vec<Expectation> {
        vec![
            Expectation::new(
                "qos_sweep.scalar_parity",
                "a single-default-class config replays the pre-refactor scalar-SLO path bitwise",
                Selector::cell(
                    "QoS-sweep derived claims",
                    "single default class vs scalar-SLO legacy path: max delta",
                    "value",
                ),
                Check::EqExact(0.0),
            ),
            Expectation::new(
                "qos_sweep.interactive_gain",
                "class priorities do not hurt mean interactive attainment under mixed load",
                Selector::cell(
                    "QoS-sweep derived claims",
                    "mean interactive attainment gain vs class-blind (pp)",
                    "value",
                ),
                Check::Ge(0.0),
            ),
            Expectation::new(
                "qos_sweep.conservation",
                "every submitted request completes exactly once at every grid point (both arms)",
                Selector::cell(
                    "QoS-sweep derived claims",
                    "request conservation violations over the grid",
                    "value",
                ),
                Check::EqExact(0.0),
            ),
            Expectation::new(
                "qos_sweep.full_grid",
                "the sweep covers every (mix, load) grid point",
                Selector::cell("QoS-sweep derived claims", "grid points swept", "value"),
                Check::Ge(MIXES.len() as f64),
            ),
        ]
    }
}

/// Run with default params (convenience for tests and library callers).
pub fn run() -> Vec<Report> {
    QosSweep.run(&QosSweep.params())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_params() -> Params {
        QosSweep
            .params()
            .with("load_points", 2.0)
            .with("duration_s", 1.5)
            .with("load_step_rps", 16.0)
    }

    #[test]
    fn one_report_per_mix_plus_claims() {
        let reports = QosSweep.run(&small_params());
        assert_eq!(reports.len(), MIXES.len() + 1);
        for (i, (label, _)) in MIXES.iter().enumerate() {
            assert!(reports[i].title().contains(label), "report {i} mislabeled");
            assert_eq!(reports[i].num_rows(), 2);
        }
        assert_eq!(reports[MIXES.len()].num_rows(), 5);
    }

    #[test]
    fn scalar_parity_is_exact() {
        let k = Knobs::from(&small_params());
        assert_eq!(scalar_parity_delta(&k), 0.0);
    }

    #[test]
    fn conservation_and_breakdown_shapes_hold() {
        let k = Knobs::from(&small_params());
        let p = run_point(&k, [4, 3, 3], k.load_min_rps);
        assert_eq!(p.submitted, p.completed);
        assert_eq!(p.submitted, p.blind_completed);
        for a in p.att {
            assert!((0.0..=1.0).contains(&a));
        }
        assert!((0.0..=1.0).contains(&p.weighted));
    }

    #[test]
    fn priorities_help_interactive_under_heavy_mixed_load() {
        // At the heaviest default load on the interactive-heavy mix, the
        // QoS arm's interactive attainment must be at least the blind
        // arm's — the experiment's headline claim at its sharpest point.
        let k = Knobs::from(&QosSweep.params());
        let heavy = k.loads().last().copied().unwrap();
        let p = run_point(&k, MIXES[0].1, heavy);
        assert!(
            p.att[0] >= p.blind_interactive - 1e-12,
            "QoS interactive {} vs blind {}",
            p.att[0],
            p.blind_interactive
        );
    }

    #[test]
    fn expectations_pass_on_default_grid() {
        // The full default grid is the artifact CI gates on; every
        // expectation must hold there.
        let reports = run();
        for e in QosSweep.expectations(&QosSweep.params()) {
            let res = e.evaluate(&reports);
            assert!(res.pass, "{}: {}", res.id, res.detail);
        }
    }
}
