//! TP sweep: Llama-3.1-70B served by *device groups* — tp ∈ {1, 2, 4, 8}
//! cards per replica on Gaudi-2 and A100 (the paper's Fig 12(a)
//! multi-device axis, re-asked as a sizing question). One typed report
//! per device kind walks group width through HBM sizing (weight shard per
//! card, KV-token capacity, block budget), analytic throughput, scaling
//! efficiency, and the decode-step collective-overhead share; a sized-
//! deployment report runs real tp=4 `ClusterSim` groups with the
//! group-aware KV block budget; a derived-claims report pins the PR's
//! headline claims — tp=1 spec fleets replay the legacy single-device
//! path bit-for-bit, tokens/s is monotone in tp at sub-linear efficiency,
//! and 70B is HBM-bound at tp=1 yet servable at tp≥4 on both devices.
//! `repro run tp-sweep --json --out bench/` writes the sweep as
//! `BENCH_tp_sweep.json` for the CI bench-diff gate.

use crate::config::{DeviceKind, ReplicaSpec, ServingConfig};
use crate::harness::{Experiment, Params};
use crate::models::llama::{self, LlamaConfig};
use crate::report::{Cell, Check, Expectation, Report, Selector, Unit};
use crate::serving::cluster::ClusterSim;
use crate::serving::router::RoutePolicy;
use crate::util::par;
use crate::workload::DynamicSonnet;

/// Group widths the sweep walks (the paper's multi-device grid).
const TP_GRID: [usize; 4] = [1, 2, 4, 8];

const DEVICES: [DeviceKind; 2] = [DeviceKind::Gaudi2, DeviceKind::A100];

struct Knobs {
    batch: usize,
    in_len: usize,
    out_len: usize,
    /// KV length at which the decode-step collective share is probed.
    probe_kv_len: usize,
    /// KV block size used when converting token capacity to a budget.
    block_size: usize,
    /// Requests / rate / seed for the simulated arms.
    requests: usize,
    rate_rps: f64,
    seed: u64,
}

impl Knobs {
    fn from(params: &Params) -> Knobs {
        Knobs {
            batch: params.get_or("batch", 16.0) as usize,
            in_len: params.get_or("in_len", 100.0) as usize,
            out_len: params.get_or("out_len", 100.0) as usize,
            probe_kv_len: params.get_or("probe_kv_len", 1024.0) as usize,
            block_size: params.get_or("block_size", 128.0) as usize,
            requests: params.get_or("requests", 32.0) as usize,
            rate_rps: params.get_or("rate_rps", 30.0),
            seed: params.get_or("seed", 31.0) as u64,
        }
    }
}

/// One (device, tp) point of the analytic sweep.
struct TpPoint {
    tp: usize,
    weights_per_card: f64,
    kv_tokens: usize,
    kv_blocks: usize,
    feasible: bool,
    tps: f64,
    comm_share: f64,
}

fn run_point(k: &Knobs, cfg: &LlamaConfig, kind: DeviceKind, tp: usize) -> TpPoint {
    let cost = llama::serve_fixed(cfg, kind, k.batch, k.in_len, k.out_len, tp);
    let decode = llama::decode_step_cost(cfg, kind, k.batch, k.probe_kv_len, tp);
    TpPoint {
        tp,
        weights_per_card: llama::weight_bytes_per_card(cfg, tp),
        kv_tokens: llama::kv_token_capacity(cfg, kind, tp),
        kv_blocks: llama::kv_block_budget(cfg, kind, tp, k.block_size),
        feasible: llama::hbm_feasible(cfg, kind, tp, k.in_len + k.out_len),
        tps: cost.throughput(k.batch, k.out_len),
        comm_share: decode.activity.comm_util,
    }
}

/// Max per-request metric delta between a fleet of tp=1 `ReplicaSpec`s and
/// the legacy homogeneous `device x replicas` config on the same trace —
/// exact-zero by construction: a width-1 group IS a single device (also
/// pinned by the `tp1_replica_spec_fleets_replay_the_legacy_path` proptest).
fn tp1_vs_legacy_delta(k: &Knobs) -> f64 {
    let legacy = ServingConfig {
        replicas: 2,
        device: DeviceKind::Gaudi2,
        route_policy: RoutePolicy::LeastLoaded,
        num_blocks: 4096,
        max_decode_batch: 16,
        ..Default::default()
    };
    let grouped = legacy
        .clone()
        .with_replica_specs(vec![ReplicaSpec::new(DeviceKind::Gaudi2, 1); 2]);
    let run = |cfg: &ServingConfig| {
        let mut sim = ClusterSim::new(cfg, LlamaConfig::llama31_8b());
        sim.submit_all(DynamicSonnet::default().generate(k.requests, k.rate_rps, k.seed));
        sim.run_to_completion();
        sim.fleet_metrics()
    };
    run(&legacy).max_request_delta(&run(&grouped))
}

/// One sized tp=4 deployment: a single device group serving 70B with its
/// KV block budget derived from the group-aware sizing helpers.
struct SizedPoint {
    kind: DeviceKind,
    blocks: usize,
    submitted: usize,
    completed: usize,
    tps: f64,
}

fn run_sized(k: &Knobs, cfg: &LlamaConfig, kind: DeviceKind) -> SizedPoint {
    // Cap the configured blocks well below the budget so the unit-test
    // grid stays fast; the budget itself is what the claims gate on.
    let budget = llama::kv_block_budget(cfg, kind, 4, k.block_size);
    let serving = ServingConfig {
        num_blocks: budget.min(8192),
        max_decode_batch: 8,
        route_policy: RoutePolicy::LeastLoaded,
        ..Default::default()
    }
    .with_replica_specs(vec![ReplicaSpec::new(kind, 4)]);
    let mut sim = ClusterSim::new(&serving, *cfg);
    let trace = DynamicSonnet::default().generate(k.requests, k.rate_rps, k.seed);
    let submitted = trace.len();
    sim.submit_all(trace);
    let s = sim.run_to_completion();
    SizedPoint { kind, blocks: budget, submitted, completed: sim.completed(), tps: s.throughput_tps }
}

pub struct TpSweep;

impl Experiment for TpSweep {
    fn id(&self) -> &'static str {
        "tp_sweep"
    }

    fn title(&self) -> &'static str {
        "TP sweep: Llama-70B device-group scaling across tp = 1/2/4/8 on Gaudi-2 and A100"
    }

    fn params(&self) -> Params {
        Params::new()
            .with("batch", 16.0)
            .with("in_len", 100.0)
            .with("out_len", 100.0)
            .with("probe_kv_len", 1024.0)
            .with("block_size", 128.0)
            .with("requests", 32.0)
            .with("rate_rps", 30.0)
            .with("seed", 31.0)
    }

    fn run(&self, params: &Params) -> Vec<Report> {
        let k = Knobs::from(params);
        let cfg = LlamaConfig::llama31_70b();
        let mut reports = Vec::new();
        // (device, per-tp points) in DEVICES order.
        let mut curves: Vec<(DeviceKind, Vec<TpPoint>)> = Vec::new();

        // Fan the flattened (device, tp) grid across the worker pool;
        // submission-ordered assembly keeps the artifact byte-identical
        // at any --jobs value.
        let grid = par::par_map_indexed(DEVICES.len() * TP_GRID.len(), |idx| {
            run_point(&k, &cfg, DEVICES[idx / TP_GRID.len()], TP_GRID[idx % TP_GRID.len()])
        });
        let mut grid_iter = grid.into_iter();

        for kind in DEVICES {
            let points: Vec<TpPoint> =
                grid_iter.by_ref().take(TP_GRID.len()).collect();
            let mut r = Report::new(format!(
                "TP sweep [{}]: {} device-group sizing and scaling",
                kind.name(),
                cfg.name
            ));
            r.header(&[
                "group",
                "weights GB/card",
                "KV tokens",
                "KV blocks",
                "fits",
                "tok/s",
                "speedup",
                "scaling eff",
                "comm share",
            ]);
            let base_tps = points[0].tps;
            for p in &points {
                let speedup = p.tps / base_tps;
                r.row(vec![
                    Cell::text(format!("tp={}", p.tp)),
                    Cell::val(p.weights_per_card / 1e9, Unit::Gigabytes),
                    Cell::count(p.kv_tokens),
                    Cell::count(p.kv_blocks),
                    Cell::count(usize::from(p.feasible)),
                    Cell::val(p.tps, Unit::TokPerSec),
                    Cell::val(speedup, Unit::Ratio),
                    Cell::val(speedup / p.tp as f64, Unit::Ratio),
                    Cell::val(p.comm_share, Unit::Percent),
                ]);
            }
            r.note(format!(
                "batch {} x {}+{} tokens; tok/s is the analytic roofline (infeasible \
                 widths priced for the curve, flagged 'fits'=0); comm share probed at \
                 kv_len {}",
                k.batch, k.in_len, k.out_len, k.probe_kv_len
            ));
            reports.push(r);
            curves.push((kind, points));
        }

        // Sized tp=4 deployments: real ClusterSim groups with budgeted KV,
        // one simulated arm per device run concurrently.
        let sized: Vec<SizedPoint> =
            par::par_map_indexed(DEVICES.len(), |i| run_sized(&k, &cfg, DEVICES[i]));
        let mut sr = Report::new("TP sweep sized deployments: tp=4 groups serving Llama-70B");
        sr.header(&["device", "KV block budget", "served", "tok/s"]);
        for p in &sized {
            sr.row(vec![
                Cell::text(p.kind.name()),
                Cell::count(p.blocks),
                Cell::count(p.completed),
                Cell::val(p.tps, Unit::TokPerSec),
            ]);
        }
        sr.note(format!(
            "one 4-card group per device, num_blocks from the group-aware budget \
             (block size {}), {} Dynamic-Sonnet requests at {} req/s",
            k.block_size, k.requests, k.rate_rps
        ));
        reports.push(sr);

        // Derived claims.
        let tps_violations: usize = curves
            .iter()
            .map(|(_, ps)| ps.windows(2).filter(|w| w[1].tps <= w[0].tps).count())
            .sum();
        let share_violations: usize = curves
            .iter()
            .map(|(_, ps)| ps.windows(2).filter(|w| w[1].comm_share <= w[0].comm_share).count())
            .sum();
        let max_scaling_eff = curves
            .iter()
            .flat_map(|(_, ps)| {
                let base = ps[0].tps;
                ps.iter()
                    .filter(|p| p.tp > 1)
                    .map(move |p| (p.tps / base) / p.tp as f64)
                    .collect::<Vec<f64>>()
            })
            .fold(0.0, f64::max);
        let tp1_fits: usize =
            curves.iter().map(|(_, ps)| usize::from(ps[0].feasible)).sum();
        let tp4_fits: usize = curves
            .iter()
            .map(|(_, ps)| usize::from(ps.iter().find(|p| p.tp == 4).unwrap().feasible))
            .sum();
        let sized_lost: usize = sized.iter().map(|p| p.submitted.abs_diff(p.completed)).sum();
        let share_at = |kind: DeviceKind| {
            curves
                .iter()
                .find(|(k2, _)| *k2 == kind)
                .and_then(|(_, ps)| ps.iter().find(|p| p.tp == 8))
                .map(|p| p.comm_share)
                .unwrap_or(0.0)
        };
        let mesh_vs_switch = share_at(DeviceKind::Gaudi2) / share_at(DeviceKind::A100);

        let mut claims = Report::new("TP-sweep derived claims");
        claims.header(&["claim", "value"]);
        claims.row(vec![
            Cell::text("tp=1 spec fleet vs legacy device fleet: max delta"),
            Cell::val(tp1_vs_legacy_delta(&k), Unit::Seconds),
        ]);
        claims.row(vec![
            Cell::text("tokens/s monotonicity violations over the grid"),
            Cell::count(tps_violations),
        ]);
        claims.row(vec![
            Cell::text("max scaling efficiency over tp>1 points"),
            Cell::val(max_scaling_eff, Unit::Ratio),
        ]);
        claims.row(vec![
            Cell::text("devices fitting 70B at tp=1"),
            Cell::count(tp1_fits),
        ]);
        claims.row(vec![
            Cell::text("devices serving 70B at tp=4"),
            Cell::count(tp4_fits),
        ]);
        claims.row(vec![
            Cell::text("sized-deployment requests lost"),
            Cell::count(sized_lost),
        ]);
        claims.row(vec![
            Cell::text("comm-share monotonicity violations over the grid"),
            Cell::count(share_violations),
        ]);
        claims.row(vec![
            Cell::text("Gaudi-2 / A100 decode comm share at tp=8"),
            Cell::val(mesh_vs_switch, Unit::Ratio),
        ]);
        claims.note(
            "width-1 groups must replay the single-device path bit-for-bit; \
             wider groups trade all-reduce overhead for sharded weights",
        );
        reports.push(claims);

        reports
    }

    fn expectations(&self, _params: &Params) -> Vec<Expectation> {
        vec![
            Expectation::new(
                "tp_sweep.tp1_parity",
                "a fleet of tp=1 replica specs is bitwise-equal to the legacy device path",
                Selector::cell(
                    "TP-sweep derived claims",
                    "tp=1 spec fleet vs legacy device fleet: max delta",
                    "value",
                ),
                Check::EqExact(0.0),
            ),
            Expectation::new(
                "tp_sweep.throughput_monotone",
                "tokens/s strictly increases with group width on both devices",
                Selector::cell(
                    "TP-sweep derived claims",
                    "tokens/s monotonicity violations over the grid",
                    "value",
                ),
                Check::EqExact(0.0),
            ),
            Expectation::new(
                "tp_sweep.sublinear_scaling",
                "scaling efficiency stays below 1.0: all-reduces make speedup sub-linear",
                Selector::cell(
                    "TP-sweep derived claims",
                    "max scaling efficiency over tp>1 points",
                    "value",
                ),
                Check::Le(1.0),
            ),
            Expectation::new(
                "tp_sweep.hbm_bound_at_tp1",
                "no single card fits Llama-70B: tp=1 is HBM-infeasible on both devices",
                Selector::cell("TP-sweep derived claims", "devices fitting 70B at tp=1", "value"),
                Check::EqExact(0.0),
            ),
            Expectation::new(
                "tp_sweep.servable_at_tp4",
                "tp=4 groups serve 70B with KV headroom on both devices",
                Selector::cell("TP-sweep derived claims", "devices serving 70B at tp=4", "value"),
                Check::EqExact(2.0),
            ),
            Expectation::new(
                "tp_sweep.sized_conservation",
                "the sized tp=4 deployments complete every submitted request",
                Selector::cell("TP-sweep derived claims", "sized-deployment requests lost", "value"),
                Check::EqExact(0.0),
            ),
            Expectation::new(
                "tp_sweep.comm_share_rises",
                "the decode collective-overhead share rises with group width",
                Selector::cell(
                    "TP-sweep derived claims",
                    "comm-share monotonicity violations over the grid",
                    "value",
                ),
                Check::EqExact(0.0),
            ),
            Expectation::new(
                "tp_sweep.mesh_pays_more",
                "Gaudi-2's mesh pays a larger decode comm share than A100's switch at tp=8",
                Selector::cell(
                    "TP-sweep derived claims",
                    "Gaudi-2 / A100 decode comm share at tp=8",
                    "value",
                ),
                Check::Ge(1.0),
            ),
        ]
    }
}

/// Run with default params (convenience for tests and library callers).
pub fn run() -> Vec<Report> {
    TpSweep.run(&TpSweep.params())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_params() -> Params {
        // A lighter simulated arm keeps the unit test quick; the full
        // default grid runs under `repro run tp-sweep` and CI.
        TpSweep.params().with("requests", 16.0).with("rate_rps", 40.0)
    }

    #[test]
    fn one_report_per_device_plus_sized_and_claims() {
        let reports = TpSweep.run(&small_params());
        assert_eq!(reports.len(), DEVICES.len() + 2);
        for (i, kind) in DEVICES.iter().enumerate() {
            assert!(reports[i].title().contains(kind.name()), "report {i} mislabeled");
            assert_eq!(reports[i].num_rows(), TP_GRID.len());
        }
        assert_eq!(reports[DEVICES.len()].num_rows(), DEVICES.len());
    }

    #[test]
    fn sizing_matches_the_sizing_helpers() {
        let k = Knobs::from(&small_params());
        let cfg = LlamaConfig::llama31_70b();
        let p1 = run_point(&k, &cfg, DeviceKind::Gaudi2, 1);
        assert!(!p1.feasible);
        assert_eq!(p1.kv_tokens, 0);
        assert_eq!(p1.comm_share, 0.0, "a width-1 group communicates nothing");
        let p4 = run_point(&k, &cfg, DeviceKind::Gaudi2, 4);
        assert!(p4.feasible && p4.kv_blocks > 1000);
        assert!(p4.tps > p1.tps);
        assert!(p4.comm_share > 0.0 && p4.comm_share < 1.0);
    }

    #[test]
    fn tp1_parity_is_exact() {
        let k = Knobs::from(&small_params());
        assert_eq!(tp1_vs_legacy_delta(&k), 0.0);
    }

    #[test]
    fn expectations_pass_on_default_grid() {
        // The full default grid is the artifact CI gates on; every
        // expectation must hold there.
        let reports = run();
        for e in TpSweep.expectations(&TpSweep.params()) {
            let res = e.evaluate(&reports);
            assert!(res.pass, "{}: {}", res.id, res.detail);
        }
    }
}
