//! Fleet-budget experiment: the paper's sizing question asked the way a
//! capacity planner would — **you have exactly eight cards per node; how
//! do you slice them?** The same 8-card budget is spent four ways
//! (8 × tp1, 4 × tp2, 2 × tp4, 1 × tp8 device groups), on both Gaudi-2
//! and A100, under three offered loads against one scalar SLO, all
//! serving Llama-3.1-70B. Each feasible (shape, device, load) point runs
//! a real [`ClusterSim`] deployment; HBM-infeasible shapes (a single
//! card cannot hold the 70B shard) are reported analytically and never
//! simulated. Grid points fan across the [`crate::util::par`] worker
//! pool; submission-ordered assembly keeps `BENCH_fleet_budget.json`
//! byte-identical at any `--jobs` value.
//!
//! The derived claims pinned by `repro run fleet-budget --check`:
//!
//! - **Card conservation**: every shape spends exactly the 8-card
//!   budget — `replicas x tp = 8` (EqExact 0 violations).
//! - **tp=1 infeasible**: no single card fits the 70B shard on either
//!   device, so the 8 × tp1 shape never serves (EqExact 0 fits).
//! - **Wide groups serve**: every tp ≥ 2 shape is HBM-feasible on both
//!   devices (EqExact 0 infeasible).
//! - **TTFT favors wide groups at light load**: with queueing out of
//!   the picture, the 1 × tp8 group's sharded prefill beats the
//!   4 × tp2 groups' p99 TTFT on both devices (EqExact 0 violations;
//!   desk-estimated ordering — recalibrate on real hardware).
//! - **Throughput favors replicas at heavy load**: sub-linear TP
//!   scaling means 4 × tp2 out-serves 1 × tp8 once the node saturates
//!   (Ge 1.0 tok/s ratio; desk-estimated — recalibrate on hardware).
//! - **Energy ledger complete**: every simulated point prices its good
//!   tokens — no feasible cell is missing a J/good-token entry
//!   (EqExact 0 missing).
//!
//! The "Fleet-budget goodput frontier" report (rows = shapes, one
//! goodput-per-card column per device at the heavy load) is the typed
//! contract `python/plot_bench.py` renders as the fleet-shape figure.

use crate::config::{DeviceKind, ReplicaSpec, ServingConfig};
use crate::harness::{Experiment, Params};
use crate::models::llama::{self, LlamaConfig};
use crate::report::{Cell, Check, Expectation, Report, Selector, Unit};
use crate::serving::cluster::ClusterSim;
use crate::serving::qos::ClassSet;
use crate::serving::router::RoutePolicy;
use crate::util::par;
use crate::workload::OpenLoopTrace;

/// The node's card budget (one HLS-Gaudi-2 or DGX A100 node).
const CARD_BUDGET: usize = 8;

/// (label, replicas, tp) — the four ways to slice eight cards.
const SHAPES: [(&str, usize, usize); 4] =
    [("8x tp1", 8, 1), ("4x tp2", 4, 2), ("2x tp4", 2, 4), ("1x tp8", 1, 8)];

const DEVICES: [DeviceKind; 2] = [DeviceKind::Gaudi2, DeviceKind::A100];

struct Knobs {
    light_rps: f64,
    mid_rps: f64,
    heavy_rps: f64,
    duration_s: f64,
    slo_ttft_s: f64,
    slo_tpot_s: f64,
    block_size: usize,
    seed: u64,
}

impl Knobs {
    fn from(params: &Params) -> Knobs {
        Knobs {
            light_rps: params.get_or("light_rps", 1.0),
            mid_rps: params.get_or("mid_rps", 3.0),
            heavy_rps: params.get_or("heavy_rps", 6.0),
            duration_s: params.get_or("duration_s", 4.0),
            slo_ttft_s: params.get_or("slo_ttft_s", 6.0),
            slo_tpot_s: params.get_or("slo_tpot_s", 0.5),
            block_size: params.get_or("block_size", 128.0) as usize,
            seed: params.get_or("seed", 47.0) as u64,
        }
    }

    fn loads(&self) -> [f64; 3] {
        [self.light_rps, self.mid_rps, self.heavy_rps]
    }

    fn classes(&self) -> ClassSet {
        ClassSet::scalar(self.slo_ttft_s, self.slo_tpot_s)
    }
}

/// One (shape, device, load) grid point. Infeasible shapes carry the
/// analytic sizing verdict and zeros everywhere else.
struct FleetPoint {
    shape: &'static str,
    replicas: usize,
    tp: usize,
    feasible: bool,
    load_rps: f64,
    submitted: usize,
    completed: usize,
    goodput_rps: f64,
    attainment: f64,
    p99_ttft: f64,
    tps: f64,
    /// `None` when the simulator produced no energy entry for the
    /// point's good tokens (claim: never happens on feasible points).
    j_per_good: Option<f64>,
}

fn infeasible_point(shape: &'static str, replicas: usize, tp: usize, load: f64) -> FleetPoint {
    FleetPoint {
        shape,
        replicas,
        tp,
        feasible: false,
        load_rps: load,
        submitted: 0,
        completed: 0,
        goodput_rps: 0.0,
        attainment: 0.0,
        p99_ttft: 0.0,
        tps: 0.0,
        j_per_good: None,
    }
}

fn run_point(
    k: &Knobs,
    cfg: &LlamaConfig,
    kind: DeviceKind,
    shape: &'static str,
    replicas: usize,
    tp: usize,
    load: f64,
) -> FleetPoint {
    // A shard that does not fit (plus one block of KV) never boots:
    // report the sizing verdict analytically instead of simulating.
    if !llama::hbm_feasible(cfg, kind, tp, k.block_size) {
        return infeasible_point(shape, replicas, tp, load);
    }
    let classes = k.classes();
    let budget = llama::kv_block_budget(cfg, kind, tp, k.block_size);
    let serving = ServingConfig {
        num_blocks: budget.min(8192),
        max_decode_batch: 8,
        route_policy: RoutePolicy::LeastLoaded,
        classes: classes.clone(),
        ..Default::default()
    }
    .with_replica_specs(vec![ReplicaSpec::new(kind, tp); replicas]);
    let mut sim = ClusterSim::new(&serving, *cfg);
    let trace = OpenLoopTrace::new(load, k.duration_s).generate(k.seed);
    let submitted = trace.len();
    sim.submit_all(trace);
    let s = sim.run_to_completion();
    let fleet = sim.fleet_metrics();
    FleetPoint {
        shape,
        replicas,
        tp,
        feasible: true,
        load_rps: load,
        submitted,
        completed: sim.completed(),
        goodput_rps: fleet.goodput(&classes),
        attainment: fleet.attainment(&classes),
        p99_ttft: s.p99_ttft,
        tps: s.throughput_tps,
        j_per_good: fleet.energy_per_good_token(&classes),
    }
}

pub struct FleetBudget;

impl Experiment for FleetBudget {
    fn id(&self) -> &'static str {
        "fleet_budget"
    }

    fn title(&self) -> &'static str {
        "Fleet budget: slicing 8 cards into 8x tp1 / 4x tp2 / 2x tp4 / 1x tp8 for Llama-70B"
    }

    fn params(&self) -> Params {
        Params::new()
            .with("light_rps", 1.0)
            .with("mid_rps", 3.0)
            .with("heavy_rps", 6.0)
            .with("duration_s", 4.0)
            .with("slo_ttft_s", 6.0)
            .with("slo_tpot_s", 0.5)
            .with("block_size", 128.0)
            .with("seed", 47.0)
    }

    fn run(&self, params: &Params) -> Vec<Report> {
        let k = Knobs::from(params);
        let cfg = LlamaConfig::llama31_70b();
        let loads = k.loads();
        let mut reports = Vec::new();

        // Flattened (device, shape, load) grid fanned across the worker
        // pool; assembly order is the nesting order below, so the
        // artifact is byte-identical at any --jobs value.
        let per_device = SHAPES.len() * loads.len();
        let grid = par::par_map_indexed(DEVICES.len() * per_device, |idx| {
            let (shape, replicas, tp) = SHAPES[(idx % per_device) / loads.len()];
            run_point(
                &k,
                &cfg,
                DEVICES[idx / per_device],
                shape,
                replicas,
                tp,
                loads[idx % loads.len()],
            )
        });
        let mut grid_iter = grid.into_iter();
        // (device, points in shape-major, load-minor order).
        let mut panels: Vec<(DeviceKind, Vec<FleetPoint>)> = Vec::new();

        for kind in DEVICES {
            let points: Vec<FleetPoint> = grid_iter.by_ref().take(per_device).collect();
            let mut r = Report::new(format!(
                "Fleet budget [{}]: {}-card shapes serving {}",
                kind.name(),
                CARD_BUDGET,
                cfg.name
            ));
            r.header(&[
                "shape",
                "cards",
                "fits",
                "offered rps",
                "submitted",
                "served",
                "goodput",
                "goodput/card",
                "attainment",
                "p99 ttft",
                "tok/s",
                "J/good tok",
            ]);
            for p in &points {
                r.row(vec![
                    Cell::text(p.shape),
                    Cell::count(p.replicas * p.tp),
                    Cell::count(usize::from(p.feasible)),
                    Cell::val(p.load_rps, Unit::ReqPerSec),
                    Cell::count(p.submitted),
                    Cell::count(p.completed),
                    Cell::val(p.goodput_rps, Unit::ReqPerSec),
                    Cell::val(p.goodput_rps / CARD_BUDGET as f64, Unit::ReqPerSec),
                    Cell::val(p.attainment, Unit::Percent),
                    Cell::val(p.p99_ttft, Unit::Seconds),
                    Cell::val(p.tps, Unit::TokPerSec),
                    Cell::val(p.j_per_good.unwrap_or(-1.0), Unit::JoulePerTok),
                ]);
            }
            r.note(format!(
                "open-loop trace, {}s at each load (seed {}); scalar SLO ttft<={}s, \
                 tpot<={}s; 'fits'=0 rows are HBM-infeasible and reported analytically \
                 (never simulated); J/good tok = -1 marks a missing energy entry",
                k.duration_s, k.seed, k.slo_ttft_s, k.slo_tpot_s
            ));
            reports.push(r);
            panels.push((kind, points));
        }

        // Frontier: goodput per card at the heavy load — the plot
        // contract for python/plot_bench.py's fleet-shape figure.
        let heavy_of = |points: &[FleetPoint], shape: &str| {
            points
                .iter()
                .find(|p| p.shape == shape && p.load_rps == k.heavy_rps)
                .map(|p| p.goodput_rps / CARD_BUDGET as f64)
                .unwrap_or(0.0)
        };
        let mut fr = Report::new("Fleet-budget goodput frontier");
        let headers: Vec<String> = std::iter::once("shape".to_string())
            .chain(DEVICES.iter().map(|d| format!("{} goodput/card", d.name())))
            .collect();
        fr.header(&headers.iter().map(String::as_str).collect::<Vec<_>>());
        for (shape, _, _) in SHAPES {
            let mut row = vec![Cell::text(shape)];
            for (_, points) in &panels {
                row.push(Cell::val(heavy_of(points, shape), Unit::ReqPerSec));
            }
            fr.row(row);
        }
        fr.note(format!(
            "SLO-compliant completions per second per card at the heavy load \
             ({} req/s); infeasible shapes score 0",
            k.heavy_rps
        ));
        reports.push(fr);

        // Derived claims.
        let all: Vec<&FleetPoint> =
            panels.iter().flat_map(|(_, ps)| ps.iter()).collect();
        let budget_violations =
            all.iter().filter(|p| p.replicas * p.tp != CARD_BUDGET).count();
        let tp1_fits = all.iter().filter(|p| p.tp == 1 && p.feasible).count();
        let wide_infeasible = all.iter().filter(|p| p.tp >= 2 && !p.feasible).count();
        let ttft_violations = panels
            .iter()
            .filter(|(_, ps)| {
                let at = |shape: &str| {
                    ps.iter()
                        .find(|p| p.shape == shape && p.load_rps == k.light_rps)
                        .map(|p| p.p99_ttft)
                        .unwrap_or(0.0)
                };
                at("1x tp8") > at("4x tp2")
            })
            .count();
        let heavy_tps = |points: &[FleetPoint], shape: &str| {
            points
                .iter()
                .find(|p| p.shape == shape && p.load_rps == k.heavy_rps)
                .map(|p| p.tps)
                .unwrap_or(0.0)
        };
        let replica_ratio = panels
            .iter()
            .map(|(_, ps)| heavy_tps(ps, "4x tp2") / heavy_tps(ps, "1x tp8"))
            .fold(f64::INFINITY, f64::min);
        let energy_missing =
            all.iter().filter(|p| p.feasible && p.j_per_good.is_none()).count();

        let mut claims = Report::new("Fleet-budget derived claims");
        claims.header(&["claim", "value"]);
        claims.row(vec![
            Cell::text("card budget violations over the grid"),
            Cell::count(budget_violations),
        ]);
        claims.row(vec![
            Cell::text("grid points serving 70B at tp=1"),
            Cell::count(tp1_fits),
        ]);
        claims.row(vec![
            Cell::text("infeasible grid points among tp>=2 shapes"),
            Cell::count(wide_infeasible),
        ]);
        claims.row(vec![
            Cell::text("devices where 1x tp8 p99 TTFT exceeds 4x tp2 at light load"),
            Cell::count(ttft_violations),
        ]);
        claims.row(vec![
            Cell::text("min 4x tp2 / 1x tp8 tok/s ratio at heavy load"),
            Cell::val(replica_ratio, Unit::Ratio),
        ]);
        claims.row(vec![
            Cell::text("feasible grid points missing a J/good-token entry"),
            Cell::count(energy_missing),
        ]);
        claims.note(
            "same 8-card budget every row; TTFT-ordering and tok/s-ratio \
             thresholds are desk estimates from the analytic roofline — \
             recalibrate on real hardware",
        );
        reports.push(claims);

        reports
    }

    fn expectations(&self, _params: &Params) -> Vec<Expectation> {
        vec![
            Expectation::new(
                "fleet_budget.cards_conserved",
                "every fleet shape spends exactly the 8-card budget",
                Selector::cell(
                    "Fleet-budget derived claims",
                    "card budget violations over the grid",
                    "value",
                ),
                Check::EqExact(0.0),
            ),
            Expectation::new(
                "fleet_budget.tp1_infeasible_70b",
                "no single card fits Llama-70B: the 8x tp1 shape never serves",
                Selector::cell(
                    "Fleet-budget derived claims",
                    "grid points serving 70B at tp=1",
                    "value",
                ),
                Check::EqExact(0.0),
            ),
            Expectation::new(
                "fleet_budget.wide_groups_serve",
                "every tp>=2 shape is HBM-feasible on both devices",
                Selector::cell(
                    "Fleet-budget derived claims",
                    "infeasible grid points among tp>=2 shapes",
                    "value",
                ),
                Check::EqExact(0.0),
            ),
            Expectation::new(
                "fleet_budget.ttft_favors_wide_groups",
                "at light load the 1x tp8 group's sharded prefill beats 4x tp2 p99 TTFT",
                Selector::cell(
                    "Fleet-budget derived claims",
                    "devices where 1x tp8 p99 TTFT exceeds 4x tp2 at light load",
                    "value",
                ),
                Check::EqExact(0.0),
            ),
            Expectation::new(
                "fleet_budget.throughput_favors_replicas",
                "at heavy load 4x tp2 out-serves 1x tp8: sub-linear TP scaling",
                Selector::cell(
                    "Fleet-budget derived claims",
                    "min 4x tp2 / 1x tp8 tok/s ratio at heavy load",
                    "value",
                ),
                Check::Ge(1.0),
            ),
            Expectation::new(
                "fleet_budget.energy_ledger_complete",
                "every simulated point prices its good tokens",
                Selector::cell(
                    "Fleet-budget derived claims",
                    "feasible grid points missing a J/good-token entry",
                    "value",
                ),
                Check::EqExact(0.0),
            ),
        ]
    }
}

/// Run with default params (convenience for tests and library callers).
pub fn run() -> Vec<Report> {
    FleetBudget.run(&FleetBudget.params())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_params() -> Params {
        // Shorter trace keeps the unit-test grid quick; the full default
        // grid runs under `repro run fleet-budget` and CI.
        FleetBudget
            .params()
            .with("duration_s", 2.0)
            .with("heavy_rps", 4.0)
    }

    #[test]
    fn one_report_per_device_plus_frontier_and_claims() {
        let reports = FleetBudget.run(&small_params());
        assert_eq!(reports.len(), DEVICES.len() + 2);
        for (i, kind) in DEVICES.iter().enumerate() {
            assert!(reports[i].title().contains(kind.name()), "report {i} mislabeled");
            assert_eq!(reports[i].num_rows(), SHAPES.len() * 3);
        }
        let frontier = &reports[DEVICES.len()];
        assert_eq!(frontier.num_rows(), SHAPES.len());
    }

    #[test]
    fn every_shape_spends_the_whole_budget() {
        for (_, replicas, tp) in SHAPES {
            assert_eq!(replicas * tp, CARD_BUDGET);
        }
    }

    #[test]
    fn tp1_is_reported_analytically_not_simulated() {
        let k = Knobs::from(&small_params());
        let cfg = LlamaConfig::llama31_70b();
        for kind in DEVICES {
            let p = run_point(&k, &cfg, kind, "8x tp1", 8, 1, k.light_rps);
            assert!(!p.feasible, "{}: 70B must not fit one card", kind.name());
            assert_eq!(p.submitted, 0, "infeasible shapes must skip the sim");
        }
    }

    #[test]
    fn feasible_points_serve_and_price_their_tokens() {
        let k = Knobs::from(&small_params());
        let cfg = LlamaConfig::llama31_70b();
        let p = run_point(&k, &cfg, DeviceKind::Gaudi2, "2x tp4", 2, 4, k.light_rps);
        assert!(p.feasible);
        assert!(p.submitted > 0 && p.completed == p.submitted);
        assert!(p.tps > 0.0);
        assert!(p.j_per_good.is_some(), "energy ledger must cover the point");
    }

    #[test]
    fn expectations_pass_on_default_grid() {
        // The full default grid is the artifact CI gates on; every
        // expectation must hold there.
        let reports = run();
        for e in FleetBudget.expectations(&FleetBudget.params()) {
            let res = e.evaluate(&reports);
            assert!(res.pass, "{}: {}", res.id, res.detail);
        }
    }
}
