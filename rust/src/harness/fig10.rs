//! Fig 10: bus-bandwidth utilization of the six collectives (HCCL vs
//! NCCL), payloads 2 KB – 32 MB, 2/4/8 participating devices.

use crate::config::DeviceKind;
use crate::sim::collective::{self, ALL_COLLECTIVES};
use crate::util::table::{fmt_pct, Report};
use crate::util::units::{fmt_bytes, KIB, MIB};

pub fn run() -> Vec<Report> {
    let sizes = [2.0 * KIB, 32.0 * KIB, 512.0 * KIB, 2.0 * MIB, 32.0 * MIB];
    let mut out = Vec::new();
    for coll in ALL_COLLECTIVES {
        let mut r = Report::new(format!("Fig 10: {} bus bandwidth utilization", coll.name()));
        r.header(&["size", "G-2dev", "G-4dev", "G-8dev", "A-2dev", "A-4dev", "A-8dev"]);
        for &s in &sizes {
            let mut row = vec![fmt_bytes(s)];
            for kind in [DeviceKind::Gaudi2, DeviceKind::A100] {
                for n in [2usize, 4, 8] {
                    row.push(fmt_pct(collective::run(kind, coll, n, s).utilization));
                }
            }
            r.row(row);
        }
        let g8 = collective::run(DeviceKind::Gaudi2, coll, 8, 32.0 * MIB).utilization;
        let a8 = collective::run(DeviceKind::A100, coll, 8, 32.0 * MIB).utilization;
        r.note(format!(
            "at 8 devices / 32 MiB: Gaudi {} vs A100 {} -> {}",
            fmt_pct(g8),
            fmt_pct(a8),
            if g8 > a8 { "Gaudi wins" } else { "A100 wins" }
        ));
        out.push(r);
    }
    vec![merge(out)]
}

/// The paper presents the six collectives as one figure; merge the panels
/// under one report for `repro run fig10`.
fn merge(panels: Vec<Report>) -> Report {
    let mut all = Report::new("Fig 10: collective communication (6 panels)");
    all.header(&["panel"]);
    for p in panels {
        all.row(vec![p.render()]);
    }
    all
}

#[cfg(test)]
mod tests {
    #[test]
    fn six_panels_and_gaudi_wins_five() {
        let reports = super::run();
        let text = reports[0].render();
        let gaudi_wins = text.matches("Gaudi wins").count();
        let a100_wins = text.matches("A100 wins").count();
        assert_eq!(gaudi_wins, 5, "{text}");
        assert_eq!(a100_wins, 1);
    }
}
