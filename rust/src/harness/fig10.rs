//! Fig 10: bus-bandwidth utilization of the six collectives (HCCL vs
//! NCCL), payloads 2 KB – 32 MB, 2/4/8 participating devices — one typed
//! report per collective plus a winners summary at the 8-device / 32 MiB
//! headline point.

use crate::config::DeviceKind;
use crate::harness::{Experiment, Params};
use crate::report::{Agg, Cell, Check, Expectation, Report, Selector, Unit};
use crate::sim::collective::{CollectiveModel, ALL_COLLECTIVES};
use crate::util::units::{KIB, MIB};

pub struct Fig10;

impl Experiment for Fig10 {
    fn id(&self) -> &'static str {
        "fig10"
    }

    fn title(&self) -> &'static str {
        "Fig 10: collective communication bus bandwidth"
    }

    fn run(&self, _params: &Params) -> Vec<Report> {
        let sizes = [2.0 * KIB, 32.0 * KIB, 512.0 * KIB, 2.0 * MIB, 32.0 * MIB];
        let headline = 32.0 * MIB;
        let mut out = Vec::new();
        // Winners at the paper's headline point (8 devices, 32 MiB),
        // captured from the same simulator calls that fill the panels.
        let mut winners = Report::new("Fig 10 summary: winners at 8 devices / 32 MiB");
        winners.header(&["collective", "Gaudi-2", "A100", "Gaudi wins"]);
        for coll in ALL_COLLECTIVES {
            let mut r = Report::new(format!("Fig 10: {} bus bandwidth utilization", coll.name()));
            r.header(&["size", "G-2dev", "G-4dev", "G-8dev", "A-2dev", "A-4dev", "A-8dev"]);
            let (mut g8, mut a8) = (0.0f64, 0.0f64);
            for &s in &sizes {
                let mut row = vec![Cell::val(s, Unit::Bytes)];
                for kind in [DeviceKind::Gaudi2, DeviceKind::A100] {
                    // The same unified model the serving path prices its
                    // tensor-parallel all-reduces through.
                    let model = CollectiveModel::for_device(kind);
                    for n in [2usize, 4, 8] {
                        let util = model.run(coll, n, s).utilization;
                        if n == 8 && s == headline {
                            match kind {
                                DeviceKind::Gaudi2 => g8 = util,
                                DeviceKind::A100 => a8 = util,
                            }
                        }
                        row.push(Cell::val(util, Unit::Percent));
                    }
                }
                r.row(row);
            }
            out.push(r);
            winners.row(vec![
                Cell::text(coll.name()),
                Cell::val(g8, Unit::Percent),
                Cell::val(a8, Unit::Percent),
                Cell::count(usize::from(g8 > a8)),
            ]);
        }
        winners.note("paper: the P2P mesh wins 5 of 6 collectives at scale");
        out.push(winners);
        out
    }

    fn expectations(&self, _params: &Params) -> Vec<Expectation> {
        vec![Expectation::new(
            "fig10.gaudi_wins_five_of_six",
            "Gaudi-2 wins 5 of the 6 collectives at 8 devices / 32 MiB",
            Selector::column("Fig 10 summary", "Gaudi wins", Agg::Sum),
            Check::EqExact(5.0),
        )]
    }
}

/// Run with default params (convenience for tests and library callers).
pub fn run() -> Vec<Report> {
    Fig10.run(&Fig10.params())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn six_panels_and_gaudi_wins_five() {
        let reports = run();
        assert_eq!(reports.len(), 7, "six collectives + winners summary");
        let wins = reports[6].series("Gaudi wins").unwrap();
        assert_eq!(wins.sum(), 5.0);
        assert_eq!(wins.values.len(), 6);
    }

    #[test]
    fn expectations_pass() {
        let reports = run();
        for e in Fig10.expectations(&Fig10.params()) {
            let res = e.evaluate(&reports);
            assert!(res.pass, "{}: {}", res.id, res.detail);
        }
    }
}
