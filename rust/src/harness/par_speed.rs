//! Par-speed experiment: the parallel executor benchmarking itself. A
//! fixed bundle of cheap analytic experiments (the paper tables/figures
//! — no sweeps, no [`crate::harness::sim_speed`], and never this
//! experiment) is run twice over the *same* registry entries: once
//! pinned to one worker (`with_jobs(1)`) and once fanned across the
//! machine (`with_jobs(available_jobs())`). Each pass dumps every
//! experiment's full JSON artifact; the two dump sets are compared
//! byte-for-byte.
//!
//! Two claims come out (`repro run par-speed --check`):
//!
//! - **Jobs-invariance** (`par_speed.jobs_invariance`): zero byte
//!   mismatches between the serial and parallel dumps — the executor's
//!   submission-ordered assembly means worker count can never leak into
//!   an artifact (EqExact 0). This is the headline invariant behind
//!   `repro run all --jobs N`.
//! - **Speedup** (`par_speed.speedup`): the parallel pass beats the
//!   serial pass's wall-clock by `min_speedup` (default 1.2x, a desk
//!   estimate — `--param min_speedup=K` to recalibrate; trivially 0
//!   when the machine reports a single core, where no speedup exists).
//!
//! The bundle deliberately does NOT recurse into `repro run all`: that
//! would re-run every sweep (minutes of sim inside one experiment) and
//! nest the pool against itself. Twelve analytic experiments give the
//! pool real, unequal-cost work at a cost CI can afford.
//!
//! Wall-clock cells make `BENCH_par_speed.json` machine-dependent by
//! design (like `BENCH_sim_speed.json`); the bench-diff gate tracks its
//! claims, not its bytes.

use std::time::Instant;

use crate::harness::{self, Experiment, Params};
use crate::report::{Cell, Check, Expectation, Report, Selector, Unit};
use crate::util::par;

/// The benchmarked bundle: every analytic table/figure experiment —
/// cheap, deterministic, and wall-clock-free.
const BUNDLE: [&str; 12] = [
    "table1", "fig4", "fig5", "fig7", "fig8", "fig9", "fig10", "fig11", "fig12", "fig13",
    "fig15", "fig17",
];

/// One timed pass over the bundle: per-experiment artifact dumps (in
/// BUNDLE order) plus the wall-clock the pass took.
struct Pass {
    dumps: Vec<String>,
    wall_s: f64,
}

fn dump_one(id: &str) -> String {
    let e = harness::find(id).expect("bundle ids must stay in the registry");
    let params = e.params();
    let reports = e.run(&params);
    let results = harness::evaluate(e.as_ref(), &params, &reports);
    harness::artifact_json(e.as_ref(), &params, &reports, &results).dump()
}

fn run_pass(jobs: usize) -> Pass {
    par::with_jobs(jobs, || {
        let t = Instant::now();
        let dumps = par::par_map_indexed(BUNDLE.len(), |i| dump_one(BUNDLE[i]));
        Pass { dumps, wall_s: t.elapsed().as_secs_f64() }
    })
}

/// Two trials, fastest wall kept (standard timing-noise reducer; the
/// dumps are deterministic, so either trial's set is THE set).
fn best_of_two(jobs: usize) -> Pass {
    let first = run_pass(jobs);
    let second = run_pass(jobs);
    Pass { dumps: first.dumps, wall_s: first.wall_s.min(second.wall_s) }
}

pub struct ParSpeed;

impl Experiment for ParSpeed {
    fn id(&self) -> &'static str {
        "par_speed"
    }

    fn title(&self) -> &'static str {
        "Par speed: parallel-executor self-benchmark and jobs-invariance check"
    }

    fn params(&self) -> Params {
        // Desk estimate pending hardware recalibration: even two workers
        // should clear 1.2x on twelve unequal-cost analytic experiments.
        Params::new().with("min_speedup", 1.2)
    }

    fn run(&self, _params: &Params) -> Vec<Report> {
        let jobs = par::available_jobs();
        let serial = best_of_two(1);
        let parallel = best_of_two(jobs);
        let mismatches = serial
            .dumps
            .iter()
            .zip(&parallel.dumps)
            .filter(|(a, b)| a != b)
            .count();
        let speedup = serial.wall_s / parallel.wall_s.max(1e-9);

        let mut bench = Report::new("Parallel-executor self-benchmark");
        bench.header(&["pass", "jobs", "experiments", "wall s"]);
        bench.row(vec![
            Cell::text("serial"),
            Cell::count(1),
            Cell::count(BUNDLE.len()),
            Cell::val(serial.wall_s, Unit::Seconds),
        ]);
        bench.row(vec![
            Cell::text("parallel"),
            Cell::count(jobs),
            Cell::count(BUNDLE.len()),
            Cell::val(parallel.wall_s, Unit::Seconds),
        ]);
        bench.note(
            "same registry entries, same params, dumped to full JSON artifacts in \
             both passes; wall-clock cells are machine-dependent by design",
        );

        let mut claims = Report::new("Par-speed derived claims");
        claims.header(&["claim", "value"]);
        claims.row(vec![
            Cell::text("artifact byte mismatches between serial and parallel passes"),
            Cell::count(mismatches),
        ]);
        claims.row(vec![
            Cell::text("parallel speedup over serial"),
            Cell::val(speedup, Unit::Ratio),
        ]);
        claims.note(format!(
            "bundle: the {} analytic table/figure experiments; sweeps and timing \
             experiments are excluded so the self-benchmark stays cheap",
            BUNDLE.len()
        ));

        vec![bench, claims]
    }

    fn expectations(&self, params: &Params) -> Vec<Expectation> {
        // No parallelism, no speedup to claim: make the timing check
        // trivially true on single-core machines.
        let min_speedup =
            if par::available_jobs() < 2 { 0.0 } else { params.get_or("min_speedup", 1.2) };
        vec![
            Expectation::new(
                "par_speed.jobs_invariance",
                "serial and parallel passes dump byte-identical artifacts",
                Selector::cell(
                    "Par-speed derived claims",
                    "artifact byte mismatches between serial and parallel passes",
                    "value",
                ),
                Check::EqExact(0.0),
            ),
            Expectation::new(
                "par_speed.speedup",
                "the parallel pass beats serial wall-clock by the min_speedup factor \
                 (default 1.2x, `--param min_speedup=K` to recalibrate)",
                Selector::cell(
                    "Par-speed derived claims",
                    "parallel speedup over serial",
                    "value",
                ),
                Check::Ge(min_speedup),
            ),
        ]
    }
}

/// Run with default params (convenience for tests and library callers).
pub fn run() -> Vec<Report> {
    ParSpeed.run(&ParSpeed.params())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bundle_ids_resolve_and_exclude_recursive_or_slow_entries() {
        for id in BUNDLE {
            assert!(harness::find(id).is_some(), "bundle id {id} missing from registry");
            assert!(!id.contains("sweep"), "{id}: sweeps are too slow for the bundle");
            assert_ne!(id, "sim_speed");
            assert_ne!(id, "par_speed", "the self-benchmark must not recurse");
            assert_ne!(id, "cluster");
        }
    }

    #[test]
    fn serial_and_parallel_passes_dump_identical_artifacts() {
        let serial = run_pass(1);
        let parallel = run_pass(4);
        assert_eq!(serial.dumps.len(), BUNDLE.len());
        for (i, (a, b)) in serial.dumps.iter().zip(&parallel.dumps).enumerate() {
            assert_eq!(a, b, "bundle entry {} ({}) is not jobs-invariant", i, BUNDLE[i]);
        }
    }

    #[test]
    fn jobs_invariance_claim_passes_and_speedup_threshold_follows_param() {
        // The timing claim is skipped here (CI machines make wall-clock
        // assertions flaky — same policy as sim_speed's tests); the
        // structural claim must hold.
        let reports = run();
        let exps = ParSpeed.expectations(&ParSpeed.params());
        let invariance = exps
            .iter()
            .find(|e| e.id == "par_speed.jobs_invariance")
            .expect("jobs-invariance claim registered");
        let res = invariance.evaluate(&reports);
        assert!(res.pass, "{}: {}", res.id, res.detail);

        if par::available_jobs() >= 2 {
            let exps = ParSpeed.expectations(&ParSpeed.params().with("min_speedup", 2.5));
            let speedup =
                exps.iter().find(|e| e.id == "par_speed.speedup").expect("speedup claim");
            assert_eq!(speedup.check, Check::Ge(2.5));
        }
    }
}
