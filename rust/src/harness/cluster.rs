//! Cluster experiment: the paper's Fig 17(d,e) serving comparison lifted
//! to deployment scale. A fixed open-loop Dynamic-Sonnet-like offered
//! load is served by fleets of 1/2/4 engine replicas per device
//! (Gaudi-2 vs A100) under two router policies; the sweep reports fleet
//! throughput, tail latency and goodput-under-SLO, then derives the
//! iso-SLO sizing table: the smallest replica count per (device, policy)
//! that meets the SLO — the "how many Gaudi-2 replace my A100s" question.

use crate::config::{DeviceKind, ServingConfig};
use crate::models::llama::LlamaConfig;
use crate::serving::cluster::ClusterSim;
use crate::serving::router::RoutePolicy;
use crate::util::table::{fmt3, Report};
use crate::workload::OpenLoopTrace;

/// Offered load shared by every fleet in the sweep.
const RATE_RPS: f64 = 24.0;
const DURATION_S: f64 = 4.0;
const SEED: u64 = 29;

/// The SLO used for the sizing table (p99 TTFT / p99 TPOT).
const SLO_TTFT_S: f64 = 1.0;
const SLO_TPOT_S: f64 = 0.1;

const REPLICA_SWEEP: [usize; 3] = [1, 2, 4];
const POLICIES: [RoutePolicy; 2] = [RoutePolicy::RoundRobin, RoutePolicy::LeastLoaded];

/// One fleet run's reported numbers.
struct FleetPoint {
    device: DeviceKind,
    policy: RoutePolicy,
    replicas: usize,
    tps: f64,
    p99_ttft: f64,
    p99_tpot: f64,
    goodput_rps: f64,
    attainment: f64,
    requeues: u64,
}

fn run_fleet(device: DeviceKind, policy: RoutePolicy, replicas: usize) -> FleetPoint {
    let cfg = ServingConfig {
        device,
        replicas,
        route_policy: policy,
        max_decode_batch: 32,
        num_blocks: 8192,
        ..Default::default()
    };
    let mut sim = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
    sim.submit_all(OpenLoopTrace::new(RATE_RPS, DURATION_S).generate(SEED));
    let s = sim.run_to_completion();
    let fleet = sim.fleet_metrics();
    FleetPoint {
        device,
        policy,
        replicas,
        tps: s.throughput_tps,
        p99_ttft: s.p99_ttft,
        p99_tpot: s.p99_tpot,
        goodput_rps: fleet.goodput_under_slo(SLO_TTFT_S, SLO_TPOT_S),
        attainment: fleet.slo_attainment(SLO_TTFT_S, SLO_TPOT_S),
        requeues: sim.requeues,
    }
}

pub fn run() -> Vec<Report> {
    let mut points: Vec<FleetPoint> = Vec::new();
    for device in [DeviceKind::Gaudi2, DeviceKind::A100] {
        for policy in POLICIES {
            for replicas in REPLICA_SWEEP {
                points.push(run_fleet(device, policy, replicas));
            }
        }
    }

    let mut sweep = Report::new(format!(
        "Cluster sweep: {RATE_RPS} req/s open-loop Dynamic-Sonnet, Llama-3.1-8B \
         (SLO: p99 TTFT <= {SLO_TTFT_S}s, p99 TPOT <= {SLO_TPOT_S}s)"
    ));
    sweep.header(&[
        "device",
        "policy",
        "replicas",
        "tok/s",
        "p99 TTFT s",
        "p99 TPOT s",
        "goodput req/s",
        "SLO attain",
        "requeues",
    ]);
    for p in &points {
        sweep.row(vec![
            p.device.name().to_string(),
            p.policy.name().to_string(),
            p.replicas.to_string(),
            fmt3(p.tps),
            fmt3(p.p99_ttft),
            fmt3(p.p99_tpot),
            fmt3(p.goodput_rps),
            fmt3(p.attainment),
            p.requeues.to_string(),
        ]);
    }
    sweep.note("goodput = SLO-compliant completions / fleet makespan");

    // Iso-SLO sizing: smallest replica count meeting the SLO on >= 99% of
    // requests, per (device, policy).
    let mut iso = Report::new("Iso-SLO replica counts: Gaudi-2 vs A100");
    iso.header(&["policy", "Gaudi-2 replicas", "A100 replicas", "ratio G2/A100"]);
    for policy in POLICIES {
        let min_for = |device: DeviceKind| -> Option<usize> {
            REPLICA_SWEEP
                .iter()
                .copied()
                .find(|&r| {
                    points
                        .iter()
                        .any(|p| {
                            p.device == device
                                && p.policy == policy
                                && p.replicas == r
                                && p.attainment >= 0.99
                        })
                })
        };
        let fmt_min = |m: Option<usize>| match m {
            Some(r) => r.to_string(),
            None => format!(">{}", REPLICA_SWEEP[REPLICA_SWEEP.len() - 1]),
        };
        let g = min_for(DeviceKind::Gaudi2);
        let a = min_for(DeviceKind::A100);
        let ratio = match (g, a) {
            (Some(g), Some(a)) => format!("{:.2}", g as f64 / a as f64),
            _ => "n/a".to_string(),
        };
        iso.row(vec![policy.name().to_string(), fmt_min(g), fmt_min(a), ratio]);
    }
    iso.note(format!(
        "smallest fleet with >= 99% of requests meeting p99-style SLO \
         (TTFT <= {SLO_TTFT_S}s, TPOT <= {SLO_TPOT_S}s) at {RATE_RPS} req/s"
    ));

    vec![sweep, iso]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_reports_with_full_grids() {
        let reports = run();
        assert_eq!(reports.len(), 2);
        // 2 devices x 2 policies x 3 replica counts.
        assert_eq!(reports[0].num_rows(), 12);
        // One sizing row per policy.
        assert_eq!(reports[1].num_rows(), POLICIES.len());
    }

    #[test]
    fn scaling_helps_the_fleet() {
        let one = run_fleet(DeviceKind::Gaudi2, RoutePolicy::RoundRobin, 1);
        let four = run_fleet(DeviceKind::Gaudi2, RoutePolicy::RoundRobin, 4);
        assert!(four.p99_ttft <= one.p99_ttft, "{} vs {}", four.p99_ttft, one.p99_ttft);
        assert!(four.attainment >= one.attainment);
    }
}
