//! Cluster experiment: the paper's Fig 17(d,e) serving comparison lifted
//! to deployment scale. A fixed open-loop Dynamic-Sonnet-like offered
//! load is served by fleets of 1/2/4 engine replicas per device
//! (Gaudi-2 vs A100) under two router policies; the sweep reports fleet
//! throughput, tail latency and goodput-under-SLO, then derives the
//! iso-SLO sizing table: the smallest replica count per (device, policy)
//! that meets the SLO — the "how many Gaudi-2 replace my A100s" question.
//! A derived-claims report carries the 1-replica-equals-single-engine
//! parity deltas (checked bitwise by `--check`) and the tail-latency
//! scaling ratio.

use crate::config::{DeviceKind, ServingConfig};
use crate::harness::{Experiment, Params};
use crate::models::llama::LlamaConfig;
use crate::report::{Cell, Check, Expectation, Report, Selector, Unit};
use crate::serving::cluster::ClusterSim;
use crate::serving::engine::{Engine, SimBackend};
use crate::serving::qos::ClassSet;
use crate::serving::router::RoutePolicy;
use crate::workload::{DynamicSonnet, OpenLoopTrace};

const REPLICA_SWEEP: [usize; 3] = [1, 2, 4];
const POLICIES: [RoutePolicy; 2] = [RoutePolicy::RoundRobin, RoutePolicy::LeastLoaded];

/// One fleet run's reported numbers.
struct FleetPoint {
    device: DeviceKind,
    policy: RoutePolicy,
    replicas: usize,
    tps: f64,
    p99_ttft: f64,
    p99_tpot: f64,
    goodput_rps: f64,
    attainment: f64,
    requeues: u64,
}

struct Knobs {
    rate_rps: f64,
    duration_s: f64,
    seed: u64,
    slo_ttft_s: f64,
    slo_tpot_s: f64,
}

impl Knobs {
    fn from(params: &Params) -> Knobs {
        Knobs {
            rate_rps: params.get_or("rate_rps", 24.0),
            duration_s: params.get_or("duration_s", 4.0),
            seed: params.get_or("seed", 29.0) as u64,
            slo_ttft_s: params.get_or("slo_ttft_s", 1.0),
            slo_tpot_s: params.get_or("slo_tpot_s", 0.1),
        }
    }

    /// The scalar SLO params as a single traffic class (`serving::qos`).
    fn classes(&self) -> ClassSet {
        ClassSet::scalar(self.slo_ttft_s, self.slo_tpot_s)
    }
}

fn run_fleet(k: &Knobs, device: DeviceKind, policy: RoutePolicy, replicas: usize) -> FleetPoint {
    let cfg = ServingConfig {
        device,
        replicas,
        route_policy: policy,
        max_decode_batch: 32,
        num_blocks: 8192,
        ..Default::default()
    };
    let mut sim = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
    sim.submit_all(OpenLoopTrace::new(k.rate_rps, k.duration_s).generate(k.seed));
    let s = sim.run_to_completion();
    let fleet = sim.fleet_metrics();
    FleetPoint {
        device,
        policy,
        replicas,
        tps: s.throughput_tps,
        p99_ttft: s.p99_ttft,
        p99_tpot: s.p99_tpot,
        goodput_rps: fleet.goodput(&k.classes()),
        attainment: fleet.attainment(&k.classes()),
        requeues: sim.requeues,
    }
}

/// Max absolute per-request metric delta (TTFT/TPOT/E2E) over *paired*
/// requests, makespan/step-count deltas, requests compared, and the
/// count of pairing mismatches between a 1-replica cluster and a bare
/// engine on the same trace — all zero iff the cluster replays the
/// exact step sequence. Every value stays finite so the JSON artifact
/// remains valid evidence even when parity regresses.
fn parity_deltas() -> (f64, f64, u64, usize, usize) {
    let cfg = ServingConfig {
        replicas: 1,
        num_blocks: 8192,
        max_decode_batch: 32,
        ..Default::default()
    };
    let trace = || DynamicSonnet::default().generate(40, 30.0, 42);

    let backend = SimBackend::new(LlamaConfig::llama31_8b(), &cfg);
    let mut engine = Engine::new(cfg.clone(), backend);
    for r in trace() {
        engine.submit(r);
    }
    engine.run_to_completion();

    let mut sim = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
    sim.submit_all(trace());
    sim.run_to_completion();
    let fleet = sim.fleet_metrics();

    let mut max_delta = 0.0f64;
    let mut mismatches = engine.metrics.len().abs_diff(fleet.len());
    for m in engine.metrics.per_request() {
        match fleet.per_request().iter().find(|f| f.id == m.id) {
            Some(f) => {
                max_delta = max_delta
                    .max((m.ttft - f.ttft).abs())
                    .max((m.tpot - f.tpot).abs())
                    .max((m.e2e - f.e2e).abs());
            }
            None => mismatches += 1,
        }
    }
    let makespan_delta = (engine.metrics.makespan - fleet.makespan).abs();
    let steps_delta = engine.steps_executed().abs_diff(sim.replica(0).steps_executed());
    (max_delta, makespan_delta, steps_delta, engine.metrics.len(), mismatches)
}

pub struct Cluster;

impl Experiment for Cluster {
    fn id(&self) -> &'static str {
        "cluster"
    }

    fn title(&self) -> &'static str {
        "Cluster: iso-SLO replica sizing, Gaudi-2 vs A100 (multi-replica serving)"
    }

    fn params(&self) -> Params {
        Params::new()
            .with("rate_rps", 24.0)
            .with("duration_s", 4.0)
            .with("seed", 29.0)
            .with("slo_ttft_s", 1.0)
            .with("slo_tpot_s", 0.1)
    }

    fn run(&self, params: &Params) -> Vec<Report> {
        let k = Knobs::from(params);
        let mut points: Vec<FleetPoint> = Vec::new();
        for device in [DeviceKind::Gaudi2, DeviceKind::A100] {
            for policy in POLICIES {
                for replicas in REPLICA_SWEEP {
                    points.push(run_fleet(&k, device, policy, replicas));
                }
            }
        }

        let mut sweep = Report::new(format!(
            "Cluster sweep: {} req/s open-loop Dynamic-Sonnet, Llama-3.1-8B \
             (SLO: p99 TTFT <= {}s, p99 TPOT <= {}s)",
            k.rate_rps, k.slo_ttft_s, k.slo_tpot_s
        ));
        sweep.header(&[
            "device",
            "policy",
            "replicas",
            "tok/s",
            "p99 TTFT s",
            "p99 TPOT s",
            "goodput req/s",
            "SLO attain",
            "requeues",
        ]);
        for p in &points {
            sweep.row(vec![
                Cell::text(p.device.name()),
                Cell::text(p.policy.name()),
                Cell::count(p.replicas),
                Cell::val(p.tps, Unit::TokPerSec),
                Cell::val(p.p99_ttft, Unit::Seconds),
                Cell::val(p.p99_tpot, Unit::Seconds),
                Cell::val(p.goodput_rps, Unit::ReqPerSec),
                Cell::val(p.attainment, Unit::Percent),
                Cell::count(p.requeues as usize),
            ]);
        }
        sweep.note("goodput = SLO-compliant completions / fleet makespan");

        // Iso-SLO sizing: smallest replica count meeting the SLO on >= 99%
        // of requests, per (device, policy).
        let mut iso = Report::new("Iso-SLO replica counts: Gaudi-2 vs A100");
        iso.header(&["policy", "Gaudi-2 replicas", "A100 replicas", "ratio G2/A100"]);
        for policy in POLICIES {
            let min_for = |device: DeviceKind| -> Option<usize> {
                REPLICA_SWEEP.iter().copied().find(|&r| {
                    points.iter().any(|p| {
                        p.device == device
                            && p.policy == policy
                            && p.replicas == r
                            && p.attainment >= 0.99
                    })
                })
            };
            let fmt_min = |m: Option<usize>| match m {
                Some(r) => Cell::count(r),
                None => Cell::text(format!(">{}", REPLICA_SWEEP[REPLICA_SWEEP.len() - 1])),
            };
            let g = min_for(DeviceKind::Gaudi2);
            let a = min_for(DeviceKind::A100);
            let ratio = match (g, a) {
                (Some(g), Some(a)) => Cell::val(g as f64 / a as f64, Unit::Ratio),
                _ => Cell::text("n/a"),
            };
            iso.row(vec![Cell::text(policy.name()), fmt_min(g), fmt_min(a), ratio]);
        }
        iso.note(format!(
            "smallest fleet with >= 99% of requests meeting p99-style SLO \
             (TTFT <= {}s, TPOT <= {}s) at {} req/s",
            k.slo_ttft_s, k.slo_tpot_s, k.rate_rps
        ));

        // Derived claims: engine/cluster parity and tail-latency scaling.
        let (max_delta, makespan_delta, steps_delta, parity_n, mismatches) = parity_deltas();
        let scaling = {
            let find = |r: usize| {
                points
                    .iter()
                    .find(|p| {
                        p.device == DeviceKind::Gaudi2
                            && p.policy == RoutePolicy::RoundRobin
                            && p.replicas == r
                    })
                    .expect("sweep covers the full grid")
            };
            find(1).p99_ttft / find(4).p99_ttft.max(1e-12)
        };
        let mut claims = Report::new("Cluster derived claims");
        claims.header(&["claim", "value"]);
        claims.row(vec![
            Cell::text("1-replica max per-request metric delta vs engine (s)"),
            Cell::val(max_delta, Unit::Seconds),
        ]);
        claims.row(vec![
            Cell::text("1-replica makespan delta vs engine (s)"),
            Cell::val(makespan_delta, Unit::Seconds),
        ]);
        claims.row(vec![
            Cell::text("1-replica step-count delta vs engine"),
            Cell::val(steps_delta as f64, Unit::Count),
        ]);
        claims.row(vec![
            Cell::text("parity requests compared"),
            Cell::count(parity_n),
        ]);
        claims.row(vec![
            Cell::text("parity id mismatches"),
            Cell::count(mismatches),
        ]);
        claims.row(vec![
            Cell::text("p99 TTFT improvement, 1 -> 4 replicas (Gaudi-2, RR)"),
            Cell::val(scaling, Unit::Ratio),
        ]);
        claims.note("parity deltas are exact-zero by construction of the merged event loop");

        vec![sweep, iso, claims]
    }

    fn expectations(&self, _params: &Params) -> Vec<Expectation> {
        vec![
            Expectation::new(
                "cluster.bitwise_parity",
                "a 1-replica cluster replays the single engine bit-for-bit",
                Selector::cell(
                    "Cluster derived claims",
                    "1-replica max per-request metric delta vs engine (s)",
                    "value",
                ),
                Check::EqExact(0.0),
            ),
            Expectation::new(
                "cluster.step_parity",
                "the 1-replica cluster executes exactly the engine's step sequence",
                Selector::cell(
                    "Cluster derived claims",
                    "1-replica step-count delta vs engine",
                    "value",
                ),
                Check::EqExact(0.0),
            ),
            Expectation::new(
                "cluster.pairing_parity",
                "every engine request appears exactly once in the 1-replica cluster run",
                Selector::cell("Cluster derived claims", "parity id mismatches", "value"),
                Check::EqExact(0.0),
            ),
            Expectation::new(
                "cluster.scaling_cuts_tail",
                "scaling 1 -> 4 replicas does not worsen p99 TTFT",
                Selector::cell(
                    "Cluster derived claims",
                    "p99 TTFT improvement, 1 -> 4 replicas (Gaudi-2, RR)",
                    "value",
                ),
                Check::Ge(1.0),
            ),
        ]
    }
}

/// Run with default params (convenience for tests and library callers).
pub fn run() -> Vec<Report> {
    Cluster.run(&Cluster.params())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn three_reports_with_full_grids() {
        let reports = run();
        assert_eq!(reports.len(), 3);
        // 2 devices x 2 policies x 3 replica counts.
        assert_eq!(reports[0].num_rows(), 12);
        // One sizing row per policy.
        assert_eq!(reports[1].num_rows(), POLICIES.len());
        assert_eq!(reports[2].num_rows(), 6);
    }

    #[test]
    fn scaling_helps_the_fleet() {
        let k = Knobs::from(&Cluster.params());
        let one = run_fleet(&k, DeviceKind::Gaudi2, RoutePolicy::RoundRobin, 1);
        let four = run_fleet(&k, DeviceKind::Gaudi2, RoutePolicy::RoundRobin, 4);
        assert!(four.p99_ttft <= one.p99_ttft, "{} vs {}", four.p99_ttft, one.p99_ttft);
        assert!(four.attainment >= one.attainment);
    }

    #[test]
    fn parity_is_bitwise() {
        let (max_delta, makespan_delta, steps_delta, n, mismatches) = parity_deltas();
        assert_eq!(max_delta, 0.0);
        assert_eq!(makespan_delta, 0.0);
        assert_eq!(steps_delta, 0);
        assert_eq!(n, 40);
        assert_eq!(mismatches, 0);
    }

    #[test]
    fn expectations_pass() {
        let reports = run();
        for e in Cluster.expectations(&Cluster.params()) {
            let res = e.evaluate(&reports);
            assert!(res.pass, "{}: {}", res.id, res.detail);
        }
    }
}
