//! Chaos-sweep experiment: seeded fault schedules x fleet shapes — the
//! serving stack's robustness claims made executable. Each grid point
//! replays the same open-loop mixed-class trace under one
//! [`FaultSchedule`] (a replica crash with restart, a straggler window,
//! or a preemption storm under a flash crowd) on one fleet (homogeneous
//! Gaudi-2 or mixed Gaudi-2 + A100) and reports the goodput dip and the
//! time back to baseline (`MetricsCollector::recovery`).
//!
//! The structural claims checked by `repro run chaos-sweep --check` are
//! the chaos engine's contract, not tuning outcomes:
//!
//! - **Conservation**: every submitted request either completes exactly
//!   once or is counted shed — crashes requeue, hedges cancel their
//!   losers, nothing is lost or double-served (EqExact 0 violations).
//! - **Inertness**: an *empty* fault schedule is bitwise-equal to a run
//!   with no chaos installed at all — the third event heap never fires,
//!   so the fault-free fast path is provably untouched (EqExact 0).
//! - **Determinism**: the same seed and schedule replay bitwise
//!   (EqExact 0 max delta between twin runs at every grid point).
//! - **Recovery**: after the crash schedule, fleet goodput returns to
//!   `RECOVERY_FRACTION` of its pre-fault baseline within a bounded
//!   time on every fleet.
//! - **Hedging**: duplicating long-stuck requests to a second replica
//!   does not worsen p99 TTFT under a straggler (Le 0 delta), and fires
//!   at least once there.
//! - **Shedding**: under a flash-crowd overload with admission control
//!   on, only priority-0 background traffic is shed (EqExact 0
//!   non-background requests lost).
//!
//! `repro run chaos-sweep --json --out bench/` writes the grid as
//! `BENCH_chaos_sweep.json`; `python/plot_bench.py` renders the
//! goodput-over-time timelines with the fault windows shaded.

use crate::config::{DeviceKind, ServingConfig};
use crate::harness::{Experiment, Params};
use crate::models::llama::LlamaConfig;
use crate::report::{Cell, Check, Expectation, Report, Selector, Unit};
use crate::serving::chaos::{ChaosStats, Fault, FaultSchedule};
use crate::serving::cluster::ClusterSim;
use crate::serving::metrics::RecoveryMetrics;
use crate::serving::qos::ClassSet;
use crate::serving::router::RoutePolicy;
use crate::util::par;
use crate::workload::{DynamicSonnet, OpenLoopTrace, RateProcess};

/// (label, per-replica devices) — the two fleet shapes every schedule
/// runs against. Three replicas so a single crash leaves capacity.
const FLEETS: [(&str, [DeviceKind; 3]); 2] = [
    ("homogeneous 3x gaudi2", [DeviceKind::Gaudi2; 3]),
    ("mixed gaudi2/a100", [DeviceKind::Gaudi2, DeviceKind::A100, DeviceKind::Gaudi2]),
];

/// Flash-crowd window paired with the preemption-storm schedule: the
/// offered rate triples over [3, 5) s.
const CROWD: RateProcess = RateProcess::FlashCrowd { start_s: 3.0, duration_s: 2.0, mult: 3.0 };

/// The three fault schedules of the grid. Times sit inside the default
/// 12 s trace (and inside the >= 7 s traces the tests shrink to).
fn schedules() -> Vec<(&'static str, FaultSchedule, bool)> {
    vec![
        (
            "crash r0@3s (1.5s down)",
            FaultSchedule::empty().with(Fault::Crash { replica: 0, at: 3.0, down_s: 1.5 }),
            false,
        ),
        (
            "straggler r1 x4 [2,6]s",
            FaultSchedule::empty()
                .with(Fault::Straggler { replica: 1, from: 2.0, until: 6.0, factor: 4.0 }),
            false,
        ),
        (
            "storm r0@4s + flash crowd x3 [3,5]s",
            FaultSchedule::empty().with(Fault::PreemptStorm { replica: 0, at: 4.0, count: 6 }),
            true,
        ),
    ]
}

struct Knobs {
    rate_rps: f64,
    duration_s: f64,
    bucket_s: f64,
    hedge_after_s: f64,
    recovery_bound_s: f64,
    seed: u64,
}

impl Knobs {
    fn from(params: &Params) -> Knobs {
        Knobs {
            rate_rps: params.get_or("rate_rps", 10.0),
            duration_s: params.get_or("duration_s", 12.0),
            bucket_s: params.get_or("bucket_s", 0.5),
            hedge_after_s: params.get_or("hedge_after_s", 0.25),
            recovery_bound_s: params.get_or("recovery_bound_s", 8.0),
            seed: params.get_or("seed", 47.0) as u64,
        }
    }
}

fn chaos_config(fleet: &[DeviceKind]) -> ServingConfig {
    ServingConfig {
        route_policy: RoutePolicy::LeastLoaded,
        max_decode_batch: 24,
        num_blocks: 4096,
        classes: ClassSet::three_tier(),
        ..Default::default()
    }
    .with_fleet(fleet.to_vec())
}

/// One (schedule, fleet) grid point, plus its bitwise twin-run check.
struct ChaosPoint {
    submitted: usize,
    completed: usize,
    stats: ChaosStats,
    p99_ttft: f64,
    recovery: RecoveryMetrics,
    timeline: Vec<f64>,
    has_crash: bool,
    determinism_delta: f64,
}

fn run_point(k: &Knobs, fleet: &[DeviceKind], schedule: &FaultSchedule, crowd: bool) -> ChaosPoint {
    let classes = ClassSet::three_tier();
    let mix = vec![(0usize, 2usize), (1, 1), (2, 1)];
    let trace = || -> Vec<crate::serving::request::Request> {
        let tr = OpenLoopTrace::new(k.rate_rps, k.duration_s).with_class_mix(mix.clone());
        if crowd {
            tr.stream(k.seed).with_process(CROWD).collect()
        } else {
            tr.generate(k.seed)
        }
    };
    let submitted = trace().len();

    let run = || {
        let mut sim = ClusterSim::new(&chaos_config(fleet), LlamaConfig::llama31_8b());
        sim.install_chaos(schedule);
        sim.submit_all(trace());
        sim.run_to_completion();
        sim
    };
    let sim = run();
    let twin = run();
    let ms = sim.fleet_metrics();
    let determinism_delta = ms.max_request_delta(&twin.fleet_metrics())
        + sim.events().abs_diff(twin.events()) as f64;

    let first_fault =
        schedule.windows().iter().map(|w| w.0).fold(f64::INFINITY, f64::min);
    ChaosPoint {
        submitted,
        completed: sim.completed(),
        stats: sim.chaos_stats(),
        p99_ttft: ms.summary().p99_ttft,
        recovery: ms.recovery(&classes, first_fault, k.bucket_s),
        timeline: ms.goodput_timeline(&classes, k.bucket_s),
        has_crash: schedule.faults.iter().any(|f| matches!(f, Fault::Crash { .. })),
        determinism_delta,
    }
}

/// Max per-request delta between a chaos-free run and one with an empty
/// [`FaultSchedule`] installed — the inertness claim (exact zero: the
/// control heap stays empty, so the indexed event loop never diverges).
fn empty_schedule_parity(k: &Knobs) -> f64 {
    let cfg = chaos_config(&FLEETS[0].1);
    let trace = || OpenLoopTrace::new(k.rate_rps, k.duration_s).generate(k.seed);
    let run = |chaos: bool| {
        let mut sim = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
        if chaos {
            sim.install_chaos(&FaultSchedule::empty());
        }
        sim.submit_all(trace());
        sim.run_to_completion();
        sim
    };
    let plain = run(false);
    let empty = run(true);
    plain.fleet_metrics().max_request_delta(&empty.fleet_metrics())
        + plain.events().abs_diff(empty.events()) as f64
}

/// Hedging cell: p99 TTFT with hedging on minus off, under a hard
/// straggler on a 2-replica round-robin fleet (round-robin keeps
/// steering half the trace onto the slow replica, so hedges have work
/// to rescue). Returns the delta and the number of hedges launched.
fn hedging_cell(k: &Knobs) -> (f64, u64) {
    let schedule = FaultSchedule::empty().with(Fault::Straggler {
        replica: 0,
        from: 0.0,
        until: k.duration_s,
        factor: 12.0,
    });
    let run = |hedge_after_s: f64| {
        let cfg = ServingConfig {
            route_policy: RoutePolicy::RoundRobin,
            max_decode_batch: 24,
            num_blocks: 4096,
            classes: ClassSet::three_tier(),
            hedge_after_s,
            ..Default::default()
        }
        .with_fleet(vec![DeviceKind::Gaudi2; 2]);
        let mut sim = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
        sim.install_chaos(&schedule);
        sim.submit_all(OpenLoopTrace::new(6.0, k.duration_s).generate(k.seed));
        sim.run_to_completion();
        (sim.fleet_metrics().summary().p99_ttft, sim.chaos_stats())
    };
    let (hedged_p99, stats) = run(k.hedge_after_s);
    let (control_p99, _) = run(0.0);
    (hedged_p99 - control_p99, stats.hedges_launched)
}

/// Shedding cell: a t=0 burst (2x the router queue cap) against a
/// half-interactive / half-background mix with admission control at 50%
/// queue depth. Returns (background requests shed, non-background
/// requests lost) — the latter must be exactly zero.
fn shed_cell(k: &Knobs) -> (u64, usize) {
    let reqs = DynamicSonnet::default()
        .with_class_mix(vec![(0, 1), (2, 1)])
        .generate(40, f64::INFINITY, k.seed);
    let foreground_submitted = reqs.iter().filter(|r| r.class_id != 2).count();
    let cfg = ServingConfig {
        route_policy: RoutePolicy::LeastLoaded,
        max_decode_batch: 24,
        num_blocks: 4096,
        max_queued: 12,
        classes: ClassSet::three_tier(),
        shed_threshold: 0.5,
        ..Default::default()
    }
    .with_fleet(vec![DeviceKind::Gaudi2; 2]);
    let mut sim = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
    sim.submit_all(reqs);
    sim.run_to_completion();
    let foreground_completed =
        sim.fleet_metrics().per_request().iter().filter(|m| m.class_id != 2).count();
    (sim.chaos_stats().shed, foreground_submitted - foreground_completed)
}

pub struct ChaosSweep;

impl Experiment for ChaosSweep {
    fn id(&self) -> &'static str {
        "chaos_sweep"
    }

    fn title(&self) -> &'static str {
        "Chaos sweep: fault schedules x fleets (conservation, recovery, hedging, shedding)"
    }

    fn params(&self) -> Params {
        Params::new()
            .with("rate_rps", 10.0)
            .with("duration_s", 12.0)
            .with("bucket_s", 0.5)
            .with("hedge_after_s", 0.25)
            .with("recovery_bound_s", 8.0)
            .with("seed", 47.0)
    }

    fn run(&self, params: &Params) -> Vec<Report> {
        let k = Knobs::from(params);
        let scheds = schedules();
        let mut reports = Vec::new();
        let mut all: Vec<ChaosPoint> = Vec::new();

        // Fan the flattened (fleet, schedule) grid across the worker
        // pool — each point is an independent seeded run (including its
        // twin determinism re-run); submission-ordered assembly keeps
        // the artifact byte-identical at any --jobs value.
        let grid = par::par_map_indexed(FLEETS.len() * scheds.len(), |idx| {
            let (label, s, crowd) = &scheds[idx % scheds.len()];
            (*label, run_point(&k, &FLEETS[idx / scheds.len()].1, s, *crowd))
        });
        let mut grid_iter = grid.into_iter();

        for (fleet_label, fleet) in FLEETS {
            let points: Vec<(&str, ChaosPoint)> =
                grid_iter.by_ref().take(scheds.len()).collect();

            let mut r = Report::new(format!(
                "Chaos schedule sweep [{fleet_label}]: {} replicas, three-tier classes",
                fleet.len()
            ));
            r.header(&[
                "schedule",
                "served",
                "crashes",
                "restarts",
                "requeued by crash",
                "forced preemptions",
                "hedges launched",
                "p99 ttft",
                "baseline goodput",
                "dip depth",
                "dip area",
                "recovery time",
            ]);
            for (label, p) in &points {
                r.row(vec![
                    Cell::text(*label),
                    Cell::count(p.completed),
                    Cell::count(p.stats.crashes as usize),
                    Cell::count(p.stats.restarts as usize),
                    Cell::count(p.stats.requeued_by_crash as usize),
                    Cell::count(p.stats.forced_preemptions as usize),
                    Cell::count(p.stats.hedges_launched as usize),
                    Cell::val(p.p99_ttft, Unit::Seconds),
                    Cell::val(p.recovery.baseline_rps, Unit::ReqPerSec),
                    Cell::val(p.recovery.dip_depth, Unit::ReqPerSec),
                    Cell::val(p.recovery.dip_area, Unit::Count),
                    Cell::val(p.recovery.recovery_time_s.unwrap_or(-1.0), Unit::Seconds),
                ]);
            }
            r.note(format!(
                "open-loop mixed-class trace, {} req/s for {}s (seed {}); recovery time is \
                 seconds from first fault back to {}x of pre-fault goodput, -1 = not within \
                 the run",
                k.rate_rps,
                k.duration_s,
                k.seed,
                crate::serving::metrics::RECOVERY_FRACTION,
            ));
            reports.push(r);

            // Goodput-over-time series for the dip/recovery plot.
            let mut tl = Report::new(format!("Chaos goodput timeline [{fleet_label}]"));
            tl.header(&["schedule", "t", "goodput"]);
            for (label, p) in &points {
                for (i, &g) in p.timeline.iter().enumerate() {
                    tl.row(vec![
                        Cell::text(*label),
                        Cell::val((i as f64 + 0.5) * k.bucket_s, Unit::Seconds),
                        Cell::val(g, Unit::ReqPerSec),
                    ]);
                }
            }
            tl.note("bucket midpoints; compliant completions per second per bucket");
            reports.push(tl);

            all.extend(points.into_iter().map(|(_, p)| p));
        }

        // Fault windows (fleet-independent) for the plot's shaded spans.
        let mut win = Report::new("Chaos fault windows");
        win.header(&["schedule", "kind", "from", "until"]);
        for (label, s, _) in &scheds {
            for (from, until, kind) in s.windows() {
                win.row(vec![
                    Cell::text(*label),
                    Cell::text(kind),
                    Cell::val(from, Unit::Seconds),
                    Cell::val(until, Unit::Seconds),
                ]);
            }
        }
        reports.push(win);

        // Derived claims over the grid plus the dedicated cells.
        let parity = empty_schedule_parity(&k);
        let (hedge_delta, hedges_launched) = hedging_cell(&k);
        let (shed, foreground_lost) = shed_cell(&k);
        let conservation: usize = all
            .iter()
            .map(|p| p.submitted.abs_diff(p.completed + p.stats.shed as usize))
            .sum();
        let determinism = all.iter().map(|p| p.determinism_delta).fold(0.0, f64::max);
        let crash_cells: Vec<&ChaosPoint> = all.iter().filter(|p| p.has_crash).collect();
        let unrecovered =
            crash_cells.iter().filter(|p| p.recovery.recovery_time_s.is_none()).count();
        let max_recovery = crash_cells
            .iter()
            .filter_map(|p| p.recovery.recovery_time_s)
            .fold(0.0, f64::max);

        let mut claims = Report::new("Chaos-sweep derived claims");
        claims.header(&["claim", "value"]);
        claims.row(vec![
            Cell::text("request conservation violations over the grid"),
            Cell::count(conservation),
        ]);
        claims.row(vec![
            Cell::text("empty fault schedule vs chaos-free run: max delta"),
            Cell::val(parity, Unit::Seconds),
        ]);
        claims.row(vec![
            Cell::text("same-seed twin-run determinism: max delta over the grid"),
            Cell::val(determinism, Unit::Seconds),
        ]);
        claims.row(vec![
            Cell::text("crash cells without goodput recovery"),
            Cell::count(unrecovered),
        ]);
        claims.row(vec![
            Cell::text("max crash recovery time"),
            Cell::val(max_recovery, Unit::Seconds),
        ]);
        let over_bound = crash_cells
            .iter()
            .filter_map(|p| p.recovery.recovery_time_s)
            .filter(|&t| t > k.recovery_bound_s)
            .count();
        claims.row(vec![
            Cell::text("crash cells exceeding the recovery bound"),
            Cell::count(over_bound),
        ]);
        claims.row(vec![
            Cell::text("hedging p99 TTFT delta under straggler (on - off)"),
            Cell::val(hedge_delta, Unit::Seconds),
        ]);
        claims.row(vec![
            Cell::text("hedges launched under straggler"),
            Cell::count(hedges_launched as usize),
        ]);
        claims.row(vec![
            Cell::text("background requests shed under overload"),
            Cell::count(shed as usize),
        ]);
        claims.row(vec![
            Cell::text("non-background requests lost to shedding"),
            Cell::count(foreground_lost),
        ]);
        claims.row(vec![Cell::text("grid points swept"), Cell::count(all.len())]);
        claims.note(
            "conservation counts |submitted - completed - shed| at every grid point: \
             crashes requeue their in-flight work, hedge losers are cancelled before \
             they can double-complete, and admission control only ever drops \
             priority-0 background traffic",
        );
        reports.push(claims);

        reports
    }

    fn expectations(&self, _params: &Params) -> Vec<Expectation> {
        vec![
            Expectation::new(
                "chaos_sweep.conservation",
                "no request is lost or double-served under any fault schedule",
                Selector::cell(
                    "Chaos-sweep derived claims",
                    "request conservation violations over the grid",
                    "value",
                ),
                Check::EqExact(0.0),
            ),
            Expectation::new(
                "chaos_sweep.empty_schedule_inert",
                "an empty fault schedule replays the chaos-free run bitwise",
                Selector::cell(
                    "Chaos-sweep derived claims",
                    "empty fault schedule vs chaos-free run: max delta",
                    "value",
                ),
                Check::EqExact(0.0),
            ),
            Expectation::new(
                "chaos_sweep.determinism",
                "the same seed and schedule replay bitwise at every grid point",
                Selector::cell(
                    "Chaos-sweep derived claims",
                    "same-seed twin-run determinism: max delta over the grid",
                    "value",
                ),
                Check::EqExact(0.0),
            ),
            Expectation::new(
                "chaos_sweep.recovery",
                "goodput returns to baseline after a crash on every fleet",
                Selector::cell(
                    "Chaos-sweep derived claims",
                    "crash cells without goodput recovery",
                    "value",
                ),
                Check::EqExact(0.0),
            ),
            Expectation::new(
                "chaos_sweep.recovery_bound",
                "crash recovery completes within the recovery SLO",
                Selector::cell(
                    "Chaos-sweep derived claims",
                    "crash cells exceeding the recovery bound",
                    "value",
                ),
                Check::EqExact(0.0),
            ),
            Expectation::new(
                "chaos_sweep.hedging_p99",
                "hedged requests do not worsen p99 TTFT under a straggler",
                Selector::cell(
                    "Chaos-sweep derived claims",
                    "hedging p99 TTFT delta under straggler (on - off)",
                    "value",
                ),
                Check::Le(0.0),
            ),
            Expectation::new(
                "chaos_sweep.hedging_fires",
                "the straggler cell actually launches hedges",
                Selector::cell(
                    "Chaos-sweep derived claims",
                    "hedges launched under straggler",
                    "value",
                ),
                Check::Ge(1.0),
            ),
            Expectation::new(
                "chaos_sweep.shed_only_background",
                "admission control sheds background traffic only",
                Selector::cell(
                    "Chaos-sweep derived claims",
                    "non-background requests lost to shedding",
                    "value",
                ),
                Check::EqExact(0.0),
            ),
            Expectation::new(
                "chaos_sweep.full_grid",
                "the sweep covers every (schedule, fleet) grid point",
                Selector::cell("Chaos-sweep derived claims", "grid points swept", "value"),
                Check::Ge((FLEETS.len() * 3) as f64),
            ),
        ]
    }
}

/// Run with default params (convenience for tests and library callers).
pub fn run() -> Vec<Report> {
    ChaosSweep.run(&ChaosSweep.params())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_params() -> Params {
        ChaosSweep.params().with("duration_s", 7.0).with("bucket_s", 1.0)
    }

    #[test]
    fn report_shape_per_fleet_plus_windows_and_claims() {
        let reports = ChaosSweep.run(&small_params());
        // Per fleet: schedule table + timeline; then windows + claims.
        assert_eq!(reports.len(), 2 * FLEETS.len() + 2);
        for (i, (label, _)) in FLEETS.iter().enumerate() {
            assert!(reports[2 * i].title().contains(label));
            assert_eq!(reports[2 * i].num_rows(), schedules().len());
            assert!(reports[2 * i + 1].title().contains("timeline"));
        }
        assert_eq!(reports[reports.len() - 2].title(), "Chaos fault windows");
        assert_eq!(reports[reports.len() - 1].num_rows(), 11);
    }

    #[test]
    fn empty_schedule_is_inert() {
        let k = Knobs::from(&small_params());
        assert_eq!(empty_schedule_parity(&k), 0.0);
    }

    #[test]
    fn grid_points_conserve_requests_and_replay() {
        let k = Knobs::from(&small_params());
        for (_, schedule, crowd) in schedules() {
            let p = run_point(&k, &FLEETS[0].1, &schedule, crowd);
            assert_eq!(p.submitted, p.completed + p.stats.shed as usize);
            assert_eq!(p.determinism_delta, 0.0);
            assert!(!p.timeline.is_empty());
        }
    }

    #[test]
    fn crash_cell_recovers_on_the_default_grid() {
        let k = Knobs::from(&ChaosSweep.params());
        let (_, schedule, crowd) = &schedules()[0];
        let p = run_point(&k, &FLEETS[0].1, schedule, *crowd);
        assert!(p.has_crash && p.stats.crashes == 1 && p.stats.restarts == 1);
        assert!(p.stats.requeued_by_crash > 0, "a 3 s crash should catch in-flight work");
        let rt = p.recovery.recovery_time_s.expect("goodput should recover");
        assert!(rt <= k.recovery_bound_s, "recovery {rt}s");
    }

    #[test]
    fn shedding_is_background_only() {
        let k = Knobs::from(&small_params());
        let (shed, foreground_lost) = shed_cell(&k);
        assert!(shed > 0, "overload burst should shed background work");
        assert_eq!(foreground_lost, 0);
    }

    #[test]
    fn expectations_pass_on_default_grid() {
        // The full default grid is the artifact CI gates on; every
        // expectation must hold there.
        let reports = run();
        for e in ChaosSweep.expectations(&ChaosSweep.params()) {
            let res = e.evaluate(&reports);
            assert!(res.pass, "{}: {}", res.id, res.detail);
        }
    }
}
