//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation section (`repro run <exp>`, `repro list`). Each experiment
//! implements the [`Experiment`] trait: it declares its [`Params`], emits
//! typed [`Report`]s (raw numbers + units, rendered by `util::table`,
//! exported as JSON artifacts), and carries the paper's headline claims
//! as typed [`Expectation`]s checked by `repro run --check`.
//!
//! Experiments are `Sync` and every grid point is a seeded, deterministic
//! simulation, so the harness runs them through the dependency-free
//! executor in [`crate::util::par`]: `repro run all --jobs N` fans
//! experiments across a work pool via [`run_all_isolated`] (results
//! assembled in registry order, one panicking experiment never poisons
//! its siblings' artifacts), and the big sweeps fan their own grid
//! points the same way. The per-experiment `BENCH_*.json` artifacts are
//! byte-identical at any `--jobs` value — jobs-invariance — leaving
//! [`wall_report`]'s timing table as the only jobs-dependent output.

pub mod ablations;
pub mod cache_sweep;
pub mod chaos_sweep;
pub mod cluster;
pub mod cluster_sweep;
pub mod fig10;
pub mod fig11;
pub mod fig12;
pub mod fig13;
pub mod fig15;
pub mod fig17;
pub mod fig4;
pub mod fig5;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod fleet_budget;
pub mod par_speed;
pub mod qos_sweep;
pub mod sim_speed;
pub mod table1;
pub mod tp_sweep;

use crate::report::{Cell, Expectation, ExpectationResult, Report, Unit};
use crate::util::json::Json;
use crate::util::par;

/// Named numeric parameters of an experiment (sweep rates, seeds, SLOs).
/// Declared by `Experiment::params`, read back in `run`, and recorded in
/// the JSON artifact so every emitted number carries its provenance.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Params {
    entries: Vec<(String, f64)>,
}

impl Params {
    pub fn new() -> Params {
        Params::default()
    }

    /// Set (or replace) a parameter; builder-style.
    pub fn with(mut self, key: &str, value: f64) -> Params {
        match self.entries.iter_mut().find(|(k, _)| k == key) {
            Some(e) => e.1 = value,
            None => self.entries.push((key.to_string(), value)),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<f64> {
        self.entries.iter().find(|(k, _)| k == key).map(|(_, v)| *v)
    }

    pub fn get_or(&self, key: &str, dflt: f64) -> f64 {
        self.get(key).unwrap_or(dflt)
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, f64)> + '_ {
        self.entries.iter().map(|(k, v)| (k.as_str(), *v))
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(self.entries.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect())
    }
}

/// Evenly spaced offered-load grid shared by the sweep experiments:
/// `min_rps + i x step_rps` for `points` points (at least one) — one
/// definition so cluster-sweep and qos-sweep can never disagree on what
/// a load grid means.
pub fn load_grid(min_rps: f64, step_rps: f64, points: usize) -> Vec<f64> {
    (0..points.max(1)).map(|i| min_rps + i as f64 * step_rps).collect()
}

/// A runnable experiment (one paper table/figure, ablation or extension).
/// `Sync` because the parallel runner shares experiments by reference
/// across its worker threads (all implementors are stateless unit
/// structs; their runs derive everything from `Params`).
pub trait Experiment: Sync {
    /// Stable CLI id (`repro run <id>`, artifact file name).
    fn id(&self) -> &'static str;
    /// Human title shown by `repro list`.
    fn title(&self) -> &'static str;
    /// Default parameters; recorded in the JSON artifact.
    fn params(&self) -> Params {
        Params::new()
    }
    /// Regenerate the experiment's reports under `params`.
    fn run(&self, params: &Params) -> Vec<Report>;
    /// The paper's headline claims over this experiment's reports. The
    /// run's `params` are passed in so machine-dependent thresholds can
    /// be `--param`-overridden (e.g. sim-speed's `min_speedup`).
    fn expectations(&self, _params: &Params) -> Vec<Expectation> {
        Vec::new()
    }
}

/// All experiments, in paper order.
pub fn registry() -> Vec<Box<dyn Experiment>> {
    vec![
        Box::new(table1::Table1),
        Box::new(fig4::Fig4),
        Box::new(fig5::Fig5),
        Box::new(fig7::Fig7),
        Box::new(fig8::Fig8),
        Box::new(fig9::Fig9),
        Box::new(fig10::Fig10),
        Box::new(fig11::Fig11),
        Box::new(fig12::Fig12),
        Box::new(fig13::Fig13),
        Box::new(fig15::Fig15),
        Box::new(fig17::Fig17),
        Box::new(cluster::Cluster),
        Box::new(cluster_sweep::ClusterSweep),
        Box::new(cache_sweep::CacheSweep),
        Box::new(qos_sweep::QosSweep),
        Box::new(chaos_sweep::ChaosSweep),
        Box::new(ablations::AblMme),
        Box::new(ablations::AblWatermark),
        Box::new(ablations::ExtMultiRecsys),
        Box::new(ablations::ExtTraining),
        Box::new(ablations::ExtGaudi3),
        Box::new(sim_speed::SimSpeed),
        Box::new(tp_sweep::TpSweep),
        Box::new(fleet_budget::FleetBudget),
        Box::new(par_speed::ParSpeed),
    ]
}

/// Look up one experiment by id. Hyphens and underscores are
/// interchangeable (`repro run cluster-sweep` finds `cluster_sweep` —
/// ids stay underscore-only so the artifact file name is shell-friendly).
pub fn find(id: &str) -> Option<Box<dyn Experiment>> {
    let canon = id.replace('-', "_");
    registry().into_iter().find(|e| e.id() == canon)
}

/// Run one experiment by id under its default params; None if unknown.
pub fn run_experiment(id: &str) -> Option<Vec<Report>> {
    find(id).map(|e| e.run(&e.params()))
}

/// Run everything (the `repro run all` path).
pub fn run_all() -> Vec<Report> {
    registry().iter().flat_map(|e| e.run(&e.params())).collect()
}

/// Evaluate an experiment's expectations over already-produced reports
/// (`params` = the params the run used, so overridden thresholds apply).
pub fn evaluate(e: &dyn Experiment, params: &Params, reports: &[Report]) -> Vec<ExpectationResult> {
    e.expectations(params).iter().map(|x| x.evaluate(reports)).collect()
}

/// An experiment's params after applying the CLI's `--param` overrides
/// (only keys the experiment declares; unknown keys are the caller's
/// usage error to reject).
pub fn apply_overrides(e: &dyn Experiment, overrides: &[(String, f64)]) -> Params {
    let mut params = e.params();
    for (k, v) in overrides {
        if params.get(k).is_some() {
            params = params.with(k, *v);
        }
    }
    params
}

/// Everything one experiment produced under [`run_all_isolated`]: the
/// effective params, reports, evaluated claims, the wall-clock cost, and
/// — when the run unwound — the panic message plus one synthesized
/// failing [`ExpectationResult`] so `--check` reports the crash.
pub struct ExpRun {
    pub id: &'static str,
    pub title: &'static str,
    pub params: Params,
    pub reports: Vec<Report>,
    pub results: Vec<ExpectationResult>,
    /// `Some(message)` if `run` (or `expectations`) panicked.
    pub panic: Option<String>,
    /// Wall-clock seconds this experiment spent on its worker.
    pub wall_s: f64,
}

impl ExpRun {
    pub fn failed(&self) -> bool {
        self.panic.is_some() || self.results.iter().any(|r| !r.pass)
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Run a batch of experiments across the [`par`] pool, each isolated by
/// `catch_unwind`: a panicking experiment (or a panicking grid point
/// inside one — the pool re-raises it on the experiment's worker)
/// becomes that entry's failure without poisoning its siblings. Results
/// come back in input order at any jobs count, so artifact emission
/// stays registry-ordered and byte-identical — the jobs-invariance
/// contract.
pub fn run_all_isolated(exps: &[Box<dyn Experiment>], overrides: &[(String, f64)]) -> Vec<ExpRun> {
    par::par_map_indexed(exps.len(), |i| {
        let e = exps[i].as_ref();
        let params = apply_overrides(e, overrides);
        let t0 = std::time::Instant::now();
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let reports = e.run(&params);
            let results = evaluate(e, &params, &reports);
            (reports, results)
        }));
        let wall_s = t0.elapsed().as_secs_f64();
        match outcome {
            Ok((reports, results)) => {
                ExpRun { id: e.id(), title: e.title(), params, reports, results, panic: None, wall_s }
            }
            Err(payload) => {
                let msg = panic_message(payload.as_ref());
                let results = vec![ExpectationResult {
                    id: format!("{}.run_panicked", e.id()),
                    claim: "the experiment's run must complete without panicking".to_string(),
                    pass: false,
                    actual: None,
                    detail: format!("panicked: {msg}"),
                }];
                ExpRun {
                    id: e.id(),
                    title: e.title(),
                    params,
                    reports: Vec::new(),
                    results,
                    panic: Some(msg),
                    wall_s,
                }
            }
        }
    })
}

/// Per-experiment wall-time summary of a batch run (`repro run all`):
/// one row per experiment in registry order with `Unit::Seconds` cells,
/// so humans and the bench-diff gate can see which experiments dominate
/// CI time. This is the ONE deliberately jobs-/machine-dependent table —
/// it ships in its own `BENCH_run_wall.json` artifact (see
/// [`wall_artifact_json`]) precisely so the per-experiment artifacts
/// stay byte-identical across `--jobs`.
pub fn wall_report(runs: &[ExpRun], jobs: usize) -> Report {
    let mut r = Report::new("Run wall-time summary: per-experiment cost");
    r.header(&["experiment", "reports", "claims", "wall s", "status"]);
    for run in runs {
        r.row(vec![
            Cell::text(run.id),
            Cell::count(run.reports.len()),
            Cell::count(run.results.len()),
            Cell::val(run.wall_s, Unit::Seconds),
            Cell::text(if run.panic.is_some() {
                "PANIC"
            } else if run.failed() {
                "FAIL"
            } else {
                "ok"
            }),
        ]);
    }
    let total: f64 = runs.iter().map(|r| r.wall_s).sum();
    r.note(format!(
        "{} experiment(s), {:.1} s summed worker time at jobs={jobs}; wall-clock cells \
         are machine-dependent (see bench/baseline/README.md)",
        runs.len(),
        total
    ));
    r
}

/// The `BENCH_run_wall.json` artifact: [`wall_report`] wrapped in the
/// standard experiment-v1 schema (experiment id `run_wall`) so
/// bench-diff and the plotting script consume it like any other
/// artifact. Unlike every other artifact it is jobs- and
/// machine-dependent by design.
pub fn wall_artifact_json(runs: &[ExpRun], jobs: usize) -> Json {
    Json::obj(vec![
        ("schema", Json::Str(ARTIFACT_SCHEMA.into())),
        ("experiment", Json::Str("run_wall".into())),
        ("title", Json::Str("Per-experiment wall time of the harness run".into())),
        ("params", Params::new().with("jobs", jobs as f64).to_json()),
        ("reports", Json::Arr(vec![wall_report(runs, jobs).to_json()])),
        ("expectations", Json::Arr(Vec::new())),
    ])
}

/// Schema tag of the per-experiment JSON artifact.
pub const ARTIFACT_SCHEMA: &str = "cuda-myth/experiment-v1";

/// The per-experiment JSON artifact written by `repro run --json`:
/// schema tag, id/title, the params the run used, every report with raw
/// typed cells, and the evaluated paper-claim expectations.
pub fn artifact_json(
    e: &dyn Experiment,
    params: &Params,
    reports: &[Report],
    results: &[ExpectationResult],
) -> Json {
    Json::obj(vec![
        ("schema", Json::Str(ARTIFACT_SCHEMA.into())),
        ("experiment", Json::Str(e.id().into())),
        ("title", Json::Str(e.title().into())),
        ("params", params.to_json()),
        ("reports", Json::Arr(reports.iter().map(|r| r.to_json()).collect())),
        ("expectations", Json::Arr(results.iter().map(|r| r.to_json()).collect())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_every_table_and_figure() {
        let ids: Vec<&str> = registry().iter().map(|e| e.id()).collect();
        for required in [
            "table1", "fig4", "fig5", "fig7", "fig8", "fig9", "fig10", "fig11", "fig12",
            "fig13", "fig15", "fig17", "cluster", "cluster_sweep", "cache_sweep", "qos_sweep",
            "chaos_sweep", "sim_speed", "tp_sweep", "fleet_budget", "par_speed",
        ] {
            assert!(ids.contains(&required), "missing {required}");
        }
        assert_eq!(ids.len(), 26, "registry must keep all 26 entries");
    }

    #[test]
    fn unknown_experiment_is_none() {
        assert!(run_experiment("fig99").is_none());
        assert!(find("fig99").is_none());
    }

    #[test]
    fn find_accepts_hyphenated_ids() {
        assert_eq!(find("cluster-sweep").unwrap().id(), "cluster_sweep");
        assert_eq!(find("cluster_sweep").unwrap().id(), "cluster_sweep");
        assert_eq!(find("cache-sweep").unwrap().id(), "cache_sweep");
        assert_eq!(find("qos-sweep").unwrap().id(), "qos_sweep");
        assert_eq!(find("chaos-sweep").unwrap().id(), "chaos_sweep");
        assert_eq!(find("sim-speed").unwrap().id(), "sim_speed");
        assert_eq!(find("tp-sweep").unwrap().id(), "tp_sweep");
        assert_eq!(find("fleet-budget").unwrap().id(), "fleet_budget");
        assert_eq!(find("par-speed").unwrap().id(), "par_speed");
        assert!(find("cluster-").is_none());
    }

    #[test]
    fn params_set_get_and_json() {
        let p = Params::new().with("rate", 24.0).with("seed", 29.0).with("rate", 30.0);
        assert_eq!(p.get("rate"), Some(30.0));
        assert_eq!(p.get_or("missing", 7.0), 7.0);
        assert_eq!(p.iter().count(), 2);
        let j = p.to_json();
        assert_eq!(j.get("rate").unwrap().as_f64(), Some(30.0));
    }

    #[test]
    fn artifact_shape_is_schema_stable() {
        let e = find("table1").unwrap();
        let params = e.params();
        let reports = e.run(&params);
        let results = evaluate(e.as_ref(), &params, &reports);
        let j = artifact_json(e.as_ref(), &params, &reports, &results);
        let parsed = Json::parse(&j.dump()).unwrap();
        assert_eq!(parsed.get("schema").unwrap().as_str(), Some(ARTIFACT_SCHEMA));
        assert_eq!(parsed.get("experiment").unwrap().as_str(), Some("table1"));
        assert!(!parsed.get("reports").unwrap().as_arr().unwrap().is_empty());
        assert!(!parsed.get("expectations").unwrap().as_arr().unwrap().is_empty());
    }

    #[test]
    fn apply_overrides_only_touches_declared_keys() {
        let e = find("tp_sweep").unwrap();
        let overrides =
            vec![("requests".to_string(), 16.0), ("no_such_key".to_string(), 1.0)];
        let params = apply_overrides(e.as_ref(), &overrides);
        assert_eq!(params.get("requests"), Some(16.0));
        assert_eq!(params.get("no_such_key"), None);
    }

    #[test]
    fn isolated_runner_reports_and_walls_every_entry() {
        let exps: Vec<Box<dyn Experiment>> =
            vec![find("table1").unwrap(), find("fig4").unwrap()];
        let runs = run_all_isolated(&exps, &[]);
        assert_eq!(runs.len(), 2);
        // Input order is preserved regardless of worker scheduling.
        assert_eq!(runs[0].id, "table1");
        assert_eq!(runs[1].id, "fig4");
        for run in &runs {
            assert!(run.panic.is_none(), "{}: {:?}", run.id, run.panic);
            assert!(!run.failed());
            assert!(!run.reports.is_empty());
            assert!(run.wall_s >= 0.0);
        }
        let wall = wall_report(&runs, 2);
        assert_eq!(wall.num_rows(), 2);
        let cell = wall.value_at("table1", "wall s").unwrap();
        assert_eq!(cell.unit, Unit::Seconds);
        let j = Json::parse(&wall_artifact_json(&runs, 2).dump()).unwrap();
        assert_eq!(j.get("experiment").unwrap().as_str(), Some("run_wall"));
        assert_eq!(j.get("params").unwrap().get("jobs").unwrap().as_f64(), Some(2.0));
    }
}
