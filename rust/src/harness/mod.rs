//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation section (`repro run <exp>`, `repro list`). Each experiment
//! implements the [`Experiment`] trait: it declares its [`Params`], emits
//! typed [`Report`]s (raw numbers + units, rendered by `util::table`,
//! exported as JSON artifacts), and carries the paper's headline claims
//! as typed [`Expectation`]s checked by `repro run --check`.

pub mod ablations;
pub mod cache_sweep;
pub mod chaos_sweep;
pub mod cluster;
pub mod cluster_sweep;
pub mod fig10;
pub mod fig11;
pub mod fig12;
pub mod fig13;
pub mod fig15;
pub mod fig17;
pub mod fig4;
pub mod fig5;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod qos_sweep;
pub mod sim_speed;
pub mod table1;
pub mod tp_sweep;

use crate::report::{Expectation, ExpectationResult, Report};
use crate::util::json::Json;

/// Named numeric parameters of an experiment (sweep rates, seeds, SLOs).
/// Declared by `Experiment::params`, read back in `run`, and recorded in
/// the JSON artifact so every emitted number carries its provenance.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Params {
    entries: Vec<(String, f64)>,
}

impl Params {
    pub fn new() -> Params {
        Params::default()
    }

    /// Set (or replace) a parameter; builder-style.
    pub fn with(mut self, key: &str, value: f64) -> Params {
        match self.entries.iter_mut().find(|(k, _)| k == key) {
            Some(e) => e.1 = value,
            None => self.entries.push((key.to_string(), value)),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<f64> {
        self.entries.iter().find(|(k, _)| k == key).map(|(_, v)| *v)
    }

    pub fn get_or(&self, key: &str, dflt: f64) -> f64 {
        self.get(key).unwrap_or(dflt)
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, f64)> + '_ {
        self.entries.iter().map(|(k, v)| (k.as_str(), *v))
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(self.entries.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect())
    }
}

/// Evenly spaced offered-load grid shared by the sweep experiments:
/// `min_rps + i x step_rps` for `points` points (at least one) — one
/// definition so cluster-sweep and qos-sweep can never disagree on what
/// a load grid means.
pub fn load_grid(min_rps: f64, step_rps: f64, points: usize) -> Vec<f64> {
    (0..points.max(1)).map(|i| min_rps + i as f64 * step_rps).collect()
}

/// A runnable experiment (one paper table/figure, ablation or extension).
pub trait Experiment {
    /// Stable CLI id (`repro run <id>`, artifact file name).
    fn id(&self) -> &'static str;
    /// Human title shown by `repro list`.
    fn title(&self) -> &'static str;
    /// Default parameters; recorded in the JSON artifact.
    fn params(&self) -> Params {
        Params::new()
    }
    /// Regenerate the experiment's reports under `params`.
    fn run(&self, params: &Params) -> Vec<Report>;
    /// The paper's headline claims over this experiment's reports.
    fn expectations(&self) -> Vec<Expectation> {
        Vec::new()
    }
}

/// All experiments, in paper order.
pub fn registry() -> Vec<Box<dyn Experiment>> {
    vec![
        Box::new(table1::Table1),
        Box::new(fig4::Fig4),
        Box::new(fig5::Fig5),
        Box::new(fig7::Fig7),
        Box::new(fig8::Fig8),
        Box::new(fig9::Fig9),
        Box::new(fig10::Fig10),
        Box::new(fig11::Fig11),
        Box::new(fig12::Fig12),
        Box::new(fig13::Fig13),
        Box::new(fig15::Fig15),
        Box::new(fig17::Fig17),
        Box::new(cluster::Cluster),
        Box::new(cluster_sweep::ClusterSweep),
        Box::new(cache_sweep::CacheSweep),
        Box::new(qos_sweep::QosSweep),
        Box::new(chaos_sweep::ChaosSweep),
        Box::new(ablations::AblMme),
        Box::new(ablations::AblWatermark),
        Box::new(ablations::ExtMultiRecsys),
        Box::new(ablations::ExtTraining),
        Box::new(ablations::ExtGaudi3),
        Box::new(sim_speed::SimSpeed),
        Box::new(tp_sweep::TpSweep),
    ]
}

/// Look up one experiment by id. Hyphens and underscores are
/// interchangeable (`repro run cluster-sweep` finds `cluster_sweep` —
/// ids stay underscore-only so the artifact file name is shell-friendly).
pub fn find(id: &str) -> Option<Box<dyn Experiment>> {
    let canon = id.replace('-', "_");
    registry().into_iter().find(|e| e.id() == canon)
}

/// Run one experiment by id under its default params; None if unknown.
pub fn run_experiment(id: &str) -> Option<Vec<Report>> {
    find(id).map(|e| e.run(&e.params()))
}

/// Run everything (the `repro run all` path).
pub fn run_all() -> Vec<Report> {
    registry().iter().flat_map(|e| e.run(&e.params())).collect()
}

/// Evaluate an experiment's expectations over already-produced reports.
pub fn evaluate(e: &dyn Experiment, reports: &[Report]) -> Vec<ExpectationResult> {
    e.expectations().iter().map(|x| x.evaluate(reports)).collect()
}

/// Schema tag of the per-experiment JSON artifact.
pub const ARTIFACT_SCHEMA: &str = "cuda-myth/experiment-v1";

/// The per-experiment JSON artifact written by `repro run --json`:
/// schema tag, id/title, the params the run used, every report with raw
/// typed cells, and the evaluated paper-claim expectations.
pub fn artifact_json(
    e: &dyn Experiment,
    params: &Params,
    reports: &[Report],
    results: &[ExpectationResult],
) -> Json {
    Json::obj(vec![
        ("schema", Json::Str(ARTIFACT_SCHEMA.into())),
        ("experiment", Json::Str(e.id().into())),
        ("title", Json::Str(e.title().into())),
        ("params", params.to_json()),
        ("reports", Json::Arr(reports.iter().map(|r| r.to_json()).collect())),
        ("expectations", Json::Arr(results.iter().map(|r| r.to_json()).collect())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_every_table_and_figure() {
        let ids: Vec<&str> = registry().iter().map(|e| e.id()).collect();
        for required in [
            "table1", "fig4", "fig5", "fig7", "fig8", "fig9", "fig10", "fig11", "fig12",
            "fig13", "fig15", "fig17", "cluster", "cluster_sweep", "cache_sweep", "qos_sweep",
            "chaos_sweep", "sim_speed", "tp_sweep",
        ] {
            assert!(ids.contains(&required), "missing {required}");
        }
        assert_eq!(ids.len(), 24, "registry must keep all 24 entries");
    }

    #[test]
    fn unknown_experiment_is_none() {
        assert!(run_experiment("fig99").is_none());
        assert!(find("fig99").is_none());
    }

    #[test]
    fn find_accepts_hyphenated_ids() {
        assert_eq!(find("cluster-sweep").unwrap().id(), "cluster_sweep");
        assert_eq!(find("cluster_sweep").unwrap().id(), "cluster_sweep");
        assert_eq!(find("cache-sweep").unwrap().id(), "cache_sweep");
        assert_eq!(find("qos-sweep").unwrap().id(), "qos_sweep");
        assert_eq!(find("chaos-sweep").unwrap().id(), "chaos_sweep");
        assert_eq!(find("sim-speed").unwrap().id(), "sim_speed");
        assert_eq!(find("tp-sweep").unwrap().id(), "tp_sweep");
        assert!(find("cluster-").is_none());
    }

    #[test]
    fn params_set_get_and_json() {
        let p = Params::new().with("rate", 24.0).with("seed", 29.0).with("rate", 30.0);
        assert_eq!(p.get("rate"), Some(30.0));
        assert_eq!(p.get_or("missing", 7.0), 7.0);
        assert_eq!(p.iter().count(), 2);
        let j = p.to_json();
        assert_eq!(j.get("rate").unwrap().as_f64(), Some(30.0));
    }

    #[test]
    fn artifact_shape_is_schema_stable() {
        let e = find("table1").unwrap();
        let params = e.params();
        let reports = e.run(&params);
        let results = evaluate(e.as_ref(), &reports);
        let j = artifact_json(e.as_ref(), &params, &reports, &results);
        let parsed = Json::parse(&j.dump()).unwrap();
        assert_eq!(parsed.get("schema").unwrap().as_str(), Some(ARTIFACT_SCHEMA));
        assert_eq!(parsed.get("experiment").unwrap().as_str(), Some("table1"));
        assert!(!parsed.get("reports").unwrap().as_arr().unwrap().is_empty());
        assert!(!parsed.get("expectations").unwrap().as_arr().unwrap().is_empty());
    }
}
