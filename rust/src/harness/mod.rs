//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation section (`repro run <exp>`, `repro list`). Each module
//! returns `Report`s — the same rows/series the paper plots — rendered by
//! `util::table`.

pub mod ablations;
pub mod cluster;
pub mod fig10;
pub mod fig11;
pub mod fig12;
pub mod fig13;
pub mod fig15;
pub mod fig17;
pub mod fig4;
pub mod fig5;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod table1;

use crate::util::table::Report;

/// A runnable experiment (one paper table/figure).
pub struct Experiment {
    pub id: &'static str,
    pub title: &'static str,
    pub run: fn() -> Vec<Report>,
}

/// All experiments, in paper order.
pub fn registry() -> Vec<Experiment> {
    vec![
        Experiment { id: "table1", title: "Table 1: A100 vs Gaudi-2 specification ratios", run: table1::run },
        Experiment { id: "fig4", title: "Fig 4: GEMM roofline (achieved TFLOPS, BF16)", run: fig4::run },
        Experiment { id: "fig5", title: "Fig 5: GEMM compute utilization heatmaps", run: fig5::run },
        Experiment { id: "fig7", title: "Fig 7: MME geometry configurability", run: fig7::run },
        Experiment { id: "fig8", title: "Fig 8: STREAM microbenchmarks on TPC", run: fig8::run },
        Experiment { id: "fig9", title: "Fig 9: vector gather/scatter bandwidth utilization", run: fig9::run },
        Experiment { id: "fig10", title: "Fig 10: collective communication bus bandwidth", run: fig10::run },
        Experiment { id: "fig11", title: "Fig 11: RecSys (RM1/RM2) speedup + energy", run: fig11::run },
        Experiment { id: "fig12", title: "Fig 12: LLM serving speedup + latency breakdown", run: fig12::run },
        Experiment { id: "fig13", title: "Fig 13: LLM serving energy efficiency", run: fig13::run },
        Experiment { id: "fig15", title: "Fig 15: embedding lookup operators (DLRM case study)", run: fig15::run },
        Experiment { id: "fig17", title: "Fig 17: vLLM PagedAttention case study", run: fig17::run },
        Experiment { id: "cluster", title: "Cluster: iso-SLO replica sizing, Gaudi-2 vs A100 (multi-replica serving)", run: cluster::run },
        Experiment { id: "abl-mme", title: "Ablation: MME reconfigurability", run: ablations::mme_reconfig },
        Experiment { id: "abl-watermark", title: "Ablation: KV watermark vs preemptions", run: ablations::watermark_sweep },
        Experiment { id: "ext-multi-recsys", title: "Extension: multi-device RecSys serving", run: ablations::multi_recsys },
        Experiment { id: "ext-training", title: "Extension: training-step comparison", run: ablations::training },
        Experiment { id: "ext-gaudi3", title: "Extension: Gaudi-3 projection", run: ablations::gaudi3_projection },
    ]
}

/// Run one experiment by id; returns its reports or None if unknown.
pub fn run_experiment(id: &str) -> Option<Vec<Report>> {
    registry().into_iter().find(|e| e.id == id).map(|e| (e.run)())
}

/// Run everything (the `repro run all` path).
pub fn run_all() -> Vec<Report> {
    registry().into_iter().flat_map(|e| (e.run)()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_every_table_and_figure() {
        let ids: Vec<&str> = registry().iter().map(|e| e.id).collect();
        for required in [
            "table1", "fig4", "fig5", "fig7", "fig8", "fig9", "fig10", "fig11", "fig12",
            "fig13", "fig15", "fig17",
        ] {
            assert!(ids.contains(&required), "missing {required}");
        }
    }

    #[test]
    fn unknown_experiment_is_none() {
        assert!(run_experiment("fig99").is_none());
    }
}
