//! Fig 5: compute-utilization heatmaps — (a) square GEMMs along M=K=N,
//! (b) irregular GEMMs (M=K large, N small).

use crate::config::DeviceKind;
use crate::ops::gemm;
use crate::sim::Dtype;
use crate::util::stats::mean;
use crate::util::table::{fmt_pct, Report};

pub fn run() -> Vec<Report> {
    let mut sq = Report::new("Fig 5(a): square GEMM compute utilization (M=K=N)");
    sq.header(&["size", "Gaudi-2", "A100", "gap (pp)"]);
    let mut gaps = Vec::new();
    for &s in &gemm::SQUARE_SIZES {
        let g = gemm::run(DeviceKind::Gaudi2, s, s, s, Dtype::Bf16);
        let a = gemm::run(DeviceKind::A100, s, s, s, Dtype::Bf16);
        let gap = g.exec.utilization - a.exec.utilization;
        gaps.push(gap);
        sq.row(vec![
            format!("{s}"),
            fmt_pct(g.exec.utilization),
            fmt_pct(a.exec.utilization),
            format!("{:+.1}", 100.0 * gap),
        ]);
    }

    let mut irr = Report::new("Fig 5(b): irregular GEMM compute utilization (N fixed small)");
    irr.header(&["shape (M=K, N)", "Gaudi-2", "A100", "gap (pp)"]);
    for (m, k, n) in gemm::fig5_irregular_grid() {
        let g = gemm::run(DeviceKind::Gaudi2, m, k, n, Dtype::Bf16);
        let a = gemm::run(DeviceKind::A100, m, k, n, Dtype::Bf16);
        let gap = g.exec.utilization - a.exec.utilization;
        gaps.push(gap);
        irr.row(vec![
            format!("({m}, {n})"),
            fmt_pct(g.exec.utilization),
            fmt_pct(a.exec.utilization),
            format!("{:+.1}", 100.0 * gap),
        ]);
    }
    let avg = mean(&gaps);
    let max = gaps.iter().cloned().fold(f64::MIN, f64::max);
    irr.note(format!(
        "avg gap {:+.1}pp (paper: +4.5pp), max {:+.1}pp (paper: +32pp @2048^3)",
        100.0 * avg,
        100.0 * max
    ));
    vec![sq, irr]
}

#[cfg(test)]
mod tests {
    #[test]
    fn two_heatmaps_with_notes() {
        let reports = super::run();
        assert_eq!(reports.len(), 2);
        assert!(reports[1].render().contains("avg gap"));
    }
}
