//! Fig 5: compute-utilization heatmaps — (a) square GEMMs along M=K=N,
//! (b) irregular GEMMs (M=K large, N small) — plus a typed summary of the
//! paper's aggregate gap claims.

use crate::config::DeviceKind;
use crate::harness::{Experiment, Params};
use crate::ops::gemm;
use crate::report::{Cell, Check, Expectation, Report, Selector, Unit};
use crate::sim::Dtype;
use crate::util::stats::mean;

pub struct Fig5;

impl Experiment for Fig5 {
    fn id(&self) -> &'static str {
        "fig5"
    }

    fn title(&self) -> &'static str {
        "Fig 5: GEMM compute utilization heatmaps"
    }

    fn run(&self, _params: &Params) -> Vec<Report> {
        let mut sq = Report::new("Fig 5(a): square GEMM compute utilization (M=K=N)");
        sq.header(&["size", "Gaudi-2", "A100", "gap (pp)"]);
        let mut gaps = Vec::new();
        for &s in &gemm::SQUARE_SIZES {
            let g = gemm::run(DeviceKind::Gaudi2, s, s, s, Dtype::Bf16);
            let a = gemm::run(DeviceKind::A100, s, s, s, Dtype::Bf16);
            let gap = g.exec.utilization - a.exec.utilization;
            gaps.push(gap);
            sq.row(vec![
                Cell::count(s),
                Cell::val(g.exec.utilization, Unit::Percent),
                Cell::val(a.exec.utilization, Unit::Percent),
                Cell::val(100.0 * gap, Unit::Pp),
            ]);
        }

        let mut irr = Report::new("Fig 5(b): irregular GEMM compute utilization (N fixed small)");
        irr.header(&["shape (M=K, N)", "Gaudi-2", "A100", "gap (pp)"]);
        for (m, k, n) in gemm::fig5_irregular_grid() {
            let g = gemm::run(DeviceKind::Gaudi2, m, k, n, Dtype::Bf16);
            let a = gemm::run(DeviceKind::A100, m, k, n, Dtype::Bf16);
            let gap = g.exec.utilization - a.exec.utilization;
            gaps.push(gap);
            irr.row(vec![
                Cell::text(format!("({m}, {n})")),
                Cell::val(g.exec.utilization, Unit::Percent),
                Cell::val(a.exec.utilization, Unit::Percent),
                Cell::val(100.0 * gap, Unit::Pp),
            ]);
        }

        // Aggregates over BOTH panels — the note of the old rendering,
        // now typed so --check can regress them.
        let avg = 100.0 * mean(&gaps);
        let max = 100.0 * gaps.iter().cloned().fold(f64::MIN, f64::max);
        let mut summary = Report::new("Fig 5 summary: utilization gap, Gaudi-2 minus A100");
        summary.header(&["aggregate", "gap (pp)"]);
        summary.row(vec![Cell::text("avg gap"), Cell::val(avg, Unit::Pp)]);
        summary.row(vec![Cell::text("max gap"), Cell::val(max, Unit::Pp)]);
        summary.note("paper: +4.5pp average, +32pp max (at 2048^3)");
        vec![sq, irr, summary]
    }

    fn expectations(&self, _params: &Params) -> Vec<Expectation> {
        vec![
            Expectation::new(
                "fig5.avg_gap",
                "Gaudi-2's utilization averages ~4.5pp above the A100's over the GEMM grids",
                Selector::cell("Fig 5 summary", "avg gap", "gap (pp)"),
                Check::Within { target: 4.5, tol: 4.0 },
            ),
            Expectation::new(
                "fig5.max_gap",
                "the largest gap is ~32pp (the 2048^3 wave-quantization cliff)",
                Selector::cell("Fig 5 summary", "max gap", "gap (pp)"),
                Check::Within { target: 32.0, tol: 14.0 },
            ),
        ]
    }
}

/// Run with default params (convenience for tests and library callers).
pub fn run() -> Vec<Report> {
    Fig5.run(&Fig5.params())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_heatmaps_and_a_summary() {
        let reports = run();
        assert_eq!(reports.len(), 3);
        assert!(reports[2].value_at("avg gap", "gap (pp)").is_some());
    }

    #[test]
    fn expectations_pass() {
        let reports = run();
        for e in Fig5.expectations(&Fig5.params()) {
            let res = e.evaluate(&reports);
            assert!(res.pass, "{}: {}", res.id, res.detail);
        }
    }
}
