//! Fig 8: STREAM microbenchmarks — (a) access granularity, (b) unroll
//! factor, (c) TPC weak scaling, (d,e,f) operational-intensity sweeps vs
//! A100.

use crate::config::DeviceKind;
use crate::sim::tpc::{self, StreamOp, NUM_TPCS};
use crate::sim::{simd, Dtype};
use crate::util::table::{fmt3, fmt_pct, Report};

const OPS: [StreamOp; 3] = [StreamOp::Add, StreamOp::Scale, StreamOp::Triad];

pub fn run() -> Vec<Report> {
    let spec = DeviceKind::Gaudi2.spec();
    let a100 = DeviceKind::A100.spec();

    let mut a = Report::new("Fig 8(a): single-TPC throughput vs access granularity (no unroll)");
    a.header(&["granularity (B)", "ADD GF", "SCALE GF", "TRIAD GF"]);
    for g in [2.0f64, 8.0, 32.0, 64.0, 128.0, 256.0, 512.0, 2048.0] {
        a.row(
            std::iter::once(format!("{g}"))
                .chain(OPS.iter().map(|&op| {
                    fmt3(tpc::single_tpc_throughput(op, 1, g, Dtype::Bf16) / 1e9)
                }))
                .collect(),
        );
    }
    a.note("cliff below the 256 B minimum access granularity");

    let mut b = Report::new("Fig 8(b): single-TPC throughput vs unroll factor (256 B)");
    b.header(&["unroll", "ADD GF", "SCALE GF", "TRIAD GF"]);
    for u in [1usize, 2, 4, 8, 16] {
        b.row(
            std::iter::once(format!("{u}"))
                .chain(OPS.iter().map(|&op| {
                    fmt3(tpc::single_tpc_throughput(op, u, 256.0, Dtype::Bf16) / 1e9)
                }))
                .collect(),
        );
    }
    b.note("SCALE benefits most (1 load/iter leaves pipeline slots to fill)");

    let mut c = Report::new("Fig 8(c): weak scaling over TPCs (unroll 4)");
    c.header(&["TPCs", "ADD GF", "SCALE GF", "TRIAD GF"]);
    for n in [1usize, 2, 4, 8, 11, 12, 15, 20, NUM_TPCS] {
        c.row(
            std::iter::once(format!("{n}"))
                .chain(OPS.iter().map(|&op| {
                    fmt3(tpc::weak_scaled_throughput(&spec, op, n, Dtype::Bf16) / 1e9)
                }))
                .collect(),
        );
    }
    c.note("paper: saturates ~330 / ~530 / ~670 GFLOPS at 11-15 TPCs");

    let mut d = Report::new("Fig 8(d,e,f): operational-intensity sweep, Gaudi-2 vs A100");
    d.header(&["op", "intensity", "Gaudi GF", "A100 GF"]);
    for &op in &OPS {
        for mult in [1.0f64, 4.0, 16.0, 64.0, 256.0, 4096.0] {
            let i = op.intensity(Dtype::Bf16) * mult;
            d.row(vec![
                op.name().into(),
                fmt3(i),
                fmt3(tpc::intensity_sweep_throughput(&spec, op, i) / 1e9),
                fmt3(simd::intensity_sweep_throughput(&a100, op, i) / 1e9),
            ]);
        }
        let g_sat = tpc::intensity_sweep_throughput(&spec, op, 1e5);
        let a_sat = simd::intensity_sweep_throughput(&a100, op, 1e5);
        d.note(format!(
            "{} saturation: Gaudi {} TF ({}), A100 {} TF ({})",
            op.name(),
            fmt3(g_sat / 1e12),
            fmt_pct(g_sat / tpc::chip_peak_flops(&spec, op)),
            fmt3(a_sat / 1e12),
            fmt_pct(a_sat / simd::chip_peak_flops(&a100, op)),
        ));
    }
    vec![a, b, c, d]
}

#[cfg(test)]
mod tests {
    #[test]
    fn four_panels() {
        let reports = super::run();
        assert_eq!(reports.len(), 4);
        let sat = reports[3].render();
        // TRIAD saturates at ~99%, ADD/SCALE at ~50% on both devices.
        assert!(sat.contains("99"), "{sat}");
        assert!(sat.contains("50"), "{sat}");
    }
}
