//! Fig 8: STREAM microbenchmarks — (a) access granularity, (b) unroll
//! factor, (c) TPC weak scaling, (d,e,f) operational-intensity sweeps vs
//! A100 — plus a typed saturation summary.

use crate::config::DeviceKind;
use crate::harness::{Experiment, Params};
use crate::report::{Cell, Check, Expectation, Report, Selector, Unit};
use crate::sim::tpc::{self, StreamOp, NUM_TPCS};
use crate::sim::{simd, Dtype};

const OPS: [StreamOp; 3] = [StreamOp::Add, StreamOp::Scale, StreamOp::Triad];

pub struct Fig8;

impl Experiment for Fig8 {
    fn id(&self) -> &'static str {
        "fig8"
    }

    fn title(&self) -> &'static str {
        "Fig 8: STREAM microbenchmarks on TPC"
    }

    fn run(&self, _params: &Params) -> Vec<Report> {
        let spec = DeviceKind::Gaudi2.spec();
        let a100 = DeviceKind::A100.spec();

        let mut a = Report::new("Fig 8(a): single-TPC throughput vs access granularity (no unroll)");
        a.header(&["granularity (B)", "ADD GF", "SCALE GF", "TRIAD GF"]);
        for g in [2.0f64, 8.0, 32.0, 64.0, 128.0, 256.0, 512.0, 2048.0] {
            a.row(
                std::iter::once(Cell::val(g, Unit::Count))
                    .chain(OPS.iter().map(|&op| {
                        Cell::val(tpc::single_tpc_throughput(op, 1, g, Dtype::Bf16) / 1e9, Unit::Gflops)
                    }))
                    .collect(),
            );
        }
        a.note("cliff below the 256 B minimum access granularity");

        let mut b = Report::new("Fig 8(b): single-TPC throughput vs unroll factor (256 B)");
        b.header(&["unroll", "ADD GF", "SCALE GF", "TRIAD GF"]);
        for u in [1usize, 2, 4, 8, 16] {
            b.row(
                std::iter::once(Cell::count(u))
                    .chain(OPS.iter().map(|&op| {
                        Cell::val(
                            tpc::single_tpc_throughput(op, u, 256.0, Dtype::Bf16) / 1e9,
                            Unit::Gflops,
                        )
                    }))
                    .collect(),
            );
        }
        b.note("SCALE benefits most (1 load/iter leaves pipeline slots to fill)");

        let mut c = Report::new("Fig 8(c): weak scaling over TPCs (unroll 4)");
        c.header(&["TPCs", "ADD GF", "SCALE GF", "TRIAD GF"]);
        for n in [1usize, 2, 4, 8, 11, 12, 15, 20, NUM_TPCS] {
            c.row(
                std::iter::once(Cell::count(n))
                    .chain(OPS.iter().map(|&op| {
                        Cell::val(
                            tpc::weak_scaled_throughput(&spec, op, n, Dtype::Bf16) / 1e9,
                            Unit::Gflops,
                        )
                    }))
                    .collect(),
            );
        }
        c.note("paper: saturates ~330 / ~530 / ~670 GFLOPS at 11-15 TPCs");

        let mut d = Report::new("Fig 8(d,e,f): operational-intensity sweep, Gaudi-2 vs A100");
        d.header(&["op", "intensity", "Gaudi GF", "A100 GF"]);
        for &op in &OPS {
            for mult in [1.0f64, 4.0, 16.0, 64.0, 256.0, 4096.0] {
                let i = op.intensity(Dtype::Bf16) * mult;
                d.row(vec![
                    Cell::text(op.name()),
                    Cell::val(i, Unit::FlopPerByte),
                    Cell::val(tpc::intensity_sweep_throughput(&spec, op, i) / 1e9, Unit::Gflops),
                    Cell::val(simd::intensity_sweep_throughput(&a100, op, i) / 1e9, Unit::Gflops),
                ]);
            }
        }

        // Saturation summary — previously free-text notes, now typed.
        let mut sat = Report::new("Fig 8 saturation: compute-bound plateau vs chip peak");
        sat.header(&["op", "Gaudi TF", "Gaudi frac", "A100 TF", "A100 frac"]);
        for &op in &OPS {
            let g_sat = tpc::intensity_sweep_throughput(&spec, op, 1e5);
            let a_sat = simd::intensity_sweep_throughput(&a100, op, 1e5);
            sat.row(vec![
                Cell::text(op.name()),
                Cell::val(g_sat / 1e12, Unit::Tflops),
                Cell::val(g_sat / tpc::chip_peak_flops(&spec, op), Unit::Percent),
                Cell::val(a_sat / 1e12, Unit::Tflops),
                Cell::val(a_sat / simd::chip_peak_flops(&a100, op), Unit::Percent),
            ]);
        }
        sat.note("TRIAD saturates near peak on both devices; ADD/SCALE stall near 50%");
        vec![a, b, c, d, sat]
    }

    fn expectations(&self, _params: &Params) -> Vec<Expectation> {
        vec![
            Expectation::new(
                "fig8.triad_weak_scaling",
                "chip-level TRIAD saturates around 670 GFLOPS",
                Selector::cell("Fig 8(c)", "24", "TRIAD GF"),
                Check::Within { target: 670.0, tol: 50.0 },
            ),
            Expectation::new(
                "fig8.triad_saturation",
                "TRIAD reaches ~99% of vector peak at high intensity",
                Selector::cell("Fig 8 saturation", "TRIAD", "Gaudi frac"),
                Check::Ge(0.95),
            ),
            Expectation::new(
                "fig8.add_saturation",
                "ADD stalls near 50% of vector peak (load/store bound)",
                Selector::cell("Fig 8 saturation", "ADD", "Gaudi frac"),
                Check::Within { target: 0.50, tol: 0.08 },
            ),
        ]
    }
}

/// Run with default params (convenience for tests and library callers).
pub fn run() -> Vec<Report> {
    Fig8.run(&Fig8.params())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn five_panels_with_saturation_bands() {
        let reports = run();
        assert_eq!(reports.len(), 5);
        let triad = reports[4].value_at("TRIAD", "Gaudi frac").unwrap();
        assert!(triad.x > 0.95, "TRIAD sat {}", triad.x);
        let add = reports[4].value_at("ADD", "Gaudi frac").unwrap();
        assert!((add.x - 0.5).abs() < 0.1, "ADD sat {}", add.x);
    }

    #[test]
    fn expectations_pass() {
        let reports = run();
        for e in Fig8.expectations(&Fig8.params()) {
            let res = e.evaluate(&reports);
            assert!(res.pass, "{}: {}", res.id, res.detail);
        }
    }
}
