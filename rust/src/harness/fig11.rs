//! Fig 11: Gaudi-2 vs A100 for single-device RecSys serving (RM1/RM2):
//! (a) performance heatmap, (b) energy-efficiency heatmap.

use crate::config::DeviceKind;
use crate::harness::{Experiment, Params};
use crate::models::dlrm::{self, DlrmConfig};
use crate::report::{Agg, Cell, Check, Expectation, Report, Selector, Unit};

pub struct Fig11;

impl Experiment for Fig11 {
    fn id(&self) -> &'static str {
        "fig11"
    }

    fn title(&self) -> &'static str {
        "Fig 11: RecSys (RM1/RM2) speedup + energy"
    }

    fn run(&self, _params: &Params) -> Vec<Report> {
        let mut out = Vec::new();
        for cfg in [DlrmConfig::rm1(), DlrmConfig::rm2()] {
            let mut perf =
                Report::new(format!("Fig 11(a): {} speedup (Gaudi-2 over A100)", cfg.name));
            perf.header(&["batch", "dim32", "dim64", "dim128", "dim256", "dim512"]);
            let mut energy =
                Report::new(format!("Fig 11(b): {} energy-efficiency (Gaudi-2 over A100)", cfg.name));
            energy.header(&["batch", "dim32", "dim64", "dim128", "dim256", "dim512"]);
            for &batch in &[256usize, 1024, 4096, 16384] {
                let mut prow = vec![Cell::count(batch)];
                let mut erow = vec![Cell::count(batch)];
                for &dim in &[32usize, 64, 128, 256, 512] {
                    let g = dlrm::serve(&cfg, DeviceKind::Gaudi2, batch, dim);
                    let a = dlrm::serve(&cfg, DeviceKind::A100, batch, dim);
                    prow.push(Cell::val(a.time / g.time, Unit::Ratio));
                    erow.push(Cell::val(
                        g.samples_per_joule(batch) / a.samples_per_joule(batch),
                        Unit::Ratio,
                    ));
                }
                perf.row(prow);
                energy.row(erow);
            }
            perf.note(format!(
                "paper: {} averages ~{}",
                cfg.name,
                if cfg.name == "RM1" { "0.78x" } else { "0.82x" }
            ));
            energy.note("paper: ~0.78x energy-efficiency combined");
            out.push(perf);
            out.push(energy);
        }
        out
    }

    fn expectations(&self, _params: &Params) -> Vec<Expectation> {
        vec![
            Expectation::new(
                "fig11.rm1_avg_speedup",
                "Gaudi-2 trails the A100 on RM1 (~0.78x average over the grid)",
                Selector::body("RM1 speedup", Agg::Mean),
                Check::Within { target: 0.78, tol: 0.12 },
            ),
            Expectation::new(
                "fig11.rm2_avg_speedup",
                "Gaudi-2 trails the A100 on RM2 (~0.82x average over the grid)",
                Selector::body("RM2 speedup", Agg::Mean),
                Check::Within { target: 0.82, tol: 0.12 },
            ),
            Expectation::new(
                "fig11.gaudi_near_parity_somewhere",
                "wide-vector large-batch cells reach (near-)parity",
                Selector::body("RM2 speedup", Agg::Max),
                Check::Ge(0.95),
            ),
        ]
    }
}

/// Run with default params (convenience for tests and library callers).
pub fn run() -> Vec<Report> {
    Fig11.run(&Fig11.params())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_heatmaps() {
        let reports = run();
        assert_eq!(reports.len(), 4);
        for r in &reports {
            assert_eq!(r.num_rows(), 4);
            assert_eq!(r.body_values().len(), 20, "{}", r.title());
        }
    }

    #[test]
    fn expectations_pass() {
        let reports = run();
        for e in Fig11.expectations(&Fig11.params()) {
            let res = e.evaluate(&reports);
            assert!(res.pass, "{}: {}", res.id, res.detail);
        }
    }
}
