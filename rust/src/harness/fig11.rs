//! Fig 11: Gaudi-2 vs A100 for single-device RecSys serving (RM1/RM2):
//! (a) performance heatmap, (b) energy-efficiency heatmap.

use crate::config::DeviceKind;
use crate::models::dlrm::{self, DlrmConfig};
use crate::util::stats::mean;
use crate::util::table::{fmt_ratio, Report};

pub fn run() -> Vec<Report> {
    let mut out = Vec::new();
    for cfg in [DlrmConfig::rm1(), DlrmConfig::rm2()] {
        let mut perf = Report::new(format!("Fig 11(a): {} speedup (Gaudi-2 over A100)", cfg.name));
        perf.header(&["batch", "dim32", "dim64", "dim128", "dim256", "dim512"]);
        let mut energy =
            Report::new(format!("Fig 11(b): {} energy-efficiency (Gaudi-2 over A100)", cfg.name));
        energy.header(&["batch", "dim32", "dim64", "dim128", "dim256", "dim512"]);
        let mut speedups = Vec::new();
        let mut effs = Vec::new();
        for &batch in &[256usize, 1024, 4096, 16384] {
            let mut prow = vec![batch.to_string()];
            let mut erow = vec![batch.to_string()];
            for &dim in &[32usize, 64, 128, 256, 512] {
                let g = dlrm::serve(&cfg, DeviceKind::Gaudi2, batch, dim);
                let a = dlrm::serve(&cfg, DeviceKind::A100, batch, dim);
                let s = a.time / g.time;
                let e = g.samples_per_joule(batch) / a.samples_per_joule(batch);
                speedups.push(s);
                effs.push(e);
                prow.push(fmt_ratio(s));
                erow.push(fmt_ratio(e));
            }
            perf.row(prow);
            energy.row(erow);
        }
        perf.note(format!(
            "avg speedup {} (paper: {} ~{})",
            fmt_ratio(mean(&speedups)),
            cfg.name,
            if cfg.name == "RM1" { "0.78x" } else { "0.82x" }
        ));
        energy.note(format!("avg energy-eff {} (paper: ~0.78x combined)", fmt_ratio(mean(&effs))));
        out.push(perf);
        out.push(energy);
    }
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn four_heatmaps() {
        let reports = super::run();
        assert_eq!(reports.len(), 4);
        // Every heatmap is 4 batch rows x 5 dim cols.
        for r in &reports {
            assert_eq!(r.num_rows(), 4);
        }
    }

    #[test]
    fn gaudi_wins_somewhere_and_loses_overall() {
        let text: String = super::run().iter().map(|r| r.render()).collect();
        // Wide-vector large-batch cells exceed 1x; notes show a <1x average.
        assert!(text.contains("avg speedup 0."), "{text}");
        let has_win = text
            .lines()
            .filter(|l| l.contains('x') && !l.contains("avg"))
            .any(|l| l.split_whitespace().skip(1).any(|c| c.starts_with('1') && c.ends_with('x')));
        assert!(has_win, "expected at least one >1x cell\n{text}");
    }
}
