//! Fig 17: the vLLM case study (§4.2) — PagedAttention throughput of
//! vLLM_opt vs vLLM_base vs A100 (a–c), and end-to-end serving on the
//! Dynamic-Sonnet-like workload sweeping the max decode batch size (d, e),
//! run through the real serving engine.

use crate::config::{DeviceKind, ServingConfig};
use crate::models::llama::LlamaConfig;
use crate::ops::attention::{run as attn, PagedAttnImpl, PagedAttnWork};
use crate::serving::engine::{Engine, SimBackend};
use crate::util::stats::mean;
use crate::util::table::{fmt3, fmt_ratio, Report};
use crate::workload::DynamicSonnet;

pub fn run() -> Vec<Report> {
    let mut out = Vec::new();

    // (a) opt vs base, 0% padding, seq x batch.
    let mut a = Report::new("Fig 17(a): vLLM_opt speedup over vLLM_base (0% padding)");
    a.header(&["seq len", "b8", "b16", "b32", "b64"]);
    let mut ratios = Vec::new();
    for &s in &[512usize, 1024, 2048, 4096] {
        let mut row = vec![s.to_string()];
        for &b in &[8usize, 16, 32, 64] {
            let w = PagedAttnWork::llama8b(b, s);
            let r = attn(PagedAttnImpl::GaudiVllmBase, w).time
                / attn(PagedAttnImpl::GaudiVllmOpt, w).time;
            ratios.push(r);
            row.push(fmt_ratio(r));
        }
        a.row(row);
    }
    a.note(format!("avg {} (paper: 7.4x)", fmt_ratio(mean(&ratios))));
    out.push(a);

    // (b) padding sweep at seq 4K, batch 32.
    let mut b = Report::new("Fig 17(b): speedup vs zero-padded fraction (seq 4K, batch 32)");
    b.header(&["padding", "speedup"]);
    let mut pr = Vec::new();
    for p in [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9] {
        let eff_len = ((4096.0 * (1.0 - p)) as usize).max(1);
        let w = PagedAttnWork { kv_len: eff_len, padded_len: 4096, ..PagedAttnWork::llama8b(32, 4096) };
        let r =
            attn(PagedAttnImpl::GaudiVllmBase, w).time / attn(PagedAttnImpl::GaudiVllmOpt, w).time;
        pr.push(r);
        b.row(vec![format!("{:.0}%", p * 100.0), fmt_ratio(r)]);
    }
    b.note(format!(
        "avg {} max {} (paper: avg 21x, max 55.7x)",
        fmt_ratio(mean(&pr)),
        fmt_ratio(pr.iter().cloned().fold(f64::MIN, f64::max))
    ));
    out.push(b);

    // (c) opt vs A100.
    let mut c = Report::new("Fig 17(c): vLLM_opt (Gaudi-2) vs A100 PagedAttention");
    c.header(&["seq len", "b8", "b16", "b32", "b64"]);
    let mut cr = Vec::new();
    for &s in &[512usize, 1024, 2048, 4096] {
        let mut row = vec![s.to_string()];
        for &bsz in &[8usize, 16, 32, 64] {
            let w = PagedAttnWork::llama8b(bsz, s);
            let r =
                attn(PagedAttnImpl::A100Paged, w).time / attn(PagedAttnImpl::GaudiVllmOpt, w).time;
            cr.push(r);
            row.push(fmt_ratio(r));
        }
        c.row(row);
    }
    c.note(format!("avg {} (paper: 45% of A100)", fmt_ratio(mean(&cr))));
    out.push(c);

    // (d, e) end-to-end serving through the engine.
    let mut d = Report::new("Fig 17(d,e): e2e serving vs max decode batch (Dynamic-Sonnet-like)");
    d.header(&["max batch", "thpt tok/s (Gaudi)", "TTFT ms", "TPOT ms", "thpt tok/s (A100)"]);
    for &mb in &[8usize, 16, 32, 64, 128] {
        let g = serve_once(DeviceKind::Gaudi2, mb);
        let a100 = serve_once(DeviceKind::A100, mb);
        d.row(vec![
            mb.to_string(),
            fmt3(g.0),
            fmt3(g.1 * 1e3),
            fmt3(g.2 * 1e3),
            fmt3(a100.0),
        ]);
    }
    d.note("throughput rises then TTFT/TPOT degrade as the batch knob grows (paper Fig 17(d,e))");
    out.push(d);
    out
}

/// Run the simulated engine once; returns (tokens/s, mean TTFT, mean TPOT).
pub fn serve_once(device: DeviceKind, max_batch: usize) -> (f64, f64, f64) {
    let cfg = ServingConfig {
        device,
        max_decode_batch: max_batch,
        num_blocks: 8192,
        block_size: 128,
        max_seq_len: 4096,
        max_prefill_tokens: 8192,
        use_block_list: true,
        ..Default::default()
    };
    let backend = SimBackend::new(LlamaConfig::llama31_8b(), &cfg);
    let mut engine = Engine::new(cfg, backend);
    for req in DynamicSonnet::default().generate(96, f64::INFINITY, 17) {
        engine.submit(req);
    }
    let s = engine.run_to_completion();
    (s.throughput_tps, s.mean_ttft, s.mean_tpot)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_reports() {
        assert_eq!(run().len(), 4);
    }

    #[test]
    fn throughput_grows_then_tpot_degrades() {
        let (t8, _, p8) = serve_once(DeviceKind::Gaudi2, 8);
        let (t64, _, p64) = serve_once(DeviceKind::Gaudi2, 64);
        assert!(t64 > t8, "throughput should grow: {t8} -> {t64}");
        assert!(p64 > p8, "TPOT should degrade with batch: {p8} -> {p64}");
    }

    #[test]
    fn e2e_parity_with_a100() {
        // Paper: vLLM_opt Gaudi-2 reaches ~parity end-to-end (Amdahl:
        // PagedAttention is only part of the step).
        let (g, _, _) = serve_once(DeviceKind::Gaudi2, 64);
        let (a, _, _) = serve_once(DeviceKind::A100, 64);
        let ratio = g / a;
        assert!((0.75..1.45).contains(&ratio), "e2e ratio {ratio}");
    }
}
