//! Fig 17: the vLLM case study (§4.2) — PagedAttention throughput of
//! vLLM_opt vs vLLM_base vs A100 (a–c), and end-to-end serving on the
//! Dynamic-Sonnet-like workload sweeping the max decode batch size (d, e),
//! run through the real serving engine.

use crate::config::{DeviceKind, ServingConfig};
use crate::harness::{Experiment, Params};
use crate::models::llama::LlamaConfig;
use crate::ops::attention::{run as attn, PagedAttnImpl, PagedAttnWork};
use crate::report::{Agg, Cell, Check, Expectation, Report, Selector, Unit};
use crate::serving::engine::{Engine, SimBackend};
use crate::workload::DynamicSonnet;

pub struct Fig17;

impl Experiment for Fig17 {
    fn id(&self) -> &'static str {
        "fig17"
    }

    fn title(&self) -> &'static str {
        "Fig 17: vLLM PagedAttention case study"
    }

    fn params(&self) -> Params {
        Params::new().with("requests", 96.0).with("seed", 17.0)
    }

    fn run(&self, params: &Params) -> Vec<Report> {
        let requests = params.get_or("requests", 96.0) as usize;
        let seed = params.get_or("seed", 17.0) as u64;
        let mut out = Vec::new();

        // (a) opt vs base, 0% padding, seq x batch.
        let mut a = Report::new("Fig 17(a): vLLM_opt speedup over vLLM_base (0% padding)");
        a.header(&["seq len", "b8", "b16", "b32", "b64"]);
        for &s in &[512usize, 1024, 2048, 4096] {
            let mut row = vec![Cell::count(s)];
            for &b in &[8usize, 16, 32, 64] {
                let w = PagedAttnWork::llama8b(b, s);
                let r = attn(PagedAttnImpl::GaudiVllmBase, w).time
                    / attn(PagedAttnImpl::GaudiVllmOpt, w).time;
                row.push(Cell::val(r, Unit::Ratio));
            }
            a.row(row);
        }
        a.note("paper: 7.4x average");
        out.push(a);

        // (b) padding sweep at seq 4K, batch 32.
        let mut b = Report::new("Fig 17(b): speedup vs zero-padded fraction (seq 4K, batch 32)");
        b.header(&["padding", "speedup"]);
        for p in [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9] {
            let eff_len = ((4096.0 * (1.0 - p)) as usize).max(1);
            let w = PagedAttnWork {
                kv_len: eff_len,
                padded_len: 4096,
                ..PagedAttnWork::llama8b(32, 4096)
            };
            let r = attn(PagedAttnImpl::GaudiVllmBase, w).time
                / attn(PagedAttnImpl::GaudiVllmOpt, w).time;
            b.row(vec![Cell::val(p, Unit::Percent), Cell::val(r, Unit::Ratio)]);
        }
        b.note("paper: avg 21x, max 55.7x");
        out.push(b);

        // (c) opt vs A100.
        let mut c = Report::new("Fig 17(c): vLLM_opt (Gaudi-2) vs A100 PagedAttention");
        c.header(&["seq len", "b8", "b16", "b32", "b64"]);
        for &s in &[512usize, 1024, 2048, 4096] {
            let mut row = vec![Cell::count(s)];
            for &bsz in &[8usize, 16, 32, 64] {
                let w = PagedAttnWork::llama8b(bsz, s);
                let r = attn(PagedAttnImpl::A100Paged, w).time
                    / attn(PagedAttnImpl::GaudiVllmOpt, w).time;
                row.push(Cell::val(r, Unit::Ratio));
            }
            c.row(row);
        }
        c.note("paper: 45% of A100");
        out.push(c);

        // (d, e) end-to-end serving through the engine.
        let mut d = Report::new("Fig 17(d,e): e2e serving vs max decode batch (Dynamic-Sonnet-like)");
        d.header(&["max batch", "Gaudi tok/s", "TTFT ms", "TPOT ms", "A100 tok/s", "G/A"]);
        for &mb in &[8usize, 16, 32, 64, 128] {
            let g = serve_once(DeviceKind::Gaudi2, mb, requests, seed);
            let a100 = serve_once(DeviceKind::A100, mb, requests, seed);
            d.row(vec![
                Cell::count(mb),
                Cell::val(g.0, Unit::TokPerSec),
                Cell::val(g.1 * 1e3, Unit::Millis),
                Cell::val(g.2 * 1e3, Unit::Millis),
                Cell::val(a100.0, Unit::TokPerSec),
                Cell::val(g.0 / a100.0, Unit::Ratio),
            ]);
        }
        d.note("throughput rises then TTFT/TPOT degrade as the batch knob grows (paper Fig 17(d,e))");
        out.push(d);
        out
    }

    fn expectations(&self, _params: &Params) -> Vec<Expectation> {
        vec![
            Expectation::new(
                "fig17.opt_over_base",
                "vLLM_opt beats vLLM_base by ~7.4x on average (0% padding grid)",
                Selector::body("vLLM_opt speedup over vLLM_base", Agg::Mean),
                Check::Within { target: 7.4, tol: 2.5 },
            ),
            Expectation::new(
                "fig17.opt_vs_a100_kernel",
                "the optimized kernel still runs at ~45% of the A100's",
                Selector::body("vLLM_opt (Gaudi-2) vs A100", Agg::Mean),
                Check::Within { target: 0.45, tol: 0.12 },
            ),
            Expectation::new(
                "fig17.e2e_parity",
                "end-to-end serving reaches rough parity with A100 at batch 64 (Amdahl)",
                Selector::cell("Fig 17(d,e)", "64", "G/A"),
                Check::Between(0.75, 1.45),
            ),
        ]
    }
}

/// Run with default params (convenience for tests and library callers).
pub fn run() -> Vec<Report> {
    Fig17.run(&Fig17.params())
}

/// Run the simulated engine once; returns (tokens/s, mean TTFT, mean TPOT).
pub fn serve_once(device: DeviceKind, max_batch: usize, requests: usize, seed: u64) -> (f64, f64, f64) {
    let cfg = ServingConfig {
        device,
        max_decode_batch: max_batch,
        num_blocks: 8192,
        block_size: 128,
        max_seq_len: 4096,
        max_prefill_tokens: 8192,
        use_block_list: true,
        ..Default::default()
    };
    let backend = SimBackend::new(LlamaConfig::llama31_8b(), &cfg);
    let mut engine = Engine::new(cfg, backend);
    for req in DynamicSonnet::default().generate(requests, f64::INFINITY, seed) {
        engine.submit(req);
    }
    let s = engine.run_to_completion();
    (s.throughput_tps, s.mean_ttft, s.mean_tpot)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_reports() {
        assert_eq!(run().len(), 4);
    }

    #[test]
    fn throughput_grows_then_tpot_degrades() {
        let (t8, _, p8) = serve_once(DeviceKind::Gaudi2, 8, 96, 17);
        let (t64, _, p64) = serve_once(DeviceKind::Gaudi2, 64, 96, 17);
        assert!(t64 > t8, "throughput should grow: {t8} -> {t64}");
        assert!(p64 > p8, "TPOT should degrade with batch: {p8} -> {p64}");
    }

    #[test]
    fn expectations_pass() {
        let reports = run();
        for e in Fig17.expectations(&Fig17.params()) {
            let res = e.evaluate(&reports);
            assert!(res.pass, "{}: {}", res.id, res.detail);
        }
    }
}
