//! Cluster-sweep experiment: open-loop latency-vs-load curves across
//! heterogeneous fleet mixes — the paper's §7 iso-SLO sizing question
//! generalized to mixed Gaudi-2/A100 deployments. Offered load walks a
//! grid while the fleet mix steps from 100% Gaudi-2 through 75/50/25%
//! mixes to 100% A100 (4 replicas behind one cost-aware PrefixAffinity
//! router), producing one typed report per mix — the goodput-under-SLO
//! frontier curves — plus a frontier summary and derived-claims report.
//! `repro run cluster-sweep --json --out bench/` writes the whole sweep
//! as `BENCH_cluster_sweep.json` for the CI bench-diff gate.

use crate::config::{DeviceKind, ServingConfig};
use crate::harness::{Experiment, Params};
use crate::models::llama::LlamaConfig;
use crate::report::{Cell, Check, Expectation, Report, Selector, Unit};
use crate::serving::cluster::ClusterSim;
use crate::serving::qos::ClassSet;
use crate::serving::router::RoutePolicy;
use crate::util::par;
use crate::workload::OpenLoopTrace;

/// Replicas per fleet (every mix is a 4-replica deployment, so curves
/// compare mixes at equal fleet size).
const FLEET_SIZE: usize = 4;

/// (label, Gaudi-2 replica count) per mix; the rest are A100.
const MIXES: [(&str, usize); 5] = [
    ("Gaudi-2 100%", 4),
    ("Gaudi-2 75% / A100 25%", 3),
    ("Gaudi-2 50% / A100 50%", 2),
    ("Gaudi-2 25% / A100 75%", 1),
    ("A100 100%", 0),
];

struct Knobs {
    load_min_rps: f64,
    load_step_rps: f64,
    load_points: usize,
    duration_s: f64,
    seed: u64,
    slo_ttft_s: f64,
    slo_tpot_s: f64,
    prefix_groups: usize,
}

impl Knobs {
    fn from(params: &Params) -> Knobs {
        Knobs {
            load_min_rps: params.get_or("load_min_rps", 8.0),
            load_step_rps: params.get_or("load_step_rps", 8.0),
            load_points: params.get_or("load_points", 4.0) as usize,
            duration_s: params.get_or("duration_s", 3.0),
            seed: params.get_or("seed", 29.0) as u64,
            slo_ttft_s: params.get_or("slo_ttft_s", 1.0),
            slo_tpot_s: params.get_or("slo_tpot_s", 0.1),
            prefix_groups: params.get_or("prefix_groups", 8.0) as usize,
        }
    }

    /// The scalar SLO params as a single traffic class (`serving::qos`).
    fn classes(&self) -> ClassSet {
        ClassSet::scalar(self.slo_ttft_s, self.slo_tpot_s)
    }

    fn loads(&self) -> Vec<f64> {
        crate::harness::load_grid(self.load_min_rps, self.load_step_rps, self.load_points)
    }
}

fn mix_fleet(gaudi: usize) -> Vec<DeviceKind> {
    let mut fleet = vec![DeviceKind::Gaudi2; gaudi];
    fleet.extend(vec![DeviceKind::A100; FLEET_SIZE - gaudi]);
    fleet
}

fn mix_config(gaudi: usize) -> ServingConfig {
    ServingConfig {
        route_policy: RoutePolicy::PrefixAffinity,
        max_decode_batch: 32,
        num_blocks: 8192,
        ..Default::default()
    }
    .with_fleet(mix_fleet(gaudi))
}

/// One (mix, offered load) grid point.
struct SweepPoint {
    offered_rps: f64,
    submitted: usize,
    completed: usize,
    tps: f64,
    p99_ttft: f64,
    p99_tpot: f64,
    goodput_rps: f64,
    attainment: f64,
    requeues: u64,
}

fn run_point(k: &Knobs, gaudi: usize, rate: f64) -> SweepPoint {
    let cfg = mix_config(gaudi);
    let trace = OpenLoopTrace::new(rate, k.duration_s)
        .with_prefix_groups(k.prefix_groups)
        .generate(k.seed);
    let submitted = trace.len();
    let mut sim = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
    sim.submit_all(trace);
    let s = sim.run_to_completion();
    let fleet = sim.fleet_metrics();
    SweepPoint {
        offered_rps: rate,
        submitted,
        completed: sim.completed(),
        tps: s.throughput_tps,
        p99_ttft: s.p99_ttft,
        p99_tpot: s.p99_tpot,
        goodput_rps: fleet.goodput(&k.classes()),
        attainment: fleet.attainment(&k.classes()),
        requeues: sim.requeues,
    }
}

/// Max per-request metric delta between a `fleet: [gaudi2; 4]` cluster
/// and the homogeneous `replicas: 4, device: gaudi2` path on the same
/// trace — exact-zero by construction: a 100%-Gaudi mixed fleet must BE
/// the homogeneous fleet (also pinned by `rust/tests/integration_cluster.rs`).
fn mixed_vs_homogeneous_delta(k: &Knobs) -> f64 {
    let trace = || {
        OpenLoopTrace::new(k.load_min_rps, k.duration_s)
            .with_prefix_groups(k.prefix_groups)
            .generate(k.seed)
    };
    let run = |cfg: &ServingConfig| {
        let mut sim = ClusterSim::new(cfg, LlamaConfig::llama31_8b());
        sim.submit_all(trace());
        sim.run_to_completion();
        sim.fleet_metrics()
    };
    let mixed = run(&mix_config(FLEET_SIZE));
    // Same knobs, but expressed as the homogeneous `device x replicas`
    // config (mix_config already set replicas = FLEET_SIZE).
    let mut homog_cfg = mix_config(FLEET_SIZE);
    homog_cfg.fleet = Vec::new();
    homog_cfg.device = DeviceKind::Gaudi2;
    let homog = run(&homog_cfg);
    mixed.max_request_delta(&homog)
}

pub struct ClusterSweep;

impl Experiment for ClusterSweep {
    fn id(&self) -> &'static str {
        "cluster_sweep"
    }

    fn title(&self) -> &'static str {
        "Cluster sweep: goodput-under-SLO frontier across Gaudi-2/A100 fleet mixes"
    }

    fn params(&self) -> Params {
        Params::new()
            .with("load_min_rps", 8.0)
            .with("load_step_rps", 8.0)
            .with("load_points", 4.0)
            .with("duration_s", 3.0)
            .with("seed", 29.0)
            .with("slo_ttft_s", 1.0)
            .with("slo_tpot_s", 0.1)
            .with("prefix_groups", 8.0)
    }

    fn run(&self, params: &Params) -> Vec<Report> {
        let k = Knobs::from(params);
        let loads = k.loads();
        // Every (mix, load) point is an independent seeded simulation:
        // fan the flattened grid across the worker pool. Results come
        // back in submission order, so the reports (and the BENCH
        // artifact) are byte-identical at any --jobs value.
        let all_points = par::par_map_indexed(MIXES.len() * loads.len(), |idx| {
            run_point(&k, MIXES[idx / loads.len()].1, loads[idx % loads.len()])
        });
        let mut point_chunks = all_points.chunks_exact(loads.len());

        let mut reports = Vec::new();
        // (mix label, per-load points), in MIXES order.
        let mut curves: Vec<(&str, &[SweepPoint])> = Vec::new();

        for (label, _gaudi) in MIXES {
            let points: &[SweepPoint] = point_chunks.next().expect("one chunk per mix");
            let mut r = Report::new(format!(
                "Cluster load sweep [{label}]: {FLEET_SIZE} replicas, prefix-affinity \
                 router (SLO: TTFT <= {}s, TPOT <= {}s)",
                k.slo_ttft_s, k.slo_tpot_s
            ));
            r.header(&[
                "offered",
                "offered req/s",
                "served",
                "tok/s",
                "p99 TTFT s",
                "p99 TPOT s",
                "goodput req/s",
                "SLO attain",
                "requeues",
            ]);
            for p in points {
                r.row(vec![
                    Cell::text(format!("{:.0} rps", p.offered_rps)),
                    Cell::val(p.offered_rps, Unit::ReqPerSec),
                    Cell::count(p.completed),
                    Cell::val(p.tps, Unit::TokPerSec),
                    Cell::val(p.p99_ttft, Unit::Seconds),
                    Cell::val(p.p99_tpot, Unit::Seconds),
                    Cell::val(p.goodput_rps, Unit::ReqPerSec),
                    Cell::val(p.attainment, Unit::Percent),
                    Cell::count(p.requeues as usize),
                ]);
            }
            r.note(format!(
                "open-loop Dynamic-Sonnet at each offered load for {}s (seed {}), \
                 {} shared-prefix groups",
                k.duration_s, k.seed, k.prefix_groups
            ));
            reports.push(r);
            curves.push((label, points));
        }

        // Frontier: largest offered load each mix sustains at >= 99%
        // attainment — the paper-style goodput-under-SLO frontier.
        let mut frontier = Report::new("Goodput-under-SLO frontier per fleet mix");
        frontier.header(&[
            "fleet mix",
            "frontier load req/s",
            "goodput @ frontier req/s",
            "best goodput req/s",
        ]);
        for (label, points) in &curves {
            let sustained = points.iter().rev().find(|p| p.attainment >= 0.99);
            let best = points.iter().map(|p| p.goodput_rps).fold(0.0, f64::max);
            match sustained {
                Some(p) => frontier.row(vec![
                    Cell::text(*label),
                    Cell::val(p.offered_rps, Unit::ReqPerSec),
                    Cell::val(p.goodput_rps, Unit::ReqPerSec),
                    Cell::val(best, Unit::ReqPerSec),
                ]),
                None => frontier.row(vec![
                    Cell::text(*label),
                    Cell::text(format!("< {:.0}", k.load_min_rps)),
                    Cell::text("n/a"),
                    Cell::val(best, Unit::ReqPerSec),
                ]),
            };
        }
        frontier.note("frontier = largest swept load with >= 99% of requests meeting the SLO");
        reports.push(frontier);

        // Derived claims.
        let conservation: usize = curves
            .iter()
            .flat_map(|(_, ps)| ps.iter())
            .map(|p| p.submitted.abs_diff(p.completed))
            .sum();
        let max_goodput_ratio = curves
            .iter()
            .flat_map(|(_, ps)| ps.iter())
            .map(|p| p.goodput_rps / p.offered_rps)
            .fold(0.0, f64::max);
        let grid_points: usize = curves.iter().map(|(_, ps)| ps.len()).sum();
        let mut claims = Report::new("Cluster-sweep derived claims");
        claims.header(&["claim", "value"]);
        claims.row(vec![
            Cell::text("100% Gaudi-2 fleet vs homogeneous cluster: max delta"),
            Cell::val(mixed_vs_homogeneous_delta(&k), Unit::Seconds),
        ]);
        claims.row(vec![
            Cell::text("request conservation violations over the grid"),
            Cell::count(conservation),
        ]);
        claims.row(vec![
            Cell::text("max goodput / offered ratio over the grid"),
            Cell::val(max_goodput_ratio, Unit::Ratio),
        ]);
        claims.row(vec![Cell::text("grid points swept"), Cell::count(grid_points)]);
        claims.note("the 100%-Gaudi-2 mix must replay the homogeneous fleet bit-for-bit");
        reports.push(claims);

        reports
    }

    fn expectations(&self, _params: &Params) -> Vec<Expectation> {
        vec![
            Expectation::new(
                "cluster_sweep.mixed_homogeneous_parity",
                "a 100%-Gaudi-2 mixed fleet is bitwise-equal to the homogeneous path",
                Selector::cell(
                    "Cluster-sweep derived claims",
                    "100% Gaudi-2 fleet vs homogeneous cluster: max delta",
                    "value",
                ),
                Check::EqExact(0.0),
            ),
            Expectation::new(
                "cluster_sweep.conservation",
                "every submitted request completes exactly once at every grid point",
                Selector::cell(
                    "Cluster-sweep derived claims",
                    "request conservation violations over the grid",
                    "value",
                ),
                Check::EqExact(0.0),
            ),
            Expectation::new(
                "cluster_sweep.goodput_bounded_by_offered",
                "goodput never exceeds offered load beyond Poisson slack",
                Selector::cell(
                    "Cluster-sweep derived claims",
                    "max goodput / offered ratio over the grid",
                    "value",
                ),
                Check::Le(1.5),
            ),
            Expectation::new(
                "cluster_sweep.full_grid",
                "the sweep covers at least one load for every fleet mix",
                Selector::cell("Cluster-sweep derived claims", "grid points swept", "value"),
                Check::Ge(MIXES.len() as f64),
            ),
        ]
    }
}

/// Run with default params (convenience for tests and library callers).
pub fn run() -> Vec<Report> {
    ClusterSweep.run(&ClusterSweep.params())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_params() -> Params {
        // Two mix-loads at short duration keep the unit test quick; the
        // full default grid runs under `repro run cluster-sweep` and the
        // integration suite.
        ClusterSweep
            .params()
            .with("load_points", 2.0)
            .with("duration_s", 1.5)
            .with("load_step_rps", 16.0)
    }

    #[test]
    fn one_report_per_mix_plus_frontier_and_claims() {
        let reports = ClusterSweep.run(&small_params());
        assert_eq!(reports.len(), MIXES.len() + 2);
        for (i, (label, _)) in MIXES.iter().enumerate() {
            assert!(reports[i].title().contains(label), "report {i} mislabeled");
            assert_eq!(reports[i].num_rows(), 2);
        }
        assert_eq!(reports[MIXES.len()].num_rows(), MIXES.len());
    }

    #[test]
    fn parity_and_conservation_hold() {
        let k = Knobs::from(&small_params());
        assert_eq!(mixed_vs_homogeneous_delta(&k), 0.0);
        let p = run_point(&k, 2, k.load_min_rps);
        assert_eq!(p.submitted, p.completed);
        assert!(p.goodput_rps <= p.offered_rps * 1.5);
    }

    #[test]
    fn mix_fleets_are_well_formed() {
        for (_, g) in MIXES {
            let fleet = mix_fleet(g);
            assert_eq!(fleet.len(), FLEET_SIZE);
            assert_eq!(fleet.iter().filter(|d| **d == DeviceKind::Gaudi2).count(), g);
            mix_config(g).validate().unwrap();
        }
    }

    #[test]
    fn expectations_pass_on_default_grid() {
        // The full default grid is the artifact CI gates on; every
        // expectation must hold there.
        let reports = run();
        for e in ClusterSweep.expectations(&ClusterSweep.params()) {
            let res = e.evaluate(&reports);
            assert!(res.pass, "{}: {}", res.id, res.detail);
        }
    }
}
