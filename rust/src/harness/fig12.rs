//! Fig 12: (a) Gaudi-2 speedup over A100 serving Llama-3.1-8B (single
//! device) and 70B (2/4/8-way TP); (b) prefill/decode latency breakdown.

use crate::config::DeviceKind;
use crate::models::llama::{self, LlamaConfig};
use crate::util::stats::mean;
use crate::util::table::{fmt_ratio, Report};
use crate::util::units::fmt_time;

const BATCHES: [usize; 3] = [4, 16, 64];
const OUTPUTS: [usize; 4] = [25, 100, 200, 400];
const INPUT: usize = 100;

fn speedup_heatmap(cfg: &LlamaConfig, tp: usize) -> (Report, f64) {
    let title = if tp == 1 {
        format!("Fig 12(a): {} speedup, single device", cfg.name)
    } else {
        format!("Fig 12(a): {} speedup, {tp} devices (TP)", cfg.name)
    };
    let mut r = Report::new(title);
    let mut header = vec!["batch".to_string()];
    header.extend(OUTPUTS.iter().map(|o| format!("out{o}")));
    r.header(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    let mut all = Vec::new();
    for &b in &BATCHES {
        let mut row = vec![b.to_string()];
        for &o in &OUTPUTS {
            let g = llama::serve_fixed(cfg, DeviceKind::Gaudi2, b, INPUT, o, tp);
            let a = llama::serve_fixed(cfg, DeviceKind::A100, b, INPUT, o, tp);
            let s = a.total_time() / g.total_time();
            all.push(s);
            row.push(fmt_ratio(s));
        }
        r.row(row);
    }
    let avg = mean(&all);
    r.note(format!("avg {}", fmt_ratio(avg)));
    (r, avg)
}

pub fn run() -> Vec<Report> {
    let cfg8 = LlamaConfig::llama31_8b();
    let cfg70 = LlamaConfig::llama31_70b();
    let mut out = Vec::new();
    let (r, _) = speedup_heatmap(&cfg8, 1);
    out.push(r);
    for tp in [2usize, 4, 8] {
        let (r, _) = speedup_heatmap(&cfg70, tp);
        out.push(r);
    }

    // (b) latency breakdown, batch 64.
    let mut br = Report::new("Fig 12(b): prefill/decode latency breakdown (8B, batch 64, Gaudi-2)");
    br.header(&["in len", "out len", "prefill", "decode", "prefill share"]);
    for &(i, o) in
        &[(100usize, 25usize), (100, 100), (100, 400), (400, 100), (1600, 100), (6400, 100)]
    {
        let c = llama::serve_fixed(&cfg8, DeviceKind::Gaudi2, 64, i, o, 1);
        br.row(vec![
            i.to_string(),
            o.to_string(),
            fmt_time(c.prefill_time),
            fmt_time(c.decode_time),
            format!("{:.0}%", 100.0 * c.prefill_time / c.total_time()),
        ]);
    }
    br.note("paper: decode dominates as output grows; prefill share rises with input length");
    out.push(br);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::llama::LlamaConfig;

    #[test]
    fn single_device_avg_near_paper() {
        let (_, avg) = speedup_heatmap(&LlamaConfig::llama31_8b(), 1);
        assert!((avg - 1.47).abs() < 0.2, "avg {avg}");
    }

    #[test]
    fn speedup_grows_with_tp() {
        let cfg = LlamaConfig::llama31_70b();
        let (_, a2) = speedup_heatmap(&cfg, 2);
        let (_, a8) = speedup_heatmap(&cfg, 8);
        assert!(a8 > a2, "tp8 {a8} vs tp2 {a2}");
    }

    #[test]
    fn five_reports() {
        assert_eq!(run().len(), 5);
    }
}
