//! Fig 12: (a) Gaudi-2 speedup over A100 serving Llama-3.1-8B (single
//! device) and 70B (2/4/8-way TP); (b) prefill/decode latency breakdown.

use crate::config::DeviceKind;
use crate::harness::{Experiment, Params};
use crate::models::llama::{self, LlamaConfig};
use crate::report::{Agg, Cell, Check, Expectation, Report, Selector, Unit};

const BATCHES: [usize; 3] = [4, 16, 64];
const OUTPUTS: [usize; 4] = [25, 100, 200, 400];
const INPUT: usize = 100;

fn speedup_heatmap(cfg: &LlamaConfig, tp: usize) -> Report {
    let title = if tp == 1 {
        format!("Fig 12(a): {} speedup, single device", cfg.name)
    } else {
        format!("Fig 12(a): {} speedup, {tp} devices (TP)", cfg.name)
    };
    let mut r = Report::new(title);
    let mut header = vec!["batch".to_string()];
    header.extend(OUTPUTS.iter().map(|o| format!("out{o}")));
    r.header(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    for &b in &BATCHES {
        let mut row = vec![Cell::count(b)];
        for &o in &OUTPUTS {
            let g = llama::serve_fixed(cfg, DeviceKind::Gaudi2, b, INPUT, o, tp);
            let a = llama::serve_fixed(cfg, DeviceKind::A100, b, INPUT, o, tp);
            row.push(Cell::val(a.total_time() / g.total_time(), Unit::Ratio));
        }
        r.row(row);
    }
    r
}

pub struct Fig12;

impl Experiment for Fig12 {
    fn id(&self) -> &'static str {
        "fig12"
    }

    fn title(&self) -> &'static str {
        "Fig 12: LLM serving speedup + latency breakdown"
    }

    fn run(&self, _params: &Params) -> Vec<Report> {
        let cfg8 = LlamaConfig::llama31_8b();
        let cfg70 = LlamaConfig::llama31_70b();
        let mut out = Vec::new();
        out.push(speedup_heatmap(&cfg8, 1));
        for tp in [2usize, 4, 8] {
            out.push(speedup_heatmap(&cfg70, tp));
        }

        // (b) latency breakdown, batch 64.
        let mut br =
            Report::new("Fig 12(b): prefill/decode latency breakdown (8B, batch 64, Gaudi-2)");
        br.header(&["in len", "out len", "prefill ms", "decode ms", "prefill share"]);
        for &(i, o) in
            &[(100usize, 25usize), (100, 100), (100, 400), (400, 100), (1600, 100), (6400, 100)]
        {
            let c = llama::serve_fixed(&cfg8, DeviceKind::Gaudi2, 64, i, o, 1);
            br.row(vec![
                Cell::count(i),
                Cell::count(o),
                Cell::val(c.prefill_time * 1e3, Unit::Millis),
                Cell::val(c.decode_time * 1e3, Unit::Millis),
                Cell::val(c.prefill_time / c.total_time(), Unit::Percent),
            ]);
        }
        br.note("paper: decode dominates as output grows; prefill share rises with input length");
        out.push(br);
        out
    }

    fn expectations(&self, _params: &Params) -> Vec<Expectation> {
        vec![
            Expectation::new(
                "fig12.8b_single_device_speedup",
                "Gaudi-2 serves 8B ~1.47x faster than A100 on average",
                Selector::body("speedup, single device", Agg::Mean),
                Check::Within { target: 1.47, tol: 0.20 },
            ),
            Expectation::new(
                "fig12.70b_tp8_speedup",
                "the 70B TP-8 advantage averages ~1.35x",
                Selector::body("speedup, 8 devices", Agg::Mean),
                Check::Within { target: 1.35, tol: 0.15 },
            ),
            Expectation::new(
                "fig12.gaudi_wins_every_cell",
                "Gaudi-2 wins every (batch, output) cell of the single-device grid",
                Selector::body("speedup, single device", Agg::Min),
                Check::Ge(1.0),
            ),
        ]
    }
}

/// Run with default params (convenience for tests and library callers).
pub fn run() -> Vec<Report> {
    Fig12.run(&Fig12.params())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::llama::LlamaConfig;
    use crate::util::stats::mean;

    #[test]
    fn single_device_avg_near_paper() {
        let avg = mean(&speedup_heatmap(&LlamaConfig::llama31_8b(), 1).body_values());
        assert!((avg - 1.47).abs() < 0.2, "avg {avg}");
    }

    #[test]
    fn speedup_grows_with_tp() {
        let cfg = LlamaConfig::llama31_70b();
        let a2 = mean(&speedup_heatmap(&cfg, 2).body_values());
        let a8 = mean(&speedup_heatmap(&cfg, 8).body_values());
        assert!(a8 > a2, "tp8 {a8} vs tp2 {a2}");
    }

    #[test]
    fn five_reports() {
        assert_eq!(run().len(), 5);
    }

    #[test]
    fn expectations_pass() {
        let reports = run();
        for e in Fig12.expectations(&Fig12.params()) {
            let res = e.evaluate(&reports);
            assert!(res.pass, "{}: {}", res.id, res.detail);
        }
    }
}
