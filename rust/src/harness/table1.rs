//! Table 1: NVIDIA A100 vs Intel Gaudi-2 specification comparison.

use crate::config::DeviceSpec;
use crate::util::table::{fmt3, Report};
use crate::util::units::{GB, TB, TFLOPS};

pub fn run() -> Vec<Report> {
    let g = DeviceSpec::gaudi2();
    let a = DeviceSpec::a100();
    let mut r = Report::new("Table 1: A100 vs Gaudi-2");
    r.header(&["metric", "A100", "Gaudi-2", "ratio"]);
    let mut row = |name: &str, av: f64, gv: f64, unit: &str| {
        r.row(vec![
            name.to_string(),
            format!("{} {unit}", fmt3(av)),
            format!("{} {unit}", fmt3(gv)),
            format!("{:.1}x", gv / av),
        ]);
    };
    row("Matrix TFLOPS (BF16)", a.matrix_tflops / TFLOPS, g.matrix_tflops / TFLOPS, "TF");
    row("Vector TFLOPS (BF16)", a.vector_tflops / TFLOPS, g.vector_tflops / TFLOPS, "TF");
    row("HBM capacity", a.hbm_capacity / GB, g.hbm_capacity / GB, "GB");
    row("HBM bandwidth", a.hbm_bandwidth / TB, g.hbm_bandwidth / TB, "TB/s");
    row("SRAM capacity", a.sram_bytes / 1e6, g.sram_bytes / 1e6, "MB");
    row("Comm bandwidth", a.comm_bandwidth / GB, g.comm_bandwidth / GB, "GB/s");
    row("Power (TDP)", a.tdp_watts, g.tdp_watts, "W");
    r.note("paper Table 1 ratios: 1.4x / 0.3x / 1.2x / 1.2x / 1.2x / 1.0x / 1.5x");
    vec![r]
}

#[cfg(test)]
mod tests {
    #[test]
    fn renders_all_rows() {
        let reports = super::run();
        assert_eq!(reports.len(), 1);
        assert_eq!(reports[0].num_rows(), 7);
        let text = reports[0].render();
        assert!(text.contains("1.4x"));
        assert!(text.contains("1.5x"));
    }
}
