//! Table 1: NVIDIA A100 vs Intel Gaudi-2 specification comparison.

use crate::config::DeviceSpec;
use crate::harness::{Experiment, Params};
use crate::report::{Agg, Cell, Check, Expectation, Report, Selector, Unit};
use crate::util::units::{GB, TB, TFLOPS};

pub struct Table1;

impl Experiment for Table1 {
    fn id(&self) -> &'static str {
        "table1"
    }

    fn title(&self) -> &'static str {
        "Table 1: A100 vs Gaudi-2 specification ratios"
    }

    fn run(&self, _params: &Params) -> Vec<Report> {
        let g = DeviceSpec::gaudi2();
        let a = DeviceSpec::a100();
        let mut r = Report::new("Table 1: A100 vs Gaudi-2");
        r.header(&["metric", "A100", "Gaudi-2", "ratio"]);
        let mut row = |name: &str, av: f64, gv: f64, unit: Unit| {
            r.row(vec![
                Cell::text(name),
                Cell::val(av, unit),
                Cell::val(gv, unit),
                Cell::val(gv / av, Unit::Ratio),
            ]);
        };
        row("Matrix TFLOPS (BF16)", a.matrix_tflops / TFLOPS, g.matrix_tflops / TFLOPS, Unit::Tflops);
        row("Vector TFLOPS (BF16)", a.vector_tflops / TFLOPS, g.vector_tflops / TFLOPS, Unit::Tflops);
        row("HBM capacity (GB)", a.hbm_capacity / GB, g.hbm_capacity / GB, Unit::Gigabytes);
        row("HBM bandwidth (TB/s)", a.hbm_bandwidth / TB, g.hbm_bandwidth / TB, Unit::TbPerSec);
        row("SRAM capacity (MB)", a.sram_bytes / 1e6, g.sram_bytes / 1e6, Unit::Megabytes);
        row("Comm bandwidth (GB/s)", a.comm_bandwidth / GB, g.comm_bandwidth / GB, Unit::GbPerSec);
        row("Power (TDP, W)", a.tdp_watts, g.tdp_watts, Unit::Watts);
        r.note("paper Table 1 ratios: 1.4x / 0.3x / 1.2x / 1.2x / 1.2x / 1.0x / 1.5x");
        vec![r]
    }

    fn expectations(&self, _params: &Params) -> Vec<Expectation> {
        vec![
            Expectation::new(
                "table1.matrix_ratio",
                "Gaudi-2 has ~1.4x the A100's BF16 matrix TFLOPS",
                Selector::cell("Table 1", "Matrix TFLOPS (BF16)", "ratio"),
                Check::Within { target: 1.4, tol: 0.05 },
            ),
            Expectation::new(
                "table1.vector_ratio",
                "Gaudi-2 has only ~0.3x the A100's vector TFLOPS",
                Selector::cell("Table 1", "Vector TFLOPS (BF16)", "ratio"),
                Check::Within { target: 0.3, tol: 0.05 },
            ),
            Expectation::new(
                "table1.power_ratio",
                "Gaudi-2's TDP is ~1.5x the A100's",
                Selector::cell("Table 1", "Power (TDP, W)", "ratio"),
                Check::Within { target: 1.5, tol: 0.05 },
            ),
            Expectation::new(
                "table1.all_rows",
                "all seven specification rows are present",
                Selector::column("Table 1", "ratio", Agg::Min),
                Check::Ge(0.1),
            ),
        ]
    }
}

/// Run with default params (convenience for tests and library callers).
pub fn run() -> Vec<Report> {
    Table1.run(&Table1.params())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_all_rows_with_typed_ratios() {
        let reports = run();
        assert_eq!(reports.len(), 1);
        assert_eq!(reports[0].num_rows(), 7);
        let matrix = reports[0].value_at("Matrix TFLOPS (BF16)", "ratio").unwrap();
        assert_eq!(matrix.unit, Unit::Ratio);
        assert!((matrix.x - 1.3846).abs() < 0.01, "{}", matrix.x);
        let power = reports[0].value_at("Power (TDP, W)", "ratio").unwrap();
        assert!((power.x - 1.5).abs() < 0.05, "{}", power.x);
    }

    #[test]
    fn expectations_pass() {
        let reports = run();
        for e in Table1.expectations(&Table1.params()) {
            let res = e.evaluate(&reports);
            assert!(res.pass, "{}: {}", res.id, res.detail);
        }
    }
}
