//! Fig 13: energy-efficiency improvement of Gaudi-2 over A100 for LLM
//! serving (tokens per joule), single device (8B) and TP 2/4/8 (70B).

use crate::config::DeviceKind;
use crate::models::llama::{self, LlamaConfig};
use crate::util::stats::mean;
use crate::util::table::{fmt_ratio, Report};

const BATCHES: [usize; 3] = [4, 16, 64];
const OUTPUTS: [usize; 4] = [25, 100, 200, 400];
const INPUT: usize = 100;

fn energy_heatmap(cfg: &LlamaConfig, tp: usize) -> (Report, f64, f64) {
    let title = if tp == 1 {
        format!("Fig 13: {} energy-efficiency, single device", cfg.name)
    } else {
        format!("Fig 13: {} energy-efficiency, {tp} devices", cfg.name)
    };
    let mut r = Report::new(title);
    let mut header = vec!["batch".to_string()];
    header.extend(OUTPUTS.iter().map(|o| format!("out{o}")));
    r.header(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    let mut effs = Vec::new();
    let mut powers = Vec::new();
    for &b in &BATCHES {
        let mut row = vec![b.to_string()];
        for &o in &OUTPUTS {
            let g = llama::serve_fixed(cfg, DeviceKind::Gaudi2, b, INPUT, o, tp);
            let a = llama::serve_fixed(cfg, DeviceKind::A100, b, INPUT, o, tp);
            let e = g.tokens_per_joule(b, o) / a.tokens_per_joule(b, o);
            effs.push(e);
            powers.push(g.avg_power / a.avg_power);
            row.push(fmt_ratio(e));
        }
        r.row(row);
    }
    let avg = mean(&effs);
    let pw = mean(&powers);
    r.note(format!("avg energy-eff {}, avg power ratio {}", fmt_ratio(avg), fmt_ratio(pw)));
    (r, avg, pw)
}

pub fn run() -> Vec<Report> {
    let mut out = Vec::new();
    let (r, _, _) = energy_heatmap(&LlamaConfig::llama31_8b(), 1);
    out.push(r);
    for tp in [2usize, 4, 8] {
        let (r, _, _) = energy_heatmap(&LlamaConfig::llama31_70b(), tp);
        out.push(r);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_device_eff_near_paper() {
        // Paper: 1.48x average for single-device 8B serving.
        let (_, avg, _) = energy_heatmap(&LlamaConfig::llama31_8b(), 1);
        assert!((avg - 1.48).abs() < 0.3, "avg {avg}");
    }

    #[test]
    fn multi_device_power_below_a100() {
        // Paper: Gaudi draws ~88% of A100's power at multi-device.
        let (_, _, pw) = energy_heatmap(&LlamaConfig::llama31_70b(), 8);
        assert!((pw - 0.88).abs() < 0.15, "power ratio {pw}");
    }
}
