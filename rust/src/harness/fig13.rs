//! Fig 13: energy-efficiency improvement of Gaudi-2 over A100 for LLM
//! serving (tokens per joule), single device (8B) and TP 2/4/8 (70B).

use crate::config::DeviceKind;
use crate::harness::{Experiment, Params};
use crate::models::llama::{self, LlamaConfig};
use crate::report::{Agg, Cell, Check, Expectation, Report, Selector, Unit};

const BATCHES: [usize; 3] = [4, 16, 64];
const OUTPUTS: [usize; 4] = [25, 100, 200, 400];
const INPUT: usize = 100;

/// Heatmap of tokens-per-joule ratios plus the grid's mean power ratio.
fn energy_heatmap(cfg: &LlamaConfig, tp: usize) -> (Report, f64) {
    let title = if tp == 1 {
        format!("Fig 13: {} energy-efficiency, single device", cfg.name)
    } else {
        format!("Fig 13: {} energy-efficiency, {tp} devices", cfg.name)
    };
    let mut r = Report::new(title);
    let mut header = vec!["batch".to_string()];
    header.extend(OUTPUTS.iter().map(|o| format!("out{o}")));
    r.header(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    let mut powers = Vec::new();
    for &b in &BATCHES {
        let mut row = vec![Cell::count(b)];
        for &o in &OUTPUTS {
            let g = llama::serve_fixed(cfg, DeviceKind::Gaudi2, b, INPUT, o, tp);
            let a = llama::serve_fixed(cfg, DeviceKind::A100, b, INPUT, o, tp);
            row.push(Cell::val(g.tokens_per_joule(b, o) / a.tokens_per_joule(b, o), Unit::Ratio));
            powers.push(g.avg_power / a.avg_power);
        }
        r.row(row);
    }
    (r, crate::util::stats::mean(&powers))
}

pub struct Fig13;

impl Experiment for Fig13 {
    fn id(&self) -> &'static str {
        "fig13"
    }

    fn title(&self) -> &'static str {
        "Fig 13: LLM serving energy efficiency"
    }

    fn run(&self, _params: &Params) -> Vec<Report> {
        let mut out = Vec::new();
        let mut power = Report::new("Fig 13 power: mean draw ratio (Gaudi-2 / A100) per config");
        power.header(&["config", "power ratio"]);
        let (r, pw) = energy_heatmap(&LlamaConfig::llama31_8b(), 1);
        out.push(r);
        power.row(vec![Cell::text("8B tp1"), Cell::val(pw, Unit::Ratio)]);
        for tp in [2usize, 4, 8] {
            let (r, pw) = energy_heatmap(&LlamaConfig::llama31_70b(), tp);
            out.push(r);
            power.row(vec![Cell::text(format!("70B tp{tp}")), Cell::val(pw, Unit::Ratio)]);
        }
        power.note("paper: Gaudi draws ~88% of the A100's power at multi-device");
        out.push(power);
        out
    }

    fn expectations(&self, _params: &Params) -> Vec<Expectation> {
        vec![
            Expectation::new(
                "fig13.8b_energy_efficiency",
                "single-device 8B serving is ~1.48x more energy-efficient on Gaudi-2",
                Selector::body("energy-efficiency, single device", Agg::Mean),
                Check::Within { target: 1.48, tol: 0.30 },
            ),
            Expectation::new(
                "fig13.multi_device_power",
                "at 70B TP-8, Gaudi-2 draws ~88% of the A100's power",
                Selector::cell("Fig 13 power", "70B tp8", "power ratio"),
                Check::Within { target: 0.88, tol: 0.15 },
            ),
        ]
    }
}

/// Run with default params (convenience for tests and library callers).
pub fn run() -> Vec<Report> {
    Fig13.run(&Fig13.params())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::mean;

    #[test]
    fn four_heatmaps_and_power_summary() {
        let reports = run();
        assert_eq!(reports.len(), 5);
        assert_eq!(reports[4].num_rows(), 4);
    }

    #[test]
    fn single_device_eff_near_paper() {
        let (r, _) = energy_heatmap(&LlamaConfig::llama31_8b(), 1);
        let avg = mean(&r.body_values());
        assert!((avg - 1.48).abs() < 0.3, "avg {avg}");
    }

    #[test]
    fn expectations_pass() {
        let reports = run();
        for e in Fig13.expectations(&Fig13.params()) {
            let res = e.evaluate(&reports);
            assert!(res.pass, "{}: {}", res.id, res.detail);
        }
    }
}
