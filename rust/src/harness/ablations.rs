//! Ablation experiments for the design choices DESIGN.md calls out —
//! beyond the paper's figures:
//!
//! * **abl-mme**: MME *without* geometry reconfigurability (the Fig 6(a)
//!   fixed array) across the Fig 4 shapes — quantifies how much of
//!   Key Takeaway #1 is the reconfiguration vs raw FLOPS.
//! * **abl-pipeline**: vLLM_opt with graph-compiler slicing disabled —
//!   isolates the pipelining contribution within the §4.2 optimization.
//! * **abl-watermark**: KV watermark sweep — admission reserve vs
//!   preemption count in the serving engine.
//! * **ext-multi-recsys** / **ext-training**: the paper's missing feature
//!   and stated future work, implemented (models/dlrm_multi, llama_training).

use crate::config::{DeviceKind, ServingConfig};
use crate::models::dlrm::DlrmConfig;
use crate::models::dlrm_multi;
use crate::models::llama::LlamaConfig;
use crate::models::llama_training;
use crate::ops::gemm;
use crate::serving::engine::{Engine, SimBackend};
use crate::sim::mme::{self, MME_CLOCK_HZ};
use crate::sim::systolic::{self, Geometry};
use crate::sim::Dtype;
use crate::util::table::{fmt3, fmt_pct, fmt_ratio, Report};

/// abl-mme: reconfigurable vs fixed 256x256x2 across Fig 4 shapes.
pub fn mme_reconfig() -> Vec<Report> {
    let spec = DeviceKind::Gaudi2.spec();
    let mut r = Report::new("Ablation: MME reconfigurability (vs fixed 256x256x2)");
    r.header(&["shape", "reconfig TF", "fixed TF", "gain"]);
    let mut shapes = gemm::fig4_shapes();
    shapes.push((16384, 16384, 64));
    shapes.push((16384, 16384, 128));
    for (m, k, n) in shapes {
        let conf = mme::run_gemm(&spec, m, k, n, Dtype::Bf16);
        let fixed = systolic::gemm_cycles(Geometry::new(256, 256, 2), m, k, n);
        let mem = mme::gemm_traffic_bytes(m, k, n, Dtype::Bf16) / (spec.hbm_bandwidth * 0.90);
        let fixed_time = (fixed.cycles / MME_CLOCK_HZ).max(mem);
        let fixed_tf = mme::gemm_flops(m, k, n) / fixed_time / 1e12;
        r.row(vec![
            format!("{m}x{k}x{n}"),
            fmt3(conf.achieved_flops / 1e12),
            fmt3(fixed_tf),
            fmt_ratio(conf.achieved_flops / 1e12 / fixed_tf),
        ]);
    }
    r.note("square shapes: no gain (array already full); benefit concentrates on skinny N");
    vec![r]
}

/// abl-watermark: watermark sweep vs preemptions and throughput.
pub fn watermark_sweep() -> Vec<Report> {
    let mut r = Report::new("Ablation: KV watermark vs preemptions (tight memory)");
    r.header(&["watermark", "preemptions", "throughput tok/s"]);
    for wm in [0.0f64, 0.02, 0.05, 0.10, 0.20] {
        let cfg = ServingConfig {
            num_blocks: 96,
            max_decode_batch: 16,
            watermark: wm,
            ..Default::default()
        };
        let backend = SimBackend::new(LlamaConfig::llama31_8b(), &cfg);
        let mut e = Engine::new(cfg, backend);
        for i in 0..16u64 {
            e.submit(crate::serving::request::Request::new(i, 256, 256, 0.0));
        }
        let s = e.run_to_completion();
        let preemptions: usize = (0..16u64).map(|i| e.sched.seq(i).preemptions).sum();
        r.row(vec![format!("{:.0}%", wm * 100.0), preemptions.to_string(), fmt3(s.throughput_tps)]);
    }
    r.note("reserving blocks trades admission latency for fewer mid-flight preemptions");
    vec![r]
}

/// ext-multi-recsys: the multi-device RecSys serving the Gaudi SDK lacks.
pub fn multi_recsys() -> Vec<Report> {
    let cfg = DlrmConfig::rm2();
    let mut r = Report::new("Extension: multi-device RecSys (TorchRec-style sharding)");
    r.header(&["devices", "Gaudi thpt", "Gaudi a2a share", "A100 thpt", "A100 a2a share"]);
    for n in [1usize, 2, 4, 8] {
        let g = dlrm_multi::serve_multi(&cfg, DeviceKind::Gaudi2, 65536, 128, n);
        let a = dlrm_multi::serve_multi(&cfg, DeviceKind::A100, 65536, 128, n);
        r.row(vec![
            n.to_string(),
            fmt3(g.throughput(65536)),
            fmt_pct(g.alltoall_time / g.time),
            fmt3(a.throughput(65536)),
            fmt_pct(a.alltoall_time / a.time),
        ]);
    }
    r.note("Gaudi's P2P mesh taxes the embedding AllToAll hardest at 2 devices (Fig 10 mechanism)");
    vec![r]
}

/// ext-gaudi3: Gaudi-3 projection (paper footnote 1) — rerun the GEMM
/// roofline and the decode memory bound with the chiplet-scaled spec.
pub fn gaudi3_projection() -> Vec<Report> {
    let g3 = crate::config::DeviceSpec::gaudi3_projection();
    let g2 = DeviceKind::Gaudi2.spec();
    let mut r = Report::new("Extension: Gaudi-3 projection (footnote 1 scaling)");
    r.header(&["metric", "Gaudi-2", "Gaudi-3 (proj)", "ratio"]);
    for (name, f) in [
        ("matrix TF", (|s: &crate::config::DeviceSpec| s.matrix_tflops / 1e12) as fn(&crate::config::DeviceSpec) -> f64),
        ("HBM TB/s", |s| s.hbm_bandwidth / 1e12),
        ("SRAM MB", |s| s.sram_bytes / 1e6),
    ] {
        r.row(vec![name.into(), fmt3(f(&g2)), fmt3(f(&g3)), fmt_ratio(f(&g3) / f(&g2))]);
    }
    // GEMM roofline at the headline shape with the scaled spec.
    let e2 = mme::run_gemm(&g2, 8192, 8192, 8192, Dtype::Bf16);
    let e3 = mme::run_gemm(&g3, 8192, 8192, 8192, Dtype::Bf16);
    r.row(vec![
        "8192^3 achieved TF".into(),
        fmt3(e2.achieved_flops / 1e12),
        fmt3(e3.achieved_flops / 1e12),
        fmt_ratio(e3.achieved_flops / e2.achieved_flops),
    ]);
    // Decode memory bound: weight streaming time for Llama-8B.
    let w = LlamaConfig::llama31_8b().weight_bytes();
    r.row(vec![
        "8B decode step (mem-bound) ms".into(),
        fmt3(w / (g2.hbm_bandwidth * 0.88) * 1e3),
        fmt3(w / (g3.hbm_bandwidth * 0.88) * 1e3),
        fmt_ratio(g3.hbm_bandwidth / g2.hbm_bandwidth),
    ]);
    r.note("projection only: the simulator mechanisms are Gaudi-2's; Gaudi-3 adds chiplet scaling");
    vec![r]
}

/// ext-training: training-step throughput comparison (paper future work).
pub fn training() -> Vec<Report> {
    let mut r = Report::new("Extension: training-step throughput (Gaudi-2 / A100)");
    r.header(&["model", "dp", "batch x seq", "speedup", "comm share (Gaudi)"]);
    for (cfg, b, s) in [
        (LlamaConfig::llama31_8b(), 8usize, 4096usize),
        (LlamaConfig::llama31_8b(), 2, 4096),
        (LlamaConfig::llama31_70b(), 2, 4096),
    ] {
        for dp in [2usize, 8] {
            let sp = llama_training::speedup(&cfg, b, s, dp);
            let g = llama_training::train_step(&cfg, DeviceKind::Gaudi2, b, s, dp);
            r.row(vec![
                cfg.name.into(),
                dp.to_string(),
                format!("{b}x{s}"),
                fmt_ratio(sp),
                fmt_pct(g.allreduce_time / (g.compute_time + g.allreduce_time)),
            ]);
        }
    }
    r.note("training is compute-bound: the MME advantage carries over (paper's conjecture)");
    vec![r]
}

#[cfg(test)]
mod tests {
    #[test]
    fn all_ablations_render() {
        for reports in
            [super::mme_reconfig(), super::watermark_sweep(), super::multi_recsys(), super::training()]
        {
            for r in reports {
                assert!(r.render().len() > 60);
            }
        }
    }

    #[test]
    fn mme_ablation_shows_gain_on_skinny_shapes() {
        let text = super::mme_reconfig()[0].render();
        // At least one row has gain > 1.5x (skinny N), square rows ~1.0x.
        assert!(text.contains("1.0"), "{text}");
        // The memory roofline caps the reconfiguration benefit: gains land
        // in the 1.2-1.4x range on skinny-N shapes, ~1.0x on square.
        let has_big_gain = text
            .lines()
            .filter_map(|l| l.split_whitespace().last())
            .filter_map(|w| w.strip_suffix('x').and_then(|x| x.parse::<f64>().ok()))
            .any(|g| g > 1.15);
        assert!(has_big_gain, "{text}");
    }
}
