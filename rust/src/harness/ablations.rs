//! Ablation experiments for the design choices DESIGN.md calls out —
//! beyond the paper's figures:
//!
//! * **abl-mme**: MME *without* geometry reconfigurability (the Fig 6(a)
//!   fixed array) across the Fig 4 shapes — quantifies how much of
//!   Key Takeaway #1 is the reconfiguration vs raw FLOPS.
//! * **abl-watermark**: KV watermark sweep — admission reserve vs
//!   preemption count in the serving engine.
//! * **ext-multi-recsys** / **ext-training**: the paper's missing feature
//!   and stated future work, implemented (models/dlrm_multi, llama_training).
//! * **ext-gaudi3**: the paper's footnote-1 Gaudi-3 projection.

use crate::config::{DeviceKind, ServingConfig};
use crate::harness::{Experiment, Params};
use crate::models::dlrm::DlrmConfig;
use crate::models::dlrm_multi;
use crate::models::llama::LlamaConfig;
use crate::models::llama_training;
use crate::ops::gemm;
use crate::report::{Agg, Cell, Check, Expectation, Report, Selector, Unit};
use crate::serving::engine::{Engine, SimBackend};
use crate::sim::mme::{self, MME_CLOCK_HZ};
use crate::sim::systolic::{self, Geometry};
use crate::sim::Dtype;

/// abl-mme: reconfigurable vs fixed 256x256x2 across Fig 4 shapes.
pub struct AblMme;

impl Experiment for AblMme {
    fn id(&self) -> &'static str {
        "abl-mme"
    }

    fn title(&self) -> &'static str {
        "Ablation: MME reconfigurability"
    }

    fn run(&self, _params: &Params) -> Vec<Report> {
        let spec = DeviceKind::Gaudi2.spec();
        let mut r = Report::new("Ablation: MME reconfigurability (vs fixed 256x256x2)");
        r.header(&["shape", "reconfig TF", "fixed TF", "gain"]);
        let mut shapes = gemm::fig4_shapes();
        shapes.push((16384, 16384, 64));
        shapes.push((16384, 16384, 128));
        for (m, k, n) in shapes {
            let conf = mme::run_gemm(&spec, m, k, n, Dtype::Bf16);
            let fixed = systolic::gemm_cycles(Geometry::new(256, 256, 2), m, k, n);
            let mem = mme::gemm_traffic_bytes(m, k, n, Dtype::Bf16) / (spec.hbm_bandwidth * 0.90);
            let fixed_time = (fixed.cycles / MME_CLOCK_HZ).max(mem);
            let fixed_tf = mme::gemm_flops(m, k, n) / fixed_time / 1e12;
            r.row(vec![
                Cell::text(format!("{m}x{k}x{n}")),
                Cell::val(conf.achieved_flops / 1e12, Unit::Tflops),
                Cell::val(fixed_tf, Unit::Tflops),
                Cell::val(conf.achieved_flops / 1e12 / fixed_tf, Unit::Ratio),
            ]);
        }
        r.note("square shapes: no gain (array already full); benefit concentrates on skinny N");
        vec![r]
    }

    fn expectations(&self, _params: &Params) -> Vec<Expectation> {
        vec![Expectation::new(
            "abl-mme.skinny_gain",
            "the memory roofline caps reconfiguration gains at 1.15-2x on skinny N",
            Selector::column("MME reconfigurability", "gain", Agg::Max),
            Check::Between(1.15, 2.0),
        )]
    }
}

/// abl-watermark: watermark sweep vs preemptions and throughput.
pub struct AblWatermark;

impl Experiment for AblWatermark {
    fn id(&self) -> &'static str {
        "abl-watermark"
    }

    fn title(&self) -> &'static str {
        "Ablation: KV watermark vs preemptions"
    }

    fn params(&self) -> Params {
        Params::new().with("requests", 16.0)
    }

    fn run(&self, params: &Params) -> Vec<Report> {
        let n = params.get_or("requests", 16.0) as u64;
        let mut r = Report::new("Ablation: KV watermark vs preemptions (tight memory)");
        r.header(&["watermark", "preemptions", "throughput tok/s"]);
        for wm in [0.0f64, 0.02, 0.05, 0.10, 0.20] {
            let cfg = ServingConfig {
                num_blocks: 96,
                max_decode_batch: 16,
                watermark: wm,
                ..Default::default()
            };
            let backend = SimBackend::new(LlamaConfig::llama31_8b(), &cfg);
            let mut e = Engine::new(cfg, backend);
            for i in 0..n {
                e.submit(crate::serving::request::Request::new(i, 256, 256, 0.0));
            }
            let s = e.run_to_completion();
            let preemptions: usize = (0..n).map(|i| e.sched.seq(i).preemptions).sum();
            r.row(vec![
                Cell::val(wm, Unit::Percent),
                Cell::count(preemptions),
                Cell::val(s.throughput_tps, Unit::TokPerSec),
            ]);
        }
        r.note("reserving blocks trades admission latency for fewer mid-flight preemptions");
        vec![r]
    }
}

/// ext-multi-recsys: the multi-device RecSys serving the Gaudi SDK lacks.
pub struct ExtMultiRecsys;

impl Experiment for ExtMultiRecsys {
    fn id(&self) -> &'static str {
        "ext-multi-recsys"
    }

    fn title(&self) -> &'static str {
        "Extension: multi-device RecSys serving"
    }

    fn run(&self, _params: &Params) -> Vec<Report> {
        let cfg = DlrmConfig::rm2();
        let mut r = Report::new("Extension: multi-device RecSys (TorchRec-style sharding)");
        r.header(&["devices", "Gaudi thpt", "Gaudi a2a share", "A100 thpt", "A100 a2a share"]);
        for n in [1usize, 2, 4, 8] {
            let g = dlrm_multi::serve_multi(&cfg, DeviceKind::Gaudi2, 65536, 128, n);
            let a = dlrm_multi::serve_multi(&cfg, DeviceKind::A100, 65536, 128, n);
            r.row(vec![
                Cell::count(n),
                Cell::val(g.throughput(65536), Unit::ReqPerSec),
                Cell::val(g.alltoall_time / g.time, Unit::Percent),
                Cell::val(a.throughput(65536), Unit::ReqPerSec),
                Cell::val(a.alltoall_time / a.time, Unit::Percent),
            ]);
        }
        r.note("Gaudi's P2P mesh taxes the embedding AllToAll hardest at 2 devices (Fig 10 mechanism)");
        vec![r]
    }
}

/// ext-gaudi3: Gaudi-3 projection (paper footnote 1) — rerun the GEMM
/// roofline and the decode memory bound with the chiplet-scaled spec.
pub struct ExtGaudi3;

impl Experiment for ExtGaudi3 {
    fn id(&self) -> &'static str {
        "ext-gaudi3"
    }

    fn title(&self) -> &'static str {
        "Extension: Gaudi-3 projection"
    }

    fn run(&self, _params: &Params) -> Vec<Report> {
        let g3 = crate::config::DeviceSpec::gaudi3_projection();
        let g2 = DeviceKind::Gaudi2.spec();
        let mut r = Report::new("Extension: Gaudi-3 projection (footnote 1 scaling)");
        r.header(&["metric", "Gaudi-2", "Gaudi-3 (proj)", "ratio"]);
        type SpecF = fn(&crate::config::DeviceSpec) -> f64;
        let rows: [(&str, Unit, SpecF); 3] = [
            ("matrix TF", Unit::Tflops, |s| s.matrix_tflops / 1e12),
            ("HBM TB/s", Unit::TbPerSec, |s| s.hbm_bandwidth / 1e12),
            ("SRAM MB", Unit::Megabytes, |s| s.sram_bytes / 1e6),
        ];
        for (name, unit, f) in rows {
            r.row(vec![
                Cell::text(name),
                Cell::val(f(&g2), unit),
                Cell::val(f(&g3), unit),
                Cell::val(f(&g3) / f(&g2), Unit::Ratio),
            ]);
        }
        // GEMM roofline at the headline shape with the scaled spec.
        let e2 = mme::run_gemm(&g2, 8192, 8192, 8192, Dtype::Bf16);
        let e3 = mme::run_gemm(&g3, 8192, 8192, 8192, Dtype::Bf16);
        r.row(vec![
            Cell::text("8192^3 achieved TF"),
            Cell::val(e2.achieved_flops / 1e12, Unit::Tflops),
            Cell::val(e3.achieved_flops / 1e12, Unit::Tflops),
            Cell::val(e3.achieved_flops / e2.achieved_flops, Unit::Ratio),
        ]);
        // Decode memory bound: weight streaming time for Llama-8B.
        let w = LlamaConfig::llama31_8b().weight_bytes();
        r.row(vec![
            Cell::text("8B decode step (mem-bound) ms"),
            Cell::val(w / (g2.hbm_bandwidth * 0.88) * 1e3, Unit::Millis),
            Cell::val(w / (g3.hbm_bandwidth * 0.88) * 1e3, Unit::Millis),
            Cell::val(g3.hbm_bandwidth / g2.hbm_bandwidth, Unit::Ratio),
        ]);
        r.note("projection only: the simulator mechanisms are Gaudi-2's; Gaudi-3 adds chiplet scaling");
        vec![r]
    }

    fn expectations(&self, _params: &Params) -> Vec<Expectation> {
        vec![Expectation::new(
            "ext-gaudi3.strictly_better",
            "every projected Gaudi-3 metric improves on Gaudi-2",
            Selector::column("Gaudi-3 projection", "ratio", Agg::Min),
            Check::Ge(1.0),
        )]
    }
}

/// ext-training: training-step throughput comparison (paper future work).
pub struct ExtTraining;

impl Experiment for ExtTraining {
    fn id(&self) -> &'static str {
        "ext-training"
    }

    fn title(&self) -> &'static str {
        "Extension: training-step comparison"
    }

    fn run(&self, _params: &Params) -> Vec<Report> {
        let mut r = Report::new("Extension: training-step throughput (Gaudi-2 / A100)");
        r.header(&["model", "dp", "batch x seq", "speedup", "comm share (Gaudi)"]);
        for (cfg, b, s) in [
            (LlamaConfig::llama31_8b(), 8usize, 4096usize),
            (LlamaConfig::llama31_8b(), 2, 4096),
            (LlamaConfig::llama31_70b(), 2, 4096),
        ] {
            for dp in [2usize, 8] {
                let sp = llama_training::speedup(&cfg, b, s, dp);
                let g = llama_training::train_step(&cfg, DeviceKind::Gaudi2, b, s, dp);
                r.row(vec![
                    Cell::text(cfg.name),
                    Cell::count(dp),
                    Cell::text(format!("{b}x{s}")),
                    Cell::val(sp, Unit::Ratio),
                    Cell::val(
                        g.allreduce_time / (g.compute_time + g.allreduce_time),
                        Unit::Percent,
                    ),
                ]);
            }
        }
        r.note("training is compute-bound: the MME advantage carries over (paper's conjecture)");
        vec![r]
    }

    fn expectations(&self, _params: &Params) -> Vec<Expectation> {
        vec![Expectation::new(
            "ext-training.compute_bound_advantage",
            "the MME advantage carries over to training (speedup > 1x on average)",
            Selector::column("training-step throughput", "speedup", Agg::Mean),
            Check::Ge(1.0),
        )]
    }
}

/// Default-params conveniences for tests and library callers.
pub fn mme_reconfig() -> Vec<Report> {
    AblMme.run(&AblMme.params())
}

pub fn watermark_sweep() -> Vec<Report> {
    AblWatermark.run(&AblWatermark.params())
}

pub fn multi_recsys() -> Vec<Report> {
    ExtMultiRecsys.run(&ExtMultiRecsys.params())
}

pub fn training() -> Vec<Report> {
    ExtTraining.run(&ExtTraining.params())
}

pub fn gaudi3_projection() -> Vec<Report> {
    ExtGaudi3.run(&ExtGaudi3.params())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_ablations_render() {
        for reports in [mme_reconfig(), watermark_sweep(), multi_recsys(), training()] {
            for r in reports {
                assert!(r.render().len() > 60);
            }
        }
    }

    #[test]
    fn mme_ablation_shows_gain_on_skinny_shapes() {
        let gains = mme_reconfig()[0].series("gain").unwrap();
        // Square shapes sit near 1.0x; the memory roofline caps the
        // reconfiguration benefit at ~1.2-1.4x on skinny-N shapes.
        assert!(gains.min() < 1.1, "{:?}", gains.values);
        assert!(gains.max() > 1.15, "{:?}", gains.values);
    }

    #[test]
    fn expectations_pass() {
        for e in crate::harness::registry() {
            if !e.id().starts_with("abl") && !e.id().starts_with("ext") {
                continue;
            }
            let reports = e.run(&e.params());
            for x in e.expectations(&e.params()) {
                let res = x.evaluate(&reports);
                assert!(res.pass, "{}: {}", res.id, res.detail);
            }
        }
    }
}
