//! Cache-sweep experiment: prefix-cache capacity x prefix-group skew —
//! the memory-capacity question the paper's §4.2 KV-cache study raises,
//! asked of the *shared-prefix* cache: how much HBM block budget does
//! prefix reuse need before the routing discount it promises is actually
//! delivered? Capacity walks from 0 (caching off) to the whole pool
//! (effectively unbounded) across three request-skew regimes (few hot
//! prefix groups -> many cold ones), reporting hit rate, evictions,
//! goodput and energy per token as typed reports.
//!
//! Two structural claims are checked: hit rate is monotone non-decreasing
//! in capacity, and the unbounded configuration reproduces the
//! pre-refactor ever-warm-set behavior *bitwise* (exact-zero typed
//! delta) — pinned by replaying the deleted `seen_prefixes` logic in a
//! harness-local [`LegacyWarmBackend`] oracle. `repro run cache-sweep
//! --json --out bench/` writes the grid as `BENCH_cache_sweep.json` for
//! the CI bench-diff gate.

use crate::config::ServingConfig;
use crate::harness::{Experiment, Params};
use crate::models::llama::{self, LlamaConfig};
use crate::report::{Cell, Check, Expectation, Report, Selector, Unit};
use crate::serving::cluster::ClusterSim;
use crate::serving::engine::{Backend, DecodeWork, Engine, PrefillItem, SimBackend};
use crate::serving::qos::ClassSet;
use crate::serving::router::RoutePolicy;
use crate::serving::trace::TraceStepKind;
use crate::serving::PREFIX_HIT_DISCOUNT;
use crate::util::fasthash::FastMap;
use crate::util::par;
use crate::workload::DynamicSonnet;

/// KV pool per replica (ample: capacity effects must come from the
/// prefix budget, not from sequence-block starvation).
const NUM_BLOCKS: usize = 8192;

/// Prefix-cache budgets swept, in blocks. The last equals the whole pool
/// — effectively unbounded, the legacy-parity point.
const CAPACITIES: [usize; 5] = [0, 16, 64, 256, NUM_BLOCKS];

/// (label, prefix groups) per skew regime: fewer groups = hotter reuse.
const SKEWS: [(&str, usize); 3] =
    [("hot: 2 groups", 2), ("warm: 8 groups", 8), ("cold: 64 groups", 64)];

struct Knobs {
    requests: usize,
    rate_rps: f64,
    seed: u64,
    slo_ttft_s: f64,
    slo_tpot_s: f64,
}

impl Knobs {
    fn from(params: &Params) -> Knobs {
        Knobs {
            requests: params.get_or("requests", 96.0) as usize,
            rate_rps: params.get_or("rate_rps", 40.0),
            seed: params.get_or("seed", 23.0) as u64,
            slo_ttft_s: params.get_or("slo_ttft_s", 1.0),
            slo_tpot_s: params.get_or("slo_tpot_s", 0.1),
        }
    }
}

fn sweep_config(capacity: usize) -> ServingConfig {
    ServingConfig {
        num_blocks: NUM_BLOCKS,
        max_decode_batch: 32,
        prefix_cache_blocks: capacity,
        route_policy: RoutePolicy::PrefixAffinity,
        ..Default::default()
    }
}

/// One (skew, capacity) grid point.
struct SweepPoint {
    capacity: usize,
    hit_rate: f64,
    evictions: u64,
    uncached: u64,
    submitted: usize,
    completed: usize,
    tps: f64,
    p99_ttft: f64,
    joule_per_tok: f64,
    goodput_rps: f64,
}

fn run_point(k: &Knobs, groups: usize, capacity: usize) -> SweepPoint {
    let cfg = sweep_config(capacity);
    let trace =
        DynamicSonnet::default().with_prefix_groups(groups).generate(k.requests, k.rate_rps, k.seed);
    let submitted = trace.len();
    let mut sim = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
    sim.submit_all(trace);
    let s = sim.run_to_completion();
    let stats = sim.fleet_prefix_stats();
    let fleet = sim.fleet_metrics();
    SweepPoint {
        capacity,
        hit_rate: stats.hit_rate(),
        evictions: stats.evictions,
        uncached: stats.uncached,
        submitted,
        completed: sim.completed(),
        tps: s.throughput_tps,
        p99_ttft: s.p99_ttft,
        joule_per_tok: s.joule_per_tok,
        goodput_rps: fleet.goodput(&ClassSet::scalar(k.slo_ttft_s, k.slo_tpot_s)),
    }
}

/// The pre-refactor warmth oracle: `SimBackend`'s prefill costing with
/// the deleted `seen_prefixes` ever-warm set re-created locally (first
/// prefill of a group pays full price and warms it forever; later
/// prefills are discounted unconditionally). Decode and power delegate
/// to the real backend. Driving an `Engine` with this backend and
/// prefix caching *disabled* replays the legacy step sequence exactly —
/// the executable specification the unbounded-capacity configuration is
/// diffed against, here and in `rust/tests/proptests.rs` (one oracle,
/// two gates — keep it single-sourced so they can never drift apart).
pub struct LegacyWarmBackend {
    inner: SimBackend,
    seen: FastMap<u64, ()>,
}

impl LegacyWarmBackend {
    pub fn new(model: LlamaConfig, cfg: &ServingConfig) -> LegacyWarmBackend {
        LegacyWarmBackend { inner: SimBackend::new(model, cfg), seen: FastMap::default() }
    }
}

impl Backend for LegacyWarmBackend {
    fn prefill(&mut self, batch: &[PrefillItem]) -> f64 {
        if batch.is_empty() {
            return 0.0;
        }
        // Verbatim legacy arithmetic: discounted sum, truncating mean.
        let tokens: f64 = batch
            .iter()
            .map(|i| match i.prefix_id {
                Some(p) => {
                    if self.seen.insert(p, ()).is_some() {
                        i.prompt_len as f64 * (1.0 - PREFIX_HIT_DISCOUNT)
                    } else {
                        i.prompt_len as f64
                    }
                }
                None => i.prompt_len as f64,
            })
            .sum();
        let mean_len = ((tokens / batch.len() as f64) as usize).max(1);
        llama::prefill_cost(&self.inner.model, self.inner.device, batch.len(), mean_len, self.inner.tp)
            .time
    }

    fn decode(&mut self, work: &DecodeWork) -> f64 {
        self.inner.decode(work)
    }

    fn step_power_w(&self, kind: TraceStepKind) -> f64 {
        self.inner.step_power_w(kind)
    }
}

/// Max per-request metric delta between the unbounded-capacity unified
/// cache and the legacy warm-set oracle on the same tagged trace —
/// exact-zero by construction: with the whole pool as budget (nothing
/// ever evicted) and ample memory, "resident at admission" degenerates
/// to "seen before", so every step duration is the same f64.
fn unbounded_vs_legacy_delta(k: &Knobs, groups: usize) -> f64 {
    let trace = || {
        DynamicSonnet::default().with_prefix_groups(groups).generate(k.requests, k.rate_rps, k.seed)
    };
    let model = LlamaConfig::llama31_8b();

    let unbounded_cfg = sweep_config(NUM_BLOCKS);
    let mut unified = Engine::new(unbounded_cfg.clone(), SimBackend::new(model, &unbounded_cfg));
    for r in trace() {
        unified.submit(r);
    }
    unified.run_to_completion();

    // The oracle runs with prefix caching disabled so the block manager
    // never touches shared blocks — warmth lives in the backend, exactly
    // as it did before the refactor.
    let legacy_cfg = sweep_config(0);
    let mut legacy = Engine::new(legacy_cfg.clone(), LegacyWarmBackend::new(model, &legacy_cfg));
    for r in trace() {
        legacy.submit(r);
    }
    legacy.run_to_completion();

    unified.metrics.max_request_delta(&legacy.metrics)
}

pub struct CacheSweep;

impl Experiment for CacheSweep {
    fn id(&self) -> &'static str {
        "cache_sweep"
    }

    fn title(&self) -> &'static str {
        "Cache sweep: prefix-cache capacity x prefix-group skew (hit rate, evictions, goodput)"
    }

    fn params(&self) -> Params {
        Params::new()
            .with("requests", 96.0)
            .with("rate_rps", 40.0)
            .with("seed", 23.0)
            .with("slo_ttft_s", 1.0)
            .with("slo_tpot_s", 0.1)
    }

    fn run(&self, params: &Params) -> Vec<Report> {
        let k = Knobs::from(params);
        // Fan the flattened (skew, capacity) grid across the worker pool;
        // submission-ordered assembly keeps the artifact byte-identical
        // at any --jobs value.
        let all_points = par::par_map_indexed(SKEWS.len() * CAPACITIES.len(), |idx| {
            run_point(&k, SKEWS[idx / CAPACITIES.len()].1, CAPACITIES[idx % CAPACITIES.len()])
        });
        let mut point_chunks = all_points.chunks_exact(CAPACITIES.len());
        let mut reports = Vec::new();
        let mut curves: Vec<(&str, &[SweepPoint])> = Vec::new();

        for (label, groups) in SKEWS {
            let points: &[SweepPoint] = point_chunks.next().expect("one chunk per skew");
            let mut r = Report::new(format!(
                "Prefix-cache capacity sweep [{label}]: {NUM_BLOCKS}-block pool, \
                 prefix-affinity router"
            ));
            r.header(&[
                "capacity",
                "blocks",
                "hit rate",
                "evictions",
                "uncached",
                "served",
                "tok/s",
                "p99 TTFT s",
                "goodput req/s",
                "J/tok",
            ]);
            for p in points {
                let cap_label = if p.capacity == 0 {
                    "off".to_string()
                } else if p.capacity >= NUM_BLOCKS {
                    "unbounded".to_string()
                } else {
                    format!("{} blk", p.capacity)
                };
                r.row(vec![
                    Cell::text(cap_label),
                    Cell::count(p.capacity),
                    Cell::val(p.hit_rate, Unit::Percent),
                    Cell::count(p.evictions as usize),
                    Cell::count(p.uncached as usize),
                    Cell::count(p.completed),
                    Cell::val(p.tps, Unit::TokPerSec),
                    Cell::val(p.p99_ttft, Unit::Seconds),
                    Cell::val(p.goodput_rps, Unit::ReqPerSec),
                    Cell::val(p.joule_per_tok, Unit::JoulePerTok),
                ]);
            }
            r.note(format!(
                "Dynamic-Sonnet, {} requests at {} req/s (seed {}), {} shared-prefix groups; \
                 SLO: TTFT <= {}s, TPOT <= {}s",
                k.requests, k.rate_rps, k.seed, groups, k.slo_ttft_s, k.slo_tpot_s
            ));
            reports.push(r);
            curves.push((label, points));
        }

        // Derived claims over the grid.
        let mut monotonicity_violations = 0usize;
        let mut conservation = 0usize;
        let mut unbounded_evictions = 0u64;
        let mut unbounded_uncached = 0u64;
        for (_, points) in &curves {
            for pair in points.windows(2) {
                // CAPACITIES is ascending; hit rate must not drop.
                if pair[1].hit_rate < pair[0].hit_rate - 1e-12 {
                    monotonicity_violations += 1;
                }
            }
            for p in points.iter() {
                conservation += p.submitted.abs_diff(p.completed);
                if p.capacity >= NUM_BLOCKS {
                    unbounded_evictions += p.evictions;
                    unbounded_uncached += p.uncached;
                }
            }
        }
        let parity = unbounded_vs_legacy_delta(&k, SKEWS[1].1);
        let grid_points: usize = curves.iter().map(|(_, ps)| ps.len()).sum();

        let mut claims = Report::new("Cache-sweep derived claims");
        claims.header(&["claim", "value"]);
        claims.row(vec![
            Cell::text("hit-rate monotonicity violations over the grid"),
            Cell::count(monotonicity_violations),
        ]);
        claims.row(vec![
            Cell::text("unbounded capacity vs legacy warm-set: max delta"),
            Cell::val(parity, Unit::Seconds),
        ]);
        claims.row(vec![
            Cell::text("evictions + uncached at unbounded capacity"),
            Cell::count((unbounded_evictions + unbounded_uncached) as usize),
        ]);
        claims.row(vec![
            Cell::text("request conservation violations over the grid"),
            Cell::count(conservation),
        ]);
        claims.row(vec![Cell::text("grid points swept"), Cell::count(grid_points)]);
        claims.note(
            "capacity is swept ascending, so hit rate must be monotone non-decreasing; \
             the unbounded point must replay the pre-refactor ever-warm set bit-for-bit",
        );
        reports.push(claims);

        reports
    }

    fn expectations(&self, _params: &Params) -> Vec<Expectation> {
        vec![
            Expectation::new(
                "cache_sweep.hit_rate_monotone",
                "prefix hit rate is monotone non-decreasing in cache capacity",
                Selector::cell(
                    "Cache-sweep derived claims",
                    "hit-rate monotonicity violations over the grid",
                    "value",
                ),
                Check::EqExact(0.0),
            ),
            Expectation::new(
                "cache_sweep.legacy_parity",
                "unbounded capacity reproduces the legacy warm-set behavior bitwise",
                Selector::cell(
                    "Cache-sweep derived claims",
                    "unbounded capacity vs legacy warm-set: max delta",
                    "value",
                ),
                Check::EqExact(0.0),
            ),
            Expectation::new(
                "cache_sweep.unbounded_never_evicts",
                "an unbounded cache neither evicts nor refuses residency",
                Selector::cell(
                    "Cache-sweep derived claims",
                    "evictions + uncached at unbounded capacity",
                    "value",
                ),
                Check::EqExact(0.0),
            ),
            Expectation::new(
                "cache_sweep.conservation",
                "every submitted request completes exactly once at every grid point",
                Selector::cell(
                    "Cache-sweep derived claims",
                    "request conservation violations over the grid",
                    "value",
                ),
                Check::EqExact(0.0),
            ),
            Expectation::new(
                "cache_sweep.full_grid",
                "the sweep covers every (skew, capacity) grid point",
                Selector::cell("Cache-sweep derived claims", "grid points swept", "value"),
                Check::Ge((SKEWS.len() * CAPACITIES.len()) as f64),
            ),
        ]
    }
}

/// Run with default params (convenience for tests and library callers).
pub fn run() -> Vec<Report> {
    CacheSweep.run(&CacheSweep.params())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_params() -> Params {
        CacheSweep.params().with("requests", 32.0).with("rate_rps", 60.0)
    }

    #[test]
    fn one_report_per_skew_plus_claims() {
        let reports = CacheSweep.run(&small_params());
        assert_eq!(reports.len(), SKEWS.len() + 1);
        for (i, (label, _)) in SKEWS.iter().enumerate() {
            assert!(reports[i].title().contains(label), "report {i} mislabeled");
            assert_eq!(reports[i].num_rows(), CAPACITIES.len());
        }
    }

    #[test]
    fn capacity_zero_never_hits_and_unbounded_hits_most() {
        let k = Knobs::from(&small_params());
        let off = run_point(&k, 8, 0);
        assert_eq!(off.hit_rate, 0.0);
        assert_eq!(off.evictions, 0);
        assert!(off.uncached > 0, "every acquisition is refused at capacity 0");
        let unbounded = run_point(&k, 8, NUM_BLOCKS);
        assert!(unbounded.hit_rate > off.hit_rate);
        assert_eq!(unbounded.evictions, 0);
        assert_eq!(unbounded.uncached, 0);
        // Hits buy throughput (cheaper prefills) on the same trace.
        assert!(unbounded.tps >= off.tps, "{} vs {}", unbounded.tps, off.tps);
        assert_eq!(unbounded.submitted, unbounded.completed);
    }

    #[test]
    fn tight_capacity_evicts_under_cold_skew() {
        let k = Knobs::from(&small_params());
        // 64 groups cannot fit in 16 blocks: eviction churn must show up.
        let tight = run_point(&k, 64, 16);
        assert!(
            tight.evictions > 0 || tight.uncached > 0,
            "16 blocks over 64 groups must pressure the cache"
        );
    }

    #[test]
    fn legacy_parity_is_exact() {
        let k = Knobs::from(&small_params());
        for (_, groups) in SKEWS {
            assert_eq!(unbounded_vs_legacy_delta(&k, groups), 0.0, "{groups} groups");
        }
    }

    #[test]
    fn expectations_pass_on_default_grid() {
        // The full default grid is the artifact CI gates on; every
        // expectation must hold there.
        let reports = run();
        for e in CacheSweep.expectations(&CacheSweep.params()) {
            let res = e.evaluate(&reports);
            assert!(res.pass, "{}: {}", res.id, res.detail);
        }
    }
}
