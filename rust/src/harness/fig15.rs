//! Fig 15: embedding-lookup operator study (§4.1) — SDK-SingleTable,
//! custom SingleTable, BatchedTable (Gaudi TPC-C) vs FBGEMM (A100).

use crate::harness::{Experiment, Params};
use crate::ops::embedding::{self, rm2_work, EmbeddingImpl};
use crate::report::{Agg, Cell, Check, Expectation, Report, Selector, Unit};
use crate::sim::Dtype;

const IMPLS: [EmbeddingImpl; 4] = [
    EmbeddingImpl::GaudiSdkSingleTable,
    EmbeddingImpl::GaudiSingleTable,
    EmbeddingImpl::GaudiBatchedTable,
    EmbeddingImpl::A100Fbgemm,
];

pub struct Fig15;

impl Experiment for Fig15 {
    fn id(&self) -> &'static str {
        "fig15"
    }

    fn title(&self) -> &'static str {
        "Fig 15: embedding lookup operators (DLRM case study)"
    }

    fn run(&self, _params: &Params) -> Vec<Report> {
        // (a) utilization vs number of tables at low batch, 256 B vectors,
        // normalized to SingleTable @ 1 table.
        let mut a = Report::new("Fig 15(a): utilization vs #tables (batch 64, 256 B), normalized");
        a.header(&["tables", "SingleTable", "BatchedTable"]);
        let base = embedding::run(
            EmbeddingImpl::GaudiSingleTable,
            embedding::EmbeddingWork { tables: 1, batch: 64, pooling: 1, vec_bytes: 256.0 },
            Dtype::Fp32,
        )
        .bandwidth_utilization;
        for tables in [1usize, 2, 4, 8, 16] {
            let w = embedding::EmbeddingWork { tables, batch: 64, pooling: 1, vec_bytes: 256.0 };
            let s = embedding::run(EmbeddingImpl::GaudiSingleTable, w, Dtype::Fp32);
            let b = embedding::run(EmbeddingImpl::GaudiBatchedTable, w, Dtype::Fp32);
            a.row(vec![
                Cell::count(tables),
                Cell::val(s.bandwidth_utilization / base, Unit::Ratio),
                Cell::val(b.bandwidth_utilization / base, Unit::Ratio),
            ]);
        }
        a.note("BatchedTable grows with table count; SingleTable stays flat");

        // (b,c,d) utilization heatmaps per implementation.
        let mut out = vec![a];
        for imp in IMPLS {
            let mut r = Report::new(format!("Fig 15(b-d): {} bandwidth utilization", imp.name()));
            r.header(&["batch", "64B", "128B", "256B", "512B", "1KB", "2KB"]);
            for &batch in &[256usize, 1024, 4096, 16384] {
                let mut row = vec![Cell::count(batch)];
                for &v in &[64.0f64, 128.0, 256.0, 512.0, 1024.0, 2048.0] {
                    let u =
                        embedding::run(imp, rm2_work(batch, v), Dtype::Fp32).bandwidth_utilization;
                    row.push(Cell::val(u, Unit::Percent));
                }
                r.row(row);
            }
            out.push(r);
        }
        out.last_mut().unwrap().note(
            "paper: BatchedTable 34.2% avg / 70.5% peak vs A100 38.7% / 81.8%; \
             BatchedTable = 1.52x SingleTable; SDK = 37% of A100",
        );
        out
    }

    fn expectations(&self, _params: &Params) -> Vec<Expectation> {
        vec![
            Expectation::new(
                "fig15.batched_avg_utilization",
                "BatchedTable averages ~34.2% bandwidth utilization over the RM2 grid",
                Selector::body("BatchedTable bandwidth", Agg::Mean),
                Check::Between(0.26, 0.42),
            ),
            Expectation::new(
                "fig15.batched_scales_with_tables",
                "BatchedTable scales with table count, beating the flat SingleTable baseline",
                Selector::cell("Fig 15(a)", "16", "BatchedTable"),
                Check::Ge(1.2),
            ),
        ]
    }
}

/// Run with default params (convenience for tests and library callers).
pub fn run() -> Vec<Report> {
    Fig15.run(&Fig15.params())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::mean;

    #[test]
    fn five_reports_with_batched_avg_in_band() {
        let reports = run();
        assert_eq!(reports.len(), 5);
        let batched =
            reports.iter().find(|r| r.title().contains("BatchedTable bandwidth")).unwrap();
        let avg = mean(&batched.body_values());
        assert!((0.26..0.42).contains(&avg), "batched avg {avg}");
    }

    #[test]
    fn expectations_pass() {
        let reports = run();
        for e in Fig15.expectations(&Fig15.params()) {
            let res = e.evaluate(&reports);
            assert!(res.pass, "{}: {}", res.id, res.detail);
        }
    }
}
