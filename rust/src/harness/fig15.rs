//! Fig 15: embedding-lookup operator study (§4.1) — SDK-SingleTable,
//! custom SingleTable, BatchedTable (Gaudi TPC-C) vs FBGEMM (A100).

use crate::ops::embedding::{self, rm2_work, EmbeddingImpl};
use crate::sim::Dtype;
use crate::util::stats::mean;
use crate::util::table::{fmt_pct, fmt_ratio, Report};

const IMPLS: [EmbeddingImpl; 4] = [
    EmbeddingImpl::GaudiSdkSingleTable,
    EmbeddingImpl::GaudiSingleTable,
    EmbeddingImpl::GaudiBatchedTable,
    EmbeddingImpl::A100Fbgemm,
];

pub fn run() -> Vec<Report> {
    // (a) utilization vs number of tables at low batch, 256 B vectors,
    // normalized to SingleTable @ 1 table.
    let mut a = Report::new("Fig 15(a): utilization vs #tables (batch 64, 256 B), normalized");
    a.header(&["tables", "SingleTable", "BatchedTable"]);
    let base = embedding::run(
        EmbeddingImpl::GaudiSingleTable,
        embedding::EmbeddingWork { tables: 1, batch: 64, pooling: 1, vec_bytes: 256.0 },
        Dtype::Fp32,
    )
    .bandwidth_utilization;
    for tables in [1usize, 2, 4, 8, 16] {
        let w = embedding::EmbeddingWork { tables, batch: 64, pooling: 1, vec_bytes: 256.0 };
        let s = embedding::run(EmbeddingImpl::GaudiSingleTable, w, Dtype::Fp32);
        let b = embedding::run(EmbeddingImpl::GaudiBatchedTable, w, Dtype::Fp32);
        a.row(vec![
            tables.to_string(),
            fmt_ratio(s.bandwidth_utilization / base),
            fmt_ratio(b.bandwidth_utilization / base),
        ]);
    }
    a.note("BatchedTable grows with table count; SingleTable stays flat");

    // (b,c,d) utilization heatmaps per implementation.
    let mut out = vec![a];
    for imp in IMPLS {
        let mut r = Report::new(format!("Fig 15(b-d): {} bandwidth utilization", imp.name()));
        r.header(&["batch", "64B", "128B", "256B", "512B", "1KB", "2KB"]);
        let mut utils = Vec::new();
        for &batch in &[256usize, 1024, 4096, 16384] {
            let mut row = vec![batch.to_string()];
            for &v in &[64.0f64, 128.0, 256.0, 512.0, 1024.0, 2048.0] {
                let u = embedding::run(imp, rm2_work(batch, v), Dtype::Fp32)
                    .bandwidth_utilization;
                utils.push(u);
                row.push(fmt_pct(u));
            }
            r.row(row);
        }
        let peak = utils.iter().cloned().fold(f64::MIN, f64::max);
        r.note(format!("avg {} peak {}", fmt_pct(mean(&utils)), fmt_pct(peak)));
        out.push(r);
    }
    out.last_mut().unwrap().note(
        "paper: BatchedTable 34.2% avg / 70.5% peak vs A100 38.7% / 81.8%; \
         BatchedTable = 1.52x SingleTable; SDK = 37% of A100",
    );
    out
}

#[cfg(test)]
mod tests {
    #[test]
    fn five_reports_with_batched_avg_in_band() {
        let reports = super::run();
        assert_eq!(reports.len(), 5);
        let batched = reports
            .iter()
            .find(|r| r.title().contains("BatchedTable bandwidth"))
            .unwrap()
            .render();
        // avg note in the 26-42% band around the paper's 34.2%.
        let avg_line = batched.lines().find(|l| l.contains("avg")).unwrap();
        let pct: f64 = avg_line
            .split_whitespace()
            .find_map(|w| w.strip_suffix('%').and_then(|x| x.parse().ok()))
            .unwrap();
        assert!((26.0..42.0).contains(&pct), "batched avg {pct}%");
    }
}
