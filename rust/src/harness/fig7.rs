//! Fig 7: (a) which MME geometry the compiler model picks as a function of
//! (M, N) at K=16384; (b) the resulting compute utilization; (c)
//! configurable MME vs a fixed 256x256x2 output-stationary array.

use crate::config::DeviceKind;
use crate::sim::mme::{self, MME_CLOCK_HZ};
use crate::sim::systolic::{self, Geometry};
use crate::sim::Dtype;
use crate::util::table::{fmt_pct, Report};

const K: usize = 16384;
const SIZES: [usize; 7] = [64, 128, 256, 512, 1024, 2048, 8192];

pub fn run() -> Vec<Report> {
    let spec = DeviceKind::Gaudi2.spec();

    let mut geo = Report::new("Fig 7(a): MME geometry picked per (M, N), K=16384");
    let mut header = vec!["M \\ N".to_string()];
    header.extend(SIZES.iter().map(|n| n.to_string()));
    geo.header(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    let mut util = Report::new("Fig 7(b): resulting MME compute utilization");
    util.header(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    for &m in &SIZES {
        let mut grow = vec![m.to_string()];
        let mut urow = vec![m.to_string()];
        for &n in &SIZES {
            let r = mme::run_gemm(&spec, m, K, n, Dtype::Bf16);
            let gated = if r.active_mac_fraction < 1.0 { "*" } else { "" };
            grow.push(format!("{}{}", r.geometry.label(), gated));
            urow.push(fmt_pct(r.utilization));
        }
        geo.row(grow);
        util.row(urow);
    }
    geo.note("* = power-gated subset of the MAC array (gray configs in the paper)");

    let mut cmp = Report::new("Fig 7(c): configurable MME vs fixed 256x256x2 array (M=K=16384)");
    cmp.header(&["N", "configurable", "fixed", "improvement (pp)"]);
    for &n in &[16usize, 32, 64, 128, 256, 512] {
        let conf = mme::run_gemm(&spec, 16384, K, n, Dtype::Bf16);
        let fixed_t = systolic::gemm_cycles(Geometry::new(256, 256, 2), 16384, K, n);
        let mem_time = mme::gemm_traffic_bytes(16384, K, n, Dtype::Bf16)
            / (spec.hbm_bandwidth * 0.90);
        let fixed_time = (fixed_t.cycles / MME_CLOCK_HZ).max(mem_time);
        let fixed_util = mme::gemm_flops(16384, K, n) / fixed_time / spec.matrix_tflops;
        cmp.row(vec![
            n.to_string(),
            fmt_pct(conf.utilization),
            fmt_pct(fixed_util),
            format!("{:+.1}", 100.0 * (conf.utilization - fixed_util)),
        ]);
    }
    cmp.note("paper: configurability buys up to ~15pp of utilization");
    vec![geo, util, cmp]
}

#[cfg(test)]
mod tests {
    #[test]
    fn improvement_peaks_in_paper_band() {
        let reports = super::run();
        let text = reports[2].render();
        // At least one N shows a >8pp improvement and none exceeds ~25pp.
        let improvements: Vec<f64> = text
            .lines()
            .filter_map(|l| l.split_whitespace().last())
            .filter_map(|s| s.strip_prefix('+').and_then(|x| x.parse::<f64>().ok()))
            .collect();
        assert!(improvements.iter().any(|&x| x > 8.0), "{improvements:?}");
        assert!(improvements.iter().all(|&x| x < 25.0), "{improvements:?}");
    }

    #[test]
    fn small_gemms_power_gate() {
        let reports = super::run();
        assert!(reports[0].render().contains('*'), "expected power-gated configs");
    }
}
