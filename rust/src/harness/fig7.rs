//! Fig 7: (a) which MME geometry the compiler model picks as a function of
//! (M, N) at K=16384; (b) the resulting compute utilization; (c)
//! configurable MME vs a fixed 256x256x2 output-stationary array.

use crate::config::DeviceKind;
use crate::harness::{Experiment, Params};
use crate::report::{Agg, Cell, Check, Expectation, Report, Selector, Unit};
use crate::sim::mme::{self, MME_CLOCK_HZ};
use crate::sim::systolic::{self, Geometry};
use crate::sim::Dtype;

const K: usize = 16384;
const SIZES: [usize; 7] = [64, 128, 256, 512, 1024, 2048, 8192];

pub struct Fig7;

impl Experiment for Fig7 {
    fn id(&self) -> &'static str {
        "fig7"
    }

    fn title(&self) -> &'static str {
        "Fig 7: MME geometry configurability"
    }

    fn run(&self, _params: &Params) -> Vec<Report> {
        let spec = DeviceKind::Gaudi2.spec();

        let mut geo = Report::new("Fig 7(a): MME geometry picked per (M, N), K=16384");
        let mut header = vec!["M \\ N".to_string()];
        header.extend(SIZES.iter().map(|n| n.to_string()));
        geo.header(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
        let mut util = Report::new("Fig 7(b): resulting MME compute utilization");
        util.header(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
        for &m in &SIZES {
            let mut grow = vec![Cell::count(m)];
            let mut urow = vec![Cell::count(m)];
            for &n in &SIZES {
                let r = mme::run_gemm(&spec, m, K, n, Dtype::Bf16);
                let gated = if r.active_mac_fraction < 1.0 { "*" } else { "" };
                grow.push(Cell::text(format!("{}{}", r.geometry.label(), gated)));
                urow.push(Cell::val(r.utilization, Unit::Percent));
            }
            geo.row(grow);
            util.row(urow);
        }
        geo.note("* = power-gated subset of the MAC array (gray configs in the paper)");

        let mut cmp =
            Report::new("Fig 7(c): configurable MME vs fixed 256x256x2 array (M=K=16384)");
        cmp.header(&["N", "configurable", "fixed", "improvement (pp)"]);
        for &n in &[16usize, 32, 64, 128, 256, 512] {
            let conf = mme::run_gemm(&spec, 16384, K, n, Dtype::Bf16);
            let fixed_t = systolic::gemm_cycles(Geometry::new(256, 256, 2), 16384, K, n);
            let mem_time =
                mme::gemm_traffic_bytes(16384, K, n, Dtype::Bf16) / (spec.hbm_bandwidth * 0.90);
            let fixed_time = (fixed_t.cycles / MME_CLOCK_HZ).max(mem_time);
            let fixed_util = mme::gemm_flops(16384, K, n) / fixed_time / spec.matrix_tflops;
            cmp.row(vec![
                Cell::count(n),
                Cell::val(conf.utilization, Unit::Percent),
                Cell::val(fixed_util, Unit::Percent),
                Cell::val(100.0 * (conf.utilization - fixed_util), Unit::Pp),
            ]);
        }
        cmp.note("paper: configurability buys up to ~15pp of utilization");
        vec![geo, util, cmp]
    }

    fn expectations(&self, _params: &Params) -> Vec<Expectation> {
        vec![Expectation::new(
            "fig7.reconfig_peak_benefit",
            "configurability buys a double-digit utilization improvement on skinny N",
            Selector::column("Fig 7(c)", "improvement (pp)", Agg::Max),
            Check::Between(8.0, 25.0),
        )]
    }
}

/// Run with default params (convenience for tests and library callers).
pub fn run() -> Vec<Report> {
    Fig7.run(&Fig7.params())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn improvement_peaks_in_paper_band() {
        let reports = run();
        let improvements = reports[2].series("improvement (pp)").unwrap();
        assert!(improvements.max() > 8.0, "{:?}", improvements.values);
        assert!(improvements.max() < 25.0, "{:?}", improvements.values);
    }

    #[test]
    fn small_gemms_power_gate() {
        let reports = run();
        assert!(reports[0].render().contains('*'), "expected power-gated configs");
    }

    #[test]
    fn expectations_pass() {
        let reports = run();
        for e in Fig7.expectations(&Fig7.params()) {
            let res = e.evaluate(&reports);
            assert!(res.pass, "{}: {}", res.id, res.detail);
        }
    }
}
