//! # cuda-myth — reproduction of "Debunking the CUDA Myth Towards GPU-based AI Systems"
//!
//! This crate reproduces the CS.DC 2024 characterization of Intel's Gaudi-2
//! NPU against NVIDIA's A100 GPU for AI model serving. Since neither device
//! is available in this environment, the hardware is replaced by calibrated
//! architectural simulators (see `DESIGN.md` §1 for the substitution table):
//!
//! * [`sim`] — device-level models: the reconfigurable MME systolic array,
//!   VLIW TPC pipeline, A100 tensor cores with wave quantization, HBM access
//!   granularity (256 B vs 32 B sectors), P2P-mesh vs switched interconnect,
//!   collective-communication algorithms, activity-based power, and the
//!   Gaudi graph-compiler pipelining model.
//! * [`ops`] — operator-level models composed from `sim`: GEMM, STREAM,
//!   gather/scatter, FBGEMM-style embedding lookups (SingleTable vs
//!   BatchedTable), and PagedAttention (BlockTable vs BlockList).
//! * [`models`] — end-to-end workload cost models: DLRM-DCNv2 (RM1/RM2) and
//!   Llama-3.1 (8B/70B) with tensor parallelism.
//! * [`serving`] — the L3 coordination contribution: a vLLM-style serving
//!   stack (router, continuous batcher, paged KV-cache block manager)
//!   that drives either the simulators or real PJRT executables, layered
//!   for cluster-scale deployments:
//!
//!   ```text
//!   Backend (SimBackend | PjrtBackend)    step costs: simulated / wall
//!       │                                 (SimBackend memoizes decode
//!       │                                 costs by batch-composition
//!       │                                 signature, exact-verified hits)
//!       └── EngineCore<B, ClockSource>    one shared step loop (scheduler,
//!           │                             paged KV with ref-counted
//!           │                             shared-prefix blocks under a
//!           │                             finite budget + LRU/cost-aware
//!           │                             eviction, trace, metrics+energy);
//!           │                             provably-stable decode windows
//!           │                             macro-step k ticks per call
//!           │                             (`step_until`, bitwise-equal to
//!           │                             the retained micro oracle)
//!           └── ClusterSim                N replicas, each a *device
//!               │                         group* (`ReplicaSpec { device,
//!               │                         tp }`: homogeneous, mixed
//!               │                         Gaudi-2/A100, or tp-wide
//!               │                         tensor-parallel groups),
//!               │                         indexed discrete-event core
//!               │                         (arrival + replica-wake heaps,
//!               │                         streamed arrivals at O(open
//!               │                         requests) memory)
//!               ├── Router                dispatch (incl. cost-aware
//!               │                         prefix affinity over real block
//!               │                         residency, per-class QoS
//!               │                         penalty) + backpressure + drain
//!               └── Autoscaler            weighted-per-class-attainment
//!                                         scale-up/drain
//!                                         + J-per-good-token cost report
//!   ```
//!
//!   Cross-cutting the stack, `serving::qos` defines first-class traffic
//!   classes (`TrafficClass` / `ClassSet`): each request carries a
//!   `ClassId` fixing its SLO, scheduling priority and goodput weight;
//!   the scheduler admits/preempts by class priority, the router
//!   penalizes degraded per-class attainment, metrics judge each request
//!   against its own class's SLO, and the autoscaler controls on
//!   weighted per-class attainment. A single default class replays the
//!   legacy scalar-SLO path bitwise.
//!
//!   `serving::chaos` makes the stack's failure behavior first-class: a
//!   seeded, JSON-configurable `FaultSchedule` (replica crashes with
//!   restarts, straggler slow-clock windows, preemption storms) feeds a
//!   third min-heap of control events into the indexed event core;
//!   crashes requeue their replica's work through the router with
//!   no-lost-request conservation, the router hedges long-stuck requests
//!   to a second replica (first completion wins, the loser is cancelled
//!   without double-counting) and sheds priority-0 background traffic
//!   under overload, and metrics report goodput dip depth/area and
//!   time-to-recover. An empty schedule is bitwise-equal to no chaos at
//!   all.
//!
//!   `ServingConfig { replicas, route_policy, max_queued, fleet,
//!   prefix_cache_blocks, eviction, classes, hedge_after_s,
//!   shed_threshold, .. }` sizes the fleet — `fleet` is a
//!   `Vec<ReplicaSpec>`, each entry one device group whose `tp` cards
//!   shard every transformer block's GEMMs and KV heads and pay two
//!   all-reduces per block through the collective model (a tp=1 group
//!   replays the single-device path bitwise);
//!   `repro run cluster` produces the iso-SLO Gaudi-2 vs A100
//!   replica-count comparison, `repro run cluster-sweep` the
//!   goodput-under-SLO frontier across fleet mixes, `repro run
//!   cache-sweep` the prefix-cache capacity x skew grid (hit rate
//!   monotone in capacity; unbounded capacity bitwise-replays the legacy
//!   ever-warm set), `repro run qos-sweep` the class-mix x load grid
//!   (priorities help interactive attainment; single-default-class
//!   EqExact-0 parity with the scalar-SLO path), `repro run chaos-sweep`
//!   the fault-schedule x fleet grid (conservation, empty-schedule
//!   inertness, bounded recovery, hedging, background-only shedding),
//!   `repro run sim-speed` the simulator's own dispatch throughput
//!   (indexed event core vs the retained scan-loop oracle, decode
//!   macro-stepping vs the retained micro-step oracle: bitwise parity,
//!   events/sec, O(open requests) streaming memory), `repro
//!   run tp-sweep` the Llama-70B device-group scaling grid (tp=1 parity,
//!   monotone sub-linear tokens/s, HBM-bound at tp=1 / servable at
//!   tp>=4, mesh-vs-switch collective overhead share), and `repro run
//!   fleet-budget` the fixed-card-budget shape sweep (the same 8 cards
//!   as 8x tp1 / 4x tp2 / 2x tp4 / 1x tp8: card conservation, the tp=1
//!   HBM cliff, TTFT-vs-throughput crossover between wide groups and
//!   replicated narrow groups, J-per-good-token ledger).
//! * [`runtime`] — loads AOT-compiled HLO artifacts (JAX/Pallas, lowered at
//!   build time by `python/compile/aot.py`) and executes them on the PJRT
//!   CPU client. Python is never on the request path.
//! * [`harness`] — regenerates every table and figure in the paper's
//!   evaluation section. Each entry implements the `Experiment` trait
//!   (`id` / `title` / `params` / `run` / `expectations`); `repro run
//!   <exp|all> [--json] [--out DIR] [--check] [--jobs N]` renders ASCII,
//!   writes one `BENCH_<id>.json` artifact per experiment, and
//!   regression-checks the paper's headline claims. `--jobs` fans
//!   experiments and sweep grid points across `util::par`'s
//!   `std::thread::scope` pool (dependency-free, submission-ordered
//!   assembly): artifacts are byte-identical at any jobs count — the
//!   jobs-invariance contract pinned by `repro run par-speed` — and a
//!   panicking experiment fails alone without poisoning its siblings.
//! * [`report`] — the typed result model underneath the harness:
//!   `Value` (raw `f64` + `Unit`), `Cell`/`Report` tables that render to
//!   ASCII/CSV/JSON, `Series` column views, `Expectation` paper-claim
//!   assertions, and the `diff` trend engine behind `repro bench-diff`
//!   (the CI regression gate over `BENCH_*.json` artifact directories).
//!   `util::table` is the ASCII/CSV renderer over this model.
//! * [`workload`] — synthetic workload generators (fixed-length sweeps,
//!   Dynamic-Sonnet-like variable-length traces, Zipf embedding indices,
//!   token-level prompts for the real-numerics engine), eager
//!   (`generate` a `Vec<Request>`) or streaming (`ArrivalStream`: a lazy
//!   time-ordered iterator with constant-rate, diurnal-day, MMPP or
//!   flash-crowd arrival processes, fed to `ClusterSim::feed`).

pub mod config;
pub mod harness;
pub mod models;
pub mod ops;
pub mod report;
pub mod runtime;
pub mod serving;
pub mod sim;
pub mod util;
pub mod workload;

pub use config::device_specs::{DeviceKind, DeviceSpec};
