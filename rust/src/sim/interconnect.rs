//! Intra-node interconnect topologies (paper §2.1 "Communication").
//!
//! * HLS-Gaudi-2: every pair of the 8 devices is wired **point-to-point**
//!   with 3×100 GbE RoCE links (37.5 GB/s per direction per pair; 21 of the
//!   24 ports). A device's usable egress therefore *scales with the number
//!   of participants*: `(n-1) × 37.5 GB/s`.
//! * DGX A100: all devices hang off **NVSwitch**, so each GPU gets its full
//!   300 GB/s NVLink bandwidth regardless of how many GPUs communicate.
//!
//! This asymmetry is the whole mechanism of Fig 10 / Key Takeaway #4.

use crate::config::DeviceKind;
use crate::util::units::GB;

/// Maximum devices per server node (both systems).
pub const NODE_SIZE: usize = 8;

/// Node-level interconnect.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Topology {
    /// Full point-to-point mesh; `pair_bandwidth` per direction per pair.
    P2pMesh { pair_bandwidth: f64, latency: f64 },
    /// Central switch; `device_bandwidth` per direction per device.
    Switch { device_bandwidth: f64, latency: f64 },
}

impl Topology {
    /// The node topology shipped with each device family.
    pub fn for_device(kind: DeviceKind) -> Topology {
        match kind {
            // 3 × 100 GbE per pair; RoCE hop latency.
            DeviceKind::Gaudi2 => {
                Topology::P2pMesh { pair_bandwidth: 37.5 * GB, latency: 12e-6 }
            }
            // NVSwitch: 300 GB/s per direction per GPU; NVLink hop latency
            // (chunk pipelining hides most of the per-hop cost).
            DeviceKind::A100 => Topology::Switch { device_bandwidth: 300.0 * GB, latency: 3e-6 },
        }
    }

    /// Usable per-device egress bandwidth when `n` devices participate.
    pub fn egress_bandwidth(&self, n: usize) -> f64 {
        assert!((2..=NODE_SIZE).contains(&n), "participants {n}");
        match self {
            Topology::P2pMesh { pair_bandwidth, .. } => (n as f64 - 1.0) * pair_bandwidth,
            Topology::Switch { device_bandwidth, .. } => *device_bandwidth,
        }
    }

    /// Per-step latency (alpha term).
    pub fn step_latency(&self) -> f64 {
        match self {
            Topology::P2pMesh { latency, .. } | Topology::Switch { latency, .. } => *latency,
        }
    }

    /// Nominal aggregate per-device bandwidth used as the utilization
    /// denominator (both nodes: 300 GB/s, per the paper).
    pub fn nominal_bandwidth(&self) -> f64 {
        300.0 * GB
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gaudi_egress_scales_with_participants() {
        let t = Topology::for_device(DeviceKind::Gaudi2);
        // Paper: 2 devices -> 300 Gbps (37.5 GB/s) = 1/8 of max 2.4 Tbps.
        assert!((t.egress_bandwidth(2) - 37.5 * GB).abs() < 1.0);
        assert!((t.egress_bandwidth(8) - 262.5 * GB).abs() < 1.0);
        let r = t.egress_bandwidth(2) / t.egress_bandwidth(8);
        assert!((r - 1.0 / 7.0).abs() < 1e-9);
    }

    #[test]
    fn a100_egress_flat() {
        let t = Topology::for_device(DeviceKind::A100);
        assert_eq!(t.egress_bandwidth(2), t.egress_bandwidth(8));
        assert!((t.egress_bandwidth(4) - 300.0 * GB).abs() < 1.0);
    }

    #[test]
    #[should_panic]
    fn rejects_single_participant() {
        Topology::for_device(DeviceKind::A100).egress_bandwidth(1);
    }
}
