//! Unified device façade over the per-engine simulators: one entry point
//! for "run a GEMM / stream op / gather on this device" that dispatches to
//! the MME or Tensor-Core model and carries the spec + power model along.

use crate::config::{DeviceKind, DeviceSpec};
use crate::sim::power::{Activity, PowerModel};
use crate::sim::{memory, mme, tensor_core, Dtype};

/// Execution result common to both matrix engines.
#[derive(Debug, Clone)]
pub struct GemmExec {
    pub time: f64,
    pub achieved_flops: f64,
    /// achieved / device matrix peak.
    pub utilization: f64,
    pub memory_bound: bool,
    /// Gaudi: fraction of MME powered on; A100: always 1.0.
    pub matrix_active_fraction: f64,
    /// Human-readable engine configuration (geometry or CTA tile).
    pub config: String,
}

/// A simulated device: spec + power model.
#[derive(Debug, Clone)]
pub struct Device {
    pub spec: DeviceSpec,
    pub power: PowerModel,
}

impl Device {
    pub fn new(kind: DeviceKind) -> Device {
        Device { spec: kind.spec(), power: PowerModel::for_device(kind) }
    }

    pub fn kind(&self) -> DeviceKind {
        self.spec.kind
    }

    /// Run GEMM (m,k,n) on the device's matrix engine.
    pub fn gemm(&self, m: usize, k: usize, n: usize, dtype: Dtype) -> GemmExec {
        match self.spec.kind {
            DeviceKind::Gaudi2 => {
                let r = mme::run_gemm(&self.spec, m, k, n, dtype);
                GemmExec {
                    time: r.time,
                    achieved_flops: r.achieved_flops,
                    utilization: r.utilization,
                    memory_bound: r.memory_bound,
                    matrix_active_fraction: r.active_mac_fraction,
                    config: r.geometry.label(),
                }
            }
            DeviceKind::A100 => {
                let r = tensor_core::run_gemm(&self.spec, m, k, n, dtype);
                GemmExec {
                    time: r.time,
                    achieved_flops: r.achieved_flops,
                    utilization: r.utilization,
                    memory_bound: r.memory_bound,
                    matrix_active_fraction: 1.0,
                    config: format!("{}x{}", r.tile.0, r.tile.1),
                }
            }
        }
    }

    /// Random gather of `n_vectors` × `vec_bytes`.
    pub fn gather(&self, n_vectors: f64, vec_bytes: f64) -> memory::GatherResult {
        memory::random_access(&self.spec, memory::AccessDir::Gather, n_vectors, vec_bytes)
    }

    /// Random scatter of `n_vectors` × `vec_bytes`.
    pub fn scatter(&self, n_vectors: f64, vec_bytes: f64) -> memory::GatherResult {
        memory::random_access(&self.spec, memory::AccessDir::Scatter, n_vectors, vec_bytes)
    }

    /// Average power draw (watts) for a GEMM-dominated phase.
    pub fn gemm_power(&self, exec: &GemmExec, hbm_util: f64) -> f64 {
        self.power.power(Activity {
            matrix_util: exec.utilization / exec.matrix_active_fraction.max(1e-6),
            matrix_active_fraction: exec.matrix_active_fraction,
            vector_util: 0.1, // epilogue / activation work
            hbm_util,
            comm_util: 0.0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_dispatches_per_device() {
        let g = Device::new(DeviceKind::Gaudi2).gemm(8192, 8192, 8192, Dtype::Bf16);
        let a = Device::new(DeviceKind::A100).gemm(8192, 8192, 8192, Dtype::Bf16);
        // Fig 4: Gaudi-2 consistently outperforms A100 on GEMM.
        assert!(g.achieved_flops > a.achieved_flops);
        assert!(g.config.contains('x'));
        assert_eq!(a.matrix_active_fraction, 1.0);
    }

    #[test]
    fn fig4_gaudi_wins_all_explored_shapes() {
        let gd = Device::new(DeviceKind::Gaudi2);
        let ad = Device::new(DeviceKind::A100);
        for &(m, k, n) in &[
            (512usize, 512usize, 512usize),
            (1024, 1024, 1024),
            (2048, 2048, 2048),
            (4096, 4096, 4096),
            (8192, 8192, 8192),
            (4096, 4096, 16),
            (8192, 8192, 16),
            (16384, 16384, 16),
        ] {
            let g = gd.gemm(m, k, n, Dtype::Bf16);
            let a = ad.gemm(m, k, n, Dtype::Bf16);
            assert!(
                g.achieved_flops >= a.achieved_flops,
                "({m},{k},{n}): gaudi {} < a100 {}",
                g.achieved_flops / 1e12,
                a.achieved_flops / 1e12
            );
        }
    }

    #[test]
    fn gather_uses_memory_model() {
        let d = Device::new(DeviceKind::Gaudi2);
        let r = d.gather(1e6, 256.0);
        assert!(r.utilization > 0.3 && r.utilization < 0.8);
    }

    #[test]
    fn gemm_power_within_tdp() {
        let d = Device::new(DeviceKind::Gaudi2);
        let e = d.gemm(8192, 8192, 8192, Dtype::Bf16);
        let p = d.gemm_power(&e, 0.3);
        assert!(p > 100.0 && p <= d.spec.tdp_watts, "power {p}");
    }
}
