//! Collective-communication model (Fig 10): the six collectives the paper
//! benchmarks with HCCL (Gaudi) and NCCL (A100), timed with an alpha-beta
//! cost model over the node topology, and reported in the **bus bandwidth**
//! accounting of NCCL-tests (`busbw = algbw × factor`).
//!
//! Algorithm choices follow the vendors' libraries:
//! * HCCL on the P2P mesh uses *direct* (fully-connected) algorithms —
//!   every device exchanges shards with every peer simultaneously, so the
//!   achievable bandwidth is the mesh egress `(n-1)×37.5 GB/s`.
//! * NCCL on NVSwitch uses ring pipelines at a protocol efficiency that is
//!   per-collective (single-root Reduce notoriously underuses the switch).

use crate::config::DeviceKind;
use crate::sim::interconnect::Topology;

/// The six collective patterns of Fig 10.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Collective {
    AllReduce,
    AllGather,
    ReduceScatter,
    AllToAll,
    Reduce,
    Broadcast,
}

pub const ALL_COLLECTIVES: [Collective; 6] = [
    Collective::AllReduce,
    Collective::AllGather,
    Collective::ReduceScatter,
    Collective::AllToAll,
    Collective::Reduce,
    Collective::Broadcast,
];

impl Collective {
    pub fn name(&self) -> &'static str {
        match self {
            Collective::AllReduce => "AllReduce",
            Collective::AllGather => "AllGather",
            Collective::ReduceScatter => "ReduceScatter",
            Collective::AllToAll => "AlltoAll",
            Collective::Reduce => "Reduce",
            Collective::Broadcast => "Broadcast",
        }
    }

    /// NCCL-tests busbw correction factor (doc/PERFORMANCE.md).
    pub fn busbw_factor(&self, n: usize) -> f64 {
        let nf = n as f64;
        match self {
            Collective::AllReduce => 2.0 * (nf - 1.0) / nf,
            Collective::AllGather | Collective::ReduceScatter | Collective::AllToAll => {
                (nf - 1.0) / nf
            }
            Collective::Reduce | Collective::Broadcast => 1.0,
        }
    }

    /// Bytes each device must move per unit payload (per direction),
    /// normalized by payload size S, for the *direct* mesh algorithm, plus
    /// the number of alpha steps.
    fn mesh_cost(&self, n: usize) -> (f64, f64) {
        let nf = n as f64;
        let shard = (nf - 1.0) / nf;
        match self {
            // reduce-scatter phase + all-gather phase.
            Collective::AllReduce => (2.0 * shard, 2.0),
            Collective::AllGather | Collective::ReduceScatter => (shard, 1.0),
            Collective::AllToAll => (shard, 1.0),
            // reduce-scatter then shard-gather at the root.
            Collective::Reduce => (2.0 * shard, 2.0),
            // root scatters distinct shards, then peers all-gather them;
            // second phase is bounded by the (n-1)-degree subgraph and
            // carries a relay inefficiency.
            Collective::Broadcast => (2.2 * shard, 2.0),
        }
    }

    /// NCCL ring protocol efficiency on NVSwitch (fraction of 300 GB/s).
    fn nccl_efficiency(&self) -> f64 {
        match self {
            Collective::AllReduce => 0.78,
            Collective::AllGather => 0.80,
            Collective::ReduceScatter => 0.80,
            Collective::AllToAll => 0.72,
            // Single-root collectives pipeline poorly through the switch.
            Collective::Reduce => 0.42,
            Collective::Broadcast => 0.80,
        }
    }

    /// HCCL direct-algorithm efficiency on the mesh.
    fn hccl_efficiency(&self) -> f64 {
        match self {
            Collective::AllToAll => 0.95, // dedicated pairwise links: near ideal
            Collective::Reduce => 0.95,
            _ => 0.97,
        }
    }
}

/// Result of one collective execution.
#[derive(Debug, Clone, Copy)]
pub struct CollectiveResult {
    /// Wall time, seconds.
    pub time: f64,
    /// Algorithm bandwidth S/t, bytes/sec.
    pub algbw: f64,
    /// Bus bandwidth (NCCL accounting), bytes/sec.
    pub busbw: f64,
    /// busbw / 300 GB/s — the y-axis of Fig 10.
    pub utilization: f64,
}

/// The unified collective cost model: one device kind bound to its node
/// topology, pricing every collective the fig-10 harness benchmarks AND
/// the tensor-parallel all-reduces the serving path pays — one type, so
/// the microbenchmark numbers and the serving simulator can never drift
/// apart.
#[derive(Debug, Clone, Copy)]
pub struct CollectiveModel {
    kind: DeviceKind,
    topo: Topology,
}

impl CollectiveModel {
    /// The model for one device kind on its native node topology
    /// (Gaudi-2: 24x100GbE P2P mesh; A100: NVSwitch).
    pub fn for_device(kind: DeviceKind) -> CollectiveModel {
        CollectiveModel { kind, topo: Topology::for_device(kind) }
    }

    pub fn device(&self) -> DeviceKind {
        self.kind
    }

    /// The node topology the model prices against.
    pub fn topology(&self) -> Topology {
        self.topo
    }

    /// Run `coll` over `n` devices with per-device payload `bytes`.
    pub fn run(&self, coll: Collective, n: usize, bytes: f64) -> CollectiveResult {
        assert!((2..=8).contains(&n), "devices {n}");
        assert!(bytes > 0.0);
        let topo = self.topo;
        let (t_bw, steps) = match self.kind {
            DeviceKind::Gaudi2 => {
                let (traffic, steps) = coll.mesh_cost(n);
                let bw = topo.egress_bandwidth(n) * coll.hccl_efficiency();
                (bytes * traffic / bw, steps)
            }
            DeviceKind::A100 => {
                // Ring pipelines move the same shard traffic as the direct
                // algorithm but at NVSwitch's flat per-device bandwidth;
                // ring latency grows with the number of hops.
                let (traffic, _) = coll.mesh_cost(n.min(8));
                let traffic = match coll {
                    // NCCL ring broadcast/reduce forward the full payload.
                    Collective::Broadcast | Collective::Reduce => 1.0,
                    _ => traffic,
                };
                let bw = topo.egress_bandwidth(n) * coll.nccl_efficiency();
                (bytes * traffic / bw, (n as f64 - 1.0))
            }
        };
        let time = t_bw + steps * topo.step_latency();
        let algbw = bytes / time;
        let busbw = algbw * coll.busbw_factor(n);
        CollectiveResult { time, algbw, busbw, utilization: busbw / topo.nominal_bandwidth() }
    }

    /// Time for an AllReduce of `bytes` over `n` devices — the
    /// tensor-parallel primitive the LLM serving model pays twice per
    /// transformer block. A single-device "group" communicates nothing.
    pub fn allreduce_time(&self, n: usize, bytes: f64) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        self.run(Collective::AllReduce, n, bytes).time
    }
}

/// Run `coll` over `n` devices with per-device payload `bytes` on the node
/// topology of `kind`. Delegating wrapper over [`CollectiveModel::run`].
pub fn run(kind: DeviceKind, coll: Collective, n: usize, bytes: f64) -> CollectiveResult {
    CollectiveModel::for_device(kind).run(coll, n, bytes)
}

/// Convenience: time for an AllReduce of `bytes` over `n` devices — the
/// tensor-parallel primitive used by the LLM serving model. Delegating
/// wrapper over [`CollectiveModel::allreduce_time`].
pub fn allreduce_time(kind: DeviceKind, n: usize, bytes: f64) -> f64 {
    CollectiveModel::for_device(kind).allreduce_time(n, bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::MB;

    #[test]
    fn fig10_gaudi_wins_5_of_6_at_8_devices() {
        let mut gaudi_wins = 0;
        for coll in ALL_COLLECTIVES {
            let g = run(DeviceKind::Gaudi2, coll, 8, 32.0 * MB);
            let a = run(DeviceKind::A100, coll, 8, 32.0 * MB);
            if g.utilization > a.utilization {
                gaudi_wins += 1;
            }
        }
        assert_eq!(gaudi_wins, 5, "gaudi should win 5 of 6 at n=8");
    }

    #[test]
    fn fig10_gaudi_declines_linearly_with_fewer_devices() {
        for coll in [Collective::AllReduce, Collective::AllGather] {
            let u8 = run(DeviceKind::Gaudi2, coll, 8, 32.0 * MB).utilization;
            let u4 = run(DeviceKind::Gaudi2, coll, 4, 32.0 * MB).utilization;
            let u2 = run(DeviceKind::Gaudi2, coll, 2, 32.0 * MB).utilization;
            assert!(u8 > u4 && u4 > u2, "{}: {u8} {u4} {u2}", coll.name());
            // Near-linear in (n-1): u2/u8 ≈ (1/7) · (busbw factor ratio).
            assert!(u2 / u8 < 0.30, "{}: ratio {}", coll.name(), u2 / u8);
        }
    }

    #[test]
    fn fig10_a100_stable_across_device_counts() {
        for coll in ALL_COLLECTIVES {
            let u8 = run(DeviceKind::A100, coll, 8, 32.0 * MB).utilization;
            let u2 = run(DeviceKind::A100, coll, 2, 32.0 * MB).utilization;
            assert!(
                (u2 - u8).abs() / u8 < 0.30,
                "{}: u2 {} vs u8 {}",
                coll.name(),
                u2,
                u8
            );
        }
    }

    #[test]
    fn small_messages_are_latency_bound() {
        let small = run(DeviceKind::Gaudi2, Collective::AllReduce, 8, 2e3);
        let large = run(DeviceKind::Gaudi2, Collective::AllReduce, 8, 32.0 * MB);
        assert!(small.utilization < 0.05 * large.utilization);
    }

    #[test]
    fn allreduce_busbw_factor() {
        assert!((Collective::AllReduce.busbw_factor(8) - 1.75).abs() < 1e-12);
        assert!((Collective::AllGather.busbw_factor(8) - 0.875).abs() < 1e-12);
        assert_eq!(Collective::Broadcast.busbw_factor(8), 1.0);
    }

    #[test]
    fn allreduce_time_zero_for_single_device() {
        assert_eq!(allreduce_time(DeviceKind::Gaudi2, 1, 1e6), 0.0);
        assert!(allreduce_time(DeviceKind::Gaudi2, 8, 1e6) > 0.0);
    }

    #[test]
    fn model_and_free_functions_agree_bitwise() {
        // The free functions are delegating wrappers: same f64s, always.
        for kind in [DeviceKind::Gaudi2, DeviceKind::A100] {
            let m = CollectiveModel::for_device(kind);
            assert_eq!(m.device(), kind);
            assert_eq!(m.topology(), Topology::for_device(kind));
            for coll in ALL_COLLECTIVES {
                for n in [2usize, 4, 8] {
                    for bytes in [2e3, 2.0 * MB, 32.0 * MB] {
                        let a = m.run(coll, n, bytes);
                        let b = run(kind, coll, n, bytes);
                        assert_eq!(a.time, b.time);
                        assert_eq!(a.busbw, b.busbw);
                        assert_eq!(a.utilization, b.utilization);
                    }
                }
            }
            for n in 1..=8 {
                assert_eq!(m.allreduce_time(n, 4.0 * MB), allreduce_time(kind, n, 4.0 * MB));
            }
        }
    }

    #[test]
    fn busbw_factor_is_monotone_in_participants() {
        // Every collective's busbw correction factor is nondecreasing in
        // n (AllReduce: 2(n-1)/n climbs toward 2; single-root factors are
        // constant 1), and AllReduce's strictly increases.
        for coll in ALL_COLLECTIVES {
            for n in 2..8usize {
                assert!(
                    coll.busbw_factor(n + 1) >= coll.busbw_factor(n),
                    "{} factor dropped from n={n} to n={}",
                    coll.name(),
                    n + 1
                );
            }
        }
        for n in 2..8usize {
            assert!(Collective::AllReduce.busbw_factor(n + 1) > Collective::AllReduce.busbw_factor(n));
        }
    }

    #[test]
    fn gaudi_utilization_at_8_near_87pct_for_allreduce() {
        // egress(8)=262.5 GB/s of nominal 300 -> ~85% with protocol eff.
        let g = run(DeviceKind::Gaudi2, Collective::AllReduce, 8, 32.0 * MB);
        assert!(g.utilization > 0.75 && g.utilization < 0.88, "{}", g.utilization);
    }
}
