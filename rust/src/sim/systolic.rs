//! Generic output-stationary systolic-array timing model.
//!
//! Used two ways: (1) as the building block of the reconfigurable Gaudi MME
//! (`sim::mme`), which evaluates this model over its menu of geometries and
//! keeps the fastest; and (2) directly, as the *non-configurable* baseline
//! of Fig 6(a)/Fig 7(c) — a fixed 256×256×2 array with the same peak FLOPS.
//!
//! Model (paper §3.2, Fig 6): an H×W output-stationary array computes an
//! (M,K,N) GEMM as `ceil(M/H)·ceil(N/W)` output tiles. Each tile streams K
//! partial products; edge tiles waste the MAC rows/columns that fall outside
//! M and N. Tile passes are software-pipelined by the compiler, so fill and
//! drain (H+W cycles) are paid once per kernel plus a small per-tile
//! writeback overlap overhead.

use crate::util::ceil_div;

/// Geometry of a systolic array: `h` rows (mapped to GEMM M) × `w` columns
/// (mapped to GEMM N). `lanes` counts stacked arrays working on independent
/// output tiles (the two Gaudi MME halves in their default configuration).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Geometry {
    pub h: usize,
    pub w: usize,
    pub lanes: usize,
}

impl Geometry {
    pub const fn new(h: usize, w: usize, lanes: usize) -> Self {
        Geometry { h, w, lanes }
    }

    /// Total MAC units in this configuration.
    pub fn macs(&self) -> usize {
        self.h * self.w * self.lanes
    }

    pub fn label(&self) -> String {
        if self.lanes == 1 {
            format!("{}x{}", self.h, self.w)
        } else {
            format!("{}x{}x{}", self.h, self.w, self.lanes)
        }
    }
}

/// Per-tile writeback/setup overhead (cycles) that cannot be hidden by the
/// inter-tile pipeline. Calibrated so a 8192^3 GEMM reaches ~99.3% MME
/// utilization (paper Fig 4: 429 of 432 TFLOPS).
pub const TILE_OVERHEAD_CYCLES: f64 = 58.0;

/// Result of evaluating the timing model for one geometry.
#[derive(Debug, Clone, Copy)]
pub struct SystolicTiming {
    /// Total cycles to drain the GEMM through the array.
    pub cycles: f64,
    /// Fraction of MAC·cycles doing useful work (compute utilization
    /// relative to this geometry running flat out).
    pub geometric_utilization: f64,
}

/// Evaluate the compute-side timing of GEMM (m,k,n) on geometry `g`.
///
/// Returns cycles assuming the array is never starved by memory — the
/// memory bound is applied by the caller (roofline min).
pub fn gemm_cycles(g: Geometry, m: usize, k: usize, n: usize) -> SystolicTiming {
    assert!(m > 0 && k > 0 && n > 0, "GEMM dims must be positive");
    let tiles_m = ceil_div(m, g.h);
    let tiles_n = ceil_div(n, g.w);
    let tiles = (tiles_m * tiles_n) as f64;
    // `lanes` arrays process independent tiles concurrently.
    let tile_waves = (tiles / g.lanes as f64).ceil();
    // Each tile pass streams K elements + overlapped writeback overhead;
    // one fill+drain for the whole kernel.
    let cycles = tile_waves * (k as f64 + TILE_OVERHEAD_CYCLES) + (g.h + g.w) as f64;
    // Useful MAC-cycles vs occupied MAC-cycles.
    let useful = (m * n * k) as f64;
    let occupied = cycles * g.macs() as f64;
    SystolicTiming { cycles, geometric_utilization: (useful / occupied).min(1.0) }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FULL: Geometry = Geometry::new(256, 256, 2);

    #[test]
    fn big_square_gemm_is_nearly_fully_utilized() {
        let t = gemm_cycles(FULL, 8192, 8192, 8192);
        assert!(
            t.geometric_utilization > 0.98 && t.geometric_utilization <= 1.0,
            "util {}",
            t.geometric_utilization
        );
    }

    #[test]
    fn small_n_underutilizes_fixed_array() {
        // Fig 6(a): N=16 < W=256 wastes most columns of a fixed array.
        let t = gemm_cycles(FULL, 8192, 8192, 16);
        assert!(t.geometric_utilization < 0.10, "util {}", t.geometric_utilization);
    }

    #[test]
    fn utilization_bounded() {
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (100, 300, 7), (4096, 16, 4096)] {
            let t = gemm_cycles(FULL, m, k, n);
            assert!(t.geometric_utilization > 0.0 && t.geometric_utilization <= 1.0);
            assert!(t.cycles > 0.0);
        }
    }

    #[test]
    fn more_lanes_fewer_cycles() {
        let one = gemm_cycles(Geometry::new(256, 256, 1), 4096, 4096, 4096);
        let two = gemm_cycles(Geometry::new(256, 256, 2), 4096, 4096, 4096);
        assert!(two.cycles < one.cycles);
    }

    #[test]
    fn geometry_macs_and_label() {
        assert_eq!(FULL.macs(), 131072);
        assert_eq!(FULL.label(), "256x256x2");
        assert_eq!(Geometry::new(512, 256, 1).label(), "512x256");
    }
}
