//! Architectural simulators for Intel Gaudi-2 and NVIDIA A100.
//!
//! These are *calibrated analytic models*, not cycle-accurate RTL: each
//! module encodes the specific microarchitectural mechanism the paper
//! attributes its results to (MME geometry reconfiguration, TPC VLIW
//! pipelining, 256 B vs 32 B memory access granularity, P2P mesh vs
//! NVSwitch, MME power gating) and the emergent numbers are validated
//! against the paper's reported figures by `rust/tests/paper_bands.rs`.

pub mod collective;
pub mod device;
pub mod graph_compiler;
pub mod interconnect;
pub mod memory;
pub mod mme;
pub mod power;
pub mod simd;
pub mod systolic;
pub mod tensor_core;
pub mod tpc;

pub use device::Device;

/// Numeric datatype of an operation; the paper evaluates BF16 everywhere
/// except end-to-end RecSys (FP32).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dtype {
    Bf16,
    Fp16,
    Fp32,
}

impl Dtype {
    pub fn bytes(&self) -> f64 {
        match self {
            Dtype::Bf16 | Dtype::Fp16 => 2.0,
            Dtype::Fp32 => 4.0,
        }
    }

    /// Matrix-engine peak derating relative to BF16 peak (FP32 GEMM runs at
    /// roughly half rate on both MME and Tensor Cores w/ TF32 disabled).
    pub fn matrix_peak_factor(&self) -> f64 {
        match self {
            Dtype::Bf16 | Dtype::Fp16 => 1.0,
            Dtype::Fp32 => 0.5,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_sizes() {
        assert_eq!(Dtype::Bf16.bytes(), 2.0);
        assert_eq!(Dtype::Fp32.bytes(), 4.0);
        assert_eq!(Dtype::Fp32.matrix_peak_factor(), 0.5);
    }
}
