//! Gaudi-2 Matrix Multiplication Engine (MME) model.
//!
//! The MME is an output-stationary systolic array built from two 256×256
//! MAC halves that the graph compiler can reconfigure at runtime into
//! different geometries (512×256, 1024×128, ...) to match the target GEMM's
//! (M,K,N) shape — the mechanism behind the paper's Key Takeaway #1 and
//! Fig 6(b)/Fig 7. For small GEMMs only a subset of the MAC array is
//! activated and the rest is power-gated (the gray configurations in
//! Fig 7(a)), which the power model consumes via `active_mac_fraction`.
//!
//! This module enumerates the geometry menu, evaluates the generic systolic
//! timing model for each, applies the HBM roofline, and keeps the fastest
//! configuration (ties broken toward fewer active MACs = power gating).

use crate::config::DeviceSpec;
use crate::sim::systolic::{self, Geometry};
use crate::sim::Dtype;

/// Total MAC units across both MME halves.
pub const TOTAL_MACS: usize = 256 * 256 * 2;

/// MME clock: 432 TFLOPS BF16 = 2 FLOP/MAC/cycle × 131072 MACs × f.
pub const MME_CLOCK_HZ: f64 = 432e12 / (2.0 * TOTAL_MACS as f64);

/// Fraction of peak HBM bandwidth a well-blocked GEMM stream sustains.
const GEMM_HBM_EFFICIENCY: f64 = 0.90;

/// Extra DRAM traffic factor over the ideal one-pass-per-matrix lower bound
/// (imperfect SRAM blocking at tile edges).
const TRAFFIC_OVERHEAD: f64 = 1.05;

/// The menu of geometries the graph compiler can configure.
///
/// Full-power configurations use all 131072 MACs in different aspect
/// ratios; power-gated subsets activate part of the array for GEMMs too
/// small to fill it.
pub fn geometry_menu() -> Vec<Geometry> {
    vec![
        // Full-power reconfigurations of the 2 × (256×256) array.
        Geometry::new(256, 256, 2),
        Geometry::new(512, 256, 1),
        Geometry::new(256, 512, 1),
        Geometry::new(1024, 128, 1),
        Geometry::new(128, 1024, 1),
        Geometry::new(2048, 64, 1),
        Geometry::new(64, 2048, 1),
        // Power-gated subsets (gray configs in Fig 7(a)).
        Geometry::new(256, 256, 1),
        Geometry::new(512, 128, 1),
        Geometry::new(128, 512, 1),
        Geometry::new(1024, 64, 1),
        Geometry::new(64, 1024, 1),
        Geometry::new(256, 128, 1),
        Geometry::new(128, 256, 1),
        Geometry::new(128, 128, 1),
        Geometry::new(64, 64, 1),
    ]
}

/// Outcome of executing a GEMM on the MME.
#[derive(Debug, Clone)]
pub struct MmeGemm {
    /// Chosen systolic-array geometry.
    pub geometry: Geometry,
    /// End-to-end time (seconds), roofline of compute and HBM.
    pub time: f64,
    /// Achieved FLOP/s.
    pub achieved_flops: f64,
    /// Achieved / 432 TFLOPS peak (the paper's "compute utilization").
    pub utilization: f64,
    /// Fraction of the MAC array powered on (for the energy model).
    pub active_mac_fraction: f64,
    /// True if the HBM side, not the MAC array, set the execution time.
    pub memory_bound: bool,
}

/// DRAM traffic lower bound for an SRAM-blocked GEMM: each operand and the
/// output cross HBM once, with a small blocking-overhead factor.
pub fn gemm_traffic_bytes(m: usize, k: usize, n: usize, dtype: Dtype) -> f64 {
    let elems = (m * k + k * n + m * n) as f64;
    elems * dtype.bytes() * TRAFFIC_OVERHEAD
}

/// FLOP count of GEMM (multiply + accumulate).
pub fn gemm_flops(m: usize, k: usize, n: usize) -> f64 {
    2.0 * m as f64 * k as f64 * n as f64
}

/// Execute GEMM (m,k,n) on the MME, letting the graph-compiler model pick
/// the geometry. `spec` must be the Gaudi-2 spec (used for HBM bandwidth).
pub fn run_gemm(spec: &DeviceSpec, m: usize, k: usize, n: usize, dtype: Dtype) -> MmeGemm {
    let flops = gemm_flops(m, k, n);
    let mem_time = gemm_traffic_bytes(m, k, n, dtype) / (spec.hbm_bandwidth * GEMM_HBM_EFFICIENCY);
    // Clock derived from the spec so scaled projections (e.g. Gaudi-3,
    // DeviceSpec::gaudi3_projection) speed up the MAC array accordingly.
    let clock = spec.matrix_tflops / (2.0 * TOTAL_MACS as f64) * dtype.matrix_peak_factor();

    let mut best: Option<(MmeGemm, f64)> = None;
    for g in geometry_menu() {
        let t = systolic::gemm_cycles(g, m, k, n);
        let compute_time = t.cycles / clock;
        let time = compute_time.max(mem_time);
        let cand = MmeGemm {
            geometry: g,
            time,
            achieved_flops: flops / time,
            utilization: flops / time / spec.matrix_tflops,
            active_mac_fraction: g.macs() as f64 / TOTAL_MACS as f64,
            memory_bound: mem_time > compute_time,
        };
        let better = match &best {
            None => true,
            Some((b, b_geom_util)) => {
                // Faster wins; within 0.1% tie, fewer active MACs (power
                // gating) wins; then better geometric fit.
                if cand.time < b.time * 0.999 {
                    true
                } else if cand.time <= b.time * 1.001 {
                    (cand.geometry.macs(), -t.geometric_utilization)
                        < (b.geometry.macs(), -*b_geom_util)
                } else {
                    false
                }
            }
        };
        if better {
            best = Some((cand, t.geometric_utilization));
        }
    }
    best.expect("non-empty geometry menu").0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceKind;

    fn spec() -> DeviceSpec {
        DeviceKind::Gaudi2.spec()
    }

    #[test]
    fn peak_clock_is_consistent() {
        // 432 TFLOPS at 2 FLOP/MAC/cycle over 131072 MACs -> ~1.648 GHz.
        assert!((MME_CLOCK_HZ - 1.648e9).abs() < 5e6, "{MME_CLOCK_HZ}");
    }

    #[test]
    fn fig4_big_square_gemm_hits_99_pct_peak() {
        // Paper: 429 TFLOPS at M=K=N=8192 = 99.3% of peak.
        let r = run_gemm(&spec(), 8192, 8192, 8192, Dtype::Bf16);
        assert!(r.utilization > 0.985 && r.utilization <= 1.0, "util {}", r.utilization);
        assert!(r.achieved_flops > 425e12, "achieved {}", r.achieved_flops / 1e12);
        assert!(!r.memory_bound);
    }

    #[test]
    fn irregular_gemm_is_memory_bound() {
        // Fig 4 triangles: N=16 tall-skinny GEMMs sit on the bandwidth roof.
        let r = run_gemm(&spec(), 8192, 8192, 16, Dtype::Bf16);
        assert!(r.memory_bound);
        assert!(r.utilization < 0.12, "util {}", r.utilization);
    }

    #[test]
    fn small_gemm_power_gates() {
        // Fig 7(a) gray region: small (M,N) activates a MAC-array subset.
        let r = run_gemm(&spec(), 64, 16384, 64, Dtype::Bf16);
        assert!(r.active_mac_fraction < 0.5, "active {}", r.active_mac_fraction);
        assert_eq!(r.geometry.label(), "64x64");
    }

    #[test]
    fn geometry_adapts_to_aspect_ratio() {
        // Tall-skinny output (large M, small N) should pick a tall geometry.
        let r = run_gemm(&spec(), 16384, 16384, 64, Dtype::Bf16);
        assert!(r.geometry.h > r.geometry.w, "picked {}", r.geometry.label());
        // Wide output picks a wide geometry.
        let r = run_gemm(&spec(), 64, 16384, 16384, Dtype::Bf16);
        assert!(r.geometry.w > r.geometry.h, "picked {}", r.geometry.label());
    }

    #[test]
    fn configurability_beats_fixed_array() {
        // Fig 7(c): for N much smaller than 256 the configurable MME beats
        // a fixed 256x256x2 array.
        let m = 16384;
        let k = 16384;
        for n in [64usize, 128] {
            let conf = run_gemm(&spec(), m, k, n, Dtype::Bf16);
            let fixed = systolic::gemm_cycles(Geometry::new(256, 256, 2), m, k, n);
            let fixed_time = (fixed.cycles / MME_CLOCK_HZ)
                .max(gemm_traffic_bytes(m, k, n, Dtype::Bf16) / (spec().hbm_bandwidth * 0.90));
            assert!(conf.time < fixed_time, "n={n}: conf {} fixed {}", conf.time, fixed_time);
        }
    }

    #[test]
    fn fp32_runs_at_half_rate() {
        let b = run_gemm(&spec(), 4096, 4096, 4096, Dtype::Bf16);
        let f = run_gemm(&spec(), 4096, 4096, 4096, Dtype::Fp32);
        assert!(f.time > 1.8 * b.time, "bf16 {} fp32 {}", b.time, f.time);
    }

    #[test]
    fn flops_and_traffic_helpers() {
        assert_eq!(gemm_flops(2, 3, 4), 48.0);
        let t = gemm_traffic_bytes(100, 100, 100, Dtype::Bf16);
        assert!((t - 3.0 * 10000.0 * 2.0 * 1.05).abs() < 1e-6);
    }
}
