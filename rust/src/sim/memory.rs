//! HBM memory-system model: streaming vs random (gather/scatter) accesses.
//!
//! The paper's Key Takeaway #3 mechanism: Gaudi-2's minimum global-memory
//! access granularity is **256 B**, so gathering a vector smaller than 256 B
//! still moves a full 256 B chunk; A100's sectored L2 fetches **32 B**
//! sectors, wasting almost nothing down to 32 B vectors. On top of chunk
//! waste, every random access pays a per-request overhead (row activation,
//! request-path occupancy), and random streams sustain only a fraction of
//! the pin bandwidth even for large vectors.

use crate::config::{DeviceKind, DeviceSpec};

/// Fraction of peak HBM bandwidth sustainable by a fully random access
/// stream with perfectly-sized requests (calibrated: Gaudi-2 peaks at ~70%
/// in Fig 15, A100 at ~82%).
pub fn random_stream_efficiency(kind: DeviceKind) -> f64 {
    match kind {
        DeviceKind::Gaudi2 => 0.745,
        DeviceKind::A100 => 0.80,
    }
}

/// Bytes actually occupied on the memory path when fetching one vector of
/// `vec_bytes` at a random location: chunk-rounded data + per-request
/// overhead.
pub fn fetched_bytes_per_vector(spec: &DeviceSpec, vec_bytes: f64) -> f64 {
    let chunk = spec.mem_access_granularity;
    let chunks = (vec_bytes / chunk).ceil().max(1.0);
    chunks * chunk + spec.random_access_overhead_bytes
}

/// Result of a gather/scatter microbenchmark run.
#[derive(Debug, Clone, Copy)]
pub struct GatherResult {
    /// Wall time, seconds.
    pub time: f64,
    /// Useful bytes moved / (peak bandwidth × time): the paper's
    /// "memory bandwidth utilization".
    pub utilization: f64,
    /// Useful bytes/sec.
    pub effective_bandwidth: f64,
}

/// Direction of the random-access benchmark.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessDir {
    Gather,
    Scatter,
}

/// Model a gather/scatter of `n_vectors` vectors of `vec_bytes` each from
/// random locations (Fig 9). Writes pay a read-modify-write allocate cost.
pub fn random_access(
    spec: &DeviceSpec,
    dir: AccessDir,
    n_vectors: f64,
    vec_bytes: f64,
) -> GatherResult {
    assert!(n_vectors > 0.0 && vec_bytes > 0.0);
    let useful = n_vectors * vec_bytes;
    let fetched = n_vectors * fetched_bytes_per_vector(spec, vec_bytes);
    let dir_eff = match dir {
        AccessDir::Gather => 1.0,
        AccessDir::Scatter => 0.90, // write-allocate / RMW on partial chunks
    };
    let bw = spec.hbm_bandwidth * random_stream_efficiency(spec.kind) * dir_eff;
    let time = spec.kernel_launch_overhead + fetched / bw;
    GatherResult {
        time,
        utilization: useful / (spec.hbm_bandwidth * time),
        effective_bandwidth: useful / time,
    }
}

/// Streaming (sequential) copy of `bytes`: used by operators that relayout
/// contiguous tensors (e.g. vLLM_base's KV re-gather writes).
pub fn stream_copy_time(spec: &DeviceSpec, bytes: f64) -> f64 {
    // Read + write cross the pins.
    spec.kernel_launch_overhead + 2.0 * bytes / (spec.hbm_bandwidth * spec.stream_efficiency)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::mean;

    fn gaudi() -> DeviceSpec {
        DeviceKind::Gaudi2.spec()
    }
    fn a100() -> DeviceSpec {
        DeviceKind::A100.spec()
    }

    /// Average utilization over a set of vector sizes, large vector count
    /// (launch overhead negligible).
    fn avg_util(spec: &DeviceSpec, sizes: &[f64]) -> f64 {
        mean(
            &sizes
                .iter()
                .map(|&v| random_access(spec, AccessDir::Gather, 4e6, v).utilization)
                .collect::<Vec<_>>(),
        )
    }

    #[test]
    fn fig9_gaudi_large_vectors_64pct() {
        // Paper: Gaudi-2 averages 64% utilization for >=256 B gathers.
        let u = avg_util(&gaudi(), &[256.0, 512.0, 1024.0, 2048.0]);
        assert!((u - 0.64).abs() < 0.05, "gaudi >=256B avg {u}");
    }

    #[test]
    fn fig9_a100_large_vectors_72pct() {
        let u = avg_util(&a100(), &[256.0, 512.0, 1024.0, 2048.0]);
        assert!((u - 0.72).abs() < 0.05, "a100 >=256B avg {u}");
    }

    #[test]
    fn fig9_small_vectors_gap() {
        // Paper: <=128 B gathers: Gaudi 15% vs A100 36% (a 2.4x gap).
        let g = avg_util(&gaudi(), &[16.0, 32.0, 64.0, 128.0]);
        let a = avg_util(&a100(), &[16.0, 32.0, 64.0, 128.0]);
        assert!((g - 0.15).abs() < 0.04, "gaudi small {g}");
        assert!((a - 0.36).abs() < 0.06, "a100 small {a}");
        assert!(a / g > 1.8 && a / g < 3.2, "gap {}", a / g);
    }

    #[test]
    fn granularity_cliff_at_256() {
        // Gaudi's utilization collapses below 256 B, A100 degrades smoothly.
        let g128 = random_access(&gaudi(), AccessDir::Gather, 4e6, 128.0).utilization;
        let g256 = random_access(&gaudi(), AccessDir::Gather, 4e6, 256.0).utilization;
        assert!(g256 / g128 > 1.8, "cliff ratio {}", g256 / g128);
        let a128 = random_access(&a100(), AccessDir::Gather, 4e6, 128.0).utilization;
        let a256 = random_access(&a100(), AccessDir::Gather, 4e6, 256.0).utilization;
        assert!(a256 / a128 < 1.5, "a100 smooth {}", a256 / a128);
    }

    #[test]
    fn scatter_slightly_slower_than_gather() {
        let g = random_access(&gaudi(), AccessDir::Gather, 1e6, 512.0);
        let s = random_access(&gaudi(), AccessDir::Scatter, 1e6, 512.0);
        assert!(s.time > g.time);
        assert!(s.time < 1.3 * g.time);
    }

    #[test]
    fn few_vectors_hit_launch_overhead() {
        let few = random_access(&gaudi(), AccessDir::Gather, 10.0, 256.0);
        let many = random_access(&gaudi(), AccessDir::Gather, 4e6, 256.0);
        assert!(few.utilization < 0.1 * many.utilization);
    }

    #[test]
    fn stream_copy_accounts_read_and_write() {
        let t = stream_copy_time(&gaudi(), 1e9);
        let expected = 5e-6 + 2e9 / (2.45e12 * 0.82);
        assert!((t - expected).abs() / expected < 1e-9);
    }
}
