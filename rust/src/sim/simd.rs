//! A100 SIMD-core (CUDA-core) vector model — the comparison side of the
//! Fig 8(d,e,f) operational-intensity sweeps.
//!
//! A100's 39 TFLOPS BF16 vector peak assumes FMA; ADD/SCALE-style kernels
//! that issue a single non-fused op per element top out at half peak,
//! exactly mirroring the Gaudi TPC behaviour (both saturate at ~50% for
//! ADD/SCALE and ~98-99% for TRIAD in the paper).

use crate::config::DeviceSpec;
use crate::sim::tpc::StreamOp;

/// Chip-wide CUDA-core peak for `op`'s compute instruction.
pub fn chip_peak_flops(spec: &DeviceSpec, op: StreamOp) -> f64 {
    if op.is_mac() {
        spec.vector_tflops
    } else {
        spec.vector_tflops / 2.0
    }
}

/// Roofline throughput at a given operational intensity (FLOP/byte).
pub fn intensity_sweep_throughput(spec: &DeviceSpec, op: StreamOp, intensity: f64) -> f64 {
    let peak = chip_peak_flops(spec, op) * 0.98;
    (intensity * spec.hbm_bandwidth * spec.stream_efficiency).min(peak)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceKind;
    use crate::sim::Dtype;

    fn spec() -> DeviceSpec {
        DeviceKind::A100.spec()
    }

    #[test]
    fn saturation_matches_paper() {
        // Paper: A100 saturates at ~19.4 / 19.4 / 38.2 TFLOPS.
        let s = spec();
        let sat = |op| intensity_sweep_throughput(&s, op, 1e4);
        assert!((sat(StreamOp::Add) / 1e12 - 19.4).abs() < 0.8);
        assert!((sat(StreamOp::Scale) / 1e12 - 19.4).abs() < 0.8);
        assert!((sat(StreamOp::Triad) / 1e12 - 38.2).abs() < 1.0);
    }

    #[test]
    fn gaudi_wins_at_low_intensity_a100_at_high() {
        // Fig 8(d-f): memory-bound region favours Gaudi's 1.2x bandwidth,
        // compute-bound region favours A100's 3.5x vector throughput.
        let a = spec();
        let g = DeviceKind::Gaudi2.spec();
        let low = StreamOp::Add.intensity(Dtype::Bf16);
        let a_low = intensity_sweep_throughput(&a, StreamOp::Add, low);
        let g_low = crate::sim::tpc::intensity_sweep_throughput(&g, StreamOp::Add, low);
        assert!(g_low > a_low, "low intensity: gaudi {g_low} a100 {a_low}");
        let a_hi = intensity_sweep_throughput(&a, StreamOp::Add, 100.0);
        let g_hi = crate::sim::tpc::intensity_sweep_throughput(&g, StreamOp::Add, 100.0);
        assert!(a_hi > 2.0 * g_hi, "high intensity: gaudi {g_hi} a100 {a_hi}");
    }
}
