//! Activity-based power model (paper §3.5 energy-efficiency analysis).
//!
//! `hl-smi` / `nvidia-smi` are replaced by a component model:
//! `P = P_idle + P_matrix·(active fraction)·(toggle rate) + P_vector·util
//!    + P_hbm·(bandwidth util)`.
//!
//! The Gaudi-specific behaviour the paper highlights: for small GEMMs the
//! MME activates only a subset of its MAC array and power-gates the rest
//! (Fig 7(a) gray configs), so despite a 1.5× TDP Gaudi-2 draws comparable
//! power to A100 at small batch sizes (Fig 13 discussion, "more
//! aggressively power-gates its circuitry via DVFS").

use crate::config::{DeviceKind, DeviceSpec};

/// Activity snapshot of a device over an execution phase.
#[derive(Debug, Clone, Copy, Default)]
pub struct Activity {
    /// Matrix-engine throughput utilization (achieved/peak) *within* the
    /// powered-on portion of the array.
    pub matrix_util: f64,
    /// Fraction of the MAC array powered on (1.0 on A100: no reconfigurable
    /// power gating).
    pub matrix_active_fraction: f64,
    /// Vector-engine utilization.
    pub vector_util: f64,
    /// HBM bandwidth utilization.
    pub hbm_util: f64,
    /// Interconnect utilization (SerDes power).
    pub comm_util: f64,
}

impl Activity {
    pub fn clamped(self) -> Activity {
        let c = |x: f64| x.clamp(0.0, 1.0);
        Activity {
            matrix_util: c(self.matrix_util),
            matrix_active_fraction: c(self.matrix_active_fraction),
            vector_util: c(self.vector_util),
            hbm_util: c(self.hbm_util),
            comm_util: c(self.comm_util),
        }
    }
}

/// Power-model coefficients (watts).
#[derive(Debug, Clone, Copy)]
pub struct PowerModel {
    pub idle: f64,
    pub matrix_max: f64,
    pub vector_max: f64,
    pub hbm_max: f64,
    pub comm_max: f64,
    pub tdp: f64,
}

impl PowerModel {
    pub fn for_device(kind: DeviceKind) -> PowerModel {
        match kind {
            // Gaudi-2: 600 W TDP; the big MME array dominates.
            DeviceKind::Gaudi2 => PowerModel {
                idle: 105.0,
                matrix_max: 270.0,
                vector_max: 60.0,
                hbm_max: 130.0,
                comm_max: 25.0,
                tdp: 600.0,
            },
            // A100: 400 W TDP (sum of components exceeds TDP; the cap
            // models power steering, matching ~400 W under full load).
            DeviceKind::A100 => PowerModel {
                idle: 90.0,
                matrix_max: 200.0,
                vector_max: 48.0,
                hbm_max: 120.0,
                comm_max: 15.0,
                tdp: 400.0,
            },
        }
    }

    /// Instantaneous power draw for an activity snapshot.
    pub fn power(&self, a: Activity) -> f64 {
        let a = a.clamped();
        // The matrix engine burns leakage+clock power over its *active*
        // fraction even when stalled, plus dynamic power when toggling.
        let matrix = self.matrix_max * a.matrix_active_fraction * (0.35 + 0.65 * a.matrix_util);
        let p = self.idle
            + matrix
            + self.vector_max * a.vector_util
            + self.hbm_max * a.hbm_util
            + self.comm_max * a.comm_util;
        p.min(self.tdp)
    }

    /// Energy (joules) over a phase of `seconds` at activity `a`.
    pub fn energy(&self, a: Activity, seconds: f64) -> f64 {
        self.power(a) * seconds
    }
}

/// Convenience: power for a device kind.
pub fn power(kind: DeviceKind, a: Activity) -> f64 {
    PowerModel::for_device(kind).power(a)
}

/// Full-device spec accessor used by callers that track energy.
pub fn tdp(spec: &DeviceSpec) -> f64 {
    spec.tdp_watts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_and_tdp_bounds() {
        for kind in [DeviceKind::Gaudi2, DeviceKind::A100] {
            let m = PowerModel::for_device(kind);
            assert_eq!(m.power(Activity::default()), m.idle);
            let max = m.power(Activity {
                matrix_util: 1.0,
                matrix_active_fraction: 1.0,
                vector_util: 1.0,
                hbm_util: 1.0,
                comm_util: 1.0,
            });
            assert!(max <= m.tdp);
            assert!(max > 0.85 * m.tdp, "{kind:?} max {max}");
        }
    }

    #[test]
    fn power_gating_saves_energy_on_small_gemms() {
        // Same utilization but only 1/8 of the MME powered on.
        let m = PowerModel::for_device(DeviceKind::Gaudi2);
        let full = m.power(Activity {
            matrix_util: 0.5,
            matrix_active_fraction: 1.0,
            hbm_util: 0.5,
            ..Default::default()
        });
        let gated = m.power(Activity {
            matrix_util: 0.5,
            matrix_active_fraction: 0.125,
            hbm_util: 0.5,
            ..Default::default()
        });
        assert!(gated < full - 100.0, "full {full} gated {gated}");
    }

    #[test]
    fn gaudi_small_batch_power_below_a100_large_tdp_gap() {
        // Fig 13 narrative: at small batches (low matrix activity, gated
        // array) Gaudi draws comparable or lower power than A100 despite
        // the 1.5x TDP.
        let g = power(
            DeviceKind::Gaudi2,
            Activity {
                matrix_util: 0.3,
                matrix_active_fraction: 0.25,
                hbm_util: 0.7,
                vector_util: 0.2,
                ..Default::default()
            },
        );
        let a = power(
            DeviceKind::A100,
            Activity {
                matrix_util: 0.3,
                matrix_active_fraction: 1.0,
                hbm_util: 0.7,
                vector_util: 0.2,
                ..Default::default()
            },
        );
        assert!(g < 1.15 * a, "gaudi {g} a100 {a}");
    }

    #[test]
    fn activity_clamping() {
        let a = Activity { matrix_util: 7.0, hbm_util: -1.0, ..Default::default() }.clamped();
        assert_eq!(a.matrix_util, 1.0);
        assert_eq!(a.hbm_util, 0.0);
    }

    #[test]
    fn energy_scales_with_time() {
        let m = PowerModel::for_device(DeviceKind::A100);
        let a = Activity { hbm_util: 0.5, ..Default::default() };
        assert!((m.energy(a, 2.0) - 2.0 * m.power(a)).abs() < 1e-9);
    }
}
