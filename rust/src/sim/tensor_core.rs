//! NVIDIA A100 Tensor Core GEMM model (cuBLAS-like).
//!
//! Unlike the Gaudi MME, A100 GEMMs execute as fixed-shape CTA tiles
//! scheduled across 108 SMs. The dominant utilization effects are
//! (1) *wave quantization* — `ceil(ctas/108)` waves, the last one partially
//! filled; (2) *tile-edge waste* when M,N are not multiples of the tile; and
//! (3) a per-tile mainloop efficiency that shrinks with smaller tiles
//! (less latency hiding per CTA). cuBLAS heuristics pick the best tile from
//! a menu, which we reproduce with an argmin over the same roofline used by
//! the MME model.

use crate::config::DeviceSpec;
use crate::sim::mme::{gemm_flops, gemm_traffic_bytes};
use crate::sim::Dtype;
use crate::util::ceil_div;

/// Number of streaming multiprocessors on A100.
pub const NUM_SMS: usize = 108;

/// Fraction of peak HBM bandwidth a blocked GEMM stream sustains.
const GEMM_HBM_EFFICIENCY: f64 = 0.88;

/// CTA tile menu: (tile_m, tile_n, mainloop efficiency).
///
/// Efficiencies are calibrated against public cuBLAS BF16 measurements:
/// large tiles reach ~93% of Tensor-Core peak in their mainloop, small
/// tiles pay relatively more prologue/epilogue and smem-latency cost.
pub const TILE_MENU: &[(usize, usize, f64)] = &[
    (256, 128, 0.93),
    (128, 256, 0.93),
    (128, 128, 0.91),
    (256, 64, 0.88),
    (64, 256, 0.88),
    (128, 64, 0.84),
    (64, 128, 0.84),
    (64, 64, 0.76),
];

/// Outcome of a Tensor-Core GEMM.
#[derive(Debug, Clone)]
pub struct TcGemm {
    pub tile: (usize, usize),
    pub time: f64,
    pub achieved_flops: f64,
    /// Achieved / 312 TFLOPS peak.
    pub utilization: f64,
    pub memory_bound: bool,
    /// Fraction of SMs busy in the last wave (diagnostic).
    pub wave_efficiency: f64,
}

/// Execute GEMM (m,k,n) with cuBLAS-style tile selection.
pub fn run_gemm(spec: &DeviceSpec, m: usize, k: usize, n: usize, dtype: Dtype) -> TcGemm {
    assert!(m > 0 && k > 0 && n > 0);
    let flops = gemm_flops(m, k, n);
    let mem_time = gemm_traffic_bytes(m, k, n, dtype) / (spec.hbm_bandwidth * GEMM_HBM_EFFICIENCY);
    let peak = spec.matrix_tflops * dtype.matrix_peak_factor();
    let per_sm_peak = peak / NUM_SMS as f64;
    // Fixed per-CTA prologue/epilogue cost (smem staging, writeback).
    let cta_overhead_s = 1.3e-6;

    let mut best: Option<TcGemm> = None;
    for &(th, tw, eff) in TILE_MENU {
        let ctas = ceil_div(m, th) * ceil_div(n, tw);
        let waves = ceil_div(ctas, NUM_SMS);
        // A CTA computes th*tw*K MACs; its mainloop runs at eff * per-SM peak.
        let cta_time = (2.0 * (th * tw) as f64 * k as f64) / (per_sm_peak * eff) + cta_overhead_s;
        let compute_time = waves as f64 * cta_time;
        let time = compute_time.max(mem_time);
        let wave_eff = ctas as f64 / (waves * NUM_SMS) as f64;
        let cand = TcGemm {
            tile: (th, tw),
            time,
            achieved_flops: flops / time,
            utilization: flops / time / spec.matrix_tflops,
            memory_bound: mem_time > compute_time,
            wave_efficiency: wave_eff,
        };
        if best.as_ref().is_none_or(|b| cand.time < b.time) {
            best = Some(cand);
        }
    }
    best.expect("non-empty tile menu")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceKind;

    fn spec() -> DeviceSpec {
        DeviceKind::A100.spec()
    }

    #[test]
    fn big_square_gemm_near_peak() {
        // cuBLAS BF16 at 8192^3 reaches ~90% of TC peak on A100.
        let r = run_gemm(&spec(), 8192, 8192, 8192, Dtype::Bf16);
        assert!(r.utilization > 0.85 && r.utilization < 0.97, "util {}", r.utilization);
    }

    #[test]
    fn wave_quantization_hurts_midsize() {
        // 2048^3: CTA count sits just above a wave boundary for the large
        // tiles, so utilization dips well below the 8192^3 point (this is
        // the paper's max-gap point vs Gaudi in Fig 5).
        let big = run_gemm(&spec(), 8192, 8192, 8192, Dtype::Bf16);
        let mid = run_gemm(&spec(), 2048, 2048, 2048, Dtype::Bf16);
        assert!(mid.utilization < big.utilization - 0.10, "mid {}", mid.utilization);
    }

    #[test]
    fn skinny_gemm_memory_bound() {
        let r = run_gemm(&spec(), 8192, 8192, 16, Dtype::Bf16);
        assert!(r.memory_bound);
        assert!(r.utilization < 0.12);
    }

    #[test]
    fn picks_reasonable_tile_for_small_gemm() {
        let r = run_gemm(&spec(), 128, 1024, 128, Dtype::Bf16);
        assert!(r.tile.0 <= 128 && r.tile.1 <= 128, "tile {:?}", r.tile);
    }

    #[test]
    fn utilization_bounded_everywhere() {
        for &m in &[64usize, 256, 1024, 4096, 8192] {
            for &n in &[16usize, 64, 1024, 8192] {
                let r = run_gemm(&spec(), m, 2048, n, Dtype::Bf16);
                assert!(r.utilization > 0.0 && r.utilization <= 1.0);
                assert!(r.wave_efficiency > 0.0 && r.wave_efficiency <= 1.0);
            }
        }
    }
}
