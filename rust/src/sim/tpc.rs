//! Gaudi-2 Tensor Processing Core (TPC) model — the programmable VLIW SIMD
//! engine, exercised by the paper's STREAM-derived microbenchmarks (Fig 8).
//!
//! Modeled mechanisms (paper §2.2 and §3.2):
//! * 2048-bit SIMD datapath → 128 BF16 lanes per vector instruction;
//! * 4-cycle architectural latency: a result is visible 4 cycles after
//!   issue, so an un-unrolled Load→Compute→Store loop stalls twice per
//!   iteration; unrolling by U amortizes the stall to `2·LAT/U`;
//! * VLIW slot structure: the load/store units and the vector ALU issue in
//!   parallel, so issue cost per iteration is bounded by the busiest unit
//!   (2 cycles for the two loads of ADD/TRIAD, 1 for SCALE) — this is why
//!   SCALE "benefits remarkably" from unrolling while ADD/TRIAD saturate
//!   their per-TPC memory path first;
//! * 256 B minimum global access granularity: narrower accesses waste the
//!   remainder of the 256 B chunk (Fig 8(a) cliff);
//! * per-TPC sustainable HBM bandwidth (~170 GB/s) and chip-level STREAM
//!   efficiency (~82% of 2.45 TB/s), which cap single-core and weak-scaled
//!   throughput respectively (Fig 8(c)).

use crate::config::DeviceSpec;
use crate::sim::Dtype;

/// Number of TPCs on Gaudi-2.
pub const NUM_TPCS: usize = 24;

/// SIMD width in bytes (2048-bit vector datapath).
pub const VECTOR_BYTES: f64 = 256.0;

/// Architectural instruction latency in cycles.
pub const ARCH_LATENCY: f64 = 4.0;

/// TPC clock: 11 TFLOPS BF16 = 24 TPCs × 128 lanes × 2 FLOP (MAC) × f.
pub const TPC_CLOCK_HZ: f64 = 11e12 / (NUM_TPCS as f64 * 128.0 * 2.0);

/// Sustainable HBM bandwidth from a single TPC's load/store path, bytes/s.
pub const PER_TPC_HBM_BW: f64 = 170e9;

/// The three STREAM kernels of Algorithm 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StreamOp {
    /// c[i] = a[i] + b[i]
    Add,
    /// b[i] = s * a[i]
    Scale,
    /// c[i] = s * a[i] + b[i]
    Triad,
}

impl StreamOp {
    pub fn name(&self) -> &'static str {
        match self {
            StreamOp::Add => "ADD",
            StreamOp::Scale => "SCALE",
            StreamOp::Triad => "TRIAD",
        }
    }

    /// Loads per element.
    pub fn loads(&self) -> f64 {
        match self {
            StreamOp::Add | StreamOp::Triad => 2.0,
            StreamOp::Scale => 1.0,
        }
    }

    /// FLOPs per element (TRIAD is a fused multiply-add).
    pub fn flops_per_elem(&self) -> f64 {
        match self {
            StreamOp::Add | StreamOp::Scale => 1.0,
            StreamOp::Triad => 2.0,
        }
    }

    /// Memory traffic per element in units of element-size (loads + 1 store).
    pub fn elem_accesses(&self) -> f64 {
        self.loads() + 1.0
    }

    /// True if the compute instruction is a MAC (2 FLOP/lane/cycle);
    /// plain add/mul issue 1 FLOP/lane/cycle.
    pub fn is_mac(&self) -> bool {
        matches!(self, StreamOp::Triad)
    }

    /// Bytes moved per FLOP for a given dtype.
    pub fn bytes_per_flop(&self, dtype: Dtype) -> f64 {
        self.elem_accesses() * dtype.bytes() / self.flops_per_elem()
    }

    /// STREAM operational intensity (FLOP/byte) for a given dtype.
    pub fn intensity(&self, dtype: Dtype) -> f64 {
        1.0 / self.bytes_per_flop(dtype)
    }
}

/// Effective fraction of each 256 B memory chunk that carries useful data
/// when the program accesses `granularity` bytes at a time (Fig 8(a)).
pub fn granularity_factor(granularity_bytes: f64) -> f64 {
    (granularity_bytes / VECTOR_BYTES).min(1.0)
}

/// Throughput (FLOP/s) of a *single* TPC running `op` with loop-unroll
/// factor `unroll` and data-access granularity `granularity_bytes`.
pub fn single_tpc_throughput(
    op: StreamOp,
    unroll: usize,
    granularity_bytes: f64,
    dtype: Dtype,
) -> f64 {
    assert!(unroll >= 1);
    let lanes = VECTOR_BYTES / dtype.bytes();
    let g = granularity_factor(granularity_bytes);

    // Issue cost per iteration: load unit is the busiest slot for 2-load
    // kernels; the ALU and store unit overlap underneath it.
    let issue_cycles = op.loads().max(1.0);
    // Two dependency edges (load→compute, compute→store) stall the pipeline
    // unless unrolling provides independent work to fill the bubbles.
    let stall_cycles = 2.0 * ARCH_LATENCY / unroll as f64;
    let cycles_per_iter = issue_cycles + stall_cycles;
    let compute_flops = lanes * op.flops_per_elem() / cycles_per_iter * TPC_CLOCK_HZ;

    // Per-TPC memory path cap; narrow accesses waste chunk bandwidth.
    let mem_flops = PER_TPC_HBM_BW * g / op.bytes_per_flop(dtype);

    // Narrow accesses also shrink the useful work per vector instruction.
    (compute_flops * g).min(mem_flops)
}

/// Throughput (FLOP/s) of `n_tpcs` TPCs weak-scaling `op` (Fig 8(c)).
/// Each TPC runs the optimized kernel (unroll 4, 256 B granularity).
pub fn weak_scaled_throughput(spec: &DeviceSpec, op: StreamOp, n_tpcs: usize, dtype: Dtype) -> f64 {
    assert!(n_tpcs >= 1 && n_tpcs <= NUM_TPCS);
    let single = single_tpc_throughput(op, 4, VECTOR_BYTES, dtype);
    let chip_mem_flops =
        spec.hbm_bandwidth * spec.stream_efficiency / op.bytes_per_flop(dtype);
    (single * n_tpcs as f64).min(chip_mem_flops)
}

/// Chip-wide vector-engine peak for `op`'s compute instruction:
/// MAC-capable kernels reach the full 11 TFLOPS, single-op kernels half.
pub fn chip_peak_flops(spec: &DeviceSpec, op: StreamOp) -> f64 {
    if op.is_mac() {
        spec.vector_tflops
    } else {
        spec.vector_tflops / 2.0
    }
}

/// Throughput at an *artificially increased* operational intensity
/// (Fig 8(d,e,f)): roofline between the op-specific vector peak and the
/// streaming memory bound.
pub fn intensity_sweep_throughput(spec: &DeviceSpec, op: StreamOp, intensity: f64) -> f64 {
    // Saturating-compute efficiency: TRIAD's MAC pipeline reaches ~99% of
    // peak, matching the paper's measured saturation.
    let peak = chip_peak_flops(spec, op) * 0.99;
    (intensity * spec.hbm_bandwidth * spec.stream_efficiency).min(peak)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceKind;
    use crate::util::units::GFLOPS;

    fn spec() -> DeviceSpec {
        DeviceKind::Gaudi2.spec()
    }

    #[test]
    fn clock_sanity() {
        assert!((TPC_CLOCK_HZ - 1.79e9).abs() < 2e7, "{TPC_CLOCK_HZ}");
    }

    #[test]
    fn fig8a_granularity_cliff() {
        // Below 256 B the throughput drops proportionally.
        let full = single_tpc_throughput(StreamOp::Triad, 1, 256.0, Dtype::Bf16);
        let half = single_tpc_throughput(StreamOp::Triad, 1, 128.0, Dtype::Bf16);
        let tiny = single_tpc_throughput(StreamOp::Triad, 1, 2.0, Dtype::Bf16);
        assert!((half / full - 0.5).abs() < 0.05, "half/full {}", half / full);
        assert!(tiny / full < 0.02);
        // Above 256 B it saturates.
        let big = single_tpc_throughput(StreamOp::Triad, 1, 2048.0, Dtype::Bf16);
        assert!((big - full).abs() / full < 1e-9);
    }

    #[test]
    fn fig8a_saturation_levels() {
        // Paper: ~55 GFLOPS TRIAD, ~30 GFLOPS ADD/SCALE for a single TPC.
        let triad = single_tpc_throughput(StreamOp::Triad, 1, 256.0, Dtype::Bf16);
        let add = single_tpc_throughput(StreamOp::Add, 1, 256.0, Dtype::Bf16);
        let scale = single_tpc_throughput(StreamOp::Scale, 1, 256.0, Dtype::Bf16);
        assert!(triad > 35.0 * GFLOPS && triad < 60.0 * GFLOPS, "triad {}", triad / GFLOPS);
        assert!(add > 18.0 * GFLOPS && add < 35.0 * GFLOPS, "add {}", add / GFLOPS);
        assert!(scale > 18.0 * GFLOPS && scale < 35.0 * GFLOPS, "scale {}", scale / GFLOPS);
    }

    #[test]
    fn fig8b_scale_benefits_most_from_unrolling() {
        let gain = |op| {
            single_tpc_throughput(op, 8, 256.0, Dtype::Bf16)
                / single_tpc_throughput(op, 1, 256.0, Dtype::Bf16)
        };
        let g_scale = gain(StreamOp::Scale);
        let g_add = gain(StreamOp::Add);
        let g_triad = gain(StreamOp::Triad);
        assert!(g_scale > 1.5, "scale gain {g_scale}");
        assert!(g_scale > g_add && g_scale > g_triad, "{g_scale} {g_add} {g_triad}");
        assert!(g_add < 1.6 && g_triad < 1.6, "add {g_add} triad {g_triad}");
    }

    #[test]
    fn fig8c_weak_scaling_saturates_at_11_to_15_tpcs() {
        for op in [StreamOp::Add, StreamOp::Scale, StreamOp::Triad] {
            let full = weak_scaled_throughput(&spec(), op, NUM_TPCS, Dtype::Bf16);
            // Find saturation point: first n achieving >99% of full.
            let sat = (1..=NUM_TPCS)
                .find(|&n| weak_scaled_throughput(&spec(), op, n, Dtype::Bf16) > 0.99 * full)
                .unwrap();
            assert!((11..=15).contains(&sat), "{} saturates at {sat}", op.name());
        }
    }

    #[test]
    fn fig8c_chip_saturation_levels() {
        // Paper: ~330 / ~530 / ~670 GFLOPS for ADD / SCALE / TRIAD.
        let add = weak_scaled_throughput(&spec(), StreamOp::Add, NUM_TPCS, Dtype::Bf16);
        let scale = weak_scaled_throughput(&spec(), StreamOp::Scale, NUM_TPCS, Dtype::Bf16);
        let triad = weak_scaled_throughput(&spec(), StreamOp::Triad, NUM_TPCS, Dtype::Bf16);
        assert!((add / GFLOPS - 330.0).abs() < 40.0, "add {}", add / GFLOPS);
        assert!((scale / GFLOPS - 530.0).abs() < 50.0, "scale {}", scale / GFLOPS);
        assert!((triad / GFLOPS - 670.0).abs() < 50.0, "triad {}", triad / GFLOPS);
    }

    #[test]
    fn fig8def_intensity_saturation() {
        // Gaudi saturates at ~5.5 / 5.5 / 10.9 TFLOPS (50% / 50% / 99%).
        let s = spec();
        let sat = |op| intensity_sweep_throughput(&s, op, 1e4);
        assert!((sat(StreamOp::Add) / 1e12 - 5.45).abs() < 0.2);
        assert!((sat(StreamOp::Scale) / 1e12 - 5.45).abs() < 0.2);
        assert!((sat(StreamOp::Triad) / 1e12 - 10.9).abs() < 0.3);
        // At low intensity it is memory bound and scales linearly.
        let lo = intensity_sweep_throughput(&s, StreamOp::Add, StreamOp::Add.intensity(Dtype::Bf16));
        assert!(lo < 0.5e12, "{lo}");
    }

    #[test]
    fn stream_op_accounting() {
        assert_eq!(StreamOp::Add.intensity(Dtype::Bf16), 1.0 / 6.0);
        assert_eq!(StreamOp::Scale.intensity(Dtype::Bf16), 1.0 / 4.0);
        assert_eq!(StreamOp::Triad.intensity(Dtype::Bf16), 2.0 / 6.0);
        assert!(StreamOp::Triad.is_mac() && !StreamOp::Add.is_mac());
    }
}
