//! Gaudi graph-compiler pipelining model (paper §2.2 "Graph compiler").
//!
//! When an MME operation feeds a TPC operation (or vice versa), the graph
//! compiler breaks both into independent sub-operation slices and overlaps
//! them through on-chip shared SRAM, hiding the shorter stage under the
//! longer one. Whether slicing is *possible* depends on the program
//! structure the user wrote at the PyTorch level — the core finding of the
//! vLLM case study (§4.2): vLLM_base's contiguous re-gather creates a full
//! barrier (no slicing), while vLLM_opt's BlockList form exposes
//! independent per-block slices.

use crate::config::DeviceSpec;

/// Per-slice scheduling overhead (synchronization + descriptor setup).
pub const SLICE_OVERHEAD_S: f64 = 2.0e-6;

/// Maximum slice count the compiler will generate.
pub const MAX_SLICES: usize = 64;

/// Result of scheduling a producer→consumer pair.
#[derive(Debug, Clone, Copy)]
pub struct PipelineResult {
    pub time: f64,
    pub n_slices: usize,
    /// time saved vs serial execution, as a fraction of serial time.
    pub overlap_gain: f64,
}

/// Pipeline two dependent stages of durations `a` then `b` (seconds),
/// streaming `working_set_bytes` between them through shared SRAM.
///
/// With `n` slices the schedule costs `(a+b)/n` to fill/drain plus
/// `max(a,b)·(n-1)/n` of steady state, plus per-slice overhead. The
/// compiler picks the best `n` subject to each slice's working set fitting
/// in SRAM — callers pass `sliceable = false` when the program structure
/// (e.g. a contiguous re-gather) forbids slicing.
pub fn pipeline2(
    spec: &DeviceSpec,
    a: f64,
    b: f64,
    working_set_bytes: f64,
    sliceable: bool,
) -> PipelineResult {
    assert!(a >= 0.0 && b >= 0.0);
    let serial = a + b;
    if !sliceable || serial == 0.0 {
        return PipelineResult { time: serial, n_slices: 1, overlap_gain: 0.0 };
    }
    // Minimum slices so one slice's inter-stage buffer fits in (half of)
    // shared SRAM (double buffering).
    let min_slices = ((working_set_bytes / (spec.sram_bytes / 2.0)).ceil() as usize).max(1);
    let mut best = PipelineResult { time: serial, n_slices: 1, overlap_gain: 0.0 };
    for n in slice_candidates(min_slices) {
        let nf = n as f64;
        let t = serial / nf + a.max(b) * (nf - 1.0) / nf + nf as f64 * SLICE_OVERHEAD_S;
        if t < best.time {
            best = PipelineResult { time: t, n_slices: n, overlap_gain: (serial - t) / serial };
        }
    }
    best
}

/// Slice counts to evaluate: the dense range up to `MAX_SLICES` when the
/// SRAM constraint allows it, otherwise a small geometric ladder above the
/// forced minimum (very large working sets, e.g. gradient buckets).
fn slice_candidates(min_slices: usize) -> Vec<usize> {
    if min_slices <= MAX_SLICES {
        (min_slices..=MAX_SLICES).collect()
    } else {
        vec![min_slices, min_slices * 2, min_slices * 4]
    }
}

/// Pipeline a chain of dependent stages (e.g. TPC gather → MME bgemm →
/// TPC softmax). Adjacent pairs overlap; the chain time approaches
/// `max(stages) + sum(others)/n`.
pub fn pipeline_chain(
    spec: &DeviceSpec,
    stages: &[f64],
    working_set_bytes: f64,
    sliceable: bool,
) -> PipelineResult {
    let serial: f64 = stages.iter().sum();
    if !sliceable || stages.len() <= 1 || serial == 0.0 {
        return PipelineResult { time: serial, n_slices: 1, overlap_gain: 0.0 };
    }
    let min_slices = ((working_set_bytes / (spec.sram_bytes / 2.0)).ceil() as usize).max(1);
    let bottleneck = stages.iter().cloned().fold(0.0_f64, f64::max);
    let mut best = PipelineResult { time: serial, n_slices: 1, overlap_gain: 0.0 };
    for n in slice_candidates(min_slices) {
        let nf = n as f64;
        // Fill/drain of the non-bottleneck stages + steady state on the
        // bottleneck + scheduling overhead per slice per stage boundary.
        let t = bottleneck * (nf - 1.0) / nf
            + serial / nf
            + nf * (stages.len() - 1) as f64 * SLICE_OVERHEAD_S;
        if t < best.time {
            best = PipelineResult { time: t, n_slices: n, overlap_gain: (serial - t) / serial };
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceKind;

    fn spec() -> DeviceSpec {
        DeviceKind::Gaudi2.spec()
    }

    #[test]
    fn balanced_stages_approach_half_serial() {
        let r = pipeline2(&spec(), 1e-3, 1e-3, 1e6, true);
        assert!(r.time < 1.15e-3, "time {}", r.time);
        assert!(r.overlap_gain > 0.40);
        assert!(r.n_slices > 4);
    }

    #[test]
    fn unsliceable_is_serial() {
        let r = pipeline2(&spec(), 1e-3, 1e-3, 1e6, false);
        assert_eq!(r.time, 2e-3);
        assert_eq!(r.n_slices, 1);
        assert_eq!(r.overlap_gain, 0.0);
    }

    #[test]
    fn imbalanced_stages_bounded_by_bottleneck() {
        let r = pipeline2(&spec(), 10e-3, 1e-3, 1e6, true);
        assert!(r.time >= 10e-3);
        assert!(r.time < 10.4e-3, "time {}", r.time);
    }

    #[test]
    fn tiny_stages_do_not_oversplit() {
        // Slice overhead must keep the compiler from slicing microscopic ops.
        let r = pipeline2(&spec(), 3e-6, 3e-6, 1e3, true);
        assert!(r.n_slices <= 2, "slices {}", r.n_slices);
    }

    #[test]
    fn chain_bounded_by_bottleneck() {
        let r = pipeline_chain(&spec(), &[2e-3, 5e-3, 1e-3], 4e6, true);
        assert!(r.time >= 5e-3 && r.time < 6.2e-3, "time {}", r.time);
        let serial = pipeline_chain(&spec(), &[2e-3, 5e-3, 1e-3], 4e6, false);
        assert_eq!(serial.time, 8e-3);
    }

    #[test]
    fn sram_limits_minimum_slices() {
        // Working set 10x SRAM forces at least ~20 slices w/ double buffering.
        let r = pipeline2(&spec(), 1e-3, 1e-3, 10.0 * spec().sram_bytes, true);
        assert!(r.n_slices >= 20, "slices {}", r.n_slices);
    }
}
