//! PJRT runtime: loads AOT-compiled HLO text artifacts (produced by
//! `python/compile/aot.py` from JAX/Pallas) and executes them on the PJRT
//! CPU client via the `xla` crate. This is the only place the Rust side
//! touches XLA; everything above works with plain `Vec<f32>`/`Vec<i32>`.
//!
//! Interchange is HLO *text*, not serialized protos: jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod artifact;

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

use artifact::{ArtifactDtype, ArtifactEntry, Manifest, TensorSpec};

/// A host-side tensor crossing the runtime boundary.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(v) => v.len(),
            HostTensor::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(v) => Ok(v),
            _ => anyhow::bail!("expected f32 tensor"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32(v) => Ok(v),
            _ => anyhow::bail!("expected i32 tensor"),
        }
    }

    fn matches(&self, spec: &TensorSpec) -> bool {
        let dtype_ok = matches!(
            (self, spec.dtype),
            (HostTensor::F32(_), ArtifactDtype::F32) | (HostTensor::I32(_), ArtifactDtype::I32)
        );
        dtype_ok && self.len() == spec.num_elements()
    }

    fn to_literal(&self, spec: &TensorSpec) -> Result<xla::Literal> {
        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32(v) => xla::Literal::vec1(v),
            HostTensor::I32(v) => xla::Literal::vec1(v),
        };
        Ok(lit.reshape(&dims)?)
    }

    fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<HostTensor> {
        Ok(match spec.dtype {
            ArtifactDtype::F32 => HostTensor::F32(lit.to_vec::<f32>()?),
            ArtifactDtype::I32 => HostTensor::I32(lit.to_vec::<i32>()?),
        })
    }
}

/// A compiled entry point ready to execute.
pub struct LoadedArtifact {
    pub entry: ArtifactEntry,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedArtifact {
    /// Execute with host tensors; validates shapes/dtypes against the
    /// manifest and unwraps the (tupled) outputs.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        anyhow::ensure!(
            inputs.len() == self.entry.inputs.len(),
            "{}: expected {} inputs, got {}",
            self.entry.name,
            self.entry.inputs.len(),
            inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (t, spec)) in inputs.iter().zip(&self.entry.inputs).enumerate() {
            anyhow::ensure!(
                t.matches(spec),
                "{}: input {i} mismatch (len {} vs spec {:?})",
                self.entry.name,
                t.len(),
                spec
            );
            literals.push(t.to_literal(spec)?);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True.
        let parts = result.to_tuple()?;
        anyhow::ensure!(
            parts.len() == self.entry.outputs.len(),
            "{}: expected {} outputs, got {}",
            self.entry.name,
            self.entry.outputs.len(),
            parts.len()
        );
        parts
            .iter()
            .zip(&self.entry.outputs)
            .map(|(l, spec)| HostTensor::from_literal(l, spec))
            .collect()
    }
}

/// The PJRT runtime: client + compiled-artifact cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, LoadedArtifact>,
}

impl Runtime {
    /// Create a CPU PJRT client and load the manifest from `artifacts_dir`.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let manifest = Manifest::load(artifacts_dir)?;
        Ok(Runtime { client, manifest, cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) an entry point.
    pub fn load(&mut self, name: &str) -> Result<&LoadedArtifact> {
        if !self.cache.contains_key(name) {
            let entry = self.manifest.entry(name)?.clone();
            let path = self.manifest.hlo_path(&entry);
            let proto =
                xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
                    .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact '{name}'"))?;
            self.cache.insert(name.to_string(), LoadedArtifact { entry, exe });
        }
        Ok(&self.cache[name])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_spec_matching() {
        let spec = TensorSpec { shape: vec![2, 3], dtype: ArtifactDtype::F32 };
        assert!(HostTensor::F32(vec![0.0; 6]).matches(&spec));
        assert!(!HostTensor::F32(vec![0.0; 5]).matches(&spec));
        assert!(!HostTensor::I32(vec![0; 6]).matches(&spec));
    }

    #[test]
    fn accessors() {
        let t = HostTensor::I32(vec![1, 2, 3]);
        assert_eq!(t.len(), 3);
        assert!(t.as_i32().is_ok());
        assert!(t.as_f32().is_err());
        assert!(!t.is_empty());
    }

    // Full load/execute round-trips live in rust/tests/integration_runtime.rs
    // (they need `make artifacts` to have run).
}
