//! Artifact manifest: `python/compile/aot.py` lowers the L2 JAX programs
//! (which call the L1 Pallas kernels) to HLO **text** files under
//! `artifacts/` and writes `manifest.json` describing each entry point's
//! name, file and I/O shapes. The Rust side loads the manifest, compiles
//! the HLO on the PJRT CPU client, and serves from the compiled
//! executables — Python never runs on the request path.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Dtype of a tensor crossing the artifact boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactDtype {
    F32,
    I32,
}

impl ArtifactDtype {
    fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "float32" | "f32" => Ok(ArtifactDtype::F32),
            "int32" | "i32" => Ok(ArtifactDtype::I32),
            other => anyhow::bail!("unsupported artifact dtype '{other}'"),
        }
    }
}

/// Shape + dtype of one input/output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: ArtifactDtype,
}

impl TensorSpec {
    pub fn num_elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(j: &Json) -> anyhow::Result<TensorSpec> {
        let shape = j
            .req("shape")
            .map_err(|e| anyhow::anyhow!("{e}"))?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("shape must be an array"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow::anyhow!("bad dim")))
            .collect::<Result<Vec<_>, _>>()?;
        let dtype = ArtifactDtype::parse(
            j.req("dtype")
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("dtype must be a string"))?,
        )?;
        Ok(TensorSpec { shape, dtype })
    }
}

/// One compiled entry point.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    /// HLO text file, relative to the manifest directory.
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Free-form metadata (model dims etc.).
    pub meta: BTreeMap<String, f64>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: BTreeMap<String, ArtifactEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e} (run `make artifacts`)", path.display()))?;
        Manifest::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> anyhow::Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let list = j
            .req("entries")
            .map_err(|e| anyhow::anyhow!("{e}"))?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("entries must be an array"))?;
        let mut entries = BTreeMap::new();
        for e in list {
            let name = e
                .req("name")
                .map_err(|er| anyhow::anyhow!("{er}"))?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("name must be a string"))?
                .to_string();
            let file = PathBuf::from(
                e.req("file")
                    .map_err(|er| anyhow::anyhow!("{er}"))?
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("file must be a string"))?,
            );
            let parse_specs = |key: &str| -> anyhow::Result<Vec<TensorSpec>> {
                e.req(key)
                    .map_err(|er| anyhow::anyhow!("{er}"))?
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("{key} must be an array"))?
                    .iter()
                    .map(TensorSpec::parse)
                    .collect()
            };
            let mut meta = BTreeMap::new();
            if let Some(Json::Obj(m)) = e.get("meta") {
                for (k, v) in m {
                    if let Some(x) = v.as_f64() {
                        meta.insert(k.clone(), x);
                    }
                }
            }
            entries.insert(
                name.clone(),
                ArtifactEntry {
                    name,
                    file,
                    inputs: parse_specs("inputs")?,
                    outputs: parse_specs("outputs")?,
                    meta,
                },
            );
        }
        Ok(Manifest { dir, entries })
    }

    pub fn entry(&self, name: &str) -> anyhow::Result<&ArtifactEntry> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact '{name}' not in manifest"))
    }

    /// Absolute path of an entry's HLO file.
    pub fn hlo_path(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "entries": [
        {
          "name": "decode_step",
          "file": "decode_step.hlo.txt",
          "inputs": [
            {"shape": [4], "dtype": "int32"},
            {"shape": [2, 2, 4, 2, 128, 16], "dtype": "float32"},
            {"shape": [], "dtype": "int32"}
          ],
          "outputs": [
            {"shape": [4, 256], "dtype": "float32"},
            {"shape": [2, 2, 4, 2, 128, 16], "dtype": "float32"}
          ],
          "meta": {"vocab": 256, "layers": 2}
        }
      ]
    }"#;

    #[test]
    fn parses_sample_manifest() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        let e = m.entry("decode_step").unwrap();
        assert_eq!(e.inputs.len(), 3);
        assert_eq!(e.inputs[0].dtype, ArtifactDtype::I32);
        assert_eq!(e.outputs[0].shape, vec![4, 256]);
        assert_eq!(e.outputs[0].num_elements(), 1024);
        assert_eq!(e.meta["vocab"], 256.0);
        assert_eq!(m.hlo_path(e), PathBuf::from("/tmp/a/decode_step.hlo.txt"));
    }

    #[test]
    fn missing_entry_is_error() {
        let m = Manifest::parse(SAMPLE, PathBuf::from(".")).unwrap();
        assert!(m.entry("nope").is_err());
    }

    #[test]
    fn bad_manifest_rejected() {
        assert!(Manifest::parse("{}", PathBuf::from(".")).is_err());
        assert!(Manifest::parse(r#"{"entries": [{"name": "x"}]}"#, PathBuf::from(".")).is_err());
        assert!(Manifest::parse("not json", PathBuf::from(".")).is_err());
    }

    #[test]
    fn scalar_shape_has_one_element() {
        let m = Manifest::parse(SAMPLE, PathBuf::from(".")).unwrap();
        let e = m.entry("decode_step").unwrap();
        assert_eq!(e.inputs[2].shape.len(), 0);
        assert_eq!(e.inputs[2].num_elements(), 1);
    }
}
