//! Cross-PR trend diff over `BENCH_*.json` experiment artifacts — the
//! engine behind `repro bench-diff <baseline-dir> <candidate-dir>`.
//!
//! Two artifact sets are compared *cell by cell*: experiments match on
//! their artifact `experiment` id, reports match on title, rows on their
//! row label (first-cell rendering, with duplicate labels matched by
//! occurrence), columns on header name. Every matched pair of value
//! cells yields a signed percentage delta classified through the unit's
//! [`Polarity`]: a worse-direction move beyond tolerance is a
//! regression, a better-direction move an improvement, and for neutral
//! units (ratios, counts, sizes) any beyond-tolerance drift is a
//! regression — a deterministic simulator that quietly changed its
//! numbers is exactly what the CI gate exists to catch. Structural gaps
//! (missing experiment/report/row/column, unit changes, text-cell edits)
//! and paper-claim expectations that flipped from PASS to FAIL are
//! regressions too; candidate-only additions are reported as notes.

use crate::report::model::{Cell, Report};
use crate::report::value::{Polarity, Unit};
use crate::util::json::Json;

/// Classification of one beyond-tolerance cell move.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    Regressed,
    Improved,
}

/// One compared cell whose move exceeds the tolerance.
#[derive(Debug, Clone)]
pub struct CellDelta {
    pub experiment: String,
    /// Report title.
    pub report: String,
    /// Row label (first cell of the row).
    pub row: String,
    pub column: String,
    pub unit: Unit,
    pub baseline: f64,
    pub candidate: f64,
    /// Signed percent change relative to the baseline magnitude.
    pub pct: f64,
    pub verdict: Verdict,
}

/// Aggregated outcome of diffing one or more artifact pairs.
#[derive(Debug, Default)]
pub struct DiffOutcome {
    /// Value cells compared.
    pub cells_compared: usize,
    /// Beyond-tolerance cell moves (regressions and improvements).
    pub deltas: Vec<CellDelta>,
    /// Structural regressions: things the baseline had that the candidate
    /// lost (experiments, reports, rows, columns, units, text content) and
    /// expectations that flipped to FAIL.
    pub structural: Vec<String>,
    /// Candidate-only additions (informational, never a regression).
    pub additions: Vec<String>,
}

impl DiffOutcome {
    pub fn merge(&mut self, other: DiffOutcome) {
        self.cells_compared += other.cells_compared;
        self.deltas.extend(other.deltas);
        self.structural.extend(other.structural);
        self.additions.extend(other.additions);
    }

    pub fn regressions(&self) -> usize {
        self.structural.len()
            + self.deltas.iter().filter(|d| d.verdict == Verdict::Regressed).count()
    }

    pub fn improvements(&self) -> usize {
        self.deltas.iter().filter(|d| d.verdict == Verdict::Improved).count()
    }

    pub fn has_regressions(&self) -> bool {
        self.regressions() > 0
    }

    /// The typed delta table `repro bench-diff` prints (and CI uploads).
    pub fn to_report(&self, tolerance_pct: f64) -> Report {
        let mut r = Report::new(format!(
            "Bench diff: candidate vs baseline (tolerance +-{tolerance_pct}%)"
        ));
        r.header(&[
            "experiment",
            "report / row / column",
            "baseline",
            "candidate",
            "delta %",
            "verdict",
        ]);
        for d in &self.deltas {
            r.row(vec![
                Cell::text(d.experiment.clone()),
                Cell::text(format!("{} / {} / {}", d.report, d.row, d.column)),
                Cell::val(d.baseline, d.unit),
                Cell::val(d.candidate, d.unit),
                Cell::val(d.pct, Unit::Pp),
                Cell::text(match d.verdict {
                    Verdict::Regressed => "REGRESSED",
                    Verdict::Improved => "improved",
                }),
            ]);
        }
        for s in &self.structural {
            r.row(vec![
                Cell::text("-"),
                Cell::text(s.clone()),
                Cell::text("-"),
                Cell::text("-"),
                Cell::text("-"),
                Cell::text("REGRESSED"),
            ]);
        }
        for a in &self.additions {
            r.note(format!("candidate-only: {a}"));
        }
        r.note(format!(
            "{} cells compared, {} beyond tolerance ({} regressions, {} improvements), \
             {} structural regressions",
            self.cells_compared,
            self.deltas.len(),
            self.regressions() - self.structural.len(),
            self.improvements(),
            self.structural.len()
        ));
        r
    }
}

/// Occurrence-tagged key so duplicate labels still pair deterministically.
fn keyed(labels: impl Iterator<Item = String>) -> Vec<(String, usize)> {
    let mut seen: Vec<(String, usize)> = Vec::new();
    labels
        .map(|label| {
            let occ = match seen.iter_mut().find(|(l, _)| *l == label) {
                Some(e) => {
                    e.1 += 1;
                    e.1
                }
                None => {
                    seen.push((label.clone(), 0));
                    0
                }
            };
            (label, occ)
        })
        .collect()
}

fn row_label(cells: &[Cell]) -> String {
    cells.first().map(|c| c.fmt()).unwrap_or_default()
}

/// Signed percent change of `cand` vs `base`, relative to |base|.
fn pct_change(base: f64, cand: f64) -> f64 {
    if base == cand {
        0.0
    } else if base == 0.0 {
        // From exactly zero any move is a full-scale change.
        100.0 * cand.signum()
    } else {
        100.0 * (cand - base) / base.abs()
    }
}

fn classify(unit: Unit, pct: f64) -> Verdict {
    let worse = match unit.polarity() {
        Polarity::HigherIsBetter => pct < 0.0,
        Polarity::LowerIsBetter => pct > 0.0,
        Polarity::Neutral => true,
    };
    if worse {
        Verdict::Regressed
    } else {
        Verdict::Improved
    }
}

/// Diff two parsed reports of one experiment (already matched by title).
fn diff_reports(
    experiment: &str,
    base: &Report,
    cand: &Report,
    tolerance_pct: f64,
    out: &mut DiffOutcome,
) {
    let loc = |row: &str, col: &str| format!("{} / {} / {}", base.title(), row, col);
    // Columns pair by header name (occurrence-tagged).
    let base_cols = keyed(base.columns().iter().cloned());
    let cand_cols = keyed(cand.columns().iter().cloned());
    let col_idx: Vec<Option<usize>> = base_cols
        .iter()
        .map(|k| cand_cols.iter().position(|c| c == k))
        .collect();
    for (bi, k) in base_cols.iter().enumerate() {
        if col_idx[bi].is_none() {
            out.structural
                .push(format!("{experiment}: column '{}' of '{}' missing", k.0, base.title()));
        }
    }
    for k in &cand_cols {
        if !base_cols.contains(k) {
            out.additions.push(format!("{experiment}: new column '{}' in '{}'", k.0, cand.title()));
        }
    }
    // Rows pair by label (occurrence-tagged).
    let base_rows = keyed(base.rows().iter().map(|r| row_label(r)));
    let cand_rows = keyed(cand.rows().iter().map(|r| row_label(r)));
    for (bi, key) in base_rows.iter().enumerate() {
        let Some(ci) = cand_rows.iter().position(|c| c == key) else {
            out.structural
                .push(format!("{experiment}: row '{}' of '{}' missing", key.0, base.title()));
            continue;
        };
        let brow = &base.rows()[bi];
        let crow = &cand.rows()[ci];
        for (bcol, mapped) in col_idx.iter().enumerate() {
            let Some(ccol) = *mapped else { continue };
            let (Some(bcell), Some(ccell)) = (brow.get(bcol), crow.get(ccol)) else {
                if brow.get(bcol).is_some() {
                    out.structural.push(format!(
                        "{experiment}: cell at {} missing",
                        loc(&key.0, &base_cols[bcol].0)
                    ));
                }
                continue;
            };
            match (bcell, ccell) {
                (Cell::Text(b), Cell::Text(c)) => {
                    if b != c {
                        out.structural.push(format!(
                            "{experiment}: text at {} changed '{b}' -> '{c}'",
                            loc(&key.0, &base_cols[bcol].0)
                        ));
                    }
                }
                (Cell::Val(b), Cell::Val(c)) => {
                    if b.unit != c.unit {
                        out.structural.push(format!(
                            "{experiment}: unit at {} changed {} -> {}",
                            loc(&key.0, &base_cols[bcol].0),
                            b.unit.name(),
                            c.unit.name()
                        ));
                        continue;
                    }
                    out.cells_compared += 1;
                    let pct = pct_change(b.x, c.x);
                    if pct.abs() > tolerance_pct {
                        out.deltas.push(CellDelta {
                            experiment: experiment.to_string(),
                            report: base.title().to_string(),
                            row: key.0.clone(),
                            column: base_cols[bcol].0.clone(),
                            unit: b.unit,
                            baseline: b.x,
                            candidate: c.x,
                            pct,
                            verdict: classify(b.unit, pct),
                        });
                    }
                }
                _ => out.structural.push(format!(
                    "{experiment}: cell at {} changed kind (text <-> value)",
                    loc(&key.0, &base_cols[bcol].0)
                )),
            }
        }
    }
    for key in &cand_rows {
        if !base_rows.contains(key) {
            out.additions.push(format!("{experiment}: new row '{}' in '{}'", key.0, cand.title()));
        }
    }
}

fn expectation_status(artifact: &Json) -> Result<Vec<(String, bool)>, String> {
    let arr = match artifact.get("expectations") {
        None => return Ok(Vec::new()),
        Some(v) => v.as_arr().ok_or("artifact 'expectations' must be an array")?,
    };
    arr.iter()
        .map(|e| {
            let id = e
                .req("id")
                .map_err(|e| e.to_string())?
                .as_str()
                .ok_or("expectation 'id' must be a string")?
                .to_string();
            let pass = e
                .req("pass")
                .map_err(|e| e.to_string())?
                .as_bool()
                .ok_or("expectation 'pass' must be a bool")?;
            Ok((id, pass))
        })
        .collect()
}

fn artifact_reports(artifact: &Json) -> Result<Vec<Report>, String> {
    artifact
        .req("reports")
        .map_err(|e| e.to_string())?
        .as_arr()
        .ok_or("artifact 'reports' must be an array")?
        .iter()
        .map(|r| Report::from_json(r).map_err(|e| e.to_string()))
        .collect()
}

/// The artifact's experiment id (for matching and messages).
pub fn artifact_experiment(artifact: &Json) -> Result<String, String> {
    Ok(artifact
        .req("experiment")
        .map_err(|e| e.to_string())?
        .as_str()
        .ok_or("artifact 'experiment' must be a string")?
        .to_string())
}

/// Diff two parsed `BENCH_<id>.json` artifacts of the same experiment.
pub fn diff_artifacts(base: &Json, cand: &Json, tolerance_pct: f64) -> Result<DiffOutcome, String> {
    let experiment = artifact_experiment(base)?;
    if artifact_experiment(cand)? != experiment {
        return Err(format!(
            "artifact mismatch: baseline is '{}', candidate is '{}'",
            experiment,
            artifact_experiment(cand)?
        ));
    }
    let mut out = DiffOutcome::default();
    let base_reports = artifact_reports(base)?;
    let cand_reports = artifact_reports(cand)?;
    let base_keys = keyed(base_reports.iter().map(|r| r.title().to_string()));
    let cand_keys = keyed(cand_reports.iter().map(|r| r.title().to_string()));
    for (bi, key) in base_keys.iter().enumerate() {
        match cand_keys.iter().position(|c| c == key) {
            Some(ci) => diff_reports(
                &experiment,
                &base_reports[bi],
                &cand_reports[ci],
                tolerance_pct,
                &mut out,
            ),
            None => out
                .structural
                .push(format!("{experiment}: report '{}' missing from candidate", key.0)),
        }
    }
    for key in &cand_keys {
        if !base_keys.contains(key) {
            out.additions.push(format!("{experiment}: new report '{}'", key.0));
        }
    }
    // Paper-claim expectations: PASS -> FAIL is a regression even when
    // every compared cell stayed inside tolerance.
    let base_exp = expectation_status(base)?;
    let cand_exp = expectation_status(cand)?;
    for (id, pass) in &base_exp {
        match cand_exp.iter().find(|(cid, _)| cid == id) {
            Some((_, cand_pass)) => {
                if *pass && !cand_pass {
                    out.structural
                        .push(format!("{experiment}: expectation '{id}' regressed PASS -> FAIL"));
                }
            }
            None => out
                .structural
                .push(format!("{experiment}: expectation '{id}' missing from candidate")),
        }
    }
    for (id, _) in &cand_exp {
        if !base_exp.iter().any(|(bid, _)| bid == id) {
            out.additions.push(format!("{experiment}: new expectation '{id}'"));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::{self, Experiment};

    fn artifact(tweak: impl Fn(&mut Report)) -> Json {
        let mut r = Report::new("Fig T: throughput");
        r.header(&["batch", "tok/s", "p99 s", "note"]);
        r.row(vec![
            Cell::count(8),
            Cell::val(100.0, Unit::TokPerSec),
            Cell::val(0.5, Unit::Seconds),
            Cell::text("a"),
        ]);
        r.row(vec![
            Cell::count(64),
            Cell::val(400.0, Unit::TokPerSec),
            Cell::val(0.9, Unit::Seconds),
            Cell::text("b"),
        ]);
        tweak(&mut r);
        Json::obj(vec![
            ("schema", Json::Str(harness::ARTIFACT_SCHEMA.into())),
            ("experiment", Json::Str("figT".into())),
            ("title", Json::Str("t".into())),
            ("params", Json::obj(vec![])),
            ("reports", Json::Arr(vec![r.to_json()])),
            (
                "expectations",
                Json::Arr(vec![Json::obj(vec![
                    ("id", Json::Str("figT.claim".into())),
                    ("claim", Json::Str("c".into())),
                    ("pass", Json::Bool(true)),
                    ("actual", Json::Num(1.0)),
                    ("detail", Json::Str("d".into())),
                ])]),
            ),
        ])
    }

    #[test]
    fn identical_artifacts_diff_clean() {
        let a = artifact(|_| {});
        let out = diff_artifacts(&a, &a, 1.0).unwrap();
        assert_eq!(out.cells_compared, 6);
        assert!(out.deltas.is_empty());
        assert!(!out.has_regressions());
        assert_eq!(out.regressions(), 0);
    }

    #[test]
    fn throughput_drop_is_a_regression_and_gain_is_not() {
        let base = artifact(|_| {});
        let cand = artifact(|r| {
            *r = {
                let mut n = Report::new("Fig T: throughput");
                n.header(&["batch", "tok/s", "p99 s", "note"]);
                n.row(vec![
                    Cell::count(8),
                    Cell::val(90.0, Unit::TokPerSec), // -10%: regression
                    Cell::val(0.5, Unit::Seconds),
                    Cell::text("a"),
                ]);
                n.row(vec![
                    Cell::count(64),
                    Cell::val(480.0, Unit::TokPerSec), // +20%: improvement
                    Cell::val(0.45, Unit::Seconds),    // latency halved: improvement
                    Cell::text("b"),
                ]);
                n
            };
        });
        let out = diff_artifacts(&base, &cand, 2.0).unwrap();
        assert_eq!(out.deltas.len(), 3);
        assert_eq!(out.regressions(), 1);
        assert_eq!(out.improvements(), 2);
        let reg = out.deltas.iter().find(|d| d.verdict == Verdict::Regressed).unwrap();
        assert_eq!(reg.row, "8");
        assert_eq!(reg.column, "tok/s");
        assert!((reg.pct + 10.0).abs() < 1e-9);
        // Tolerance gates it: at 15% the drop passes.
        let lax = diff_artifacts(&base, &cand, 15.0).unwrap();
        assert_eq!(lax.regressions(), 0);
    }

    #[test]
    fn latency_rise_and_count_drift_regress() {
        let base = artifact(|_| {});
        let cand = artifact(|r| {
            let mut n = Report::new("Fig T: throughput");
            n.header(&["batch", "tok/s", "p99 s", "note"]);
            n.row(vec![
                Cell::count(8),
                Cell::val(100.0, Unit::TokPerSec),
                Cell::val(1.0, Unit::Seconds), // +100%: regression
                Cell::text("a"),
            ]);
            n.row(vec![
                Cell::count(64),
                Cell::val(400.0, Unit::TokPerSec),
                Cell::val(0.9, Unit::Seconds),
                Cell::text("b"),
            ]);
            *r = n;
        });
        let out = diff_artifacts(&base, &cand, 1.0).unwrap();
        assert_eq!(out.regressions(), 1);
        assert_eq!(out.deltas[0].verdict, Verdict::Regressed);
        // Neutral-unit drift (a Count row label changing is structural,
        // not a delta: the row fails to pair and is reported missing).
        let drifted = artifact(|r| {
            let mut n = Report::new("Fig T: throughput");
            n.header(&["batch", "tok/s", "p99 s", "note"]);
            n.row(vec![
                Cell::count(9),
                Cell::val(100.0, Unit::TokPerSec),
                Cell::val(0.5, Unit::Seconds),
                Cell::text("a"),
            ]);
            n.row(vec![
                Cell::count(64),
                Cell::val(400.0, Unit::TokPerSec),
                Cell::val(0.9, Unit::Seconds),
                Cell::text("b"),
            ]);
            *r = n;
        });
        let out2 = diff_artifacts(&base, &drifted, 1.0).unwrap();
        assert!(out2.has_regressions());
        assert!(out2.structural.iter().any(|s| s.contains("row '8'")));
        assert!(out2.additions.iter().any(|s| s.contains("row '9'")));
    }

    #[test]
    fn structural_losses_regress_and_additions_do_not() {
        let base = artifact(|_| {});
        // Candidate lost a column but gained a report.
        let cand = artifact(|r| {
            let mut n = Report::new("Fig T: throughput");
            n.header(&["batch", "tok/s", "note"]);
            n.row(vec![Cell::count(8), Cell::val(100.0, Unit::TokPerSec), Cell::text("a")]);
            n.row(vec![Cell::count(64), Cell::val(400.0, Unit::TokPerSec), Cell::text("b")]);
            *r = n;
        });
        let out = diff_artifacts(&base, &cand, 1.0).unwrap();
        assert!(out.structural.iter().any(|s| s.contains("column 'p99 s'")));
        assert!(out.has_regressions());
        // Reverse direction: the extra column is an addition, not a
        // regression.
        let rev = diff_artifacts(&cand, &base, 1.0).unwrap();
        assert!(rev.additions.iter().any(|s| s.contains("new column 'p99 s'")));
        assert_eq!(rev.regressions(), 0);
    }

    #[test]
    fn expectation_flip_regresses() {
        let base = artifact(|_| {});
        let mut cand = artifact(|_| {});
        if let Json::Obj(m) = &mut cand {
            m.insert(
                "expectations".into(),
                Json::Arr(vec![Json::obj(vec![
                    ("id", Json::Str("figT.claim".into())),
                    ("claim", Json::Str("c".into())),
                    ("pass", Json::Bool(false)),
                    ("actual", Json::Num(0.0)),
                    ("detail", Json::Str("d".into())),
                ])]),
            );
        }
        let out = diff_artifacts(&base, &cand, 1.0).unwrap();
        assert!(out.has_regressions());
        assert!(out.structural.iter().any(|s| s.contains("PASS -> FAIL")));
        // FAIL -> PASS is fine.
        let out2 = diff_artifacts(&cand, &base, 1.0).unwrap();
        assert_eq!(out2.regressions(), 0);
    }

    #[test]
    fn mismatched_experiments_rejected() {
        let base = artifact(|_| {});
        let mut cand = artifact(|_| {});
        if let Json::Obj(m) = &mut cand {
            m.insert("experiment".into(), Json::Str("other".into()));
        }
        assert!(diff_artifacts(&base, &cand, 1.0).is_err());
    }

    #[test]
    fn delta_report_renders_summary() {
        let base = artifact(|_| {});
        let cand = artifact(|r| {
            let mut n = Report::new("Fig T: throughput");
            n.header(&["batch", "tok/s", "p99 s", "note"]);
            n.row(vec![
                Cell::count(8),
                Cell::val(50.0, Unit::TokPerSec),
                Cell::val(0.5, Unit::Seconds),
                Cell::text("a"),
            ]);
            n.row(vec![
                Cell::count(64),
                Cell::val(400.0, Unit::TokPerSec),
                Cell::val(0.9, Unit::Seconds),
                Cell::text("b"),
            ]);
            *r = n;
        });
        let out = diff_artifacts(&base, &cand, 1.0).unwrap();
        let rep = out.to_report(1.0);
        let text = rep.render();
        assert!(text.contains("REGRESSED"));
        assert!(text.contains("tok/s"));
        assert!(rep.notes().iter().any(|n| n.contains("1 regressions")));
    }

    #[test]
    fn real_artifact_diffs_clean_against_itself() {
        // End-to-end over a real experiment artifact (the CI gate's
        // unchanged-tree case must exit 0).
        let e = harness::find("table1").unwrap();
        let params = e.params();
        let reports = e.run(&params);
        let results = harness::evaluate(e.as_ref(), &params, &reports);
        let j = harness::artifact_json(e.as_ref(), &params, &reports, &results);
        let parsed = Json::parse(&j.dump()).unwrap();
        let out = diff_artifacts(&parsed, &parsed, 0.0).unwrap();
        assert!(out.cells_compared > 0);
        assert!(!out.has_regressions());
    }
}
