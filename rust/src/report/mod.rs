//! Typed experiment reports: the data model every harness module emits.
//!
//! The paper's contribution is *quantitative* (Gaudi-2 at 99.3% of peak
//! for 8192^3 GEMM, iso-SLO replica counts, energy-efficiency ratios), so
//! reports carry raw numbers, not pre-formatted strings:
//!
//! * [`Value`] — an `f64` plus a [`Unit`] that fixes both the ASCII cell
//!   formatting and the JSON serialization tag.
//! * [`Cell`] / [`Report`] — a titled table of typed cells with headers
//!   and notes; renders to the same ASCII tables as before
//!   (`util::table` is the renderer), to CSV, and to JSON via
//!   `util::json`.
//! * [`Series`] — a typed column view (`report.series("tok/s")`) for
//!   consumers that want the numbers back out.
//! * [`Expectation`] — a paper-claim regression check: a cell/column
//!   selector plus a typed comparison, evaluated by `repro run --check`
//!   and by the integration tests (replacing substring asserts over
//!   rendered tables).
//!
//! * [`diff`] — the cross-PR trend diff over two artifact directories
//!   (`repro bench-diff`): cell-by-cell typed deltas classified through
//!   each unit's [`Polarity`], structural-loss detection, and
//!   expectation PASS->FAIL tracking — the CI regression gate.
//!
//! `repro run all --json --out bench/` writes one `BENCH_<id>.json`
//! artifact per experiment (schema `cuda-myth/experiment-v1`), which is
//! the machine-readable perf trajectory CI uploads per commit.

pub mod diff;
pub mod expect;
pub mod model;
pub mod value;

pub use diff::{CellDelta, DiffOutcome, Verdict};
pub use expect::{Agg, Check, Expectation, ExpectationResult, Selector};
pub use model::{Cell, Report, Series};
pub use value::{Polarity, Unit, Value};
