//! Paper-claim regression checks: a typed selector over an experiment's
//! reports plus a typed comparison. `Expectation`s replace the substring
//! asserts that used to grep rendered ASCII — the claim "Gaudi-2 reaches
//! >= 425 TFLOPS at 8192^3" is now a cell selector and a bound, evaluated
//! by `repro run --check` (exit non-zero on any failure), folded into the
//! per-experiment JSON artifacts, and enforced by the integration tests.

use crate::util::json::Json;
use crate::util::table::fmt3;

use super::model::{Cell, Report, Series};

/// How to reduce the selected cells to one number.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Agg {
    /// A single cell (requires a row label).
    Cell,
    Mean,
    Min,
    Max,
    Sum,
}

impl Agg {
    fn name(&self) -> &'static str {
        match self {
            Agg::Cell => "cell",
            Agg::Mean => "mean",
            Agg::Min => "min",
            Agg::Max => "max",
            Agg::Sum => "sum",
        }
    }
}

/// Addresses a number inside an experiment's reports: which report (title
/// substring), which column (header name, or `"*"` for every value cell
/// outside the row-label column), optionally which row (label match), and
/// how to aggregate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Selector {
    pub report: &'static str,
    pub column: &'static str,
    pub row: Option<&'static str>,
    pub agg: Agg,
}

impl Selector {
    /// One cell: `(report, row label, column)`.
    pub fn cell(report: &'static str, row: &'static str, column: &'static str) -> Selector {
        Selector { report, column, row: Some(row), agg: Agg::Cell }
    }

    /// Aggregate over one column's value cells.
    pub fn column(report: &'static str, column: &'static str, agg: Agg) -> Selector {
        Selector { report, column, row: None, agg }
    }

    /// Aggregate over every value cell outside the row-label column —
    /// the "average over the heatmap grid" shape of claim.
    pub fn body(report: &'static str, agg: Agg) -> Selector {
        Selector { report, column: "*", row: None, agg }
    }

    /// Extract the addressed number, or explain what failed to resolve.
    pub fn resolve(&self, reports: &[Report]) -> Result<f64, String> {
        let rep = reports
            .iter()
            .find(|r| r.title().contains(self.report))
            .ok_or_else(|| format!("no report titled like '{}'", self.report))?;
        match (self.row, self.agg) {
            (Some(row), Agg::Cell) => rep
                .value_at(row, self.column)
                .map(|v| v.x)
                .ok_or_else(|| {
                    format!("no value cell at row '{row}', column '{}' of '{}'", self.column, rep.title())
                }),
            (Some(_), agg) => Err(format!(
                "a row label requires Agg::Cell, not Agg::{} (selector {})",
                agg.name(),
                self.describe()
            )),
            (None, Agg::Cell) => Err(format!(
                "Agg::Cell requires a row label (selector {})",
                self.describe()
            )),
            (None, agg) => {
                let values: Vec<f64> = if self.column == "*" {
                    rep.body_values()
                } else {
                    rep.series(self.column)
                        .ok_or_else(|| {
                            format!("no column '{}' in '{}'", self.column, rep.title())
                        })?
                        .values
                };
                if values.is_empty() {
                    return Err(format!(
                        "column '{}' of '{}' has no value cells",
                        self.column,
                        rep.title()
                    ));
                }
                // One fold implementation: the Series methods.
                let s = Series { column: self.column.to_string(), unit: None, values };
                Ok(match agg {
                    Agg::Cell => unreachable!("handled by the (None, Agg::Cell) arm"),
                    Agg::Mean => s.mean(),
                    Agg::Min => s.min(),
                    Agg::Max => s.max(),
                    Agg::Sum => s.sum(),
                })
            }
        }
    }

    fn describe(&self) -> String {
        match self.row {
            Some(row) => format!("{}[{row}].{}", self.report, self.column),
            None => format!("{}({} {})", self.report, self.agg.name(), self.column),
        }
    }
}

/// The typed comparison against the paper's number.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Check {
    Ge(f64),
    Le(f64),
    /// |actual - target| <= tol.
    Within { target: f64, tol: f64 },
    /// lo <= actual <= hi.
    Between(f64, f64),
    /// Bitwise equality (e.g. the 1-replica cluster parity claim).
    EqExact(f64),
}

impl Check {
    pub fn pass(&self, actual: f64) -> bool {
        match *self {
            Check::Ge(bound) => actual >= bound,
            Check::Le(bound) => actual <= bound,
            Check::Within { target, tol } => (actual - target).abs() <= tol,
            Check::Between(lo, hi) => (lo..=hi).contains(&actual),
            Check::EqExact(target) => actual == target,
        }
    }

    pub fn describe(&self) -> String {
        match *self {
            Check::Ge(bound) => format!(">= {}", fmt3(bound)),
            Check::Le(bound) => format!("<= {}", fmt3(bound)),
            Check::Within { target, tol } => format!("{} +- {}", fmt3(target), fmt3(tol)),
            Check::Between(lo, hi) => format!("in [{}, {}]", fmt3(lo), fmt3(hi)),
            Check::EqExact(target) => format!("== {} exactly", fmt3(target)),
        }
    }
}

/// One paper-claim assertion: where the number lives and what the paper
/// says it should be.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Expectation {
    /// Stable id, "<experiment>.<claim>" by convention.
    pub id: &'static str,
    /// The paper claim in words (shows up in artifacts and failures).
    pub claim: &'static str,
    pub selector: Selector,
    pub check: Check,
}

impl Expectation {
    pub fn new(
        id: &'static str,
        claim: &'static str,
        selector: Selector,
        check: Check,
    ) -> Expectation {
        Expectation { id, claim, selector, check }
    }

    pub fn evaluate(&self, reports: &[Report]) -> ExpectationResult {
        match self.selector.resolve(reports) {
            Ok(actual) => ExpectationResult {
                id: self.id.to_string(),
                claim: self.claim.to_string(),
                pass: self.check.pass(actual),
                actual: Some(actual),
                detail: format!(
                    "{} = {} (want {})",
                    self.selector.describe(),
                    fmt3(actual),
                    self.check.describe()
                ),
            },
            Err(why) => ExpectationResult {
                id: self.id.to_string(),
                claim: self.claim.to_string(),
                pass: false,
                actual: None,
                detail: format!("selector failed: {why}"),
            },
        }
    }
}

/// Outcome of evaluating one expectation.
#[derive(Debug, Clone, PartialEq)]
pub struct ExpectationResult {
    pub id: String,
    pub claim: String,
    pub pass: bool,
    pub actual: Option<f64>,
    pub detail: String,
}

impl ExpectationResult {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::Str(self.id.clone())),
            ("claim", Json::Str(self.claim.clone())),
            ("pass", Json::Bool(self.pass)),
            (
                "actual",
                match self.actual {
                    Some(x) => Json::Num(x),
                    None => Json::Null,
                },
            ),
            ("detail", Json::Str(self.detail.clone())),
        ])
    }
}

/// Human-readable PASS/FAIL table over a batch of results (`repro run
/// --check` prints this).
pub fn results_report(results: &[ExpectationResult]) -> Report {
    let mut r = Report::new("Paper-claim expectation checks");
    r.header(&["expectation", "status", "detail"]);
    for res in results {
        r.row(vec![
            Cell::text(res.id.clone()),
            Cell::text(if res.pass { "PASS" } else { "FAIL" }),
            Cell::text(res.detail.clone()),
        ]);
    }
    let failed = results.iter().filter(|r| !r.pass).count();
    r.note(format!("{} checks, {} failed", results.len(), failed));
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::report::value::Unit;

    fn reports() -> Vec<Report> {
        let mut r = Report::new("Fig T: throughput");
        r.header(&["batch", "tok/s", "note"]);
        r.row(vec![Cell::count(8), Cell::val(100.0, Unit::TokPerSec), Cell::text("a")]);
        r.row(vec![Cell::count(64), Cell::val(400.0, Unit::TokPerSec), Cell::text("b")]);
        vec![r]
    }

    #[test]
    fn cell_selector_resolves_by_row_label() {
        let s = Selector::cell("Fig T", "64", "tok/s");
        assert_eq!(s.resolve(&reports()), Ok(400.0));
        assert!(Selector::cell("Fig T", "99", "tok/s").resolve(&reports()).is_err());
        assert!(Selector::cell("Fig Z", "64", "tok/s").resolve(&reports()).is_err());
    }

    #[test]
    fn column_and_body_aggregates() {
        let r = reports();
        assert_eq!(Selector::column("Fig T", "tok/s", Agg::Mean).resolve(&r), Ok(250.0));
        assert_eq!(Selector::column("Fig T", "tok/s", Agg::Min).resolve(&r), Ok(100.0));
        assert_eq!(Selector::column("Fig T", "tok/s", Agg::Sum).resolve(&r), Ok(500.0));
        // body skips the row-label column and text cells.
        assert_eq!(Selector::body("Fig T", Agg::Max).resolve(&r), Ok(400.0));
        // text-only column has no value cells.
        assert!(Selector::column("Fig T", "note", Agg::Mean).resolve(&r).is_err());
        // Agg::Cell without a row label is rejected, not first-cell.
        let bad = Selector { report: "Fig T", column: "tok/s", row: None, agg: Agg::Cell };
        assert!(bad.resolve(&r).unwrap_err().contains("row label"));
        // And a row label with a non-Cell agg is rejected, not silently
        // treated as a cell lookup.
        let bad2 = Selector { report: "Fig T", column: "tok/s", row: Some("64"), agg: Agg::Mean };
        assert!(bad2.resolve(&r).unwrap_err().contains("Agg::Cell"));
    }

    #[test]
    fn checks_compare_as_documented() {
        assert!(Check::Ge(425.0).pass(429.0));
        assert!(!Check::Ge(425.0).pass(400.0));
        assert!(Check::Within { target: 1.47, tol: 0.2 }.pass(1.30));
        assert!(!Check::Within { target: 1.47, tol: 0.2 }.pass(1.0));
        assert!(Check::Between(8.0, 25.0).pass(14.9));
        assert!(Check::EqExact(0.0).pass(0.0));
        assert!(!Check::EqExact(0.0).pass(1e-300));
    }

    #[test]
    fn evaluate_produces_result_and_json() {
        let e = Expectation::new(
            "figT.peak",
            "throughput reaches 400 tok/s at batch 64",
            Selector::cell("Fig T", "64", "tok/s"),
            Check::Ge(390.0),
        );
        let res = e.evaluate(&reports());
        assert!(res.pass);
        assert_eq!(res.actual, Some(400.0));
        let j = crate::util::json::Json::parse(&res.to_json().dump()).unwrap();
        assert_eq!(j.get("pass").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("actual").unwrap().as_f64(), Some(400.0));
    }

    #[test]
    fn unresolvable_selector_fails_closed() {
        let e = Expectation::new(
            "figT.broken",
            "selector points nowhere",
            Selector::cell("Fig T", "64", "no-such-col"),
            Check::Ge(0.0),
        );
        let res = e.evaluate(&reports());
        assert!(!res.pass);
        assert!(res.actual.is_none());
        assert!(res.detail.contains("selector failed"));
    }

    #[test]
    fn results_table_counts_failures() {
        let ok = ExpectationResult {
            id: "a".into(),
            claim: "c".into(),
            pass: true,
            actual: Some(1.0),
            detail: "d".into(),
        };
        let bad = ExpectationResult { id: "b".into(), pass: false, ..ok.clone() };
        let table = results_report(&[ok, bad]);
        assert_eq!(table.num_rows(), 2);
        assert!(table.render().contains("FAIL"));
        assert!(table.notes()[0].contains("1 failed"));
    }
}
