//! The report table model: typed cells, typed column views, and the
//! JSON (de)serialization. ASCII/CSV rendering lives in `util::table`
//! (a renderer over this model); the builder API (`new` / `header` /
//! `row` / `note`) is unchanged from the stringly-typed predecessor so
//! harness modules read the same — only the cells are typed now.

use crate::util::json::{Json, JsonError};
use crate::util::stats::mean;

use super::value::{Unit, Value};

/// One table cell: a text label or a typed value.
#[derive(Debug, Clone, PartialEq)]
pub enum Cell {
    Text(String),
    Val(Value),
}

impl Cell {
    pub fn text(s: impl Into<String>) -> Cell {
        Cell::Text(s.into())
    }

    pub fn val(x: f64, unit: Unit) -> Cell {
        Cell::Val(Value::new(x, unit))
    }

    pub fn count(n: usize) -> Cell {
        Cell::Val(Value::new(n as f64, Unit::Count))
    }

    /// The typed value, if this is a value cell.
    pub fn value(&self) -> Option<Value> {
        match self {
            Cell::Val(v) => Some(*v),
            Cell::Text(_) => None,
        }
    }

    /// ASCII rendering of the cell.
    pub fn fmt(&self) -> String {
        match self {
            Cell::Text(s) => s.clone(),
            Cell::Val(v) => v.fmt(),
        }
    }

    /// Raw CSV rendering: full-precision numbers for values, the plain
    /// text for labels (JSON carries the unit; CSV is for plotting).
    pub fn to_csv_field(&self) -> String {
        match self {
            Cell::Text(s) => s.clone(),
            Cell::Val(v) => format!("{}", v.x),
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            Cell::Text(s) => Json::Str(s.clone()),
            Cell::Val(v) => v.to_json(),
        }
    }

    pub fn from_json(j: &Json) -> Result<Cell, JsonError> {
        match j {
            Json::Str(s) => Ok(Cell::Text(s.clone())),
            Json::Obj(_) => Ok(Cell::Val(Value::from_json(j)?)),
            _ => Err(JsonError("cell must be a string or a {v, unit} object".into())),
        }
    }
}

/// A typed column view: the numeric values of one column (text cells
/// skipped), with the unit of the first value cell.
#[derive(Debug, Clone, PartialEq)]
pub struct Series {
    pub column: String,
    pub unit: Option<Unit>,
    pub values: Vec<f64>,
}

impl Series {
    pub fn mean(&self) -> f64 {
        mean(&self.values)
    }

    pub fn min(&self) -> f64 {
        self.values.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.values.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn sum(&self) -> f64 {
        self.values.iter().sum()
    }
}

/// A titled table of typed cells — what every experiment emits and what
/// `util::table` renders to ASCII/CSV.
#[derive(Debug, Clone, PartialEq)]
pub struct Report {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<Cell>>,
    notes: Vec<String>,
}

impl Report {
    pub fn new(title: impl Into<String>) -> Self {
        Report { title: title.into(), header: Vec::new(), rows: Vec::new(), notes: Vec::new() }
    }

    pub fn header(&mut self, cols: &[&str]) -> &mut Self {
        self.header = cols.iter().map(|s| s.to_string()).collect();
        self
    }

    pub fn row(&mut self, cells: Vec<Cell>) -> &mut Self {
        self.rows.push(cells);
        self
    }

    pub fn note(&mut self, note: impl Into<String>) -> &mut Self {
        self.notes.push(note.into());
        self
    }

    pub fn title(&self) -> &str {
        &self.title
    }

    pub fn columns(&self) -> &[String] {
        &self.header
    }

    pub fn rows(&self) -> &[Vec<Cell>] {
        &self.rows
    }

    pub fn notes(&self) -> &[String] {
        &self.notes
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    fn col_index(&self, column: &str) -> Option<usize> {
        self.header.iter().position(|h| h == column)
    }

    /// Typed view of one column by header name (text cells skipped).
    pub fn series(&self, column: &str) -> Option<Series> {
        let idx = self.col_index(column)?;
        let vals: Vec<Value> =
            self.rows.iter().filter_map(|r| r.get(idx).and_then(|c| c.value())).collect();
        Some(Series {
            column: column.to_string(),
            unit: vals.first().map(|v| v.unit),
            values: vals.iter().map(|v| v.x).collect(),
        })
    }

    /// Every value cell outside the first (row-label) column — the
    /// aggregate view heatmap claims use ("avg speedup over the grid").
    pub fn body_values(&self) -> Vec<f64> {
        self.rows
            .iter()
            .flat_map(|r| r.iter().skip(1))
            .filter_map(|c| c.value().map(|v| v.x))
            .collect()
    }

    /// The value at (row, column), where `row_label` matches the ASCII
    /// rendering of the first cell of the row (the row label).
    pub fn value_at(&self, row_label: &str, column: &str) -> Option<Value> {
        let idx = self.col_index(column)?;
        self.rows
            .iter()
            .find(|r| r.first().map(|c| c.fmt()) == Some(row_label.to_string()))
            .and_then(|r| r.get(idx))
            .and_then(|c| c.value())
    }

    /// Column-aligned ASCII rendering (see `util::table`).
    pub fn render(&self) -> String {
        crate::util::table::render_ascii(self)
    }

    /// Raw-number CSV rendering (see `util::table`).
    pub fn to_csv(&self) -> String {
        crate::util::table::render_csv(self)
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("title", Json::Str(self.title.clone())),
            ("columns", Json::Arr(self.header.iter().map(|h| Json::Str(h.clone())).collect())),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| Json::Arr(r.iter().map(|c| c.to_json()).collect()))
                        .collect(),
                ),
            ),
            ("notes", Json::Arr(self.notes.iter().map(|n| Json::Str(n.clone())).collect())),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Report, JsonError> {
        let title = j
            .req("title")?
            .as_str()
            .ok_or_else(|| JsonError("report 'title' must be a string".into()))?
            .to_string();
        let str_arr = |key: &str| -> Result<Vec<String>, JsonError> {
            j.req(key)?
                .as_arr()
                .ok_or_else(|| JsonError(format!("report '{key}' must be an array")))?
                .iter()
                .map(|v| {
                    v.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| JsonError(format!("'{key}' entries must be strings")))
                })
                .collect()
        };
        let header = str_arr("columns")?;
        let notes = str_arr("notes")?;
        let rows = j
            .req("rows")?
            .as_arr()
            .ok_or_else(|| JsonError("report 'rows' must be an array".into()))?
            .iter()
            .map(|r| {
                r.as_arr()
                    .ok_or_else(|| JsonError("each row must be an array".into()))?
                    .iter()
                    .map(Cell::from_json)
                    .collect::<Result<Vec<Cell>, JsonError>>()
            })
            .collect::<Result<Vec<Vec<Cell>>, JsonError>>()?;
        Ok(Report { title, header, rows, notes })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Report {
        let mut r = Report::new("Fig X: sample");
        r.header(&["shape", "TF", "util"]);
        r.row(vec![Cell::text("8192^3"), Cell::val(429.3, Unit::Tflops), Cell::val(0.993, Unit::Percent)]);
        r.row(vec![Cell::text("1024^3"), Cell::val(118.0, Unit::Tflops), Cell::val(0.273, Unit::Percent)]);
        r.note("a note");
        r
    }

    #[test]
    fn series_and_value_at() {
        let r = sample();
        let s = r.series("TF").unwrap();
        assert_eq!(s.unit, Some(Unit::Tflops));
        assert_eq!(s.values, vec![429.3, 118.0]);
        assert!((s.mean() - 273.65).abs() < 1e-9);
        assert_eq!(s.min(), 118.0);
        assert_eq!(s.max(), 429.3);
        let v = r.value_at("8192^3", "util").unwrap();
        assert_eq!(v, Value::new(0.993, Unit::Percent));
        assert!(r.value_at("missing", "util").is_none());
        assert!(r.series("nope").is_none());
    }

    #[test]
    fn body_values_skip_labels_and_text() {
        let r = sample();
        assert_eq!(r.body_values().len(), 4);
        assert!(r.body_values().contains(&0.273));
    }

    #[test]
    fn json_roundtrip_preserves_model_and_rendering() {
        let r = sample();
        let j = Json::parse(&r.to_json().dump()).unwrap();
        let back = Report::from_json(&j).unwrap();
        assert_eq!(back, r);
        assert_eq!(back.render(), r.render());
        assert_eq!(back.to_csv(), r.to_csv());
    }

    #[test]
    fn csv_is_raw_numbers() {
        let csv = sample().to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "shape,TF,util");
        assert_eq!(lines[1], "8192^3,429.3,0.993");
    }

    #[test]
    fn from_json_rejects_malformed() {
        for bad in [
            r#"{"columns": [], "rows": [], "notes": []}"#,
            r#"{"title": "t", "columns": [1], "rows": [], "notes": []}"#,
            r#"{"title": "t", "columns": [], "rows": [[true]], "notes": []}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(Report::from_json(&j).is_err(), "{bad}");
        }
    }
}
