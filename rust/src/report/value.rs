//! Typed scalar values: a raw `f64` plus a [`Unit`] that fixes the ASCII
//! cell format and the JSON tag. The raw number is the source of truth —
//! formatting is a pure function of `(x, unit)`, so the rendered tables
//! and the JSON artifacts can never disagree on a value.

use crate::util::json::{Json, JsonError};
use crate::util::table::{fmt3, fmt_pct, fmt_ratio};
use crate::util::units::fmt_bytes;

/// Physical unit of a reported value.
///
/// Fractions (utilization, shares, SLO attainment) are stored as
/// fractions in `[0, 1]` under [`Unit::Percent`] and *rendered* as
/// percentages; percentage-point gaps ([`Unit::Pp`]) are stored already
/// scaled (x100) and rendered signed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Unit {
    Tflops,
    Gflops,
    /// Arithmetic intensity, FLOP per byte.
    FlopPerByte,
    GibPerSec,
    GbPerSec,
    TbPerSec,
    Gigabytes,
    Megabytes,
    /// Raw byte sizes, rendered human-readable ("32.0MiB").
    Bytes,
    Millis,
    Seconds,
    TokPerSec,
    ReqPerSec,
    /// Simulated events per wall-clock second (simulator raw speed).
    EventPerSec,
    Joules,
    JoulePerTok,
    /// Dimensionless ratio, rendered as "1.47x".
    Ratio,
    /// Fraction in [0, 1], rendered as "64.2%".
    Percent,
    /// Percentage points (already x100), rendered signed as "+4.5".
    Pp,
    Count,
    Watts,
}

/// Every unit, for JSON tag parsing.
pub const ALL_UNITS: [Unit; 21] = [
    Unit::Tflops,
    Unit::Gflops,
    Unit::FlopPerByte,
    Unit::GibPerSec,
    Unit::GbPerSec,
    Unit::TbPerSec,
    Unit::Gigabytes,
    Unit::Megabytes,
    Unit::Bytes,
    Unit::Millis,
    Unit::Seconds,
    Unit::TokPerSec,
    Unit::ReqPerSec,
    Unit::EventPerSec,
    Unit::Joules,
    Unit::JoulePerTok,
    Unit::Ratio,
    Unit::Percent,
    Unit::Pp,
    Unit::Count,
    Unit::Watts,
];

/// Which direction of change is an improvement for a metric in this
/// unit — the default the bench-diff regression gate classifies with.
/// Heuristic by necessity (a `Percent` cell is usually utilization or
/// SLO attainment, where more is better); `Neutral` units treat *any*
/// beyond-tolerance change as a regression, because for dimensionless
/// ratios, counts and sizes a silent drift is exactly what the gate
/// exists to catch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Polarity {
    HigherIsBetter,
    LowerIsBetter,
    Neutral,
}

impl Unit {
    /// Default improvement direction for `bench-diff` (see [`Polarity`]).
    pub fn polarity(&self) -> Polarity {
        match self {
            Unit::Tflops
            | Unit::Gflops
            | Unit::FlopPerByte
            | Unit::GibPerSec
            | Unit::GbPerSec
            | Unit::TbPerSec
            | Unit::TokPerSec
            | Unit::ReqPerSec
            | Unit::EventPerSec
            | Unit::Percent => Polarity::HigherIsBetter,
            Unit::Millis | Unit::Seconds | Unit::Joules | Unit::JoulePerTok | Unit::Watts => {
                Polarity::LowerIsBetter
            }
            Unit::Gigabytes | Unit::Megabytes | Unit::Bytes | Unit::Ratio | Unit::Pp
            | Unit::Count => Polarity::Neutral,
        }
    }

    /// Stable JSON tag (also usable as an axis label).
    pub fn name(&self) -> &'static str {
        match self {
            Unit::Tflops => "TFLOPS",
            Unit::Gflops => "GFLOPS",
            Unit::FlopPerByte => "FLOP/B",
            Unit::GibPerSec => "GiB/s",
            Unit::GbPerSec => "GB/s",
            Unit::TbPerSec => "TB/s",
            Unit::Gigabytes => "GB",
            Unit::Megabytes => "MB",
            Unit::Bytes => "B",
            Unit::Millis => "ms",
            Unit::Seconds => "s",
            Unit::TokPerSec => "tok/s",
            Unit::ReqPerSec => "req/s",
            Unit::EventPerSec => "ev/s",
            Unit::Joules => "J",
            Unit::JoulePerTok => "J/tok",
            Unit::Ratio => "ratio",
            Unit::Percent => "frac",
            Unit::Pp => "pp",
            Unit::Count => "count",
            Unit::Watts => "W",
        }
    }

    pub fn parse(tag: &str) -> Option<Unit> {
        ALL_UNITS.iter().copied().find(|u| u.name() == tag)
    }

    /// Canonical ASCII cell rendering of `x` in this unit.
    pub fn fmt(&self, x: f64) -> String {
        match self {
            Unit::Ratio => fmt_ratio(x),
            Unit::Percent => fmt_pct(x),
            Unit::Pp => format!("{:+.1}", x),
            Unit::Bytes => fmt_bytes(x),
            Unit::Count => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    format!("{}", x as i64)
                } else {
                    fmt3(x)
                }
            }
            _ => fmt3(x),
        }
    }
}

/// A raw number with its unit — the atom of every report.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Value {
    pub x: f64,
    pub unit: Unit,
}

impl Value {
    pub fn new(x: f64, unit: Unit) -> Value {
        Value { x, unit }
    }

    /// ASCII cell rendering (pure function of `(x, unit)`).
    pub fn fmt(&self) -> String {
        self.unit.fmt(self.x)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![("v", Json::Num(self.x)), ("unit", Json::Str(self.unit.name().into()))])
    }

    pub fn from_json(j: &Json) -> Result<Value, JsonError> {
        let x = j
            .req("v")?
            .as_f64()
            .ok_or_else(|| JsonError("value 'v' must be a number".into()))?;
        let tag = j
            .req("unit")?
            .as_str()
            .ok_or_else(|| JsonError("value 'unit' must be a string".into()))?;
        let unit =
            Unit::parse(tag).ok_or_else(|| JsonError(format!("unknown unit tag '{tag}'")))?;
        Ok(Value { x, unit })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_tags_roundtrip() {
        for u in ALL_UNITS {
            assert_eq!(Unit::parse(u.name()), Some(u), "{u:?}");
        }
        assert_eq!(Unit::parse("furlongs"), None);
    }

    #[test]
    fn canonical_formats() {
        assert_eq!(Value::new(429.3, Unit::Tflops).fmt(), "429");
        assert_eq!(Value::new(1.466, Unit::Ratio).fmt(), "1.47x");
        assert_eq!(Value::new(0.642, Unit::Percent).fmt(), "64.2%");
        assert_eq!(Value::new(4.5, Unit::Pp).fmt(), "+4.5");
        assert_eq!(Value::new(-2.25, Unit::Pp).fmt(), "-2.2");
        assert_eq!(Value::new(64.0, Unit::Count).fmt(), "64");
        assert_eq!(Value::new(33554432.0, Unit::Bytes).fmt(), "32.0MiB");
    }

    #[test]
    fn polarity_covers_every_unit() {
        assert_eq!(Unit::TokPerSec.polarity(), Polarity::HigherIsBetter);
        assert_eq!(Unit::Seconds.polarity(), Polarity::LowerIsBetter);
        assert_eq!(Unit::Count.polarity(), Polarity::Neutral);
        // Every unit maps without panicking (match is exhaustive, but pin
        // the heuristic split so a new unit makes this list explicit).
        let (mut hi, mut lo, mut neutral) = (0, 0, 0);
        for u in ALL_UNITS {
            match u.polarity() {
                Polarity::HigherIsBetter => hi += 1,
                Polarity::LowerIsBetter => lo += 1,
                Polarity::Neutral => neutral += 1,
            }
        }
        assert_eq!((hi, lo, neutral), (10, 5, 6));
    }

    #[test]
    fn json_roundtrip_is_exact() {
        for v in [
            Value::new(429.31415926, Unit::Tflops),
            Value::new(0.993, Unit::Percent),
            Value::new(-7.25e-3, Unit::Seconds),
            Value::new(8192.0, Unit::Count),
        ] {
            let j = Json::parse(&v.to_json().dump()).unwrap();
            let back = Value::from_json(&j).unwrap();
            assert_eq!(back, v, "raw f64 must survive the JSON round-trip bit-exactly");
        }
    }

    #[test]
    fn from_json_rejects_malformed() {
        let bad = Json::parse(r#"{"v": 1.0, "unit": "parsecs"}"#).unwrap();
        assert!(Value::from_json(&bad).is_err());
        let missing = Json::parse(r#"{"v": 1.0}"#).unwrap();
        assert!(Value::from_json(&missing).is_err());
    }
}
