//! The L3 serving framework — a vLLM-style stack (the paper's §4.2 case
//! study) implemented as a real coordinator, layered as:
//!
//! ```text
//! Backend (SimBackend | PjrtBackend)     step costs: simulated or wall
//!     └── EngineCore<B, ClockSource>     ONE step loop: scheduler +
//!         │                              paged-KV bookkeeping (incl.
//!         │                              budgeted shared-prefix blocks
//!         │                              with eviction) + trace +
//!         │                              metrics/energy emission
//!         └── ClusterSim                 N replicas (homogeneous or a
//!             │                          mixed Gaudi-2/A100 fleet),
//!             │                          merged virtual-time event loop
//!             ├── Router                 admission + dispatch policies
//!             │                          (incl. cost-aware PrefixAffinity
//!             │                          over real block residency),
//!             │                          global queue cap, drain support
//!             └── Autoscaler             goodput-driven scale-up/drain
//!                                        against an SLO target
//! ```
//!
//! All block bookkeeping is identical in the simulated and real paths;
//! the cluster layer turns the per-device reproduction into a
//! deployment-scale simulator (`repro run cluster`, `repro run
//! cluster-sweep`, `repro run cache-sweep`).

pub mod autoscale;
pub mod block_table;
pub mod cluster;
pub mod engine;
pub mod kv_cache;
pub mod metrics;
pub mod real_engine;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod trace;
