//! The L3 serving framework — a vLLM-style stack (the paper's §4.2 case
//! study) implemented as a real coordinator, layered as:
//!
//! ```text
//! Backend (SimBackend | PjrtBackend)     step costs: simulated or wall
//!     └── EngineCore<B, ClockSource>     ONE step loop: scheduler +
//!         │                              paged-KV bookkeeping (incl.
//!         │                              budgeted shared-prefix blocks
//!         │                              with eviction) + trace +
//!         │                              metrics/energy emission
//!         └── ClusterSim                 N replicas, each a *device
//!             │                          group* (`ReplicaSpec { device,
//!             │                          tp }`: homogeneous, mixed
//!             │                          Gaudi-2/A100, or tp-wide
//!             │                          tensor-parallel groups),
//!             │                          indexed discrete-event core:
//!             │                          arrival heap + replica-wake heap
//!             │                          (O(log) dispatch), lazy arrival
//!             │                          streams at O(open requests) mem
//!             ├── Router                 admission + dispatch policies
//!             │                          (incl. cost-aware PrefixAffinity
//!             │                          over real block residency and
//!             │                          per-class QoS penalties),
//!             │                          global queue cap, per-class
//!             │                          admission control (shed
//!             │                          priority-0 under overload),
//!             │                          drain support
//!             ├── ChaosEngine            seeded FaultSchedule (crash/
//!             │                          restart, straggler slow-clock,
//!             │                          preemption storms) on a third
//!             │                          control-event heap + hedged
//!             │                          requests (first completion
//!             │                          wins, loser cancelled)
//!             └── Autoscaler             weighted per-class-attainment-
//!                                        driven scale-up/drain
//! ```
//!
//! Cross-cutting the stack, [`qos`] defines the traffic classes
//! ([`qos::TrafficClass`] / [`qos::ClassSet`]) every layer speaks:
//! requests carry a [`qos::ClassId`], the scheduler admits and preempts
//! by class priority, the router penalizes degraded per-class attainment,
//! metrics filter compliance per class, and the autoscaler controls on
//! weighted per-class attainment. A single default class reproduces the
//! legacy anonymous-SLO behavior bitwise (`repro run qos-sweep`).
//!
//! The cluster is advanced by an indexed discrete-event core
//! ([`cluster`]): pending arrivals in a min-heap keyed `(due, enqueue
//! seq)`, working replicas in a min-heap keyed by their own
//! `Engine::next_tick()`, with a pinned same-time ordering policy that
//! keeps legacy runs bitwise-equal to the pre-refactor scan loop (the
//! retained oracle behind the `sim-speed` benchmark and the equivalence
//! property tests). Workloads can attach lazily via
//! `ClusterSim::feed(workload::ArrivalStream)` — constant-rate, diurnal
//! or MMPP — so million-request days hold only the open requests in
//! memory (`repro run sim-speed` tracks events/sec and the memory bound).
//!
//! Failure behavior is first-class too ([`chaos`]): a seeded,
//! JSON-loadable `FaultSchedule` (replica crash/restart, straggler
//! slow-clock factors, preemption storms) expands onto a third
//! control-event min-heap in the same pinned-ordering event core, so
//! every degraded run is reproducible from its schedule + workload seed,
//! an empty schedule is bitwise-equal to the fault-free run, crashes
//! conserve requests (evacuated + requeued, prefix residency invalidated
//! not leaked), and the router's hedged requests + per-class admission
//! control bound tail latency under the injected faults (`repro run
//! chaos-sweep` checks recovery time, goodput dip and conservation).
//!
//! Replicas are *device groups* ([`crate::config::ReplicaSpec`]): a `tp`-wide
//! group shards each transformer block's GEMMs and KV heads across its
//! cards and pays two all-reduces per block through the unified
//! collective model (`sim::collective::CollectiveModel`), so KV block
//! budgets, prefix residency, router cost weights and energy are all
//! per-group. A tp=1 group is bitwise-equal to the legacy single-device
//! replica (`repro run tp-sweep` pins parity, monotone sub-linear
//! scaling, and the 70B HBM-feasibility frontier).
//!
//! All block bookkeeping is identical in the simulated and real paths;
//! the cluster layer turns the per-device reproduction into a
//! deployment-scale simulator (`repro run cluster`, `repro run
//! cluster-sweep`, `repro run cache-sweep`, `repro run qos-sweep`,
//! `repro run sim-speed`, `repro run chaos-sweep`, `repro run
//! tp-sweep`).

pub mod autoscale;
pub mod block_table;
pub mod chaos;
pub mod cluster;
pub mod engine;
pub mod kv_cache;
pub mod metrics;
pub mod qos;
pub mod real_engine;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod trace;

/// Fractional prefill saved when a request lands on the replica whose
/// prefix cache holds its group's shared blocks resident (vLLM
/// APC-style reuse). Shared between the router's routing score, the
/// substrate's resident prefix sizing (`request::Request::prefix_len`)
/// and `engine::SimBackend`'s prefill costing, so the router's bias and
/// the simulated saving cannot drift apart: a residency hit really does
/// prefill cheaper on the replica the router steered it to. Lives here
/// (not in `router`) because `request` and `engine` consume it too —
/// lower layers must not depend on the dispatch layer.
pub const PREFIX_HIT_DISCOUNT: f64 = 0.4;
