//! The L3 serving framework — a vLLM-style engine (the paper's §4.2 case
//! study) implemented as a real coordinator: admission router, continuous
//! batcher, paged KV-cache block manager, BlockTable/BlockList layouts,
//! and pluggable execution backends (simulated devices or real PJRT
//! executables). All block bookkeeping is identical in both paths.

pub mod block_table;
pub mod engine;
pub mod kv_cache;
pub mod metrics;
pub mod real_engine;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod trace;
