//! Request router: admission control and dispatch across engine replicas
//! (the front door of the serving deployment, vllm-project/router-style).
//!
//! Policies: round-robin, least-loaded (by queued prompt tokens), and
//! session-affinity hashing. The router also enforces a global queue cap,
//! returning backpressure errors instead of unbounded queueing.

use crate::serving::request::Request;

/// Dispatch policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    LeastLoaded,
    /// Hash request id (session affinity for prefix caching).
    Affinity,
}

impl RoutePolicy {
    pub const ALL: [RoutePolicy; 3] =
        [RoutePolicy::RoundRobin, RoutePolicy::LeastLoaded, RoutePolicy::Affinity];

    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round_robin",
            RoutePolicy::LeastLoaded => "least_loaded",
            RoutePolicy::Affinity => "affinity",
        }
    }

    /// Parse a config-file name (see `ServingConfig::from_json`).
    pub fn parse(s: &str) -> Option<RoutePolicy> {
        match s {
            "round_robin" | "rr" => Some(RoutePolicy::RoundRobin),
            "least_loaded" | "ll" => Some(RoutePolicy::LeastLoaded),
            "affinity" => Some(RoutePolicy::Affinity),
            _ => None,
        }
    }
}

/// Router over `n` engine replicas. The router does not own the engines;
/// it assigns requests to replica indices so deployments can pump each
/// replica on its own thread.
#[derive(Debug)]
pub struct Router {
    policy: RoutePolicy,
    replicas: usize,
    rr_next: usize,
    /// Outstanding load per replica (prompt+output tokens, decremented by
    /// `complete`).
    load: Vec<u64>,
    queued: usize,
    max_queued: usize,
}

/// Backpressure error.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueueFull;

impl Router {
    pub fn new(policy: RoutePolicy, replicas: usize, max_queued: usize) -> Router {
        assert!(replicas > 0);
        Router { policy, replicas, rr_next: 0, load: vec![0; replicas], queued: 0, max_queued }
    }

    pub fn queued(&self) -> usize {
        self.queued
    }

    pub fn load_of(&self, replica: usize) -> u64 {
        self.load[replica]
    }

    /// Route a request; returns the replica index.
    pub fn route(&mut self, req: &Request) -> Result<usize, QueueFull> {
        if self.queued >= self.max_queued {
            return Err(QueueFull);
        }
        let idx = match self.policy {
            RoutePolicy::RoundRobin => {
                let i = self.rr_next;
                self.rr_next = (self.rr_next + 1) % self.replicas;
                i
            }
            RoutePolicy::LeastLoaded => self
                .load
                .iter()
                .enumerate()
                .min_by_key(|(_, l)| **l)
                .map(|(i, _)| i)
                .unwrap(),
            RoutePolicy::Affinity => {
                // Fibonacci hash of the request id.
                (req.id.wrapping_mul(0x9E3779B97F4A7C15) >> 32) as usize % self.replicas
            }
        };
        self.load[idx] += (req.prompt_len + req.max_new_tokens) as u64;
        self.queued += 1;
        Ok(idx)
    }

    /// Mark a request complete on its replica.
    pub fn complete(&mut self, replica: usize, req: &Request) {
        let work = (req.prompt_len + req.max_new_tokens) as u64;
        self.load[replica] = self.load[replica].saturating_sub(work);
        self.queued = self.queued.saturating_sub(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, tokens: usize) -> Request {
        Request::new(id, tokens, 10, 0.0)
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(RoutePolicy::RoundRobin, 3, 100);
        let idx: Vec<usize> = (0..6).map(|i| r.route(&req(i, 10)).unwrap()).collect();
        assert_eq!(idx, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_balances_uneven_work() {
        let mut r = Router::new(RoutePolicy::LeastLoaded, 2, 100);
        let a = r.route(&req(0, 1000)).unwrap();
        let b = r.route(&req(1, 10)).unwrap();
        let c = r.route(&req(2, 10)).unwrap();
        assert_ne!(a, b);
        // Third goes to the lighter replica (b's).
        assert_eq!(b, c);
    }

    #[test]
    fn affinity_is_stable() {
        let mut r = Router::new(RoutePolicy::Affinity, 4, 100);
        let i1 = r.route(&req(42, 10)).unwrap();
        r.complete(i1, &req(42, 10));
        let i2 = r.route(&req(42, 10)).unwrap();
        assert_eq!(i1, i2);
    }

    #[test]
    fn backpressure_when_full() {
        let mut r = Router::new(RoutePolicy::RoundRobin, 1, 2);
        r.route(&req(0, 10)).unwrap();
        r.route(&req(1, 10)).unwrap();
        assert_eq!(r.route(&req(2, 10)), Err(QueueFull));
        r.complete(0, &req(0, 10));
        assert!(r.route(&req(2, 10)).is_ok());
    }

    #[test]
    fn policy_names_roundtrip() {
        for p in RoutePolicy::ALL {
            assert_eq!(RoutePolicy::parse(p.name()), Some(p));
        }
        assert_eq!(RoutePolicy::parse("rr"), Some(RoutePolicy::RoundRobin));
        assert_eq!(RoutePolicy::parse("nope"), None);
    }

    #[test]
    fn load_accounting_roundtrip() {
        let mut r = Router::new(RoutePolicy::RoundRobin, 2, 10);
        let q = req(0, 100);
        let i = r.route(&q).unwrap();
        assert_eq!(r.load_of(i), 110);
        r.complete(i, &q);
        assert_eq!(r.load_of(i), 0);
        assert_eq!(r.queued(), 0);
    }
}
