//! Request router: admission control and dispatch across engine replicas
//! (the front door of the serving deployment, vllm-project/router-style).
//!
//! Policies: round-robin, least-loaded (by queued prompt tokens),
//! session-affinity hashing, and cost-aware prefix affinity
//! (`PrefixAffinity`): route on prefix-cache *residency* and per-replica
//! decode cost, which is what a heterogeneous Gaudi-2/A100 fleet needs —
//! the two devices' relative throughput shifts with batch and sequence
//! shape, so a warm prefix on a slower replica can still beat a cold
//! fast one. Residency is supplied by the caller as an oracle
//! (`route_resident`): `ClusterSim` answers it from each replica's paged
//! KV-cache block manager, so the router scores blocks that actually
//! survived eviction rather than guessing from the last writer.
//!
//! QoS scoring (`serving::qos`): the deployment feeds per-completion
//! outcomes back ([`Router::record_outcome`]) into a windowed EWMA of
//! per-replica **per-class** SLO attainment, and the scored policies
//! (least-loaded, prefix-affinity) multiply in a penalty that steers
//! *high-priority* traffic away from replicas whose recent attainment
//! for that class is degraded. The penalty scales with class priority,
//! so priority-0 classes — including the single default class — are
//! never moved by it: routing for legacy configs is bit-identical.
//!
//! The router also enforces a global queue cap
//! (backpressure instead of unbounded queueing) and supports draining:
//! a drained replica finishes its in-flight work but receives no new
//! requests, which is how the autoscaler (`serving::autoscale`) removes
//! capacity without dropping requests.

use crate::serving::qos::{ClassId, ClassSet};
use crate::serving::request::Request;
use crate::util::fasthash::FastMap;

// Hoisted to `serving::PREFIX_HIT_DISCOUNT` so the request/engine layers
// no longer depend on the dispatch layer; re-exported here for the
// router-centric call sites that read it as part of the routing score.
pub use crate::serving::PREFIX_HIT_DISCOUNT;

/// Strength of the per-class QoS routing penalty: a replica whose recent
/// attainment for the request's class is `a` scores
/// `1 + QOS_ROUTE_PENALTY x priority x (1 - a)` times worse. Priority 0
/// (the default class) makes the factor exactly 1.0 — legacy routing.
pub const QOS_ROUTE_PENALTY: f64 = 2.0;

/// EWMA smoothing of the per-(replica, class) attainment estimate: each
/// completion moves the estimate by this fraction toward 1 (met) or 0
/// (missed). ~20 completions of memory.
pub const QOS_EWMA_ALPHA: f64 = 0.1;

/// Dispatch policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    LeastLoaded,
    /// Hash request id (session affinity for prefix caching).
    Affinity,
    /// Cost-aware prefix affinity: minimize expected cost =
    /// per-replica decode cost x outstanding load, discounted on the
    /// replica whose KV cache holds the request's prefix group resident.
    PrefixAffinity,
}

impl RoutePolicy {
    pub const ALL: [RoutePolicy; 4] = [
        RoutePolicy::RoundRobin,
        RoutePolicy::LeastLoaded,
        RoutePolicy::Affinity,
        RoutePolicy::PrefixAffinity,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round_robin",
            RoutePolicy::LeastLoaded => "least_loaded",
            RoutePolicy::Affinity => "affinity",
            RoutePolicy::PrefixAffinity => "prefix_affinity",
        }
    }

    /// Parse a config-file name (see `ServingConfig::from_json`).
    pub fn parse(s: &str) -> Option<RoutePolicy> {
        match s {
            "round_robin" | "rr" => Some(RoutePolicy::RoundRobin),
            "least_loaded" | "ll" => Some(RoutePolicy::LeastLoaded),
            "affinity" => Some(RoutePolicy::Affinity),
            "prefix_affinity" | "pa" => Some(RoutePolicy::PrefixAffinity),
            _ => None,
        }
    }
}

/// Router over `n` engine replicas. The router does not own the engines;
/// it assigns requests to replica indices so deployments can pump each
/// replica on its own thread.
#[derive(Debug)]
pub struct Router {
    policy: RoutePolicy,
    rr_next: usize,
    /// Outstanding load per replica (prompt+output tokens, decremented by
    /// `complete`).
    load: Vec<u64>,
    /// Relative per-token decode cost of each replica (any consistent
    /// scale; `ClusterSim` derives it from the device cost model). Uniform
    /// 1.0 for homogeneous fleets.
    cost: Vec<f64>,
    /// Drained replicas receive no new requests (autoscaler scale-down).
    drained: Vec<bool>,
    queued: usize,
    max_queued: usize,
    /// Per-class admission control (`serving::chaos` graceful
    /// degradation): once the global queue reaches this fraction of
    /// `max_queued`, priority-0 background requests are shed at the door
    /// instead of queueing behind interactive traffic. 1.0 disables the
    /// mechanism (shedding at the cap is indistinguishable from the
    /// `QueueFull` backpressure that already fires there).
    shed_threshold: f64,
    /// Declared traffic classes (priorities drive the QoS penalty). The
    /// default single class keeps every penalty factor at exactly 1.0.
    classes: ClassSet,
    /// EWMA per-class SLO attainment per replica (absent = 1.0, i.e.
    /// healthy until evidence says otherwise), fed by `record_outcome`.
    qos_att: Vec<FastMap<ClassId, f64>>,
}

/// Backpressure error.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueueFull;

impl Router {
    pub fn new(policy: RoutePolicy, replicas: usize, max_queued: usize) -> Router {
        Router::with_costs(policy, vec![1.0; replicas], max_queued)
    }

    /// Heterogeneous-fleet constructor: one decode-cost weight per replica.
    pub fn with_costs(policy: RoutePolicy, costs: Vec<f64>, max_queued: usize) -> Router {
        assert!(!costs.is_empty(), "router needs at least one replica");
        assert!(costs.iter().all(|c| c.is_finite() && *c > 0.0), "costs must be positive");
        let n = costs.len();
        Router {
            policy,
            rr_next: 0,
            load: vec![0; n],
            cost: costs,
            drained: vec![false; n],
            queued: 0,
            max_queued,
            shed_threshold: 1.0,
            classes: ClassSet::default(),
            qos_att: vec![FastMap::default(); n],
        }
    }

    /// Declare the deployment's traffic classes (builder-style) so the
    /// QoS penalty knows each request class's priority. Without this the
    /// router assumes the single default class (priority 0 — no penalty).
    pub fn with_classes(mut self, classes: ClassSet) -> Router {
        self.classes = classes;
        self
    }

    /// Enable load shedding (builder-style): priority-0 requests are
    /// rejected once the queue reaches `threshold x max_queued`. Must be
    /// in `(0, 1]`; 1.0 keeps shedding disabled.
    pub fn with_shed_threshold(mut self, threshold: f64) -> Router {
        assert!(
            threshold.is_finite() && threshold > 0.0 && threshold <= 1.0,
            "shed threshold must be in (0, 1], got {threshold}"
        );
        self.shed_threshold = threshold;
        self
    }

    pub fn num_replicas(&self) -> usize {
        self.load.len()
    }

    pub fn num_active(&self) -> usize {
        self.drained.iter().filter(|d| !**d).count()
    }

    pub fn queued(&self) -> usize {
        self.queued
    }

    pub fn load_of(&self, replica: usize) -> u64 {
        self.load[replica]
    }

    pub fn cost_of(&self, replica: usize) -> f64 {
        self.cost[replica]
    }

    /// Reweight a replica's decode cost in place. `serving::chaos` uses
    /// this to make a straggler's slowdown visible to the cost-aware
    /// policies for the duration of its fault window (and to restore the
    /// base weight afterwards).
    pub fn set_cost(&mut self, replica: usize, cost: f64) {
        assert!(cost.is_finite() && cost > 0.0, "cost must be positive");
        self.cost[replica] = cost;
    }

    pub fn is_drained(&self, replica: usize) -> bool {
        self.drained[replica]
    }

    /// Register a new replica (autoscaler scale-up); returns its index.
    pub fn add_replica(&mut self, cost: f64) -> usize {
        assert!(cost.is_finite() && cost > 0.0, "cost must be positive");
        self.load.push(0);
        self.cost.push(cost);
        self.drained.push(false);
        self.qos_att.push(FastMap::default());
        self.load.len() - 1
    }

    /// Feed back one completion outcome: did the request of `class` on
    /// `replica` meet its class SLO? Updates the windowed per-(replica,
    /// class) attainment estimate the QoS penalty scores with.
    pub fn record_outcome(&mut self, replica: usize, class: ClassId, met: bool) {
        let a = self.qos_att[replica].entry(class).or_insert(1.0);
        *a = (1.0 - QOS_EWMA_ALPHA) * *a + QOS_EWMA_ALPHA * if met { 1.0 } else { 0.0 };
    }

    /// Recent EWMA attainment of `class` on `replica` (1.0 until the
    /// first recorded outcome).
    pub fn class_attainment(&self, replica: usize, class: ClassId) -> f64 {
        self.qos_att[replica].get(&class).copied().unwrap_or(1.0)
    }

    /// QoS score multiplier for placing `req` on `replica`: 1.0 for
    /// healthy replicas and for priority-0 classes (hence exactly 1.0 —
    /// legacy routing — for every single-default-class deployment),
    /// growing with the request class's priority and how degraded the
    /// replica's recent attainment for that class is.
    fn qos_factor(&self, replica: usize, req: &Request) -> f64 {
        let priority = self.classes.priority_of(req.class_id) as f64;
        if priority == 0.0 {
            return 1.0;
        }
        1.0 + QOS_ROUTE_PENALTY * priority * (1.0 - self.class_attainment(replica, req.class_id))
    }

    /// Stop routing new requests to `replica`; its in-flight work drains
    /// naturally. The last active replica cannot be drained — the fleet
    /// must always be able to accept work.
    pub fn drain(&mut self, replica: usize) {
        assert!(
            self.drained[replica] || self.num_active() > 1,
            "cannot drain the last active replica"
        );
        self.drained[replica] = true;
    }

    /// Return a drained replica to service (autoscaler scale-up reuse).
    pub fn undrain(&mut self, replica: usize) {
        self.drained[replica] = false;
    }

    /// Active (non-drained) replica indices, ascending.
    fn active(&self) -> impl Iterator<Item = usize> + '_ {
        (0..self.load.len()).filter(|&i| !self.drained[i])
    }

    /// Route a request with no residency information (`PrefixAffinity`
    /// then scores every replica as cold). Deployments that track real
    /// prefix residency use [`route_resident`](Self::route_resident).
    pub fn route(&mut self, req: &Request) -> Result<usize, QueueFull> {
        self.route_resident(req, |_, _| false)
    }

    /// Route a request; returns the replica index. `resident(replica,
    /// prefix_id)` answers whether that replica's KV cache currently
    /// holds the prefix group's shared blocks — `ClusterSim` wires it to
    /// `KvBlockManager::prefix_resident`, so `PrefixAffinity` chases only
    /// savings that survived eviction.
    pub fn route_resident(
        &mut self,
        req: &Request,
        resident: impl Fn(usize, u64) -> bool,
    ) -> Result<usize, QueueFull> {
        if self.queued >= self.max_queued {
            return Err(QueueFull);
        }
        let idx = match self.policy {
            RoutePolicy::RoundRobin => {
                // First active replica at or after the cursor (wrapping).
                let n = self.load.len();
                let i = (0..n)
                    .map(|k| (self.rr_next + k) % n)
                    .find(|&i| !self.drained[i])
                    .expect("at least one active replica");
                self.rr_next = (i + 1) % n;
                i
            }
            RoutePolicy::LeastLoaded => {
                // Effective load: outstanding work scaled by the QoS
                // penalty (the `+ work` term keeps the penalty effective
                // on idle replicas). With the factor pinned at 1.0 —
                // priority-0 classes, or no recorded degradation — the
                // argmin is exactly the legacy least-loaded pick.
                let work = (req.prompt_len + req.max_new_tokens) as u64;
                self.active()
                    .min_by(|&a, &b| {
                        let sa = (self.load[a] + work) as f64 * self.qos_factor(a, req);
                        let sb = (self.load[b] + work) as f64 * self.qos_factor(b, req);
                        sa.total_cmp(&sb)
                    })
                    .expect("at least one active replica")
            }
            RoutePolicy::Affinity => {
                // Fibonacci hash of the request id over the active set
                // (nth-active selection, no per-request allocation).
                let h = (req.id.wrapping_mul(0x9E3779B97F4A7C15) >> 32) as usize;
                self.active()
                    .nth(h % self.num_active())
                    .expect("at least one active replica")
            }
            RoutePolicy::PrefixAffinity => self.prefix_affinity_pick(req, &resident),
        };
        debug_assert!(!self.drained[idx], "routed to a drained replica");
        self.load[idx] += (req.prompt_len + req.max_new_tokens) as u64;
        self.queued += 1;
        Ok(idx)
    }

    /// Expected-cost minimizer: `cost[r] x (outstanding + this request)`,
    /// discounted by `PREFIX_HIT_DISCOUNT` on replicas whose KV cache
    /// holds the request's prefix group resident and penalized by the
    /// per-class QoS factor (degraded recent attainment for this class
    /// repels its high-priority traffic). Ties break to the lowest
    /// index, so routing is deterministic.
    fn prefix_affinity_pick(&self, req: &Request, resident: &impl Fn(usize, u64) -> bool) -> usize {
        let work = (req.prompt_len + req.max_new_tokens) as u64;
        let mut best: Option<(usize, f64)> = None;
        for i in self.active() {
            let hit = req.prefix_id.is_some_and(|p| resident(i, p));
            let factor = if hit { 1.0 - PREFIX_HIT_DISCOUNT } else { 1.0 };
            let score =
                self.cost[i] * (self.load[i] + work) as f64 * factor * self.qos_factor(i, req);
            if best.is_none_or(|(_, s)| score < s) {
                best = Some((i, score));
            }
        }
        best.expect("at least one active replica").0
    }

    /// Should this request be shed at the door instead of queued?
    /// Fires only for priority-0 classes once the queue is at or past
    /// `shed_threshold x max_queued` — overload protection that keeps
    /// interactive tiers queueable while background is turned away.
    /// Callers check this *before* `route_resident` so a shed request
    /// never touches load accounting.
    pub fn should_shed(&self, req: &Request) -> bool {
        self.shed_threshold < 1.0
            && self.classes.priority_of(req.class_id) == 0
            && (self.queued as f64) >= self.shed_threshold * self.max_queued as f64
    }

    /// Route a hedge copy: like [`route_resident`](Self::route_resident)
    /// but never places the copy on `avoid` (the primary's replica — a
    /// hedge against the very replica it is stuck on would be useless,
    /// and keeping the copies apart is what makes "both finish in one
    /// step" impossible). Returns `Err(QueueFull)` if the queue is at
    /// the cap or no other active replica exists.
    pub fn route_hedge(
        &mut self,
        req: &Request,
        avoid: usize,
        resident: impl Fn(usize, u64) -> bool,
    ) -> Result<usize, QueueFull> {
        let was_drained = self.drained[avoid];
        self.drained[avoid] = true;
        let out = if self.num_active() == 0 {
            Err(QueueFull)
        } else {
            self.route_resident(req, resident)
        };
        self.drained[avoid] = was_drained;
        debug_assert!(out != Ok(avoid), "hedge landed on the avoided replica");
        out
    }

    /// Mark a request complete on its replica.
    pub fn complete(&mut self, replica: usize, req: &Request) {
        let work = (req.prompt_len + req.max_new_tokens) as u64;
        self.load[replica] = self.load[replica].saturating_sub(work);
        self.queued = self.queued.saturating_sub(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, tokens: usize) -> Request {
        Request::new(id, tokens, 10, 0.0)
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(RoutePolicy::RoundRobin, 3, 100);
        let idx: Vec<usize> = (0..6).map(|i| r.route(&req(i, 10)).unwrap()).collect();
        assert_eq!(idx, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn round_robin_skips_drained() {
        let mut r = Router::new(RoutePolicy::RoundRobin, 3, 100);
        r.drain(1);
        let idx: Vec<usize> = (0..4).map(|i| r.route(&req(i, 10)).unwrap()).collect();
        assert_eq!(idx, vec![0, 2, 0, 2]);
        r.undrain(1);
        assert_eq!(r.route(&req(9, 10)).unwrap(), 1);
    }

    #[test]
    fn least_loaded_balances_uneven_work() {
        let mut r = Router::new(RoutePolicy::LeastLoaded, 2, 100);
        let a = r.route(&req(0, 1000)).unwrap();
        let b = r.route(&req(1, 10)).unwrap();
        let c = r.route(&req(2, 10)).unwrap();
        assert_ne!(a, b);
        // Third goes to the lighter replica (b's).
        assert_eq!(b, c);
    }

    #[test]
    fn affinity_is_stable() {
        let mut r = Router::new(RoutePolicy::Affinity, 4, 100);
        let i1 = r.route(&req(42, 10)).unwrap();
        r.complete(i1, &req(42, 10));
        let i2 = r.route(&req(42, 10)).unwrap();
        assert_eq!(i1, i2);
    }

    #[test]
    fn backpressure_when_full() {
        let mut r = Router::new(RoutePolicy::RoundRobin, 1, 2);
        r.route(&req(0, 10)).unwrap();
        r.route(&req(1, 10)).unwrap();
        assert_eq!(r.route(&req(2, 10)), Err(QueueFull));
        r.complete(0, &req(0, 10));
        assert!(r.route(&req(2, 10)).is_ok());
    }

    #[test]
    fn policy_names_roundtrip() {
        for p in RoutePolicy::ALL {
            assert_eq!(RoutePolicy::parse(p.name()), Some(p));
        }
        assert_eq!(RoutePolicy::parse("rr"), Some(RoutePolicy::RoundRobin));
        assert_eq!(RoutePolicy::parse("pa"), Some(RoutePolicy::PrefixAffinity));
        assert_eq!(RoutePolicy::parse("nope"), None);
    }

    #[test]
    fn load_accounting_roundtrip() {
        let mut r = Router::new(RoutePolicy::RoundRobin, 2, 10);
        let q = req(0, 100);
        let i = r.route(&q).unwrap();
        assert_eq!(r.load_of(i), 110);
        r.complete(i, &q);
        assert_eq!(r.load_of(i), 0);
        assert_eq!(r.queued(), 0);
    }

    #[test]
    fn prefix_affinity_prefers_cheap_idle_replica() {
        // Replica 0 is 2x the cost of replica 1: with equal load, traffic
        // without a warm prefix goes to the cheap one.
        let mut r = Router::with_costs(RoutePolicy::PrefixAffinity, vec![2.0, 1.0], 100);
        assert_eq!(r.route(&req(0, 10)).unwrap(), 1);
    }

    #[test]
    fn prefix_affinity_follows_residency() {
        let mut r = Router::new(RoutePolicy::PrefixAffinity, 2, 100);
        // Group 7's blocks are resident on replica 1 only.
        let resident = |i: usize, p: u64| i == 1 && p == 7;
        // Balance the load first so residency is the deciding factor.
        assert_eq!(r.route(&req(0, 100)).unwrap(), 0, "ties break to the lowest index");
        assert_eq!(r.route(&req(1, 100)).unwrap(), 1, "then to the lighter replica");
        // With equal load, the group follows its resident blocks...
        assert_eq!(r.route_resident(&req(2, 100).with_prefix(7), resident).unwrap(), 1);
        // ...a group resident nowhere balances to the lighter replica...
        assert_eq!(r.route_resident(&req(3, 100).with_prefix(8), resident).unwrap(), 0);
        // ...and with no oracle, PrefixAffinity is pure cost x load — the
        // router keeps no last-writer warmth bookkeeping of its own.
        assert_eq!(r.route(&req(4, 100).with_prefix(7)).unwrap(), 0);
    }

    #[test]
    fn prefix_affinity_cost_beats_weak_warmth() {
        // The 40% prefix discount cannot make up a 10x decode-cost gap:
        // even with the group resident on the expensive replica, traffic
        // routes to an idle cheap one.
        let mut r = Router::with_costs(RoutePolicy::PrefixAffinity, vec![1.0, 10.0], 100);
        let resident = |i: usize, p: u64| i == 1 && p == 3;
        // Bury the cheap replica: residency on the expensive one wins.
        let big: Vec<Request> = (0..4).map(|i| req(i, 1000)).collect();
        let placed: Vec<usize> =
            big.iter().map(|q| r.route_resident(q, resident).unwrap()).collect();
        assert!(placed.iter().all(|&i| i == 0), "bulk load fills the cheap replica");
        assert_eq!(
            r.route_resident(&req(10, 10).with_prefix(3), resident).unwrap(),
            1,
            "resident on expensive"
        );
        // Clear the cheap replica's queue.
        for (idx, q) in placed.iter().zip(&big) {
            r.complete(*idx, q);
        }
        // Residency (x0.6) on a 10x-cost replica loses to the idle cheap one.
        assert_eq!(r.route_resident(&req(11, 10).with_prefix(3), resident).unwrap(), 0);
    }

    #[test]
    fn drain_never_receives_new_work() {
        for policy in RoutePolicy::ALL {
            let mut r = Router::new(policy, 3, 1000);
            r.drain(2);
            for i in 0..30 {
                let idx = r.route(&req(i, 10).with_prefix(i % 4)).unwrap();
                assert_ne!(idx, 2, "{policy:?} routed to a drained replica");
            }
        }
    }

    #[test]
    #[should_panic(expected = "last active replica")]
    fn cannot_drain_last_active() {
        let mut r = Router::new(RoutePolicy::RoundRobin, 2, 10);
        r.drain(0);
        r.drain(1);
    }

    #[test]
    fn qos_penalty_steers_high_priority_off_degraded_replicas() {
        use crate::serving::qos::ClassSet;
        // Two equal replicas, three-tier classes (interactive = class 0,
        // priority 2). Replica 0 repeatedly misses interactive SLOs.
        let mut r = Router::new(RoutePolicy::LeastLoaded, 2, 1000)
            .with_classes(ClassSet::three_tier());
        for _ in 0..30 {
            r.record_outcome(0, 0, false);
        }
        assert!(r.class_attainment(0, 0) < 0.1);
        assert_eq!(r.class_attainment(1, 0), 1.0);
        // Interactive traffic avoids the degraded replica even though
        // ties would otherwise go to index 0...
        assert_eq!(r.route(&req(0, 100).with_class(0)).unwrap(), 1);
        // ...while background (priority 0) still balances normally: the
        // penalty never moves priority-0 traffic.
        assert_eq!(r.route(&req(1, 100).with_class(2)).unwrap(), 0);
    }

    #[test]
    fn default_class_routing_is_unmoved_by_feedback() {
        // Single default class (priority 0): even heavy recorded
        // degradation leaves every routing decision exactly as legacy.
        for policy in [RoutePolicy::LeastLoaded, RoutePolicy::PrefixAffinity] {
            let mut a = Router::new(policy, 3, 1000);
            let mut b = Router::new(policy, 3, 1000);
            for _ in 0..50 {
                b.record_outcome(1, 0, false);
            }
            for i in 0..30 {
                let q = req(i, 64 + (i as usize * 37) % 500).with_prefix(i % 4);
                assert_eq!(a.route(&q).unwrap(), b.route(&q).unwrap(), "{policy:?} id {i}");
            }
        }
    }

    #[test]
    fn qos_penalty_composes_with_prefix_affinity() {
        use crate::serving::qos::ClassSet;
        let mut r = Router::new(RoutePolicy::PrefixAffinity, 2, 1000)
            .with_classes(ClassSet::three_tier());
        let resident = |i: usize, p: u64| i == 0 && p == 7;
        // Warm prefix on replica 0 wins while both replicas are healthy...
        let warm = req(0, 100).with_prefix(7).with_class(0);
        assert_eq!(r.route_resident(&warm, resident).unwrap(), 0);
        r.complete(0, &warm);
        // ...but a badly degraded interactive attainment on replica 0
        // outweighs the 40% prefix discount (factor 1 + 2*2*0.9 = 4.6 >
        // 1/0.6).
        for _ in 0..60 {
            r.record_outcome(0, 0, false);
        }
        let again = req(1, 100).with_prefix(7).with_class(0);
        assert_eq!(r.route_resident(&again, resident).unwrap(), 1);
    }

    #[test]
    fn recovery_restores_routing() {
        use crate::serving::qos::ClassSet;
        let mut r = Router::new(RoutePolicy::LeastLoaded, 2, 1000)
            .with_classes(ClassSet::three_tier());
        for _ in 0..30 {
            r.record_outcome(0, 0, false);
        }
        // While degraded, even a loaded healthy replica beats replica 0.
        let filler = req(9, 300).with_class(0);
        assert_eq!(r.route(&filler).unwrap(), 1);
        // A healthy streak pulls the EWMA back toward 1; the residual
        // epsilon penalty is then dominated by real load differences, so
        // interactive traffic returns to the recovered replica.
        for _ in 0..80 {
            r.record_outcome(0, 0, true);
        }
        assert!(r.class_attainment(0, 0) > 0.99);
        assert_eq!(r.route(&req(0, 100).with_class(0)).unwrap(), 0);
    }

    #[test]
    fn add_replica_grows_the_fleet() {
        let mut r = Router::with_costs(RoutePolicy::LeastLoaded, vec![1.0], 100);
        assert_eq!(r.num_replicas(), 1);
        let idx = r.add_replica(2.0);
        assert_eq!(idx, 1);
        assert_eq!(r.num_replicas(), 2);
        assert_eq!(r.num_active(), 2);
        assert_eq!(r.cost_of(1), 2.0);
        // New replica is routable immediately.
        r.route(&req(0, 1000)).unwrap();
        assert_eq!(r.route(&req(1, 10)).unwrap(), 1);
    }

    #[test]
    fn set_cost_reweights_prefix_affinity() {
        let mut r = Router::with_costs(RoutePolicy::PrefixAffinity, vec![1.0, 1.0], 100);
        // Tie breaks to index 0 while costs are uniform...
        assert_eq!(r.route(&req(0, 10)).unwrap(), 0);
        r.complete(0, &req(0, 10));
        // ...a straggling replica 0 (cost x4) repels fresh traffic...
        r.set_cost(0, 4.0);
        assert_eq!(r.route(&req(1, 10)).unwrap(), 1);
        r.complete(1, &req(1, 10));
        // ...and restoring the base weight restores the legacy pick.
        r.set_cost(0, 1.0);
        assert_eq!(r.route(&req(2, 10)).unwrap(), 0);
    }

    #[test]
    fn shedding_rejects_only_background_under_overload() {
        use crate::serving::qos::ClassSet;
        let mut r = Router::new(RoutePolicy::LeastLoaded, 2, 10)
            .with_classes(ClassSet::three_tier())
            .with_shed_threshold(0.5);
        let interactive = |id| req(id, 10).with_class(0);
        let background = |id| req(id, 10).with_class(2);
        assert!(!r.should_shed(&background(0)), "empty queue sheds nothing");
        for i in 0..5 {
            r.route(&interactive(i)).unwrap();
        }
        // Queue at the threshold: background is shed, interactive queues.
        assert!(r.should_shed(&background(100)));
        assert!(!r.should_shed(&interactive(101)));
        assert!(r.route(&interactive(101)).is_ok());
    }

    #[test]
    fn default_shed_threshold_never_sheds() {
        // Disabled (1.0): even a full queue answers false — the QueueFull
        // backpressure path owns that regime.
        let mut r = Router::new(RoutePolicy::RoundRobin, 1, 2);
        r.route(&req(0, 10)).unwrap();
        r.route(&req(1, 10)).unwrap();
        assert!(!r.should_shed(&req(2, 10)));
        assert_eq!(r.route(&req(2, 10)), Err(QueueFull));
    }

    #[test]
    fn route_hedge_avoids_the_primary_replica() {
        let mut r = Router::new(RoutePolicy::LeastLoaded, 3, 100);
        // Replica 0 is idle and would win least-loaded; hedging around it
        // must land elsewhere anyway.
        for i in 0..20 {
            let idx = r.route_hedge(&req(i, 10), 0, |_, _| false).unwrap();
            assert_ne!(idx, 0);
        }
        // A previously drained avoid target stays drained afterwards.
        r.drain(2);
        assert_ne!(r.route_hedge(&req(50, 10), 0, |_, _| false).unwrap(), 0);
        assert!(r.is_drained(2));
    }

    #[test]
    fn route_hedge_fails_with_no_alternative() {
        let mut r = Router::new(RoutePolicy::LeastLoaded, 2, 100);
        r.drain(1);
        assert_eq!(r.route_hedge(&req(0, 10), 0, |_, _| false), Err(QueueFull));
        assert!(!r.is_drained(0), "avoid target restored to active");
    }
}
