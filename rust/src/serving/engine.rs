//! The serving engine core: ONE discrete-event step loop driving the
//! scheduler against a pluggable `Backend`, parameterized by a
//! `ClockSource`. With `SimBackend` + `VirtualClock` the step durations
//! come from the device simulators and time is advanced analytically
//! (this is how Fig 17(d,e) is regenerated); with `PjrtBackend`
//! (`real_engine.rs`) + `WallClock` the same scheduler, block bookkeeping,
//! trace and metrics emission drive real HLO executables under the wall
//! clock. `serving::cluster` composes N cores into a data-parallel fleet.

use crate::config::{DeviceKind, ServingConfig};
use crate::models::llama::{self, LlamaConfig};
use crate::ops::attention::{self, PagedAttnImpl, PagedAttnWork};
use crate::serving::metrics::{MetricsCollector, RequestMetrics};
use crate::serving::request::{Phase, Request, RequestId};
use crate::serving::scheduler::{Scheduler, Step};
use crate::serving::trace::{Trace, TraceEvent, TraceStepKind};

/// One prompt handed to the backend for prefill.
#[derive(Debug, Clone, Copy)]
pub struct PrefillItem {
    pub id: RequestId,
    pub prompt_len: usize,
    /// Shared-prefix group of the request (None = no reusable prefix).
    pub prefix_id: Option<u64>,
    /// Whether the scheduler found the group's shared blocks *resident*
    /// in the paged KV cache when it admitted this sequence. `SimBackend`
    /// costs a resident-prefix prefill cheaper — the same discount
    /// `RoutePolicy::PrefixAffinity` routes on, now backed by real block
    /// residency instead of an ever-warm set.
    pub prefix_hit: bool,
}

/// A batch of decode work handed to the backend.
#[derive(Debug, Clone)]
pub struct DecodeWork {
    /// Sequences in the step, in decode order (parallel to `kv_lens`).
    pub ids: Vec<RequestId>,
    pub kv_lens: Vec<usize>,
    /// Padded table width in blocks × block_size (vLLM_base) — equals the
    /// longest sequence rounded up to a block.
    pub padded_len: usize,
    /// Zero-padding fraction of the BlockTable layout.
    pub padding_fraction: f64,
    pub use_block_list: bool,
}

/// Execution backend abstraction. Implementations return the step
/// duration in seconds — simulated for `SimBackend`, measured wall time
/// for `PjrtBackend`.
pub trait Backend {
    /// Process prompts; returns step duration in seconds.
    fn prefill(&mut self, batch: &[PrefillItem]) -> f64;
    /// One decode step; returns step duration in seconds.
    fn decode(&mut self, work: &DecodeWork) -> f64;
    /// Whether prefill itself emits each sequence's first token (real
    /// engines sample the prefill's last-position logits; the cost-model
    /// backend produces no tokens, so its first token lands on the first
    /// decode step).
    fn prefill_emits_first_token(&self) -> bool {
        false
    }
    /// A sequence finished: release any backend-side state, e.g. a PJRT
    /// batch slot.
    fn release(&mut self, _id: RequestId) {}
    /// A sequence was preempted (KV freed; the scheduler will re-prefill
    /// it later). Backends that cannot recompute must surface an error
    /// here rather than silently corrupting generation state.
    fn preempt(&mut self, id: RequestId) {
        self.release(id);
    }
    /// Recompute-cost weight for `EvictionPolicy::CostAware` prefix
    /// eviction (any consistent positive scale; the engine threads it
    /// into the scheduler's block manager at construction).
    fn prefix_recompute_weight(&self) -> f64 {
        1.0
    }
    /// Device power draw (watts) while executing a step of `kind` — the
    /// activity-based model of `sim::power` for simulated backends, 0 for
    /// backends that do not model energy. The engine accumulates
    /// `duration x draw` into `MetricsCollector::energy_j`.
    fn step_power_w(&self, _kind: TraceStepKind) -> f64 {
        0.0
    }
}

/// Source of engine time. The step loop is written once against this
/// trait; simulation jumps time analytically while the real engine lets
/// wall time pass on its own.
pub trait ClockSource {
    /// Current engine time in seconds.
    fn now(&self) -> f64;
    /// A step reported duration `dt`; virtual clocks add it, wall clocks
    /// ignore it (the time already passed while the backend ran).
    fn advance(&mut self, dt: f64);
    /// Idle until time `t` (never moves time backwards).
    fn wait_until(&mut self, t: f64);
}

/// Analytic simulation clock.
#[derive(Debug, Clone, Default)]
pub struct VirtualClock {
    t: f64,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock { t: 0.0 }
    }
}

impl ClockSource for VirtualClock {
    fn now(&self) -> f64 {
        self.t
    }

    fn advance(&mut self, dt: f64) {
        self.t += dt;
    }

    fn wait_until(&mut self, t: f64) {
        self.t = self.t.max(t);
    }
}

/// Wall clock anchored at an epoch (engine construction or run start).
#[derive(Debug, Clone)]
pub struct WallClock {
    start: std::time::Instant,
}

impl WallClock {
    pub fn new() -> WallClock {
        WallClock { start: std::time::Instant::now() }
    }

    /// Re-anchor the epoch at the present instant (run start).
    pub fn reset(&mut self) {
        self.start = std::time::Instant::now();
    }
}

impl Default for WallClock {
    fn default() -> Self {
        WallClock::new()
    }
}

impl ClockSource for WallClock {
    fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    fn advance(&mut self, _dt: f64) {
        // Wall time advanced by itself while the backend executed.
    }

    fn wait_until(&mut self, t: f64) {
        let now = self.now();
        if t > now {
            std::thread::sleep(std::time::Duration::from_secs_f64(t - now));
        }
    }
}

/// Number of slots in the decode-cost memo. Power of two so the hash
/// maps to a slot by masking; 256 comfortably covers the distinct batch
/// compositions a quiescent window cycles through (one per tick of the
/// longest burst between block-boundary crossings).
const DECODE_MEMO_SLOTS: usize = 256;

/// One decode-cost memo entry: the full costing inputs plus the cost.
struct DecodeMemoEntry {
    sig: u64,
    use_block_list: bool,
    padded_len: usize,
    kv_lens: Vec<usize>,
    cost: f64,
}

/// Direct-mapped decode-cost memo keyed by a batch-composition signature
/// (`util::fasthash` over the layout flag, padded table width and the
/// per-sequence KV lengths — the only inputs `SimBackend::decode` reads).
/// The signature picks the slot and quick-rejects; a hit is declared only
/// after the stored inputs compare *equal*, so a collision can never
/// return a wrong cost — it just overwrites the slot on store
/// (deterministic eviction, keeping runs independent of hash quality).
/// Entries hold the *raw* model cost: straggler dilation (`slow_factor`)
/// is applied by the engine outside the backend, so a slow-clock window
/// needs no invalidation here; any batch membership or length change
/// simply produces a different key.
struct DecodeMemo {
    slots: Vec<Option<DecodeMemoEntry>>,
    hits: u64,
    misses: u64,
}

impl DecodeMemo {
    fn new() -> DecodeMemo {
        DecodeMemo {
            slots: (0..DECODE_MEMO_SLOTS).map(|_| None).collect(),
            hits: 0,
            misses: 0,
        }
    }

    fn signature(work: &DecodeWork) -> u64 {
        use std::hash::Hasher;
        let mut h = crate::util::fasthash::FastHasher::default();
        h.write_u64(work.use_block_list as u64);
        h.write_usize(work.padded_len);
        for &kv in &work.kv_lens {
            h.write_usize(kv);
        }
        h.finish()
    }

    fn lookup(&mut self, sig: u64, work: &DecodeWork) -> Option<f64> {
        let entry = self.slots[sig as usize & (DECODE_MEMO_SLOTS - 1)].as_ref();
        if let Some(e) = entry {
            if e.sig == sig
                && e.use_block_list == work.use_block_list
                && e.padded_len == work.padded_len
                && e.kv_lens == work.kv_lens
            {
                self.hits += 1;
                return Some(e.cost);
            }
        }
        self.misses += 1;
        None
    }

    fn store(&mut self, sig: u64, work: &DecodeWork, cost: f64) {
        match &mut self.slots[sig as usize & (DECODE_MEMO_SLOTS - 1)] {
            Some(e) => {
                e.sig = sig;
                e.use_block_list = work.use_block_list;
                e.padded_len = work.padded_len;
                e.kv_lens.clear();
                e.kv_lens.extend_from_slice(&work.kv_lens); // reuses capacity
                e.cost = cost;
            }
            empty => {
                *empty = Some(DecodeMemoEntry {
                    sig,
                    use_block_list: work.use_block_list,
                    padded_len: work.padded_len,
                    kv_lens: work.kv_lens.clone(),
                    cost,
                });
            }
        }
    }
}

/// Simulated-device backend: Llama cost model + PagedAttention operator.
/// Holds no prefix-warmth state of its own: whether a prefill enjoys the
/// shared-prefix discount is decided by *block residency* in the
/// scheduler's `KvBlockManager` and arrives here as
/// `PrefillItem::prefix_hit`.
pub struct SimBackend {
    pub model: LlamaConfig,
    pub device: DeviceKind,
    pub tp: usize,
    pub block_size: usize,
    /// Scratch for `bucketed_attention_time`: the per-step bucket and
    /// kernel-work vectors are reused across calls instead of allocated
    /// per decode tick. `RefCell` because costing is logically `&self`.
    scratch_buckets: std::cell::RefCell<Vec<(usize, usize, usize)>>,
    scratch_works: std::cell::RefCell<Vec<PagedAttnWork>>,
    memo: DecodeMemo,
}

impl SimBackend {
    pub fn new(model: LlamaConfig, cfg: &ServingConfig) -> SimBackend {
        SimBackend {
            model,
            device: cfg.device,
            tp: cfg.tensor_parallel,
            block_size: cfg.block_size,
            scratch_buckets: std::cell::RefCell::new(Vec::new()),
            scratch_works: std::cell::RefCell::new(Vec::new()),
            memo: DecodeMemo::new(),
        }
    }

    /// Decode-memo hit/miss counters. Hits are exact-input-verified, so
    /// this is pure telemetry — the returned costs are identical with
    /// the memo disabled.
    pub fn memo_stats(&self) -> (u64, u64) {
        (self.memo.hits, self.memo.misses)
    }

    /// Effective prompt tokens of one prefill item: a resident shared
    /// prefix skips its cached portion (`PREFIX_HIT_DISCOUNT`), exactly
    /// the bias `RoutePolicy::PrefixAffinity` routes on — the saving the
    /// router chases is delivered only while the blocks actually survive
    /// in the cache.
    fn effective_prefill_len(&self, item: &PrefillItem) -> f64 {
        if item.prefix_hit {
            item.prompt_len as f64 * (1.0 - crate::serving::PREFIX_HIT_DISCOUNT)
        } else {
            item.prompt_len as f64
        }
    }

    /// Relative decode-cost weight of a replica on `device`: the modeled
    /// time of one decode step at a reference shape (batch 8, 1K-token KV).
    /// `ClusterSim` feeds these into `Router::with_costs` so cost-aware
    /// policies (`RoutePolicy::PrefixAffinity`) can trade a warm prefix
    /// cache against per-device decode speed in heterogeneous fleets.
    pub fn decode_cost_weight(model: &LlamaConfig, device: DeviceKind, tp: usize) -> f64 {
        llama::decode_step_cost(model, device, 8, 1024, tp).time
    }

    /// Attention geometry shared by every per-step costing call.
    fn attn_geometry(&self, batch: usize, kv_len: usize, padded_len: usize) -> PagedAttnWork {
        PagedAttnWork {
            batch,
            kv_len: kv_len.max(1),
            padded_len: padded_len.max(kv_len.max(1)),
            n_q_heads: self.model.n_q_heads / self.tp,
            n_kv_heads: (self.model.n_kv_heads / self.tp).max(1),
            head_dim: self.model.head_dim,
            block_size: self.block_size,
        }
    }

    /// Cost the layout-specific attention over a skewed batch by grouping
    /// sequences into power-of-two block-count buckets and costing each
    /// bucket at its own length, rather than collapsing the whole batch to
    /// the mean KV length (which under-costs skewed batches: the long tail
    /// pays super-linear gather/dispatch costs the mean never sees).
    fn bucketed_attention_time(&self, imp: PagedAttnImpl, work: &DecodeWork) -> f64 {
        // Bucket key: ceil(kv/block) rounded up to a power of two, so a
        // 4-bucket batch costs 4 kernel slices, not `batch` of them.
        // Both vectors are warm scratch (clear + refill, no per-tick
        // allocation); first-occurrence bucket order is preserved — it
        // fixes the float summation order in `run_bucketed`, which the
        // bitwise-parity claims depend on.
        let mut buckets = self.scratch_buckets.borrow_mut(); // (key, n, sum_kv)
        buckets.clear();
        for &kv in &work.kv_lens {
            let blocks = crate::util::ceil_div(kv.max(1), self.block_size).max(1);
            let key = blocks.next_power_of_two();
            match buckets.iter_mut().find(|b| b.0 == key) {
                Some(b) => {
                    b.1 += 1;
                    b.2 += kv.max(1);
                }
                None => buckets.push((key, 1, kv.max(1))),
            }
        }
        let mut works = self.scratch_works.borrow_mut();
        works.clear();
        works.extend(buckets.iter().map(|&(_, n, sum_kv)| {
            let mean_kv = (sum_kv / n).max(1);
            // BlockTable pads every row to the global table width;
            // BlockList and the fused A100 kernel read effectual KV.
            let padded = match imp {
                PagedAttnImpl::GaudiVllmBase => work.padded_len.max(mean_kv),
                _ => mean_kv,
            };
            self.attn_geometry(n, mean_kv, padded)
        }));
        self.model.layers as f64 * attention::run_bucketed(imp, &works)
    }
}

impl Backend for SimBackend {
    fn prefill(&mut self, batch: &[PrefillItem]) -> f64 {
        if batch.is_empty() {
            return 0.0;
        }
        // Cost model treats the chunk as one batched prefill at the mean
        // *effective* length: resident shared prefixes (`prefix_hit`)
        // skip their cached portion, cold and untagged requests pay full
        // price. Truncating division keeps the untagged path identical to
        // the old integer-mean computation (whole-token sums floor the
        // same).
        let tokens: f64 = batch.iter().map(|i| self.effective_prefill_len(i)).sum();
        let mean_len = ((tokens / batch.len() as f64) as usize).max(1);
        llama::prefill_cost(&self.model, self.device, batch.len(), mean_len, self.tp).time
    }

    fn decode(&mut self, work: &DecodeWork) -> f64 {
        let batch = work.kv_lens.len();
        if batch == 0 {
            return 0.0;
        }
        // Memoized costing: a macro burst re-visits batch compositions
        // (same membership, lengths one token apart tick to tick) whose
        // costs were already computed the last time the window crossed
        // this composition — e.g. after a block-boundary re-pad. The
        // lookup verifies the full inputs, so the memo is exact.
        let sig = DecodeMemo::signature(work);
        if let Some(cost) = self.memo.lookup(sig, work) {
            return cost;
        }
        // Weight streaming + allreduce via the model layer.
        let mean_kv = (work.kv_lens.iter().sum::<usize>() / batch).max(1);
        let base = llama::decode_step_cost(&self.model, self.device, batch, mean_kv, self.tp);
        // Replace the model's default attention (costed at the mean KV
        // length, exactly as `decode_step_cost` folded it in) with the
        // layout-specific operator costed per KV-length bucket.
        let (default_impl, this_impl) = match self.device {
            DeviceKind::Gaudi2 => (
                PagedAttnImpl::GaudiVllmOpt,
                if work.use_block_list {
                    PagedAttnImpl::GaudiVllmOpt
                } else {
                    PagedAttnImpl::GaudiVllmBase
                },
            ),
            DeviceKind::A100 => (PagedAttnImpl::A100Paged, PagedAttnImpl::A100Paged),
        };
        let default_attn = self.model.layers as f64
            * attention::run(default_impl, self.attn_geometry(batch, mean_kv, mean_kv)).time;
        let this_attn = self.bucketed_attention_time(this_impl, work);
        let cost = base.time - default_attn + this_attn;
        self.memo.store(sig, work, cost);
        cost
    }

    fn prefix_recompute_weight(&self) -> f64 {
        SimBackend::decode_cost_weight(&self.model, self.device, self.tp)
    }

    /// Activity-based step power (`sim::power`): prefill is matrix-bound
    /// (large batched GEMMs light most of the MME), decode is
    /// HBM-bandwidth-bound with the array mostly power-gated — the Fig 13
    /// asymmetry, reused here for the serving energy ledger.
    fn step_power_w(&self, kind: TraceStepKind) -> f64 {
        use crate::sim::power::{self, Activity};
        let comm = if self.tp > 1 { 0.3 } else { 0.0 };
        let activity = match kind {
            TraceStepKind::Prefill => Activity {
                matrix_util: 0.75,
                matrix_active_fraction: 0.9,
                vector_util: 0.3,
                hbm_util: 0.55,
                comm_util: comm,
            },
            TraceStepKind::Decode => Activity {
                matrix_util: 0.25,
                matrix_active_fraction: 0.4,
                vector_util: 0.2,
                hbm_util: 0.9,
                comm_util: comm,
            },
            TraceStepKind::Idle => Activity::default(),
        };
        // A replica is a *device group*: every one of its `tp` cards draws
        // the activity's power simultaneously, so the group's energy rate
        // is per-card power x width (x1 is bitwise-inert for tp=1).
        power::power(self.device, activity) * self.tp as f64
    }
}

/// The engine core: owns the scheduler, a backend and a clock source.
/// This is the single step loop shared by the simulated engine
/// (`Engine<SimBackend>`), the real PJRT engine (`real_engine.rs`) and
/// every replica of `serving::cluster::ClusterSim`.
pub struct EngineCore<B: Backend, C: ClockSource = VirtualClock> {
    pub sched: Scheduler,
    backend: B,
    clock: C,
    pub metrics: MetricsCollector,
    /// Requests not yet arrived, sorted by arrival time.
    pending: std::collections::VecDeque<Request>,
    steps_executed: u64,
    /// Step-level execution trace (bounded ring buffer).
    pub trace: Trace,
    /// Straggler dilation (`serving::chaos`): every backend-reported step
    /// duration is multiplied by this before the clock advances, so a
    /// slow replica's virtual time, energy and trace all stretch
    /// consistently — and the router's cost weight / attainment EWMA see
    /// the slowdown through ordinary completions. 1.0 (the default) is
    /// bitwise-inert: `1.0 * dt == dt` for every f64.
    slow_factor: f64,
    /// Quiescent-window macro-stepping (`step_until`): on by default.
    /// `ClusterSim::new_micro_oracle` and the parity tests turn it off to
    /// pin the macro path bitwise against the per-tick micro loop.
    macro_on: bool,
    /// Macro bursts taken / decode ticks covered by them (telemetry for
    /// the sim-speed macro section; parity without engagement is vacuous).
    macro_bursts: u64,
    macro_ticks: u64,
}

/// The classic simulated engine: `EngineCore` on a virtual clock.
pub type Engine<B> = EngineCore<B, VirtualClock>;

impl<B: Backend> EngineCore<B, VirtualClock> {
    pub fn new(cfg: ServingConfig, backend: B) -> Engine<B> {
        EngineCore::with_clock(cfg, backend, VirtualClock::new())
    }
}

impl<B: Backend, C: ClockSource> EngineCore<B, C> {
    pub fn with_clock(cfg: ServingConfig, backend: B, clock: C) -> EngineCore<B, C> {
        let mut sched = Scheduler::new(cfg);
        // Cost-aware prefix eviction ranks by the device's recompute cost.
        sched.set_prefix_weight(backend.prefix_recompute_weight());
        EngineCore {
            sched,
            backend,
            clock,
            metrics: MetricsCollector::default(),
            pending: std::collections::VecDeque::new(),
            steps_executed: 0,
            trace: Trace::new(4096),
            slow_factor: 1.0,
            macro_on: true,
            macro_bursts: 0,
            macro_ticks: 0,
        }
    }

    pub fn clock(&self) -> f64 {
        self.clock.now()
    }

    pub fn clock_mut(&mut self) -> &mut C {
        &mut self.clock
    }

    pub fn backend(&self) -> &B {
        &self.backend
    }

    pub fn backend_mut(&mut self) -> &mut B {
        &mut self.backend
    }

    pub fn steps_executed(&self) -> u64 {
        self.steps_executed
    }

    /// Enqueue a request for (future) arrival. Binary-search insert keeps
    /// the queue sorted without a full re-sort per submit (§Perf opt-2).
    pub fn submit(&mut self, req: Request) {
        let pos = self.pending.partition_point(|r| r.arrival <= req.arrival);
        self.pending.insert(pos, req);
    }

    /// Anything left to do, now or in the future?
    pub fn has_any_work(&self) -> bool {
        self.sched.has_work() || !self.pending.is_empty()
    }

    /// Wake time of this replica's next event under cluster dispatch: its
    /// own clock whenever *any* work remains, `None` when fully drained.
    /// A replica whose only work is a future pending arrival still wakes
    /// at its (possibly lagging) clock rather than at the arrival time:
    /// the next `advance()` is then an idle-jump that moves the clock to
    /// the arrival. Those no-op warm-up steps are part of the pinned
    /// event order — cluster dispatch ranks replicas by clock, and the
    /// lagging clock is what backpressure floors and pump limits compare
    /// against. (`serving::cluster::ClusterSim` keys its replica wake
    /// heap on this.)
    pub fn next_tick(&self) -> Option<f64> {
        self.has_any_work().then(|| self.clock.now())
    }

    /// Move arrived requests into the scheduler.
    fn admit_arrivals(&mut self) {
        while let Some(first) = self.pending.front() {
            if first.arrival <= self.clock.now() {
                let req = self.pending.pop_front().expect("front checked");
                self.sched.submit(req);
            } else {
                break;
            }
        }
    }

    /// Run until every submitted request is finished. Returns the summary.
    /// Drives `step_until` with an unbounded horizon so standalone engines
    /// get the quiescent-window fast path too (pending arrivals still cap
    /// each burst from inside `try_macro_burst`).
    pub fn run_to_completion(&mut self) -> crate::serving::metrics::MetricsSummary {
        while self.has_any_work() {
            self.step_until(f64::INFINITY, f64::INFINITY);
        }
        self.metrics.makespan = self.clock.now();
        self.metrics.summary()
    }

    /// Toggle the quiescent-window macro fast path (on by default). Off,
    /// every iteration runs the per-tick micro loop — the retained oracle
    /// the bitwise-parity claims compare against.
    pub fn set_macro_stepping(&mut self, on: bool) {
        self.macro_on = on;
    }

    /// Macro bursts taken so far.
    pub fn macro_bursts(&self) -> u64 {
        self.macro_bursts
    }

    /// Decode ticks covered by macro bursts so far.
    pub fn macro_ticks(&self) -> u64 {
        self.macro_ticks
    }

    /// One discrete-event iteration under an externally-supplied quiescent
    /// horizon — the engine-side entry point of the macro-stepping fast
    /// path. `before` is the *strict* bound (the next cluster arrival due
    /// or chaos control event: a tick may only start while
    /// `clock < before`, matching the event loop's arrivals-win-ties
    /// policy) and `limit` the *inclusive* pump bound (a tick starting at
    /// or before `limit` runs to its end — events are atomic). When the
    /// decode batch is provably stable for k >= 2 ticks (see
    /// `try_macro_burst`) all k run in one call by the same
    /// repeated-addition arithmetic as the micro loop; otherwise exactly
    /// one micro `advance()` runs. Returns the ids of requests finished
    /// during the iteration (always empty for a burst — bursts end
    /// strictly before any completion) and the number of discrete
    /// iterations covered (k for a burst, 1 otherwise — this keeps
    /// `ClusterSim::events` equal between macro and micro runs).
    pub fn step_until(&mut self, before: f64, limit: f64) -> (Vec<RequestId>, u64) {
        if self.macro_on {
            if let Some(ticks) = self.try_macro_burst(before, limit) {
                return (Vec::new(), ticks);
            }
        }
        (self.advance(), 1)
    }

    /// Attempt a quiescent-window macro burst: prove the decode batch
    /// cannot change for the next k ticks, then advance all k in one call.
    ///
    /// The window-entry proof, established once per burst:
    /// - *pure decode*: the scheduler is in a steady decode state
    ///   (`Scheduler::steady_decode_batch`) — the running set is
    ///   non-empty and the best waiting request (if any) is blocked by a
    ///   condition that is monotone under pure decode (batch cap: nobody
    ///   retires inside the window; prefill token budget: constant;
    ///   `can_admit`: free blocks only shrink while decoding), so no
    ///   prefill can become admissible mid-window;
    /// - *no completion*: k stops one tick short of the earliest
    ///   finishing sequence — the finishing tick retires state and may
    ///   unblock admission, so it runs micro;
    /// - *no block exhaustion*: k is capped by
    ///   `KvBlockManager::max_stable_growth`, so every per-tick
    ///   `allocate` below succeeds without eviction or preemption;
    /// - *no external boundary*: each tick starts only while
    ///   `clock < before` (next arrival or chaos control event, min'd
    ///   with this engine's own pending-arrival head) and
    ///   `clock <= limit` — a straggler window edge or hedge check always
    ///   terminates the burst because `ClusterSim` folds its control heap
    ///   into `before`.
    ///
    /// Inside the window every tick performs the *same arithmetic in the
    /// same order* as the micro loop — per-tick KV allocation in batch
    /// order (identical free-list pops), per-tick cost-model evaluation
    /// (the cost genuinely varies tick to tick: the mean KV length
    /// grows), per-tick clock/energy/trace accrual — so a burst is
    /// bitwise-identical to k micro steps. What it skips is the per-tick
    /// scheduler pass, work-descriptor rebuild, per-sequence map writes
    /// and (at the cluster level) the wake-heap re-key.
    fn try_macro_burst(&mut self, before: f64, limit: f64) -> Option<u64> {
        let before = match self.pending.front() {
            Some(next) => before.min(next.arrival),
            None => before,
        };
        let now = self.clock.now();
        if !(now < before && now <= limit) {
            return None;
        }
        let batch: Vec<RequestId> = self.sched.steady_decode_batch()?.to_vec();
        // One tick short of the earliest finish; a 1-tick "burst" saves
        // nothing over the micro step, so bail below k = 2.
        let mut k_cap = usize::MAX;
        for &id in &batch {
            let s = self.sched.seq(id);
            k_cap = k_cap.min(s.req.max_new_tokens - s.generated - 1);
        }
        if k_cap < 2 {
            return None;
        }
        let kv0 = self.sched.kv_lens(&batch);
        let k_cap = k_cap.min(self.sched.kv.max_stable_growth(&kv0, k_cap));
        if k_cap < 2 {
            return None;
        }
        let use_block_list = self.sched.config().use_block_list;
        let block_size = self.sched.config().block_size;
        let n = batch.len();
        // One work descriptor per burst, mutated per tick (the micro loop
        // rebuilds ids/kv_lens/blocks from scratch every tick).
        let mut work = DecodeWork {
            ids: batch.clone(),
            kv_lens: kv0.clone(),
            padded_len: 0,
            padding_fraction: 0.0,
            use_block_list,
        };
        let power = self.backend.step_power_w(TraceStepKind::Decode);
        let mut ticks = 0usize;
        let mut first_tick_end = 0.0f64;
        while ticks < k_cap {
            let t0 = self.clock.now();
            if !(t0 < before && t0 <= limit) {
                break;
            }
            let grown = ticks + 1;
            // Replay the scheduler's per-tick allocations in batch order
            // so free-list pops — and therefore per-sequence block sets
            // and `kv_blocks_used` — are identical to the micro loop's.
            let mut max_blocks = 0usize;
            let mut total_blocks = 0usize;
            for (i, &id) in batch.iter().enumerate() {
                self.sched
                    .kv
                    .allocate(id, kv0[i] + grown)
                    .expect("macro burst sized within the free-block budget");
                let nb = self.sched.kv.blocks_for(kv0[i] + grown);
                max_blocks = max_blocks.max(nb);
                total_blocks += nb;
                // The KV attended this tick (pre-increment, as decode_work
                // reads it before complete_decode bumps kv_len).
                work.kv_lens[i] = kv0[i] + ticks;
            }
            work.padded_len = max_blocks * block_size;
            let padded = n * max_blocks;
            work.padding_fraction =
                if padded == 0 { 0.0 } else { 1.0 - total_blocks as f64 / padded as f64 };
            let dt = self.slow_factor * self.backend.decode(&work);
            self.clock.advance(dt);
            self.steps_executed += 1;
            self.metrics.energy_j += dt * power;
            if ticks == 0 {
                first_tick_end = self.clock.now();
            }
            self.trace.record(TraceEvent {
                t_start: t0,
                kind: TraceStepKind::Decode,
                batch: n,
                tokens: n,
                duration: dt,
                kv_blocks_used: self.sched.kv.num_allocated(),
            });
            ticks += 1;
        }
        debug_assert!(ticks >= 1, "the entry guard admits at least one tick");
        // Settle the window's per-sequence growth in one pass (the micro
        // loop pays these map writes every tick via `complete_decode`).
        for (i, &id) in batch.iter().enumerate() {
            let s = self.sched.seq_mut(id);
            s.kv_len = kv0[i] + ticks;
            s.generated += ticks;
            if s.first_token_time.is_none() {
                s.first_token_time = Some(first_tick_end);
            }
            debug_assert!(!s.is_done(), "bursts end strictly before any finish");
        }
        self.macro_bursts += 1;
        self.macro_ticks += ticks as u64;
        Some(ticks as u64)
    }

    /// One discrete-event iteration: admit due arrivals and either execute
    /// a step or idle-jump to the next arrival. Returns the ids of
    /// requests that finished during the iteration.
    pub fn advance(&mut self) -> Vec<RequestId> {
        self.admit_arrivals();
        if !self.sched.has_work() {
            if let Some(next) = self.pending.front() {
                // Idle until the next arrival.
                let t = next.arrival;
                self.clock.wait_until(t);
            }
            return Vec::new();
        }
        self.step()
    }

    /// Execute one scheduling step. Returns newly finished request ids.
    pub fn step(&mut self) -> Vec<RequestId> {
        self.admit_arrivals();
        let mut finished = Vec::new();
        match self.sched.schedule() {
            Step::Prefill(ids) => {
                let items: Vec<PrefillItem> = ids
                    .iter()
                    .map(|id| {
                        let s = self.sched.seq(*id);
                        PrefillItem {
                            id: *id,
                            prompt_len: s.req.prompt_len,
                            prefix_id: s.req.prefix_id,
                            prefix_hit: s.prefix_hit,
                        }
                    })
                    .collect();
                let tokens: usize = items.iter().map(|i| i.prompt_len).sum();
                let t0 = self.clock.now();
                let dt = self.slow_factor * self.backend.prefill(&items);
                self.clock.advance(dt);
                self.steps_executed += 1;
                self.metrics.energy_j += dt * self.backend.step_power_w(TraceStepKind::Prefill);
                let now = self.clock.now();
                self.trace.record(TraceEvent {
                    t_start: t0,
                    kind: TraceStepKind::Prefill,
                    batch: ids.len(),
                    tokens,
                    duration: dt,
                    kv_blocks_used: self.sched.kv.num_allocated(),
                });
                if self.backend.prefill_emits_first_token() {
                    for &id in &ids {
                        let s = self.sched.seq_mut(id);
                        // Only the first prefill of a sequence emits a
                        // token; a recompute-preemption re-prefill merely
                        // restores already-generated state.
                        if s.generated == 0 {
                            s.generated = 1;
                            s.first_token_time = Some(now);
                            if s.is_done() {
                                s.phase = Phase::Finished;
                                s.finish_time = Some(now);
                            }
                        }
                    }
                    self.sched.retire_finished(&ids);
                    finished.extend(self.harvest_finished());
                }
            }
            Step::Decode(ids) => {
                let work = self.decode_work(&ids);
                let t0 = self.clock.now();
                let dt = self.slow_factor * self.backend.decode(&work);
                self.clock.advance(dt);
                self.steps_executed += 1;
                self.metrics.energy_j += dt * self.backend.step_power_w(TraceStepKind::Decode);
                self.sched.complete_decode(&ids, self.clock.now());
                self.trace.record(TraceEvent {
                    t_start: t0,
                    kind: TraceStepKind::Decode,
                    batch: ids.len(),
                    tokens: ids.len(),
                    duration: dt,
                    kv_blocks_used: self.sched.kv.num_allocated(),
                });
                finished.extend(self.harvest_finished());
            }
            Step::Idle => {
                // No schedulable work (all blocked); advance to next arrival
                // or nudge time forward (run_to_completion handles
                // termination).
                let bump = self.clock.now() + 1e-6;
                let target = match self.pending.front() {
                    Some(next) => next.arrival.max(bump),
                    None => bump,
                };
                self.clock.wait_until(target);
            }
        }
        // Preempted sequences also leave the backend (KV recomputed later).
        for id in self.sched.take_preempted() {
            self.backend.preempt(id);
        }
        finished
    }

    /// Drain finished sequences into metrics and release backend state.
    fn harvest_finished(&mut self) -> Vec<RequestId> {
        let done = self.sched.take_finished();
        for &id in &done {
            let m = RequestMetrics::from_sequence(self.sched.seq(id));
            // `ClusterSim::window_attainment` suffix-scans this history in
            // reverse and stops at the first record before the window,
            // which is only correct if records are monotone in finish
            // time. They are — harvest runs under a never-rewinding clock
            // — but keep the law checked so an event-loop change that
            // breaks it fails loudly instead of silently truncating
            // windows.
            debug_assert!(
                self.metrics.per_request().last().is_none_or(|prev| prev.finish <= m.finish),
                "per-replica completion records must be monotone in finish time \
                 (prev {:?} > new {:?} for request {id})",
                self.metrics.per_request().last().map(|p| p.finish),
                m.finish,
            );
            self.metrics.record(m);
            self.backend.release(id);
        }
        done
    }

    /// Build the backend work descriptor. Padding metrics are computed
    /// directly from the block manager's per-sequence block counts —
    /// materializing the full BlockTable/BlockList here doubled the
    /// per-step cost for no benefit (§Perf opt-1); the layout structures
    /// themselves are still exercised by the real engine and tests.
    fn decode_work(&self, ids: &[RequestId]) -> DecodeWork {
        let kv_lens = self.sched.kv_lens(ids);
        let use_block_list = self.sched.config().use_block_list;
        let block_size = self.sched.config().block_size;
        let mut max_blocks = 0usize;
        let mut total_blocks = 0usize;
        for id in ids {
            let nb = self.sched.kv.blocks_of(*id).map_or(0, |b| b.len());
            max_blocks = max_blocks.max(nb);
            total_blocks += nb;
        }
        let padded = ids.len() * max_blocks;
        DecodeWork {
            ids: ids.to_vec(),
            padded_len: max_blocks * block_size,
            padding_fraction: if padded == 0 {
                0.0
            } else {
                1.0 - total_blocks as f64 / padded as f64
            },
            kv_lens,
            use_block_list,
        }
    }

    // ---- chaos hooks (`serving::chaos`) --------------------------------

    /// Current straggler dilation factor (1.0 = healthy).
    pub fn slow_factor(&self) -> f64 {
        self.slow_factor
    }

    /// Set the straggler dilation factor. Every subsequent step's
    /// duration (and hence energy and trace) is multiplied by `factor`;
    /// pass 1.0 to restore healthy pacing.
    pub fn set_slow(&mut self, factor: f64) {
        assert!(
            factor.is_finite() && factor >= 1.0,
            "slow factor must be finite and >= 1.0, got {factor}"
        );
        self.slow_factor = factor;
    }

    /// Crash support: pull every unfinished request out of this replica —
    /// both not-yet-admitted pending arrivals and everything the
    /// scheduler holds — freeing all KV so nothing leaks with the dead
    /// replica. The caller (ClusterSim) requeues the returned requests
    /// through the router; completions already harvested stay counted.
    pub fn evacuate(&mut self) -> Vec<Request> {
        let mut out: Vec<Request> = self.pending.drain(..).collect();
        let scheduled = self.sched.evacuate();
        for req in &scheduled {
            // No-op for SimBackend; keeps real backends from leaking
            // per-sequence state if chaos ever runs against one.
            self.backend.release(req.id);
        }
        out.extend(scheduled);
        out
    }

    /// Cancel a single in-flight request (the hedge loser). Returns the
    /// request if it was still unfinished on this replica; `None` if it
    /// is unknown here or already finished (completions are immutable).
    pub fn cancel(&mut self, id: RequestId) -> Option<Request> {
        if let Some(pos) = self.pending.iter().position(|r| r.id == id) {
            return self.pending.remove(pos);
        }
        let req = self.sched.cancel(id)?;
        self.backend.release(id);
        Some(req)
    }

    /// A request is hedge-eligible while it has made no visible progress
    /// on this replica: still waiting in pending, or scheduled but
    /// without a first token. Once a token has streamed (or the request
    /// finished) duplicating it would waste work, not cut tail latency.
    pub fn hedge_eligible(&self, id: RequestId) -> bool {
        if self.pending.iter().any(|r| r.id == id) {
            return true;
        }
        match self.sched.try_seq(id) {
            Some(s) => s.phase != Phase::Finished && s.first_token_time.is_none(),
            None => false,
        }
    }

    /// Clone of a live (pending or scheduled, unfinished) request, used
    /// to mint the hedge copy without disturbing the primary.
    pub fn request_snapshot(&self, id: RequestId) -> Option<Request> {
        if let Some(r) = self.pending.iter().find(|r| r.id == id) {
            return Some(r.clone());
        }
        self.sched.try_seq(id).and_then(|s| {
            (s.phase != Phase::Finished).then(|| s.req.clone())
        })
    }

    /// Preemption storm: forcibly preempt up to `count` running
    /// sequences (their KV is recomputed when next scheduled). Returns
    /// how many were actually hit.
    pub fn inject_preemptions(&mut self, count: usize) -> usize {
        let n = self.sched.force_preempt(count);
        for id in self.sched.take_preempted() {
            self.backend.preempt(id);
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(use_block_list: bool) -> ServingConfig {
        ServingConfig {
            device: DeviceKind::Gaudi2,
            num_blocks: 2048,
            max_decode_batch: 16,
            use_block_list,
            ..Default::default()
        }
    }

    fn engine(use_block_list: bool) -> Engine<SimBackend> {
        let cfg = small_cfg(use_block_list);
        let backend = SimBackend::new(LlamaConfig::llama31_8b(), &cfg);
        Engine::new(cfg, backend)
    }

    #[test]
    fn completes_all_requests() {
        let mut e = engine(true);
        for i in 0..8 {
            e.submit(Request::new(i, 100, 20, 0.0));
        }
        let s = e.run_to_completion();
        assert_eq!(s.requests, 8);
        assert!(s.mean_ttft > 0.0);
        assert!(s.mean_tpot > 0.0);
        assert!(s.throughput_tps > 0.0);
        // All KV returned.
        assert_eq!(e.sched.kv.num_free(), e.sched.kv.num_blocks());
    }

    #[test]
    fn block_list_engine_outperforms_block_table() {
        // The Fig 17(d) headline at the engine level: same workload,
        // vLLM_opt (BlockList) vs vLLM_base (BlockTable), variable lengths
        // to induce padding.
        let run = |ubl: bool| {
            let mut e = engine(ubl);
            for i in 0..12 {
                // Mixed lengths -> padding in the BlockTable layout.
                let prompt = 64 + (i as usize % 4) * 512;
                e.submit(Request::new(i, prompt, 32 + (i as usize % 3) * 64, 0.0));
            }
            e.run_to_completion().throughput_tps
        };
        let opt = run(true);
        let base = run(false);
        assert!(opt > 2.0 * base, "opt {opt} base {base}");
    }

    #[test]
    fn staggered_arrivals_respected() {
        let mut e = engine(true);
        e.submit(Request::new(0, 100, 10, 0.0));
        e.submit(Request::new(1, 100, 10, 1000.0)); // arrives much later
        let s = e.run_to_completion();
        assert_eq!(s.requests, 2);
        assert!(e.clock() >= 1000.0);
        // Second request's TTFT measured from its own arrival, so small.
        assert!(s.p99_ttft < 10.0, "ttft {}", s.p99_ttft);
    }

    #[test]
    fn decode_work_padding_reflects_length_skew() {
        let mut e = engine(false);
        e.submit(Request::new(0, 128, 4, 0.0));
        e.submit(Request::new(1, 1024, 4, 0.0));
        // Prefill both, then inspect the first decode work.
        e.step();
        let ids: Vec<RequestId> = e.sched.running_ids().to_vec();
        let w = e.decode_work(&ids);
        assert!(w.padding_fraction > 0.3, "padding {}", w.padding_fraction);
        assert_eq!(w.padded_len, 1024);
        assert_eq!(w.ids, ids);
    }

    #[test]
    fn throughput_saturates_with_batch_size() {
        // More concurrent requests -> better weight-streaming amortization.
        let run = |n: u64| {
            let mut e = engine(true);
            for i in 0..n {
                e.submit(Request::new(i, 100, 50, 0.0));
            }
            e.run_to_completion().throughput_tps
        };
        assert!(run(16) > 4.0 * run(1), "batching should amortize decode");
    }

    #[test]
    fn skewed_batch_costs_more_than_uniform_at_same_total_kv() {
        // Bucketed costing: one 3072-token + three 64-token sequences must
        // not be costed like four ~816-token sequences (the mean collapse).
        let cfg = small_cfg(true);
        let mut be = SimBackend::new(LlamaConfig::llama31_8b(), &cfg);
        let mk = |kv_lens: Vec<usize>| {
            let n = kv_lens.len();
            let max = *kv_lens.iter().max().unwrap();
            DecodeWork {
                ids: (0..n as u64).collect(),
                padded_len: crate::util::ceil_div(max, cfg.block_size) * cfg.block_size,
                padding_fraction: 0.0,
                kv_lens,
                use_block_list: true,
            }
        };
        let skewed = be.decode(&mk(vec![3072, 64, 64, 64]));
        let uniform = be.decode(&mk(vec![816, 816, 816, 816]));
        assert!(
            skewed > uniform,
            "skew must cost extra: skewed {skewed} uniform {uniform}"
        );
    }

    #[test]
    fn resident_prefix_prefills_cheaper() {
        // The saving PrefixAffinity routes toward must actually exist in
        // the backend: a residency hit is discounted, a miss (or an
        // untagged request) pays full price. The backend keeps no warmth
        // state — the hit flag comes from the scheduler's block manager.
        let cfg = small_cfg(true);
        let mut be = SimBackend::new(LlamaConfig::llama31_8b(), &cfg);
        let item = |id: u64, prefix: Option<u64>, hit: bool| PrefillItem {
            id,
            prompt_len: 1024,
            prefix_id: prefix,
            prefix_hit: hit,
        };
        let cold = be.prefill(&[item(0, Some(7), false)]);
        let warm = be.prefill(&[item(1, Some(7), true)]);
        let untagged = be.prefill(&[item(2, None, false)]);
        assert!(warm < cold, "warm {warm} vs cold {cold}");
        assert_eq!(untagged, cold, "untagged requests pay full prefill price");
    }

    #[test]
    fn engine_prefix_warmth_is_block_residency() {
        // End-to-end through the scheduler: the second request of a group
        // hits only because the first left resident blocks behind; with
        // the cache budget at 0 every prefill is cold.
        let run = |prefix_blocks: usize| {
            let cfg = ServingConfig { prefix_cache_blocks: prefix_blocks, ..small_cfg(true) };
            let backend = SimBackend::new(LlamaConfig::llama31_8b(), &cfg);
            let mut e = Engine::new(cfg, backend);
            // Staggered so the two prefills are separate steps.
            e.submit(Request::new(0, 1024, 4, 0.0).with_prefix(7));
            e.submit(Request::new(1, 1024, 4, 1000.0).with_prefix(7));
            let s = e.run_to_completion();
            assert_eq!(s.requests, 2);
            (e.sched.kv.prefix_stats(), e.clock())
        };
        let (cached, t_cached) = run(2048);
        assert_eq!((cached.hits, cached.misses), (1, 1));
        let (off, t_off) = run(0);
        assert_eq!((off.hits, off.uncached), (0, 2));
        // The hit shows up as wall-clock savings on the same workload.
        assert!(t_cached < t_off, "cached {t_cached} vs cold {t_off}");
    }

    #[test]
    fn virtual_clock_semantics() {
        let mut c = VirtualClock::new();
        assert_eq!(c.now(), 0.0);
        c.advance(1.5);
        c.wait_until(1.0); // never backwards
        assert_eq!(c.now(), 1.5);
        c.wait_until(3.0);
        assert_eq!(c.now(), 3.0);
    }

    #[test]
    fn slow_factor_dilates_time_and_energy() {
        let run = |factor: f64| {
            let mut e = engine(true);
            e.set_slow(factor);
            for i in 0..6 {
                e.submit(Request::new(i, 256, 16, 0.0));
            }
            let s = e.run_to_completion();
            assert_eq!(s.requests, 6);
            (e.clock(), e.metrics.energy_j)
        };
        let (t1, j1) = run(1.0);
        let (t4, j4) = run(4.0);
        // Same step sequence, every duration ×4 → makespan and energy ×4.
        assert!((t4 / t1 - 4.0).abs() < 1e-9, "t1 {t1} t4 {t4}");
        assert!((j4 / j1 - 4.0).abs() < 1e-9, "j1 {j1} j4 {j4}");
    }

    #[test]
    fn evacuate_empties_replica_and_frees_kv() {
        let mut e = engine(true);
        // One admitted + running, one pending far in the future.
        e.submit(Request::new(0, 256, 64, 0.0));
        e.submit(Request::new(1, 256, 64, 1e6));
        e.step(); // prefill request 0
        let evac = e.evacuate();
        let mut ids: Vec<u64> = evac.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1]);
        assert!(!e.has_any_work());
        assert_eq!(e.sched.kv.num_free(), e.sched.kv.num_blocks());
        // Evacuated requests keep their original arrival time so requeue
        // latency lands in TTFT, not silently forgiven.
        assert_eq!(evac.iter().find(|r| r.id == 1).unwrap().arrival, 1e6);
    }

    #[test]
    fn cancel_spares_finished_and_unknown() {
        let mut e = engine(true);
        e.submit(Request::new(0, 64, 1, 0.0));
        e.submit(Request::new(1, 64, 64, 0.0));
        while e.sched.try_seq(0).map(|s| s.phase) != Some(Phase::Finished)
            && e.has_any_work()
        {
            e.advance();
        }
        assert!(e.cancel(0).is_none(), "finished requests are immutable");
        assert!(e.cancel(99).is_none(), "unknown id");
        assert_eq!(e.cancel(1).map(|r| r.id), Some(1));
        assert_eq!(e.sched.kv.num_free(), e.sched.kv.num_blocks());
    }

    #[test]
    fn hedge_eligibility_ends_at_first_token() {
        let mut e = engine(true);
        e.submit(Request::new(0, 256, 8, 0.0));
        e.submit(Request::new(1, 256, 8, 1e6));
        assert!(e.hedge_eligible(0), "queued, no progress yet");
        assert!(e.hedge_eligible(1), "still pending");
        assert!(!e.hedge_eligible(42), "unknown");
        e.step(); // prefill emits request 0's first token
        assert!(!e.hedge_eligible(0), "first token already streamed");
        assert_eq!(e.request_snapshot(1).map(|r| r.id), Some(1));
    }

    #[test]
    fn decode_memo_hits_on_identical_inputs_only() {
        let cfg = small_cfg(true);
        let mut be = SimBackend::new(LlamaConfig::llama31_8b(), &cfg);
        let work = |kv: usize| DecodeWork {
            ids: vec![0, 1],
            kv_lens: vec![kv, kv + 64],
            padded_len: crate::util::ceil_div(kv + 64, cfg.block_size) * cfg.block_size,
            padding_fraction: 0.0,
            use_block_list: true,
        };
        let a1 = be.decode(&work(256));
        let b = be.decode(&work(512)); // different inputs: a miss
        let a2 = be.decode(&work(256)); // exact repeat: a verified hit
        assert_eq!(a1.to_bits(), a2.to_bits(), "memo must return the identical f64");
        assert_ne!(a1.to_bits(), b.to_bits());
        assert_eq!(be.memo_stats(), (1, 2));
    }

    #[test]
    fn macro_stepping_is_bitwise_inert() {
        // The engine-level parity claim: the quiescent-window fast path
        // must replay the micro loop bit-for-bit — clock, energy, every
        // summary metric — while actually taking bursts.
        let run = |macro_on: bool| {
            let mut e = engine(true);
            e.set_macro_stepping(macro_on);
            for i in 0..10 {
                let prompt = 64 + (i as usize % 4) * 256;
                e.submit(Request::new(i, prompt, 48 + (i as usize % 3) * 32, (i as f64) * 0.2));
            }
            let s = e.run_to_completion();
            (e.clock(), e.metrics.energy_j, e.steps_executed(), e.macro_ticks(), s)
        };
        let (t_macro, j_macro, steps_macro, ticks_macro, s_macro) = run(true);
        let (t_micro, j_micro, steps_micro, ticks_micro, s_micro) = run(false);
        assert!(ticks_macro > 0, "the fast path never engaged — parity is vacuous");
        assert_eq!(ticks_micro, 0, "the oracle must stay micro-stepped");
        assert_eq!(steps_macro, steps_micro, "bursts count every covered tick");
        assert_eq!(t_macro.to_bits(), t_micro.to_bits());
        assert_eq!(j_macro.to_bits(), j_micro.to_bits());
        assert_eq!(s_macro.requests, s_micro.requests);
        assert_eq!(s_macro.mean_ttft.to_bits(), s_micro.mean_ttft.to_bits());
        assert_eq!(s_macro.mean_tpot.to_bits(), s_micro.mean_tpot.to_bits());
        assert_eq!(s_macro.p99_ttft.to_bits(), s_micro.p99_ttft.to_bits());
        assert_eq!(s_macro.throughput_tps.to_bits(), s_micro.throughput_tps.to_bits());
    }

    #[test]
    fn macro_burst_stops_at_the_horizon() {
        // A burst may not start a tick at or past `before` — the strict
        // external bound ClusterSim derives from the next arrival due or
        // chaos control event (e.g. a straggler window boundary). Ticks
        // already started may overrun it (events are atomic), exactly
        // like the micro loop.
        let mut e = engine(true);
        for i in 0..8 {
            e.submit(Request::new(i, 64, 400, 0.0));
        }
        e.step(); // prefill all eight into Running
        let horizon = e.clock() + 0.5;
        let mut iters = 0u64;
        while e.clock() < horizon {
            let (_, n) = e.step_until(horizon, f64::INFINITY);
            iters += n;
        }
        assert!(e.macro_bursts() >= 1, "expected at least one burst before the horizon");
        assert!(iters >= 2, "several ticks fit under the horizon");
        for ev in e.trace.iter() {
            assert!(
                ev.t_start < horizon,
                "tick started at {} past the horizon {horizon}",
                ev.t_start
            );
        }
        // The boundary only pauses the window; the run still completes.
        let s = e.run_to_completion();
        assert_eq!(s.requests, 8);
    }

    #[test]
    fn inject_preemptions_hits_running_sequences() {
        let mut e = engine(true);
        for i in 0..4 {
            e.submit(Request::new(i, 128, 64, 0.0));
        }
        e.step(); // prefill all four into Running
        let hit = e.inject_preemptions(2);
        assert_eq!(hit, 2);
        assert_eq!(e.inject_preemptions(10), 2, "only the remaining two");
        let s = e.run_to_completion();
        assert_eq!(s.requests, 4, "storm delays but never loses requests");
    }
}
