//! The serving engine: a discrete-event loop driving the scheduler against
//! a pluggable `Backend`. With `SimBackend` the clock is virtual and step
//! durations come from the device simulators (this is how Fig 17(d,e) is
//! regenerated); with `PjrtBackend` (`real_engine.rs`) the same scheduler
//! and block bookkeeping drive real HLO executables under the wall clock.

use crate::config::{DeviceKind, ServingConfig};
use crate::models::llama::{self, LlamaConfig};
use crate::ops::attention::{self, PagedAttnImpl, PagedAttnWork};
use crate::serving::metrics::{MetricsCollector, RequestMetrics};
use crate::serving::request::{Request, RequestId};
use crate::serving::scheduler::{Scheduler, Step};
use crate::serving::trace::{Trace, TraceEvent, TraceStepKind};

/// A batch of decode work handed to the backend.
#[derive(Debug, Clone)]
pub struct DecodeWork {
    pub kv_lens: Vec<usize>,
    /// Padded table width in blocks × block_size (vLLM_base) — equals the
    /// longest sequence rounded up to a block.
    pub padded_len: usize,
    /// Zero-padding fraction of the BlockTable layout.
    pub padding_fraction: f64,
    pub use_block_list: bool,
}

/// Execution backend abstraction.
pub trait Backend {
    /// Process prompts (lengths given); returns step duration in seconds.
    fn prefill(&mut self, prompt_lens: &[usize]) -> f64;
    /// One decode step; returns step duration in seconds.
    fn decode(&mut self, work: &DecodeWork) -> f64;
}

/// Simulated-device backend: Llama cost model + PagedAttention operator.
pub struct SimBackend {
    pub model: LlamaConfig,
    pub device: DeviceKind,
    pub tp: usize,
    pub block_size: usize,
}

impl SimBackend {
    pub fn new(model: LlamaConfig, cfg: &ServingConfig) -> SimBackend {
        SimBackend {
            model,
            device: cfg.device,
            tp: cfg.tensor_parallel,
            block_size: cfg.block_size,
        }
    }
}

impl Backend for SimBackend {
    fn prefill(&mut self, prompt_lens: &[usize]) -> f64 {
        if prompt_lens.is_empty() {
            return 0.0;
        }
        // Cost model treats the chunk as one batched prefill at the mean
        // length (token count preserved).
        let tokens: usize = prompt_lens.iter().sum();
        let mean_len = (tokens / prompt_lens.len()).max(1);
        llama::prefill_cost(&self.model, self.device, prompt_lens.len(), mean_len, self.tp).time
    }

    fn decode(&mut self, work: &DecodeWork) -> f64 {
        let batch = work.kv_lens.len();
        if batch == 0 {
            return 0.0;
        }
                // Weight streaming + allreduce via the model layer.
        let mean_kv = (work.kv_lens.iter().sum::<usize>() / batch).max(1);
        let base = llama::decode_step_cost(&self.model, self.device, batch, mean_kv, self.tp);
        // Replace the model's default attention with the layout-specific
        // operator: BlockTable (padded) vs BlockList (effectual).
        let attn_work = PagedAttnWork {
            batch,
            kv_len: mean_kv,
            padded_len: work.padded_len.max(mean_kv),
            n_q_heads: self.model.n_q_heads / self.tp,
            n_kv_heads: (self.model.n_kv_heads / self.tp).max(1),
            head_dim: self.model.head_dim,
            block_size: self.block_size,
        };
        let (default_impl, this_impl) = match self.device {
            DeviceKind::Gaudi2 => (
                PagedAttnImpl::GaudiVllmOpt,
                if work.use_block_list {
                    PagedAttnImpl::GaudiVllmOpt
                } else {
                    PagedAttnImpl::GaudiVllmBase
                },
            ),
            DeviceKind::A100 => (PagedAttnImpl::A100Paged, PagedAttnImpl::A100Paged),
        };
        let default_attn = self.model.layers as f64
            * attention::run(
                default_impl,
                PagedAttnWork { padded_len: mean_kv, ..attn_work },
            )
            .time;
        let this_attn = self.model.layers as f64 * attention::run(this_impl, attn_work).time;
        base.time - default_attn + this_attn
    }
}

/// The engine: owns the scheduler, a backend and the virtual clock.
pub struct Engine<B: Backend> {
    pub sched: Scheduler,
    backend: B,
    clock: f64,
    pub metrics: MetricsCollector,
    /// Requests not yet arrived, sorted by arrival time.
    pending: std::collections::VecDeque<Request>,
    steps_executed: u64,
    /// Step-level execution trace (bounded ring buffer).
    pub trace: Trace,
}

impl<B: Backend> Engine<B> {
    pub fn new(cfg: ServingConfig, backend: B) -> Engine<B> {
        Engine {
            sched: Scheduler::new(cfg),
            backend,
            clock: 0.0,
            metrics: MetricsCollector::default(),
            pending: std::collections::VecDeque::new(),
            steps_executed: 0,
            trace: Trace::new(4096),
        }
    }

    pub fn clock(&self) -> f64 {
        self.clock
    }

    pub fn steps_executed(&self) -> u64 {
        self.steps_executed
    }

    /// Enqueue a request for (future) arrival. Binary-search insert keeps
    /// the queue sorted without a full re-sort per submit (§Perf opt-2).
    pub fn submit(&mut self, req: Request) {
        let pos = self.pending.partition_point(|r| r.arrival <= req.arrival);
        self.pending.insert(pos, req);
    }

    /// Move arrived requests into the scheduler.
    fn admit_arrivals(&mut self) {
        while let Some(first) = self.pending.front() {
            if first.arrival <= self.clock {
                let req = self.pending.pop_front().expect("front checked");
                self.sched.submit(req);
            } else {
                break;
            }
        }
    }

    /// Run until every submitted request is finished. Returns the summary.
    pub fn run_to_completion(&mut self) -> crate::serving::metrics::MetricsSummary {
        loop {
            self.admit_arrivals();
            if !self.sched.has_work() {
                if let Some(next) = self.pending.front() {
                    // Idle until the next arrival.
                    self.clock = next.arrival;
                    continue;
                }
                break;
            }
            self.step();
        }
        self.metrics.makespan = self.clock;
        self.metrics.summary()
    }

    /// Execute one scheduling step.
    pub fn step(&mut self) {
        self.admit_arrivals();
        match self.sched.schedule() {
            Step::Prefill(ids) => {
                let lens: Vec<usize> =
                    ids.iter().map(|id| self.sched.seq(*id).req.prompt_len).collect();
                let tokens: usize = lens.iter().sum();
                let t0 = self.clock;
                let dt = self.backend.prefill(&lens);
                self.clock += dt;
                self.steps_executed += 1;
                self.trace.record(TraceEvent {
                    t_start: t0,
                    kind: TraceStepKind::Prefill,
                    batch: ids.len(),
                    tokens,
                    duration: dt,
                    kv_blocks_used: self.sched.kv.num_allocated(),
                });
            }
            Step::Decode(ids) => {
                let work = self.decode_work(&ids);
                let t0 = self.clock;
                let dt = self.backend.decode(&work);
                self.clock += dt;
                self.steps_executed += 1;
                self.sched.complete_decode(&ids, self.clock);
                self.trace.record(TraceEvent {
                    t_start: t0,
                    kind: TraceStepKind::Decode,
                    batch: ids.len(),
                    tokens: ids.len(),
                    duration: dt,
                    kv_blocks_used: self.sched.kv.num_allocated(),
                });
                for id in self.sched.take_finished() {
                    let m = RequestMetrics::from_sequence(self.sched.seq(id));
                    self.metrics.record(m);
                }
            }
            Step::Idle => {
                // No schedulable work (all blocked); advance to next arrival
                // or bail (run_to_completion handles termination).
                if let Some(next) = self.pending.front() {
                    self.clock = next.arrival.max(self.clock + 1e-6);
                } else {
                    // Avoid an infinite loop on a stuck schedule.
                    self.clock += 1e-6;
                }
            }
        }
    }

    /// Build the backend work descriptor. Padding metrics are computed
    /// directly from the block manager's per-sequence block counts —
    /// materializing the full BlockTable/BlockList here doubled the
    /// per-step cost for no benefit (§Perf opt-1); the layout structures
    /// themselves are still exercised by the real engine and tests.
    fn decode_work(&self, ids: &[RequestId]) -> DecodeWork {
        let kv_lens = self.sched.kv_lens(ids);
        let use_block_list = self.sched.config().use_block_list;
        let block_size = self.sched.config().block_size;
        let mut max_blocks = 0usize;
        let mut total_blocks = 0usize;
        for id in ids {
            let nb = self.sched.kv.blocks_of(*id).map_or(0, |b| b.len());
            max_blocks = max_blocks.max(nb);
            total_blocks += nb;
        }
        let padded = ids.len() * max_blocks;
        DecodeWork {
            padded_len: max_blocks * block_size,
            padding_fraction: if padded == 0 {
                0.0
            } else {
                1.0 - total_blocks as f64 / padded as f64
            },
            kv_lens,
            use_block_list,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg(use_block_list: bool) -> ServingConfig {
        ServingConfig {
            device: DeviceKind::Gaudi2,
            num_blocks: 2048,
            max_decode_batch: 16,
            use_block_list,
            ..Default::default()
        }
    }

    fn engine(use_block_list: bool) -> Engine<SimBackend> {
        let cfg = small_cfg(use_block_list);
        let backend = SimBackend::new(LlamaConfig::llama31_8b(), &cfg);
        Engine::new(cfg, backend)
    }

    #[test]
    fn completes_all_requests() {
        let mut e = engine(true);
        for i in 0..8 {
            e.submit(Request::new(i, 100, 20, 0.0));
        }
        let s = e.run_to_completion();
        assert_eq!(s.requests, 8);
        assert!(s.mean_ttft > 0.0);
        assert!(s.mean_tpot > 0.0);
        assert!(s.throughput_tps > 0.0);
        // All KV returned.
        assert_eq!(e.sched.kv.num_free(), e.sched.kv.num_blocks());
    }

    #[test]
    fn block_list_engine_outperforms_block_table() {
        // The Fig 17(d) headline at the engine level: same workload,
        // vLLM_opt (BlockList) vs vLLM_base (BlockTable), variable lengths
        // to induce padding.
        let run = |ubl: bool| {
            let mut e = engine(ubl);
            for i in 0..12 {
                // Mixed lengths -> padding in the BlockTable layout.
                let prompt = 64 + (i as usize % 4) * 512;
                e.submit(Request::new(i, prompt, 32 + (i as usize % 3) * 64, 0.0));
            }
            e.run_to_completion().throughput_tps
        };
        let opt = run(true);
        let base = run(false);
        assert!(opt > 2.0 * base, "opt {opt} base {base}");
    }

    #[test]
    fn staggered_arrivals_respected() {
        let mut e = engine(true);
        e.submit(Request::new(0, 100, 10, 0.0));
        e.submit(Request::new(1, 100, 10, 1000.0)); // arrives much later
        let s = e.run_to_completion();
        assert_eq!(s.requests, 2);
        assert!(e.clock() >= 1000.0);
        // Second request's TTFT measured from its own arrival, so small.
        assert!(s.p99_ttft < 10.0, "ttft {}", s.p99_ttft);
    }

    #[test]
    fn decode_work_padding_reflects_length_skew() {
        let mut e = engine(false);
        e.submit(Request::new(0, 128, 4, 0.0));
        e.submit(Request::new(1, 1024, 4, 0.0));
        // Prefill both, then inspect the first decode work.
        e.step();
        let ids: Vec<RequestId> = e.sched.running_ids().to_vec();
        let w = e.decode_work(&ids);
        assert!(w.padding_fraction > 0.3, "padding {}", w.padding_fraction);
        assert_eq!(w.padded_len, 1024);
    }

    #[test]
    fn throughput_saturates_with_batch_size() {
        // More concurrent requests -> better weight-streaming amortization.
        let run = |n: u64| {
            let mut e = engine(true);
            for i in 0..n {
                e.submit(Request::new(i, 100, 50, 0.0));
            }
            e.run_to_completion().throughput_tps
        };
        assert!(run(16) > 4.0 * run(1), "batching should amortize decode");
    }
}
