//! Goodput-driven autoscaling for `ClusterSim` fleets: a control loop
//! that adds replicas when the recent window misses the attainment
//! target and drains the most expensive replica when the fleet has
//! slack — the deployment-cost half of the paper's iso-SLO sizing
//! question, run online instead of by offline sweep.
//!
//! The control signal is **weighted per-class attainment**
//! (`serving::qos`): each traffic class's windowed attainment against
//! its own SLO, folded by class weight — so an interactive class
//! missing its tight SLO forces a scale-up even while bulk background
//! traffic is comfortably compliant. A single default class reduces the
//! signal to the legacy global-window attainment exactly.
//!
//! The controller is deliberately split into a *pure sizing rule*
//! ([`Autoscaler::desired_replicas`], monotone in offered load by
//! construction — property-tested) and a *windowed feedback step*
//! ([`Autoscaler::control`]) that observes attainment over the last
//! control interval and applies at most one action per tick. One action
//! per tick keeps the loop stable: capacity changes need a window of
//! effect before the next observation is meaningful.

use crate::config::DeviceKind;
use crate::report::{Cell, Report, Unit};
use crate::serving::cluster::ClusterSim;
use crate::serving::qos::ClassSet;

/// Fraction of a replica's SLO-compliant capacity the sizing rule plans
/// to use — headroom absorbs Poisson burstiness.
pub const TARGET_UTILIZATION: f64 = 0.8;

/// Controller targets and bounds.
#[derive(Debug, Clone)]
pub struct AutoscaleConfig {
    /// Traffic classes the fleet is scaled against: the control signal
    /// is attainment per class (each against its own SLO) folded by
    /// class weight. Left at the default single class, the controller
    /// inherits the *deployment's* declared classes at control time
    /// (single-class deployments therefore get exactly the legacy
    /// scalar-SLO controller); set explicitly to measure against a
    /// different set.
    pub classes: ClassSet,
    /// Scale up when windowed attainment drops below this.
    pub low_watermark: f64,
    /// Consider draining only when windowed attainment is at/above this.
    pub high_watermark: f64,
    /// Control interval in (virtual) seconds.
    pub interval_s: f64,
    /// Device new replicas are provisioned on.
    pub scale_up_device: DeviceKind,
    pub min_replicas: usize,
    pub max_replicas: usize,
    /// In-flight requests *per active replica* above which a window with
    /// zero completions counts as pressure. Continuous batching keeps
    /// tens of requests in flight per replica in healthy operation, so a
    /// bare `queued > active` test would read every warm-up as underwater
    /// and scale straight to `max_replicas`; this threshold separates
    /// "still filling the batch" from "drowning".
    pub pressure_queue_depth: usize,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            classes: ClassSet::default(),
            low_watermark: 0.95,
            high_watermark: 0.999,
            interval_s: 0.25,
            scale_up_device: DeviceKind::Gaudi2,
            min_replicas: 1,
            max_replicas: 8,
            pressure_queue_depth: 64,
        }
    }
}

/// What one control tick decided to do — the replica to drain is not yet
/// resolved ([`Autoscaler::control`] picks the most expensive active one
/// and records the resolved [`Action`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    /// Provision (or un-drain) one replica of this device.
    ScaleUp(DeviceKind),
    /// Drain the most expensive active replica.
    DrainMostExpensive,
    Hold,
}

/// One applied capacity action (drain target resolved).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// Provisioned (or un-drained) one replica of this device.
    ScaleUp(DeviceKind),
    /// Drained this replica (finishes in-flight, accepts nothing new).
    Drain(usize),
    Hold,
}

/// The feedback controller; drive it with `ClusterSim::run_autoscaled`.
#[derive(Debug)]
pub struct Autoscaler {
    cfg: AutoscaleConfig,
    /// (tick time, applied action) log, for reports and tests.
    actions: Vec<(f64, Action)>,
}

impl Autoscaler {
    pub fn new(cfg: AutoscaleConfig) -> Autoscaler {
        assert!(cfg.interval_s > 0.0, "control interval must be positive");
        assert!(cfg.min_replicas >= 1 && cfg.max_replicas >= cfg.min_replicas);
        assert!(cfg.low_watermark <= cfg.high_watermark);
        Autoscaler { cfg, actions: Vec::new() }
    }

    pub fn interval_s(&self) -> f64 {
        self.cfg.interval_s
    }

    pub fn config(&self) -> &AutoscaleConfig {
        &self.cfg
    }

    /// Applied (tick, action) history, in tick order (`Hold`s included).
    pub fn actions(&self) -> &[(f64, Action)] {
        &self.actions
    }

    /// Net scale-ups applied so far.
    pub fn scale_ups(&self) -> usize {
        self.actions.iter().filter(|(_, a)| matches!(a, Action::ScaleUp(_))).count()
    }

    pub fn drains(&self) -> usize {
        self.actions.iter().filter(|(_, a)| matches!(a, Action::Drain(_))).count()
    }

    /// Pure open-loop sizing rule: replicas needed to keep `offered_rps`
    /// under SLO given one replica's compliant capacity, planned at
    /// [`TARGET_UTILIZATION`] and clamped to the configured bounds.
    /// Monotone non-decreasing in `offered_rps` by construction (a
    /// clamped ceil of a non-decreasing function) — the property the
    /// proptest suite pins down.
    pub fn desired_replicas(&self, offered_rps: f64, per_replica_goodput_rps: f64) -> usize {
        assert!(per_replica_goodput_rps > 0.0, "per-replica capacity must be positive");
        let offered = offered_rps.max(0.0);
        let raw = (offered / (per_replica_goodput_rps * TARGET_UTILIZATION)).ceil() as usize;
        raw.clamp(self.cfg.min_replicas, self.cfg.max_replicas)
    }

    /// Pure feedback rule for one tick: `attainment` is the windowed SLO
    /// attainment (`None` when the window saw no completions), `queued`
    /// the router's in-flight count, `active` the non-drained replica
    /// count.
    pub fn decide(&self, attainment: Option<f64>, queued: usize, active: usize) -> Decision {
        let pressured = match attainment {
            Some(a) => a < self.cfg.low_watermark,
            // A window with zero completions is pressure only when the
            // per-replica backlog exceeds what continuous batching keeps
            // in flight when healthy (see `pressure_queue_depth`).
            None => queued > active * self.cfg.pressure_queue_depth,
        };
        if pressured {
            if active < self.cfg.max_replicas {
                return Decision::ScaleUp(self.cfg.scale_up_device);
            }
            return Decision::Hold;
        }
        let slack = attainment.is_some_and(|a| a >= self.cfg.high_watermark);
        if slack && active > self.cfg.min_replicas && queued < active {
            return Decision::DrainMostExpensive;
        }
        Decision::Hold
    }

    /// The measurement set a controller on `sim` scales against: the
    /// explicitly configured classes, except that a default
    /// (single-legacy-class) config inherits the *deployment's* declared
    /// classes — so `Autoscaler::new(AutoscaleConfig::default())` on a
    /// three-tier fleet really does control on weighted per-class
    /// attainment instead of silently degrading to the global scalar
    /// view. Configure `classes` explicitly to override.
    fn measurement_classes<'a>(&'a self, sim: &'a ClusterSim) -> &'a ClassSet {
        if self.cfg.classes == ClassSet::default() {
            sim.classes()
        } else {
            &self.cfg.classes
        }
    }

    /// One control tick at virtual time `now`: observe the last interval
    /// (weighted per-class attainment), decide, and apply at most one
    /// capacity action to `sim`.
    pub fn control(&mut self, sim: &mut ClusterSim, now: f64) {
        let attainment =
            sim.window_attainment(now - self.cfg.interval_s, self.measurement_classes(sim));
        let active = sim.router().num_active();
        let action = match self.decide(attainment, sim.router().queued(), active) {
            Decision::ScaleUp(device) => {
                // Prefer waking a drained replica of the right device over
                // provisioning a cold one.
                let drained = (0..sim.num_replicas())
                    .find(|&i| sim.router().is_drained(i) && sim.device_of(i) == device);
                match drained {
                    Some(i) => sim.undrain_replica(i),
                    None => {
                        sim.add_replica(device, now);
                    }
                }
                Action::ScaleUp(device)
            }
            Decision::DrainMostExpensive => {
                // Ties resolve deterministically to the highest index
                // (`max_by` semantics), trimming fleet cost where it
                // hurts least.
                let victim = (0..sim.num_replicas())
                    .filter(|&i| !sim.router().is_drained(i))
                    .max_by(|&a, &b| {
                        sim.router().cost_of(a).total_cmp(&sim.router().cost_of(b))
                    });
                match victim {
                    Some(i) => {
                        sim.drain_replica(i);
                        Action::Drain(i)
                    }
                    None => Action::Hold,
                }
            }
            Decision::Hold => Action::Hold,
        };
        self.actions.push((now, action));
    }
}

/// Typed per-replica cost report for a (possibly autoscaled) fleet:
/// busy-time energy from the device power model, J per output token, and
/// J per *good* token under `cfg`'s traffic classes (each request judged
/// against its own class SLO) — the deployment-cost ledger the ROADMAP's
/// "autoscaler cost reports" item asks for. Rendered by `repro run
/// cluster`-style harness callers; the same numbers reach `repro serve
/// --json` through `MetricsSummary`.
pub fn cost_report(sim: &ClusterSim, cfg: &AutoscaleConfig) -> Report {
    // Same defaulting as the control loop: a default config reports
    // under the deployment's own declared classes.
    let classes =
        if cfg.classes == ClassSet::default() { sim.classes() } else { &cfg.classes };
    let class_names: Vec<&str> = classes.iter().map(|c| c.name.as_str()).collect();
    let mut r = Report::new(format!(
        "Fleet energy cost (classes: {})",
        class_names.join(", ")
    ));
    r.header(&["replica", "energy", "output tok", "J/tok", "J/good tok", "drained"]);
    let fmt_good = |c: &crate::serving::metrics::MetricsCollector| match c
        .energy_per_good_token(classes)
    {
        Some(j) => Cell::val(j, Unit::JoulePerTok),
        None => Cell::text("n/a"),
    };
    for i in 0..sim.num_replicas() {
        let m = &sim.replica(i).metrics;
        let tokens = m.output_tokens();
        r.row(vec![
            Cell::text(format!("{} [{}]", i, sim.device_of(i).name())),
            Cell::val(m.energy_j, Unit::Joules),
            Cell::count(tokens),
            Cell::val(
                if tokens == 0 { 0.0 } else { m.energy_j / tokens as f64 },
                Unit::JoulePerTok,
            ),
            fmt_good(m),
            Cell::text(if sim.router().is_drained(i) { "yes" } else { "no" }),
        ]);
    }
    let fleet = sim.fleet_metrics();
    let tokens = fleet.output_tokens();
    r.row(vec![
        Cell::text("fleet"),
        Cell::val(fleet.energy_j, Unit::Joules),
        Cell::count(tokens),
        Cell::val(
            if tokens == 0 { 0.0 } else { fleet.energy_j / tokens as f64 },
            Unit::JoulePerTok,
        ),
        fmt_good(&fleet),
        Cell::text("-"),
    ]);
    r.note("energy = device power model x busy step time, summed per replica");
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctl() -> Autoscaler {
        Autoscaler::new(AutoscaleConfig::default())
    }

    #[test]
    fn desired_replicas_is_monotone_and_clamped() {
        let c = ctl();
        let cap = 10.0; // one replica's compliant req/s
        let mut last = 0;
        for load in 0..200 {
            let want = c.desired_replicas(load as f64, cap);
            assert!(want >= last, "monotone violated at load {load}");
            assert!((1..=8).contains(&want));
            last = want;
        }
        // Exact sizing at the utilization target: 16 rps / (10 * 0.8) = 2.
        assert_eq!(c.desired_replicas(16.0, 10.0), 2);
        assert_eq!(c.desired_replicas(0.0, 10.0), 1);
        assert_eq!(c.desired_replicas(1e9, 10.0), 8);
    }

    #[test]
    fn decide_scales_up_under_pressure() {
        let c = ctl();
        assert_eq!(
            c.decide(Some(0.5), 10, 2),
            Decision::ScaleUp(DeviceKind::Gaudi2)
        );
        // A starved window is pressure only past the per-replica backlog
        // threshold — warm-up (batches still filling) must NOT scale.
        assert_eq!(c.decide(None, 10, 2), Decision::Hold);
        assert_eq!(
            c.decide(None, 2 * 64 + 1, 2),
            Decision::ScaleUp(DeviceKind::Gaudi2)
        );
        // At the cap: hold, never exceed max_replicas.
        assert_eq!(c.decide(Some(0.5), 10, 8), Decision::Hold);
    }

    #[test]
    fn decide_drains_on_slack_and_holds_otherwise() {
        let c = ctl();
        assert_eq!(c.decide(Some(1.0), 0, 3), Decision::DrainMostExpensive);
        // At min replicas: hold.
        assert_eq!(c.decide(Some(1.0), 0, 1), Decision::Hold);
        // Healthy but not perfect: hold.
        assert_eq!(c.decide(Some(0.97), 1, 3), Decision::Hold);
        // Perfect attainment but a deep queue: hold (slack is not real).
        assert_eq!(c.decide(Some(1.0), 50, 3), Decision::Hold);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_capacity_rejected() {
        ctl().desired_replicas(10.0, 0.0);
    }

    #[test]
    fn cost_report_covers_every_replica_plus_fleet() {
        use crate::config::ServingConfig;
        use crate::models::llama::LlamaConfig;
        let cfg = ServingConfig { replicas: 2, ..Default::default() };
        let mut sim = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
        sim.submit_all(crate::workload::DynamicSonnet::default().generate(
            12,
            f64::INFINITY,
            5,
        ));
        sim.run_to_completion();
        let r = cost_report(&sim, &AutoscaleConfig::default());
        assert_eq!(r.num_rows(), 3, "one row per replica + the fleet total");
        let energy = r.series("energy").unwrap();
        assert!(energy.values.iter().all(|&j| j > 0.0), "busy replicas drew energy");
        // Fleet energy is the sum of the replicas'.
        assert!((energy.values[2] - (energy.values[0] + energy.values[1])).abs() < 1e-9);
        let jpt = r.series("J/tok").unwrap();
        assert!(jpt.values.iter().all(|&x| x.is_finite() && x > 0.0));
    }
}
