//! Paged KV-cache block manager — the PagedAttention memory substrate
//! (paper §4.2 / vLLM). Fixed-size token blocks are allocated on demand
//! per sequence; freeing returns blocks to a free list. The manager is
//! the single source of truth the BlockTable / BlockList layouts are
//! compiled from, and its invariants (no double allocation, conservation,
//! watermark) are property-tested in `rust/tests/proptests.rs`.

use crate::serving::request::RequestId;
use crate::util::fasthash::FastMap;
use crate::util::ceil_div;

/// Physical block index.
pub type BlockId = u32;

/// Paged KV-cache block manager.
#[derive(Debug, Clone)]
pub struct KvBlockManager {
    block_size: usize,
    num_blocks: usize,
    free: Vec<BlockId>,
    /// Per-sequence ordered block lists (logical → physical).
    table: FastMap<RequestId, Vec<BlockId>>,
    /// Free-block watermark kept in reserve for running sequences.
    watermark_blocks: usize,
}

/// Why an allocation was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocError {
    /// Not enough free blocks at all.
    OutOfBlocks,
    /// Enough blocks, but the request would dip below the watermark.
    BelowWatermark,
}

impl KvBlockManager {
    pub fn new(num_blocks: usize, block_size: usize, watermark: f64) -> Self {
        assert!(num_blocks > 0 && block_size > 0);
        assert!((0.0..0.5).contains(&watermark));
        KvBlockManager {
            block_size,
            num_blocks,
            free: (0..num_blocks as BlockId).rev().collect(),
            table: FastMap::default(),
            watermark_blocks: (watermark * num_blocks as f64).ceil() as usize,
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn num_blocks(&self) -> usize {
        self.num_blocks
    }

    pub fn num_free(&self) -> usize {
        self.free.len()
    }

    pub fn num_allocated(&self) -> usize {
        self.num_blocks - self.free.len()
    }

    /// Blocks needed to hold `tokens`.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        ceil_div(tokens, self.block_size)
    }

    /// Can a *new* sequence of `tokens` be admitted without dipping below
    /// the watermark?
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.blocks_for(tokens) + self.watermark_blocks <= self.free.len()
    }

    /// Allocate blocks so sequence `id` can hold `tokens` total. Grows the
    /// existing allocation; never shrinks. New sequences respect the
    /// watermark; growth of existing sequences may consume the reserve.
    pub fn allocate(&mut self, id: RequestId, tokens: usize) -> Result<(), AllocError> {
        let needed_total = self.blocks_for(tokens);
        let have = self.table.get(&id).map_or(0, |v| v.len());
        if needed_total <= have {
            return Ok(());
        }
        let grow = needed_total - have;
        let is_new = have == 0;
        if grow > self.free.len() {
            return Err(AllocError::OutOfBlocks);
        }
        if is_new && grow + self.watermark_blocks > self.free.len() {
            return Err(AllocError::BelowWatermark);
        }
        let entry = self.table.entry(id).or_default();
        for _ in 0..grow {
            entry.push(self.free.pop().expect("checked length"));
        }
        Ok(())
    }

    /// Free all blocks of sequence `id` (finish or preemption).
    pub fn free(&mut self, id: RequestId) {
        if let Some(blocks) = self.table.remove(&id) {
            self.free.extend(blocks);
        }
    }

    /// The physical block list of a sequence (ordered by logical index).
    pub fn blocks_of(&self, id: RequestId) -> Option<&[BlockId]> {
        self.table.get(&id).map(|v| v.as_slice())
    }

    /// All sequences currently holding blocks.
    pub fn holders(&self) -> impl Iterator<Item = RequestId> + '_ {
        self.table.keys().copied()
    }

    /// Invariant check used by tests: every block is either free or owned
    /// by exactly one sequence.
    pub fn check_conservation(&self) -> bool {
        let mut seen = vec![false; self.num_blocks];
        for &b in &self.free {
            if seen[b as usize] {
                return false;
            }
            seen[b as usize] = true;
        }
        for blocks in self.table.values() {
            for &b in blocks {
                if seen[b as usize] {
                    return false;
                }
                seen[b as usize] = true;
            }
        }
        seen.into_iter().all(|x| x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_grow_free_roundtrip() {
        let mut m = KvBlockManager::new(16, 128, 0.0);
        m.allocate(1, 100).unwrap(); // 1 block
        assert_eq!(m.blocks_of(1).unwrap().len(), 1);
        m.allocate(1, 300).unwrap(); // grow to 3
        assert_eq!(m.blocks_of(1).unwrap().len(), 3);
        assert_eq!(m.num_free(), 13);
        // No shrink on smaller request.
        m.allocate(1, 10).unwrap();
        assert_eq!(m.blocks_of(1).unwrap().len(), 3);
        m.free(1);
        assert_eq!(m.num_free(), 16);
        assert!(m.check_conservation());
    }

    #[test]
    fn out_of_blocks() {
        let mut m = KvBlockManager::new(4, 128, 0.0);
        m.allocate(1, 512).unwrap(); // all 4
        assert_eq!(m.allocate(2, 1), Err(AllocError::OutOfBlocks));
        m.free(1);
        m.allocate(2, 1).unwrap();
    }

    #[test]
    fn watermark_blocks_new_sequences_only() {
        let mut m = KvBlockManager::new(10, 128, 0.2); // 2 reserved
        m.allocate(1, 128 * 7).unwrap(); // 7 blocks, 3 free
        // New sequence wanting 2 blocks would leave 1 < watermark 2.
        assert!(!m.can_admit(128 * 2));
        assert_eq!(m.allocate(2, 128 * 2), Err(AllocError::BelowWatermark));
        // But the existing sequence may grow into the reserve.
        m.allocate(1, 128 * 9).unwrap();
        assert_eq!(m.num_free(), 1);
    }

    #[test]
    fn conservation_under_churn() {
        let mut m = KvBlockManager::new(32, 16, 0.05);
        for i in 0..8 {
            m.allocate(i, 16 * (i as usize % 4 + 1)).unwrap();
        }
        for i in (0..8).step_by(2) {
            m.free(i);
        }
        for i in 8..12 {
            let _ = m.allocate(i, 64);
        }
        assert!(m.check_conservation());
    }

    #[test]
    fn blocks_for_rounding() {
        let m = KvBlockManager::new(8, 128, 0.0);
        assert_eq!(m.blocks_for(1), 1);
        assert_eq!(m.blocks_for(128), 1);
        assert_eq!(m.blocks_for(129), 2);
    }
}
