//! Paged KV-cache block manager — the PagedAttention memory substrate
//! (paper §4.2 / vLLM). Fixed-size token blocks are allocated on demand
//! per sequence; freeing returns blocks to a free list. The manager is
//! the single source of truth the BlockTable / BlockList layouts are
//! compiled from, and its invariants (no double allocation, conservation,
//! watermark) are property-tested in `rust/tests/proptests.rs`.
//!
//! Shared-prefix caching (vLLM APC-style) lives *inside* this substrate:
//! a prefix group's cached blocks are ordinary physical blocks from the
//! same pool, held in a ref-counted registry under a finite block budget
//! (`ServingConfig::prefix_cache_blocks`). A sequence whose prefix is
//! resident maps the front of its block list onto the shared blocks
//! (copy-on-read sharing) and allocates exclusively only for the suffix.
//! Idle prefixes are evicted under an [`EvictionPolicy`] when the budget
//! or the physical pool runs dry; prefixes pinned by in-flight sequences
//! are never evicted. Warmth therefore *is* block residency — there is
//! no separate ever-warm set anywhere in the stack.

use crate::serving::request::RequestId;
use crate::util::ceil_div;
use crate::util::fasthash::FastMap;

/// Physical block index.
pub type BlockId = u32;

/// Which idle prefix to evict first when the cache needs room.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvictionPolicy {
    /// Least-recently-used prefix group first.
    Lru,
    /// Cheapest-to-recompute first: smallest `recompute weight x tokens`
    /// score (the weight comes from the device cost model, see
    /// `SimBackend::decode_cost_weight`), LRU as the tie-break.
    CostAware,
}

impl EvictionPolicy {
    pub const ALL: [EvictionPolicy; 2] = [EvictionPolicy::Lru, EvictionPolicy::CostAware];

    pub fn name(&self) -> &'static str {
        match self {
            EvictionPolicy::Lru => "lru",
            EvictionPolicy::CostAware => "cost_aware",
        }
    }

    /// Parse a config-file name (see `ServingConfig::from_json`).
    pub fn parse(s: &str) -> Option<EvictionPolicy> {
        match s {
            "lru" => Some(EvictionPolicy::Lru),
            "cost_aware" | "cost-aware" => Some(EvictionPolicy::CostAware),
            _ => None,
        }
    }
}

/// One resident shared-prefix entry.
#[derive(Debug, Clone)]
struct SharedPrefix {
    blocks: Vec<BlockId>,
    /// Prefix length in tokens (what a hit saves re-prefilling).
    tokens: usize,
    /// Outstanding acquisition pins (scheduler-side admission leases).
    refcount: usize,
    /// Sequence tables currently mapping these blocks at their front.
    /// Tracked independently of `refcount` so eviction can never free a
    /// block a sequence still references, even under pathological
    /// pin/release interleavings (property-tested).
    mapped: usize,
    /// Logical-clock timestamp of the last acquire (LRU order).
    last_use: u64,
    /// Recompute-cost weight recorded at first acquisition (device cost
    /// model scale; any consistent positive scale ranks correctly).
    weight: f64,
}

impl SharedPrefix {
    /// Evictable: no admission pin and no sequence mapping the blocks.
    fn idle(&self) -> bool {
        self.refcount == 0 && self.mapped == 0
    }
}

/// Counters of the shared-prefix cache over a manager's lifetime.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PrefixCacheStats {
    /// Acquisitions that found the prefix resident.
    pub hits: u64,
    /// Acquisitions that warmed a previously non-resident prefix.
    pub misses: u64,
    /// Acquisitions that could not cache at all (no budget / no room).
    pub uncached: u64,
    /// Idle prefixes evicted to make room.
    pub evictions: u64,
}

impl PrefixCacheStats {
    /// Hit fraction over all acquisitions (0 when there were none).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses + self.uncached;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Fold another replica's counters into this one.
    pub fn merge(&mut self, other: &PrefixCacheStats) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.uncached += other.uncached;
        self.evictions += other.evictions;
    }
}

/// Outcome of acquiring a shared prefix for one admitted sequence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrefixAcquire {
    /// The prefix was resident; the sequence shares its blocks (pinned).
    Hit,
    /// The prefix was not resident; blocks were allocated so this prefill
    /// warms it for later sequences (pinned, full prefill price now).
    Warmed,
    /// The cache could not hold the prefix (budget zero, or no evictable
    /// room); the sequence proceeds fully exclusive, nothing pinned.
    Uncached,
}

/// Why an allocation was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocError {
    /// Not enough free blocks at all.
    OutOfBlocks,
    /// Enough blocks, but the request would dip below the watermark.
    BelowWatermark,
}

/// Paged KV-cache block manager.
#[derive(Debug, Clone)]
pub struct KvBlockManager {
    block_size: usize,
    num_blocks: usize,
    free: Vec<BlockId>,
    /// Per-sequence ordered block lists (logical → physical). A prefix-hit
    /// sequence's list *starts with shared blocks*; `free()` returns only
    /// the exclusive tail to the free list.
    table: FastMap<RequestId, Vec<BlockId>>,
    /// Free-block watermark kept in reserve for running sequences.
    watermark_blocks: usize,
    /// Cap on blocks the shared-prefix registry may hold resident.
    /// 0 disables prefix caching; >= `num_blocks` is effectively
    /// unbounded (only physical pressure can then limit residency).
    prefix_capacity: usize,
    eviction: EvictionPolicy,
    /// Resident prefix groups.
    shared: FastMap<u64, SharedPrefix>,
    /// Physical block -> owning prefix group, for `free()` filtering.
    shared_owner: FastMap<BlockId, u64>,
    /// Blocks currently held by the shared registry (Σ entry sizes).
    shared_blocks_resident: usize,
    /// Logical clock for LRU ordering.
    tick: u64,
    stats: PrefixCacheStats,
}

impl KvBlockManager {
    /// A manager with prefix caching disabled (capacity 0) — the substrate
    /// most unit tests and the real-numerics engine use.
    pub fn new(num_blocks: usize, block_size: usize, watermark: f64) -> Self {
        assert!(num_blocks > 0 && block_size > 0);
        assert!((0.0..0.5).contains(&watermark));
        KvBlockManager {
            block_size,
            num_blocks,
            free: (0..num_blocks as BlockId).rev().collect(),
            table: FastMap::default(),
            watermark_blocks: (watermark * num_blocks as f64).ceil() as usize,
            prefix_capacity: 0,
            eviction: EvictionPolicy::Lru,
            shared: FastMap::default(),
            shared_owner: FastMap::default(),
            shared_blocks_resident: 0,
            tick: 0,
            stats: PrefixCacheStats::default(),
        }
    }

    /// Enable shared-prefix caching under a `capacity`-block budget with
    /// the given eviction policy (builder-style).
    pub fn with_prefix_cache(mut self, capacity: usize, eviction: EvictionPolicy) -> Self {
        self.prefix_capacity = capacity;
        self.eviction = eviction;
        self
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn num_blocks(&self) -> usize {
        self.num_blocks
    }

    pub fn num_free(&self) -> usize {
        self.free.len()
    }

    pub fn num_allocated(&self) -> usize {
        self.num_blocks - self.free.len()
    }

    /// Shared-prefix budget in blocks (0 = caching disabled).
    pub fn prefix_capacity(&self) -> usize {
        self.prefix_capacity
    }

    /// Free blocks held in reserve for running sequences (the scheduler
    /// folds this into prefix-acquisition reserves).
    pub fn watermark_blocks(&self) -> usize {
        self.watermark_blocks
    }

    pub fn eviction_policy(&self) -> EvictionPolicy {
        self.eviction
    }

    /// Blocks currently resident in the shared-prefix registry.
    pub fn prefix_resident_blocks(&self) -> usize {
        self.shared_blocks_resident
    }

    /// Number of resident prefix groups.
    pub fn num_resident_prefixes(&self) -> usize {
        self.shared.len()
    }

    /// Is `prefix_id`'s shared prefix resident right now? This is the
    /// query `RoutePolicy::PrefixAffinity` scores on — warmth that
    /// survived eviction, not a last-writer guess.
    pub fn prefix_resident(&self, prefix_id: u64) -> bool {
        self.shared.contains_key(&prefix_id)
    }

    /// Lifetime hit/miss/eviction counters of the prefix cache.
    pub fn prefix_stats(&self) -> PrefixCacheStats {
        self.stats
    }

    /// Blocks needed to hold `tokens`.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        ceil_div(tokens, self.block_size)
    }

    /// Can a *new* sequence of `tokens` be admitted without dipping below
    /// the watermark? (Conservative: ignores any prefix sharing the
    /// sequence might enjoy.)
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.blocks_for(tokens) + self.watermark_blocks <= self.free.len()
    }

    /// Largest `k <= cap` such that growing every sequence in `kv_lens`
    /// by `k` tokens — one token per tick for `k` ticks, the shape of a
    /// macro-stepping window — allocates at most the currently-free
    /// block count. Growth of an *existing* sequence ignores the
    /// watermark (only new-sequence admission reserves it), so free
    /// blocks are the only bound; within the returned window every
    /// per-tick `allocate` succeeds without eviction or preemption. The
    /// total block need is monotone in `k`, hence the binary search.
    pub fn max_stable_growth(&self, kv_lens: &[usize], cap: usize) -> usize {
        let free = self.free.len();
        let need = |k: usize| -> usize {
            kv_lens.iter().map(|&kv| self.blocks_for(kv + k) - self.blocks_for(kv)).sum()
        };
        let (mut lo, mut hi) = (0usize, cap);
        while lo < hi {
            let mid = lo + (hi - lo + 1) / 2;
            if need(mid) <= free {
                lo = mid;
            } else {
                hi = mid - 1;
            }
        }
        lo
    }

    /// Acquire the shared prefix `prefix_id` (length `prefix_tokens`,
    /// recompute weight `weight`) for one sequence about to prefill,
    /// pinning it against eviction. `reserve` blocks are left untouched in
    /// the free list so the caller's subsequent sequence allocation cannot
    /// fail (the scheduler passes the sequence's own block need plus the
    /// watermark). Idle prefixes are evicted per policy to make room in
    /// the budget and the pool; when room still cannot be found the
    /// acquisition degrades to [`PrefixAcquire::Uncached`].
    pub fn acquire_prefix(
        &mut self,
        prefix_id: u64,
        prefix_tokens: usize,
        weight: f64,
        reserve: usize,
    ) -> PrefixAcquire {
        self.tick += 1;
        if let Some(p) = self.shared.get_mut(&prefix_id) {
            p.refcount += 1;
            p.last_use = self.tick;
            self.stats.hits += 1;
            return PrefixAcquire::Hit;
        }
        let need = self.blocks_for(prefix_tokens.max(1));
        if self.prefix_capacity == 0 || need > self.prefix_capacity {
            self.stats.uncached += 1;
            return PrefixAcquire::Uncached;
        }
        // Evict idle prefixes until both the budget and the physical pool
        // have room (never touching `reserve` free blocks).
        while self.shared_blocks_resident + need > self.prefix_capacity
            || self.free.len() < need + reserve
        {
            if !self.evict_one_idle_prefix() {
                self.stats.uncached += 1;
                return PrefixAcquire::Uncached;
            }
        }
        let blocks: Vec<BlockId> =
            (0..need).map(|_| self.free.pop().expect("room checked")).collect();
        for &b in &blocks {
            self.shared_owner.insert(b, prefix_id);
        }
        self.shared_blocks_resident += need;
        self.shared.insert(
            prefix_id,
            SharedPrefix {
                blocks,
                tokens: prefix_tokens.max(1),
                refcount: 1,
                mapped: 0,
                last_use: self.tick,
                weight: weight.max(f64::MIN_POSITIVE),
            },
        );
        self.stats.misses += 1;
        PrefixAcquire::Warmed
    }

    /// Release one sequence's pin on `prefix_id`. The blocks stay
    /// resident (warm) until evicted.
    pub fn release_prefix(&mut self, prefix_id: u64) {
        if let Some(p) = self.shared.get_mut(&prefix_id) {
            assert!(p.refcount > 0, "unbalanced release of prefix {prefix_id}");
            p.refcount -= 1;
        }
    }

    /// Evict one idle (unpinned) prefix per the policy; returns whether
    /// anything was evicted. The scheduler calls this under decode memory
    /// pressure before resorting to preemption.
    pub fn evict_one_idle_prefix(&mut self) -> bool {
        let victim = self
            .shared
            .iter()
            .filter(|(_, p)| p.idle())
            .min_by(|(_, a), (_, b)| match self.eviction {
                EvictionPolicy::Lru => a.last_use.cmp(&b.last_use),
                EvictionPolicy::CostAware => (a.weight * a.tokens as f64)
                    .total_cmp(&(b.weight * b.tokens as f64))
                    .then(a.last_use.cmp(&b.last_use)),
            })
            .map(|(id, _)| *id);
        match victim {
            Some(id) => {
                let p = self.shared.remove(&id).expect("victim exists");
                for b in &p.blocks {
                    self.shared_owner.remove(b);
                }
                self.shared_blocks_resident -= p.blocks.len();
                self.free.extend(p.blocks);
                self.stats.evictions += 1;
                true
            }
            None => false,
        }
    }

    /// Allocate blocks so sequence `id` can hold `tokens` total. Grows the
    /// existing allocation; never shrinks. New sequences respect the
    /// watermark; growth of existing sequences may consume the reserve.
    pub fn allocate(&mut self, id: RequestId, tokens: usize) -> Result<(), AllocError> {
        self.allocate_prefixed(id, tokens, None)
    }

    /// Like [`allocate`](Self::allocate), but a *new* sequence holding a
    /// pin on resident prefix `prefix_id` maps the front of its block
    /// list onto the shared blocks and allocates exclusively only for the
    /// remainder (copy-on-read sharing). Growth of an existing sequence
    /// ignores `prefix_id` (the share is already mapped).
    pub fn allocate_prefixed(
        &mut self,
        id: RequestId,
        tokens: usize,
        prefix_id: Option<u64>,
    ) -> Result<(), AllocError> {
        let needed_total = self.blocks_for(tokens);
        let have = self.table.get(&id).map_or(0, |v| v.len());
        if needed_total <= have {
            return Ok(());
        }
        let is_new = have == 0;
        let shared_front: Vec<BlockId> = match (is_new, prefix_id) {
            (true, Some(p)) => self.shared.get(&p).map_or(Vec::new(), |sp| {
                sp.blocks[..sp.blocks.len().min(needed_total)].to_vec()
            }),
            _ => Vec::new(),
        };
        let grow = needed_total - have - shared_front.len();
        if grow > self.free.len() {
            return Err(AllocError::OutOfBlocks);
        }
        if is_new && grow + self.watermark_blocks > self.free.len() {
            return Err(AllocError::BelowWatermark);
        }
        if !shared_front.is_empty() {
            // The mapping itself blocks eviction (independent of pins).
            let p = prefix_id.expect("shared front implies a prefix id");
            self.shared.get_mut(&p).expect("resident checked").mapped += 1;
        }
        let entry = self.table.entry(id).or_default();
        entry.extend(shared_front);
        for _ in 0..grow {
            entry.push(self.free.pop().expect("checked length"));
        }
        Ok(())
    }

    /// Free all blocks of sequence `id` (finish or preemption). Shared
    /// prefix blocks mapped at the front of the list stay resident —
    /// only the exclusive tail returns to the free list. (The scheduler
    /// releases the prefix *pin* separately via `release_prefix`.)
    pub fn free(&mut self, id: RequestId) {
        if let Some(blocks) = self.table.remove(&id) {
            // A sequence maps at most one group's front; unmap it.
            if let Some(&g) = blocks.iter().find_map(|b| self.shared_owner.get(b)) {
                let p = self.shared.get_mut(&g).expect("owned block implies residency");
                debug_assert!(p.mapped > 0, "unmap without a mapping");
                p.mapped = p.mapped.saturating_sub(1);
            }
            self.free.extend(blocks.into_iter().filter(|b| !self.shared_owner.contains_key(b)));
        }
    }

    /// The physical block list of a sequence (ordered by logical index).
    pub fn blocks_of(&self, id: RequestId) -> Option<&[BlockId]> {
        self.table.get(&id).map(|v| v.as_slice())
    }

    /// All sequences currently holding blocks.
    pub fn holders(&self) -> impl Iterator<Item = RequestId> + '_ {
        self.table.keys().copied()
    }

    /// Invariant check used by tests: every physical block is exactly one
    /// of free, exclusively owned by one sequence, or resident in the
    /// shared-prefix registry (where it may be mapped by any number of
    /// sequence tables); and the resident total respects the budget.
    pub fn check_conservation(&self) -> bool {
        let mut seen = vec![false; self.num_blocks];
        for &b in &self.free {
            if seen[b as usize] || self.shared_owner.contains_key(&b) {
                return false;
            }
            seen[b as usize] = true;
        }
        let mut shared_count = 0usize;
        for p in self.shared.values() {
            for &b in &p.blocks {
                if seen[b as usize] {
                    return false;
                }
                seen[b as usize] = true;
                shared_count += 1;
            }
        }
        if shared_count != self.shared_blocks_resident
            || (self.prefix_capacity > 0 && shared_count > self.prefix_capacity)
        {
            return false;
        }
        for blocks in self.table.values() {
            for &b in blocks {
                if self.shared_owner.contains_key(&b) {
                    // Shared block mapped by a sequence: already counted
                    // once via the registry; sharing is the point.
                    continue;
                }
                if seen[b as usize] {
                    return false;
                }
                seen[b as usize] = true;
            }
        }
        seen.into_iter().all(|x| x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_grow_free_roundtrip() {
        let mut m = KvBlockManager::new(16, 128, 0.0);
        m.allocate(1, 100).unwrap(); // 1 block
        assert_eq!(m.blocks_of(1).unwrap().len(), 1);
        m.allocate(1, 300).unwrap(); // grow to 3
        assert_eq!(m.blocks_of(1).unwrap().len(), 3);
        assert_eq!(m.num_free(), 13);
        // No shrink on smaller request.
        m.allocate(1, 10).unwrap();
        assert_eq!(m.blocks_of(1).unwrap().len(), 3);
        m.free(1);
        assert_eq!(m.num_free(), 16);
        assert!(m.check_conservation());
    }

    #[test]
    fn out_of_blocks() {
        let mut m = KvBlockManager::new(4, 128, 0.0);
        m.allocate(1, 512).unwrap(); // all 4
        assert_eq!(m.allocate(2, 1), Err(AllocError::OutOfBlocks));
        m.free(1);
        m.allocate(2, 1).unwrap();
    }

    #[test]
    fn watermark_blocks_new_sequences_only() {
        let mut m = KvBlockManager::new(10, 128, 0.2); // 2 reserved
        m.allocate(1, 128 * 7).unwrap(); // 7 blocks, 3 free
        // New sequence wanting 2 blocks would leave 1 < watermark 2.
        assert!(!m.can_admit(128 * 2));
        assert_eq!(m.allocate(2, 128 * 2), Err(AllocError::BelowWatermark));
        // But the existing sequence may grow into the reserve.
        m.allocate(1, 128 * 9).unwrap();
        assert_eq!(m.num_free(), 1);
    }

    #[test]
    fn conservation_under_churn() {
        let mut m = KvBlockManager::new(32, 16, 0.05);
        for i in 0..8 {
            m.allocate(i, 16 * (i as usize % 4 + 1)).unwrap();
        }
        for i in (0..8).step_by(2) {
            m.free(i);
        }
        for i in 8..12 {
            let _ = m.allocate(i, 64);
        }
        assert!(m.check_conservation());
    }

    #[test]
    fn blocks_for_rounding() {
        let m = KvBlockManager::new(8, 128, 0.0);
        assert_eq!(m.blocks_for(1), 1);
        assert_eq!(m.blocks_for(128), 1);
        assert_eq!(m.blocks_for(129), 2);
    }

    #[test]
    fn max_stable_growth_matches_brute_force() {
        let mut m = KvBlockManager::new(16, 4, 0.0);
        m.allocate(1, 6).unwrap(); // 2 blocks
        m.allocate(2, 9).unwrap(); // 3 blocks -> 11 free
        let kv = [6usize, 9];
        let need = |k: usize| -> usize {
            kv.iter().map(|&v| m.blocks_for(v + k) - m.blocks_for(v)).sum()
        };
        for cap in 0..48 {
            let k = m.max_stable_growth(&kv, cap);
            // Maximal feasible: k fits, and k+1 (when under cap) does not.
            assert!(k <= cap);
            assert!(need(k) <= m.num_free(), "cap {cap} k {k}");
            if k < cap {
                assert!(need(k + 1) > m.num_free(), "cap {cap} k {k} not maximal");
            }
        }
        // The watermark must NOT bound growth (existing sequences may dip
        // into the reserve, so neither may the window proof count it):
        // 14 free blocks ahead of the 2 held -> the sequence can reach all
        // 16 blocks = 64 tokens, i.e. grow by 58 from 6 — reserve ignored.
        let mut w = KvBlockManager::new(16, 4, 0.25); // 4 reserved
        w.allocate(1, 6).unwrap();
        assert_eq!(w.max_stable_growth(&[6], 64), 58);
    }

    #[test]
    fn prefix_acquire_hit_miss_and_sharing() {
        let mut m = KvBlockManager::new(16, 128, 0.0).with_prefix_cache(8, EvictionPolicy::Lru);
        // First acquisition warms: 2 shared blocks leave the free list.
        assert_eq!(m.acquire_prefix(7, 200, 1.0, 0), PrefixAcquire::Warmed);
        assert_eq!(m.prefix_resident_blocks(), 2);
        assert_eq!(m.num_free(), 14);
        assert!(m.prefix_resident(7));
        // A sequence with the pin maps the shared front, allocating only
        // the suffix exclusively: 5 blocks total, 3 exclusive.
        m.allocate_prefixed(1, 600, Some(7)).unwrap();
        assert_eq!(m.blocks_of(1).unwrap().len(), 5);
        assert_eq!(m.num_free(), 11);
        assert!(m.check_conservation());
        // Second sequence hits and shares the same front.
        assert_eq!(m.acquire_prefix(7, 200, 1.0, 0), PrefixAcquire::Hit);
        m.allocate_prefixed(2, 600, Some(7)).unwrap();
        assert_eq!(m.blocks_of(2).unwrap()[..2], m.blocks_of(1).unwrap()[..2]);
        assert!(m.check_conservation());
        // Freeing a sequence returns only its exclusive tail.
        m.free(1);
        m.release_prefix(7);
        assert_eq!(m.num_free(), 11); // 3 exclusive back, 3 still out for seq 2...
        assert!(m.prefix_resident(7));
        m.free(2);
        m.release_prefix(7);
        assert_eq!(m.num_free(), 14); // everything but the warm prefix
        assert!(m.check_conservation());
        let s = m.prefix_stats();
        assert_eq!((s.hits, s.misses, s.uncached), (1, 1, 0));
    }

    #[test]
    fn pinned_prefix_never_evicted_and_idle_evicts_lru() {
        // Budget of 2 blocks: one 1-block prefix at a time once pinned.
        let mut m = KvBlockManager::new(16, 128, 0.0).with_prefix_cache(2, EvictionPolicy::Lru);
        assert_eq!(m.acquire_prefix(1, 100, 1.0, 0), PrefixAcquire::Warmed);
        assert_eq!(m.acquire_prefix(2, 100, 1.0, 0), PrefixAcquire::Warmed);
        // Both pinned; a third group finds no evictable room.
        assert_eq!(m.acquire_prefix(3, 100, 1.0, 0), PrefixAcquire::Uncached);
        assert!(m.prefix_resident(1) && m.prefix_resident(2));
        // Unpin group 1 (the older): group 3 now evicts it, not group 2.
        m.release_prefix(1);
        assert_eq!(m.acquire_prefix(3, 100, 1.0, 0), PrefixAcquire::Warmed);
        assert!(!m.prefix_resident(1));
        assert!(m.prefix_resident(2) && m.prefix_resident(3));
        assert_eq!(m.prefix_stats().evictions, 1);
        assert!(m.check_conservation());
    }

    #[test]
    fn cost_aware_evicts_cheapest_recompute_first() {
        let mut m =
            KvBlockManager::new(32, 128, 0.0).with_prefix_cache(4, EvictionPolicy::CostAware);
        // Group 10: big (2 blocks, expensive to recompute); group 11:
        // small (1 block, cheap). Same weight scale.
        assert_eq!(m.acquire_prefix(10, 256, 2.0, 0), PrefixAcquire::Warmed);
        assert_eq!(m.acquire_prefix(11, 100, 2.0, 0), PrefixAcquire::Warmed);
        m.release_prefix(10);
        m.release_prefix(11);
        // A 2-block newcomer must evict: cost-aware picks the cheap small
        // group even though the big one is older (LRU would pick 10).
        assert_eq!(m.acquire_prefix(12, 256, 2.0, 0), PrefixAcquire::Warmed);
        assert!(m.prefix_resident(10), "expensive prefix must survive");
        assert!(!m.prefix_resident(11), "cheap prefix is the victim");
        assert!(m.check_conservation());
    }

    #[test]
    fn acquire_respects_reserve_and_zero_capacity() {
        let mut m = KvBlockManager::new(4, 128, 0.0).with_prefix_cache(4, EvictionPolicy::Lru);
        // Reserving all free blocks leaves no room to warm.
        assert_eq!(m.acquire_prefix(5, 100, 1.0, 4), PrefixAcquire::Uncached);
        assert_eq!(m.num_free(), 4);
        // Capacity 0 never caches.
        let mut off = KvBlockManager::new(4, 128, 0.0);
        assert_eq!(off.acquire_prefix(5, 100, 1.0, 0), PrefixAcquire::Uncached);
        assert_eq!(off.prefix_stats().uncached, 1);
    }

    #[test]
    fn free_of_missing_prefix_release_is_harmless() {
        let mut m = KvBlockManager::new(8, 128, 0.0);
        m.release_prefix(99); // not resident: no-op
        m.free(42); // never allocated: no-op
        assert!(m.check_conservation());
    }
}
