//! BlockTable vs BlockList — the two KV-cache index layouts of the §4.2
//! case study (Fig 16), compiled from the same `KvBlockManager` state.
//!
//! * `BlockTable` (vLLM_base): a 2D `[batch × max_blocks]` tensor padded
//!   with zeros for shorter sequences. The padded entries cause redundant
//!   KV block gathers on the device.
//! * `BlockList` (vLLM_opt): a flat 1D concatenation of only the effectual
//!   block indices plus per-sequence offsets (a CSR-style layout), which
//!   eliminates padding work and lets the graph compiler slice the gather
//!   for MME/TPC pipelining.

use crate::serving::kv_cache::{BlockId, KvBlockManager};
use crate::serving::request::RequestId;

/// Zero-padded 2D layout (vLLM_base).
#[derive(Debug, Clone, PartialEq)]
pub struct BlockTable {
    pub batch: usize,
    pub max_blocks: usize,
    /// Row-major `[batch][max_blocks]`; 0 is used as the padding index
    /// (like the Gaudi vLLM fork, block 0 is sacrificed as the pad target).
    pub entries: Vec<BlockId>,
    /// Real block count per row (for accounting; the device sees padding).
    pub effectual: Vec<usize>,
}

impl BlockTable {
    /// Build from manager state for the given batch of sequences.
    pub fn build(mgr: &KvBlockManager, seqs: &[RequestId]) -> BlockTable {
        let rows: Vec<&[BlockId]> =
            seqs.iter().map(|id| mgr.blocks_of(*id).unwrap_or(&[])).collect();
        let max_blocks = rows.iter().map(|r| r.len()).max().unwrap_or(0);
        let mut entries = Vec::with_capacity(seqs.len() * max_blocks);
        let mut effectual = Vec::with_capacity(seqs.len());
        for r in &rows {
            entries.extend_from_slice(r);
            entries.extend(std::iter::repeat(0).take(max_blocks - r.len()));
            effectual.push(r.len());
        }
        BlockTable { batch: seqs.len(), max_blocks, entries, effectual }
    }

    /// Total entries the device will gather (including padding).
    pub fn padded_entries(&self) -> usize {
        self.batch * self.max_blocks
    }

    /// Fraction of entries that are zero padding — the x-axis of Fig 17(b).
    pub fn padding_fraction(&self) -> f64 {
        let total = self.padded_entries();
        if total == 0 {
            return 0.0;
        }
        let real: usize = self.effectual.iter().sum();
        1.0 - real as f64 / total as f64
    }
}

/// Flat effectual layout (vLLM_opt).
#[derive(Debug, Clone, PartialEq)]
pub struct BlockList {
    pub batch: usize,
    /// Concatenated effectual block ids.
    pub blocks: Vec<BlockId>,
    /// CSR-style row offsets: row i spans `blocks[offsets[i]..offsets[i+1]]`.
    pub offsets: Vec<usize>,
}

impl BlockList {
    pub fn build(mgr: &KvBlockManager, seqs: &[RequestId]) -> BlockList {
        let mut blocks = Vec::new();
        let mut offsets = Vec::with_capacity(seqs.len() + 1);
        offsets.push(0);
        for id in seqs {
            blocks.extend_from_slice(mgr.blocks_of(*id).unwrap_or(&[]));
            offsets.push(blocks.len());
        }
        BlockList { batch: seqs.len(), blocks, offsets }
    }

    /// Entries the device gathers — exactly the effectual blocks.
    pub fn entries(&self) -> usize {
        self.blocks.len()
    }

    pub fn row(&self, i: usize) -> &[BlockId] {
        &self.blocks[self.offsets[i]..self.offsets[i + 1]]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr_with(lens: &[usize]) -> (KvBlockManager, Vec<RequestId>) {
        let mut m = KvBlockManager::new(256, 128, 0.0);
        let ids: Vec<RequestId> = (0..lens.len() as u64).collect();
        for (i, &l) in lens.iter().enumerate() {
            m.allocate(i as u64, l).unwrap();
        }
        (m, ids)
    }

    #[test]
    fn table_pads_to_longest_row() {
        let (m, ids) = mgr_with(&[128, 512, 256]); // 1, 4, 2 blocks
        let t = BlockTable::build(&m, &ids);
        assert_eq!(t.max_blocks, 4);
        assert_eq!(t.padded_entries(), 12);
        assert_eq!(t.effectual, vec![1, 4, 2]);
        // 7 real of 12 → padding fraction 5/12.
        assert!((t.padding_fraction() - 5.0 / 12.0).abs() < 1e-12);
    }

    #[test]
    fn list_has_no_padding() {
        let (m, ids) = mgr_with(&[128, 512, 256]);
        let l = BlockList::build(&m, &ids);
        assert_eq!(l.entries(), 7);
        assert_eq!(l.offsets, vec![0, 1, 5, 7]);
        assert_eq!(l.row(1).len(), 4);
    }

    #[test]
    fn same_manager_state_same_effectual_blocks() {
        let (m, ids) = mgr_with(&[300, 700]);
        let t = BlockTable::build(&m, &ids);
        let l = BlockList::build(&m, &ids);
        let real: usize = t.effectual.iter().sum();
        assert_eq!(real, l.entries());
    }

    #[test]
    fn equal_lengths_zero_padding() {
        let (m, ids) = mgr_with(&[512, 512, 512]);
        let t = BlockTable::build(&m, &ids);
        assert_eq!(t.padding_fraction(), 0.0);
    }

    #[test]
    fn empty_batch() {
        let (m, _) = mgr_with(&[]);
        let t = BlockTable::build(&m, &[]);
        assert_eq!(t.padded_entries(), 0);
        assert_eq!(t.padding_fraction(), 0.0);
        let l = BlockList::build(&m, &[]);
        assert_eq!(l.entries(), 0);
    }
}
