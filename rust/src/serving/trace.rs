//! Step-level execution tracing for the serving engine: a timeline of
//! scheduling decisions (step kind, batch size, KV occupancy, simulated
//! duration) that can be exported as CSV for offline analysis — the
//! observability substrate a production deployment of this coordinator
//! would need, and the tool used to debug the Fig 17(d) SLO knee.

/// Kind of an executed step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceStepKind {
    Prefill,
    Decode,
    Idle,
}

impl TraceStepKind {
    pub fn name(&self) -> &'static str {
        match self {
            TraceStepKind::Prefill => "prefill",
            TraceStepKind::Decode => "decode",
            TraceStepKind::Idle => "idle",
        }
    }
}

/// One traced step.
#[derive(Debug, Clone, Copy)]
pub struct TraceEvent {
    /// Engine clock at step start.
    pub t_start: f64,
    pub kind: TraceStepKind,
    /// Sequences in the step.
    pub batch: usize,
    /// Tokens processed (prompt tokens for prefill, batch for decode).
    pub tokens: usize,
    /// Step duration (simulated or wall).
    pub duration: f64,
    /// KV blocks in use after the step.
    pub kv_blocks_used: usize,
}

/// Ring-buffer trace collector (bounded memory, keeps the newest events).
#[derive(Debug, Clone)]
pub struct Trace {
    events: Vec<TraceEvent>,
    capacity: usize,
    head: usize,
    total_recorded: u64,
}

impl Trace {
    pub fn new(capacity: usize) -> Trace {
        assert!(capacity > 0);
        Trace { events: Vec::with_capacity(capacity), capacity, head: 0, total_recorded: 0 }
    }

    pub fn record(&mut self, ev: TraceEvent) {
        if self.events.len() < self.capacity {
            self.events.push(ev);
        } else {
            self.events[self.head] = ev;
            self.head = (self.head + 1) % self.capacity;
        }
        self.total_recorded += 1;
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn total_recorded(&self) -> u64 {
        self.total_recorded
    }

    /// Events in chronological order.
    pub fn iter(&self) -> impl Iterator<Item = &TraceEvent> {
        self.events[self.head..].iter().chain(self.events[..self.head].iter())
    }

    /// Fraction of traced time spent in decode steps (batching health).
    pub fn decode_time_share(&self) -> f64 {
        let mut decode = 0.0;
        let mut total = 0.0;
        for e in self.iter() {
            total += e.duration;
            if e.kind == TraceStepKind::Decode {
                decode += e.duration;
            }
        }
        if total == 0.0 {
            0.0
        } else {
            decode / total
        }
    }

    /// Mean decode batch size (weighted by step count).
    pub fn mean_decode_batch(&self) -> f64 {
        let decodes: Vec<usize> =
            self.iter().filter(|e| e.kind == TraceStepKind::Decode).map(|e| e.batch).collect();
        if decodes.is_empty() {
            0.0
        } else {
            decodes.iter().sum::<usize>() as f64 / decodes.len() as f64
        }
    }

    /// CSV export: t_start,kind,batch,tokens,duration,kv_blocks_used.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("t_start,kind,batch,tokens,duration,kv_blocks_used\n");
        for e in self.iter() {
            out.push_str(&format!(
                "{:.9},{},{},{},{:.9},{}\n",
                e.t_start,
                e.kind.name(),
                e.batch,
                e.tokens,
                e.duration,
                e.kv_blocks_used
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(t: f64, kind: TraceStepKind, batch: usize, dur: f64) -> TraceEvent {
        TraceEvent { t_start: t, kind, batch, tokens: batch, duration: dur, kv_blocks_used: 10 }
    }

    #[test]
    fn records_in_order() {
        let mut tr = Trace::new(8);
        for i in 0..5 {
            tr.record(ev(i as f64, TraceStepKind::Decode, 4, 0.1));
        }
        let ts: Vec<f64> = tr.iter().map(|e| e.t_start).collect();
        assert_eq!(ts, vec![0.0, 1.0, 2.0, 3.0, 4.0]);
        assert_eq!(tr.total_recorded(), 5);
    }

    #[test]
    fn ring_buffer_keeps_newest() {
        let mut tr = Trace::new(3);
        for i in 0..7 {
            tr.record(ev(i as f64, TraceStepKind::Decode, 1, 0.1));
        }
        let ts: Vec<f64> = tr.iter().map(|e| e.t_start).collect();
        assert_eq!(ts, vec![4.0, 5.0, 6.0]);
        assert_eq!(tr.len(), 3);
        assert_eq!(tr.total_recorded(), 7);
    }

    #[test]
    fn aggregates() {
        let mut tr = Trace::new(16);
        tr.record(ev(0.0, TraceStepKind::Prefill, 2, 0.3));
        tr.record(ev(0.3, TraceStepKind::Decode, 8, 0.6));
        tr.record(ev(0.9, TraceStepKind::Decode, 4, 0.1));
        assert!((tr.decode_time_share() - 0.7).abs() < 1e-12);
        assert!((tr.mean_decode_batch() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut tr = Trace::new(4);
        tr.record(ev(0.0, TraceStepKind::Idle, 0, 0.0));
        let csv = tr.to_csv();
        assert!(csv.starts_with("t_start,kind"));
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.contains("idle"));
    }

    #[test]
    fn empty_trace_sane() {
        let tr = Trace::new(4);
        assert!(tr.is_empty());
        assert_eq!(tr.decode_time_share(), 0.0);
        assert_eq!(tr.mean_decode_batch(), 0.0);
    }
}
