//! Cluster-scale data-parallel serving simulator: N engine replicas behind
//! the admission `Router`, advanced by an indexed discrete-event core.
//!
//! This is the deployment shape the paper's §6 serving evaluation points
//! at — vLLM-style fleets serve heavy traffic by running many independent
//! engine replicas behind a router — and it turns the per-device question
//! of Fig 17 into the production question: *how many Gaudi-2 vs A100
//! replicas does a given SLO need?* (`repro run cluster`). Fleets may be
//! **heterogeneous**: each replica carries its own device config
//! (`ServingConfig::fleet`, mixed Gaudi-2 + A100 behind one router), the
//! router weighs per-replica decode cost, and `repro run cluster-sweep`
//! walks offered load across fleet mixes to trace the goodput-under-SLO
//! frontier.
//!
//! Event core (indexed next-event dispatch): pending arrivals live in a
//! min-heap keyed `(due, enqueue seq)` and working replicas in a min-heap
//! of `(wake_time, replica)` entries — exactly one entry per replica with
//! work, keyed by `Engine::next_tick()`. Every iteration pops whichever
//! event is earliest, O(log n) per event instead of the former
//! O(replicas) scan per step and O(queue) sorted insert per arrival
//! (`repro run sim-speed` tracks the resulting events/sec).
//!
//! Same-time ordering policy (pinned — legacy runs stay bitwise-equal):
//! 1. an arrival due at or before the earliest replica wake delivers
//!    first (arrivals beat replica steps at equal timestamps);
//! 2. equal-due arrivals deliver FIFO by enqueue order, matching the old
//!    sorted queue's `<=` partition point;
//! 3. equal-wake replicas step lowest-index-first, matching the old
//!    scan's first-of-equal-minima `min_by`;
//! 4. a replica whose only work is a future arrival wakes at its
//!    *lagging clock* (see `Engine::next_tick`), so its no-op warm-up
//!    steps run exactly where the scan loop ran them.
//!
//! Replica clocks are therefore never rewound, arrivals are routed in
//! order at their arrival times, and with one replica the step sequence
//! is *identical* to a single `Engine` run (asserted bit-for-bit in
//! `rust/tests/integration_cluster.rs`). The pre-refactor scan loop is
//! retained behind the hidden `ClusterSim::new_scan_oracle` constructor
//! solely as the oracle for the bitwise-equivalence property tests
//! (`rust/tests/proptests.rs`) and the `sim-speed` baseline.
//!
//! Streaming arrivals: `feed()` attaches a lazy
//! `Iterator<Item = Request>` (`workload::ArrivalStream` — constant-rate,
//! diurnal or MMPP) pulled one request at a time as virtual time reaches
//! it, so a million-request day on a 100-replica fleet holds O(open
//! requests) in memory rather than the whole trace; the arrival heap then
//! carries only backpressure requeues. `run_autoscaled` interleaves the
//! same event core with periodic control ticks for `serving::autoscale`
//! (the pump limit *is* the control-tick event: it fires after every
//! event at or before the tick, exactly as the legacy loop ordered it).
//!
//! Backpressure: when the router's global queue cap rejects an arrival
//! (`QueueFull`), the request is rescheduled as a wake event just past
//! the earliest busy replica's clock (`floor.max(due) + REQUEUE_EPS`,
//! the exact legacy retry time — the epsilon is load-bearing and part of
//! the pinned event-ordering policy, see [`REQUEUE_EPS`] and `deliver`)
//! — it retries as soon as the fleet has made progress, preserving
//! arrival order among retries. The request's *arrival* timestamp is
//! untouched, so queueing delay from backpressure shows up in its TTFT,
//! exactly as a client would see it.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use crate::config::{DeviceKind, ReplicaSpec, ServingConfig};
use crate::models::llama::LlamaConfig;
use crate::serving::autoscale::Autoscaler;
use crate::serving::chaos::{self, ChaosStats, ControlKind, FaultSchedule};
use crate::serving::engine::{ClockSource, Engine, SimBackend};
use crate::serving::metrics::{MetricsCollector, MetricsSummary, RequestMetrics};
use crate::serving::qos::ClassSet;
use crate::serving::request::{Request, RequestId};
use crate::serving::router::{QueueFull, Router};
use crate::util::fasthash::FastMap;

/// Backpressure retry offset: a `QueueFull` arrival is requeued at
/// `requeue_floor().max(due) + REQUEUE_EPS`. The epsilon is load-bearing
/// under same-time policy 1 (arrivals beat equal-time replica steps): a
/// retry at exactly the floor would fire *before* the replica step that
/// frees queue capacity and spin forever. Its exact value is part of the
/// pinned event-ordering policy — changing it reorders every
/// backpressured trace, so it is a named constant rather than a literal.
pub const REQUEUE_EPS: f64 = 1e-6;

/// Which event loop drives `pump`: the indexed heap core (default), or
/// the retained pre-refactor scan loop (the parity/benchmark oracle).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum DispatchMode {
    Indexed,
    ScanOracle,
}

/// Pending arrival in the indexed core's event heap, ordered by due time
/// then FIFO by enqueue sequence — the legacy sorted-queue pop order.
struct ArrivalEvent {
    due: f64,
    seq: u64,
    req: Request,
}

impl PartialEq for ArrivalEvent {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other).is_eq()
    }
}
impl Eq for ArrivalEvent {}
impl PartialOrd for ArrivalEvent {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for ArrivalEvent {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.due.total_cmp(&other.due).then(self.seq.cmp(&other.seq))
    }
}

/// Replica wake entry, ordered by wake time then lowest replica index —
/// the legacy scan's first-of-equal-minima tie-break.
#[derive(Debug, Clone, Copy)]
struct ReplicaWake {
    time: f64,
    index: usize,
}

impl PartialEq for ReplicaWake {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other).is_eq()
    }
}
impl Eq for ReplicaWake {}
impl PartialOrd for ReplicaWake {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for ReplicaWake {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.time.total_cmp(&other.time).then(self.index.cmp(&other.index))
    }
}

/// Chaos control event (`serving::chaos`): a fault-schedule expansion
/// entry or a hedge-timeout check, ordered by fire time then FIFO by
/// push order. Control outranks arrivals *and* wakes at equal
/// timestamps (same-time policy 0, pinned): a fault at `t` acts on the
/// fleet as it stood before anything else scheduled at `t` — so a crash
/// evacuates the step that would have run at `t`, and an arrival at the
/// same instant already sees the replica gone.
struct ControlEvent {
    time: f64,
    seq: u64,
    kind: ControlKind,
}

impl PartialEq for ControlEvent {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other).is_eq()
    }
}
impl Eq for ControlEvent {}
impl PartialOrd for ControlEvent {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for ControlEvent {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.time.total_cmp(&other.time).then(self.seq.cmp(&other.seq))
    }
}

/// One outstanding hedged request: where the primary and its tagged
/// copy live. The two replicas are distinct by construction
/// (`Router::route_hedge`), which is what makes a same-instant double
/// completion impossible — each copy finishes in its own replica event,
/// and the first one through dissolves the pair and cancels the other.
#[derive(Debug, Clone, Copy)]
struct HedgePair {
    primary: usize,
    hedge: usize,
}

/// One-element-lookahead adapter over a lazy arrival iterator (`feed`).
struct StreamSource {
    iter: Box<dyn Iterator<Item = Request>>,
    /// The next not-yet-delivered request (the lookahead).
    next: Option<Request>,
}

impl StreamSource {
    fn new(mut iter: Box<dyn Iterator<Item = Request>>) -> StreamSource {
        let next = iter.next();
        StreamSource { iter, next }
    }

    fn peek_due(&self) -> Option<f64> {
        self.next.as_ref().map(|r| r.arrival)
    }

    fn take(&mut self) -> Request {
        let r = self.next.take().expect("take called on a drained stream");
        self.next = self.iter.next();
        if let Some(n) = &self.next {
            debug_assert!(n.arrival >= r.arrival, "arrival streams must be time-ordered");
        }
        r
    }
}

/// A multi-replica serving deployment under simulated time.
pub struct ClusterSim {
    replicas: Vec<Engine<SimBackend>>,
    /// Device group of each replica (parallel to `replicas`).
    specs: Vec<ReplicaSpec>,
    router: Router,
    /// The cluster-level config replicas are instantiated from (per-replica
    /// scheduler/KV knobs; `device` is overridden per replica).
    cfg: ServingConfig,
    model: LlamaConfig,
    mode: DispatchMode,
    /// Indexed mode: pending arrivals (initial + requeued), min-heap on
    /// (due, enqueue seq). With a `stream` attached this holds only
    /// backpressure requeues — the O(open requests) memory bound.
    /// `due` equals the request's arrival unless backpressure requeued it.
    arrivals: BinaryHeap<Reverse<ArrivalEvent>>,
    /// FIFO tie-break for equal due times (monotone enqueue counter).
    arrival_seq: u64,
    /// Indexed mode: the replica wake index — exactly one entry per
    /// replica with work, keyed by `Engine::next_tick()`.
    wakes: BinaryHeap<Reverse<ReplicaWake>>,
    /// Oracle mode: the legacy sorted arrival queue.
    legacy_queue: VecDeque<(f64, Request)>,
    /// Lazy arrival source (`feed`), pulled as virtual time reaches it.
    stream: Option<StreamSource>,
    /// Which replica each routed request landed on.
    assignment: FastMap<RequestId, usize>,
    /// Backpressure events (requeues due to `QueueFull`).
    pub requeues: u64,
    completed: usize,
    /// Requests routed to a replica and not yet completed.
    in_flight: usize,
    /// Discrete events processed (arrival deliveries + replica steps +
    /// chaos control events).
    events: u64,
    /// High-water mark of `open_requests()` over the run.
    peak_open: usize,
    /// Chaos control events (fault schedule + hedge checks), min-heap on
    /// (time, push seq). Empty unless `install_chaos` ran or hedging is
    /// on — and an empty heap leaves the event loop bitwise-identical to
    /// the chaos-free core. Indexed mode only.
    control: BinaryHeap<Reverse<ControlEvent>>,
    control_seq: u64,
    /// Replicas currently crashed (drained, awaiting their restart event).
    down: Vec<bool>,
    /// Router cost weights at build time — restored when a straggler
    /// window ends (the window multiplies them by its slow factor).
    base_cost: Vec<f64>,
    /// Fault windows of the installed schedule, for reporting/plots.
    chaos_windows: Vec<(f64, f64, &'static str)>,
    /// Hedge a request still first-token-less this long after delivery;
    /// 0.0 (the default) disables hedging.
    hedge_after_s: f64,
    /// Outstanding hedge pairs, keyed by primary request id.
    hedged: FastMap<RequestId, HedgePair>,
    chaos_stats: ChaosStats,
    /// Quiescent-window macro-stepping on this fleet's replicas (current
    /// and future — `add_replica_spec` applies it to autoscaled ones).
    /// On by default; `new_micro_oracle` builds the fleet with it off.
    macro_stepping: bool,
}

impl ClusterSim {
    /// Build the fleet `cfg` describes — `cfg.replica_specs()` engine
    /// replicas (homogeneous `device` x `replicas`, or the explicit mixed
    /// `fleet` of device groups) serving `model`, fronted by a router with
    /// `cfg.route_policy` / `cfg.max_queued` and per-group decode-cost
    /// weights from the device cost model (a wider group decodes faster,
    /// so cost-aware policies see tensor parallelism honestly).
    pub fn new(cfg: &ServingConfig, model: LlamaConfig) -> ClusterSim {
        cfg.validate().expect("valid config");
        let specs = cfg.replica_specs();
        let costs: Vec<f64> = specs
            .iter()
            .map(|s| SimBackend::decode_cost_weight(&model, s.device, s.tp))
            .collect();
        let base_cost = costs.clone();
        let router = Router::with_costs(cfg.route_policy, costs, cfg.max_queued)
            .with_classes(cfg.classes.clone())
            .with_shed_threshold(cfg.shed_threshold);
        let replicas: Vec<Engine<SimBackend>> = specs
            .iter()
            .map(|s| Self::build_replica(cfg, model, *s))
            .collect();
        let n = replicas.len();
        ClusterSim {
            replicas,
            specs,
            router,
            cfg: cfg.clone(),
            model,
            mode: DispatchMode::Indexed,
            arrivals: BinaryHeap::new(),
            arrival_seq: 0,
            wakes: BinaryHeap::new(),
            legacy_queue: VecDeque::new(),
            stream: None,
            assignment: FastMap::default(),
            requeues: 0,
            completed: 0,
            in_flight: 0,
            events: 0,
            peak_open: 0,
            control: BinaryHeap::new(),
            control_seq: 0,
            down: vec![false; n],
            base_cost,
            chaos_windows: Vec::new(),
            hedge_after_s: cfg.hedge_after_s,
            hedged: FastMap::default(),
            chaos_stats: ChaosStats::default(),
            macro_stepping: true,
        }
    }

    /// The pre-refactor scan-loop oracle: the same `ClusterSim` driven by
    /// the legacy dispatch (per-event replica scan + sorted arrival
    /// queue). Hidden — it exists solely so the bitwise-equivalence
    /// property tests and the `sim-speed` benchmark can pin the indexed
    /// core against it. Eager submission only (`feed` is rejected).
    #[doc(hidden)]
    pub fn new_scan_oracle(cfg: &ServingConfig, model: LlamaConfig) -> ClusterSim {
        ClusterSim { mode: DispatchMode::ScanOracle, ..ClusterSim::new(cfg, model) }
    }

    /// The micro-stepped oracle: the indexed event core with the
    /// quiescent-window macro fast path disabled on every replica
    /// (current and future), so each decode tick runs the full per-tick
    /// scheduler pass exactly as before macro-stepping landed. Hidden —
    /// it exists solely for the macro-vs-micro bitwise property tests and
    /// the `sim-speed` macro section (the `new_scan_oracle` pattern).
    #[doc(hidden)]
    pub fn new_micro_oracle(cfg: &ServingConfig, model: LlamaConfig) -> ClusterSim {
        let mut sim = ClusterSim::new(cfg, model);
        sim.macro_stepping = false;
        for e in &mut sim.replicas {
            e.set_macro_stepping(false);
        }
        sim
    }

    /// Total quiescent-window macro bursts taken across the fleet.
    pub fn macro_bursts(&self) -> u64 {
        self.replicas.iter().map(|e| e.macro_bursts()).sum()
    }

    /// Total decode ticks covered by macro bursts across the fleet.
    pub fn macro_ticks(&self) -> u64 {
        self.replicas.iter().map(|e| e.macro_ticks()).sum()
    }

    /// One engine replica pinned to the device group `spec`. The
    /// per-replica config is the cluster config with the group's device
    /// and width substituted and the fleet list cleared (a replica is
    /// always one engine, however many cards wide) — for homogeneous
    /// configs this is exactly the cluster config, which is what keeps
    /// the 1-replica path bitwise-equal to a bare `Engine`, and a tp=1
    /// spec bitwise-equal to the pre-group single-device replica.
    fn build_replica(
        cfg: &ServingConfig,
        model: LlamaConfig,
        spec: ReplicaSpec,
    ) -> Engine<SimBackend> {
        let replica_cfg = ServingConfig {
            device: spec.device,
            tensor_parallel: spec.tp,
            fleet: Vec::new(),
            ..cfg.clone()
        };
        let backend = SimBackend::new(model, &replica_cfg);
        Engine::new(replica_cfg, backend)
    }

    pub fn num_replicas(&self) -> usize {
        self.replicas.len()
    }

    pub fn replica(&self, i: usize) -> &Engine<SimBackend> {
        &self.replicas[i]
    }

    /// Device of replica `i` (group width dropped).
    pub fn device_of(&self, i: usize) -> DeviceKind {
        self.specs[i].device
    }

    /// Device group of replica `i`.
    pub fn spec_of(&self, i: usize) -> ReplicaSpec {
        self.specs[i]
    }

    /// Per-replica device groups, in replica order.
    pub fn specs(&self) -> &[ReplicaSpec] {
        &self.specs
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    /// The deployment's declared traffic classes.
    pub fn classes(&self) -> &ClassSet {
        &self.cfg.classes
    }

    pub fn completed(&self) -> usize {
        self.completed
    }

    /// Replica index a request was routed to (after delivery).
    pub fn assignment_of(&self, id: RequestId) -> Option<usize> {
        self.assignment.get(&id).copied()
    }

    /// Queue a request for open-loop arrival at `req.arrival`.
    pub fn submit(&mut self, req: Request) {
        self.enqueue(req.arrival, req);
        self.note_open();
    }

    pub fn submit_all(&mut self, reqs: impl IntoIterator<Item = Request>) {
        for r in reqs {
            self.submit(r);
        }
    }

    /// Attach a lazy arrival stream (e.g. `workload::ArrivalStream`):
    /// requests are pulled one at a time as virtual time reaches them, so
    /// memory stays O(open requests) instead of O(trace length). The
    /// stream must be time-ordered; at equal timestamps a streamed
    /// arrival delivers before a same-time requeue, matching the enqueue
    /// order an eager `submit_all` of the same trace would have. Indexed
    /// mode only — the scan oracle predates streaming and stays eager.
    pub fn feed(&mut self, arrivals: impl Iterator<Item = Request> + 'static) {
        assert_eq!(self.mode, DispatchMode::Indexed, "the scan oracle is eager-only");
        assert!(self.stream.is_none(), "one arrival stream per run");
        self.stream = Some(StreamSource::new(Box::new(arrivals)));
        self.note_open();
    }

    /// Open requests right now: pending (queued + stream lookahead) plus
    /// routed-but-unfinished. The streaming-memory claim is about this
    /// number's peak — it bounds the simulator's working set.
    pub fn open_requests(&self) -> usize {
        let pending = self.arrivals.len()
            + self.legacy_queue.len()
            + self.stream.as_ref().map_or(0, |s| usize::from(s.next.is_some()));
        pending + self.in_flight
    }

    /// High-water mark of [`open_requests`](Self::open_requests).
    pub fn peak_open(&self) -> usize {
        self.peak_open
    }

    /// Discrete events processed so far (arrival deliveries + replica
    /// steps + chaos control events) — the numerator of the `sim-speed`
    /// events/sec metric. A quiescent-window macro burst counts each
    /// decode tick it covers, so macro and micro runs of the same trace
    /// report identical totals and events/sec comparisons stay fair.
    pub fn events(&self) -> u64 {
        self.events
    }

    fn note_open(&mut self) {
        self.peak_open = self.peak_open.max(self.open_requests());
    }

    /// Scale up: add a fresh replica on `device` (at the deployment's
    /// scalar `tensor_parallel` width) whose clock starts at `now` (the
    /// control tick that decided it). Returns its index.
    pub fn add_replica(&mut self, device: DeviceKind, now: f64) -> usize {
        self.add_replica_spec(ReplicaSpec::new(device, self.cfg.tensor_parallel), now)
    }

    /// Scale up with an explicit device group.
    pub fn add_replica_spec(&mut self, spec: ReplicaSpec, now: f64) -> usize {
        spec.validate().expect("valid replica spec");
        let mut engine = Self::build_replica(&self.cfg, self.model, spec);
        engine.set_macro_stepping(self.macro_stepping);
        engine.clock_mut().wait_until(now);
        self.replicas.push(engine);
        self.specs.push(spec);
        self.down.push(false);
        let cost = SimBackend::decode_cost_weight(&self.model, spec.device, spec.tp);
        self.base_cost.push(cost);
        self.router.add_replica(cost)
    }

    /// Scale down: stop routing to replica `i`; its in-flight work drains
    /// naturally and its history stays in the fleet metrics.
    pub fn drain_replica(&mut self, i: usize) {
        self.router.drain(i);
    }

    /// Return a drained replica to service.
    pub fn undrain_replica(&mut self, i: usize) {
        self.router.undrain(i);
    }

    /// Expand a fault schedule onto the control-event heap. Validated
    /// against the current fleet size; may be called more than once
    /// (schedules compose). The expansion is purely data-driven — a
    /// given schedule + workload seed replays bitwise, and an *empty*
    /// schedule pushes nothing, leaving the run bitwise-equal to a
    /// chaos-free one. Indexed mode only (the scan oracle predates the
    /// control heap and stays fault-free).
    pub fn install_chaos(&mut self, schedule: &FaultSchedule) {
        assert_eq!(self.mode, DispatchMode::Indexed, "chaos rides the indexed event core");
        schedule
            .validate(self.num_replicas())
            .expect("fault schedule must be valid for this fleet");
        for (t, kind) in schedule.control_events() {
            self.push_control(t, kind);
        }
        self.chaos_windows.extend(schedule.windows());
    }

    /// Counters for everything the chaos layer did this run.
    pub fn chaos_stats(&self) -> ChaosStats {
        self.chaos_stats
    }

    /// Is replica `i` currently crashed (drained, awaiting restart)?
    pub fn is_down(&self, i: usize) -> bool {
        self.down[i]
    }

    /// `(start, end, kind)` windows of the installed fault schedule(s),
    /// in installation order — the shading source for the chaos plots.
    pub fn fault_windows(&self) -> &[(f64, f64, &'static str)] {
        &self.chaos_windows
    }

    fn push_control(&mut self, time: f64, kind: ControlKind) {
        debug_assert_eq!(self.mode, DispatchMode::Indexed, "control events are indexed-only");
        let seq = self.control_seq;
        self.control_seq += 1;
        self.control.push(Reverse(ControlEvent { time, seq, kind }));
    }

    /// Schedule a (re-)arrival at `due`: a heap push in the indexed core,
    /// the legacy sorted insert under the scan oracle. Both order by
    /// (due, enqueue order), so the pop sequence is identical.
    fn enqueue(&mut self, due: f64, req: Request) {
        match self.mode {
            DispatchMode::Indexed => {
                let seq = self.arrival_seq;
                self.arrival_seq += 1;
                self.arrivals.push(Reverse(ArrivalEvent { due, seq, req }));
            }
            DispatchMode::ScanOracle => {
                let pos = self.legacy_queue.partition_point(|(t, _)| *t <= due);
                self.legacy_queue.insert(pos, (due, req));
            }
        }
    }

    /// Earliest clock among replicas that still have work — the legacy
    /// O(replicas) scan, retained for the oracle loop only (the indexed
    /// core reads the same value off the top of the wake heap).
    fn earliest_busy(&self) -> Option<(usize, f64)> {
        self.replicas
            .iter()
            .enumerate()
            .filter(|(_, e)| e.has_any_work())
            .min_by(|a, b| a.1.clock().total_cmp(&b.1.clock()))
            .map(|(i, e)| (i, e.clock()))
    }

    /// Due time of the earliest pending arrival (queued or streamed).
    fn next_arrival_due(&self) -> Option<f64> {
        let queued = match self.mode {
            DispatchMode::Indexed => self.arrivals.peek().map(|Reverse(a)| a.due),
            DispatchMode::ScanOracle => self.legacy_queue.front().map(|(t, _)| *t),
        };
        let streamed = self.stream.as_ref().and_then(|s| s.peek_due());
        match (queued, streamed) {
            (Some(q), Some(s)) => Some(q.min(s)),
            (q, s) => q.or(s),
        }
    }

    /// Pop the earliest pending arrival. The stream wins ties against the
    /// requeue heap: an eager run enqueues every initial arrival before
    /// any requeue exists, so FIFO order puts the original first — the
    /// lazy path must agree for streamed runs to replay eager runs.
    fn pop_next_arrival(&mut self) -> (f64, Request) {
        let queued = match self.mode {
            DispatchMode::Indexed => self.arrivals.peek().map(|Reverse(a)| a.due),
            DispatchMode::ScanOracle => self.legacy_queue.front().map(|(t, _)| *t),
        };
        let from_stream = match (queued, self.stream.as_ref().and_then(|s| s.peek_due())) {
            (Some(q), Some(s)) => s <= q,
            (None, Some(_)) => true,
            _ => false,
        };
        if from_stream {
            let req = self.stream.as_mut().expect("stream peeked above").take();
            // The lookahead refilled: a new request entered the window.
            self.note_open();
            return (req.arrival, req);
        }
        match self.mode {
            DispatchMode::Indexed => {
                let Reverse(a) = self.arrivals.pop().expect("deliver called with a queued request");
                (a.due, a.req)
            }
            DispatchMode::ScanOracle => {
                self.legacy_queue.pop_front().expect("deliver called with a queued request")
            }
        }
    }

    /// Earliest busy replica's clock — the backpressure retry floor. In
    /// the indexed core this is the top of the wake heap (every entry is
    /// keyed at its replica's clock); the oracle scans, as the legacy
    /// loop did. Same value either way.
    fn requeue_floor(&self) -> Option<f64> {
        match self.mode {
            DispatchMode::Indexed => self.wakes.peek().map(|Reverse(w)| w.time),
            DispatchMode::ScanOracle => self.earliest_busy().map(|(_, t)| t),
        }
    }

    /// Is prefix group `prefix_id` resident in replica `i`'s paged KV
    /// cache right now? The real-residency answer `PrefixAffinity`
    /// scoring consumes (blocks that survived eviction, not a last-writer
    /// guess).
    pub fn prefix_resident(&self, replica: usize, prefix_id: u64) -> bool {
        self.replicas[replica].sched.kv.prefix_resident(prefix_id)
    }

    /// Fleet-wide prefix-cache counters (per-replica stats summed).
    pub fn fleet_prefix_stats(&self) -> crate::serving::kv_cache::PrefixCacheStats {
        let mut total = crate::serving::kv_cache::PrefixCacheStats::default();
        for e in &self.replicas {
            total.merge(&e.sched.kv.prefix_stats());
        }
        total
    }

    /// Route the earliest pending arrival; requeue on backpressure.
    fn deliver(&mut self) {
        let (due, req) = self.pop_next_arrival();
        self.events += 1;
        // Per-class admission control: under overload, priority-0
        // background is turned away at the door — permanently, before it
        // touches load accounting — so interactive tiers keep the queue.
        // Conservation then reads submitted == completed + shed.
        if self.router.should_shed(&req) {
            self.chaos_stats.shed += 1;
            return;
        }
        let replicas = &self.replicas;
        match self
            .router
            .route_resident(&req, |i, p| replicas[i].sched.kv.prefix_resident(p))
        {
            Ok(idx) => {
                let id = req.id;
                self.assignment.insert(id, idx);
                let was_idle = !self.replicas[idx].has_any_work();
                self.replicas[idx].submit(req);
                self.in_flight += 1;
                self.note_open();
                // Idle -> busy: the replica (re-)enters the wake index. A
                // busy replica already holds its one entry, still keyed
                // at its clock (which a submit never moves).
                if self.mode == DispatchMode::Indexed && was_idle {
                    if let Some(t) = self.replicas[idx].next_tick() {
                        self.wakes.push(Reverse(ReplicaWake { time: t, index: idx }));
                    }
                }
                // Hedging armed: revisit this request after the timeout;
                // the check fires only if it is still first-token-less.
                if self.hedge_after_s > 0.0 && self.mode == DispatchMode::Indexed {
                    self.push_control(due + self.hedge_after_s, ControlKind::HedgeCheck { id });
                }
            }
            Err(QueueFull) => {
                self.requeues += 1;
                let floor = match self.requeue_floor() {
                    Some(t) => t,
                    None => panic!(
                        "router backpressure with an idle fleet: queued={} but no \
                         replica has work (max_queued too small for in-flight load?)",
                        self.router.queued()
                    ),
                };
                // Retry just after the fleet has made progress; the
                // request's own arrival timestamp is preserved so the
                // extra queueing delay lands in its TTFT (see
                // `REQUEUE_EPS` for why the offset must be strictly
                // positive).
                self.enqueue(floor.max(due) + REQUEUE_EPS, req);
                self.note_open();
            }
        }
    }

    /// Advance replica `i` by one discrete-event iteration and settle the
    /// router's books for anything that finished — including the QoS
    /// feedback loop: each completion's per-class SLO outcome updates the
    /// router's per-replica attainment estimate, which is what lets the
    /// scored policies steer high-priority traffic off degraded replicas.
    fn advance_replica(&mut self, i: usize) {
        self.events += 1;
        let done = self.replicas[i].advance();
        for id in done {
            self.on_completion(i, id);
        }
    }

    /// Settle one completion: router books + QoS feedback, then the
    /// hedge protocol — a completed copy of a hedged pair wins the race,
    /// is re-attributed to the primary id, and synchronously cancels its
    /// twin on the other replica (which therefore never completes:
    /// exactly one completion and one `completed` increment per
    /// request, no matter which copy won).
    fn on_completion(&mut self, i: usize, id: RequestId) {
        let seq = self.replicas[i].sched.seq(id);
        let met = self.cfg.classes.met_by(&RequestMetrics::from_sequence(seq));
        let req = seq.req.clone();
        self.router.record_outcome(i, req.class_id, met);
        self.router.complete(i, &req);
        self.completed += 1;
        self.in_flight -= 1;
        let primary_id = chaos::hedge_primary(id);
        if chaos::is_hedge(id) {
            // The copy won: its completion (already harvested under the
            // tagged id, with the original arrival time, so TTFT/E2E are
            // honest) is re-attributed to the request it duplicates.
            self.replicas[i].metrics.relabel(id, primary_id);
            self.chaos_stats.hedges_won += 1;
        }
        if let Some(pair) = self.hedged.remove(&primary_id) {
            let (loser_replica, loser_id) = if chaos::is_hedge(id) {
                (pair.primary, primary_id)
            } else {
                (pair.hedge, primary_id | chaos::HEDGE_BIT)
            };
            if let Some(loser) = self.replicas[loser_replica].cancel(loser_id) {
                // The loser's queue slot and load are returned; its
                // partial work was real busy time (energy is metered per
                // step) but it produces no completion and no tokens.
                self.router.complete(loser_replica, &loser);
                self.in_flight -= 1;
                self.chaos_stats.hedges_cancelled += 1;
            }
        }
    }

    /// Indexed-mode replica step: retire the replica's wake entry (it is
    /// the heap top — that is why it was chosen), advance the replica —
    /// one micro iteration, or a quiescent-window macro burst bounded by
    /// the externally-safe horizon — and re-key it at its new `next_tick`
    /// while it still has work. The horizon handed to
    /// `Engine::step_until` is the *strict* bound `before` (the next
    /// arrival due or chaos control event: both beat an equal-time
    /// replica step, same-time policies 0 and 1) plus the *inclusive*
    /// pump `limit` (a tick starting at or before it runs to its end —
    /// events are atomic, exactly as the micro loop overruns). Bursts
    /// cover only completion-free decode ticks, so the books settled
    /// here per event are the same ones the micro loop would settle —
    /// just `iters` ticks at a time, which is also what keeps `events`
    /// equal between macro and micro runs.
    fn step_replica(&mut self, i: usize, limit: f64) {
        let Reverse(w) = self.wakes.pop().expect("step_replica with an empty wake index");
        debug_assert_eq!(w.index, i, "stepped replica must own the top wake entry");
        // An outstanding hedge pair is the one cross-replica mutation a
        // completion can cause (the winner synchronously cancels its twin
        // on the *other* replica, possibly mid-window), so while any pair
        // is open every replica micro-steps: a NEG_INFINITY horizon fails
        // the burst entry guard. New pairs only form at HedgeCheck
        // control events, which the control bound below already fences —
        // a burst can therefore never span a pair's creation either.
        let before = if self.hedged.is_empty() {
            self.next_arrival_due()
                .unwrap_or(f64::INFINITY)
                .min(self.control.peek().map_or(f64::INFINITY, |Reverse(c)| c.time))
        } else {
            f64::NEG_INFINITY
        };
        let (done, iters) = self.replicas[i].step_until(before, limit);
        self.events += iters;
        for id in done {
            self.on_completion(i, id);
        }
        if let Some(t) = self.replicas[i].next_tick() {
            self.wakes.push(Reverse(ReplicaWake { time: t, index: i }));
        }
    }

    /// Fire the earliest control event (it is the heap top).
    fn fire_control(&mut self) {
        let Reverse(ev) = self.control.pop().expect("fire_control with an empty heap");
        self.events += 1;
        match ev.kind {
            ControlKind::CrashStart { replica } => self.crash(replica, ev.time),
            ControlKind::Restart { replica } => {
                // Paired with a CrashStart; a no-op if the crash was
                // skipped (the replica never went down).
                if self.down[replica] {
                    self.down[replica] = false;
                    self.router.undrain(replica);
                    self.chaos_stats.restarts += 1;
                }
            }
            ControlKind::StragglerStart { replica, factor } => {
                if !self.down[replica] {
                    self.replicas[replica].set_slow(factor);
                    // The router's cost weight sees the slowdown for the
                    // duration of the window, so cost-aware policies
                    // steer around the straggler honestly.
                    self.router.set_cost(replica, self.base_cost[replica] * factor);
                    self.chaos_stats.straggler_windows += 1;
                }
            }
            ControlKind::StragglerEnd { replica } => {
                self.replicas[replica].set_slow(1.0);
                self.router.set_cost(replica, self.base_cost[replica]);
            }
            ControlKind::Storm { replica, count } => {
                if !self.down[replica] {
                    self.chaos_stats.storms += 1;
                    self.chaos_stats.forced_preemptions +=
                        self.replicas[replica].inject_preemptions(count) as u64;
                }
            }
            ControlKind::HedgeCheck { id } => self.hedge_check(id, ev.time),
        }
    }

    /// Crash replica `i` at time `t`: drain it, evacuate every
    /// unfinished request back through the router (conservation — the
    /// failover delay lands in each request's TTFT because its arrival
    /// timestamp is preserved), invalidate its resident prefixes (the
    /// cache died with the hardware; nothing leaks), and park its clock
    /// at the restart time. The last active replica never crashes — the
    /// fleet must be able to absorb the evacuation — and a dead replica
    /// cannot die twice; both skips are counted, not silently ignored.
    fn crash(&mut self, i: usize, t: f64) {
        if self.down[i] || self.router.num_active() <= 1 {
            self.chaos_stats.crashes_skipped += 1;
            return;
        }
        self.chaos_stats.crashes += 1;
        self.down[i] = true;
        self.router.drain(i);
        // Hardware state dies with the replica: straggler dilation and
        // its router cost echo reset to healthy for the restarted box.
        self.replicas[i].set_slow(1.0);
        self.router.set_cost(i, self.base_cost[i]);
        let evacuated = self.replicas[i].evacuate();
        while self.replicas[i].sched.kv.evict_one_idle_prefix() {}
        debug_assert_eq!(
            self.replicas[i].sched.kv.num_free(),
            self.replicas[i].sched.kv.num_blocks(),
            "crashed replica must not leak KV blocks"
        );
        // The replica has no work now: retire its wake entry (if any).
        let kept: Vec<Reverse<ReplicaWake>> =
            self.wakes.drain().filter(|Reverse(w)| w.index != i).collect();
        self.wakes.extend(kept);
        // Down for the outage: the clock jumps to the restart time so a
        // restarted replica never runs work "before" its restart.
        self.replicas[i].clock_mut().wait_until(t + self.downtime_of(i, t));
        for req in evacuated {
            self.router.complete(i, &req);
            self.in_flight -= 1;
            let primary_id = chaos::hedge_primary(req.id);
            if self.hedged.remove(&primary_id).is_some() {
                // One copy of a hedged pair died with the replica: the
                // surviving copy (on a distinct replica by construction)
                // carries the request alone — requeueing the dead copy
                // would race it against its own twin.
                self.chaos_stats.hedges_cancelled += 1;
            } else {
                self.chaos_stats.requeued_by_crash += 1;
                self.enqueue(t, req);
                self.note_open();
            }
        }
    }

    /// Outage length for the crash of replica `i` at `t`: the delay to
    /// the nearest pending Restart event for that replica. 0 if the
    /// schedule carried none (cannot happen for schedules built through
    /// `FaultSchedule` — every crash expands with its restart).
    fn downtime_of(&self, i: usize, t: f64) -> f64 {
        let d = self
            .control
            .iter()
            .filter_map(|Reverse(ev)| match ev.kind {
                ControlKind::Restart { replica } if replica == i && ev.time >= t => {
                    Some(ev.time - t)
                }
                _ => None,
            })
            .fold(f64::INFINITY, f64::min);
        if d.is_finite() { d } else { 0.0 }
    }

    /// A hedge timeout fired: if the request is still first-token-less
    /// on a live replica (and not already hedged), launch a tagged copy
    /// on a *different* replica. First completion wins; the loser is
    /// cancelled synchronously by `on_completion`.
    fn hedge_check(&mut self, id: RequestId, _t: f64) {
        if self.hedged.contains_key(&id) {
            return;
        }
        let Some(&r) = self.assignment.get(&id) else { return };
        if self.down[r] || !self.replicas[r].hedge_eligible(id) {
            return; // crashed (requeue owns it), progressed, or finished
        }
        if self.router.num_active() < 2 {
            return; // nowhere distinct to hedge to
        }
        let Some(mut copy) = self.replicas[r].request_snapshot(id) else { return };
        copy.id = id | chaos::HEDGE_BIT;
        let replicas = &self.replicas;
        if let Ok(idx) =
            self.router.route_hedge(&copy, r, |i, p| replicas[i].sched.kv.prefix_resident(p))
        {
            self.assignment.insert(copy.id, idx);
            let was_idle = !self.replicas[idx].has_any_work();
            self.replicas[idx].submit(copy);
            self.in_flight += 1;
            self.note_open();
            if was_idle {
                if let Some(tn) = self.replicas[idx].next_tick() {
                    self.wakes.push(Reverse(ReplicaWake { time: tn, index: idx }));
                }
            }
            self.hedged.insert(id, HedgePair { primary: r, hedge: idx });
            self.chaos_stats.hedges_launched += 1;
        }
        // QueueFull: the fleet is too loaded to afford duplicates — a
        // hedge that would deepen the overload is skipped.
    }

    /// Advance the event loop until no event remains at or before `limit`
    /// (events are atomic: a step that *starts* at or before the limit
    /// runs to its end, so control ticks land on step boundaries).
    /// Returns `true` while any work — pending or streamed arrival, or
    /// replica work — remains beyond the limit.
    fn pump(&mut self, limit: f64) -> bool {
        match self.mode {
            DispatchMode::Indexed => self.pump_indexed(limit),
            DispatchMode::ScanOracle => self.pump_scan(limit),
        }
    }

    /// The indexed core: O(log) heap peeks/pops per event. The match arms
    /// mirror `pump_scan` exactly — same-time policy 1 (arrivals first)
    /// is the `t <= w.time` guard, policies 2-3 live in the heap
    /// orderings, policy 4 in `Engine::next_tick`. Chaos adds policy 0
    /// up front: a control event at or before every arrival and wake
    /// fires first — and with the control heap empty (no schedule, no
    /// hedging) the guard never takes, so chaos-free runs execute the
    /// pre-chaos loop verbatim.
    fn pump_indexed(&mut self, limit: f64) -> bool {
        loop {
            if let Some(&Reverse(ControlEvent { time, .. })) = self.control.peek() {
                let beats_arrival = self.next_arrival_due().is_none_or(|a| time <= a);
                let beats_wake =
                    self.wakes.peek().is_none_or(|&Reverse(w)| time <= w.time);
                if beats_arrival && beats_wake {
                    if time > limit {
                        return true;
                    }
                    self.fire_control();
                    continue;
                }
            }
            let next_due = self.next_arrival_due();
            let wake = self.wakes.peek().map(|&Reverse(w)| w);
            match (next_due, wake) {
                (Some(t), Some(w)) if t <= w.time => {
                    if t > limit {
                        return true;
                    }
                    self.deliver();
                }
                (_, Some(w)) => {
                    if w.time > limit {
                        return true;
                    }
                    self.step_replica(w.index, limit);
                }
                (Some(t), None) => {
                    if t > limit {
                        return true;
                    }
                    self.deliver();
                }
                (None, None) => return false,
            }
        }
    }

    /// The retained pre-refactor loop (`new_scan_oracle`): scans every
    /// replica per event, O(replicas) — the baseline the `sim-speed`
    /// benchmark and the parity property tests measure against.
    fn pump_scan(&mut self, limit: f64) -> bool {
        loop {
            let next_due = self.legacy_queue.front().map(|(t, _)| *t);
            let busy = self.earliest_busy();
            match (next_due, busy) {
                (Some(t), Some((_, tc))) if t <= tc => {
                    if t > limit {
                        return true;
                    }
                    self.deliver();
                }
                (_, Some((i, tc))) => {
                    if tc > limit {
                        return true;
                    }
                    self.advance_replica(i);
                }
                (Some(t), None) => {
                    if t > limit {
                        return true;
                    }
                    self.deliver();
                }
                (None, None) => return false,
            }
        }
    }

    /// Seal per-replica makespans and merge the fleet summary (with the
    /// per-traffic-class breakdown).
    fn finalize(&mut self) -> MetricsSummary {
        for e in &mut self.replicas {
            e.metrics.makespan = e.clock();
        }
        self.fleet_metrics().summary_for(&self.cfg.classes)
    }

    /// Run until every submitted request has completed; returns the
    /// fleet-level summary (merged per-replica metrics over the fleet
    /// makespan).
    pub fn run_to_completion(&mut self) -> MetricsSummary {
        let more = self.pump(f64::INFINITY);
        debug_assert!(!more, "pump(inf) drains everything");
        self.finalize()
    }

    /// Run to completion with `ctl` in the loop: every `ctl` interval of
    /// virtual time the controller observes the recent window and may add
    /// or drain replicas (`serving::autoscale`).
    pub fn run_autoscaled(&mut self, ctl: &mut Autoscaler) -> MetricsSummary {
        let mut tick = ctl.interval_s();
        while self.pump(tick) {
            ctl.control(self, tick);
            tick += ctl.interval_s();
        }
        self.finalize()
    }

    /// Weighted per-class SLO attainment over requests that finished at
    /// or after `since`, across every replica *without* cloning metric
    /// history — the autoscaler reads this every control tick, so it must
    /// stay O(window) rather than O(run length). Per-class attainment is
    /// folded by class weight over classes that completed in the window
    /// (a weight-1 single class reduces to the plain ok/total fraction
    /// exactly). `None` when the window saw no completions.
    pub fn window_attainment(&self, since: f64, classes: &ClassSet) -> Option<f64> {
        let mut ok = vec![0usize; classes.len()];
        let mut total = vec![0usize; classes.len()];
        for e in &self.replicas {
            // Per-replica completion order is monotone in finish time
            // (records happen at harvest under an advancing clock), so
            // the window is a suffix.
            for m in e.metrics.per_request().iter().rev().take_while(|m| m.finish >= since) {
                // Bucket under the *measurement* set's judging id, so a
                // smaller set (e.g. the autoscaler's independent config)
                // measures a mixed-class run instead of panicking.
                let cid = classes.judging_id(m.class_id);
                total[cid] += 1;
                if classes.met_by(m) {
                    ok[cid] += 1;
                }
            }
        }
        let (mut num, mut den) = (0.0, 0.0);
        for c in 0..classes.len() {
            if total[c] > 0 {
                num += classes.class(c).weight * (ok[c] as f64 / total[c] as f64);
                den += classes.class(c).weight;
            }
        }
        (den > 0.0).then(|| num / den)
    }

    /// Merged per-replica metrics; makespan is the slowest replica's span.
    pub fn fleet_metrics(&self) -> MetricsCollector {
        let mut fleet = MetricsCollector::default();
        for e in &self.replicas {
            let mut m = e.metrics.clone();
            m.makespan = e.clock();
            fleet.merge(&m);
        }
        fleet
    }

    /// Per-replica summaries computed over the *fleet* makespan, so
    /// replica throughputs sum exactly to the fleet throughput.
    pub fn replica_summaries(&self) -> Vec<MetricsSummary> {
        let span = self.fleet_metrics().makespan;
        self.replicas
            .iter()
            .map(|e| {
                let mut m = e.metrics.clone();
                m.makespan = span;
                m.summary()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::autoscale::AutoscaleConfig;
    use crate::serving::router::RoutePolicy;
    use crate::workload::DynamicSonnet;

    fn cluster(replicas: usize, policy: RoutePolicy, max_queued: usize) -> ClusterSim {
        let cfg = ServingConfig {
            replicas,
            route_policy: policy,
            max_queued,
            num_blocks: 4096,
            max_decode_batch: 16,
            ..Default::default()
        };
        ClusterSim::new(&cfg, LlamaConfig::llama31_8b())
    }

    #[test]
    fn fleet_drains_and_balances() {
        let mut c = cluster(3, RoutePolicy::LeastLoaded, 10_000);
        let reqs = DynamicSonnet::default().generate(45, 50.0, 21);
        c.submit_all(reqs);
        let s = c.run_to_completion();
        assert_eq!(s.requests, 45);
        assert_eq!(c.completed(), 45);
        assert_eq!(c.router().queued(), 0);
        // Every replica served something and returned all KV blocks.
        for i in 0..3 {
            let e = c.replica(i);
            assert!(e.metrics.len() >= 5, "replica {i}: {}", e.metrics.len());
            assert_eq!(e.sched.kv.num_free(), e.sched.kv.num_blocks());
        }
    }

    #[test]
    fn more_replicas_cut_tail_latency() {
        let run = |n: usize| {
            let mut c = cluster(n, RoutePolicy::RoundRobin, 10_000);
            c.submit_all(DynamicSonnet::default().generate(48, 40.0, 7));
            c.run_to_completion()
        };
        let one = run(1);
        let four = run(4);
        assert_eq!(one.requests, 48);
        assert_eq!(four.requests, 48);
        assert!(
            four.p99_ttft < one.p99_ttft,
            "4 replicas should cut p99 TTFT: {} vs {}",
            four.p99_ttft,
            one.p99_ttft
        );
    }

    #[test]
    fn backpressure_requeues_but_everything_completes() {
        // A queue cap far below the burst size forces requeues.
        let mut c = cluster(2, RoutePolicy::RoundRobin, 6);
        c.submit_all(DynamicSonnet::default().generate(30, f64::INFINITY, 3));
        let s = c.run_to_completion();
        assert_eq!(s.requests, 30);
        assert!(c.requeues > 0, "expected backpressure requeues");
        assert_eq!(c.router().queued(), 0);
    }

    #[test]
    fn affinity_assignment_is_stable_per_request_id() {
        let mut c = cluster(4, RoutePolicy::Affinity, 10_000);
        c.submit_all(DynamicSonnet::default().generate(32, 100.0, 9));
        c.run_to_completion();
        let mut c2 = cluster(4, RoutePolicy::Affinity, 10_000);
        c2.submit_all(DynamicSonnet::default().generate(32, 100.0, 9));
        c2.run_to_completion();
        for id in 0..32u64 {
            assert_eq!(c.assignment_of(id), c2.assignment_of(id), "id {id}");
            assert!(c.assignment_of(id).is_some());
        }
    }

    #[test]
    fn heterogeneous_fleet_serves_on_both_devices() {
        let cfg = ServingConfig {
            num_blocks: 4096,
            max_decode_batch: 16,
            route_policy: RoutePolicy::PrefixAffinity,
            ..Default::default()
        }
        .with_fleet(vec![DeviceKind::Gaudi2, DeviceKind::A100]);
        let mut c = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
        assert_eq!(
            c.specs(),
            &[ReplicaSpec::single(DeviceKind::Gaudi2), ReplicaSpec::single(DeviceKind::A100)]
        );
        assert_eq!(c.device_of(0), DeviceKind::Gaudi2);
        c.submit_all(DynamicSonnet::default().generate(40, 30.0, 5));
        let s = c.run_to_completion();
        assert_eq!(s.requests, 40);
        // Both devices did real work (the router is cost-aware, not
        // winner-takes-all).
        assert!(!c.replica(0).metrics.is_empty(), "Gaudi-2 replica starved");
        assert!(!c.replica(1).metrics.is_empty(), "A100 replica starved");
        // Backends really run on different devices.
        assert_eq!(c.replica(0).backend().device, DeviceKind::Gaudi2);
        assert_eq!(c.replica(1).backend().device, DeviceKind::A100);
    }

    #[test]
    fn tp1_spec_fleet_is_bitwise_equal_to_the_legacy_device_fleet() {
        let base = ServingConfig {
            num_blocks: 4096,
            max_decode_batch: 16,
            route_policy: RoutePolicy::LeastLoaded,
            ..Default::default()
        };
        let legacy = base.clone().with_fleet(vec![DeviceKind::Gaudi2, DeviceKind::A100]);
        let specs = base.with_replica_specs(vec![
            ReplicaSpec::new(DeviceKind::Gaudi2, 1),
            ReplicaSpec::new(DeviceKind::A100, 1),
        ]);
        let run = |cfg: &ServingConfig| {
            let mut c = ClusterSim::new(cfg, LlamaConfig::llama31_8b());
            c.submit_all(DynamicSonnet::default().generate(40, 30.0, 11));
            c.run_to_completion();
            c
        };
        let a = run(&legacy);
        let b = run(&specs);
        assert_eq!(a.fleet_metrics().max_request_delta(&b.fleet_metrics()), 0.0);
        assert_eq!(a.events(), b.events());
        assert_eq!(a.completed(), b.completed());
    }

    #[test]
    fn tp_group_serves_a_model_too_big_for_one_card() {
        // Llama-70B BF16 weights (~141 GB) exceed a single Gaudi-2 HBM
        // (96 GB); a tp=4 device group shards them to ~35 GB/card and
        // serves the same trace to completion.
        let model = LlamaConfig::llama31_70b();
        assert_eq!(crate::models::llama::kv_token_capacity(&model, DeviceKind::Gaudi2, 1), 0);
        let blocks = crate::models::llama::kv_block_budget(&model, DeviceKind::Gaudi2, 4, 16);
        assert!(blocks > 1000, "tp=4 budget: {blocks}");
        let cfg = ServingConfig {
            num_blocks: 4096,
            max_decode_batch: 8,
            route_policy: RoutePolicy::LeastLoaded,
            ..Default::default()
        }
        .with_replica_specs(vec![ReplicaSpec::new(DeviceKind::Gaudi2, 4)]);
        let mut c = ClusterSim::new(&cfg, model);
        assert_eq!(c.spec_of(0), ReplicaSpec::new(DeviceKind::Gaudi2, 4));
        assert_eq!(c.replica(0).backend().tp, 4);
        c.submit_all(DynamicSonnet::default().generate(24, 40.0, 13));
        let s = c.run_to_completion();
        assert_eq!(s.requests, 24);
        // The group pays real all-reduce time: its decode cost weight is
        // cheaper than a (hypothetical) single card but not 4x cheaper.
        let w1 = SimBackend::decode_cost_weight(&model, DeviceKind::Gaudi2, 1);
        let w4 = SimBackend::decode_cost_weight(&model, DeviceKind::Gaudi2, 4);
        assert!(w4 < w1, "sharding must cut the step cost: {w4} vs {w1}");
        assert!(w4 > w1 / 4.0, "all-reduces keep scaling sub-linear: {w4} vs {}", w1 / 4.0);
    }

    #[test]
    fn drained_replica_gets_no_new_work_but_finishes_in_flight() {
        let mut c = cluster(2, RoutePolicy::RoundRobin, 10_000);
        c.submit_all(DynamicSonnet::default().generate(16, f64::INFINITY, 8));
        // Deliver the burst, then drain replica 1 mid-run.
        let more = c.pump(0.0);
        assert!(more);
        let before = c.router().load_of(1);
        assert!(before > 0, "replica 1 got part of the burst");
        c.drain_replica(1);
        c.submit_all(DynamicSonnet::default().generate(16, f64::INFINITY, 9).into_iter().map(
            |mut r| {
                r.id += 100; // distinct ids for the second wave
                r
            },
        ));
        let s = c.run_to_completion();
        assert_eq!(s.requests, 32);
        // Second wave all landed on replica 0.
        for id in 100..116u64 {
            assert_eq!(c.assignment_of(id), Some(0), "id {id}");
        }
        assert_eq!(c.router().load_of(1), 0, "in-flight work drained");
    }

    #[test]
    fn prefix_affinity_routes_on_real_residency() {
        let cfg = ServingConfig {
            replicas: 2,
            route_policy: RoutePolicy::PrefixAffinity,
            num_blocks: 4096,
            max_decode_batch: 16,
            ..Default::default()
        };
        let mut c = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
        c.submit_all(DynamicSonnet::default().with_prefix_groups(4).generate(40, 25.0, 21));
        let s = c.run_to_completion();
        assert_eq!(s.requests, 40);
        let stats = c.fleet_prefix_stats();
        assert!(stats.hits > 0, "steered traffic must hit resident prefixes: {stats:?}");
        assert_eq!(stats.uncached, 0, "default budget never refuses residency");
        // Whatever is resident at the end is queryable per replica, and
        // the blocks it holds are accounted (free + resident == total).
        for i in 0..c.num_replicas() {
            let kv = &c.replica(i).sched.kv;
            let resident: usize =
                (0..4u64).filter(|&p| c.prefix_resident(i, p)).count();
            assert_eq!(resident, kv.num_resident_prefixes());
            assert_eq!(kv.num_free() + kv.prefix_resident_blocks(), kv.num_blocks());
            assert!(kv.check_conservation());
        }
    }

    #[test]
    fn window_attainment_matches_whole_run_attainment() {
        use crate::serving::qos::ClassSet;
        let mut c = cluster(2, RoutePolicy::RoundRobin, 10_000);
        c.submit_all(DynamicSonnet::default().generate(20, 40.0, 4));
        c.run_to_completion();
        // The whole-history window agrees with the collector's aggregate
        // (single weight-1 class: weighted == plain attainment exactly).
        let fleet = c.fleet_metrics();
        let classes = ClassSet::scalar(1.0, 0.1);
        assert_eq!(c.window_attainment(0.0, &classes), Some(fleet.attainment(&classes)));
        // Effectively unbounded SLOs: everything complies.
        assert_eq!(c.window_attainment(0.0, &ClassSet::scalar(1e12, 1e12)), Some(1.0));
        // A window past the makespan saw no completions.
        assert_eq!(c.window_attainment(fleet.makespan + 1.0, &classes), None);
    }

    #[test]
    fn mixed_class_fleet_serves_and_reports_per_class() {
        use crate::serving::qos::ClassSet;
        let cfg = ServingConfig {
            replicas: 2,
            route_policy: RoutePolicy::LeastLoaded,
            num_blocks: 4096,
            max_decode_batch: 16,
            classes: ClassSet::three_tier(),
            ..Default::default()
        };
        let mut c = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
        c.submit_all(
            DynamicSonnet::default()
                .with_class_mix(vec![(0, 2), (1, 1), (2, 1)])
                .generate(40, 30.0, 11),
        );
        let s = c.run_to_completion();
        assert_eq!(s.requests, 40);
        // The summary carries one slice per declared class, all served.
        assert_eq!(s.classes.len(), 3);
        assert_eq!(s.classes.iter().map(|cs| cs.requests).sum::<usize>(), 40);
        // The id-derived mix: 2/4 interactive, 1/4 batch, 1/4 background.
        assert_eq!(s.classes[0].requests, 20);
        assert_eq!(s.classes[1].requests, 10);
        assert_eq!(s.classes[2].requests, 10);
        // Weighted window attainment is defined over the whole run.
        assert!(c.window_attainment(0.0, c.classes()).is_some());
        // The router saw per-class feedback for every completion.
        let att_sum: f64 = (0..2)
            .flat_map(|r| (0..3).map(move |cl| (r, cl)))
            .map(|(r, cl)| c.router().class_attainment(r, cl))
            .sum();
        assert!(att_sum > 0.0);
    }

    #[test]
    fn default_autoscaler_measures_a_mixed_class_fleet_without_panicking() {
        use crate::serving::qos::ClassSet;
        // The autoscaler's ClassSet is an independent measurement set; a
        // default (single-class) controller on a three-tier deployment
        // must judge foreign class ids under its global scalar SLO, not
        // panic or index out of bounds.
        let cfg = ServingConfig {
            replicas: 1,
            num_blocks: 4096,
            max_decode_batch: 16,
            classes: ClassSet::three_tier(),
            ..Default::default()
        };
        let mut c = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
        c.submit_all(
            DynamicSonnet::default()
                .with_class_mix(vec![(0, 1), (1, 1), (2, 1)])
                .generate(18, 30.0, 3),
        );
        let mut ctl = Autoscaler::new(AutoscaleConfig::default());
        let s = c.run_autoscaled(&mut ctl);
        assert_eq!(s.requests, 18);
        // And the 1-class window measurement buckets everything under
        // its single class (the legacy global-SLO view).
        let scalar = ClassSet::scalar(1e12, 1e12);
        assert_eq!(c.window_attainment(0.0, &scalar), Some(1.0));
    }

    #[test]
    fn indexed_core_matches_scan_oracle_bitwise() {
        use crate::serving::qos::ClassSet;
        // Tight queue cap + class mix + prefix groups: exercise requeues,
        // QoS feedback and prefix routing through both dispatch modes.
        let cfg = ServingConfig {
            replicas: 3,
            route_policy: RoutePolicy::LeastLoaded,
            max_queued: 8,
            num_blocks: 4096,
            max_decode_batch: 16,
            classes: ClassSet::three_tier(),
            ..Default::default()
        };
        let trace = || {
            DynamicSonnet::default()
                .with_prefix_groups(4)
                .with_class_mix(vec![(0, 2), (1, 1), (2, 1)])
                .generate(40, 60.0, 13)
        };
        let mut a = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
        a.submit_all(trace());
        let sa = a.run_to_completion();
        let mut b = ClusterSim::new_scan_oracle(&cfg, LlamaConfig::llama31_8b());
        b.submit_all(trace());
        let sb = b.run_to_completion();
        assert_eq!(sa.requests, 40);
        assert_eq!(sb.requests, 40);
        assert_eq!(a.fleet_metrics().max_request_delta(&b.fleet_metrics()), 0.0);
        assert_eq!(a.requeues, b.requeues);
        assert_eq!(a.events(), b.events());
        assert_eq!(
            format!("{:?}", a.fleet_prefix_stats()),
            format!("{:?}", b.fleet_prefix_stats())
        );
    }

    #[test]
    fn streamed_feed_replays_eager_submit() {
        let cfg = ServingConfig {
            replicas: 2,
            route_policy: RoutePolicy::LeastLoaded,
            max_queued: 10_000,
            num_blocks: 4096,
            max_decode_batch: 16,
            ..Default::default()
        };
        let w = DynamicSonnet::default().with_prefix_groups(4);
        let (n, rate, seed) = (30usize, 5.0, 17u64);
        let mut eager = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
        eager.submit_all(w.generate(n, rate, seed));
        let se = eager.run_to_completion();
        let mut lazy = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
        lazy.feed(w.clone().stream(n, rate, seed));
        let sl = lazy.run_to_completion();
        assert_eq!(se.requests, n);
        assert_eq!(sl.requests, n);
        assert_eq!(eager.fleet_metrics().max_request_delta(&lazy.fleet_metrics()), 0.0);
        assert_eq!(eager.events(), lazy.events());
        // Memory bound: the eager run materializes the whole trace up
        // front (peak = n pending); the lazy run's working set is only
        // the open requests at a rate the fleet keeps up with.
        assert_eq!(eager.peak_open(), n);
        assert!(lazy.peak_open() < n, "lazy peak {} vs trace {n}", lazy.peak_open());
    }

    #[test]
    fn window_attainment_matches_brute_force_filter() {
        use crate::serving::qos::ClassSet;
        // Regression guard for the suffix-scan's monotonicity assumption
        // (checked in debug builds at harvest): the reverse take_while
        // must agree with an order-independent full filter at any window.
        let cfg = ServingConfig {
            replicas: 3,
            route_policy: RoutePolicy::RoundRobin,
            max_queued: 10_000,
            num_blocks: 4096,
            max_decode_batch: 16,
            classes: ClassSet::three_tier(),
            ..Default::default()
        };
        let mut c = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
        c.submit_all(
            DynamicSonnet::default()
                .with_class_mix(vec![(0, 1), (1, 1), (2, 1)])
                .generate(36, 40.0, 23),
        );
        c.run_to_completion();
        let classes = c.classes().clone();
        let fleet = c.fleet_metrics();
        let span = fleet.makespan;
        for since in [0.0, span * 0.25, span * 0.5, span * 0.9, span + 1.0] {
            let mut ok = vec![0usize; classes.len()];
            let mut total = vec![0usize; classes.len()];
            for m in fleet.per_request().iter().filter(|m| m.finish >= since) {
                let cid = classes.judging_id(m.class_id);
                total[cid] += 1;
                if classes.met_by(m) {
                    ok[cid] += 1;
                }
            }
            let (mut num, mut den) = (0.0, 0.0);
            for cid in 0..classes.len() {
                if total[cid] > 0 {
                    num += classes.class(cid).weight * (ok[cid] as f64 / total[cid] as f64);
                    den += classes.class(cid).weight;
                }
            }
            let expect = (den > 0.0).then(|| num / den);
            assert_eq!(c.window_attainment(since, &classes), expect, "since {since}");
        }
    }

    #[test]
    fn empty_fault_schedule_is_bitwise_inert() {
        let trace = || DynamicSonnet::default().generate(30, 40.0, 31);
        let mut plain = cluster(3, RoutePolicy::LeastLoaded, 10_000);
        plain.submit_all(trace());
        plain.run_to_completion();
        let mut chaotic = cluster(3, RoutePolicy::LeastLoaded, 10_000);
        chaotic.install_chaos(&FaultSchedule::empty());
        chaotic.submit_all(trace());
        chaotic.run_to_completion();
        assert_eq!(plain.fleet_metrics().max_request_delta(&chaotic.fleet_metrics()), 0.0);
        assert_eq!(plain.events(), chaotic.events());
        assert_eq!(chaotic.chaos_stats(), ChaosStats::default());
    }

    #[test]
    fn crash_requeues_everything_and_conserves_requests() {
        use crate::serving::chaos::Fault;
        let mut c = cluster(3, RoutePolicy::LeastLoaded, 10_000);
        let n = 36;
        c.submit_all(DynamicSonnet::default().generate(n, 30.0, 41));
        c.install_chaos(&FaultSchedule::empty().with(Fault::Crash {
            replica: 0,
            at: 0.2,
            down_s: 1.0,
        }));
        let s = c.run_to_completion();
        let st = c.chaos_stats();
        assert_eq!(s.requests, n, "no request lost to the crash");
        assert_eq!(c.completed(), n);
        assert_eq!(st.crashes, 1);
        assert_eq!(st.restarts, 1);
        assert!(st.requeued_by_crash > 0, "the crash must have caught work in flight");
        assert!(!c.is_down(0), "restarted");
        assert_eq!(c.router().queued(), 0);
        // The dead replica's KV came back whole and its prefix cache was
        // invalidated, not leaked.
        let kv = &c.replica(0).sched.kv;
        assert_eq!(kv.num_free(), kv.num_blocks());
        // Unique completion per original id — nothing completed twice.
        let mut ids: Vec<u64> =
            c.fleet_metrics().per_request().iter().map(|m| m.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), n);
    }

    #[test]
    fn crash_on_the_last_active_replica_is_skipped() {
        use crate::serving::chaos::Fault;
        let mut c = cluster(1, RoutePolicy::RoundRobin, 10_000);
        c.submit_all(DynamicSonnet::default().generate(8, 40.0, 5));
        c.install_chaos(&FaultSchedule::empty().with(Fault::Crash {
            replica: 0,
            at: 0.1,
            down_s: 0.5,
        }));
        let s = c.run_to_completion();
        assert_eq!(s.requests, 8);
        let st = c.chaos_stats();
        assert_eq!((st.crashes, st.crashes_skipped, st.restarts), (0, 1, 0));
    }

    #[test]
    fn straggler_dilates_the_window_then_recovers() {
        use crate::serving::chaos::Fault;
        let run = |faulty: bool| {
            let mut c = cluster(2, RoutePolicy::RoundRobin, 10_000);
            if faulty {
                c.install_chaos(&FaultSchedule::empty().with(Fault::Straggler {
                    replica: 0,
                    from: 0.0,
                    until: 5.0,
                    factor: 8.0,
                }));
            }
            c.submit_all(DynamicSonnet::default().generate(24, 30.0, 17));
            let s = c.run_to_completion();
            assert_eq!(s.requests, 24);
            (c, s)
        };
        let (healthy, hs) = run(false);
        let (slowed, ss) = run(true);
        assert_eq!(slowed.chaos_stats().straggler_windows, 1);
        assert!(
            ss.p99_ttft > hs.p99_ttft,
            "a x8 straggler must hurt the tail: {} vs {}",
            ss.p99_ttft,
            hs.p99_ttft
        );
        // The window ended inside the run: dilation and the router's
        // cost echo are both restored.
        assert_eq!(slowed.replica(0).slow_factor(), 1.0);
        assert_eq!(slowed.router().cost_of(0), healthy.router().cost_of(0));
    }

    #[test]
    fn preemption_storm_delays_but_completes() {
        use crate::serving::chaos::Fault;
        let mut c = cluster(2, RoutePolicy::LeastLoaded, 10_000);
        c.submit_all(DynamicSonnet::default().generate(20, f64::INFINITY, 13));
        c.install_chaos(
            &FaultSchedule::empty()
                .with(Fault::PreemptStorm { replica: 0, at: 0.5, count: 4 })
                .with(Fault::PreemptStorm { replica: 1, at: 0.5, count: 4 }),
        );
        let s = c.run_to_completion();
        assert_eq!(s.requests, 20);
        let st = c.chaos_stats();
        assert_eq!(st.storms, 2);
        assert!(st.forced_preemptions > 0, "storms at t=0.5 must catch running work");
    }

    #[test]
    fn hedging_duplicates_stuck_requests_without_double_counting() {
        use crate::serving::chaos::Fault;
        // Replica 0 staggers x20 from the start; round-robin keeps
        // assigning to it anyway, so its requests sit first-token-less
        // past the hedge timeout and duplicate onto replica 1.
        let mk = |hedge: f64| {
            let cfg = ServingConfig {
                replicas: 2,
                route_policy: RoutePolicy::RoundRobin,
                max_queued: 10_000,
                num_blocks: 4096,
                max_decode_batch: 16,
                hedge_after_s: hedge,
                ..Default::default()
            };
            let mut c = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
            c.install_chaos(&FaultSchedule::empty().with(Fault::Straggler {
                replica: 0,
                from: 0.0,
                until: 50.0,
                factor: 20.0,
            }));
            c.submit_all(DynamicSonnet::default().generate(16, 8.0, 29));
            let s = c.run_to_completion();
            (c, s)
        };
        let (hedged, hesum) = mk(0.4);
        let (control, cosum) = mk(0.0);
        let st = hedged.chaos_stats();
        assert!(st.hedges_launched > 0, "straggler must trigger hedges");
        assert!(
            st.hedges_won + st.hedges_cancelled >= st.hedges_launched,
            "every launched hedge resolves: {st:?}"
        );
        assert_eq!(hesum.requests, 16, "hedging never loses requests");
        assert_eq!(cosum.requests, 16);
        // Exactly one completion per original id, none under a tagged id.
        let fleet = hedged.fleet_metrics();
        let mut ids: Vec<u64> = fleet.per_request().iter().map(|m| m.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..16).collect::<Vec<u64>>());
        // Hedging must help the tail under this straggler.
        assert!(
            hesum.p99_ttft < cosum.p99_ttft,
            "hedged p99 {} vs control {}",
            hesum.p99_ttft,
            cosum.p99_ttft
        );
    }

    #[test]
    fn shedding_drops_background_but_conserves_accounting() {
        use crate::serving::qos::ClassSet;
        let cfg = ServingConfig {
            replicas: 2,
            route_policy: RoutePolicy::LeastLoaded,
            max_queued: 12,
            num_blocks: 4096,
            max_decode_batch: 16,
            classes: ClassSet::three_tier(),
            shed_threshold: 0.5,
            ..Default::default()
        };
        let mut c = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
        let n = 40;
        c.submit_all(
            DynamicSonnet::default()
                .with_class_mix(vec![(0, 1), (2, 1)])
                .generate(n, f64::INFINITY, 37),
        );
        let s = c.run_to_completion();
        let shed = c.chaos_stats().shed as usize;
        assert!(shed > 0, "an instantaneous burst of 40 against cap 12 must shed");
        assert_eq!(s.requests + shed, n, "submitted == completed + shed");
        // Interactive (class 0) is never shed: all 20 completed.
        assert_eq!(
            s.classes.iter().find(|cs| cs.class_id == 0).unwrap().requests,
            20,
            "interactive tier must be untouched by admission control"
        );
    }

    #[test]
    fn macro_bursts_replay_micro_bitwise_on_a_small_fleet() {
        // Decode-heavy trace (short prompts, long outputs) so replicas
        // spend most of the run in stable decode windows — the macro
        // fast path's natural habitat. The indexed run must take real
        // bursts and still replay the retained micro oracle bitwise.
        let cfg = ServingConfig {
            replicas: 2,
            route_policy: RoutePolicy::LeastLoaded,
            max_queued: 10_000,
            num_blocks: 4096,
            max_decode_batch: 16,
            ..Default::default()
        };
        let trace = || {
            DynamicSonnet { max_input: 64, max_output: 256, ..Default::default() }
                .generate(24, 20.0, 19)
        };
        let mut m = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
        m.submit_all(trace());
        let sm = m.run_to_completion();
        let mut u = ClusterSim::new_micro_oracle(&cfg, LlamaConfig::llama31_8b());
        u.submit_all(trace());
        let su = u.run_to_completion();
        assert_eq!(sm.requests, 24);
        assert_eq!(su.requests, 24);
        assert!(m.macro_ticks() > m.macro_bursts(), "bursts must cover >1 tick on average");
        assert!(m.macro_bursts() > 0, "the fast path must engage on this trace");
        assert_eq!(u.macro_ticks(), 0, "the oracle must stay micro-stepped");
        assert_eq!(m.fleet_metrics().max_request_delta(&u.fleet_metrics()), 0.0);
        assert_eq!(m.events(), u.events(), "a burst of k ticks still counts k events");
        assert_eq!(m.completed(), u.completed());
        assert_eq!(sm.mean_tpot.to_bits(), su.mean_tpot.to_bits());
        assert_eq!(sm.p99_ttft.to_bits(), su.p99_ttft.to_bits());
    }

    #[test]
    fn straggler_window_boundary_terminates_macro_bursts() {
        use crate::serving::chaos::Fault;
        // A straggler window flips a replica's slow-clock factor at its
        // `from`/`until` control events. Both edges sit on the control
        // heap, so they bound every macro burst: a burst that wrongly
        // spanned either boundary would cost its later ticks under the
        // wrong dilation and break bitwise parity with the micro oracle.
        let cfg = ServingConfig {
            replicas: 2,
            route_policy: RoutePolicy::RoundRobin,
            max_queued: 10_000,
            num_blocks: 4096,
            max_decode_batch: 16,
            ..Default::default()
        };
        let chaos = FaultSchedule::empty().with(Fault::Straggler {
            replica: 0,
            from: 0.4,
            until: 3.0,
            factor: 6.0,
        });
        let trace = || {
            DynamicSonnet { max_input: 64, max_output: 192, ..Default::default() }
                .generate(20, 25.0, 43)
        };
        let mut m = ClusterSim::new(&cfg, LlamaConfig::llama31_8b());
        m.install_chaos(&chaos);
        m.submit_all(trace());
        let sm = m.run_to_completion();
        let mut u = ClusterSim::new_micro_oracle(&cfg, LlamaConfig::llama31_8b());
        u.install_chaos(&chaos);
        u.submit_all(trace());
        let su = u.run_to_completion();
        assert_eq!(sm.requests, 20);
        assert_eq!(su.requests, 20);
        assert_eq!(m.chaos_stats().straggler_windows, 1, "the window must fire mid-run");
        assert_eq!(m.chaos_stats(), u.chaos_stats());
        assert!(m.macro_bursts() > 0, "bursts must still engage around the window");
        assert_eq!(m.fleet_metrics().max_request_delta(&u.fleet_metrics()), 0.0);
        assert_eq!(m.events(), u.events());
    }

    #[test]
    fn autoscaled_run_grows_the_fleet_under_load() {
        let mut c = cluster(1, RoutePolicy::LeastLoaded, 10_000);
        c.submit_all(crate::workload::OpenLoopTrace::new(40.0, 3.0).generate(17));
        let mut ctl = Autoscaler::new(AutoscaleConfig {
            scale_up_device: DeviceKind::Gaudi2,
            max_replicas: 6,
            ..Default::default()
        });
        let s = c.run_autoscaled(&mut ctl);
        assert!(s.requests > 60, "trace should be substantial: {}", s.requests);
        assert_eq!(c.completed(), s.requests);
        // 40 req/s swamps one replica; the controller must have scaled up.
        assert!(c.num_replicas() > 1, "expected scale-up, got {} replicas", c.num_replicas());
        assert!(!ctl.actions().is_empty());
        assert_eq!(c.router().queued(), 0);
    }
}
