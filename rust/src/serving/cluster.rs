//! Cluster-scale data-parallel serving simulator: N engine replicas behind
//! the admission `Router`, advanced in one merged virtual-time event loop.
//!
//! This is the deployment shape the paper's §6 serving evaluation points
//! at — vLLM-style fleets serve heavy traffic by running many independent
//! engine replicas behind a router — and it turns the per-device question
//! of Fig 17 into the production question: *how many Gaudi-2 vs A100
//! replicas does a given SLO need?* (`repro run cluster`).
//!
//! Event loop (next-event dispatch): at every iteration the simulator
//! either delivers the earliest pending arrival to the router (when it is
//! due at or before the earliest busy replica's clock) or advances the
//! replica with the smallest clock by one engine step. Replica clocks are
//! therefore never rewound, arrivals are routed in order at their arrival
//! times, and with one replica the step sequence is *identical* to a
//! single `Engine` run (asserted bit-for-bit in
//! `rust/tests/integration_cluster.rs`).
//!
//! Backpressure: when the router's global queue cap rejects an arrival
//! (`QueueFull`), the request is requeued with its due time bumped just
//! past the earliest busy replica's clock — it retries as soon as the
//! fleet has made progress, preserving arrival order among retries. The
//! request's *arrival* timestamp is untouched, so queueing delay from
//! backpressure shows up in its TTFT, exactly as a client would see it.

use std::collections::VecDeque;

use crate::config::ServingConfig;
use crate::models::llama::LlamaConfig;
use crate::serving::engine::{Engine, SimBackend};
use crate::serving::metrics::{MetricsCollector, MetricsSummary};
use crate::serving::request::{Request, RequestId};
use crate::serving::router::{QueueFull, Router};
use crate::util::fasthash::FastMap;

/// A multi-replica serving deployment under simulated time.
pub struct ClusterSim {
    replicas: Vec<Engine<SimBackend>>,
    router: Router,
    /// Pending cluster-level arrivals: (due time, request), sorted by due.
    /// `due` equals the request's arrival unless backpressure requeued it.
    queue: VecDeque<(f64, Request)>,
    /// Which replica each routed request landed on.
    assignment: FastMap<RequestId, usize>,
    /// Backpressure events (requeues due to `QueueFull`).
    pub requeues: u64,
    completed: usize,
}

impl ClusterSim {
    /// Build `cfg.replicas` identical engine replicas serving `model`,
    /// fronted by a router with `cfg.route_policy` / `cfg.max_queued`.
    pub fn new(cfg: &ServingConfig, model: LlamaConfig) -> ClusterSim {
        cfg.validate().expect("valid config");
        let router = Router::new(cfg.route_policy, cfg.replicas, cfg.max_queued);
        let replicas = (0..cfg.replicas)
            .map(|_| Engine::new(cfg.clone(), SimBackend::new(model, cfg)))
            .collect();
        ClusterSim {
            replicas,
            router,
            queue: VecDeque::new(),
            assignment: FastMap::default(),
            requeues: 0,
            completed: 0,
        }
    }

    pub fn num_replicas(&self) -> usize {
        self.replicas.len()
    }

    pub fn replica(&self, i: usize) -> &Engine<SimBackend> {
        &self.replicas[i]
    }

    pub fn router(&self) -> &Router {
        &self.router
    }

    pub fn completed(&self) -> usize {
        self.completed
    }

    /// Replica index a request was routed to (after delivery).
    pub fn assignment_of(&self, id: RequestId) -> Option<usize> {
        self.assignment.get(&id).copied()
    }

    /// Queue a request for open-loop arrival at `req.arrival`.
    pub fn submit(&mut self, req: Request) {
        self.enqueue(req.arrival, req);
    }

    pub fn submit_all(&mut self, reqs: impl IntoIterator<Item = Request>) {
        for r in reqs {
            self.submit(r);
        }
    }

    fn enqueue(&mut self, due: f64, req: Request) {
        let pos = self.queue.partition_point(|(t, _)| *t <= due);
        self.queue.insert(pos, (due, req));
    }

    /// Earliest clock among replicas that still have work.
    fn earliest_busy(&self) -> Option<(usize, f64)> {
        self.replicas
            .iter()
            .enumerate()
            .filter(|(_, e)| e.has_any_work())
            .min_by(|a, b| a.1.clock().total_cmp(&b.1.clock()))
            .map(|(i, e)| (i, e.clock()))
    }

    /// Route the front-of-queue request; requeue on backpressure.
    fn deliver(&mut self) {
        let (due, req) = self.queue.pop_front().expect("deliver called with a queued request");
        match self.router.route(&req) {
            Ok(idx) => {
                self.assignment.insert(req.id, idx);
                self.replicas[idx].submit(req);
            }
            Err(QueueFull) => {
                self.requeues += 1;
                let floor = match self.earliest_busy() {
                    Some((_, t)) => t,
                    None => panic!(
                        "router backpressure with an idle fleet: queued={} but no \
                         replica has work (max_queued too small for in-flight load?)",
                        self.router.queued()
                    ),
                };
                // Retry just after the fleet has made progress; the
                // request's own arrival timestamp is preserved so the
                // extra queueing delay lands in its TTFT.
                self.enqueue(floor.max(due) + 1e-6, req);
            }
        }
    }

    /// Advance replica `i` by one discrete-event iteration and settle the
    /// router's books for anything that finished.
    fn step_replica(&mut self, i: usize) {
        let done = self.replicas[i].advance();
        for id in done {
            let req = self.replicas[i].sched.seq(id).req.clone();
            self.router.complete(i, &req);
            self.completed += 1;
        }
    }

    /// Run until every submitted request has completed; returns the
    /// fleet-level summary (merged per-replica metrics over the fleet
    /// makespan).
    pub fn run_to_completion(&mut self) -> MetricsSummary {
        loop {
            let next_due = self.queue.front().map(|(t, _)| *t);
            let busy = self.earliest_busy();
            match (next_due, busy) {
                (Some(t), Some((_, tc))) if t <= tc => self.deliver(),
                (_, Some((i, _))) => self.step_replica(i),
                (Some(_), None) => self.deliver(),
                (None, None) => break,
            }
        }
        for e in &mut self.replicas {
            e.metrics.makespan = e.clock();
        }
        self.fleet_metrics().summary()
    }

    /// Merged per-replica metrics; makespan is the slowest replica's span.
    pub fn fleet_metrics(&self) -> MetricsCollector {
        let mut fleet = MetricsCollector::default();
        for e in &self.replicas {
            fleet.merge(&e.metrics);
        }
        fleet
    }

    /// Per-replica summaries computed over the *fleet* makespan, so
    /// replica throughputs sum exactly to the fleet throughput.
    pub fn replica_summaries(&self) -> Vec<MetricsSummary> {
        let span = self.fleet_metrics().makespan;
        self.replicas
            .iter()
            .map(|e| {
                let mut m = e.metrics.clone();
                m.makespan = span;
                m.summary()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::router::RoutePolicy;
    use crate::workload::DynamicSonnet;

    fn cluster(replicas: usize, policy: RoutePolicy, max_queued: usize) -> ClusterSim {
        let cfg = ServingConfig {
            replicas,
            route_policy: policy,
            max_queued,
            num_blocks: 4096,
            max_decode_batch: 16,
            ..Default::default()
        };
        ClusterSim::new(&cfg, LlamaConfig::llama31_8b())
    }

    #[test]
    fn fleet_drains_and_balances() {
        let mut c = cluster(3, RoutePolicy::LeastLoaded, 10_000);
        let reqs = DynamicSonnet::default().generate(45, 50.0, 21);
        c.submit_all(reqs);
        let s = c.run_to_completion();
        assert_eq!(s.requests, 45);
        assert_eq!(c.completed(), 45);
        assert_eq!(c.router().queued(), 0);
        // Every replica served something and returned all KV blocks.
        for i in 0..3 {
            let e = c.replica(i);
            assert!(e.metrics.len() >= 5, "replica {i}: {}", e.metrics.len());
            assert_eq!(e.sched.kv.num_free(), e.sched.kv.num_blocks());
        }
    }

    #[test]
    fn more_replicas_cut_tail_latency() {
        let run = |n: usize| {
            let mut c = cluster(n, RoutePolicy::RoundRobin, 10_000);
            c.submit_all(DynamicSonnet::default().generate(48, 40.0, 7));
            c.run_to_completion()
        };
        let one = run(1);
        let four = run(4);
        assert_eq!(one.requests, 48);
        assert_eq!(four.requests, 48);
        assert!(
            four.p99_ttft < one.p99_ttft,
            "4 replicas should cut p99 TTFT: {} vs {}",
            four.p99_ttft,
            one.p99_ttft
        );
    }

    #[test]
    fn backpressure_requeues_but_everything_completes() {
        // A queue cap far below the burst size forces requeues.
        let mut c = cluster(2, RoutePolicy::RoundRobin, 6);
        c.submit_all(DynamicSonnet::default().generate(30, f64::INFINITY, 3));
        let s = c.run_to_completion();
        assert_eq!(s.requests, 30);
        assert!(c.requeues > 0, "expected backpressure requeues");
        assert_eq!(c.router().queued(), 0);
    }

    #[test]
    fn affinity_assignment_is_stable_per_request_id() {
        let mut c = cluster(4, RoutePolicy::Affinity, 10_000);
        c.submit_all(DynamicSonnet::default().generate(32, 100.0, 9));
        c.run_to_completion();
        let mut c2 = cluster(4, RoutePolicy::Affinity, 10_000);
        c2.submit_all(DynamicSonnet::default().generate(32, 100.0, 9));
        c2.run_to_completion();
        for id in 0..32u64 {
            assert_eq!(c.assignment_of(id), c2.assignment_of(id), "id {id}");
            assert!(c.assignment_of(id).is_some());
        }
    }
}
