//! Real-numerics serving engine: drives the AOT-compiled tiny-Llama
//! artifacts (L2 JAX + L1 Pallas, lowered to HLO) through the PJRT
//! runtime with slot-based continuous batching and greedy decoding.
//!
//! Shapes are static (PJRT CPU has no dynamic shapes), so the engine
//! manages a fixed number of batch *slots*: a free slot is filled by the
//! next waiting request (its prompt processed by the `prefill` artifact),
//! and every `decode_step` call advances all occupied slots by one token.
//! Paging therefore lives at the slot/position level here, while the
//! simulated engine (`engine.rs`) exercises the full block-manager path —
//! see DESIGN.md §6 for the trade-off.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::runtime::{HostTensor, Runtime};
use crate::serving::metrics::{MetricsCollector, MetricsSummary, RequestMetrics};
use crate::serving::request::{Phase, Request, Sequence};

/// Model geometry discovered from the artifact manifest metadata.
#[derive(Debug, Clone, Copy)]
pub struct RealModelDims {
    pub batch_slots: usize,
    pub max_seq: usize,
    pub prompt_pad: usize,
    pub vocab: usize,
    /// Flattened KV-cache element count.
    pub kv_elems: usize,
}

/// One occupied slot.
#[derive(Debug, Clone)]
struct Slot {
    seq: Sequence,
    /// Tokens for the sequence (prompt then generated).
    tokens: Vec<i32>,
    /// Current position (tokens in KV).
    pos: usize,
}

/// PJRT-backed LLM serving engine.
pub struct PjrtLlmEngine {
    rt: Runtime,
    dims: RealModelDims,
    slots: Vec<Option<Slot>>,
    waiting: VecDeque<(Request, Vec<i32>)>,
    /// Flat model weights, produced once by the `init_llama_weights`
    /// artifact (no weights ever constructed host-side).
    weights: Vec<f32>,
    /// Host-resident KV cache, re-fed to the artifact every step.
    kv: Vec<f32>,
    pub metrics: MetricsCollector,
    start: Instant,
    pub tokens_generated: u64,
    pub steps: u64,
}

impl PjrtLlmEngine {
    /// Load `init_llama_weights`, `prefill` and `decode_step` from the
    /// artifact directory and materialize the weights.
    pub fn new(artifacts_dir: &str) -> Result<PjrtLlmEngine> {
        let mut rt = Runtime::new(artifacts_dir)?;
        let entry = rt.load("decode_step").context("loading decode_step artifact")?;
        let meta = &entry.entry.meta;
        let get = |k: &str| -> Result<usize> {
            meta.get(k)
                .map(|x| *x as usize)
                .ok_or_else(|| anyhow::anyhow!("decode_step meta missing '{k}'"))
        };
        let dims = RealModelDims {
            batch_slots: get("batch")?,
            max_seq: get("max_seq")?,
            prompt_pad: get("prompt_pad")?,
            vocab: get("vocab")?,
            kv_elems: entry.entry.inputs[2].num_elements(),
        };
        rt.load("prefill").context("loading prefill artifact")?;
        let init = rt.load("init_llama_weights").context("loading weight init artifact")?;
        let weights = init.run(&[])?.remove(0).as_f32()?.to_vec();
        Ok(PjrtLlmEngine {
            rt,
            dims,
            slots: (0..dims.batch_slots).map(|_| None).collect(),
            waiting: VecDeque::new(),
            weights,
            kv: vec![0.0; dims.kv_elems],
            metrics: MetricsCollector::default(),
            start: Instant::now(),
            tokens_generated: 0,
            steps: 0,
        })
    }

    pub fn dims(&self) -> RealModelDims {
        self.dims
    }

    fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Submit a request with concrete prompt token ids.
    pub fn submit(&mut self, req: Request, prompt: Vec<i32>) -> Result<()> {
        anyhow::ensure!(prompt.len() == req.prompt_len, "prompt length mismatch");
        anyhow::ensure!(prompt.len() <= self.dims.prompt_pad, "prompt exceeds prompt_pad");
        anyhow::ensure!(
            req.prompt_len + req.max_new_tokens <= self.dims.max_seq,
            "request exceeds max_seq"
        );
        self.waiting.push_back((req, prompt));
        Ok(())
    }

    pub fn has_work(&self) -> bool {
        !self.waiting.is_empty() || self.slots.iter().any(|s| s.is_some())
    }

    /// Admit waiting requests into free slots, running the prefill
    /// artifact for each (prompt padded to `prompt_pad`). The first
    /// generated token comes from the prefill's last-position logits, so
    /// TTFT is measured at prefill completion, like a real server.
    fn admit(&mut self) -> Result<()> {
        for slot_idx in 0..self.slots.len() {
            if self.slots[slot_idx].is_some() {
                continue;
            }
            let Some((req, prompt)) = self.waiting.pop_front() else { break };
            let mut padded = prompt.clone();
            padded.resize(self.dims.prompt_pad, 0);
            let plen = prompt.len();
            let pf = self.rt.load("prefill")?;
            let outputs = pf.run(&[
                HostTensor::F32(self.weights.clone()),
                HostTensor::I32(padded),
                HostTensor::F32(std::mem::take(&mut self.kv)),
                HostTensor::I32(vec![slot_idx as i32]),
                HostTensor::I32(vec![plen as i32]),
            ])?;
            // outputs: (last-position logits [vocab], kv')
            let logits = outputs[0].as_f32()?;
            self.kv = match &outputs[1] {
                HostTensor::F32(v) => v.clone(),
                _ => anyhow::bail!("prefill kv output must be f32"),
            };
            let first = argmax(logits) as i32;
            let now = self.now();
            let mut seq = Sequence::new(req);
            seq.phase = Phase::Running;
            seq.kv_len = plen;
            seq.generated = 1;
            seq.first_token_time = Some(now);
            self.tokens_generated += 1;
            let mut tokens = prompt;
            tokens.push(first);
            if seq.is_done() {
                seq.phase = Phase::Finished;
                seq.finish_time = Some(now);
                self.metrics.record(RequestMetrics::from_sequence(&seq));
            } else {
                self.slots[slot_idx] = Some(Slot { seq, tokens, pos: plen });
            }
        }
        Ok(())
    }

    /// One decode step for all occupied slots.
    fn decode_step(&mut self) -> Result<()> {
        let b = self.dims.batch_slots;
        let mut tokens = vec![0i32; b];
        let mut positions = vec![0i32; b];
        let mut active = vec![false; b];
        for (i, s) in self.slots.iter().enumerate() {
            if let Some(slot) = s {
                tokens[i] = *slot.tokens.last().unwrap();
                positions[i] = slot.pos as i32;
                active[i] = true;
            }
        }
        if !active.iter().any(|&a| a) {
            return Ok(());
        }
        let de = self.rt.load("decode_step")?;
        let outputs = de.run(&[
            HostTensor::F32(self.weights.clone()),
            HostTensor::I32(tokens),
            HostTensor::F32(std::mem::take(&mut self.kv)),
            HostTensor::I32(positions),
        ])?;
        let logits = outputs[0].as_f32()?.to_vec();
        self.kv = match &outputs[1] {
            HostTensor::F32(v) => v.clone(),
            _ => anyhow::bail!("decode kv output must be f32"),
        };
        self.steps += 1;
        let now = self.now();
        for i in 0..b {
            if !active[i] {
                continue;
            }
            let slot = self.slots[i].as_mut().unwrap();
            // Greedy argmax over this slot's logits row.
            let next = argmax(&logits[i * self.dims.vocab..(i + 1) * self.dims.vocab]) as i32;
            slot.tokens.push(next);
            slot.pos += 1;
            slot.seq.kv_len += 1;
            slot.seq.generated += 1;
            self.tokens_generated += 1;
            if slot.seq.is_done() || slot.pos + 1 >= self.dims.max_seq {
                slot.seq.phase = Phase::Finished;
                slot.seq.finish_time = Some(now);
                self.metrics.record(RequestMetrics::from_sequence(&slot.seq));
                self.slots[i] = None;
            }
        }
        Ok(())
    }

    /// Run until all submitted requests complete; returns the summary and
    /// all generated token streams (request id order of completion).
    pub fn run_to_completion(&mut self) -> Result<MetricsSummary> {
        self.start = Instant::now();
        while self.has_work() {
            self.admit()?;
            self.decode_step()?;
        }
        self.metrics.makespan = self.now();
        Ok(self.metrics.summary())
    }
}

/// Index of the maximum element (greedy sampling).
fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in row.iter().enumerate() {
        if x > row[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    // PjrtLlmEngine itself requires compiled artifacts; its integration
    // tests live in rust/tests/integration_runtime.rs and
    // examples/e2e_real_serving.rs.

    #[test]
    fn argmax_picks_maximum() {
        assert_eq!(argmax(&[0.1, 3.0, -2.0, 3.0]), 1); // first max wins
        assert_eq!(argmax(&[-1.0]), 0);
    }
}
