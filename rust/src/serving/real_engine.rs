//! Real-numerics serving engine: drives the AOT-compiled tiny-Llama
//! artifacts (L2 JAX + L1 Pallas, lowered to HLO) through the PJRT
//! runtime with slot-based continuous batching and greedy decoding.
//!
//! Since the multi-layer unification, this file no longer owns a step
//! loop: `PjrtBackend` implements `serving::engine::Backend` (prefill =
//! run the `prefill` artifact per admitted prompt, decode = one
//! `decode_step` artifact call for all occupied slots) and the shared
//! `EngineCore` drives it under a `WallClock`. Scheduling, KV-block
//! bookkeeping, tracing and metrics emission are therefore *identical*
//! to the simulated path — the only difference is where step durations
//! come from.
//!
//! Shapes are static (PJRT CPU has no dynamic shapes), so the backend
//! maps each running request onto a fixed batch *slot*; the engine
//! config pins `max_decode_batch` to the slot count and sizes the block
//! pool so KV pressure can never preempt (a preempted slot would need
//! token-level recompute the artifacts do not express).

use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::{DeviceKind, ServingConfig};
use crate::runtime::{HostTensor, Runtime};
use crate::serving::engine::{Backend, DecodeWork, EngineCore, PrefillItem, WallClock};
use crate::serving::metrics::{MetricsCollector, MetricsSummary};
use crate::serving::request::{Request, RequestId};
use crate::util::ceil_div;
use crate::util::fasthash::FastMap;

/// Model geometry discovered from the artifact manifest metadata.
#[derive(Debug, Clone, Copy)]
pub struct RealModelDims {
    pub batch_slots: usize,
    pub max_seq: usize,
    pub prompt_pad: usize,
    pub vocab: usize,
    /// Flattened KV-cache element count.
    pub kv_elems: usize,
}

/// Per-request generation state held by the backend.
#[derive(Debug, Clone)]
struct SlotState {
    slot: usize,
    /// Tokens for the sequence (prompt then generated).
    tokens: Vec<i32>,
    /// Current position (tokens in KV).
    pos: usize,
}

/// PJRT execution backend: owns the runtime, weights, the host-resident
/// KV buffer and the slot map. Step durations are measured wall time.
pub struct PjrtBackend {
    rt: Runtime,
    dims: RealModelDims,
    /// Flat model weights, produced once by the `init_llama_weights`
    /// artifact (no weights ever constructed host-side).
    weights: Vec<f32>,
    /// Host-resident KV cache, re-fed to the artifact every step.
    kv: Vec<f32>,
    /// slot index -> occupying request.
    slots: Vec<Option<RequestId>>,
    state: FastMap<RequestId, SlotState>,
    /// Prompts staged at submit time, consumed at (first) prefill.
    prompts: FastMap<RequestId, Vec<i32>>,
    pub tokens_generated: u64,
    pub steps: u64,
    /// First artifact error; the engine wrapper surfaces it and aborts.
    error: Option<anyhow::Error>,
}

impl PjrtBackend {
    fn new(artifacts_dir: &str) -> Result<PjrtBackend> {
        let mut rt = Runtime::new(artifacts_dir)?;
        let entry = rt.load("decode_step").context("loading decode_step artifact")?;
        let meta = &entry.entry.meta;
        let get = |k: &str| -> Result<usize> {
            meta.get(k)
                .map(|x| *x as usize)
                .ok_or_else(|| anyhow::anyhow!("decode_step meta missing '{k}'"))
        };
        let dims = RealModelDims {
            batch_slots: get("batch")?,
            max_seq: get("max_seq")?,
            prompt_pad: get("prompt_pad")?,
            vocab: get("vocab")?,
            kv_elems: entry.entry.inputs[2].num_elements(),
        };
        rt.load("prefill").context("loading prefill artifact")?;
        let init = rt.load("init_llama_weights").context("loading weight init artifact")?;
        let weights = init.run(&[])?.remove(0).as_f32()?.to_vec();
        Ok(PjrtBackend {
            rt,
            dims,
            weights,
            kv: vec![0.0; dims.kv_elems],
            slots: (0..dims.batch_slots).map(|_| None).collect(),
            state: FastMap::default(),
            prompts: FastMap::default(),
            tokens_generated: 0,
            steps: 0,
            error: None,
        })
    }

    fn take_error(&mut self) -> Option<anyhow::Error> {
        self.error.take()
    }

    /// Run one prompt through the `prefill` artifact; the last-position
    /// logits give the first generated token, like a real server.
    fn prefill_one(&mut self, id: RequestId, prompt: Vec<i32>) -> Result<()> {
        let slot_idx = self
            .slots
            .iter()
            .position(|s| s.is_none())
            .expect("scheduler caps running sequences at the slot count");
        let plen = prompt.len();
        let mut padded = prompt.clone();
        padded.resize(self.dims.prompt_pad, 0);
        let pf = self.rt.load("prefill")?;
        let outputs = pf.run(&[
            HostTensor::F32(self.weights.clone()),
            HostTensor::I32(padded),
            HostTensor::F32(std::mem::take(&mut self.kv)),
            HostTensor::I32(vec![slot_idx as i32]),
            HostTensor::I32(vec![plen as i32]),
        ])?;
        // outputs: (last-position logits [vocab], kv')
        let logits = outputs[0].as_f32()?;
        self.kv = match &outputs[1] {
            HostTensor::F32(v) => v.clone(),
            _ => anyhow::bail!("prefill kv output must be f32"),
        };
        let first = argmax(logits) as i32;
        self.tokens_generated += 1;
        let mut tokens = prompt;
        tokens.push(first);
        self.slots[slot_idx] = Some(id);
        self.state.insert(id, SlotState { slot: slot_idx, tokens, pos: plen });
        Ok(())
    }

    /// One `decode_step` artifact call advancing every sequence in `ids`.
    fn decode_batch(&mut self, ids: &[RequestId]) -> Result<()> {
        let b = self.dims.batch_slots;
        let mut tokens = vec![0i32; b];
        let mut positions = vec![0i32; b];
        let mut active = vec![false; b];
        for id in ids {
            let st = self.state.get(id).expect("decoded sequence has a slot");
            tokens[st.slot] = *st.tokens.last().expect("slot has tokens");
            positions[st.slot] = st.pos as i32;
            active[st.slot] = true;
        }
        if !active.iter().any(|&a| a) {
            return Ok(());
        }
        let de = self.rt.load("decode_step")?;
        let outputs = de.run(&[
            HostTensor::F32(self.weights.clone()),
            HostTensor::I32(tokens),
            HostTensor::F32(std::mem::take(&mut self.kv)),
            HostTensor::I32(positions),
        ])?;
        let logits = outputs[0].as_f32()?.to_vec();
        self.kv = match &outputs[1] {
            HostTensor::F32(v) => v.clone(),
            _ => anyhow::bail!("decode kv output must be f32"),
        };
        self.steps += 1;
        for id in ids {
            let st = self.state.get_mut(id).expect("decoded sequence has a slot");
            // Greedy argmax over this slot's logits row.
            let row = &logits[st.slot * self.dims.vocab..(st.slot + 1) * self.dims.vocab];
            st.tokens.push(argmax(row) as i32);
            st.pos += 1;
            self.tokens_generated += 1;
        }
        Ok(())
    }
}

impl Backend for PjrtBackend {
    fn prefill(&mut self, batch: &[PrefillItem]) -> f64 {
        let t0 = Instant::now();
        if self.error.is_none() {
            for item in batch {
                let prompt = self
                    .prompts
                    .get(&item.id)
                    .cloned()
                    .expect("prompt staged at submit");
                if let Err(e) = self.prefill_one(item.id, prompt) {
                    self.error = Some(e);
                    break;
                }
            }
        }
        t0.elapsed().as_secs_f64()
    }

    fn decode(&mut self, work: &DecodeWork) -> f64 {
        let t0 = Instant::now();
        if self.error.is_none() {
            if let Err(e) = self.decode_batch(&work.ids) {
                self.error = Some(e);
            }
        }
        t0.elapsed().as_secs_f64()
    }

    fn prefill_emits_first_token(&self) -> bool {
        true
    }

    fn release(&mut self, id: RequestId) {
        if let Some(st) = self.state.remove(&id) {
            self.slots[st.slot] = None;
        }
        self.prompts.remove(&id);
    }

    fn preempt(&mut self, id: RequestId) {
        // The artifacts express no token-level recompute: a preempted
        // sequence cannot be restored. `PjrtLlmEngine::new` sizes the
        // block pool so this is unreachable; surface a hard error rather
        // than silently truncating output if that invariant ever breaks.
        self.release(id);
        if self.error.is_none() {
            self.error = Some(anyhow::anyhow!(
                "request {id} was preempted, but the PJRT backend cannot recompute \
                 sequences (static slots); the engine's KV pool must be sized so \
                 preemption never occurs"
            ));
        }
    }
}

/// PJRT-backed LLM serving engine: the shared `EngineCore` step loop over
/// a [`PjrtBackend`] and a wall clock.
pub struct PjrtLlmEngine {
    core: EngineCore<PjrtBackend, WallClock>,
}

impl PjrtLlmEngine {
    /// Load `init_llama_weights`, `prefill` and `decode_step` from the
    /// artifact directory and materialize the weights.
    pub fn new(artifacts_dir: &str) -> Result<PjrtLlmEngine> {
        let backend = PjrtBackend::new(artifacts_dir)?;
        let dims = backend.dims;
        // Static-shape serving config: one scheduler seat per batch slot,
        // and a block pool sized so KV pressure can never force the
        // preemption path (the artifacts cannot recompute a sequence).
        let block_size = 16;
        let cfg = ServingConfig {
            device: DeviceKind::Gaudi2, // wall-clock path; device model unused
            tensor_parallel: 1,
            block_size,
            num_blocks: dims.batch_slots * ceil_div(dims.max_seq, block_size),
            max_decode_batch: dims.batch_slots,
            max_prefill_tokens: dims.max_seq * dims.batch_slots.max(1),
            max_seq_len: dims.max_seq,
            use_block_list: true,
            watermark: 0.0,
            ..Default::default()
        };
        Ok(PjrtLlmEngine { core: EngineCore::with_clock(cfg, backend, WallClock::new()) })
    }

    pub fn dims(&self) -> RealModelDims {
        self.core.backend().dims
    }

    /// Tokens generated so far (always current, even after an error).
    pub fn tokens_generated(&self) -> u64 {
        self.core.backend().tokens_generated
    }

    /// Decode steps executed so far (always current, even after an error).
    pub fn steps(&self) -> u64 {
        self.core.backend().steps
    }

    pub fn metrics(&self) -> &MetricsCollector {
        &self.core.metrics
    }

    /// Submit a request with concrete prompt token ids.
    pub fn submit(&mut self, req: Request, prompt: Vec<i32>) -> Result<()> {
        let dims = self.core.backend().dims;
        anyhow::ensure!(prompt.len() == req.prompt_len, "prompt length mismatch");
        anyhow::ensure!(prompt.len() <= dims.prompt_pad, "prompt exceeds prompt_pad");
        anyhow::ensure!(
            req.prompt_len + req.max_new_tokens <= dims.max_seq,
            "request exceeds max_seq"
        );
        self.core.backend_mut().prompts.insert(req.id, prompt);
        self.core.submit(req);
        Ok(())
    }

    pub fn has_work(&self) -> bool {
        self.core.has_any_work()
    }

    /// Run until all submitted requests complete; returns the summary.
    pub fn run_to_completion(&mut self) -> Result<MetricsSummary> {
        self.core.clock_mut().reset();
        while self.core.has_any_work() {
            self.core.advance();
            if let Some(e) = self.core.backend_mut().take_error() {
                return Err(e);
            }
        }
        self.core.metrics.makespan = self.core.clock();
        Ok(self.core.metrics.summary())
    }
}

/// Index of the maximum element (greedy sampling).
fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in row.iter().enumerate() {
        if x > row[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    // PjrtLlmEngine itself requires compiled artifacts; its integration
    // tests live in rust/tests/integration_runtime.rs and
    // examples/e2e_real_serving.rs.

    #[test]
    fn argmax_picks_maximum() {
        assert_eq!(argmax(&[0.1, 3.0, -2.0, 3.0]), 1); // first max wins
        assert_eq!(argmax(&[-1.0]), 0);
    }
}
