//! Continuous-batching scheduler (Orca/vLLM-style): interleaves prefills
//! and decodes, bounded by `max_prefill_tokens`, `max_decode_batch`
//! (the Fig 17(d) sweep knob) and KV-block availability. Shared-prefix
//! residency is charged here against the same block pool and watermark
//! as per-sequence KV: admission acquires (and pins) the request's
//! prefix group, retirement and preemption release the pin.
//!
//! Scheduling is traffic-class aware (`serving::qos`): admission takes
//! the highest-priority waiting request first (FIFO within a class), and
//! under decode memory pressure a *strictly lower-priority* running
//! sequence is preempted before the prefix cache is touched (its idle
//! prefixes may belong to higher classes); only then does the scheduler
//! evict an idle prefix, and as a last resort preempt the lowest-
//! priority (youngest within the class) running sequence. With a single
//! class — uniform priority 0 — every tie-break degenerates to the
//! legacy order (FIFO admission, evict-before-preempt, youngest victim),
//! which is what keeps tagged uniform-priority runs bitwise-equal to
//! untagged default-class runs (the qos-sweep parity claim). One
//! deliberate behavior fix relative to the pre-refactor code: a
//! sequence preempted earlier in the same decode step is skipped, not
//! decoded (the legacy code let it run in two places at once).

use std::collections::VecDeque;

use crate::config::ServingConfig;
use crate::serving::kv_cache::{KvBlockManager, PrefixAcquire};
use crate::serving::qos::ClassSet;
use crate::serving::request::{Phase, Request, RequestId, Sequence};
use crate::util::fasthash::FastMap;

/// What the engine should execute next.
#[derive(Debug, Clone, PartialEq)]
pub enum Step {
    /// Process prompts for these request ids (chunked by token budget).
    Prefill(Vec<RequestId>),
    /// One decode iteration for these running sequences.
    Decode(Vec<RequestId>),
    /// Nothing schedulable right now.
    Idle,
}

/// Continuous-batching scheduler + sequence store.
#[derive(Debug)]
pub struct Scheduler {
    cfg: ServingConfig,
    pub kv: KvBlockManager,
    waiting: VecDeque<RequestId>,
    running: Vec<RequestId>,
    seqs: FastMap<RequestId, Sequence>,
    /// Completed sequences (kept for metrics harvesting).
    finished: Vec<RequestId>,
    /// Sequences preempted since the last drain (so the engine can release
    /// backend-side state, e.g. a PJRT batch slot).
    preempted: Vec<RequestId>,
    /// Recompute-cost weight for `EvictionPolicy::CostAware`, supplied by
    /// the backend's device cost model (1.0 until the engine sets it).
    prefix_weight: f64,
    /// The deployment's traffic classes (from `ServingConfig::classes`):
    /// admission and preemption order consult per-class priority.
    classes: ClassSet,
    /// True when every declared class has the same priority (always true
    /// for single-class configs): priority can never reorder anything,
    /// so admission/preemption/decode ordering take the legacy O(1)
    /// fast paths — which also makes the single-class bitwise parity
    /// with the pre-refactor scheduler structural, not incidental.
    uniform_priority: bool,
    /// Cached multi-class decode order: `running` stable-sorted by
    /// descending class priority. A request's priority is fixed at
    /// submit, so the order only changes when the running *membership*
    /// does — every mutation site marks it dirty and the next decode
    /// pass re-sorts once, instead of the per-decode-tick sort the
    /// pre-cache code paid. (Uniform-priority configs never touch it.)
    decode_order: Vec<RequestId>,
    decode_order_dirty: bool,
}

impl Scheduler {
    pub fn new(cfg: ServingConfig) -> Scheduler {
        cfg.validate().expect("valid config");
        let kv = KvBlockManager::new(cfg.num_blocks, cfg.block_size, cfg.watermark)
            .with_prefix_cache(cfg.prefix_cache_blocks, cfg.eviction);
        let classes = cfg.classes.clone();
        let uniform_priority =
            classes.iter().all(|c| c.priority == classes.class(0).priority);
        Scheduler {
            cfg,
            kv,
            waiting: VecDeque::new(),
            running: Vec::new(),
            seqs: FastMap::default(),
            finished: Vec::new(),
            preempted: Vec::new(),
            prefix_weight: 1.0,
            classes,
            uniform_priority,
            decode_order: Vec::new(),
            decode_order_dirty: true,
        }
    }

    /// The cached multi-class decode order, re-sorted only when the
    /// running membership changed since last use. The sort is the same
    /// stable descending-priority sort the per-tick path ran, over the
    /// same `running` snapshot, so the cached order is *identical* to a
    /// fresh sort — the cache changes when work happens, never what is
    /// scheduled.
    fn priority_order(&mut self) -> &[RequestId] {
        if self.decode_order_dirty {
            let mut order = std::mem::take(&mut self.decode_order);
            order.clear();
            order.extend_from_slice(&self.running);
            order.sort_by_key(|id| std::cmp::Reverse(self.priority_of(*id)));
            self.decode_order = order;
            self.decode_order_dirty = false;
        }
        &self.decode_order
    }

    /// Scheduling priority of a stored sequence's traffic class.
    fn priority_of(&self, id: RequestId) -> u8 {
        self.classes.priority_of(self.seqs[&id].req.class_id)
    }

    /// Set the recompute-cost weight cost-aware eviction ranks prefixes
    /// by (the engine threads it in from `Backend::prefix_recompute_weight`).
    pub fn set_prefix_weight(&mut self, weight: f64) {
        assert!(weight.is_finite() && weight > 0.0, "weight must be positive");
        self.prefix_weight = weight;
    }

    pub fn config(&self) -> &ServingConfig {
        &self.cfg
    }

    /// Admit a request into the waiting queue.
    pub fn submit(&mut self, req: Request) {
        assert!(
            req.prompt_len + req.max_new_tokens <= self.cfg.max_seq_len,
            "request exceeds max_seq_len"
        );
        assert!(
            req.class_id < self.classes.len(),
            "request {} tagged with undeclared class {} (config declares {})",
            req.id,
            req.class_id,
            self.classes.len()
        );
        let id = req.id;
        let prev = self.seqs.insert(id, Sequence::new(req));
        assert!(prev.is_none(), "duplicate request id {id}");
        self.waiting.push_back(id);
    }

    pub fn seq(&self, id: RequestId) -> &Sequence {
        &self.seqs[&id]
    }

    pub fn seq_mut(&mut self, id: RequestId) -> &mut Sequence {
        self.seqs.get_mut(&id).unwrap()
    }

    pub fn num_waiting(&self) -> usize {
        self.waiting.len()
    }

    pub fn num_running(&self) -> usize {
        self.running.len()
    }

    pub fn has_work(&self) -> bool {
        !self.waiting.is_empty() || !self.running.is_empty()
    }

    /// Drain ids of finished sequences (for metrics collection).
    pub fn take_finished(&mut self) -> Vec<RequestId> {
        std::mem::take(&mut self.finished)
    }

    /// Drain ids of sequences preempted since the last call.
    pub fn take_preempted(&mut self) -> Vec<RequestId> {
        std::mem::take(&mut self.preempted)
    }

    /// Sequences in decode order (FCFS by arrival).
    pub fn running_ids(&self) -> &[RequestId] {
        &self.running
    }

    /// Position in `waiting` of the next request to consider: highest
    /// class priority first, FIFO within a class. With uniform
    /// priorities this is always position 0 — the legacy plain-FIFO
    /// front, preserving bitwise parity for single-class configs.
    fn best_waiting_pos(&self) -> Option<usize> {
        if self.uniform_priority {
            // Legacy plain FIFO: the front, O(1).
            return if self.waiting.is_empty() { None } else { Some(0) };
        }
        let mut best: Option<(usize, u8)> = None;
        for (pos, id) in self.waiting.iter().enumerate() {
            let p = self.priority_of(*id);
            match best {
                // Strictly-greater keeps the earliest among equals (FIFO).
                Some((_, bp)) if p <= bp => {}
                _ => best = Some((pos, p)),
            }
        }
        best.map(|(pos, _)| pos)
    }

    /// The preemption victim: the lowest-priority running sequence,
    /// youngest (latest-admitted) within that class. With uniform
    /// priorities this is the legacy youngest-running victim.
    fn preempt_victim(&self) -> Option<RequestId> {
        if self.uniform_priority {
            // Legacy youngest-running victim, O(1).
            return self.running.last().copied();
        }
        let mut best: Option<(usize, u8)> = None;
        for (pos, id) in self.running.iter().enumerate() {
            let p = self.priority_of(*id);
            match best {
                // `<=` keeps the latest among equals (the youngest).
                Some((_, bp)) if p > bp => {}
                _ => best = Some((pos, p)),
            }
        }
        best.map(|(pos, _)| self.running[pos])
    }

    /// Decide the next step. vLLM policy: admit prefills while the decode
    /// batch has headroom and blocks allow; otherwise decode. Admission
    /// pulls the highest-priority waiting class first (under watermark
    /// pressure the budget goes to interactive traffic before batch).
    pub fn schedule(&mut self) -> Step {
        // 1. Try to start prefills (prefill-prioritized continuous batching).
        let mut prefill: Vec<RequestId> = Vec::new();
        let mut token_budget = self.cfg.max_prefill_tokens;
        while let Some(pos) = self.best_waiting_pos() {
            let id = self.waiting[pos];
            if self.running.len() + prefill.len() >= self.cfg.max_decode_batch {
                break;
            }
            let s = &self.seqs[&id];
            if s.req.prompt_len > token_budget {
                break;
            }
            if !self.kv.can_admit(s.req.prompt_len) {
                break;
            }
            let (prompt_len, prefix_id, prefix_len) =
                (s.req.prompt_len, s.req.prefix_id, s.req.prefix_len());
            // Acquire the shared prefix from *actual residency*: a hit
            // discounts this prefill, a miss warms the blocks for later
            // sequences, and either way the pin blocks eviction while the
            // sequence runs. The reserve keeps the sequence's own blocks
            // (plus the watermark) untouched so the allocation below
            // cannot fail.
            let (mut hit, mut pinned) = (false, false);
            if let Some(p) = prefix_id {
                let reserve = self.kv.blocks_for(prompt_len) + self.kv.watermark_blocks();
                match self.kv.acquire_prefix(p, prefix_len, self.prefix_weight, reserve) {
                    PrefixAcquire::Hit => (hit, pinned) = (true, true),
                    PrefixAcquire::Warmed => pinned = true,
                    PrefixAcquire::Uncached => {}
                }
            }
            let share = if pinned { prefix_id } else { None };
            self.kv.allocate_prefixed(id, prompt_len, share).expect("can_admit checked");
            let s = self.seqs.get_mut(&id).unwrap();
            s.prefix_hit = hit;
            s.prefix_pinned = pinned;
            token_budget -= prompt_len;
            self.waiting.remove(pos);
            prefill.push(id);
        }
        if !prefill.is_empty() {
            for &id in &prefill {
                let s = self.seqs.get_mut(&id).unwrap();
                s.phase = Phase::Running;
                s.kv_len = s.req.prompt_len;
                self.running.push(id);
            }
            self.decode_order_dirty = true;
            return Step::Prefill(prefill);
        }

        // 2. Decode: grow each running sequence's KV by one token, up to
        // max_decode_batch sequences; preempt the youngest on OOM.
        if self.running.is_empty() {
            return Step::Idle;
        }
        // Decode slots go to higher classes first; the sort is stable, so
        // within a class the running order is preserved — uniform-priority
        // configs skip the sort entirely (the legacy snapshot) and the
        // multi-class path reuses the cached order while membership holds.
        let cap = self.cfg.max_decode_batch;
        let batch: Vec<RequestId> = if self.uniform_priority {
            self.running.iter().copied().take(cap).collect()
        } else {
            self.priority_order().iter().copied().take(cap).collect()
        };
        let mut scheduled = Vec::with_capacity(batch.len());
        for id in batch {
            // A preemption earlier in this loop may have victimized a
            // later batch entry (the lowest class sorts to the end):
            // a preempted sequence is back in `waiting` with its KV
            // freed and must NOT decode — allocating for it here would
            // let it run in two places and complete twice.
            if self.seqs[&id].phase != Phase::Running {
                continue;
            }
            let kv_len = self.seqs[&id].kv_len;
            match self.kv.allocate(id, kv_len + 1) {
                Ok(()) => scheduled.push(id),
                Err(_) => {
                    // QoS ordering under memory pressure: a *strictly
                    // lower-priority* running sequence is preempted before
                    // the prefix cache is touched — its idle prefixes may
                    // belong to higher classes and are worth more than the
                    // low class's progress. With uniform priorities this
                    // arm never fires, preserving the legacy order.
                    if let Some(victim) = self.preempt_victim() {
                        if self.priority_of(victim) < self.priority_of(id) {
                            self.preempt(victim);
                            debug_assert_ne!(victim, id, "strictly lower priority");
                            if self.kv.allocate(id, kv_len + 1).is_ok() {
                                scheduled.push(id);
                            }
                            continue;
                        }
                    }
                    // Evict-or-preempt: reclaiming an idle shared prefix
                    // is strictly cheaper than recomputing a live
                    // sequence of the same (or higher) class.
                    if self.kv.evict_one_idle_prefix()
                        && self.kv.allocate(id, kv_len + 1).is_ok()
                    {
                        scheduled.push(id);
                        continue;
                    }
                    // Last resort: preempt the lowest-priority running
                    // sequence (the *youngest* within that class).
                    if let Some(victim) = self.preempt_victim() {
                        if victim != id || self.running.len() > 1 {
                            self.preempt(victim);
                            // Retry this sequence if it wasn't the victim.
                            if victim != id && self.kv.allocate(id, kv_len + 1).is_ok() {
                                scheduled.push(id);
                            }
                        }
                    }
                }
            }
        }
        if scheduled.is_empty() {
            return Step::Idle;
        }
        Step::Decode(scheduled)
    }

    /// The decode batch `schedule()` would pick right now *if* the
    /// scheduler is in a pure-decode steady state; `None` when it is not.
    /// Steady means the running set is non-empty and the best waiting
    /// request (if any) fails at least one of `schedule()`'s three
    /// admission gates — and each gate stays failed under pure decode:
    /// the batch cap (nobody retires inside a completion-free window),
    /// the prefill token budget (a constant), and `can_admit` (free
    /// blocks only shrink while decode grows KV; the only replenishers —
    /// retire, preempt, cancel, prefix eviction — cannot fire in a
    /// window). This is what lets `EngineCore::try_macro_burst` prove the
    /// batch stable over a whole window instead of re-running the
    /// admission pass per tick; the caller still bounds the window by
    /// finish distance and the free-block budget.
    pub fn steady_decode_batch(&mut self) -> Option<&[RequestId]> {
        if self.running.is_empty() {
            return None;
        }
        if let Some(pos) = self.best_waiting_pos() {
            let s = &self.seqs[&self.waiting[pos]];
            let blocked = self.running.len() >= self.cfg.max_decode_batch
                || s.req.prompt_len > self.cfg.max_prefill_tokens
                || !self.kv.can_admit(s.req.prompt_len);
            if !blocked {
                return None;
            }
        }
        let cap = self.cfg.max_decode_batch;
        if self.uniform_priority {
            let n = self.running.len().min(cap);
            Some(&self.running[..n])
        } else {
            let order = self.priority_order();
            let n = order.len().min(cap);
            Some(&order[..n])
        }
    }

    /// Record the outcome of an executed decode step: each sequence gained
    /// one token at engine time `now`.
    pub fn complete_decode(&mut self, ids: &[RequestId], now: f64) {
        for &id in ids {
            let s = self.seqs.get_mut(&id).unwrap();
            s.kv_len += 1;
            s.generated += 1;
            if s.first_token_time.is_none() {
                s.first_token_time = Some(now);
            }
            if s.is_done() {
                s.phase = Phase::Finished;
                s.finish_time = Some(now);
            }
        }
        // Retire finished sequences.
        self.retire_finished(ids);
    }

    /// Retire any of `ids` whose phase is `Finished`: drop them from the
    /// running set, free their KV and queue them for metrics harvesting.
    /// (Also used by the engine when a prefill itself completes a request —
    /// real backends emit the first token from the prefill logits.)
    pub fn retire_finished(&mut self, ids: &[RequestId]) {
        let done: Vec<RequestId> =
            ids.iter().copied().filter(|id| self.seqs[id].phase == Phase::Finished).collect();
        for id in done {
            self.running.retain(|&r| r != id);
            self.decode_order_dirty = true;
            self.release_prefix_pin(id);
            self.kv.free(id);
            self.finished.push(id);
        }
    }

    /// Drop the sequence's pin on its shared prefix (if it holds one);
    /// the blocks stay resident — warm for the next request of the group
    /// — until eviction reclaims them.
    fn release_prefix_pin(&mut self, id: RequestId) {
        let s = self.seqs.get_mut(&id).unwrap();
        if s.prefix_pinned {
            s.prefix_pinned = false;
            let p = s.req.prefix_id.expect("pinned implies tagged");
            self.kv.release_prefix(p);
        }
    }

    /// Preempt a running sequence: free its KV and put it back at the
    /// *front* of the waiting queue (recompute-style preemption).
    fn preempt(&mut self, id: RequestId) {
        self.running.retain(|&r| r != id);
        self.decode_order_dirty = true;
        self.release_prefix_pin(id);
        self.kv.free(id);
        let s = self.seqs.get_mut(&id).unwrap();
        s.phase = Phase::Preempted;
        s.kv_len = 0;
        s.prefix_hit = false;
        // Preserve generated count semantics: recompute regenerates the
        // same tokens, so keep `generated` but require full re-prefill of
        // prompt + generated so far.
        s.preemptions += 1;
        self.waiting.push_front(id);
        self.preempted.push(id);
    }

    /// Current decode KV lengths (for the backend's cost model).
    pub fn kv_lens(&self, ids: &[RequestId]) -> Vec<usize> {
        ids.iter().map(|id| self.seqs[id].kv_len).collect()
    }

    /// Non-panicking sequence lookup (the chaos/hedging layer probes ids
    /// that may have been evacuated or cancelled).
    pub fn try_seq(&self, id: RequestId) -> Option<&Sequence> {
        self.seqs.get(&id)
    }

    /// Remove an unfinished sequence entirely — waiting or running, its
    /// KV freed, its prefix pin released, its state dropped — and return
    /// the original request. `None` if the id is unknown or already
    /// finished (a finished sequence has won its race; metrics keep it).
    /// Used by hedging to cancel the losing copy without it ever
    /// completing, and therefore without double-counting tokens.
    pub fn cancel(&mut self, id: RequestId) -> Option<Request> {
        match self.seqs.get(&id) {
            None => return None,
            Some(s) if s.phase == Phase::Finished => return None,
            Some(_) => {}
        }
        self.waiting.retain(|&w| w != id);
        self.running.retain(|&r| r != id);
        self.decode_order_dirty = true;
        self.preempted.retain(|&p| p != id);
        self.release_prefix_pin(id);
        self.kv.free(id);
        self.seqs.remove(&id).map(|s| s.req)
    }

    /// Crash evacuation: drain every unfinished sequence (waiting,
    /// running or preempted), free all their KV and prefix pins, and
    /// return the original requests in admission order (waiting-queue
    /// order first, then running) so the cluster can requeue them
    /// through the router. Finished sequences must already have been
    /// harvested — the engine harvests inside every step, so between
    /// cluster events there is nothing pending.
    pub fn evacuate(&mut self) -> Vec<Request> {
        debug_assert!(
            self.finished.is_empty(),
            "evacuate with unharvested completions — crash fired mid-step?"
        );
        let ids: Vec<RequestId> =
            self.waiting.iter().copied().chain(self.running.iter().copied()).collect();
        self.waiting.clear();
        self.running.clear();
        self.decode_order_dirty = true;
        self.preempted.clear();
        let mut out = Vec::with_capacity(ids.len());
        for id in ids {
            self.release_prefix_pin(id);
            self.kv.free(id);
            if let Some(s) = self.seqs.remove(&id) {
                out.push(s.req);
            }
        }
        out
    }

    /// Preemption storm: forcibly preempt up to `count` running
    /// sequences (normal victim order — lowest priority, youngest within
    /// the class). Returns how many were actually preempted. Victims
    /// land back in `waiting` and re-prefill, exactly like a memory-
    /// pressure preemption.
    pub fn force_preempt(&mut self, count: usize) -> usize {
        let mut hit = 0;
        for _ in 0..count {
            match self.preempt_victim() {
                Some(victim) => {
                    self.preempt(victim);
                    hit += 1;
                }
                None => break,
            }
        }
        hit
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceKind;

    fn cfg(max_decode_batch: usize, num_blocks: usize) -> ServingConfig {
        ServingConfig {
            device: DeviceKind::Gaudi2,
            max_decode_batch,
            num_blocks,
            block_size: 128,
            max_prefill_tokens: 4096,
            max_seq_len: 4096,
            ..Default::default()
        }
    }

    #[test]
    fn prefill_then_decode_then_finish() {
        let mut s = Scheduler::new(cfg(8, 64));
        s.submit(Request::new(1, 100, 2, 0.0));
        assert_eq!(s.schedule(), Step::Prefill(vec![1]));
        assert_eq!(s.num_running(), 1);
        assert_eq!(s.schedule(), Step::Decode(vec![1]));
        s.complete_decode(&[1], 0.1);
        assert_eq!(s.seq(1).first_token_time, Some(0.1));
        assert_eq!(s.schedule(), Step::Decode(vec![1]));
        s.complete_decode(&[1], 0.2);
        assert_eq!(s.seq(1).phase, Phase::Finished);
        assert_eq!(s.take_finished(), vec![1]);
        assert_eq!(s.schedule(), Step::Idle);
        assert!(s.kv.check_conservation());
        assert_eq!(s.kv.num_free(), 64);
    }

    #[test]
    fn decode_batch_capped() {
        let mut s = Scheduler::new(cfg(2, 256));
        for i in 0..4 {
            s.submit(Request::new(i, 64, 10, 0.0));
        }
        // Only 2 admitted (max_decode_batch).
        match s.schedule() {
            Step::Prefill(ids) => assert_eq!(ids.len(), 2),
            other => panic!("{other:?}"),
        }
        match s.schedule() {
            Step::Decode(ids) => assert_eq!(ids.len(), 2),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn prefill_token_budget_chunks_admission() {
        let mut s = Scheduler::new(Scheduler::new(cfg(16, 256)).cfg.clone());
        for i in 0..4 {
            s.submit(Request::new(i, 2000, 4, 0.0));
        }
        match s.schedule() {
            // 4096-token budget fits two 2000-token prompts.
            Step::Prefill(ids) => assert_eq!(ids.len(), 2),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn preemption_under_memory_pressure() {
        // 8 blocks of 128 = 1024 tokens capacity; two sequences that want
        // to grow past it.
        let mut s = Scheduler::new(cfg(4, 8));
        s.submit(Request::new(1, 384, 400, 0.0));
        s.submit(Request::new(2, 384, 400, 0.0));
        let _ = s.schedule(); // prefill both (3 blocks each, 2 free)
        assert_eq!(s.num_running(), 2);
        // Decode until blocks run out; the younger (2) gets preempted.
        let mut preempted = false;
        for step in 0..400 {
            match s.schedule() {
                Step::Decode(ids) => {
                    let now = step as f64;
                    s.complete_decode(&ids, now);
                }
                Step::Prefill(ids) => {
                    // Re-admission of the preempted sequence.
                    assert!(preempted, "unexpected prefill before preemption");
                    assert_eq!(ids, vec![2]);
                    break;
                }
                Step::Idle => break,
            }
            if s.seq(2).phase == Phase::Preempted {
                preempted = true;
                assert_eq!(s.seq(2).preemptions, 1);
                assert!(s.kv.check_conservation());
            }
        }
        assert!(preempted, "expected a preemption");
    }

    #[test]
    #[should_panic(expected = "duplicate request id")]
    fn duplicate_ids_rejected() {
        let mut s = Scheduler::new(cfg(4, 16));
        s.submit(Request::new(7, 10, 5, 0.0));
        s.submit(Request::new(7, 10, 5, 0.0));
    }

    #[test]
    #[should_panic(expected = "exceeds max_seq_len")]
    fn oversized_request_rejected() {
        let mut s = Scheduler::new(cfg(4, 16));
        s.submit(Request::new(1, 4000, 200, 0.0));
    }

    #[test]
    fn prefix_hit_from_residency_and_release_on_finish() {
        let mut s = Scheduler::new(cfg(8, 64));
        s.submit(Request::new(1, 512, 1, 0.0).with_prefix(9));
        assert_eq!(s.schedule(), Step::Prefill(vec![1]));
        // First of the group: warmed, not a hit; pinned while running.
        assert!(!s.seq(1).prefix_hit && s.seq(1).prefix_pinned);
        assert!(s.kv.prefix_resident(9));
        let prefix_blocks = s.kv.prefix_resident_blocks();
        assert!(prefix_blocks > 0);
        // The sequence shares the resident front: exclusive usage is its
        // full prompt minus the shared blocks.
        let seq_blocks = s.kv.blocks_of(1).unwrap().len();
        assert_eq!(seq_blocks, s.kv.blocks_for(512));
        // The shared front is part of the sequence's table, so the pool
        // paid exactly the sequence's block count (no double charge).
        assert_eq!(s.kv.num_free(), 64 - seq_blocks);
        let _ = s.schedule();
        s.complete_decode(&[1], 0.1);
        assert_eq!(s.take_finished(), vec![1]);
        // Finished: exclusive blocks returned, prefix stays warm.
        assert!(s.kv.prefix_resident(9));
        assert_eq!(s.kv.num_free() + s.kv.prefix_resident_blocks(), 64);
        // Second of the group: a residency hit.
        s.submit(Request::new(2, 512, 1, 0.0).with_prefix(9));
        assert_eq!(s.schedule(), Step::Prefill(vec![2]));
        assert!(s.seq(2).prefix_hit && s.seq(2).prefix_pinned);
        let st = s.kv.prefix_stats();
        assert_eq!((st.hits, st.misses), (1, 1));
        assert!(s.kv.check_conservation());
    }

    #[test]
    fn idle_prefix_evicted_before_preempting_a_sequence() {
        // 8 blocks of 128. A finished prefix group leaves 2 idle resident
        // blocks; a growing sequence must reclaim those instead of
        // preempting its peer.
        let mut s = Scheduler::new(ServingConfig {
            prefix_cache_blocks: 8,
            watermark: 0.0,
            ..cfg(4, 8)
        });
        s.submit(Request::new(1, 640, 2, 0.0).with_prefix(3)); // prefix 256 tok = 2 blocks
        let _ = s.schedule(); // prefill (5 blocks: 2 shared + 3 exclusive)
        let _ = s.schedule(); // decode
        s.complete_decode(&[1], 0.1);
        let _ = s.schedule();
        s.complete_decode(&[1], 0.2);
        assert_eq!(s.take_finished(), vec![1]);
        assert!(s.kv.prefix_resident(3), "prefix idles warm after finish");
        // An untagged pair now fills the pool (3 blocks each, 2 resident,
        // 0 free); the very first decode growth must evict the idle
        // prefix rather than preempt a peer.
        s.submit(Request::new(2, 384, 200, 1.0));
        s.submit(Request::new(3, 384, 200, 1.0));
        let _ = s.schedule(); // prefill both
        assert_eq!(s.num_running(), 2);
        assert_eq!(s.kv.num_free(), 0);
        match s.schedule() {
            Step::Decode(ids) => {
                assert_eq!(ids.len(), 2, "both sequences keep decoding");
                s.complete_decode(&ids, 2.0);
            }
            other => panic!("{other:?}"),
        }
        assert!(!s.kv.prefix_resident(3), "idle prefix evicted under decode pressure");
        assert_eq!(s.seq(2).preemptions + s.seq(3).preemptions, 0, "no preemption needed");
        assert_eq!(s.kv.prefix_stats().evictions, 1);
        assert!(s.kv.check_conservation());
    }

    #[test]
    fn fcfs_order_preserved() {
        let mut s = Scheduler::new(cfg(8, 256));
        for i in 0..5 {
            s.submit(Request::new(i, 64, 3, i as f64));
        }
        match s.schedule() {
            Step::Prefill(ids) => assert_eq!(ids, vec![0, 1, 2, 3, 4]),
            other => panic!("{other:?}"),
        }
    }

    fn three_tier_cfg(max_decode_batch: usize, num_blocks: usize) -> ServingConfig {
        ServingConfig {
            classes: crate::serving::qos::ClassSet::three_tier(),
            ..cfg(max_decode_batch, num_blocks)
        }
    }

    #[test]
    #[should_panic(expected = "undeclared class")]
    fn undeclared_class_rejected() {
        let mut s = Scheduler::new(cfg(4, 16));
        s.submit(Request::new(1, 10, 5, 0.0).with_class(3));
    }

    #[test]
    fn admission_pulls_higher_classes_first_fifo_within_class() {
        // Submission order: background, batch, interactive, interactive.
        // Admission order must be interactive (FIFO among the two), then
        // batch, then background.
        let mut s = Scheduler::new(three_tier_cfg(8, 256));
        s.submit(Request::new(0, 64, 3, 0.0).with_class(2)); // background (prio 0)
        s.submit(Request::new(1, 64, 3, 0.0).with_class(1)); // batch (prio 1)
        s.submit(Request::new(2, 64, 3, 0.0).with_class(0)); // interactive (prio 2)
        s.submit(Request::new(3, 64, 3, 0.0).with_class(0)); // interactive
        match s.schedule() {
            Step::Prefill(ids) => assert_eq!(ids, vec![2, 3, 1, 0]),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn watermark_pressure_admits_interactive_first() {
        // Batch cap of 2: only the two interactive requests get in even
        // though a background request arrived first.
        let mut s = Scheduler::new(three_tier_cfg(2, 256));
        s.submit(Request::new(0, 64, 10, 0.0).with_class(2));
        s.submit(Request::new(1, 64, 10, 0.0).with_class(0));
        s.submit(Request::new(2, 64, 10, 0.0).with_class(0));
        match s.schedule() {
            Step::Prefill(ids) => assert_eq!(ids, vec![1, 2]),
            other => panic!("{other:?}"),
        }
        assert_eq!(s.num_waiting(), 1);
    }

    #[test]
    fn preemption_victimizes_the_lowest_priority_class() {
        // 8 blocks of 128 = 1024 tokens. An interactive and a background
        // sequence both want to grow past capacity: the background one
        // (submitted first, so the *older* — legacy youngest-first would
        // have spared it) must be the victim.
        let mut s = Scheduler::new(ServingConfig {
            watermark: 0.0,
            ..three_tier_cfg(4, 8)
        });
        s.submit(Request::new(1, 384, 400, 0.0).with_class(2)); // background
        s.submit(Request::new(2, 384, 400, 0.0).with_class(0)); // interactive
        let _ = s.schedule(); // prefill both (3 blocks each, 2 free)
        assert_eq!(s.num_running(), 2);
        for step in 0..400 {
            match s.schedule() {
                Step::Decode(ids) => s.complete_decode(&ids, step as f64),
                _ => break,
            }
            if s.seq(1).phase == Phase::Preempted {
                break;
            }
            assert_ne!(s.seq(2).phase, Phase::Preempted, "interactive must never be victimized");
        }
        assert_eq!(s.seq(1).phase, Phase::Preempted, "background is the victim");
        assert_eq!(s.seq(2).preemptions, 0);
        assert!(s.kv.check_conservation());
    }

    #[test]
    fn lower_priority_preempted_before_idle_prefix_eviction() {
        // A finished interactive request leaves an idle resident prefix.
        // When an interactive sequence later hits memory pressure while a
        // background sequence runs, the background sequence is preempted
        // and the higher class's warm prefix survives.
        let mut s = Scheduler::new(ServingConfig {
            prefix_cache_blocks: 8,
            watermark: 0.0,
            ..three_tier_cfg(4, 8)
        });
        s.submit(Request::new(1, 640, 2, 0.0).with_class(0).with_prefix(3)); // 2 shared blocks
        let _ = s.schedule(); // prefill
        let _ = s.schedule(); // decode
        s.complete_decode(&[1], 0.1);
        let _ = s.schedule();
        s.complete_decode(&[1], 0.2);
        assert_eq!(s.take_finished(), vec![1]);
        assert!(s.kv.prefix_resident(3), "prefix idles warm after finish");
        // Background then interactive fill the rest of the pool.
        s.submit(Request::new(2, 384, 200, 1.0).with_class(2));
        s.submit(Request::new(3, 384, 200, 1.0).with_class(0));
        let _ = s.schedule(); // prefill both (3 + 3 blocks; 2 resident, 0 free)
        assert_eq!(s.num_running(), 2);
        assert_eq!(s.kv.num_free(), 0);
        // First decode growth: the interactive sequence's allocation must
        // preempt the background peer, NOT evict the warm prefix.
        let mut preempted_background = false;
        for step in 0..10 {
            match s.schedule() {
                Step::Decode(ids) => s.complete_decode(&ids, 2.0 + step as f64),
                Step::Prefill(_) => {}
                Step::Idle => break,
            }
            if s.seq(2).phase == Phase::Preempted {
                preempted_background = true;
                break;
            }
        }
        assert!(preempted_background, "background sequence must be the victim");
        assert!(
            s.kv.prefix_resident(3),
            "the interactive class's idle prefix must survive the pressure"
        );
        assert_eq!(s.seq(3).preemptions, 0);
        assert_eq!(s.kv.prefix_stats().evictions, 0);
        // The victim was later in the (priority-sorted) decode snapshot:
        // it must have been skipped, not decoded while back in `waiting`.
        assert_eq!(s.seq(2).generated, 0, "a just-preempted sequence must not decode");
        assert!(s.kv.check_conservation());
    }

    #[test]
    fn cancel_drops_unfinished_and_spares_finished() {
        let mut s = Scheduler::new(cfg(8, 64));
        s.submit(Request::new(1, 100, 1, 0.0));
        s.submit(Request::new(2, 100, 5, 0.0));
        let _ = s.schedule(); // prefill both
        let _ = s.schedule(); // decode
        s.complete_decode(&[1, 2], 0.1);
        assert_eq!(s.take_finished(), vec![1]);
        // Finished: the race is decided, cancel must refuse.
        assert!(s.cancel(1).is_none());
        assert!(s.try_seq(1).is_some(), "finished sequence stays for metrics");
        // Running: cancelled, KV freed, state gone.
        let req = s.cancel(2).expect("running sequence cancels");
        assert_eq!(req.id, 2);
        assert!(s.try_seq(2).is_none());
        assert_eq!(s.num_running(), 0);
        assert_eq!(s.kv.num_free(), 64);
        assert!(s.kv.check_conservation());
        assert!(s.cancel(99).is_none(), "unknown ids are a no-op");
    }

    #[test]
    fn evacuate_returns_every_unfinished_request_and_frees_the_pool() {
        let mut s = Scheduler::new(ServingConfig {
            prefix_cache_blocks: 8,
            ..cfg(2, 64)
        });
        s.submit(Request::new(1, 128, 5, 0.0).with_prefix(4));
        s.submit(Request::new(2, 128, 5, 0.0));
        s.submit(Request::new(3, 128, 5, 0.0)); // stays waiting (batch cap 2)
        let _ = s.schedule(); // prefill 1, 2
        assert_eq!((s.num_running(), s.num_waiting()), (2, 1));
        let mut reqs = s.evacuate();
        reqs.sort_by_key(|r| r.id);
        assert_eq!(reqs.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2, 3]);
        assert_eq!((s.num_running(), s.num_waiting()), (0, 0));
        assert!(!s.has_work());
        // Pins released: the warm prefix is idle, eviction can reclaim it.
        while s.kv.evict_one_idle_prefix() {}
        assert_eq!(s.kv.num_free(), 64);
        assert!(s.kv.check_conservation());
    }

    #[test]
    fn force_preempt_caps_at_the_running_set() {
        let mut s = Scheduler::new(cfg(8, 256));
        for i in 0..3 {
            s.submit(Request::new(i, 64, 10, 0.0));
        }
        let _ = s.schedule(); // prefill all three
        assert_eq!(s.num_running(), 3);
        assert_eq!(s.force_preempt(5), 3, "only 3 victims exist");
        assert_eq!(s.num_running(), 0);
        assert_eq!(s.num_waiting(), 3);
        assert_eq!(s.take_preempted().len(), 3);
        for i in 0..3 {
            assert_eq!(s.seq(i).phase, Phase::Preempted);
            assert_eq!(s.seq(i).preemptions, 1);
        }
        assert!(s.kv.check_conservation());
    }

    #[test]
    fn cached_decode_order_tracks_membership_changes() {
        // Two decode passes with unchanged membership reuse the cached
        // order; a retirement dirties it and the next pass re-sorts.
        let mut s = Scheduler::new(three_tier_cfg(8, 256));
        s.submit(Request::new(0, 64, 10, 0.0).with_class(2)); // background
        s.submit(Request::new(1, 64, 2, 0.0).with_class(0)); // interactive
        let _ = s.schedule(); // prefill both
        for now in [0.1, 0.2] {
            match s.schedule() {
                Step::Decode(ids) => {
                    assert_eq!(ids, vec![1, 0], "interactive decodes first");
                    s.complete_decode(&ids, now);
                }
                other => panic!("{other:?}"),
            }
        }
        assert_eq!(s.take_finished(), vec![1]);
        match s.schedule() {
            Step::Decode(ids) => assert_eq!(ids, vec![0], "retired id left the order"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn steady_decode_batch_requires_blocked_admission() {
        let mut s = Scheduler::new(cfg(2, 256));
        for i in 0..3 {
            s.submit(Request::new(i, 64, 10, 0.0));
        }
        assert!(s.steady_decode_batch().is_none(), "nothing running yet");
        let _ = s.schedule(); // prefill 0, 1; request 2 blocked by the batch cap
        assert_eq!(s.steady_decode_batch(), Some(&[0u64, 1][..]));
        s.cancel(0); // headroom again: request 2 becomes admissible
        assert!(s.steady_decode_batch().is_none(), "admissible waiting head");
        let _ = s.schedule(); // prefill 2
        assert_eq!(s.steady_decode_batch(), Some(&[1u64, 2][..]), "queue drained");
    }

    #[test]
    fn uniform_priorities_keep_the_legacy_victim_and_eviction_order() {
        // The single-class replay of `idle_prefix_evicted_before_preempting
        // _a_sequence`: with every request in the default class, pressure
        // still evicts the idle prefix first and preempts nobody.
        let mut s = Scheduler::new(ServingConfig {
            prefix_cache_blocks: 8,
            watermark: 0.0,
            ..cfg(4, 8)
        });
        s.submit(Request::new(1, 640, 2, 0.0).with_prefix(3));
        let _ = s.schedule();
        let _ = s.schedule();
        s.complete_decode(&[1], 0.1);
        let _ = s.schedule();
        s.complete_decode(&[1], 0.2);
        assert_eq!(s.take_finished(), vec![1]);
        s.submit(Request::new(2, 384, 200, 1.0));
        s.submit(Request::new(3, 384, 200, 1.0));
        let _ = s.schedule();
        match s.schedule() {
            Step::Decode(ids) => {
                assert_eq!(ids.len(), 2);
                s.complete_decode(&ids, 2.0);
            }
            other => panic!("{other:?}"),
        }
        assert!(!s.kv.prefix_resident(3), "uniform classes evict the idle prefix first");
        assert_eq!(s.seq(2).preemptions + s.seq(3).preemptions, 0);
    }
}
