//! Request and sequence state for the serving engine.

use crate::serving::qos::ClassId;

/// Unique request identifier.
pub type RequestId = u64;

/// An inference request as submitted to the router.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    /// Prompt length in tokens. (The simulated path only needs lengths;
    /// the real PJRT path carries token ids separately.)
    pub prompt_len: usize,
    /// Number of tokens to generate.
    pub max_new_tokens: usize,
    /// Arrival time (seconds, engine clock).
    pub arrival: f64,
    /// Shared-prefix group (system prompt / session id). Requests with the
    /// same group benefit from landing on a replica whose prefix cache is
    /// already warm — `RoutePolicy::PrefixAffinity` keys on this. `None`
    /// means no reusable prefix.
    pub prefix_id: Option<u64>,
    /// Traffic class (`serving::qos`): index into the deployment's
    /// `ServingConfig::classes`, fixing the SLO this request is measured
    /// against, its scheduling priority and its goodput weight. Class 0
    /// — the default class — reproduces the legacy untagged behavior.
    pub class_id: ClassId,
}

impl Request {
    pub fn new(id: RequestId, prompt_len: usize, max_new_tokens: usize, arrival: f64) -> Self {
        assert!(prompt_len > 0 && max_new_tokens > 0);
        Request { id, prompt_len, max_new_tokens, arrival, prefix_id: None, class_id: 0 }
    }

    /// Tag this request as sharing a cached prefix group (builder-style).
    pub fn with_prefix(mut self, prefix_id: u64) -> Self {
        self.prefix_id = Some(prefix_id);
        self
    }

    /// Tag this request with a traffic class (builder-style; see
    /// `serving::qos::TrafficClass`). The scheduler rejects ids outside
    /// the deployment's declared `ServingConfig::classes`.
    pub fn with_class(mut self, class_id: ClassId) -> Self {
        self.class_id = class_id;
        self
    }

    /// Tokens of this request's prompt covered by its shared prefix: the
    /// `PREFIX_HIT_DISCOUNT` fraction a warm hit saves re-prefilling,
    /// which is therefore also the portion the paged KV substrate keeps
    /// resident as ref-counted shared blocks. 0 for untagged requests.
    pub fn prefix_len(&self) -> usize {
        match self.prefix_id {
            Some(_) => {
                ((self.prompt_len as f64 * crate::serving::PREFIX_HIT_DISCOUNT) as usize).max(1)
            }
            None => 0,
        }
    }
}

/// Lifecycle phase of a sequence inside the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Queued, no KV blocks allocated.
    Waiting,
    /// Prompt processed or being processed; producing tokens.
    Running,
    /// Preempted under memory pressure; KV freed, must re-prefill.
    Preempted,
    /// Generation complete.
    Finished,
}

/// Engine-internal state of one sequence.
#[derive(Debug, Clone)]
pub struct Sequence {
    pub req: Request,
    pub phase: Phase,
    /// Tokens currently in the KV cache (prompt + generated).
    pub kv_len: usize,
    /// Generated tokens so far.
    pub generated: usize,
    /// Engine-clock time of first generated token (TTFT measurement).
    pub first_token_time: Option<f64>,
    /// Engine-clock time of completion.
    pub finish_time: Option<f64>,
    /// Times the sequence was preempted (diagnostics / fairness tests).
    pub preemptions: usize,
    /// Whether the *next* prefill of this sequence found its shared
    /// prefix resident (set by the scheduler at admission from actual
    /// block residency; the backend costs the prefill from it).
    pub prefix_hit: bool,
    /// Whether this sequence holds a refcount pin on its prefix group's
    /// shared blocks (released at retirement or preemption).
    pub prefix_pinned: bool,
}

impl Sequence {
    pub fn new(req: Request) -> Self {
        Sequence {
            req,
            phase: Phase::Waiting,
            kv_len: 0,
            generated: 0,
            first_token_time: None,
            finish_time: None,
            preemptions: 0,
            prefix_hit: false,
            prefix_pinned: false,
        }
    }

    pub fn is_done(&self) -> bool {
        self.generated >= self.req.max_new_tokens
    }

    /// Total tokens the sequence will ever hold in KV.
    pub fn max_kv_len(&self) -> usize {
        self.req.prompt_len + self.req.max_new_tokens
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequence_lifecycle_fields() {
        let s = Sequence::new(Request::new(1, 100, 50, 0.0));
        assert_eq!(s.phase, Phase::Waiting);
        assert_eq!(s.max_kv_len(), 150);
        assert!(!s.is_done());
    }

    #[test]
    #[should_panic]
    fn zero_prompt_rejected() {
        Request::new(1, 0, 10, 0.0);
    }

    #[test]
    fn prefix_tagging_is_opt_in() {
        assert_eq!(Request::new(1, 10, 10, 0.0).prefix_id, None);
        assert_eq!(Request::new(1, 10, 10, 0.0).with_prefix(7).prefix_id, Some(7));
    }

    #[test]
    fn class_tagging_defaults_to_the_default_class() {
        assert_eq!(Request::new(1, 10, 10, 0.0).class_id, 0);
        assert_eq!(Request::new(1, 10, 10, 0.0).with_class(2).class_id, 2);
        // Builders compose.
        let r = Request::new(1, 10, 10, 0.0).with_prefix(7).with_class(1);
        assert_eq!((r.prefix_id, r.class_id), (Some(7), 1));
    }

    #[test]
    fn prefix_len_is_the_discounted_share() {
        assert_eq!(Request::new(1, 1000, 10, 0.0).prefix_len(), 0);
        let tagged = Request::new(1, 1000, 10, 0.0).with_prefix(3);
        assert_eq!(
            tagged.prefix_len(),
            (1000.0 * crate::serving::PREFIX_HIT_DISCOUNT) as usize
        );
        // Tiny prompts still pin at least one token of prefix.
        assert_eq!(Request::new(1, 1, 10, 0.0).with_prefix(3).prefix_len(), 1);
    }
}
